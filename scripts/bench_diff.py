#!/usr/bin/env python3
"""Bench trend diff — fail CI on perf regressions of the fused path.

Compares the bench result files the CI run just wrote at the repo root
(BENCH_kernels.json from benches/kernels_micro.rs, BENCH_serve.json from
benches/serve_decode.rs, BENCH_finetune.json from
benches/finetune_step.rs) against committed baselines under
scripts/baselines/, and exits non-zero when the fused hot path regressed
by more than the threshold (default 20%):

* kernels: per (bits, group) config, the fused packed GEMM's mean_s may
  not exceed baseline * (1 + threshold). Gating requires the same SIMD
  dispatch tier (top-level simd_path key) — a baseline that predates the
  key or was recorded under another tier skips with a notice; the
  in-process SIMD-vs-scalar speedup is reported alongside;
* serve: tokens_per_s may not drop below baseline * (1 - threshold).
  Swap-time drift is reported but only warns (microsecond-scale numbers
  are too noisy to gate on); paged-KV page accounting (kv_pages_peak /
  kv_pages_shared / kv_exhausted_count) is reported only;
* finetune: the host PEQA training step's step_mean_s may not exceed
  baseline * (1 + threshold); final-loss drift is reported but only
  warns (it tracks data/seed config, not the hot path).

Baselines are only comparable when they were produced with the same
bench configuration (dim/threads/quick for kernels; geometry/threads/
quick for serve); a config mismatch skips the comparison with a notice
instead of failing, since CI machines differ.

Usage:
  scripts/bench_diff.py [--threshold 0.2] [--update] [--only FILE]

--update copies the current result files into scripts/baselines/
(seeding them on first run, refreshing after an accepted perf change).
--only restricts the run to one result file (repeatable) — ci.sh uses
it to seed a missing baseline without touching a committed one.
A missing baseline or missing current file is a notice, not a failure.
"""

import argparse
import json
import pathlib
import shutil
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
BASELINES = ROOT / "scripts" / "baselines"
FILES = ["BENCH_kernels.json", "BENCH_serve.json", "BENCH_finetune.json"]


def load(path):
    with open(path) as f:
        return json.load(f)


def config_matches(cur, base, keys):
    diffs = [k for k in keys if cur.get(k) != base.get(k)]
    if diffs:
        print(f"  config changed ({', '.join(diffs)}) — baseline not comparable, skipping")
        return False
    return True


def diff_kernels(cur, base, thr):
    fails = []
    if not config_matches(cur, base, ["dim", "threads", "quick"]):
        return fails
    # Timings are only comparable under the same SIMD dispatch tier. A
    # baseline written before the simd work lacks the key entirely; a
    # baseline recorded on a different host may carry another tier —
    # both skip cleanly (reseed with --update on the gating host).
    if cur.get("simd_path") != base.get("simd_path"):
        if base.get("simd_path") is None:
            print(
                "  baseline predates the simd_path key — timings not comparable, "
                "skipping (reseed with scripts/bench_diff.py --update)"
            )
        else:
            print(
                f"  simd dispatch differs (current {cur.get('simd_path')}, baseline "
                f"{base.get('simd_path')}) — timings not comparable, skipping"
            )
        return fails
    bidx = {
        (e.get("bits"), e.get("group"), e.get("path")): e for e in base.get("results", [])
    }
    for e in cur.get("results", []):
        if e.get("path") != "fused":
            continue
        b = bidx.get((e.get("bits"), e.get("group"), e.get("path")))
        if b is None or not b.get("mean_s"):
            continue
        ratio = e["mean_s"] / b["mean_s"]
        line = (
            f"  fused b{e['bits']}/{e['group']}: {e['mean_s'] * 1e3:.2f} ms "
            f"vs baseline {b['mean_s'] * 1e3:.2f} ms ({ratio:.0%} of baseline)"
        )
        # The in-process SIMD-vs-scalar ratio rides along (reported only:
        # it is a roofline scoreboard, not a regression axis of its own —
        # a scalar-tier slowdown already shows up in the gated mean_s).
        if e.get("speedup_vs_scalar"):
            line += f", {e['speedup_vs_scalar']:.2f}x vs scalar tier"
        if ratio > 1.0 + thr:
            fails.append(line + f"  REGRESSION > +{thr:.0%}")
            print(line + "  ** REGRESSION **")
        else:
            print(line)
    return fails


def diff_serve(cur, base, thr):
    fails = []
    if not config_matches(
        cur, base, ["quick", "threads", "n_layers", "d_model", "bits", "requests"]
    ):
        return fails
    tps_cur, tps_base = cur.get("tokens_per_s", 0.0), base.get("tokens_per_s", 0.0)
    if tps_base > 0:
        ratio = tps_cur / tps_base
        line = f"  tokens/s: {tps_cur:.1f} vs baseline {tps_base:.1f} ({ratio:.0%} of baseline)"
        if ratio < 1.0 - thr:
            fails.append(line + f"  REGRESSION > -{thr:.0%}")
            print(line + "  ** REGRESSION **")
        else:
            print(line)
    sw_cur, sw_base = cur.get("swap_p99_s", 0.0), base.get("swap_p99_s", 0.0)
    if sw_base > 0:
        drift = sw_cur / sw_base
        note = " (warn only — not gated)" if drift > 1.0 + thr else ""
        print(f"  swap p99: {sw_cur * 1e3:.4f} ms vs baseline {sw_base * 1e3:.4f} ms{note}")
    # Pooled-serving latency axes (engine pool, streaming clients): gated
    # the same way as throughput — a baseline that predates the pool
    # simply lacks the keys and skips. shed_count/queue_depth_max are
    # load-shape facts, reported but not gated.
    for key, label in [
        ("ttft_p99_s", "pool TTFT p99"),
        ("inter_token_p99_s", "pool inter-token p99"),
    ]:
        lat_cur, lat_base = cur.get(key, 0.0), base.get(key, 0.0)
        if lat_base <= 0:
            continue
        ratio = lat_cur / lat_base
        line = (
            f"  {label}: {lat_cur * 1e3:.3f} ms vs baseline "
            f"{lat_base * 1e3:.3f} ms ({ratio:.0%} of baseline)"
        )
        if ratio > 1.0 + thr:
            fails.append(line + f"  REGRESSION > +{thr:.0%}")
            print(line + "  ** REGRESSION **")
        else:
            print(line)
    if base.get("shed_count") is not None:
        print(
            f"  pool shed: {cur.get('shed_count', 0):.0f} vs baseline "
            f"{base.get('shed_count', 0):.0f}; queue depth max "
            f"{cur.get('queue_depth_max', 0):.0f} vs {base.get('queue_depth_max', 0):.0f} "
            "(reported only)"
        )
    # Paged-KV same-prefix section (serve::kvpage): page accounting is a
    # memory/admission shape, not a timing, so it is reported only — a
    # baseline predating the paged backend lacks the keys and skips.
    if base.get("kv_pages_peak") is not None:
        print(
            f"  paged kv: peak {cur.get('kv_pages_peak', 0):.0f} vs baseline "
            f"{base.get('kv_pages_peak', 0):.0f} pages; shared "
            f"{cur.get('kv_pages_shared', 0):.0f} vs {base.get('kv_pages_shared', 0):.0f}; "
            f"exhausted rejects {cur.get('kv_exhausted_count', 0):.0f} vs "
            f"{base.get('kv_exhausted_count', 0):.0f} (reported only)"
        )
    return fails


def diff_finetune(cur, base, thr):
    fails = []
    if not config_matches(
        cur,
        base,
        ["quick", "threads", "n_layers", "d_model", "d_ff", "bits", "group", "steps", "batch", "seq"],
    ):
        return fails
    st_cur, st_base = cur.get("step_mean_s", 0.0), base.get("step_mean_s", 0.0)
    if st_base > 0:
        ratio = st_cur / st_base
        line = (
            f"  finetune step: {st_cur * 1e3:.2f} ms vs baseline "
            f"{st_base * 1e3:.2f} ms ({ratio:.0%} of baseline)"
        )
        if ratio > 1.0 + thr:
            fails.append(line + f"  REGRESSION > +{thr:.0%}")
            print(line + "  ** REGRESSION **")
        else:
            print(line)
    fl_cur, fl_base = cur.get("final_loss", 0.0), base.get("final_loss", 0.0)
    if fl_base > 0:
        drift = fl_cur / fl_base
        note = " (warn only — not gated)" if abs(drift - 1.0) > thr else ""
        print(f"  final loss: {fl_cur:.4f} vs baseline {fl_base:.4f}{note}")
    return fails


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--threshold", type=float, default=0.2)
    ap.add_argument("--update", action="store_true", help="refresh the committed baselines")
    ap.add_argument(
        "--only",
        action="append",
        choices=FILES,
        help="restrict to one result file (repeatable)",
    )
    args = ap.parse_args()

    fails = []
    for name in args.only or FILES:
        cur_path = ROOT / name
        base_path = BASELINES / name
        print(f"== {name} ==")
        if not cur_path.exists():
            print(f"  {cur_path} not found (bench not run) — skipping")
            continue
        if args.update:
            BASELINES.mkdir(parents=True, exist_ok=True)
            shutil.copyfile(cur_path, base_path)
            print(f"  baseline updated → {base_path.relative_to(ROOT)}")
            continue
        if not base_path.exists():
            print(
                f"  no committed baseline ({base_path.relative_to(ROOT)}); "
                "run scripts/bench_diff.py --update to seed it"
            )
            continue
        cur, base = load(cur_path), load(base_path)
        if name == "BENCH_kernels.json":
            fails += diff_kernels(cur, base, args.threshold)
        elif name == "BENCH_serve.json":
            fails += diff_serve(cur, base, args.threshold)
        else:
            fails += diff_finetune(cur, base, args.threshold)

    if fails:
        print(f"\nFAIL: {len(fails)} fused-path regression(s) beyond {args.threshold:.0%}:")
        for f in fails:
            print(f)
        return 1
    print("\nbench trend ok")
    return 0


if __name__ == "__main__":
    sys.exit(main())
