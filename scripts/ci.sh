#!/usr/bin/env bash
# CI entrypoint: tier-1 verify plus smoke-mode benches so every PR leaves
# perf datapoints (BENCH_kernels.json + BENCH_serve.json at the repo
# root), then the trend diff that fails on >20% fused-path regressions.
#
#   scripts/ci.sh            tier-1 + quick kernels_micro + serve_decode
#   scripts/ci.sh --full     same, but the benches run at full size
#                            (4096x4096 GEMM / 4-layer serve model — the
#                            acceptance measurements)
#
# The default build has no xla feature (the vendored PJRT crate is not in
# the registry); artifact-driven tests skip themselves.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== tier-1: cargo build --release =="
cargo build --release

echo "== tier-1: cargo test -q =="
cargo test -q

QUICK=1
if [[ "${1:-}" == "--full" ]]; then
  QUICK=0
fi

echo "== kernels_micro bench (PEQA_BENCH_QUICK=$QUICK) =="
# PEQA_BENCH_OUT pins the output path regardless of the bench's cwd
# (cargo runs bench binaries with cwd = the package root, rust/).
PEQA_BENCH_QUICK=$QUICK PEQA_BENCH_OUT="$PWD/BENCH_kernels.json" \
  cargo bench -p peqa --bench kernels_micro

test -s BENCH_kernels.json
echo "== ok: BENCH_kernels.json written =="

echo "== serve_decode bench (PEQA_BENCH_QUICK=$QUICK) =="
PEQA_BENCH_QUICK=$QUICK PEQA_BENCH_OUT="$PWD/BENCH_serve.json" \
  cargo bench -p peqa --bench serve_decode

test -s BENCH_serve.json
echo "== ok: BENCH_serve.json written =="

echo "== bench trend diff (scripts/baselines/) =="
if command -v python3 >/dev/null 2>&1; then
  python3 scripts/bench_diff.py
else
  echo "python3 not found; skipping bench trend diff"
fi
