#!/usr/bin/env bash
# CI entrypoint: tier-1 verify plus a smoke-mode kernel bench so every PR
# leaves a perf datapoint (BENCH_kernels.json at the repo root).
#
#   scripts/ci.sh            tier-1 + quick kernels_micro bench
#   scripts/ci.sh --full     same, but the bench runs at full size
#                            (4096x4096, the acceptance measurement)
#
# The default build has no xla feature (the vendored PJRT crate is not in
# the registry); artifact-driven tests skip themselves.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== tier-1: cargo build --release =="
cargo build --release

echo "== tier-1: cargo test -q =="
cargo test -q

QUICK=1
if [[ "${1:-}" == "--full" ]]; then
  QUICK=0
fi

echo "== kernels_micro bench (PEQA_BENCH_QUICK=$QUICK) =="
# PEQA_BENCH_OUT pins the output path regardless of the bench's cwd
# (cargo runs bench binaries with cwd = the package root, rust/).
PEQA_BENCH_QUICK=$QUICK PEQA_BENCH_OUT="$PWD/BENCH_kernels.json" \
  cargo bench -p peqa --bench kernels_micro

test -s BENCH_kernels.json
echo "== ok: BENCH_kernels.json written =="
