#!/usr/bin/env bash
# CI entrypoint: tier-1 verify plus smoke-mode benches so every PR leaves
# perf datapoints (BENCH_kernels.json + BENCH_serve.json +
# BENCH_finetune.json at the repo root), then the trend diff that fails
# on >20% fused-path regressions.
#
#   scripts/ci.sh            tier-1 + quick kernels_micro + serve_decode
#                            + finetune_step (host PEQA training smoke)
#   scripts/ci.sh --full     same, but the benches run at full size
#                            (4096x4096 GEMM / 4-layer serve model / 4-layer
#                            40-step finetune — the acceptance measurements)
#
# The default build has no xla feature (the vendored PJRT crate is not in
# the registry); artifact-driven tests skip themselves.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== tier-1: cargo build --release =="
cargo build --release

PEQA_BIN=target/release/peqa

echo "== lint gate: peqa lint rust/src =="
# The in-tree static analysis (rust/src/lint/): determinism,
# panic-freedom, and hot-path invariants over the shipped sources. Runs
# before the tests so a lint finding fails fast; the JSON artifact lands
# next to the BENCH_*.json files for the trend tooling. Exit is nonzero
# on any finding, so `set -e` gates; the artifact is written first so a
# red run still leaves the machine-readable report.
"$PEQA_BIN" lint rust/src --json > LINT.json || { cat LINT.json; exit 1; }
"$PEQA_BIN" lint rust/src
echo "== ok: lint clean, LINT.json written =="

echo "== tier-1: cargo test -q =="
cargo test -q

echo "== simd dispatch slice: kernel/parity tests under scalar and auto =="
# The SIMD tiers are bitwise-identical to the scalar baseline by
# contract. The full suite above ran under the host default dispatch;
# re-run the kernel + parity slice pinned to each end of the dispatch
# (PEQA_SIMD is read once per process, so each setting needs its own
# run). `simd` catches the tier parity fuzzers and the dispatch test,
# `parity` the trainer-vs-engine / fused-vs-reference suites.
PEQA_SIMD=scalar cargo test -q -- simd parity
PEQA_SIMD=auto cargo test -q -- simd parity
echo "== ok: dispatch-pinned test slice =="

echo "== sanitizer pass (opt-in: PEQA_SANITIZE=1) =="
# Deep UB/race hunting is too slow for every CI run, so it is an opt-in
# stage: Miri when the toolchain has it (UB, aliasing, leaks), TSan as
# the fallback (data races across the serve::/store:: thread pools).
# Both runs scope to the concurrent suites — all `unsafe` in the crate
# is confined to quant::simd under the `unsafe-confined` lint rule
# (each site carries a // SAFETY: argument); the rest is safe code.
if [[ "${PEQA_SANITIZE:-0}" == "1" ]]; then
  if cargo miri --version >/dev/null 2>&1; then
    echo "== miri: serve + store test suites =="
    MIRIFLAGS="-Zmiri-disable-isolation" \
      cargo miri test -q -- serve:: store::
  elif rustc +nightly --version >/dev/null 2>&1; then
    echo "== tsan (nightly fallback): serve + store test suites =="
    RUSTFLAGS="-Zsanitizer=thread" RUSTDOCFLAGS="-Zsanitizer=thread" \
      cargo +nightly test -q --target "$(rustc -vV | sed -n 's/^host: //p')" \
      -- serve:: store::
  else
    echo "PEQA_SANITIZE=1 set, but neither \`cargo miri\` nor a nightly"
    echo "toolchain is installed — sanitizer stage skipped. Install one"
    echo "(rustup +nightly component add miri) to arm it."
  fi
else
  echo "PEQA_SANITIZE not set — skipping (set PEQA_SANITIZE=1 to run miri/TSan)"
fi

echo "== host backward numerics cross-check (python/checks) =="
# The f64 numpy finite-difference cross-check of the host PEQA backward
# (rust/src/train/host.rs + model/blocks.rs). Guards the refactored
# backward on every CI run that has numpy; PEQA_SKIP_PYCHECK=1 opts out.
if [[ "${PEQA_SKIP_PYCHECK:-0}" == "1" ]]; then
  echo "PEQA_SKIP_PYCHECK=1 — skipping host_backward_check.py"
elif command -v python3 >/dev/null 2>&1 && python3 -c "import numpy" >/dev/null 2>&1; then
  python3 python/checks/host_backward_check.py
else
  echo "python3 with numpy not available — skipping host_backward_check.py"
  echo "(install numpy to arm the backward cross-check, or set PEQA_SKIP_PYCHECK=1 to silence)"
fi

QUICK=1
if [[ "${1:-}" == "--full" ]]; then
  QUICK=0
fi

echo "== kernels_micro bench (PEQA_BENCH_QUICK=$QUICK) =="
# PEQA_BENCH_OUT pins the output path regardless of the bench's cwd
# (cargo runs bench binaries with cwd = the package root, rust/).
PEQA_BENCH_QUICK=$QUICK PEQA_BENCH_OUT="$PWD/BENCH_kernels.json" \
  cargo bench -p peqa --bench kernels_micro

test -s BENCH_kernels.json
echo "== ok: BENCH_kernels.json written =="

echo "== serve_decode bench (PEQA_BENCH_QUICK=$QUICK) =="
PEQA_BENCH_QUICK=$QUICK PEQA_BENCH_OUT="$PWD/BENCH_serve.json" \
  cargo bench -p peqa --bench serve_decode

test -s BENCH_serve.json
echo "== ok: BENCH_serve.json written =="

echo "== finetune_step bench — host PEQA training smoke (PEQA_BENCH_QUICK=$QUICK) =="
PEQA_BENCH_QUICK=$QUICK PEQA_BENCH_OUT="$PWD/BENCH_finetune.json" \
  cargo bench -p peqa --bench finetune_step

test -s BENCH_finetune.json
echo "== ok: BENCH_finetune.json written =="

echo "== bench trend diff (scripts/baselines/) =="
if command -v python3 >/dev/null 2>&1; then
  # Per result file: no committed baseline yet → seed it from the result
  # this run just produced (and remind the operator to commit it);
  # baseline present → diff against it and fail on >20% fused-path
  # regressions. Per-file so seeding one missing baseline never
  # overwrites a committed one.
  SEEDED=0
  for bf in BENCH_kernels.json BENCH_serve.json BENCH_finetune.json; do
    if [[ ! -f "scripts/baselines/$bf" ]]; then
      python3 scripts/bench_diff.py --update --only "$bf"
      SEEDED=1
    else
      python3 scripts/bench_diff.py --only "$bf"
    fi
  done
  if [[ "$SEEDED" == "1" ]]; then
    echo "== seeded scripts/baselines/ from this run — commit them so the trend diff gates =="
  fi
elif [[ "${PEQA_SKIP_TREND:-0}" == "1" ]]; then
  echo "python3 not found; PEQA_SKIP_TREND=1 — skipping bench trend diff"
else
  echo "ERROR: python3 not found — the bench trend diff cannot run, so perf" >&2
  echo "regressions would pass silently. Install python3 or set PEQA_SKIP_TREND=1" >&2
  echo "to skip the gate knowingly." >&2
  exit 1
fi

echo "== store durability smoke: kill+resume bitwise, publish, fsck =="
# End-to-end check of the store:: guarantees through the real CLI: a
# journaled run killed mid-flight (--halt-after simulates the crash) and
# resumed must produce an adapter byte-identical to a run that was never
# interrupted; every artifact the flow wrote must pass `peqa fsck`.
SMOKE=$(mktemp -d)
trap 'rm -rf "$SMOKE"' EXIT
# Reference: one uninterrupted journaled run.
"$PEQA_BIN" finetune --task smoke --out "$SMOKE/full" --steps 8 --save-every 3 \
  --batch 2 --seq 16 --seed 11 --eval-tokens 0
# Same run killed after step 4 (journal durable through step 3), then
# resumed from disk alone and published.
"$PEQA_BIN" finetune --task smoke --out "$SMOKE/part" --steps 8 --save-every 3 \
  --batch 2 --seq 16 --seed 11 --eval-tokens 0 --halt-after 4
"$PEQA_BIN" finetune --task smoke --out "$SMOKE/part" --resume --eval-tokens 0 \
  --publish "$SMOKE/registry"
cmp "$SMOKE/full/smoke.adapter" "$SMOKE/part/smoke.adapter"
echo "== ok: resumed adapter is byte-identical to the uninterrupted run =="
"$PEQA_BIN" fsck "$SMOKE/full" "$SMOKE/part" "$SMOKE/registry"
echo "== ok: store durability smoke =="

echo "== multi-task journal smoke: round-robin kill+resume bitwise =="
# Satellite of the paged-KV PR: a journaled --tasks run (one journal,
# one slot per task per checkpoint round) killed mid-run and resumed
# must produce per-task adapters byte-identical to a run that was never
# interrupted, and every artifact must pass fsck.
"$PEQA_BIN" finetune --tasks ta,tb --out "$SMOKE/multi_full" --steps 8 --save-every 3 \
  --batch 2 --seq 16 --seed 11 --eval-tokens 0
"$PEQA_BIN" finetune --tasks ta,tb --out "$SMOKE/multi_part" --steps 8 --save-every 3 \
  --batch 2 --seq 16 --seed 11 --eval-tokens 0 --halt-after 4
"$PEQA_BIN" finetune --tasks ta,tb --out "$SMOKE/multi_part" --resume --eval-tokens 0
cmp "$SMOKE/multi_full/ta.adapter" "$SMOKE/multi_part/ta.adapter"
cmp "$SMOKE/multi_full/tb.adapter" "$SMOKE/multi_part/tb.adapter"
"$PEQA_BIN" fsck "$SMOKE/multi_part"
echo "== ok: multi-task resume is byte-identical per task =="

echo "== registry gc smoke: prune superseded generations, keep the live set =="
# Publish a second generation into the same registry, gc with keep-last
# 1, and verify the registry still loads (the live manifest's files are
# never pruned) and fsck stays green.
"$PEQA_BIN" finetune --task smoke --out "$SMOKE/part2" --steps 8 --save-every 3 \
  --batch 2 --seq 16 --seed 11 --eval-tokens 0
"$PEQA_BIN" finetune --task smoke --out "$SMOKE/part2" --resume --eval-tokens 0 \
  --publish "$SMOKE/registry" --gc-keep 1
"$PEQA_BIN" fsck "$SMOKE/registry"
echo "== ok: registry gc smoke =="

echo "== pooled serve smoke: --engines 2, concurrent streaming clients =="
# The sharded engine pool end to end through the CLI: 2 workers sharing
# one set of packed codes, 2 concurrent streaming clients, bounded
# ingress + task-affine dispatch. Greedy decode keeps it deterministic.
"$PEQA_BIN" serve --engines 2 --clients 2 --stream --requests 12 \
  --max-new 12 --tasks 3 --seed 7
echo "== ok: pooled serve smoke =="

echo "== paged-KV prefix-sharing smoke: tight page budget, CoW prefix =="
# The serve::kvpage memory claim end to end through the CLI: 8 clients
# of one task fork from a 32-token prompt prefix (prompt 33 + 8 new =
# 11 pages each at 4 tokens/page). Unshared, the batch would need 88
# pages — and 8 full-window ring buffers would hold 512 token slots —
# but the pool holds only 40 pages (160 slots). The run can only fit by
# CoW-attaching the shared prefix pages; --require-shared makes the
# binary exit nonzero if kv_pages_shared stays 0.
"$PEQA_BIN" serve --kv-pages 40 --page-tokens 4 --prefix-tokens 32 \
  --requests 8 --max-new 8 --tasks 1 --batch 4 --window 64 --seed 7 \
  --require-shared
echo "== ok: paged-KV prefix-sharing smoke =="
