//! Fixture-driven tests for `peqa::lint` — the contract behind the
//! `scripts/ci.sh` lint gate.
//!
//! Each file in `tests/fixtures/lint/` carries positive cases and
//! near-miss negatives for one rule (plus one fixture for the
//! suppression grammar). Expected findings are marked in-line:
//! `//~ <rule>` expects a finding on the same line, `//~^ <rule>` one
//! line up per `^`. A fixture passes only if the diagnostics match the
//! markers EXACTLY — extras are as fatal as misses, which is what keeps
//! the near-miss negatives honest.
//!
//! Fixtures are linted under *virtual* module paths (`lint::modpath_of`
//! maps a path without a `src` component straight to a module path), so
//! one file can be checked both in and out of a rule's scope.

use std::collections::BTreeSet;
use std::path::Path;

use peqa::lint;

fn fixture(name: &str) -> String {
    let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/lint").join(name);
    std::fs::read_to_string(&p).unwrap_or_else(|e| panic!("reading {}: {e}", p.display()))
}

/// Parse `//~` markers. A marker is `//~` + optional `^`s + one or more
/// kebab-case rule names and nothing else to end-of-line; prose that
/// merely mentions ``//~`` (backticks, punctuation) is ignored.
fn expected(src: &str) -> BTreeSet<(u32, String)> {
    let mut want = BTreeSet::new();
    for (i, line) in src.lines().enumerate() {
        let Some(pos) = line.find("//~") else { continue };
        let rest = &line[pos + 3..];
        let carets = rest.chars().take_while(|&c| c == '^').count();
        let names: Vec<&str> = rest[carets..].split_whitespace().collect();
        if names.is_empty()
            || !names
                .iter()
                .all(|n| n.chars().all(|c| c == '-' || c.is_ascii_lowercase()))
        {
            continue;
        }
        let target = (i + 1 - carets) as u32;
        for n in names {
            want.insert((target, n.to_string()));
        }
    }
    want
}

/// Lint `name` under `vpath`; keep `rule` findings (always keeping
/// allow-hygiene — suppression misuse must never pass unnoticed) and
/// compare against the fixture's own markers.
fn check(name: &str, vpath: &str, rule: Option<&str>) {
    let src = fixture(name);
    let got: BTreeSet<(u32, String)> = lint::lint_source(vpath, &src, rule)
        .into_iter()
        .map(|d| (d.line, d.rule.to_string()))
        .collect();
    let want = expected(&src);
    assert_eq!(
        got, want,
        "fixture {name} under {vpath}: diagnostics do not match `//~` markers"
    );
}

/// Lint `name` under an out-of-scope `vpath`: every rule silent.
fn check_silent(name: &str, vpath: &str) {
    let src = fixture(name);
    let got = lint::lint_source(vpath, &src, None);
    assert!(
        got.is_empty(),
        "fixture {name} must be silent under out-of-scope path {vpath}, got:\n{}",
        lint::render_text(&got)
    );
}

#[test]
fn nan_comparator_fixture() {
    // Global rule — any path is in scope.
    check("nan_comparator.rs", "eval/fixture.rs", Some("nan-comparator"));
    check("nan_comparator.rs", "serve/fixture.rs", Some("nan-comparator"));
}

#[test]
fn panic_free_fixture() {
    check("panic_free.rs", "serve/fixture.rs", Some("panic-free-paths"));
    check("panic_free.rs", "store/fixture.rs", Some("panic-free-paths"));
    check_silent("panic_free.rs", "eval/fixture.rs");
}

#[test]
fn hot_path_alloc_fixture() {
    check("hot_path_alloc.rs", "quant/kernels.rs", Some("hot-path-alloc"));
    check("hot_path_alloc.rs", "model/blocks.rs", Some("hot-path-alloc"));
    // Scope is exact-module: a sibling kernel-adjacent file is out.
    check_silent("hot_path_alloc.rs", "quant/pack.rs");
    check_silent("hot_path_alloc.rs", "eval/fixture.rs");
}

#[test]
fn float_reduction_fixture() {
    check("float_reduction.rs", "model/blocks.rs", Some("float-reduction-order"));
    check("float_reduction.rs", "quant/kernels.rs", Some("float-reduction-order"));
    check_silent("float_reduction.rs", "train/host.rs");
}

#[test]
fn unsafe_confined_fixture() {
    // Inside quant::simd the rule enforces the SAFETY-comment discipline.
    check("unsafe_confined.rs", "quant/simd.rs", Some("unsafe-confined"));
    // Anywhere else any `unsafe` is a finding, commented or not — even
    // in a sibling module of quant::simd.
    check("unsafe_outside.rs", "serve/fixture.rs", Some("unsafe-confined"));
    check("unsafe_outside.rs", "quant/kernels.rs", Some("unsafe-confined"));
}

#[test]
fn lock_across_blocking_fixture() {
    check("lock_blocking.rs", "serve/fixture.rs", Some("lock-across-blocking"));
    check_silent("lock_blocking.rs", "train/fixture.rs");
}

#[test]
fn nondeterminism_fixture() {
    check("nondeterminism.rs", "store/fixture.rs", Some("nondeterminism-sources"));
}

#[test]
fn nondeterminism_scope_flips() {
    // Hash containers are only an artifact-path concern: serve:: keeps
    // its DashMap-free HashMaps behind locks and is out of hash scope.
    let hash = "pub fn f() -> usize {\n    let m: HashMap<u32, u32> = Default::default();\n    m.len()\n}\n";
    assert!(lint::lint_source("serve/fixture.rs", hash, None).is_empty());
    assert_eq!(lint::lint_source("store/fixture.rs", hash, None).len(), 1);

    // Clocks are the JOB of bench/util::stats/util::log.
    let clock = "pub fn f() -> std::time::Instant {\n    Instant::now()\n}\n";
    assert!(lint::lint_source("bench/decode.rs", clock, None).is_empty());
    assert!(lint::lint_source("util/stats.rs", clock, None).is_empty());
    assert!(lint::lint_source("util/log.rs", clock, None).is_empty());
    assert_eq!(lint::lint_source("serve/fixture.rs", clock, None).len(), 1);
    assert_eq!(lint::lint_source("util/mod.rs", clock, None).len(), 1);

    // Bare thread::spawn is banned everywhere, even in bench.
    let spawn = "pub fn f() {\n    let h = std::thread::spawn(|| ());\n    let _ = h.join();\n}\n";
    assert_eq!(lint::lint_source("bench/decode.rs", spawn, None).len(), 1);
}

#[test]
fn suppression_fixture() {
    // No rule filter: the allow grammar is exercised against live rules.
    check("suppression.rs", "serve/fixture.rs", None);
}

#[test]
fn unknown_rule_filter_is_an_error() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("src/lint");
    let err = lint::run(&[dir.to_string_lossy().into_owned()], Some("no-such-rule"))
        .expect_err("unknown rule must be rejected, not silently match nothing");
    assert!(err.to_string().contains("no-such-rule"), "{err:#}");
}

#[test]
fn run_is_deterministic_and_json_is_stable() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("src/lint");
    let paths = vec![dir.to_string_lossy().into_owned()];
    let a = lint::run(&paths, None).expect("lint src/lint");
    let b = lint::run(&paths, None).expect("lint src/lint");
    assert_eq!(lint::render_text(&a), lint::render_text(&b));
    assert_eq!(lint::to_json(&a).to_string(), lint::to_json(&b).to_string());
}

/// The acceptance gate itself: the shipped tree is lint-clean — every
/// remaining exemption is a justified `peqa-lint: allow`, and CI runs
/// the same check through `peqa lint rust/src`.
#[test]
fn shipped_tree_is_lint_clean() {
    let src_dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let diags = lint::run(&[src_dir.to_string_lossy().into_owned()], None)
        .expect("linting src tree");
    assert!(
        diags.is_empty(),
        "shipped tree has lint findings — fix them or add a justified allow:\n{}",
        lint::render_text(&diags)
    );
}
