//! Integration tests over the live artifact runtime: every layer boundary
//! (pytest-verified python quantization ↔ rust quantization, HLO train
//! steps, eval, serving coordinator) is cross-checked here.
//!
//! Requires `make artifacts` to have run (skipped gracefully otherwise)
//! and a build with the `xla` feature (the whole file is gated on it —
//! the host kernel layer has its own in-crate tests).
#![cfg(feature = "xla")]

use peqa::config::TrainConfig;
use peqa::coordinator::{AdapterStore, BatcherConfig, Coordinator, SwitchMode};
use peqa::data::LmBatcher;
use peqa::eval::EvalModel;
use peqa::model::Checkpoint;
use peqa::pipeline::{self, Ctx};
use peqa::runtime::{literal_to_tensor, tensor_to_literal, Runtime};
use peqa::tensor::Tensor;
use peqa::train::{Trainer, Tuner};
use peqa::util::Pcg32;

fn ctx() -> Option<Ctx> {
    match Ctx::new() {
        Ok(c) => Some(c),
        Err(_) => {
            eprintln!("skipping: artifacts/ not built");
            None
        }
    }
}

#[test]
fn rust_rtn_matches_pallas_kernel_bitwise() {
    // The same matrix through quant::rtn (rust) and the kernel_rtn_256
    // artifact (Pallas, interpret mode) must agree exactly.
    let Some(ctx) = ctx() else { return };
    let art = ctx.rt.load("kernel_rtn_256").unwrap();
    let mut rng = Pcg32::new(42);
    let w = Tensor::normal(&[256, 256], 0.5, &mut rng);
    let outs = art.run(&[tensor_to_literal(&w).unwrap()]).unwrap();
    let wq_k = literal_to_tensor(&outs[0], &[256, 256]).unwrap();
    let s_k = literal_to_tensor(&outs[1], &[256, 4]).unwrap();
    let z_k = literal_to_tensor(&outs[2], &[256, 4]).unwrap();

    let q = peqa::quant::quantize_rtn(&w, 4, Some(64)).unwrap();
    let wq_r = Tensor::new(&[256, 256], q.codes.iter().map(|&c| c as f32).collect());
    // Rounding ties can differ by one code on isolated elements (fp
    // reduction order); everything else must be identical.
    let diff: usize = wq_k
        .data()
        .iter()
        .zip(wq_r.data())
        .filter(|(a, b)| (**a - **b).abs() > 0.5)
        .count();
    assert!(diff * 1000 < wq_k.len(), "codes differ on {diff}/{}", wq_k.len());
    assert!(s_k.max_abs_diff(&q.scales) < 1e-6);
    assert!(z_k.max_abs_diff(&q.zeros) < 1e-6);
}

#[test]
fn qmatmul_artifact_matches_rust_dequant_matmul() {
    let Some(ctx) = ctx() else { return };
    let art = ctx.rt.load("kernel_qmatmul_256").unwrap();
    let mut rng = Pcg32::new(7);
    let w = Tensor::normal(&[256, 256], 0.3, &mut rng);
    let x = Tensor::normal(&[8, 256], 1.0, &mut rng);
    let q = peqa::quant::quantize_rtn(&w, 4, Some(64)).unwrap();
    let wq = Tensor::new(&[256, 256], q.codes.iter().map(|&c| c as f32).collect());
    let outs = art
        .run(&[
            tensor_to_literal(&x).unwrap(),
            tensor_to_literal(&wq).unwrap(),
            tensor_to_literal(&q.scales).unwrap(),
            tensor_to_literal(&q.zeros).unwrap(),
        ])
        .unwrap();
    let y = literal_to_tensor(&outs[0], &[8, 256]).unwrap();
    let y_ref = x.matmul(&q.dequantize().t()).unwrap();
    assert!(y.max_abs_diff(&y_ref) < 1e-3, "{}", y.max_abs_diff(&y_ref));
}

#[test]
fn train_step_decreases_loss_and_freezes_codes() {
    let Some(ctx) = ctx() else { return };
    let meta = ctx.rt.meta("n1_train_peqa_b4_gc").unwrap();
    // Build a quantized model from random fp weights via the prep artifact.
    let fp_metas: Vec<_> = ctx
        .rt
        .meta("n1_train_full")
        .unwrap()
        .params_trainable
        .iter()
        .cloned()
        .collect();
    let fp_refs: Vec<_> = fp_metas.iter().collect();
    let fp = Checkpoint::init_from_meta(&fp_refs, 5).unwrap();
    let qck = pipeline::prep(&ctx, "n1", "peqa_b4_gc", &fp).unwrap();
    let codes_before = qck.req("layers.0.attn.q.wq").unwrap().clone();

    let cfg = TrainConfig { steps: 8, lr: 2e-3, warmup_steps: 1, log_every: 0, ..Default::default() };
    let mut trainer = Trainer::new(&ctx.rt, "n1_train_peqa_b4_gc", &qck, cfg).unwrap();
    let stream: Vec<u32> = (0..6000u32).map(|i| (i * 17 + 3) % 500).collect();
    let (b, t) = (meta.inputs[0].shape[0], meta.inputs[0].shape[1]);
    let mut batcher = LmBatcher::new(stream, b, t, 2);
    trainer.run(8, || batcher.next_batch()).unwrap();
    let losses = trainer.losses().to_vec();
    assert!(
        losses.last().unwrap() < losses.first().unwrap(),
        "{losses:?}"
    );
    let done = trainer.finish().unwrap();
    // Frozen integer codes bitwise identical; scales moved.
    assert_eq!(
        done.req("layers.0.attn.q.wq").unwrap().data(),
        codes_before.data()
    );
    let s0 = qck.req("layers.0.attn.q.s").unwrap();
    let s1 = done.req("layers.0.attn.q.s").unwrap();
    assert!(s0.max_abs_diff(s1) > 0.0, "scales did not move");
}

#[test]
fn dequantized_eval_matches_quantized_logits_artifact() {
    // The central equivalence: eval over dequantized fp weights must equal
    // the quantized-layout Pallas forward (n3 ships logits_q).
    let Some(ctx) = ctx() else { return };
    let fp_metas: Vec<_> = ctx
        .rt
        .meta("n3_train_full")
        .unwrap()
        .params_trainable
        .iter()
        .cloned()
        .collect();
    let refs: Vec<_> = fp_metas.iter().collect();
    let fp = Checkpoint::init_from_meta(&refs, 11).unwrap();
    let qck = pipeline::prep(&ctx, "n3", "peqa_b4_gc", &fp).unwrap();

    let q_model = EvalModel::new(&ctx.rt, "n3_logits_q_b4_gc_b8", &qck).unwrap();
    let fp_model = EvalModel::new(&ctx.rt, "n3_logits_b8", &qck.dequantize().unwrap()).unwrap();
    let tokens: Vec<i32> = (0..8 * 64).map(|i| (i * 13 % 500) as i32).collect();
    let lq = q_model.logits(&ctx.rt, &tokens).unwrap();
    let lf = fp_model.logits(&ctx.rt, &tokens).unwrap();
    let max_diff = lq
        .iter()
        .zip(&lf)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 2e-2, "quantized vs dequantized logits diverge: {max_diff}");
}

#[test]
fn eval_artifact_matches_manual_nll() {
    let Some(ctx) = ctx() else { return };
    let fp_metas: Vec<_> = ctx
        .rt
        .meta("n1_train_full")
        .unwrap()
        .params_trainable
        .iter()
        .cloned()
        .collect();
    let refs: Vec<_> = fp_metas.iter().collect();
    let fp = Checkpoint::init_from_meta(&refs, 3).unwrap();
    // Untrained model → nll/token ≈ ln(vocab).
    let stream: Vec<u32> = (0..4000u32).map(|i| i % 500).collect();
    let ppl = pipeline::ppl(&ctx, "n1", &fp, &stream).unwrap();
    assert!((ppl.ln() - (512f64).ln()).abs() < 0.2, "ppl {ppl}");
}

#[test]
fn prep_artifact_matches_rust_rtn_end_to_end() {
    let Some(ctx) = ctx() else { return };
    let fp_metas: Vec<_> = ctx
        .rt
        .meta("n1_train_full")
        .unwrap()
        .params_trainable
        .iter()
        .cloned()
        .collect();
    let refs: Vec<_> = fp_metas.iter().collect();
    let fp = Checkpoint::init_from_meta(&refs, 9).unwrap();
    let via_artifact = pipeline::prep(&ctx, "n1", "peqa_b4_gc", &fp).unwrap();
    let via_rust = pipeline::rtn_quantize(&fp, 4, None).unwrap();
    for prefix in via_rust.quantized_prefixes() {
        let a = via_artifact.req(&format!("{prefix}.s")).unwrap();
        let b = via_rust.req(&format!("{prefix}.s")).unwrap();
        assert!(a.max_abs_diff(b) < 1e-6, "{prefix} scales");
        let wa = via_artifact.req(&format!("{prefix}.wq")).unwrap();
        let wb = via_rust.req(&format!("{prefix}.wq")).unwrap();
        let ndiff =
            wa.data().iter().zip(wb.data()).filter(|(x, y)| (**x - **y).abs() > 0.5).count();
        assert!(ndiff * 500 < wa.len(), "{prefix}: {ndiff} code mismatches");
    }
}

#[test]
fn coordinator_scale_swap_equals_fresh_model() {
    // Serving invariant: after switching to task B, outputs must equal a
    // coordinator loaded directly with B's scales.
    let Some(ctx) = ctx() else { return };
    let fp_metas: Vec<_> = ctx
        .rt
        .meta("n3_train_full")
        .unwrap()
        .params_trainable
        .iter()
        .cloned()
        .collect();
    let refs: Vec<_> = fp_metas.iter().collect();
    let fp = Checkpoint::init_from_meta(&refs, 21).unwrap();
    let qck = pipeline::prep(&ctx, "n3", "peqa_b4_gc", &fp).unwrap();
    // Task B = perturbed scales.
    let mut ck_b = qck.clone();
    for name in ck_b.names().to_vec() {
        if name.ends_with(".s") {
            let mut t = ck_b.get(&name).unwrap().clone();
            for v in t.data_mut() {
                *v *= 1.05;
            }
            ck_b.insert(name, t);
        }
    }
    let mut adapters = AdapterStore::new();
    adapters.insert("a", qck.extract_adapter(false));
    adapters.insert("b", ck_b.extract_adapter(false));

    let run = |base: Checkpoint, task: &str| -> Vec<u32> {
        let mut store = AdapterStore::new();
        store.insert("a", qck.extract_adapter(false));
        store.insert("b", ck_b.extract_adapter(false));
        let mut coord = Coordinator::new(
            ctx.rt.clone(),
            "n3_logits_q_b4_gc_b8",
            base,
            store,
            SwitchMode::ScaleSwap,
            BatcherConfig { max_batch: 8, ..Default::default() },
        )
        .unwrap();
        coord.submit(task, vec![5, 6, 7, 8], 6, 0);
        coord.run_until_idle().unwrap().remove(0).tokens
    };
    // Serve task b after starting from a's scales (forces a swap)…
    let mut coord = Coordinator::new(
        ctx.rt.clone(),
        "n3_logits_q_b4_gc_b8",
        qck.clone(),
        adapters,
        SwitchMode::ScaleSwap,
        BatcherConfig { max_batch: 8, ..Default::default() },
    )
    .unwrap();
    coord.submit("a", vec![5, 6, 7, 8], 6, 0);
    coord.submit("b", vec![5, 6, 7, 8], 6, 0);
    let mut out = coord.run_until_idle().unwrap();
    let b_after_swap = out.remove(1).tokens;
    assert_eq!(coord.metrics.swap_times_s.len(), 2); // a then b
    // …and compare with a fresh coordinator serving b directly.
    let b_fresh = run(ck_b.clone(), "b");
    assert_eq!(b_after_swap, b_fresh, "task switch must be exact");
}

#[test]
fn batcher_groups_by_task_and_preserves_all_requests() {
    let Some(ctx) = ctx() else { return };
    let fp_metas: Vec<_> = ctx
        .rt
        .meta("n1_train_full")
        .unwrap()
        .params_trainable
        .iter()
        .cloned()
        .collect();
    let refs: Vec<_> = fp_metas.iter().collect();
    let fp = Checkpoint::init_from_meta(&refs, 31).unwrap();
    let qck = pipeline::prep(&ctx, "n1", "peqa_b4_gc", &fp).unwrap();
    let mut adapters = AdapterStore::new();
    adapters.insert("t0", qck.extract_adapter(false));
    adapters.insert("t1", qck.extract_adapter(false));
    let mut coord = Coordinator::new(
        ctx.rt.clone(),
        "n1_logits_b8",
        qck,
        adapters,
        SwitchMode::FullReload,
        BatcherConfig { max_batch: 4, ..Default::default() },
    )
    .unwrap();
    let mut rng = Pcg32::new(3);
    let mut ids = Vec::new();
    for _ in 0..13 {
        let task = if rng.below(2) == 0 { "t0" } else { "t1" };
        ids.push(coord.submit(task, vec![1, 2, 3], 3, 0));
    }
    let responses = coord.run_until_idle().unwrap();
    assert_eq!(responses.len(), 13);
    let mut got: Vec<u64> = responses.iter().map(|r| r.id).collect();
    got.sort();
    assert_eq!(got, ids);
    assert_eq!(coord.pending(), 0);
}

#[test]
fn optq_pipeline_beats_rtn_on_task_hessians() {
    let Some(ctx) = ctx() else { return };
    // A *trained* base gives informative activations; fall back to random
    // if the cached base is absent (test stays hermetic).
    let base = pipeline::ensure_base(&ctx, "n1", 120).unwrap();
    let (calib, eval_s) = ctx.split("wikitext", 40_000).unwrap();
    let h = pipeline::hessians(&ctx, "n1", &base, &calib, 4).unwrap();
    for (name, t) in &h {
        assert!(t.data().iter().all(|x| x.is_finite()), "{name}");
    }
    let optq = pipeline::optq_quantize(&base, &h, 3, None).unwrap();
    let rtn = pipeline::rtn_quantize(&base, 3, None).unwrap();
    let p_optq = pipeline::ppl(&ctx, "n1", &optq, &eval_s).unwrap();
    let p_rtn = pipeline::ppl(&ctx, "n1", &rtn, &eval_s).unwrap();
    // OPTQ should not be (much) worse than RTN; usually better at 3-bit.
    assert!(p_optq < p_rtn * 1.10, "optq {p_optq} vs rtn {p_rtn}");
}

#[test]
fn runtime_rejects_malformed_inputs() {
    let Some(ctx) = ctx() else { return };
    let art = ctx.rt.load("kernel_rtn_256").unwrap();
    // Wrong arity.
    assert!(art.run(&[]).is_err());
    // Unknown artifact.
    assert!(ctx.rt.load("no_such_artifact").is_err());
    // Bad checkpoint path.
    assert!(Checkpoint::load(std::path::Path::new("/nonexistent.peqa")).is_err());
    let _ = art;
}

#[test]
fn runtime_lists_and_meta_roundtrip() {
    let Some(ctx) = ctx() else { return };
    let names = ctx.rt.list().unwrap();
    assert!(names.len() > 100, "expected the full manifest, got {}", names.len());
    for name in names.iter().take(20) {
        let m = ctx.rt.meta(name).unwrap();
        assert_eq!(&m.name, name);
        assert!(!m.inputs.is_empty());
    }
    let rt2 = Runtime::new(ctx.paths.artifacts.clone()).unwrap();
    assert_eq!(rt2.list().unwrap().len(), names.len());
}
