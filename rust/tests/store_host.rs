//! Durability integration tests (default build — no artifacts, no xla):
//! the ISSUE-6 acceptance gates for `store::`.
//!
//! * kill-and-resume: a journaled training run killed at an arbitrary
//!   step — with a simulated torn tail from the interrupted append —
//!   resumes from the base snapshot + journal alone and finishes
//!   **bitwise identical** to a run that was never interrupted (loss
//!   history, EMA, every adapter tensor, the serialized adapter file,
//!   and the journal record stream itself);
//! * corruption: any single flipped byte and every possible truncation
//!   of a checkpoint/adapter container, a packed-model container, and a
//!   training journal is detected at load — an error or a reported torn
//!   tail, never a panic or silently-wrong tensors;
//! * publication: a tuned adapter published through `store::Registry`
//!   loads back checksum-verified, passes the serving scheduler's
//!   strict coverage, decodes, and `store::fsck` signs off on every
//!   artifact the flow wrote; a flipped byte in a published adapter
//!   fails the next load while the generation counter stays readable.

use std::path::{Path, PathBuf};

use peqa::config::TrainConfig;
use peqa::data::LmBatcher;
use peqa::model::{Checkpoint, PackedModel};
use peqa::serve::{self, Engine, ModelGeom, Scheduler, SchedulerConfig};
use peqa::store::journal::{self, JournalMeta, JournalWriter, TrainRecord};
use peqa::store::Registry;
use peqa::tensor::Tensor;
use peqa::train::{HostPeqaTuner, MultiTaskTuner, Tuner, TunerState};
use peqa::util::Pcg32;

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(name);
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

const GEOM: ModelGeom = ModelGeom { vocab: 64, d_model: 16, n_layers: 2, n_heads: 2, d_ff: 32 };

fn token_stream(n: usize, seed: u64) -> Vec<u32> {
    let mut rng = Pcg32::new(seed);
    (0..n).map(|_| rng.below(GEOM.vocab as u32)).collect()
}

fn cfg(steps: usize) -> TrainConfig {
    TrainConfig { steps, lr: 2e-3, warmup_steps: 2, log_every: 0, ..Default::default() }
}

/// The CLI's journaled step loop (`run_single_task` in main.rs), in
/// miniature: step, append a full-state record every `save_every` steps
/// plus at the final step, optionally stop after `halt_after` steps.
fn drive(
    tuner: &mut HostPeqaTuner,
    batcher: &mut LmBatcher,
    writer: &mut JournalWriter,
    steps: usize,
    save_every: usize,
    halt_after: usize,
) {
    let mut last_recorded = tuner.step_count();
    while tuner.step_count() < steps {
        let b = batcher.next_batch();
        tuner.step(&b).unwrap();
        let step = tuner.step_count();
        if step % save_every == 0 || step == steps {
            let st = tuner.export_state().unwrap();
            writer
                .append(&TrainRecord {
                    step: step as u64,
                    task_idx: 0,
                    rng: batcher.rng_state(),
                    ema: st.ema,
                    losses: st.losses[last_recorded..].to_vec(),
                    params: st.params,
                    opt_m: st.opt_m,
                    opt_v: st.opt_v,
                })
                .unwrap();
            last_recorded = step;
        }
        if halt_after > 0 && step >= halt_after {
            return;
        }
    }
}

fn journal_meta() -> JournalMeta {
    JournalMeta {
        task: "t".into(),
        tasks: Vec::new(),
        dataset: "synth".into(),
        base: "t.base.packed".into(),
        seed: 5,
        steps: 10,
        save_every: 3,
        batch: 2,
        seq: 8,
        lr_bits: (2e-3f64).to_bits(),
        warmup_steps: 2,
        train_zeros: true,
        vocab: GEOM.vocab,
        d_model: GEOM.d_model,
        n_layers: GEOM.n_layers,
        n_heads: GEOM.n_heads,
        d_ff: GEOM.d_ff,
    }
}

#[test]
fn killed_and_resumed_run_is_bitwise_identical_including_torn_tail() {
    let dir = tmp("peqa_test_store_resume");
    let meta = journal_meta();
    let stream = token_stream(4_000, 99);

    // Uninterrupted reference: 10 steps, records at 3/6/9/10.
    let (pm, _) = serve::synth_packed(&GEOM, 4, Some(8), meta.seed).unwrap();
    pm.to_checkpoint().save_packed(&dir.join(&meta.base), 4).unwrap();
    let mut full = HostPeqaTuner::from_packed(pm, GEOM, cfg(10), true, 2).unwrap();
    let mut batcher = LmBatcher::new(stream.clone(), 2, 8, meta.seed ^ 0x5eed);
    let mut w = JournalWriter::create(&dir.join("full.journal"), &meta).unwrap();
    drive(&mut full, &mut batcher, &mut w, 10, 3, 0);
    drop(w);
    let full_adapter = full.extract_adapter();
    full_adapter.save(&dir.join("full.adapter")).unwrap();

    // Interrupted run over the same inputs: killed after step 7 (last
    // durable record is step 6) mid-append — garbage tail bytes.
    let (pm, _) = serve::synth_packed(&GEOM, 4, Some(8), meta.seed).unwrap();
    let mut part = HostPeqaTuner::from_packed(pm, GEOM, cfg(10), true, 2).unwrap();
    let mut batcher = LmBatcher::new(stream.clone(), 2, 8, meta.seed ^ 0x5eed);
    let jpath = dir.join("t.journal");
    let mut w = JournalWriter::create(&jpath, &meta).unwrap();
    drive(&mut part, &mut batcher, &mut w, 10, 3, 7);
    drop((part, w));
    let mut bytes = std::fs::read(&jpath).unwrap();
    bytes.extend_from_slice(&[0x17, 0x42, 0xFE, 0x00, 0x99]);
    std::fs::write(&jpath, &bytes).unwrap();

    // Resume from disk alone: base snapshot + journal (torn tail
    // truncated by open_resume). Thread count deliberately differs —
    // results are pinned bit-identical across PEQA_THREADS.
    let pm = PackedModel::load(&dir.join(&meta.base)).unwrap();
    let (m2, records, mut w) = journal::open_resume(&jpath).unwrap();
    assert_eq!(m2, meta);
    let (last, losses) = journal::final_state(&records).unwrap();
    assert_eq!(last.step, 6);
    let mut resumed = HostPeqaTuner::from_packed(pm, GEOM, cfg(10), m2.train_zeros, 3).unwrap();
    resumed
        .import_state(&TunerState {
            step: last.step as usize,
            losses,
            ema: last.ema,
            params: last.params.clone(),
            opt_m: last.opt_m.clone(),
            opt_v: last.opt_v.clone(),
        })
        .unwrap();
    let mut batcher = LmBatcher::new(stream, m2.batch, m2.seq, m2.seed ^ 0x5eed);
    batcher.set_rng_state(last.rng.0, last.rng.1);
    drive(&mut resumed, &mut batcher, &mut w, 10, 3, 0);
    drop(w);

    // Bitwise identical: losses, EMA, every adapter tensor, the
    // serialized adapter container, and the journal record stream.
    assert_eq!(resumed.losses(), full.losses());
    assert_eq!(resumed.smoothed_loss(), full.smoothed_loss());
    let r_adapter = resumed.extract_adapter();
    assert_eq!(r_adapter.names(), full_adapter.names());
    for (n, t) in r_adapter.iter() {
        assert_eq!(t.data(), full_adapter.req(n).unwrap().data(), "{n}");
    }
    r_adapter.save(&dir.join("resumed.adapter")).unwrap();
    assert_eq!(
        std::fs::read(dir.join("resumed.adapter")).unwrap(),
        std::fs::read(dir.join("full.adapter")).unwrap(),
        "serialized adapters differ"
    );
    let (_, full_recs, _) = journal::read_journal(&dir.join("full.journal")).unwrap();
    let (_, res_recs, torn) = journal::read_journal(&jpath).unwrap();
    assert!(torn.is_none(), "resume left a torn tail behind");
    assert_eq!(full_recs, res_recs);
    std::fs::remove_dir_all(&dir).ok();
}

/// The CLI's journaled round-robin loop (`run_multi_task` in main.rs),
/// in miniature: step every task once per round, append one record per
/// task slot at every `save_every`-th round plus the final round.
/// `crash_mid_round > 0` kills the run at that checkpoint round AFTER
/// task 0's append but BEFORE task 1's — the on-disk partial round that
/// `open_resume_multi` must drop.
fn drive_multi(
    mt: &mut MultiTaskTuner,
    batchers: &mut [LmBatcher],
    writer: &mut JournalWriter,
    steps: usize,
    save_every: usize,
    crash_mid_round: usize,
) {
    let n = batchers.len();
    let start = mt.step_count(0);
    let mut last_recorded = vec![start; n];
    for round in (start + 1)..=steps {
        for (ti, batcher) in batchers.iter_mut().enumerate() {
            let b = batcher.next_batch();
            mt.step_task(ti, &b).unwrap();
        }
        if round % save_every == 0 || round == steps {
            for ti in 0..n {
                let st = mt.export_task_state(ti).unwrap();
                writer
                    .append(&TrainRecord {
                        step: round as u64,
                        task_idx: ti as u32,
                        rng: batchers[ti].rng_state(),
                        ema: st.ema,
                        losses: st.losses[last_recorded[ti]..].to_vec(),
                        params: st.params,
                        opt_m: st.opt_m,
                        opt_v: st.opt_v,
                    })
                    .unwrap();
                last_recorded[ti] = round;
                if round == crash_mid_round && ti == 0 {
                    return;
                }
            }
        }
    }
}

#[test]
fn multi_task_killed_and_resumed_round_robin_is_bitwise_identical() {
    let dir = tmp("peqa_test_store_resume_multi");
    let names = vec!["a".to_string(), "b".to_string()];
    let n = names.len();
    let mut meta = journal_meta();
    meta.task = "a,b".into();
    meta.tasks = names.clone();
    meta.dataset = "multi".into();
    meta.base = "a+b.base.packed".into();
    let streams: Vec<Vec<u32>> = (0..n).map(|ti| token_stream(4_000, 99 + ti as u64)).collect();
    let mk_batchers = |m: &JournalMeta| -> Vec<LmBatcher> {
        streams
            .iter()
            .enumerate()
            .map(|(ti, s)| LmBatcher::new(s.clone(), m.batch, m.seq, m.seed ^ 0x5eed ^ ti as u64))
            .collect()
    };

    // Uninterrupted reference: 10 rounds, per-task records at 3/6/9/10.
    let (pm, _) = serve::synth_packed(&GEOM, 4, Some(8), meta.seed).unwrap();
    pm.to_checkpoint().save_packed(&dir.join(&meta.base), 4).unwrap();
    let tuner = HostPeqaTuner::from_packed(pm, GEOM, cfg(10), true, 2).unwrap();
    let mut full = MultiTaskTuner::new(tuner, &names).unwrap();
    let mut batchers = mk_batchers(&meta);
    let mut w = JournalWriter::create(&dir.join("full.journal"), &meta).unwrap();
    drive_multi(&mut full, &mut batchers, &mut w, 10, 3, 0);
    drop(w);
    let full_adapters: Vec<Checkpoint> = (0..n).map(|ti| full.extract_adapter(ti)).collect();

    // Interrupted run over the same inputs: killed at checkpoint round 9
    // BETWEEN the two task appends — (9, task 0) is durable without
    // (9, task 1) — plus garbage bytes from the interrupted write.
    let (pm, _) = serve::synth_packed(&GEOM, 4, Some(8), meta.seed).unwrap();
    let tuner = HostPeqaTuner::from_packed(pm, GEOM, cfg(10), true, 2).unwrap();
    let mut part = MultiTaskTuner::new(tuner, &names).unwrap();
    let mut batchers = mk_batchers(&meta);
    let jpath = dir.join("ab.journal");
    let mut w = JournalWriter::create(&jpath, &meta).unwrap();
    drive_multi(&mut part, &mut batchers, &mut w, 10, 3, 9);
    drop((part, w));
    let mut bytes = std::fs::read(&jpath).unwrap();
    bytes.extend_from_slice(&[0x31, 0x41, 0x59]);
    std::fs::write(&jpath, &bytes).unwrap();

    // Resume from disk alone: the torn tail AND the partial round drop,
    // every slot restores from round 6, and the continued round-robin
    // is bitwise the uninterrupted run. Thread count deliberately
    // differs — results are pinned bit-identical across PEQA_THREADS.
    let pm = PackedModel::load(&dir.join(&meta.base)).unwrap();
    let (m2, records, mut w) = journal::open_resume_multi(&jpath, n).unwrap();
    assert_eq!(m2, meta);
    let (round, per_task) = journal::final_multi_state(&records, n).unwrap();
    assert_eq!(round, 6);
    let tuner = HostPeqaTuner::from_packed(pm, GEOM, cfg(10), m2.train_zeros, 3).unwrap();
    let mut resumed = MultiTaskTuner::new(tuner, &m2.tasks).unwrap();
    let mut batchers = mk_batchers(&m2);
    for (ti, (rec, losses)) in per_task.iter().enumerate() {
        resumed
            .import_task_state(
                ti,
                &TunerState {
                    step: rec.step as usize,
                    losses: losses.clone(),
                    ema: rec.ema,
                    params: rec.params.clone(),
                    opt_m: rec.opt_m.clone(),
                    opt_v: rec.opt_v.clone(),
                },
            )
            .unwrap();
        batchers[ti].set_rng_state(rec.rng.0, rec.rng.1);
    }
    drive_multi(&mut resumed, &mut batchers, &mut w, 10, 3, 0);
    drop(w);

    for ti in 0..n {
        assert_eq!(resumed.losses(ti), full.losses(ti), "task {ti} loss history");
        let r = resumed.extract_adapter(ti);
        assert_eq!(r.names(), full_adapters[ti].names());
        for (name, t) in r.iter() {
            assert_eq!(t.data(), full_adapters[ti].req(name).unwrap().data(), "task {ti} {name}");
        }
    }
    let (_, full_recs, _) = journal::read_journal(&dir.join("full.journal")).unwrap();
    let (_, res_recs, torn) = journal::read_journal(&jpath).unwrap();
    assert!(torn.is_none(), "resume left a torn tail behind");
    assert_eq!(full_recs, res_recs);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn flipped_bytes_and_truncations_never_pass_verification() {
    let dir = tmp("peqa_test_store_fuzz");

    // Checkpoint container (.peqa and .adapter share the format).
    let mut ck = Checkpoint::new();
    ck.insert("layers.0.attn.q.s", Tensor::full(&[4, 1], 0.25));
    ck.insert("layers.0.attn.q.z", Tensor::full(&[4, 1], 1.5));
    let ck_path = dir.join("a.adapter");
    ck.save(&ck_path).unwrap();
    fuzz_file(&ck_path, |p: &Path| Checkpoint::load(p).map(|_| ()));

    // Packed-model container (small geometry keeps the byte sweep fast).
    let small = ModelGeom { vocab: 32, d_model: 8, n_layers: 1, n_heads: 2, d_ff: 16 };
    let (_, q) = serve::synth_packed(&small, 3, Some(8), 9).unwrap();
    let pk_path = dir.join("m.packed");
    q.save_packed(&pk_path, 3).unwrap();
    fuzz_file(&pk_path, |p: &Path| PackedModel::load(p).map(|_| ()));

    // Journal: a flip is an error OR a reported torn tail OR visibly
    // different content — never the original records passed off as
    // intact; a truncation is an error, a torn tail, or fewer records.
    let jpath = dir.join("t.journal");
    let mut w = JournalWriter::create(&jpath, &journal_meta()).unwrap();
    let rec = |step: u64| TrainRecord {
        step,
        task_idx: 0,
        rng: (11 * step, 0x5EED | 1),
        ema: Some(0.5 + step as f64),
        losses: vec![step as f32],
        params: vec![vec![1.0, 2.0]],
        opt_m: vec![vec![0.1, 0.2]],
        opt_v: vec![vec![0.01, 0.02]],
    };
    w.append(&rec(3)).unwrap();
    w.append(&rec(6)).unwrap();
    drop(w);
    let orig = std::fs::read(&jpath).unwrap();
    let (om, orecs, otorn) = journal::read_journal(&jpath).unwrap();
    assert!(otorn.is_none());
    for i in 0..orig.len() {
        let mut b = orig.clone();
        b[i] ^= 0x01;
        std::fs::write(&jpath, &b).unwrap();
        match journal::read_journal(&jpath) {
            Err(_) => {}
            Ok((m, recs, torn)) => assert!(
                torn.is_some() || m != om || recs != orecs,
                "flip at byte {i} silently accepted"
            ),
        }
    }
    for len in 0..orig.len() {
        std::fs::write(&jpath, &orig[..len]).unwrap();
        match journal::read_journal(&jpath) {
            Err(_) => {}
            Ok((_, recs, torn)) => assert!(
                torn.is_some() || recs.len() < orecs.len(),
                "truncation to {len} byte(s) silently accepted"
            ),
        }
    }
    std::fs::write(&jpath, &orig).unwrap();
    assert!(journal::read_journal(&jpath).is_ok());
    std::fs::remove_dir_all(&dir).ok();
}

/// Flip every byte and try every truncation of `path`; `load` must
/// error each time (and must not panic), then succeed again once the
/// original bytes are restored.
fn fuzz_file(path: &Path, load: impl Fn(&Path) -> anyhow::Result<()>) {
    let orig = std::fs::read(path).unwrap();
    for i in 0..orig.len() {
        let mut b = orig.clone();
        b[i] ^= 0x01;
        std::fs::write(path, &b).unwrap();
        assert!(
            load(path).is_err(),
            "{}: flip at byte {i}/{} went undetected",
            path.display(),
            orig.len()
        );
    }
    for len in 0..orig.len() {
        std::fs::write(path, &orig[..len]).unwrap();
        assert!(
            load(path).is_err(),
            "{}: truncation to {len} byte(s) went undetected",
            path.display()
        );
    }
    std::fs::write(path, &orig).unwrap();
    load(path).unwrap();
}

#[test]
fn tuned_adapter_publishes_serves_strictly_and_fscks_clean() {
    let dir = tmp("peqa_test_store_publish");
    let (pm, _) = serve::synth_packed(&GEOM, 4, Some(8), 21).unwrap();
    let base = pm.clone();
    let mut tuner = HostPeqaTuner::from_packed(pm, GEOM, cfg(4), false, 2).unwrap();
    let mut batcher = LmBatcher::new(token_stream(2_000, 7), 2, 8, 77);
    for _ in 0..4 {
        let b = batcher.next_batch();
        tuner.step(&b).unwrap();
    }
    let adapter = tuner.extract_adapter();

    let reg_dir = dir.join("registry");
    let reg = Registry::open(&reg_dir);
    assert_eq!(reg.publish(&[("news".to_string(), &adapter)]).unwrap(), 1);

    // The published generation loads checksum-verified and registers
    // under the scheduler's strict coverage gate; the adapter decodes.
    let (generation, list) = reg.load().unwrap();
    assert_eq!(generation, 1);
    let mut adapters = serve::AdapterStore::new();
    for (t, a) in list {
        adapters.insert(t, a);
    }
    let eng = Engine::from_packed(base, GEOM, 2).unwrap();
    let scfg =
        SchedulerConfig { max_batch: 2, window: 64, strict_coverage: true, ..Default::default() };
    let mut sched = Scheduler::new(eng, adapters, scfg).unwrap();
    sched.submit("news", vec![3, 9, 27], 6, u32::MAX).unwrap();
    let rs = sched.run_until_idle().unwrap();
    assert_eq!(rs.len(), 1);
    assert_eq!(rs[0].tokens.len(), 6);

    // fsck signs off on every artifact the flow wrote.
    for f in [reg_dir.join("registry.manifest"), reg_dir.join("news.g1.adapter")] {
        let r = peqa::store::fsck(&f).unwrap();
        assert!(r.verified, "{} not verified", f.display());
    }

    // A flipped byte in the published adapter fails the next load (a
    // watching server would keep its current generation), while the
    // generation counter stays readable; fsck names the damage.
    let p = reg_dir.join("news.g1.adapter");
    let mut b = std::fs::read(&p).unwrap();
    let mid = b.len() / 2;
    b[mid] ^= 0x40;
    std::fs::write(&p, &b).unwrap();
    assert!(reg.load().is_err());
    assert_eq!(reg.generation().unwrap(), 1);
    assert!(peqa::store::fsck(&p).is_err());
    std::fs::remove_dir_all(&dir).ok();
}
