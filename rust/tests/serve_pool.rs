//! Engine-pool end-to-end tests (default build — no artifacts, no xla):
//!
//! * pooled decode is bitwise: the same mixed-task request set produces
//!   token-identical responses at 1, 2 and 4 pooled engines as a direct
//!   single-scheduler drain (greedy decode depends only on (task,
//!   prompt) — never on which worker, batch or arrival order served it);
//! * streaming is an observer: `submit_stream`'s Token events reassemble
//!   to exactly the tokens `submit` returns for the same request, and
//!   the terminal `Done` response carries the same sequence;
//! * admission control: submits past the per-task ingress cap are
//!   rejected at submit time with the typed [`ServeError::Overloaded`]
//!   (nothing queued, nothing decoded), while queued work and other
//!   tasks are unaffected;
//! * hot-reload: a registry publish between bursts is adopted by the
//!   pool without restart (`EnginePool::spawn_watching`).

use std::collections::HashMap;

use peqa::serve::{
    self, collect_stream, Engine, EnginePool, ModelGeom, PoolConfig, Scheduler, SchedulerConfig,
    ServeError,
};

const GEOM: ModelGeom = ModelGeom { vocab: 300, d_model: 32, n_layers: 2, n_heads: 2, d_ff: 64 };

fn parts(seed: u64) -> (peqa::model::PackedModel, peqa::model::Checkpoint) {
    serve::synth_packed(&GEOM, 4, Some(16), seed).unwrap()
}

fn req(i: u32) -> (&'static str, Vec<u32>) {
    (["a", "b", "c"][(i % 3) as usize], vec![1 + i, 40 + i, 7])
}

/// Ground truth for the bitwise tests: one scheduler, one drain.
fn direct_drain(n: u32) -> HashMap<(String, Vec<u32>), Vec<u32>> {
    let (pm, base_q) = parts(29);
    let adapters = serve::synth_adapters(&base_q, &["a", "b", "c"], 7);
    let eng = Engine::from_packed(pm, GEOM, 2).unwrap();
    let mut sched = Scheduler::new(
        eng,
        adapters,
        SchedulerConfig { max_batch: 4, window: 64, ..SchedulerConfig::default() },
    )
    .unwrap();
    let mut keys: HashMap<u64, (String, Vec<u32>)> = HashMap::new();
    for i in 0..n {
        let (task, prompt) = req(i);
        let id = sched.submit(task, prompt.clone(), 6, u32::MAX).unwrap();
        keys.insert(id, (task.to_string(), prompt));
    }
    let mut expected = HashMap::new();
    for r in sched.run_until_idle().unwrap() {
        expected.insert(keys.remove(&r.id).unwrap(), r.tokens);
    }
    expected
}

fn pool_cfg(engines: usize) -> PoolConfig {
    PoolConfig {
        engines,
        max_batch: 4,
        window: 64,
        queue_cap: 64,
        ..PoolConfig::default()
    }
}

#[test]
fn pooled_engines_match_direct_scheduler_bitwise() {
    const N: u32 = 12;
    let expected = direct_drain(N);
    assert_eq!(expected.len(), N as usize);

    for engines in [1usize, 2, 4] {
        let (pm, base_q) = parts(29);
        let adapters = serve::synth_adapters(&base_q, &["a", "b", "c"], 7);
        let pool = EnginePool::spawn(pm, GEOM, 2, adapters, pool_cfg(engines)).unwrap();
        let expected = &expected;
        // 4 concurrent clients × 3 requests each, racing across workers.
        std::thread::scope(|s| {
            for c in 0..4u32 {
                let h = pool.handle();
                s.spawn(move || {
                    for j in 0..3u32 {
                        let i = c * 3 + j;
                        let (task, prompt) = req(i);
                        let r = h.submit(task, prompt.clone(), 6, u32::MAX).unwrap();
                        assert_eq!(r.task, task);
                        assert_eq!(
                            &r.tokens,
                            expected.get(&(task.to_string(), prompt)).unwrap(),
                            "{engines} engine(s), request {i}: pooled tokens diverge \
                             from the direct drain"
                        );
                    }
                });
            }
        });
        let m = pool.shutdown();
        assert_eq!(m.completed, N as usize, "{engines} engine(s)");
        assert_eq!(m.shed_count, 0);
        assert_eq!(m.ttft_s.len(), N as usize, "one TTFT sample per request");
    }
}

#[test]
fn streamed_tokens_match_nonstreaming_submit_bitwise() {
    let (pm, base_q) = parts(29);
    let adapters = serve::synth_adapters(&base_q, &["a", "b", "c"], 7);
    let pool = EnginePool::spawn(pm, GEOM, 2, adapters, pool_cfg(2)).unwrap();
    let h = pool.handle();
    for i in 0..6u32 {
        let (task, prompt) = req(i);
        let plain = h.submit(task, prompt.clone(), 6, u32::MAX).unwrap();
        let rx = h.submit_stream(task, prompt.clone(), 6, u32::MAX).unwrap();
        let (streamed, done) = collect_stream(&rx).unwrap();
        assert_eq!(streamed, plain.tokens, "request {i}: Token events must reassemble");
        assert_eq!(done.tokens, plain.tokens, "request {i}: terminal Done must agree");
        assert_eq!(done.task, task);
    }
    // An unknown task is a terminal stream error, not a pool crash.
    let rx = h.submit_stream("nope", vec![1], 2, u32::MAX).unwrap();
    assert!(collect_stream(&rx).is_err());
    assert!(h.submit("a", vec![1, 2], 2, u32::MAX).is_ok(), "pool still serving");
    pool.shutdown();
}

#[test]
fn overload_rejects_at_submit_with_typed_error() {
    let (pm, base_q) = parts(29);
    let adapters = serve::synth_adapters(&base_q, &["a", "b"], 7);
    let cfg = PoolConfig { queue_cap: 2, ..pool_cfg(1) };
    let pool = EnginePool::spawn(pm, GEOM, 2, adapters, cfg).unwrap();
    let h = pool.handle();

    // Park the single worker deterministically: a streaming request
    // generating more tokens than the stream channel buffers blocks the
    // decode loop at the full channel until the client drains — classic
    // slow-consumer backpressure. Waiting for the first Token proves the
    // worker dequeued it, so everything submitted next queues behind it.
    let max_new = serve::STREAM_CHANNEL_CAP + 8;
    let rx_slow = h.submit_stream("a", vec![3, 5, 9], max_new, u32::MAX).unwrap();
    let first = rx_slow.recv().unwrap();
    assert!(matches!(first, peqa::serve::StreamEvent::Token(_)));

    // Fill task a's ingress queue to its cap...
    let rx2 = h.submit_stream("a", vec![1, 2], 4, u32::MAX).unwrap();
    let rx3 = h.submit_stream("a", vec![2, 3], 4, u32::MAX).unwrap();
    // ...and the next submit is rejected typed, at submit time.
    let err = h.submit_stream("a", vec![4, 5], 4, u32::MAX).unwrap_err();
    assert_eq!(err, ServeError::Overloaded { task: "a".into(), depth: 2, cap: 2 });

    // Draining the slow consumer unblocks the worker; the queued
    // requests (admitted before the cap) complete normally.
    let (streamed, done) = collect_stream(&rx_slow).unwrap();
    // One Token was already received before collect_stream took over.
    assert_eq!(streamed.len() + 1, max_new);
    assert_eq!(done.tokens.len(), max_new);
    assert!(collect_stream(&rx2).is_ok());
    assert!(collect_stream(&rx3).is_ok());

    let m = pool.shutdown();
    assert_eq!(m.completed, 3);
    assert_eq!(m.shed_count, 1, "exactly the rejected submit");
    assert_eq!(m.queue_depth_max, 2, "high-water is the cap, never past it");
}

#[test]
fn watching_pool_adopts_published_generation_without_restart() {
    let dir = std::env::temp_dir().join("peqa_test_pool_registry");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    let reg = peqa::store::Registry::open(&dir);

    let (pm, base_q) = parts(29);
    let full = base_q.extract_adapter(true);
    let adapters = serve::synth_adapters(&base_q, &["a"], 7);
    let pool = EnginePool::spawn_watching(
        pm,
        GEOM,
        2,
        adapters,
        pool_cfg(2),
        peqa::store::Registry::open(&dir),
    )
    .unwrap();
    let h = pool.handle();
    assert!(h.submit("a", vec![1, 2], 2, u32::MAX).is_ok());
    assert!(h.submit("fresh", vec![1], 1, u32::MAX).is_err());

    // Publish generation 1: the burst that wakes a worker next already
    // serves it — no restart, no explicit reload call.
    assert_eq!(reg.publish(&[("fresh".to_string(), &full)]).unwrap(), 1);
    let r = h.submit("fresh", vec![1, 2, 3], 2, u32::MAX).unwrap();
    assert_eq!(r.tokens.len(), 2);
    // The published generation replaced the synthesized set, on every
    // worker (several bursts so both engines get woken at least once).
    for _ in 0..4 {
        assert!(h.submit("a", vec![1], 1, u32::MAX).is_err(), "old set replaced");
    }
    pool.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}
