//! Paged-KV serving integration tests (default build — no artifacts):
//! the ISSUE-9 acceptance gates for `serve::kvpage` at the scheduler
//! boundary.
//!
//! * parity: a paged scheduler emits **bitwise** the tokens of the
//!   ring-buffer scheduler (the oracle the ring backend is kept for) at
//!   page sizes 1, 4, and 16, under greedy and seeded top-k sampling;
//! * window crossing: sequences whose prompt + generation overflow the
//!   attention window (ring wrap, page size not dividing the window)
//!   stay bitwise the ring's sliding-window decode;
//! * copy-on-write: requests forked from a common prompt prefix share
//!   prefix pages (kv_pages_shared > 0, less prefill work) and still
//!   decode bitwise the unshared ring run after diverging;
//! * recycling: a pool holding a small fraction of the offered load
//!   serves every request through page recycling, with the mapped peak
//!   bounded by the pool size.

use peqa::serve::{self, Engine, ModelGeom, Sampling, Scheduler, SchedulerConfig};

const GEOM: ModelGeom = ModelGeom { vocab: 300, d_model: 32, n_layers: 2, n_heads: 2, d_ff: 64 };

fn scheduler(cfg: SchedulerConfig, seed: u64) -> Scheduler {
    let (pm, base_q) = serve::synth_packed(&GEOM, 4, Some(16), seed).unwrap();
    let engine = Engine::from_packed(pm, GEOM, 2).unwrap();
    let adapters = serve::synth_adapters(&base_q, &["a", "b"], 7);
    Scheduler::new(engine, adapters, cfg).unwrap()
}

/// Mixed-task, mixed-length request set; every prompt fits `window`.
fn workload(window: usize) -> Vec<(&'static str, Vec<u32>, usize)> {
    (0..10u32)
        .map(|i| {
            let task = ["a", "b"][(i % 2) as usize];
            let len = 1 + (i as usize * 3) % (window / 2);
            let prompt: Vec<u32> =
                (0..len as u32).map(|t| (i * 31 + t * 7) % GEOM.vocab as u32).collect();
            (task, prompt, 4 + (i as usize % 5))
        })
        .collect()
}

/// Submit the whole workload, drain, and return the scheduler plus its
/// responses sorted by request id — submission order is deterministic,
/// so this is a stable bitwise fingerprint of the run.
fn run(
    cfg: SchedulerConfig,
    reqs: &[(&str, Vec<u32>, usize)],
    seed: u64,
) -> (Scheduler, Vec<(u64, Vec<u32>)>) {
    let mut sched = scheduler(cfg, seed);
    for (task, prompt, max_new) in reqs {
        sched.submit(task, prompt.clone(), *max_new, u32::MAX).unwrap();
    }
    let mut tokens: Vec<(u64, Vec<u32>)> =
        sched.run_until_idle().unwrap().into_iter().map(|r| (r.id, r.tokens)).collect();
    tokens.sort_by_key(|(id, _)| *id);
    (sched, tokens)
}

#[test]
fn paged_decode_is_bitwise_ring_at_every_page_size() {
    let window = 32;
    let reqs = workload(window);
    for sampling in [Sampling::Greedy, Sampling::TopK { k: 8, temperature: 0.7 }] {
        let ring_cfg = SchedulerConfig {
            max_batch: 4,
            window,
            sampling,
            seed: 11,
            ..SchedulerConfig::default()
        };
        let (ring, r_tokens) = run(ring_cfg, &reqs, 41);
        assert_eq!(ring.metrics.completed, reqs.len());
        assert_eq!(ring.metrics.kv_pages_peak, 0, "the ring maps no pages by contract");
        assert_eq!(ring.metrics.kv_pages_shared, 0);
        // Pool sized so page availability never defers an admission:
        // top-k draws from ONE seeded stream in batch order, so the
        // sampled parity claim needs identical admission schedules
        // (greedy parity holds under any schedule — the tight-pool
        // tests below exercise that).
        for page_tokens in [1usize, 4, 16] {
            let paged_cfg = SchedulerConfig {
                max_batch: 4,
                window,
                sampling,
                seed: 11,
                kv_pages: 160,
                page_tokens,
                ..SchedulerConfig::default()
            };
            let (paged, p_tokens) = run(paged_cfg, &reqs, 41);
            assert_eq!(
                r_tokens, p_tokens,
                "page_tokens {page_tokens} sampling {sampling:?}: paged != ring"
            );
            assert!(paged.metrics.kv_pages_peak > 0);
            assert!(paged.metrics.kv_pages_peak <= 160);
            assert_eq!(paged.metrics.completed, ring.metrics.completed);
        }
    }
}

#[test]
fn window_crossing_sequences_stay_bitwise_ring() {
    // window 16, pages of 6 tokens: 16 = 2×6 + 4, so the live window
    // straddles page boundaries unevenly, and every sequence below runs
    // past the window (ring wrap / page drop mid-decode).
    let window = 16;
    let reqs: Vec<(&str, Vec<u32>, usize)> = (0..6u32)
        .map(|i| {
            let task = ["a", "b"][(i % 2) as usize];
            let prompt: Vec<u32> = (0..10).map(|t| (i * 17 + t * 3) % GEOM.vocab as u32).collect();
            (task, prompt, 20usize)
        })
        .collect();
    let ring_cfg = SchedulerConfig { max_batch: 3, window, ..SchedulerConfig::default() };
    let (_, r_tokens) = run(ring_cfg, &reqs, 97);
    for page_tokens in [6usize, 16] {
        let paged_cfg = SchedulerConfig {
            max_batch: 3,
            window,
            kv_pages: 32,
            page_tokens,
            ..SchedulerConfig::default()
        };
        let (_, p_tokens) = run(paged_cfg, &reqs, 97);
        assert_eq!(r_tokens, p_tokens, "page_tokens {page_tokens}: window-crossing parity");
        for (_, toks) in &p_tokens {
            assert_eq!(toks.len(), 20);
        }
    }
}

#[test]
fn cow_fork_then_diverge_matches_unshared_ring() {
    // Eight same-task requests fork from a 12-token common prefix (three
    // full pages) and diverge on their final prompt token, then keep
    // diverging through 8 generated tokens each.
    let window = 32;
    let prefix: Vec<u32> = (0..12).map(|t| 3 + t * 5).collect();
    let reqs: Vec<(&str, Vec<u32>, usize)> = (0..8u32)
        .map(|i| {
            let mut p = prefix.clone();
            p.push(100 + i);
            ("a", p, 8usize)
        })
        .collect();
    let ring_cfg = SchedulerConfig { max_batch: 4, window, ..SchedulerConfig::default() };
    let (ring, r_tokens) = run(ring_cfg, &reqs, 131);

    let paged_cfg = SchedulerConfig {
        max_batch: 4,
        window,
        kv_pages: 40,
        page_tokens: 4,
        ..SchedulerConfig::default()
    };
    let (paged, p_tokens) = run(paged_cfg, &reqs, 131);
    assert_eq!(r_tokens, p_tokens, "fork-then-diverge parity");
    assert!(paged.metrics.kv_pages_shared > 0, "no prefix pages were shared across the fork");
    assert!(
        paged.metrics.prefill_tokens < ring.metrics.prefill_tokens,
        "sharing saved no prefill work: paged {} vs ring {}",
        paged.metrics.prefill_tokens,
        ring.metrics.prefill_tokens
    );
}

#[test]
fn page_recycling_serves_many_waves_through_a_tight_pool() {
    // Every request needs ceil((6+6)/4) = 3 pages; the pool holds 6, so
    // at most two sequences are mapped at once while 12 requests flow
    // through — completion recycles pages for the next admission.
    let cfg = SchedulerConfig {
        max_batch: 2,
        window: 32,
        kv_pages: 6,
        page_tokens: 4,
        ..SchedulerConfig::default()
    };
    let mut sched = scheduler(cfg, 57);
    for i in 0..12u32 {
        let task = ["a", "b"][(i % 2) as usize];
        let prompt: Vec<u32> = (0..6).map(|t| (i * 13 + t) % GEOM.vocab as u32).collect();
        sched.submit(task, prompt, 6, u32::MAX).unwrap();
    }
    let responses = sched.run_until_idle().unwrap();
    assert_eq!(responses.len(), 12, "recycling must serve the whole backlog");
    for r in &responses {
        assert_eq!(r.tokens.len(), 6);
    }
    assert_eq!(sched.metrics.completed, 12);
    assert!(sched.metrics.kv_pages_peak <= 6, "peak {} > pool 6", sched.metrics.kv_pages_peak);
    assert_eq!(sched.metrics.kv_exhausted_count, 0, "feasible requests were rejected");
}
