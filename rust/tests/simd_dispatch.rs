//! Whole-run SIMD dispatch invariance (the `PEQA_SIMD` contract):
//! decode and training outputs must be **bitwise identical** whether the
//! kernels run on the scalar baseline or the host's detected vector
//! tier, and `PEQA_SIMD=scalar` must actually force the baseline.
//!
//! `quant::simd::active()` reads `PEQA_SIMD` once per process, so each
//! setting needs its own process: the parent test re-execs the current
//! test binary twice (`PEQA_SIMD=scalar`, then `auto`), each child runs
//! a small decode + train workload and prints an FNV digest of every
//! f32 it produced plus the tier it dispatched to, and the parent
//! compares the two reports.

use peqa::config::TrainConfig;
use peqa::data::Batch;
use peqa::quant::simd;
use peqa::serve::{self, Engine, ModelGeom};
use peqa::train::{HostPeqaTuner, Tuner};
use peqa::util::Pcg32;

const GEOM: ModelGeom = ModelGeom { vocab: 300, d_model: 32, n_layers: 2, n_heads: 2, d_ff: 64 };

fn fnv(h: &mut u64, bits: u32) {
    *h ^= bits as u64;
    *h = h.wrapping_mul(0x100_0000_01b3);
}

fn digest_f32s(vals: &[f32], h: &mut u64) {
    for v in vals {
        fnv(h, v.to_bits());
    }
}

/// A small but representative run: batched prefill + greedy decode
/// through the serving engine (packed GEMM, attention, dense LM head),
/// then four host PEQA training steps (forward tape, backward through
/// `grad_input` / `grad_scales_zeros`, Adam update). Every f32 the run
/// produces folds into one digest.
fn workload_digest() -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;

    let (pm, _) = serve::synth_packed(&GEOM, 3, Some(16), 77).unwrap();
    let mut eng = Engine::from_packed(pm.clone(), GEOM, 2).unwrap();
    let mut cache = eng.new_cache(64);
    let mut seq: Vec<u32> = vec![11, 7, 42, 99, 3, 250, 31, 18];
    let mut logits = eng.prefill(&seq, &mut cache).unwrap();
    digest_f32s(&logits, &mut h);
    for _ in 0..8 {
        let next = serve::argmax(&logits);
        seq.push(next);
        fnv(&mut h, next);
        let mut refs = [&mut cache];
        logits = eng.decode_batch(&[next], &mut refs).unwrap();
        digest_f32s(&logits, &mut h);
    }

    let cfg = TrainConfig {
        steps: 4,
        lr: 2e-3,
        warmup_steps: 1,
        log_every: 0,
        ..Default::default()
    };
    let mut tuner = HostPeqaTuner::from_packed(pm, GEOM, cfg, false, 2).unwrap();
    let mut rng = Pcg32::new(5);
    for _ in 0..4 {
        let batch = Batch {
            tokens: (0..3 * 12).map(|_| rng.below(GEOM.vocab as u32) as i32).collect(),
            mask: vec![1.0; 3 * 11],
            batch: 3,
            seq: 12,
        };
        let loss = tuner.step(&batch).unwrap();
        fnv(&mut h, loss.to_bits());
    }
    h
}

#[test]
fn whole_run_outputs_are_bitwise_invariant_to_simd_dispatch() {
    if std::env::var("PEQA_SIMD_CHILD").is_ok() {
        // Child mode: run the workload under whatever PEQA_SIMD the
        // parent pinned and report (digest, active tier) on stdout.
        println!(
            "dispatch-digest={:016x} simd={}",
            workload_digest(),
            simd::active().name
        );
        return;
    }

    let exe = std::env::current_exe().expect("current test binary path");
    let run = |pref: &str| -> (String, String) {
        let out = std::process::Command::new(&exe)
            .args([
                "whole_run_outputs_are_bitwise_invariant_to_simd_dispatch",
                "--exact",
                "--nocapture",
            ])
            .env("PEQA_SIMD", pref)
            .env("PEQA_SIMD_CHILD", "1")
            .output()
            .expect("re-exec the test binary");
        assert!(
            out.status.success(),
            "child run (PEQA_SIMD={pref}) failed:\n{}{}",
            String::from_utf8_lossy(&out.stdout),
            String::from_utf8_lossy(&out.stderr)
        );
        let stdout = String::from_utf8_lossy(&out.stdout).into_owned();
        let line = stdout
            .lines()
            .find(|l| l.contains("dispatch-digest="))
            .unwrap_or_else(|| panic!("no digest line in child output:\n{stdout}"));
        let digest = line
            .split("dispatch-digest=")
            .nth(1)
            .and_then(|r| r.split_whitespace().next())
            .expect("digest value")
            .to_string();
        let tier = line
            .split("simd=")
            .nth(1)
            .map(|r| r.trim().to_string())
            .expect("tier name");
        (digest, tier)
    };

    let (d_scalar, n_scalar) = run("scalar");
    let (d_auto, n_auto) = run("auto");
    assert_eq!(n_scalar, "scalar", "PEQA_SIMD=scalar must force the baseline tier");
    assert_eq!(
        n_auto,
        simd::detected().name,
        "PEQA_SIMD=auto must dispatch to the detected tier"
    );
    assert_eq!(
        d_scalar, d_auto,
        "decode+train digest diverged between scalar and {n_auto} dispatch"
    );
}
