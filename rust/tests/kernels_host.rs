//! Host-side end-to-end integration: RTN quantize a checkpoint, write the
//! `.packed` deployment file, load it back as a `PackedModel` (codes stay
//! bit-packed), and check the fused dequant-GEMM against the seed's
//! scalar unpack → dequantize → matmul reference. Runs in the default
//! build — no artifacts, no xla feature.

use peqa::model::{Checkpoint, PackedModel};
use peqa::pipeline;
use peqa::quant::{quantize_rtn, reference_dequant_matmul, PackedMatrix};
use peqa::tensor::Tensor;
use peqa::util::Pcg32;

#[test]
fn quantize_pack_load_fused_roundtrip() {
    let dir = std::env::temp_dir().join("peqa_test_kernels_host");
    for (bits, group) in [(2u8, None), (3, Some(16)), (4, Some(64))] {
        let mut rng = Pcg32::new(40 + bits as u64);
        // Odd row count; cols divisible by every tested group.
        let w = Tensor::normal(&[37, 64], 0.4, &mut rng);
        let x = Tensor::normal(&[5, 64], 1.0, &mut rng);

        let mut fp = Checkpoint::new();
        fp.insert("layers.0.attn.q.w", w.clone());
        fp.insert("embed", Tensor::normal(&[8, 4], 1.0, &mut rng));
        let qck = pipeline::rtn_quantize(&fp, bits, group).unwrap();
        assert_eq!(qck.quantized_prefixes(), vec!["layers.0.attn.q".to_string()]);

        let path = dir.join(format!("m_b{bits}.packed"));
        qck.save_packed(&path, bits).unwrap();
        let pm = PackedModel::load(&path).unwrap();
        assert_eq!(pm.bits, bits);

        // Fused GEMM from packed codes vs the scalar reference path.
        let mat = pm.matrix("layers.0.attn.q").unwrap();
        let y = mat.matmul_t(&x).unwrap();
        let y_ref = reference_dequant_matmul(&x, mat).unwrap();
        assert!(
            y.max_abs_diff(&y_ref) <= 1e-4,
            "bits={bits} group={group:?}: {}",
            y.max_abs_diff(&y_ref)
        );

        // And vs a dense matmul over the compat checkpoint loader.
        let via_ck = Checkpoint::load_packed(&path).unwrap();
        let dense = via_ck.dequantize().unwrap();
        let y_dense = x.matmul(&dense.req("layers.0.attn.q.w").unwrap().t()).unwrap();
        assert!(y.max_abs_diff(&y_dense) <= 1e-4);
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn fused_gemm_agrees_with_in_memory_quantization() {
    // No file round trip: QuantizedMatrix → PackedMatrix directly.
    let mut rng = Pcg32::new(77);
    let w = Tensor::normal(&[96, 192], 0.3, &mut rng);
    let x = Tensor::normal(&[8, 192], 1.0, &mut rng);
    for bits in [2u8, 3, 4] {
        let q = quantize_rtn(&w, bits, Some(64)).unwrap();
        let pm = PackedMatrix::from_quantized(&q);
        let y = pm.matmul_t(&x).unwrap();
        let y_dense = x.matmul(&q.dequantize().t()).unwrap();
        assert!(y.max_abs_diff(&y_dense) <= 1e-4, "bits={bits}");
    }
}
