//! Host-side end-to-end integration: RTN quantize a checkpoint, write the
//! `.packed` deployment file, load it back as a `PackedModel` (codes stay
//! bit-packed), and check the fused dequant-GEMM against the seed's
//! scalar unpack → dequantize → matmul reference. Runs in the default
//! build — no artifacts, no xla feature.

use peqa::model::{Checkpoint, PackedModel};
use peqa::pipeline;
use peqa::quant::{quantize_rtn, reference_dequant_matmul, PackedMatrix};
use peqa::tensor::Tensor;
use peqa::util::Pcg32;

#[test]
fn quantize_pack_load_fused_roundtrip() {
    let dir = std::env::temp_dir().join("peqa_test_kernels_host");
    for (bits, group) in [(2u8, None), (3, Some(16)), (4, Some(64))] {
        let mut rng = Pcg32::new(40 + bits as u64);
        // Odd row count; cols divisible by every tested group.
        let w = Tensor::normal(&[37, 64], 0.4, &mut rng);
        let x = Tensor::normal(&[5, 64], 1.0, &mut rng);

        let mut fp = Checkpoint::new();
        fp.insert("layers.0.attn.q.w", w.clone());
        fp.insert("embed", Tensor::normal(&[8, 4], 1.0, &mut rng));
        let qck = pipeline::rtn_quantize(&fp, bits, group).unwrap();
        assert_eq!(qck.quantized_prefixes(), vec!["layers.0.attn.q".to_string()]);

        let path = dir.join(format!("m_b{bits}.packed"));
        qck.save_packed(&path, bits).unwrap();
        let pm = PackedModel::load(&path).unwrap();
        assert_eq!(pm.bits, bits);

        // Fused GEMM from packed codes vs the scalar reference path.
        let mat = pm.matrix("layers.0.attn.q").unwrap();
        let y = mat.matmul_t(&x).unwrap();
        let y_ref = reference_dequant_matmul(&x, mat).unwrap();
        assert!(
            y.max_abs_diff(&y_ref) <= 1e-4,
            "bits={bits} group={group:?}: {}",
            y.max_abs_diff(&y_ref)
        );

        // And vs a dense matmul over the compat checkpoint loader.
        let via_ck = Checkpoint::load_packed(&path).unwrap();
        let dense = via_ck.dequantize().unwrap();
        let y_dense = x.matmul(&dense.req("layers.0.attn.q.w").unwrap().t()).unwrap();
        assert!(y.max_abs_diff(&y_dense) <= 1e-4);
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn ragged_gemm_is_bitwise_equal_to_rows_gemm_across_widths_and_groupings() {
    // The serving/training projection entry for mixed prefill+decode
    // steps: matmul_t_ragged (direct (rows, out) layout, span-sharded,
    // no yᵀ transpose) must be bit-for-bit the batched matmul_t_rows
    // result for every bit-width 2/3/4 × per-channel/g128 × ragged span
    // shape × worker count.
    let cols = 256usize;
    let mut rng = Pcg32::new(2024);
    let w = Tensor::normal(&[23, cols], 0.4, &mut rng);
    let x = Tensor::normal(&[12, cols], 1.0, &mut rng);
    let spans_shapes: Vec<Vec<usize>> = vec![
        vec![12],                 // one prefill block
        vec![1; 12],              // pure decode batch
        vec![7, 1, 1, 3],         // mixed prefill + decode
        vec![1, 10, 1],
    ];
    for bits in [2u8, 3, 4] {
        for group in [None, Some(128)] {
            let q = quantize_rtn(&w, bits, group).unwrap();
            let pm = PackedMatrix::from_quantized(&q);
            let mut expect = vec![0.0f32; 12 * pm.rows];
            pm.matmul_t_rows(x.data(), 12, 3, &mut expect).unwrap();
            for spans in &spans_shapes {
                for threads in [1usize, 2, 5, 16] {
                    let mut out = vec![f32::NAN; 12 * pm.rows];
                    pm.matmul_t_ragged(x.data(), spans, threads, &mut out).unwrap();
                    assert_eq!(
                        out, expect,
                        "bits={bits} group={group:?} spans={spans:?} threads={threads}"
                    );
                }
            }
        }
    }
}

#[test]
fn fused_gemm_agrees_with_in_memory_quantization() {
    // No file round trip: QuantizedMatrix → PackedMatrix directly.
    let mut rng = Pcg32::new(77);
    let w = Tensor::normal(&[96, 192], 0.3, &mut rng);
    let x = Tensor::normal(&[8, 192], 1.0, &mut rng);
    for bits in [2u8, 3, 4] {
        let q = quantize_rtn(&w, bits, Some(64)).unwrap();
        let pm = PackedMatrix::from_quantized(&q);
        let y = pm.matmul_t(&x).unwrap();
        let y_dense = x.matmul(&q.dequantize().t()).unwrap();
        assert!(y.max_abs_diff(&y_dense) <= 1e-4, "bits={bits}");
    }
}
