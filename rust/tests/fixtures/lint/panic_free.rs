//! Fixture: panic-free-paths. Linted under the virtual path
//! `serve/fixture.rs` (in scope) and re-linted under `eval/fixture.rs`
//! (out of scope — everything silent). Lines tagged
//! `//~ panic-free-paths` must fire in scope.

pub fn unwraps(v: Option<u32>) -> u32 {
    v.unwrap() //~ panic-free-paths
}

pub fn expects(v: Option<u32>) -> u32 {
    v.expect("present by construction") //~ panic-free-paths
}

pub fn panics(flag: bool) {
    if flag {
        panic!("boom"); //~ panic-free-paths
    }
}

pub fn asserts(n: usize) {
    assert!(n > 0, "degenerate"); //~ panic-free-paths
}

pub fn assert_eqs(n: usize) {
    assert_eq!(n % 2, 0); //~ panic-free-paths
}

pub fn unreachable_arm(x: u8) -> u8 {
    match x {
        0 => 1,
        _ => unreachable!(), //~ panic-free-paths
    }
}

pub fn todo_stub() {
    todo!() //~ panic-free-paths
}

// ---- near misses: all silent ----

pub fn unwrap_or_is_fine(v: Option<u32>) -> u32 {
    v.unwrap_or(0)
}

pub fn unwrap_or_default_is_fine(v: Option<u32>) -> u32 {
    v.unwrap_or_default()
}

pub fn debug_asserts(n: usize) {
    debug_assert!(n > 0);
    debug_assert_eq!(n, n);
    debug_assert_ne!(n, n + 1);
}

#[test]
fn test_items_are_stripped() {
    let v: Option<u32> = None;
    v.unwrap();
    panic!("test-only panic");
}

#[cfg(test)]
mod tests {
    #[test]
    fn whole_test_module_is_stripped() {
        assert!(false);
    }
}
