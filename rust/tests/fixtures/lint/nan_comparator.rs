//! Fixture: nan-comparator. Linted under the virtual path
//! `eval/fixture.rs` (the rule is global — scope does not matter).
//! Lines tagged `//~ nan-comparator` must fire; everything else must
//! stay silent.

pub fn sort_unwrap(xs: &mut [f32]) {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap()); //~ nan-comparator
}

pub fn sort_expect(xs: &mut [f32]) {
    xs.sort_by(|a, b| a.partial_cmp(b).expect("no NaN here")); //~ nan-comparator
}

pub fn max_by_defaulted(xs: &[f64]) -> Option<f64> {
    // `unwrap_or(Equal)` does not panic — it silently mis-sorts NaN,
    // which is the subtler half of the invariant.
    xs.iter().copied().max_by(|a, b| {
        a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal) //~ nan-comparator
    })
}

pub fn min_by_key_lazy(xs: &[f32]) -> Option<f32> {
    xs.iter().copied().min_by(|a, b| {
        a.partial_cmp(b).unwrap_or_else(|| std::cmp::Ordering::Less) //~ nan-comparator
    })
}

// ---- near misses: all silent ----

pub fn total_order(xs: &mut [f32]) {
    xs.sort_by(|a, b| a.total_cmp(b));
}

pub fn inspected_not_unwrapped(a: f32, b: f32) -> bool {
    matches!(a.partial_cmp(&b), Some(std::cmp::Ordering::Less))
}

pub fn propagated_option(a: f64, b: f64) -> Option<std::cmp::Ordering> {
    a.partial_cmp(&b)
}

pub fn unrelated_unwrap(v: Option<u32>) -> u32 {
    // `.unwrap()` not on a `partial_cmp` result is this rule's
    // non-business (panic-free-paths owns it, in its own scope).
    v.unwrap()
}
