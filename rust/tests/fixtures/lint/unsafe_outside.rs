//! Fixture: unsafe-confined outside `quant::simd` — any `unsafe` is a
//! finding, SAFETY comment or not. Linted under the virtual paths
//! `serve/fixture.rs` and `quant/kernels.rs` (the confinement is
//! exact-module, so even a sibling of `quant::simd` is out).

pub fn any_unsafe(p: *const f32) -> f32 {
    unsafe { *p } //~ unsafe-confined
}

pub fn even_with_comment(p: *const f32) -> f32 {
    // SAFETY: a justification does not make unsafe legal outside simd
    unsafe { *p } //~ unsafe-confined
}

// ---- near misses: all silent ----

pub fn spelled_out() -> &'static str {
    "unsafe is only a word here"
}

pub fn keyword_flavored_ident(unsafe_count: usize) -> usize {
    unsafe_count
}
