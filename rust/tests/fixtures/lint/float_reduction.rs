//! Fixture: float-reduction-order. Linted under the virtual path
//! `model/blocks.rs` (in scope) and re-linted under `eval/fixture.rs`
//! (out of scope — everything silent). Lines tagged
//! `//~ float-reduction-order` must fire in scope.

pub fn iterator_reductions(xs: &[f32], ys: &[f64]) -> f64 {
    let s = xs.iter().sum::<f32>(); //~ float-reduction-order
    let p = ys.iter().product::<f64>(); //~ float-reduction-order
    let f = xs.iter().fold(0.0f32, |a, b| a + b); //~ float-reduction-order
    let m = xs.iter().fold(-1.0, |a, &b| if b > a { b } else { a }); //~ float-reduction-order
    let e = xs.iter().fold(1e-6, |a, &b| a.max(b)); //~ float-reduction-order
    s as f64 + p + f as f64 + m as f64 + e as f64
}

// ---- near misses: all silent ----

pub fn integer_reductions(xs: &[usize]) -> usize {
    // Integer addition is associative — order cannot change the result.
    let s = xs.iter().sum::<usize>();
    let f = xs.iter().fold(0usize, |a, b| a + b);
    let h = xs.iter().fold(0x10, |a, b| a ^ b);
    s + f + h
}

pub fn fixed_order(xs: &[f32]) -> f32 {
    // The prescribed spelling: an explicit loop pins the order.
    let mut acc = 0.0f32;
    for &v in xs {
        acc += v;
    }
    acc
}

pub fn non_float_fold(names: &[&str]) -> String {
    names.iter().fold(String::new(), |mut a, n| {
        a.push_str(n);
        a
    })
}
