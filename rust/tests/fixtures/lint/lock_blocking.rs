//! Fixture: lock-across-blocking. Linted under the virtual path
//! `serve/fixture.rs` (in scope) and re-linted under `train/fixture.rs`
//! (out of scope — everything silent). Lines tagged
//! `//~ lock-across-blocking` must fire in scope. The fixture is lexed,
//! never compiled, so `lock_clean` needs no import to be recognized.

pub fn guard_across_recv(
    m: &std::sync::Mutex<u64>,
    rx: &std::sync::mpsc::Receiver<u64>,
) -> u64 {
    let g = m.lock().unwrap();
    let v = rx.recv().unwrap_or(0); //~ lock-across-blocking
    *g + v
}

pub fn guard_across_send(
    m: &std::sync::Mutex<u64>,
    tx: &std::sync::mpsc::SyncSender<u64>,
) {
    let held = lock_clean(m);
    tx.send(*held).ok(); //~ lock-across-blocking
}

pub fn guard_across_join(
    m: &std::sync::Mutex<u64>,
    h: std::thread::JoinHandle<u64>,
) -> u64 {
    let _g = m.try_lock();
    h.join().unwrap_or(0) //~ lock-across-blocking
}

pub fn guard_across_recv_timeout(
    m: &std::sync::Mutex<u64>,
    rx: &std::sync::mpsc::Receiver<u64>,
) -> u64 {
    let g = try_lock_clean(m);
    let v = rx.recv_timeout(std::time::Duration::from_millis(1)).unwrap_or(0); //~ lock-across-blocking
    g.map_or(v, |x| *x + v)
}

// ---- near misses: all silent ----

pub fn dropped_before_recv(
    m: &std::sync::Mutex<u64>,
    rx: &std::sync::mpsc::Receiver<u64>,
) -> u64 {
    let g = m.lock().unwrap();
    let held = *g;
    drop(g);
    rx.recv().unwrap_or(held)
}

pub fn scoped_guard_then_recv(
    m: &std::sync::Mutex<u64>,
    rx: &std::sync::mpsc::Receiver<u64>,
) -> u64 {
    let held = {
        let g = m.lock().unwrap();
        *g
    };
    rx.recv().unwrap_or(held)
}

pub fn try_send_is_nonblocking(
    m: &std::sync::Mutex<u64>,
    tx: &std::sync::mpsc::SyncSender<u64>,
) {
    let g = m.lock().unwrap();
    tx.try_send(*g).ok();
}

pub fn non_guard_binding(
    q: &std::collections::VecDeque<u64>,
    rx: &std::sync::mpsc::Receiver<u64>,
) -> u64 {
    let head = q.front().copied().unwrap_or(0);
    rx.recv().unwrap_or(head)
}
