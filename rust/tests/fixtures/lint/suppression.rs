//! Fixture: suppression syntax and allow hygiene. Linted under the
//! virtual path `serve/fixture.rs` with NO rule filter, so panic-free
//! and nondeterminism findings are live and each `peqa-lint` comment
//! below exercises one corner of the allow grammar. `//~` marks an
//! expected finding on the same line, `//~^` one line up.

pub fn justified_allow(v: Option<u32>) -> u32 {
    // peqa-lint: allow(panic-free-paths) -- fixture: a well-formed
    // allow with a written justification silences the next statement.
    v.expect("covered by the allow above")
}

pub fn bare_allow_suppresses_nothing(v: Option<u32>) -> u32 {
    // peqa-lint: allow(panic-free-paths)
    //~^ allow-hygiene
    v.expect("bare allow: hygiene fires AND the finding survives") //~ panic-free-paths
}

pub fn unknown_rule_allow(v: Option<u32>) -> u32 {
    // peqa-lint: allow(no-such-rule) -- justification present, rule not
    //~^ allow-hygiene
    v.unwrap() //~ panic-free-paths
}

pub fn misplaced_allow(v: Option<u32>) -> u32 {
    v.unwrap() // peqa-lint: allow(panic-free-paths) -- not on its own line
    //~^ allow-hygiene panic-free-paths
}

pub fn block_comment_allow(v: Option<u32>) -> u32 {
    /* peqa-lint: allow(panic-free-paths) -- block comments carry no allows */
    //~^ allow-hygiene
    v.unwrap() //~ panic-free-paths
}

pub fn multi_rule_allow(rx: &std::sync::mpsc::Receiver<u64>) -> u64 {
    // peqa-lint: allow(panic-free-paths, nondeterminism-sources) -- fixture:
    // one comment may exempt several rules, and its extent runs through
    // the whole bracketed statement begun on the next line.
    let when = (
        std::time::Instant::now(),
        rx.recv().unwrap(),
    );
    when.1 + when.0.elapsed().as_secs()
}

pub fn allow_stops_at_statement_end(v: Option<u32>) -> u32 {
    // peqa-lint: allow(panic-free-paths) -- fixture: the extent is one
    // syntactic unit; the statement after it is NOT covered.
    let a = v.unwrap_or(1);
    a + v.unwrap() //~ panic-free-paths
}
