//! Fixture: hot-path-alloc. Linted under the virtual path
//! `quant/kernels.rs` (in scope) and re-linted under `eval/fixture.rs`
//! (out of scope — everything silent). Lines tagged
//! `//~ hot-path-alloc` must fire in scope.

pub fn allocs_everywhere(xs: &[f32]) -> usize {
    let grown: Vec<f32> = Vec::new(); //~ hot-path-alloc
    let filled = vec![0.0f32; 8]; //~ hot-path-alloc
    let copied = xs.to_vec(); //~ hot-path-alloc
    let cloned = copied.clone(); //~ hot-path-alloc
    let label = format!("tile-{}", cloned.len()); //~ hot-path-alloc
    let owned = String::from("scratch"); //~ hot-path-alloc
    grown.len() + filled.len() + label.len() + owned.len()
}

// ---- near misses: all silent ----

pub fn pooled(scratch: &mut Vec<f32>, n: usize) {
    // Reusing a caller-owned buffer is the house pattern.
    scratch.clear();
    scratch.resize(n, 0.0);
}

pub fn upfront(n: usize) -> Vec<f32> {
    // `with_capacity` at an entry point is the "allocate once" idiom
    // the rule's message prescribes.
    Vec::with_capacity(n)
}

pub fn clone_as_trait_bound<T: Clone>(t: &T) -> &T {
    // `Clone` the bound, not `.clone()` the call.
    t
}

pub fn string_len(s: &str) -> usize {
    // `String` as a type path that is not `String::from`.
    s.len() + std::mem::size_of::<String>()
}
