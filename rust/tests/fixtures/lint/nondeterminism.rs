//! Fixture: nondeterminism-sources. Linted under the virtual path
//! `store/fixture.rs`: hash containers are in scope (artifact path),
//! the clock exemption does not apply, and `thread::spawn` is banned
//! everywhere. Lines tagged `//~ nondeterminism-sources` must fire.
//! The scope flips (hash rules off in `serve::`, clocks legal in
//! `bench::`/`util::stats`) are exercised inline by the test.

use std::collections::HashMap;
use std::collections::HashSet;
use std::time::{Instant, SystemTime};

pub fn hash_order_iteration() -> usize {
    let by_name: HashMap<String, u64> = Default::default(); //~ nondeterminism-sources
    let seen: HashSet<u64> = Default::default(); //~ nondeterminism-sources
    by_name.len() + seen.len()
}

pub fn wall_clock_reads() -> u64 {
    let t0 = Instant::now(); //~ nondeterminism-sources
    let epoch = SystemTime::UNIX_EPOCH; //~ nondeterminism-sources
    t0.elapsed().as_nanos() as u64 + format!("{epoch:?}").len() as u64
}

pub fn detached_thread() -> u64 {
    let h = std::thread::spawn(|| 7); //~ nondeterminism-sources
    h.join().unwrap_or(0)
}

// ---- near misses: all silent ----

pub fn ordered_containers() -> usize {
    let sorted: std::collections::BTreeMap<String, u64> = Default::default();
    let dedup: std::collections::BTreeSet<u64> = Default::default();
    sorted.len() + dedup.len()
}

pub fn scoped_threads(xs: &mut [f32]) {
    std::thread::scope(|s| {
        for chunk in xs.chunks_mut(8) {
            s.spawn(move || chunk.iter_mut().for_each(|v| *v += 1.0));
        }
    });
}

pub fn built_and_joined_thread() -> std::io::Result<()> {
    let h = std::thread::Builder::new().name("worker".into()).spawn(|| ())?;
    let _ = h.join();
    Ok(())
}
