//! Fixture: unsafe-confined under the in-scope virtual path
//! `quant/simd.rs` — the one module where `unsafe` is legal at all.
//! There the rule enforces the `// SAFETY:` discipline: every `unsafe`
//! must sit under a `//` line comment starting with `SAFETY`, directly
//! above the keyword (attributes in between are stepped over, trailing
//! on the same line counts). Lines tagged `//~ unsafe-confined` must
//! fire; everything else is silent.

pub fn covered(p: *const f32) -> f32 {
    // SAFETY: caller guarantees `p` points at a live, aligned f32.
    unsafe { *p }
}

// SAFETY: `unsafe fn` because of `#[target_feature]` — the comment
// sits above the attribute and still counts as directly above.
#[target_feature(enable = "avx2")]
pub unsafe fn covered_through_attribute(p: *const f32) -> f32 {
    *p
}

pub fn trailing_comment_counts(p: *const f32) -> f32 {
    unsafe { *p } // SAFETY: same line as the keyword is still covered
}

pub fn bare(p: *const f32) -> f32 {
    unsafe { *p } //~ unsafe-confined
}

pub fn stale_comment(p: *const f32) -> f32 {
    // SAFETY: a code line intervenes, so this covers nothing below it
    let q = p;
    unsafe { *q } //~ unsafe-confined
}

pub fn wrong_comment_kind(p: *const f32) -> f32 {
    /* SAFETY: block comments do not count — the discipline is `//` */
    unsafe { *p } //~ unsafe-confined
}

pub fn wrong_case(p: *const f32) -> f32 {
    // safety: lowercase is not the marker
    unsafe { *p } //~ unsafe-confined
}

// ---- near misses: all silent ----

pub fn keyword_in_string() -> &'static str {
    // The word inside a string literal is not the keyword.
    "unsafe { nope }"
}

pub fn keyword_adjacent_ident(unsafe_ish: usize) -> usize {
    // `unsafe_ish` lexes as one identifier, not `unsafe` + `_ish`.
    unsafe_ish + 1
}
