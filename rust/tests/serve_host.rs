//! Host serving end-to-end tests (default build — no artifacts, no xla):
//!
//! * decode parity: the packed engine's logits match the reference
//!   dequantize-then-`matmul_naive` forward to ≤ 1e-4, at prefill and at
//!   every incremental decode step — including prefills longer than the
//!   KV capacity (ring-wrap mid-prefill) against the sliding-window
//!   reference;
//! * determinism: greedy decode is bit-identical across kernel worker
//!   thread counts (the PEQA_THREADS axis, pinned explicitly here),
//!   across scheduler batch sizes, and across cross-request prefill
//!   groupings;
//! * scale-swap contract: task switches replace only f32 scale/zero
//!   tensors, are exactly revertible, never touch packed codes, and a
//!   swap to a partial-coverage adapter restores base scales on every
//!   projection it does not cover (no residue from the previous task);
//! * concurrency: the threaded `serve::Server` serves parallel clients
//!   the same tokens as a direct scheduler drain;
//! * tokenizer round-trip on the demo corpus and stop-token truncation
//!   (a stop id sampled mid-batch must not leak into the response).

use std::collections::HashMap;

use peqa::data::corpus;
use peqa::serve::{
    self, reference_forward, reference_forward_windowed, Engine, KvCache, ModelGeom,
    Scheduler, SchedulerConfig, Server,
};
use peqa::tokenizer::Tokenizer;

const GEOM: ModelGeom = ModelGeom { vocab: 300, d_model: 32, n_layers: 2, n_heads: 2, d_ff: 64 };

fn engine(threads: usize, seed: u64) -> (Engine, peqa::model::Checkpoint) {
    let (pm, base_q) = serve::synth_packed(&GEOM, 4, Some(16), seed).unwrap();
    (Engine::from_packed(pm, GEOM, threads).unwrap(), base_q)
}

#[test]
fn decode_parity_with_dequantized_reference() {
    let (mut eng, base_q) = engine(2, 41);
    let fp_ref = base_q.dequantize().unwrap();
    let mut seq: Vec<u32> = vec![10, 7, 42, 99, 3, 250, 31];

    // Prefill the whole prompt as one block.
    let mut cache = eng.new_cache(64);
    let mut logits = eng.prefill(&seq, &mut cache).unwrap();
    let r = reference_forward(&fp_ref, &GEOM, &seq).unwrap();
    let (t, vocab) = r.dims2().unwrap();
    assert_eq!((t, vocab), (seq.len(), GEOM.vocab));
    let last = &r.data()[(t - 1) * vocab..];
    let d0 = max_abs(&logits, last);
    assert!(d0 <= 1e-4, "prefill parity: {d0}");

    // Greedy-extend step by step through the batched decode entry point;
    // every step must stay within 1e-4 of the full dense recompute.
    for step in 0..6 {
        let next = serve::argmax(&logits);
        seq.push(next);
        let mut refs = [&mut cache];
        logits = eng.decode_batch(&[next], &mut refs).unwrap();
        let r = reference_forward(&fp_ref, &GEOM, &seq).unwrap();
        let last = &r.data()[(seq.len() - 1) * GEOM.vocab..];
        let d = max_abs(&logits, last);
        assert!(d <= 1e-4, "step {step} parity: {d}");
    }
}

#[test]
fn ring_wrap_prefill_matches_sliding_window_reference() {
    // Prompt 21 tokens into a capacity-8 cache: the ring wraps twice
    // DURING the prefill block. Every logits row must match the dense
    // sliding-window reference (window == capacity) to ≤ 1e-4, through
    // the prefill and through decode steps that keep wrapping.
    let (mut eng, base_q) = engine(2, 71);
    let fp_ref = base_q.dequantize().unwrap();
    let cap = 8usize;
    let mut seq: Vec<u32> = (0..21).map(|i| ((i * 13 + 5) % 256) as u32).collect();
    let mut cache = eng.new_cache(cap);
    let mut logits = eng.prefill(&seq, &mut cache).unwrap();
    let r = reference_forward_windowed(&fp_ref, &GEOM, &seq, cap).unwrap();
    let last = &r.data()[(seq.len() - 1) * GEOM.vocab..];
    let d0 = max_abs(&logits, last);
    assert!(d0 <= 1e-4, "ring-wrap prefill parity: {d0}");
    for step in 0..5 {
        let next = serve::argmax(&logits);
        seq.push(next);
        let mut refs = [&mut cache];
        logits = eng.decode_batch(&[next], &mut refs).unwrap();
        let r = reference_forward_windowed(&fp_ref, &GEOM, &seq, cap).unwrap();
        let last = &r.data()[(seq.len() - 1) * GEOM.vocab..];
        let d = max_abs(&logits, last);
        assert!(d <= 1e-4, "wrap step {step} parity: {d}");
    }
    assert_eq!(cache.pos(), seq.len());
    assert_eq!(cache.len(), cap);
}

#[test]
fn cross_request_prefill_batching_is_bitwise() {
    // Batched prefill of several ragged prompts must produce, per
    // sequence, exactly the logits and cache state of prefilling each
    // prompt alone.
    let (mut eng, _) = engine(3, 83);
    let prompts: Vec<Vec<u32>> =
        vec![vec![1, 2, 3], vec![9, 8, 7, 6, 5], vec![100], vec![42, 250, 17, 3]];
    let vocab = GEOM.vocab;

    let mut solo_logits = Vec::new();
    let mut solo_caches: Vec<KvCache> = Vec::new();
    for p in &prompts {
        let mut c = eng.new_cache(16);
        solo_logits.push(eng.prefill(p, &mut c).unwrap());
        solo_caches.push(c);
    }

    let mut batch_caches: Vec<KvCache> = (0..prompts.len()).map(|_| eng.new_cache(16)).collect();
    let prompt_refs: Vec<&[u32]> = prompts.iter().map(|p| p.as_slice()).collect();
    let mut cache_refs: Vec<&mut KvCache> = batch_caches.iter_mut().collect();
    let logits = eng.prefill_batch(&prompt_refs, &mut cache_refs).unwrap();
    for (i, solo) in solo_logits.iter().enumerate() {
        assert_eq!(
            &logits[i * vocab..(i + 1) * vocab],
            solo.as_slice(),
            "prompt {i}: batched prefill must be bitwise equal to solo prefill"
        );
    }

    // The caches must be interchangeable: one more decode step from the
    // batched caches equals the step from the solo caches, bitwise.
    let next: Vec<u32> =
        (0..prompts.len()).map(|i| serve::argmax(&logits[i * vocab..(i + 1) * vocab])).collect();
    let mut refs: Vec<&mut KvCache> = batch_caches.iter_mut().collect();
    let step_batched = eng.decode_batch(&next, &mut refs).unwrap();
    for (i, c) in solo_caches.iter_mut().enumerate() {
        let mut refs = [&mut *c];
        let step_solo = eng.decode_batch(&next[i..i + 1], &mut refs).unwrap();
        assert_eq!(
            &step_batched[i * vocab..(i + 1) * vocab],
            step_solo.as_slice(),
            "seq {i}: decode after batched vs solo prefill"
        );
    }
}

#[test]
fn greedy_decode_is_thread_count_invariant() {
    // PEQA_THREADS=1 vs 4, pinned through the engine's explicit worker
    // count (the env var feeds the same parameter in production).
    let (mut e1, _) = engine(1, 13);
    let (mut e4, _) = engine(4, 13);
    let prompt: Vec<u32> = vec![5, 200, 17, 63];
    let mut c1 = e1.new_cache(64);
    let mut c4 = e4.new_cache(64);
    let mut l1 = e1.prefill(&prompt, &mut c1).unwrap();
    let mut l4 = e4.prefill(&prompt, &mut c4).unwrap();
    assert_eq!(l1, l4, "prefill logits must be bitwise equal");
    for _ in 0..8 {
        let n1 = serve::argmax(&l1);
        let n4 = serve::argmax(&l4);
        assert_eq!(n1, n4);
        let mut r1 = [&mut c1];
        let mut r4 = [&mut c4];
        l1 = e1.decode_batch(&[n1], &mut r1).unwrap();
        l4 = e4.decode_batch(&[n4], &mut r4).unwrap();
        assert_eq!(l1, l4, "decode logits must be bitwise equal");
    }
}

#[test]
fn batched_attention_is_thread_count_invariant() {
    // The attention pass shards batch rows (sequences) over workers;
    // a ragged multi-sequence batch must produce bitwise-identical
    // logits at 1, 2, 3 and 8 workers, through batched prefill AND
    // batched decode steps (worker counts above the sequence count
    // exercise the clamp; ragged lengths exercise uneven row chunks).
    let prompts: Vec<Vec<u32>> =
        vec![vec![1, 2, 3], vec![9, 8, 7, 6, 5], vec![100], vec![42, 250, 17, 3], vec![5, 6]];
    let run = |threads: usize| -> (Vec<f32>, Vec<Vec<f32>>) {
        let (mut eng, _) = engine(threads, 83);
        let mut caches: Vec<KvCache> = (0..prompts.len()).map(|_| eng.new_cache(16)).collect();
        let prompt_refs: Vec<&[u32]> = prompts.iter().map(|p| p.as_slice()).collect();
        let mut cache_refs: Vec<&mut KvCache> = caches.iter_mut().collect();
        let mut logits = eng.prefill_batch(&prompt_refs, &mut cache_refs).unwrap();
        let prefill = logits.clone();
        let vocab = GEOM.vocab;
        let mut steps = Vec::new();
        for _ in 0..4 {
            let next: Vec<u32> = (0..prompts.len())
                .map(|i| serve::argmax(&logits[i * vocab..(i + 1) * vocab]))
                .collect();
            let mut refs: Vec<&mut KvCache> = caches.iter_mut().collect();
            logits = eng.decode_batch(&next, &mut refs).unwrap();
            steps.push(logits.clone());
        }
        (prefill, steps)
    };
    let base = run(1);
    for threads in [2usize, 3, 8] {
        let got = run(threads);
        assert_eq!(base.0, got.0, "prefill logits diverge at {threads} workers");
        assert_eq!(base.1, got.1, "decode logits diverge at {threads} workers");
    }
}

#[test]
fn greedy_decode_is_batch_size_invariant() {
    // The same mixed-task request set must generate bit-identical token
    // sequences whether the scheduler runs it at batch 1 or batch 4
    // (which also varies the cross-request prefill grouping: batch 1
    // prefills each prompt alone, batch 4 fuses up to 4 prompts).
    let run = |max_batch: usize| -> Vec<(u64, Vec<u32>)> {
        let (eng, base_q) = engine(2, 29);
        let adapters = serve::synth_adapters(&base_q, &["a", "b", "c"], 7);
        let mut sched = Scheduler::new(
            eng,
            adapters,
            SchedulerConfig {
                max_batch,
                window: 64,
                ..SchedulerConfig::default()
            },
        )
        .unwrap();
        for i in 0..9u32 {
            let task = ["a", "b", "c"][(i % 3) as usize];
            sched.submit(task, vec![1 + i, 40 + i, 7], 10, u32::MAX).unwrap();
        }
        let mut out: Vec<(u64, Vec<u32>)> = sched
            .run_until_idle()
            .unwrap()
            .into_iter()
            .map(|r| (r.id, r.tokens))
            .collect();
        out.sort_by_key(|(id, _)| *id);
        out
    };
    let b1 = run(1);
    let b4 = run(4);
    assert_eq!(b1, b4);
    assert!(b1.iter().all(|(_, t)| t.len() == 10));
}

#[test]
fn scale_swap_changes_outputs_revertibly_and_leaves_codes_alone() {
    let (mut eng, base_q) = engine(2, 57);
    let adapters = serve::synth_adapters(&base_q, &["base", "tuned"], 3);
    let prompt: Vec<u32> = vec![9, 100, 4];
    let logits_of = |eng: &mut Engine| {
        let mut c = eng.new_cache(16);
        eng.prefill(&prompt, &mut c).unwrap()
    };
    let bytes0 = eng.packed_bytes();
    let base_logits = logits_of(&mut eng);

    let n = eng.apply_adapter(adapters.get("tuned").unwrap()).unwrap();
    // Every projection contributes one .s and one .z tensor.
    assert_eq!(n, GEOM.n_layers * 7 * 2);
    let tuned_logits = logits_of(&mut eng);
    assert!(max_abs(&base_logits, &tuned_logits) > 0.0, "tuned adapter must change logits");
    assert_eq!(eng.packed_bytes(), bytes0, "codes never move on a swap");

    // Swapping back restores the exact base behavior.
    eng.apply_adapter(adapters.get("base").unwrap()).unwrap();
    assert_eq!(logits_of(&mut eng), base_logits, "scale swap must be exactly revertible");

    // Malformed adapters are rejected before any mutation.
    let mut bad = peqa::model::Checkpoint::new();
    bad.insert("layers.0.attn.q.w", peqa::tensor::Tensor::zeros(&[2, 2]));
    assert!(eng.apply_adapter(&bad).is_err());
    let mut bad_shape = peqa::model::Checkpoint::new();
    bad_shape.insert("layers.0.attn.q.s", peqa::tensor::Tensor::zeros(&[1, 1]));
    assert!(eng.apply_adapter(&bad_shape).is_err());
    assert_eq!(logits_of(&mut eng), base_logits, "failed swap leaves the engine unchanged");
}

#[test]
fn asymmetric_adapter_swaps_leave_no_residue() {
    // THE residue regression: adapter `full` covers every projection,
    // adapter `partial` covers only layers.0.attn.q.s and
    // layers.1.mlp.gate.z. Swapping full → partial on one engine must
    // produce exactly the logits of a fresh engine that only ever
    // applied `partial` — i.e. the projections `partial` does not cover
    // revert to base scales instead of keeping `full`'s.
    let seed = 57u64;
    let prompt: Vec<u32> = vec![9, 100, 4, 33, 7];
    let (_, base_q) = engine(2, seed);
    let full =
        serve::synth_adapters(&base_q, &["base", "full"], 3).get("full").unwrap().clone();
    let mut partial = peqa::model::Checkpoint::new();
    let mut s = base_q.req("layers.0.attn.q.s").unwrap().clone();
    for v in s.data_mut() {
        *v *= 1.7;
    }
    partial.insert("layers.0.attn.q.s", s);
    let mut z = base_q.req("layers.1.mlp.gate.z").unwrap().clone();
    for v in z.data_mut() {
        *v += 0.25;
    }
    partial.insert("layers.1.mlp.gate.z", z);

    let logits_with = |adapter: Option<&peqa::model::Checkpoint>| -> Vec<f32> {
        let (mut eng, _) = engine(2, seed);
        if let Some(a) = adapter {
            eng.apply_adapter(a).unwrap();
        }
        let mut c = eng.new_cache(32);
        eng.prefill(&prompt, &mut c).unwrap()
    };
    let l_base = logits_with(None);
    let l_full = logits_with(Some(&full));
    let l_partial = logits_with(Some(&partial));
    // The three behaviors are genuinely distinct, so residue would show.
    assert!(max_abs(&l_base, &l_full) > 0.0);
    assert!(max_abs(&l_base, &l_partial) > 0.0);
    assert!(max_abs(&l_full, &l_partial) > 0.0);

    // One engine, both swap orders.
    let (mut eng, _) = engine(2, seed);
    eng.apply_adapter(&full).unwrap();
    eng.apply_adapter(&partial).unwrap();
    let mut c = eng.new_cache(32);
    assert_eq!(
        eng.prefill(&prompt, &mut c).unwrap(),
        l_partial,
        "full → partial must equal partial applied to a fresh engine"
    );
    eng.apply_adapter(&full).unwrap();
    let mut c = eng.new_cache(32);
    assert_eq!(
        eng.prefill(&prompt, &mut c).unwrap(),
        l_full,
        "partial → full must equal full applied to a fresh engine"
    );
    // And partial → base-coverage-only round trip: applying an EMPTY
    // adapter restores the pristine base engine.
    eng.apply_adapter(&peqa::model::Checkpoint::new()).unwrap();
    let mut c = eng.new_cache(32);
    assert_eq!(eng.prefill(&prompt, &mut c).unwrap(), l_base);
}

#[test]
fn sliding_window_decode_stays_finite_and_deterministic() {
    // Sequences longer than the KV capacity wrap the ring; decode must
    // keep producing finite logits and stay thread-invariant.
    let (mut e1, _) = engine(1, 71);
    let (mut e3, _) = engine(3, 71);
    let prompt: Vec<u32> = (0..20).map(|i| (i * 13 + 5) % 256).collect();
    let mut c1 = e1.new_cache(8);
    let mut c3 = e3.new_cache(8);
    let mut l1 = e1.prefill(&prompt, &mut c1).unwrap();
    let mut l3 = e3.prefill(&prompt, &mut c3).unwrap();
    assert_eq!(l1, l3);
    for _ in 0..6 {
        let n = serve::argmax(&l1);
        let mut r1 = [&mut c1];
        let mut r3 = [&mut c3];
        l1 = e1.decode_batch(&[n], &mut r1).unwrap();
        l3 = e3.decode_batch(&[n], &mut r3).unwrap();
        assert_eq!(l1, l3);
        assert!(l1.iter().all(|v| v.is_finite()));
    }
    assert_eq!(c1.pos(), prompt.len() + 6);
    assert_eq!(c1.len(), 8);
}

#[test]
fn threaded_server_matches_direct_scheduler_under_concurrency() {
    let mk = || {
        let (eng, base_q) = engine(2, 29);
        let adapters = serve::synth_adapters(&base_q, &["a", "b", "c"], 7);
        Scheduler::new(
            eng,
            adapters,
            SchedulerConfig { max_batch: 4, window: 64, ..SchedulerConfig::default() },
        )
        .unwrap()
    };
    let req = |i: u32| -> (&'static str, Vec<u32>) {
        (["a", "b", "c"][(i % 3) as usize], vec![1 + i, 40 + i, 7])
    };
    const N: u32 = 12;

    // Ground truth: direct scheduler drain (greedy ⇒ tokens depend only
    // on (task, prompt), not on batching or arrival order).
    let mut expected: HashMap<(String, Vec<u32>), Vec<u32>> = HashMap::new();
    {
        let mut sched = mk();
        let mut keys: HashMap<u64, (String, Vec<u32>)> = HashMap::new();
        for i in 0..N {
            let (task, prompt) = req(i);
            let id = sched.submit(task, prompt.clone(), 6, u32::MAX).unwrap();
            keys.insert(id, (task.to_string(), prompt));
        }
        for r in sched.run_until_idle().unwrap() {
            expected.insert(keys.remove(&r.id).unwrap(), r.tokens);
        }
    }
    assert_eq!(expected.len(), N as usize);

    // 4 concurrent clients × 3 requests each against the threaded server.
    let server = Server::spawn(mk()).unwrap();
    let expected = &expected;
    std::thread::scope(|s| {
        for c in 0..4u32 {
            let h = server.handle();
            s.spawn(move || {
                for j in 0..3u32 {
                    let i = c * 3 + j;
                    let (task, prompt) = req(i);
                    let r = h.generate(task, prompt.clone(), 6, u32::MAX).unwrap();
                    assert_eq!(r.task, task);
                    assert_eq!(
                        &r.tokens,
                        expected.get(&(task.to_string(), prompt)).unwrap(),
                        "client {c} request {j}: server tokens diverge from direct drain"
                    );
                }
            });
        }
    });
    let m = server.handle().metrics().unwrap();
    assert_eq!(m.completed, N as usize);
    assert!(
        (1..=N as usize).contains(&m.prefill_batches),
        "every admit pass prefills at least one request (got {})",
        m.prefill_batches
    );
    server.shutdown();
}

#[test]
fn tokenizer_roundtrips_demo_corpus_and_stop_token_truncates() {
    // encode → decode round-trip over the serving demo corpus.
    let tok = Tokenizer::byte_level(512);
    let text = corpus::wikitext_sim(12, 4000);
    let ids = tok.encode(&text);
    assert_eq!(tok.decode(&ids).unwrap(), text, "encode/decode must round-trip");

    // Establish the greedy continuation without any stop token...
    let (eng, base_q) = engine(2, 97);
    let adapters = serve::synth_adapters(&base_q, &["a"], 1);
    let prompt: Vec<u32> = vec![12, 34, 56];
    let cfg = SchedulerConfig { max_batch: 4, window: 64, ..SchedulerConfig::default() };
    let mut free_run = Scheduler::new(eng, adapters, cfg).unwrap();
    free_run.submit("a", prompt.clone(), 8, u32::MAX).unwrap();
    let unstopped = free_run.run_until_idle().unwrap().remove(0).tokens;
    assert_eq!(unstopped.len(), 8);

    // ...then pick as stop id a token whose FIRST occurrence in the
    // greedy continuation is at index `pos` (greedy decode may repeat
    // tokens, and an earlier occurrence would truncate sooner than the
    // test expects). The stopped response must be truncated exactly
    // before the stop id, never contain it, and the sibling requests
    // (different stop ids) must be unaffected mid-batch.
    let pos = (1..unstopped.len())
        .find(|&i| !unstopped[..i].contains(&unstopped[i]))
        .unwrap_or(0);
    let stop = unstopped[pos];
    let (eng, base_q) = engine(2, 97);
    let adapters = serve::synth_adapters(&base_q, &["a"], 1);
    let mut sched = Scheduler::new(eng, adapters, cfg).unwrap();
    let id_stopped = sched.submit("a", prompt.clone(), 8, stop).unwrap();
    let id_free1 = sched.submit("a", prompt.clone(), 8, u32::MAX).unwrap();
    let id_free2 = sched.submit("a", prompt.clone(), 8, u32::MAX).unwrap();
    let responses = sched.run_until_idle().unwrap();
    assert_eq!(responses.len(), 3);
    for r in &responses {
        if r.id == id_stopped {
            assert_eq!(r.tokens, unstopped[..pos].to_vec(), "truncate before the stop id");
            assert!(!r.tokens.contains(&stop), "stop id must not leak into the response");
            // The decoded text is exactly the decoded truncation.
            assert_eq!(
                tok.decode(&r.tokens).unwrap(),
                tok.decode(&unstopped[..pos]).unwrap()
            );
        } else {
            assert!([id_free1, id_free2].contains(&r.id));
            assert_eq!(r.tokens, unstopped, "siblings decode past another request's stop id");
        }
    }
}

fn max_abs(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}
