//! Host serving end-to-end tests (default build — no artifacts, no xla):
//!
//! * decode parity: the packed engine's logits match the reference
//!   dequantize-then-`matmul_naive` forward to ≤ 1e-4, at prefill and at
//!   every incremental decode step;
//! * determinism: greedy decode is bit-identical across kernel worker
//!   thread counts (the PEQA_THREADS axis, pinned explicitly here) and
//!   across scheduler batch sizes;
//! * scale-swap contract: task switches replace only f32 scale/zero
//!   tensors, are exactly revertible, and never touch packed codes;
//! * tokenizer round-trip on the demo corpus and stop-token truncation
//!   (a stop id sampled mid-batch must not leak into the response).

use peqa::data::corpus;
use peqa::serve::{
    self, reference_forward, Engine, ModelGeom, Sampling, Scheduler, SchedulerConfig,
};
use peqa::tokenizer::Tokenizer;

const GEOM: ModelGeom = ModelGeom { vocab: 300, d_model: 32, n_layers: 2, n_heads: 2, d_ff: 64 };

fn engine(threads: usize, seed: u64) -> (Engine, peqa::model::Checkpoint) {
    let (pm, base_q) = serve::synth_packed(&GEOM, 4, Some(16), seed).unwrap();
    (Engine::from_packed(pm, GEOM, threads).unwrap(), base_q)
}

#[test]
fn decode_parity_with_dequantized_reference() {
    let (eng, base_q) = engine(2, 41);
    let fp_ref = base_q.dequantize().unwrap();
    let mut seq: Vec<u32> = vec![10, 7, 42, 99, 3, 250, 31];

    // Prefill the whole prompt as one block.
    let mut cache = eng.new_cache(64);
    let mut logits = eng.prefill(&seq, &mut cache).unwrap();
    let r = reference_forward(&fp_ref, &GEOM, &seq).unwrap();
    let (t, vocab) = r.dims2().unwrap();
    assert_eq!((t, vocab), (seq.len(), GEOM.vocab));
    let last = &r.data()[(t - 1) * vocab..];
    let d0 = max_abs(&logits, last);
    assert!(d0 <= 1e-4, "prefill parity: {d0}");

    // Greedy-extend step by step through the batched decode entry point;
    // every step must stay within 1e-4 of the full dense recompute.
    for step in 0..6 {
        let next = serve::argmax(&logits);
        seq.push(next);
        let mut refs = [&mut cache];
        logits = eng.decode_batch(&[next], &mut refs).unwrap();
        let r = reference_forward(&fp_ref, &GEOM, &seq).unwrap();
        let last = &r.data()[(seq.len() - 1) * GEOM.vocab..];
        let d = max_abs(&logits, last);
        assert!(d <= 1e-4, "step {step} parity: {d}");
    }
}

#[test]
fn greedy_decode_is_thread_count_invariant() {
    // PEQA_THREADS=1 vs 4, pinned through the engine's explicit worker
    // count (the env var feeds the same parameter in production).
    let (e1, _) = engine(1, 13);
    let (e4, _) = engine(4, 13);
    let prompt: Vec<u32> = vec![5, 200, 17, 63];
    let mut c1 = e1.new_cache(64);
    let mut c4 = e4.new_cache(64);
    let mut l1 = e1.prefill(&prompt, &mut c1).unwrap();
    let mut l4 = e4.prefill(&prompt, &mut c4).unwrap();
    assert_eq!(l1, l4, "prefill logits must be bitwise equal");
    for _ in 0..8 {
        let n1 = serve::argmax(&l1);
        let n4 = serve::argmax(&l4);
        assert_eq!(n1, n4);
        let mut r1 = [&mut c1];
        let mut r4 = [&mut c4];
        l1 = e1.decode_batch(&[n1], &mut r1).unwrap();
        l4 = e4.decode_batch(&[n4], &mut r4).unwrap();
        assert_eq!(l1, l4, "decode logits must be bitwise equal");
    }
}

#[test]
fn greedy_decode_is_batch_size_invariant() {
    // The same mixed-task request set must generate bit-identical token
    // sequences whether the scheduler runs it at batch 1 or batch 4.
    let run = |max_batch: usize| -> Vec<(u64, Vec<u32>)> {
        let (eng, base_q) = engine(2, 29);
        let adapters = serve::synth_adapters(&base_q, &["a", "b", "c"], 7);
        let mut sched = Scheduler::new(
            eng,
            adapters,
            SchedulerConfig {
                max_batch,
                window: 64,
                sampling: Sampling::Greedy,
                seed: 0,
            },
        );
        for i in 0..9u32 {
            let task = ["a", "b", "c"][(i % 3) as usize];
            sched.submit(task, vec![1 + i, 40 + i, 7], 10, u32::MAX);
        }
        let mut out: Vec<(u64, Vec<u32>)> = sched
            .run_until_idle()
            .unwrap()
            .into_iter()
            .map(|r| (r.id, r.tokens))
            .collect();
        out.sort_by_key(|(id, _)| *id);
        out
    };
    let b1 = run(1);
    let b4 = run(4);
    assert_eq!(b1, b4);
    assert!(b1.iter().all(|(_, t)| t.len() == 10));
}

#[test]
fn scale_swap_changes_outputs_revertibly_and_leaves_codes_alone() {
    let (mut eng, base_q) = engine(2, 57);
    let adapters = serve::synth_adapters(&base_q, &["base", "tuned"], 3);
    let prompt: Vec<u32> = vec![9, 100, 4];
    let logits_of = |eng: &Engine| {
        let mut c = eng.new_cache(16);
        eng.prefill(&prompt, &mut c).unwrap()
    };
    let bytes0 = eng.packed_bytes();
    let base_logits = logits_of(&eng);

    let n = eng.apply_adapter(adapters.get("tuned").unwrap()).unwrap();
    // Every projection contributes one .s and one .z tensor.
    assert_eq!(n, GEOM.n_layers * 7 * 2);
    let tuned_logits = logits_of(&eng);
    assert!(max_abs(&base_logits, &tuned_logits) > 0.0, "tuned adapter must change logits");
    assert_eq!(eng.packed_bytes(), bytes0, "codes never move on a swap");

    // Swapping back restores the exact base behavior.
    eng.apply_adapter(adapters.get("base").unwrap()).unwrap();
    assert_eq!(logits_of(&eng), base_logits, "scale swap must be exactly revertible");

    // Malformed adapters are rejected before any mutation.
    let mut bad = peqa::model::Checkpoint::new();
    bad.insert("layers.0.attn.q.w", peqa::tensor::Tensor::zeros(&[2, 2]));
    assert!(eng.apply_adapter(&bad).is_err());
    let mut bad_shape = peqa::model::Checkpoint::new();
    bad_shape.insert("layers.0.attn.q.s", peqa::tensor::Tensor::zeros(&[1, 1]));
    assert!(eng.apply_adapter(&bad_shape).is_err());
    assert_eq!(logits_of(&eng), base_logits, "failed swap leaves the engine unchanged");
}

#[test]
fn sliding_window_decode_stays_finite_and_deterministic() {
    // Sequences longer than the KV capacity wrap the ring; decode must
    // keep producing finite logits and stay thread-invariant.
    let (e1, _) = engine(1, 71);
    let (e3, _) = engine(3, 71);
    let prompt: Vec<u32> = (0..20).map(|i| (i * 13 + 5) % 256).collect();
    let mut c1 = e1.new_cache(8);
    let mut c3 = e3.new_cache(8);
    let mut l1 = e1.prefill(&prompt, &mut c1).unwrap();
    let mut l3 = e3.prefill(&prompt, &mut c3).unwrap();
    assert_eq!(l1, l3);
    for _ in 0..6 {
        let n = serve::argmax(&l1);
        let mut r1 = [&mut c1];
        let mut r3 = [&mut c3];
        l1 = e1.decode_batch(&[n], &mut r1).unwrap();
        l3 = e3.decode_batch(&[n], &mut r3).unwrap();
        assert_eq!(l1, l3);
        assert!(l1.iter().all(|v| v.is_finite()));
    }
    assert_eq!(c1.pos(), prompt.len() + 6);
    assert_eq!(c1.len(), 8);
}

#[test]
fn tokenizer_roundtrips_demo_corpus_and_stop_token_truncates() {
    // encode → decode round-trip over the serving demo corpus.
    let tok = Tokenizer::byte_level(512);
    let text = corpus::wikitext_sim(12, 4000);
    let ids = tok.encode(&text);
    assert_eq!(tok.decode(&ids).unwrap(), text, "encode/decode must round-trip");

    // Establish the greedy continuation without any stop token...
    let (eng, base_q) = engine(2, 97);
    let adapters = serve::synth_adapters(&base_q, &["a"], 1);
    let prompt: Vec<u32> = vec![12, 34, 56];
    let cfg = SchedulerConfig { max_batch: 4, window: 64, sampling: Sampling::Greedy, seed: 0 };
    let mut free_run = Scheduler::new(eng, adapters, cfg);
    free_run.submit("a", prompt.clone(), 8, u32::MAX);
    let unstopped = free_run.run_until_idle().unwrap().remove(0).tokens;
    assert_eq!(unstopped.len(), 8);

    // ...then pick as stop id a token whose FIRST occurrence in the
    // greedy continuation is at index `pos` (greedy decode may repeat
    // tokens, and an earlier occurrence would truncate sooner than the
    // test expects). The stopped response must be truncated exactly
    // before the stop id, never contain it, and the sibling requests
    // (different stop ids) must be unaffected mid-batch.
    let pos = (1..unstopped.len())
        .find(|&i| !unstopped[..i].contains(&unstopped[i]))
        .unwrap_or(0);
    let stop = unstopped[pos];
    let (eng, base_q) = engine(2, 97);
    let adapters = serve::synth_adapters(&base_q, &["a"], 1);
    let mut sched = Scheduler::new(eng, adapters, cfg);
    let id_stopped = sched.submit("a", prompt.clone(), 8, stop);
    let id_free1 = sched.submit("a", prompt.clone(), 8, u32::MAX);
    let id_free2 = sched.submit("a", prompt.clone(), 8, u32::MAX);
    let responses = sched.run_until_idle().unwrap();
    assert_eq!(responses.len(), 3);
    for r in &responses {
        if r.id == id_stopped {
            assert_eq!(r.tokens, unstopped[..pos].to_vec(), "truncate before the stop id");
            assert!(!r.tokens.contains(&stop), "stop id must not leak into the response");
            // The decoded text is exactly the decoded truncation.
            assert_eq!(
                tok.decode(&r.tokens).unwrap(),
                tok.decode(&unstopped[..pos]).unwrap()
            );
        } else {
            assert!([id_free1, id_free2].contains(&r.id));
            assert_eq!(r.tokens, unstopped, "siblings decode past another request's stop id");
        }
    }
}

fn max_abs(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}
