//! Host PEQA training end-to-end tests (default build — no artifacts,
//! no xla):
//!
//! * gradient checks: the kernel-level scale/zero reductions match
//!   central finite differences to ≤ 1e-3 relative error per bit-width
//!   (2/3/4) for per-channel and g128 grouping (the loss is linear in
//!   s/z with frozen codes, so fd is exact up to f32 rounding); the
//!   full-model cross-entropy gradient matches a directional fd probe;
//! * determinism: a training step is bit-identical across kernel worker
//!   thread counts (the PEQA_THREADS axis, pinned explicitly);
//! * the STE contract: packed codes, fp tensors and (without
//!   --train-zeros) zero-points are bitwise frozen; only scales move;
//!   the trainable count matches the analytic memmodel count;
//! * the paper's loop closes: finetune a tiny synth model on one task,
//!   register the extracted adapter (which passes strict coverage),
//!   and the served greedy decode changes vs base while training loss
//!   and held-out perplexity on the task distribution drop; serving
//!   base-model + adapter is bitwise the tuned model.

use peqa::config::TrainConfig;
use peqa::data::{Batch, LmBatcher};
use peqa::memmodel;
use peqa::quant::{quantize_rtn, PackedMatrix};
use peqa::serve::{self, Engine, ModelGeom, Scheduler, SchedulerConfig};
use peqa::tensor::Tensor;
use peqa::train::{HostPeqaTuner, MultiTaskTuner, Tuner};
use peqa::util::Pcg32;

fn full_batch(bsz: usize, t_len: usize, vocab: u32, seed: u64) -> Batch {
    let mut rng = Pcg32::new(seed);
    Batch {
        tokens: (0..bsz * t_len).map(|_| rng.below(vocab) as i32).collect(),
        mask: vec![1.0; bsz * (t_len - 1)],
        batch: bsz,
        seq: t_len,
    }
}

#[test]
fn kernel_scale_zero_grads_match_fd_per_width_and_grouping() {
    // The acceptance gradcheck: per bit-width 2/3/4, per-channel and
    // g128, analytic (ds, dz) within 1e-3 relative of central finite
    // differences of L = Σ w ⊙ (X·Ŵᵀ). L is linear in s and z (codes
    // frozen), so fd error is pure f32 rounding.
    let cols = 256usize;
    for bits in [2u8, 3, 4] {
        for group in [None, Some(128)] {
            let mut rng = Pcg32::new(100 + bits as u64);
            let w = Tensor::normal(&[12, cols], 0.4, &mut rng);
            let x = Tensor::normal(&[5, cols], 1.0, &mut rng);
            let dy = Tensor::normal(&[5, 12], 1.0, &mut rng);
            let q = quantize_rtn(&w, bits, group).unwrap();
            let pm = PackedMatrix::from_quantized(&q);
            let loss = |m: &PackedMatrix| -> f64 {
                let y = m.matmul_t(&x).unwrap();
                y.data().iter().zip(dy.data()).map(|(&a, &b)| (a * b) as f64).sum()
            };
            let (ds, dz) = pm.grad_scales_zeros(x.data(), dy.data(), 5, 4).unwrap();
            let ng = pm.n_groups();
            let mut checked = 0usize;
            for r in 0..pm.rows {
                for kg in 0..ng {
                    for (which, grad) in [("s", ds.at2(r, kg)), ("z", dz.at2(r, kg))] {
                        let mut hi = pm.clone();
                        let mut lo = pm.clone();
                        let (th, tl, v) = if which == "s" {
                            (&mut hi.scales, &mut lo.scales, pm.scales.at2(r, kg))
                        } else {
                            (&mut hi.zeros, &mut lo.zeros, pm.zeros.at2(r, kg))
                        };
                        let h = (0.01 * v.abs()).max(1e-3);
                        th.set2(r, kg, v + h);
                        tl.set2(r, kg, v - h);
                        let fd = (loss(&hi) - loss(&lo)) / (2.0 * h as f64);
                        let rel = (grad as f64 - fd).abs() / fd.abs().max(1e-2);
                        assert!(
                            rel <= 1e-3,
                            "bits={bits} group={group:?} {which}[{r},{kg}]: \
                             analytic {grad} vs fd {fd} (rel {rel:.2e})"
                        );
                        checked += 1;
                    }
                }
            }
            assert!(checked >= 24, "too few entries checked: {checked}");
        }
    }
}

fn tiny_tuner(
    geom: &ModelGeom,
    bits: u8,
    group: Option<usize>,
    seed: u64,
    train_zeros: bool,
    threads: usize,
    steps: usize,
    lr: f64,
) -> HostPeqaTuner {
    let (pm, _) = serve::synth_packed(geom, bits, group, seed).unwrap();
    let cfg = TrainConfig {
        steps,
        lr,
        warmup_steps: (steps / 10).max(1),
        log_every: 0,
        ..Default::default()
    };
    HostPeqaTuner::from_packed(pm, *geom, cfg, train_zeros, threads).unwrap()
}

#[test]
fn training_forward_is_bitwise_the_serving_engine_and_tracks_dense_reference() {
    // The model the tuner trains must BE the model the engine serves.
    // Since the refactor onto the shared compute core (model::blocks),
    // that is not a tolerance statement: the training forward and
    // Engine::prefill run the SAME fixed-order primitives — RMSNorm,
    // rotary, windowed attention kernel, SwiGLU, packed projections —
    // so the last-position logits must be **bitwise equal**. The dense
    // reference stays a ≤ 1e-4 numeric cross-check per position.
    let geom = ModelGeom { vocab: 300, d_model: 32, n_layers: 2, n_heads: 2, d_ff: 64 };
    let (pm, base_q) = serve::synth_packed(&geom, 4, Some(16), 41).unwrap();
    let fp_ref = base_q.dequantize().unwrap();
    // Short prompt (yᵀ projection path) AND long prompt (ragged
    // direct-layout projection path at threads=2): bitwise either way.
    let prompts: Vec<Vec<u32>> = vec![
        vec![10, 7, 42, 99, 3, 250, 31],
        (0..16).map(|i| ((i * 13 + 5) % 299) as u32).collect(),
    ];
    for tokens in &prompts {
        let logits = peqa::train::host::forward_logits(&pm, &geom, 2, tokens).unwrap();
        assert_eq!(logits.len(), tokens.len() * geom.vocab);

        let dense = serve::reference_forward(&fp_ref, &geom, tokens).unwrap();
        let max_d = logits
            .iter()
            .zip(dense.data())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_d <= 1e-4, "train forward vs dense reference: {max_d}");

        let mut eng = Engine::from_packed(pm.clone(), geom, 2).unwrap();
        let mut cache = eng.new_cache(32);
        let served = eng.prefill(tokens, &mut cache).unwrap();
        let last = &logits[(tokens.len() - 1) * geom.vocab..];
        assert_eq!(
            served,
            last,
            "train forward vs engine prefill must be BITWISE on the shared core \
             (prompt len {})",
            tokens.len()
        );
    }
}

#[test]
fn full_model_gradient_matches_directional_fd() {
    // Directional probe through the whole network: perturb EVERY scale
    // by ±h·sign(ds) and compare the loss delta against Σ|ds|. The
    // aggregate keeps the fd signal far above f32 forward noise, so a
    // single wrong backward stage (rmsnorm, rope, softmax, SwiGLU,
    // either kernel reduction) shows up at O(1) relative error.
    let geom = ModelGeom { vocab: 64, d_model: 16, n_layers: 2, n_heads: 2, d_ff: 32 };
    for train_zeros in [false, true] {
        let mut tuner = tiny_tuner(&geom, 4, Some(8), 21, train_zeros, 2, 8, 2e-3);
        let batch = full_batch(3, 10, 64, 9);
        let (_, grads) = tuner.forward_backward(&batch).unwrap();
        let h = 5e-3f32;
        for which in ["s", "z"] {
            if which == "z" && !train_zeros {
                continue;
            }
            let directional: f64 = grads
                .iter()
                .flat_map(|(_, ds, dz)| {
                    (if which == "s" { ds } else { dz }).data().iter().map(|g| g.abs() as f64)
                })
                .sum();
            assert!(directional > 1e-3, "{which}: gradient vanished ({directional})");
            let shift = |sign: f32, tuner: &mut HostPeqaTuner| {
                for (prefix, ds, dz) in &grads {
                    let m = tuner.model_mut().matrix_mut(prefix).unwrap();
                    let (t, g) = if which == "s" { (&mut m.scales, ds) } else { (&mut m.zeros, dz) };
                    for (pv, gv) in t.data_mut().iter_mut().zip(g.data()) {
                        *pv += sign * h * gv.signum();
                    }
                }
            };
            shift(1.0, &mut tuner);
            let lp = tuner.loss(&batch).unwrap() as f64;
            shift(-2.0, &mut tuner);
            let lm = tuner.loss(&batch).unwrap() as f64;
            shift(1.0, &mut tuner); // restore
            let fd = (lp - lm) / (2.0 * h as f64);
            let rel = (fd - directional).abs() / directional;
            assert!(
                rel <= 1e-2,
                "{which} (train_zeros={train_zeros}): directional {directional} \
                 vs fd {fd} (rel {rel:.2e})"
            );
        }
    }
}

#[test]
fn training_step_is_bitwise_thread_invariant() {
    let geom = ModelGeom { vocab: 64, d_model: 16, n_layers: 2, n_heads: 2, d_ff: 32 };
    let run = |threads: usize| {
        let mut tuner = tiny_tuner(&geom, 3, Some(8), 7, true, threads, 3, 3e-3);
        let mut losses = Vec::new();
        for step in 0..3u64 {
            losses.push(tuner.step(&full_batch(2, 8, 64, 40 + step)).unwrap());
        }
        (losses, tuner.finish().unwrap())
    };
    let (l1, ck1) = run(1);
    for threads in [2usize, 4, 8] {
        let (ln, ckn) = run(threads);
        assert_eq!(l1, ln, "losses diverge at {threads} threads");
        assert_eq!(ck1.names(), ckn.names());
        for (name, t) in ck1.iter() {
            assert_eq!(
                t.data(),
                ckn.req(name).unwrap().data(),
                "'{name}' diverges at {threads} threads"
            );
        }
    }
}

#[test]
fn only_scales_move_and_counts_match_memmodel() {
    let geom = ModelGeom { vocab: 64, d_model: 16, n_layers: 2, n_heads: 2, d_ff: 32 };
    let mut tuner = tiny_tuner(&geom, 4, None, 13, false, 2, 4, 5e-3);
    // Trainable count: one scale per (row, group) over exactly the
    // quantizable block tensors — the same count memmodel's Table 4
    // analytics produce for this geometry (per-channel: group == cols).
    let mg = memmodel::Geometry::llama("t", geom.vocab, geom.d_model, geom.n_layers, geom.d_ff);
    assert_eq!(tuner.trainable_params() as u64, memmodel::peqa_trainable(&mg, None));
    assert_eq!(tuner.trainable_state_bytes(), 3 * 4 * tuner.trainable_params() as u64);

    let before = tuner.model().to_checkpoint();
    let packed_before = tuner.model().packed_bytes();
    for step in 0..4u64 {
        tuner.step(&full_batch(2, 8, 64, 70 + step)).unwrap();
    }
    assert_eq!(tuner.step_count(), 4);
    let after = tuner.model().to_checkpoint();
    assert_eq!(tuner.model().packed_bytes(), packed_before, "codes reallocated");
    let mut scales_moved = 0usize;
    for (name, t0) in before.iter() {
        let t1 = after.req(name).unwrap();
        if name.ends_with(".s") {
            if t0.max_abs_diff(t1) > 0.0 {
                scales_moved += 1;
            }
        } else {
            // Codes, zero-points (not trained here), embeddings, norms,
            // LM head: bitwise frozen.
            assert_eq!(t0.data(), t1.data(), "'{name}' must be frozen");
        }
    }
    assert_eq!(scales_moved, geom.n_layers * 7, "every projection's scales should move");
}

#[test]
fn multi_task_round_robin_is_bitwise_independent_and_serves_n_adapters() {
    // Round-robin multi-task tuning over ONE shared packed model must
    // be bitwise identical to N independent single-task runs (a task's
    // step depends only on its own scales/zeros + the frozen shared
    // codes), and the N extracted adapters must register and serve
    // together through one strict-coverage scheduler — the CLI
    // `peqa finetune --tasks` → `peqa serve --adapters` loop at library
    // level.
    let geom = ModelGeom { vocab: 512, d_model: 32, n_layers: 2, n_heads: 2, d_ff: 64 };
    let (pm, _) = serve::synth_packed(&geom, 4, Some(16), 19).unwrap();
    let cfg = TrainConfig { steps: 6, lr: 5e-3, warmup_steps: 1, log_every: 0, ..Default::default() };
    let steps = 6usize;
    let names = vec!["alpha".to_string(), "beta".to_string(), "gamma".to_string()];
    // Per-task corpora with distinct learnable structure.
    let stream_of = |ti: usize| -> Vec<u32> {
        let motif: Vec<u32> = (0..16u32).map(|i| (i * (31 + 2 * ti as u32) + 7) % 500).collect();
        motif.iter().cycle().take(1200).cloned().collect()
    };
    // LmBatcher is deterministic in (stream, seed): construct identical
    // batch sequences for the round-robin and the independent runs.
    let batcher_of = |ti: usize| LmBatcher::new(stream_of(ti), 2, 16, 100 + ti as u64);

    // Independent single-task runs from the same base.
    let mut solo_adapters = Vec::new();
    for ti in 0..names.len() {
        let mut tuner =
            HostPeqaTuner::from_packed(pm.clone(), geom, cfg.clone(), false, 2).unwrap();
        let mut batcher = batcher_of(ti);
        for _ in 0..steps {
            tuner.step(&batcher.next_batch()).unwrap();
        }
        solo_adapters.push(tuner.extract_adapter());
    }

    // Round-robin over one shared model, same batches per task.
    let tuner = HostPeqaTuner::from_packed(pm.clone(), geom, cfg.clone(), false, 2).unwrap();
    let mut mt = MultiTaskTuner::new(tuner, &names).unwrap();
    let mut batchers: Vec<LmBatcher> = (0..names.len()).map(batcher_of).collect();
    for _ in 0..steps {
        for (ti, batcher) in batchers.iter_mut().enumerate() {
            mt.step_task(ti, &batcher.next_batch()).unwrap();
        }
    }
    for (ti, solo) in solo_adapters.iter().enumerate() {
        let rr = mt.extract_adapter(ti);
        assert_eq!(rr.names(), solo.names());
        for (name, t) in solo.iter() {
            assert_eq!(
                t.data(),
                rr.req(name).unwrap().data(),
                "task {ti} '{name}': round-robin must be bitwise the independent run"
            );
        }
    }
    // The tasks genuinely diverged from each other.
    let a0 = solo_adapters[0].req("layers.0.attn.q.s").unwrap();
    let a1 = solo_adapters[1].req("layers.0.attn.q.s").unwrap();
    assert!(a0.max_abs_diff(a1) > 0.0, "distinct corpora must tune distinct scales");

    // Write all N adapters to one dir, load as a store, serve strictly —
    // the `peqa serve --adapters` half of the loop.
    let dir = std::env::temp_dir().join("peqa_test_multitask_adapters");
    std::fs::remove_dir_all(&dir).ok();
    for (ti, name) in names.iter().enumerate() {
        mt.extract_adapter(ti).save(&dir.join(format!("{name}.adapter"))).unwrap();
    }
    let store = serve::AdapterStore::load_dir(&dir).unwrap();
    assert_eq!(store.tasks().len(), names.len());
    let eng = Engine::from_packed(pm, geom, 2).unwrap();
    let scfg = SchedulerConfig {
        max_batch: 3,
        window: 64,
        strict_coverage: true,
        ..SchedulerConfig::default()
    };
    let mut sched = Scheduler::new(eng, store, scfg).unwrap();
    let prompt: Vec<u32> = vec![7, 45, 11, 260, 3];
    for name in &names {
        sched.submit(name, prompt.clone(), 8, u32::MAX).unwrap();
    }
    let responses = sched.run_until_idle().unwrap();
    assert_eq!(responses.len(), names.len());
    assert!(responses.iter().all(|r| r.tokens.len() == 8));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn finetune_then_serve_closes_the_loop() {
    // The paper's loop on one host: quantize (synth) → PEQA-tune scales
    // on a task → extract the adapter → scale-swap-serve it.
    let geom = ModelGeom { vocab: 512, d_model: 32, n_layers: 2, n_heads: 2, d_ff: 64 };
    let (pm, _) = serve::synth_packed(&geom, 4, Some(16), 11).unwrap();
    let base_model = pm.clone();
    let cfg = TrainConfig { steps: 30, lr: 5e-3, warmup_steps: 2, log_every: 0, ..Default::default() };
    let mut tuner = HostPeqaTuner::from_packed(pm, geom, cfg, false, 2).unwrap();

    // Task corpus: a repeating 16-token motif — strongly learnable
    // structure so 30 scale-only steps visibly reduce the loss.
    let motif: Vec<u32> = (0..16u32).map(|i| (i * 37 + 11) % 500).collect();
    let stream: Vec<u32> = motif.iter().cycle().take(2400).cloned().collect();
    let mut batcher = LmBatcher::new(stream.clone(), 3, 24, 5);
    tuner.run(30, || batcher.next_batch()).unwrap();

    let losses = tuner.losses().to_vec();
    let first = losses[0];
    let tail: f32 = losses[losses.len() - 5..].iter().sum::<f32>() / 5.0;
    assert!(
        tail < first - 0.05,
        "training loss must drop: first {first}, last-5 mean {tail} ({losses:?})"
    );

    // Held-out ppl on the task distribution drops too.
    let eval_slice = &stream[..500];
    let base_ppl = peqa::eval::host_perplexity(&base_model, 2, eval_slice, 2, 24, 2).unwrap();
    let tuned_ppl = peqa::eval::host_perplexity(tuner.model(), 2, eval_slice, 2, 24, 2).unwrap();
    assert!(
        tuned_ppl < base_ppl,
        "ppl must improve on the task: base {base_ppl:.3} tuned {tuned_ppl:.3}"
    );

    // The extracted adapter passes strict coverage and serves: greedy
    // decode changes vs base, and base-engine + adapter is bitwise the
    // tuned model.
    let adapter = tuner.extract_adapter();
    let tuned_model = tuner.into_model();
    let prompt: Vec<u32> = motif[..6].to_vec();
    let logits_of = |eng: &mut Engine| {
        let mut c = eng.new_cache(32);
        eng.prefill(&prompt, &mut c).unwrap()
    };
    let mut eng_base = Engine::from_packed(base_model.clone(), geom, 2).unwrap();
    let base_logits = logits_of(&mut eng_base);
    let mut eng_tuned = Engine::from_packed(tuned_model, geom, 2).unwrap();
    let tuned_logits = logits_of(&mut eng_tuned);
    assert!(eng_base.adapter_coverage_gaps(&adapter).is_empty());
    eng_base.apply_adapter(&adapter).unwrap();
    let swapped_logits = logits_of(&mut eng_base);
    assert_eq!(swapped_logits, tuned_logits, "base + adapter must BE the tuned model");
    let max_delta = base_logits
        .iter()
        .zip(&swapped_logits)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_delta > 0.0, "tuning must change served logits");

    // End to end through the scheduler in strict-coverage mode: the
    // trained adapter registers cleanly and generations differ from the
    // base task's.
    let mut store = serve::AdapterStore::new();
    store.insert("base", base_model.extract_adapter(false));
    store.insert("tuned", adapter);
    let eng = Engine::from_packed(base_model, geom, 2).unwrap();
    let cfg = SchedulerConfig { max_batch: 2, window: 64, strict_coverage: true, ..SchedulerConfig::default() };
    let mut sched = Scheduler::new(eng, store, cfg).unwrap();
    let id_base = sched.submit("base", prompt.clone(), 12, u32::MAX).unwrap();
    let id_tuned = sched.submit("tuned", prompt.clone(), 12, u32::MAX).unwrap();
    let responses = sched.run_until_idle().unwrap();
    let tok = |id: u64| responses.iter().find(|r| r.id == id).unwrap().tokens.clone();
    assert_ne!(tok(id_base), tok(id_tuned), "served greedy decode must change with the adapter");
}
