//! Serving decode benchmark (host, no xla): end-to-end multi-task
//! decode through `serve::Scheduler` → `serve::Engine` → the fused
//! packed GEMM, measuring the numbers the ROADMAP's serving items track:
//!
//! * decode throughput (tokens/s) and per-request latency p50/p99,
//! * scale-swap task-switch cost (mean + p99 of `swap_times_s`) — the
//!   "adapter-bytes moved" budget of the PEQA deployment story,
//! * paged-KV same-prefix serving (`serve::kvpage`): pages peak /
//!   shared / rejects for N clients forked from one prompt prefix
//!   through a tight page pool.
//!
//! Requests are submitted in task-rotating rounds so every round forces
//! one adapter swap. Writes `BENCH_serve.json` (at `PEQA_BENCH_OUT` or
//! the repo root) so every PR leaves a serving perf datapoint;
//! `scripts/ci.sh` runs this in quick mode and `scripts/bench_diff.py`
//! fails CI on regressions. `PEQA_BENCH_QUICK=1` shrinks the model and
//! the request volume; `PEQA_THREADS` pins the kernel worker count.

use peqa::bench::{quick_mode, save_json, Table};
use peqa::config;
use peqa::json::Value;
use peqa::serve::{
    self, collect_stream, Engine, EnginePool, ModelGeom, PoolConfig, Sampling, Scheduler,
    SchedulerConfig,
};
use peqa::tokenizer::EOS;
use peqa::util::Pcg32;

fn main() -> anyhow::Result<()> {
    let quick = quick_mode();
    let geom = if quick {
        ModelGeom { vocab: 512, d_model: 64, n_layers: 2, n_heads: 4, d_ff: 192 }
    } else {
        ModelGeom { vocab: 512, d_model: 256, n_layers: 4, n_heads: 8, d_ff: 1024 }
    };
    let bits = 4u8;
    let group = Some(64);
    let (rounds, per_round, max_new) = if quick { (4, 6, 16) } else { (12, 16, 32) };
    let threads = peqa::util::num_threads();

    let (pm, base_q) = serve::synth_packed(&geom, bits, group, 11)?;
    let engine = Engine::from_packed(pm, geom, threads)?;
    let packed_bytes = engine.packed_bytes();
    let tasks = ["wikitext", "ptb", "alpaca"];
    let adapters = serve::synth_adapters(&base_q, &tasks, 5);
    let adapter_bytes = adapters.total_bytes();
    let mut sched = Scheduler::new(
        engine,
        adapters,
        SchedulerConfig { max_batch: 8, window: 128, sampling: Sampling::Greedy, seed: 3, ..SchedulerConfig::default() },
    )?;

    // Task-rotating request rounds: each round drains one task, so every
    // round boundary is a real scale swap.
    let mut rng = Pcg32::new(17);
    for round in 0..rounds {
        let task = tasks[round % tasks.len()];
        for _ in 0..per_round {
            let len = 8 + rng.usize_below(16);
            let prompt: Vec<u32> = (0..len).map(|_| rng.below(256)).collect();
            sched.submit(task, prompt, max_new, EOS)?;
        }
        sched.run_until_idle()?;
    }

    let m = sched.metrics.clone();

    // The same task-rotating load again, through the sharded engine
    // pool: N workers over one Arc-shared set of packed codes, streaming
    // clients, task-affine dispatch. This is where the latency axes the
    // pool is judged by come from (TTFT, inter-token cadence, queue
    // depth, sheds, swaps avoided).
    let pool_engines = 2usize;
    let clients = 3usize;
    let (pm, base_q) = serve::synth_packed(&geom, bits, group, 11)?;
    let pool = EnginePool::spawn(
        pm,
        geom,
        (threads / pool_engines).max(1),
        serve::synth_adapters(&base_q, &tasks, 5),
        PoolConfig {
            engines: pool_engines,
            max_batch: 8,
            window: 128,
            seed: 3,
            queue_cap: 256,
            ..PoolConfig::default()
        },
    )?;
    std::thread::scope(|s| {
        for c in 0..clients {
            let h = pool.handle();
            s.spawn(move || {
                let mut rng = Pcg32::new(23 + c as u64);
                for round in 0..rounds {
                    let task = tasks[(round + c) % tasks.len()];
                    for _ in 0..per_round.div_ceil(clients) {
                        let len = 8 + rng.usize_below(16);
                        let prompt: Vec<u32> = (0..len).map(|_| rng.below(256)).collect();
                        let rx = h.submit_stream(task, prompt, max_new, EOS).unwrap();
                        collect_stream(&rx).unwrap();
                    }
                }
            });
        }
    });
    let pool_m = pool.shutdown();

    // Paged-KV same-prefix section: N same-task requests forked from a
    // common prompt prefix through a page pool a fraction of the
    // unshared footprint — the serve::kvpage memory claim (prefix pages
    // attached copy-on-write, not duplicated) as a tracked datapoint.
    let (kv_pages, page_tokens) = (24usize, 16usize);
    let paged_clients = 8usize;
    let (pm, base_q) = serve::synth_packed(&geom, bits, group, 11)?;
    let engine = Engine::from_packed(pm, geom, threads)?;
    let mut paged = Scheduler::new(
        engine,
        serve::synth_adapters(&base_q, &tasks, 5),
        SchedulerConfig {
            max_batch: 8,
            window: 128,
            sampling: Sampling::Greedy,
            seed: 3,
            kv_pages,
            page_tokens,
            ..SchedulerConfig::default()
        },
    )?;
    let mut rng = Pcg32::new(29);
    let prefix: Vec<u32> = (0..2 * page_tokens).map(|_| rng.below(256)).collect();
    for c in 0..paged_clients as u32 {
        let mut p = prefix.clone();
        p.push(c % 256);
        paged.submit(tasks[0], p, max_new, EOS)?;
    }
    paged.run_until_idle()?;
    let paged_m = paged.metrics.clone();

    let mut table = Table::new(
        &format!(
            "§Perf — host serving decode (L{} d{} h{} b{}g{:?}, {} req × {} rounds, {} threads)",
            geom.n_layers, geom.d_model, geom.n_heads, bits, group, per_round, rounds, threads
        ),
        &["metric", "value"],
    );
    let rowf = |t: &mut Table, k: &str, v: String| t.row(&[k.to_string(), v]);
    rowf(&mut table, "requests completed", format!("{}", m.completed));
    rowf(&mut table, "generated tokens", format!("{}", m.generated_tokens));
    rowf(&mut table, "tokens/s", format!("{:.1}", m.tokens_per_s()));
    rowf(&mut table, "latency p50 (ms)", format!("{:.3}", m.p50_latency() * 1e3));
    rowf(&mut table, "latency p99 (ms)", format!("{:.3}", m.p99_latency() * 1e3));
    rowf(&mut table, "scale swaps", format!("{}", m.swap_times_s.len()));
    rowf(&mut table, "swap mean (ms)", format!("{:.4}", m.mean_swap_s() * 1e3));
    rowf(&mut table, "swap p99 (ms)", format!("{:.4}", m.p99_swap_s() * 1e3));
    rowf(&mut table, "decode steps", format!("{}", m.decode_steps));
    rowf(&mut table, "prefill batches", format!("{}", m.prefill_batches));
    rowf(&mut table, "prefill tokens", format!("{}", m.prefill_tokens));
    rowf(&mut table, "packed code bytes", format!("{packed_bytes}"));
    rowf(&mut table, "adapter bytes (3 tasks)", format!("{adapter_bytes}"));
    rowf(&mut table, "pool engines", format!("{pool_engines}"));
    rowf(&mut table, "pool tokens/s", format!("{:.1}", pool_m.tokens_per_s()));
    rowf(&mut table, "pool TTFT p50 (ms)", format!("{:.3}", pool_m.p50_ttft_s() * 1e3));
    rowf(&mut table, "pool TTFT p99 (ms)", format!("{:.3}", pool_m.p99_ttft_s() * 1e3));
    rowf(
        &mut table,
        "pool inter-token p99 (ms)",
        format!("{:.4}", pool_m.p99_inter_token_s() * 1e3),
    );
    rowf(&mut table, "pool queue depth max", format!("{}", pool_m.queue_depth_max));
    rowf(&mut table, "pool shed", format!("{}", pool_m.shed_count));
    rowf(&mut table, "pool swaps avoided", format!("{}", pool_m.swaps_avoided));
    rowf(&mut table, "paged kv pool (pages × tok/page)", format!("{kv_pages} × {page_tokens}"));
    rowf(&mut table, "paged kv pages peak", format!("{}", paged_m.kv_pages_peak));
    rowf(&mut table, "paged kv pages shared", format!("{}", paged_m.kv_pages_shared));
    rowf(&mut table, "paged kv exhausted rejects", format!("{}", paged_m.kv_exhausted_count));
    rowf(&mut table, "paged tokens/s", format!("{:.1}", paged_m.tokens_per_s()));
    table.print();
    let paths = config::Paths::default();
    table.save(&paths.results, "serve_decode").ok();

    let out = std::env::var("PEQA_BENCH_OUT")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| config::repo_root().join("BENCH_serve.json"));
    let doc = Value::obj(vec![
        ("bench", Value::str("serve_decode")),
        ("quick", Value::str(if quick { "1" } else { "0" })),
        ("threads", Value::num(threads as f64)),
        ("n_layers", Value::num(geom.n_layers as f64)),
        ("d_model", Value::num(geom.d_model as f64)),
        ("n_heads", Value::num(geom.n_heads as f64)),
        ("d_ff", Value::num(geom.d_ff as f64)),
        ("vocab", Value::num(geom.vocab as f64)),
        ("bits", Value::num(bits as f64)),
        ("group", Value::num(64.0)),
        ("requests", Value::num(m.completed as f64)),
        ("generated_tokens", Value::num(m.generated_tokens as f64)),
        ("decode_steps", Value::num(m.decode_steps as f64)),
        ("prefill_batches", Value::num(m.prefill_batches as f64)),
        ("prefill_tokens", Value::num(m.prefill_tokens as f64)),
        ("tokens_per_s", Value::num(m.tokens_per_s())),
        ("p50_latency_s", Value::num(m.p50_latency())),
        ("p99_latency_s", Value::num(m.p99_latency())),
        ("swaps", Value::num(m.swap_times_s.len() as f64)),
        ("swap_mean_s", Value::num(m.mean_swap_s())),
        ("swap_p99_s", Value::num(m.p99_swap_s())),
        ("packed_bytes", Value::num(packed_bytes as f64)),
        ("adapter_bytes", Value::num(adapter_bytes as f64)),
        ("pool_engines", Value::num(pool_engines as f64)),
        ("pool_requests", Value::num(pool_m.completed as f64)),
        ("pool_tokens_per_s", Value::num(pool_m.tokens_per_s())),
        ("ttft_p50_s", Value::num(pool_m.p50_ttft_s())),
        ("ttft_p99_s", Value::num(pool_m.p99_ttft_s())),
        ("inter_token_p99_s", Value::num(pool_m.p99_inter_token_s())),
        ("queue_depth_max", Value::num(pool_m.queue_depth_max as f64)),
        ("shed_count", Value::num(pool_m.shed_count as f64)),
        ("pool_swaps_avoided", Value::num(pool_m.swaps_avoided as f64)),
        ("kv_pages", Value::num(kv_pages as f64)),
        ("page_tokens", Value::num(page_tokens as f64)),
        ("kv_pages_peak", Value::num(paged_m.kv_pages_peak as f64)),
        ("kv_pages_shared", Value::num(paged_m.kv_pages_shared as f64)),
        ("kv_exhausted_count", Value::num(paged_m.kv_exhausted_count as f64)),
        ("paged_tokens_per_s", Value::num(paged_m.tokens_per_s())),
    ]);
    save_json(&out, &doc)?;
    println!("\nwrote {}", out.display());
    Ok(())
}
