//! Figure 2b / Figure 3: the PPL-vs-model-size frontier — fp16 LoRA vs
//! 3/4-bit PEQA vs 3/4-bit LoRA+OPTQ over the whole family.
//!
//! Shape target: at equal deployed bytes, quantized-large (PEQA) beats
//! fp16-small (LoRA) — the "continuity of model-size options under a
//! DRAM constraint" argument; OPTQ's 3-bit curve sits far above PEQA's.

use peqa::bench::{quick_mode, steps, Table};
use peqa::memmodel::{self, Geometry};
use peqa::pipeline::{self, Ctx};

fn packed_bytes(ctx: &Ctx, size: &str, bits: Option<u8>) -> anyhow::Result<u64> {
    let m = ctx.rt.meta(&format!("{size}_eval"))?;
    let mm = m.model.as_ref().unwrap();
    let g = Geometry::llama("x", mm.vocab, mm.d_model, mm.n_layers, mm.d_ff);
    Ok(match bits {
        None => g.n_params() * 2, // fp16
        Some(b) => {
            memmodel::report(&g, memmodel::Method::Peqa { bits: b, group: None }).deploy_bytes
        }
    })
}

fn main() -> anyhow::Result<()> {
    let ctx = Ctx::new()?;
    let sizes: &[&str] =
        if quick_mode() { &["n1", "n2", "n3"] } else { &["n1", "n2", "n3", "n4", "n5", "n6"] };
    let n_steps = steps(120);
    let (_, eval_s) = ctx.split("wikitext", pipeline::ADAPT_BYTES)?;

    let mut t = Table::new(
        "Figure 2b/3 — PPL vs deployed model bytes (wikitext-sim)",
        &["Series", "Size", "Deploy bytes", "PPL"],
    );
    for size in sizes {
        eprintln!("[fig2] {size}…");
        let lora = pipeline::finetune_cached(&ctx, size, "lora_qv4", "wikitext", n_steps)?;
        t.row(&[
            "LoRA fp16".into(),
            size.to_string(),
            packed_bytes(&ctx, size, None)?.to_string(),
            format!("{:.2}", pipeline::lora_ppl(&ctx, size, "lora_qv4", &lora, &eval_s)?),
        ]);
        for bits in [4u8, 3] {
            let pq = pipeline::finetune_cached(
                &ctx, size, &format!("peqa_b{bits}_gc"), "wikitext", n_steps,
            )?;
            t.row(&[
                format!("PEQA {bits}-bit"),
                size.to_string(),
                packed_bytes(&ctx, size, Some(bits))?.to_string(),
                format!("{:.2}", pipeline::ppl(&ctx, size, &pq, &eval_s)?),
            ]);
            let lo =
                pipeline::lora_optq(&ctx, size, "lora_qv4", "wikitext", n_steps, bits, None)?;
            t.row(&[
                format!("LoRA+OPTQ {bits}-bit"),
                size.to_string(),
                packed_bytes(&ctx, size, Some(bits))?.to_string(),
                format!("{:.2}", pipeline::ppl(&ctx, size, &lo, &eval_s)?),
            ]);
        }
    }
    t.print();
    t.save(&ctx.paths.results, "fig2b_frontier")?;
    Ok(())
}
