//! Table 3 (+ Table 11): scalability of PEQA vs LoRA-fp16 vs LoRA+OPTQ
//! across the whole model family on wikitext-sim AND ptb-sim.
//!
//! Reproduction target (shape): PEQA's gap to fp16-LoRA shrinks as the
//! model grows; 3-bit LoRA+OPTQ blows up while 3-bit PEQA stays close.
//! Table 11 sub-check: LoRA QV4 ≈ LoRA QKVO16.

use peqa::bench::{quick_mode, steps, Table};
use peqa::pipeline::{self, Ctx};

fn main() -> anyhow::Result<()> {
    let ctx = Ctx::new()?;
    let sizes: &[&str] =
        if quick_mode() { &["n1", "n2", "n3"] } else { &["n1", "n2", "n3", "n4", "n5", "n6"] };
    let n_steps = steps(120);

    for dataset in ["wikitext", "ptb"] {
        let (_, eval_s) = ctx.split(dataset, pipeline::ADAPT_BYTES)?;
        let mut t = Table::new(
            &format!("Table 3 — {dataset}-sim PPL across model scale (paper Table 3)"),
            &{
                let mut h = vec!["Method", "W Bits"];
                h.extend(sizes.iter().copied());
                h
            },
        );
        let mut rows: Vec<(String, String, Vec<f64>)> = vec![
            ("LoRA".into(), "16".into(), vec![]),
            ("LoRA+OPTQ".into(), "4".into(), vec![]),
            ("PEQA (Ours)".into(), "4".into(), vec![]),
            ("LoRA+OPTQ".into(), "3".into(), vec![]),
            ("PEQA (Ours)".into(), "3".into(), vec![]),
        ];
        for size in sizes {
            eprintln!("[table3] {dataset} {size}…");
            let lora = pipeline::finetune_cached(&ctx, size, "lora_qv4", dataset, n_steps)?;
            rows[0].2.push(pipeline::lora_ppl(&ctx, size, "lora_qv4", &lora, &eval_s)?);
            for (row, bits) in [(1usize, 4u8), (3, 3)] {
                let q = pipeline::lora_optq(&ctx, size, "lora_qv4", dataset, n_steps, bits, None)?;
                rows[row].2.push(pipeline::ppl(&ctx, size, &q, &eval_s)?);
            }
            for (row, bits) in [(2usize, 4u8), (4, 3)] {
                let q = pipeline::finetune_cached(
                    &ctx, size, &format!("peqa_b{bits}_gc"), dataset, n_steps,
                )?;
                rows[row].2.push(pipeline::ppl(&ctx, size, &q, &eval_s)?);
            }
        }
        for (name, bits, ppls) in &rows {
            let mut cells = vec![name.clone(), bits.clone()];
            cells.extend(ppls.iter().map(|p| format!("{p:.2}")));
            t.row(&cells);
        }
        t.print();
        t.save(&ctx.paths.results, &format!("table3_{dataset}"))?;
    }

    // Table 11: LoRA target-set ablation on wikitext-sim.
    let (_, eval_s) = ctx.split("wikitext", pipeline::ADAPT_BYTES)?;
    let t11_sizes: &[&str] = if quick_mode() { &["n1"] } else { &["n1", "n2", "n3", "n4"] };
    let mut t11 = Table::new(
        "Table 11 — LoRA QV4 vs QKVO16 (should be ≈ equal)",
        &{
            let mut h = vec!["Config"];
            h.extend(t11_sizes.iter().copied());
            h
        },
    );
    for tag in ["lora_qv4", "lora_qkvo16"] {
        let mut cells = vec![tag.to_string()];
        for size in t11_sizes {
            let ck = pipeline::finetune_cached(&ctx, size, tag, "wikitext", steps(120))?;
            cells.push(format!("{:.2}", pipeline::lora_ppl(&ctx, size, tag, &ck, &eval_s)?));
        }
        t11.row(&cells);
    }
    t11.print();
    t11.save(&ctx.paths.results, "table11_lora_cfg")?;
    Ok(())
}
