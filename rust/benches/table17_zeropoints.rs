//! Table 17 (appendix K): what should train — zero-points only, scales
//! only (PEQA), or both? Shape target: zp-only ≫ scales-only ≈ both.

use peqa::bench::{quick_mode, steps, Table};
use peqa::pipeline::{self, Ctx};

fn main() -> anyhow::Result<()> {
    let ctx = Ctx::new()?;
    let sizes: &[&str] = if quick_mode() { &["n3"] } else { &["n3", "n4"] };
    let n_steps = steps(120);
    let (_, eval_s) = ctx.split("wikitext", pipeline::ADAPT_BYTES)?;

    let mut t = Table::new(
        "Table 17 — trainable-subset ablation, 4-bit, wikitext-sim (paper Table 17)",
        &["Model", "Zero-points only", "Scales only (PEQA)", "Both"],
    );
    for size in sizes {
        let mut cells = vec![size.to_string()];
        for tag in ["peqa_zp_b4_gc", "peqa_b4_gc", "peqa_szp_b4_gc"] {
            eprintln!("[table17] {size} {tag}…");
            let ck = pipeline::finetune_cached(&ctx, size, tag, "wikitext", n_steps)?;
            cells.push(format!("{:.2}", pipeline::ppl(&ctx, size, &ck, &eval_s)?));
        }
        t.row(&cells);
    }
    t.print();
    t.save(&ctx.paths.results, "table17_zeropoints")?;
    Ok(())
}
