//! Table 5: group-wise (multi-scale) PEQA — PPL improves monotonically as
//! the group size shrinks (more learnable scales), on the 7B/13B analogs.

use peqa::bench::{quick_mode, steps, Table};
use peqa::pipeline::{self, Ctx};

fn main() -> anyhow::Result<()> {
    let ctx = Ctx::new()?;
    let (_, eval_s) = ctx.split("wikitext", pipeline::ADAPT_BYTES)?;
    let sizes: &[&str] = if quick_mode() { &["n3"] } else { &["n3", "n4"] };
    let groups = ["gc", "g64", "g32", "g16"]; // paper: chan, g256, g128, g64
    let n_steps = steps(120);

    let mut t = Table::new(
        "Table 5 — group-wise PEQA on wikitext-sim (paper Table 5; g scaled to our dims)",
        &["Model", "W Bits", "Channel-wise", "g64", "g32", "g16", "train params (4b)"],
    );
    for size in sizes {
        for bits in [4u8, 3] {
            let mut cells = vec![size.to_string(), bits.to_string()];
            for g in groups {
                eprintln!("[table5] {size} b{bits} {g}…");
                let tag = format!("peqa_b{bits}_{g}");
                let ck = pipeline::finetune_cached(&ctx, size, &tag, "wikitext", n_steps)?;
                cells.push(format!("{:.2}", pipeline::ppl(&ctx, size, &ck, &eval_s)?));
            }
            let meta = ctx.rt.meta(&format!("{size}_train_peqa_b4_g16"))?;
            let tp: usize = meta.params_trainable.iter().map(|p| p.numel()).sum();
            cells.push(if bits == 4 { tp.to_string() } else { String::new() });
            t.row(&cells);
        }
    }
    t.print();
    t.save(&ctx.paths.results, "table5_groups")?;
    Ok(())
}
