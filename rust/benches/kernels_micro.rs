//! Kernel micro-benchmarks (the §Perf instrumentation), host edition:
//!
//! * fused packed GEMM (`quant::kernels::PackedMatrix::matmul_t`) vs the
//!   seed's reference unpack → dequantize → naive-matmul path, across
//!   bits ∈ {2, 3, 4} × group ∈ {per-channel, 128, 64};
//! * the same fused GEMM pinned to the scalar tier, so every result file
//!   carries the SIMD-vs-scalar ratio (`speedup_vs_scalar`) next to the
//!   dispatch tier it ran under (top-level `simd_path` key);
//! * the blocked/parallel dense `Tensor::matmul` for context;
//! * writes `BENCH_kernels.json` at the repo root so every PR leaves a
//!   perf datapoint (scripts/ci.sh runs this in quick mode).
//!
//! Defaults to the acceptance shape — 4096×4096 weights, batch 8.
//! `PEQA_BENCH_QUICK=1` shrinks to a smoke run; `PEQA_BENCH_DIM`
//! overrides the matrix dimension; `PEQA_THREADS` pins the worker count.
//!
//! With `--features xla` it additionally runs the original artifact
//! micro-bench (Pallas qmatmul / decode / train-step / adapter-swap) when
//! artifacts are present.

use peqa::bench::{quick_mode, save_json, time_fn, Table, Timing};
use peqa::config;
use peqa::json::Value;
use peqa::quant::{quantize_rtn, reference_dequant_matmul, simd, PackedMatrix};
use peqa::tensor::Tensor;
use peqa::util::Pcg32;

fn row(table: &mut Table, bits: u8, group: &str, t: &Timing, speedup: Option<f64>) {
    table.row(&[
        bits.to_string(),
        group.to_string(),
        t.label.clone(),
        format!("{:.2}", t.mean_s() * 1e3),
        format!("{:.2}", t.min_s() * 1e3),
        speedup.map(|s| format!("{s:.2}x")).unwrap_or_default(),
    ]);
}

fn json_entry(
    bits: u8,
    group: &str,
    path: &str,
    t: &Timing,
    speedup: Option<f64>,
    speedup_scalar: Option<f64>,
) -> Value {
    let mut fields = vec![
        ("bits", Value::num(bits as f64)),
        ("group", Value::str(group)),
        ("path", Value::str(path)),
        ("mean_s", Value::num(t.mean_s())),
        ("min_s", Value::num(t.min_s())),
        ("p50_s", Value::num(t.p50_s())),
    ];
    if let Some(s) = speedup {
        fields.push(("speedup_vs_reference", Value::num(s)));
    }
    if let Some(s) = speedup_scalar {
        fields.push(("speedup_vs_scalar", Value::num(s)));
    }
    Value::obj(fields)
}

fn main() -> anyhow::Result<()> {
    let quick = quick_mode();
    // Rounded up to a multiple of the largest bench group (128) so every
    // (bits, group) config divides evenly.
    let dim: usize = std::env::var("PEQA_BENCH_DIM")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(if quick { 512 } else { 4096 })
        .div_ceil(128)
        * 128;
    let batch = 8usize;
    let (warmup, iters) = if quick { (1, 2) } else { (1, 5) };
    let threads = peqa::util::num_threads();

    let mut rng = Pcg32::new(3);
    let w = Tensor::normal(&[dim, dim], 0.3, &mut rng);
    let x = Tensor::normal(&[batch, dim], 1.0, &mut rng);

    let mut table = Table::new(
        &format!(
            "§Perf — fused packed GEMM vs reference ({dim}x{dim}, batch {batch}, {threads} \
             threads, simd {})",
            simd::active().name
        ),
        &["bits", "group", "path", "mean ms", "min ms", "speedup"],
    );
    let mut entries: Vec<Value> = Vec::new();

    for bits in [2u8, 3, 4] {
        for group in [None, Some(128), Some(64)] {
            let gname = group.map(|g| format!("g{g}")).unwrap_or_else(|| "per-channel".into());
            let q = quantize_rtn(&w, bits, group)?;
            let pm = PackedMatrix::from_quantized(&q);

            let t_ref = time_fn(
                &format!("reference unpack+dequant+matmul b{bits}/{gname}"),
                warmup,
                iters,
                || {
                    std::hint::black_box(reference_dequant_matmul(&x, &pm).unwrap());
                },
            );
            let t_fused = time_fn(&format!("fused packed gemm b{bits}/{gname}"), warmup, iters, || {
                std::hint::black_box(pm.matmul_t(&x).unwrap());
            });
            // Same fused kernel pinned to the scalar tier: the
            // SIMD-vs-scalar ratio is the roofline scoreboard, measured
            // in-process so both tiers see identical inputs and threads.
            let t_scalar = time_fn(
                &format!("fused packed gemm (scalar tier) b{bits}/{gname}"),
                warmup,
                iters,
                || {
                    std::hint::black_box(
                        pm.matmul_t_with_ops(&x, threads, simd::scalar()).unwrap(),
                    );
                },
            );
            let speedup = t_ref.mean_s() / t_fused.mean_s().max(1e-12);
            let speedup_scalar = t_scalar.mean_s() / t_fused.mean_s().max(1e-12);
            row(&mut table, bits, &gname, &t_ref, None);
            row(&mut table, bits, &gname, &t_fused, Some(speedup));
            row(&mut table, bits, &gname, &t_scalar, None);
            entries.push(json_entry(bits, &gname, "reference", &t_ref, None, None));
            entries.push(json_entry(
                bits,
                &gname,
                "fused",
                &t_fused,
                Some(speedup),
                Some(speedup_scalar),
            ));
            entries.push(json_entry(bits, &gname, "fused_scalar", &t_scalar, None, None));
        }
    }

    // Dense parallel matmul for context (bits/group independent).
    let dense = quantize_rtn(&w, 4, Some(64))?.dequantize();
    let dense_t = dense.t();
    let t_dense = time_fn("dense blocked/parallel matmul", warmup, iters, || {
        std::hint::black_box(x.matmul(&dense_t).unwrap());
    });
    row(&mut table, 32, "-", &t_dense, None);
    entries.push(json_entry(32, "-", "dense_parallel", &t_dense, None, None));

    table.print();
    let paths = config::Paths::default();
    table.save(&paths.results, "kernels_micro").ok();

    let out = std::env::var("PEQA_BENCH_OUT")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| config::repo_root().join("BENCH_kernels.json"));
    let doc = Value::obj(vec![
        ("bench", Value::str("kernels_micro")),
        ("dim", Value::num(dim as f64)),
        ("batch", Value::num(batch as f64)),
        ("threads", Value::num(threads as f64)),
        ("simd_path", Value::str(simd::active().name)),
        ("iters", Value::num(iters as f64)),
        ("quick", Value::str(if quick { "1" } else { "0" })),
        ("results", Value::Arr(entries)),
    ]);
    save_json(&out, &doc)?;
    println!("\nwrote {}", out.display());

    #[cfg(feature = "xla")]
    if let Err(e) = artifact_micro() {
        eprintln!("artifact micro-bench skipped: {e:#}");
    }
    Ok(())
}

/// The original artifact-driven micro-bench (needs `make artifacts`):
/// Pallas qmatmul kernel, fp-vs-quantized decode, per-method train steps,
/// adapter swap vs full reload, HBM-traffic model.
#[cfg(feature = "xla")]
fn artifact_micro() -> anyhow::Result<()> {
    use peqa::bench::steps;
    use peqa::config::TrainConfig;
    use peqa::coordinator::{AdapterStore, BatcherConfig, Coordinator, SwitchMode};
    use peqa::data::LmBatcher;
    use peqa::eval::EvalModel;
    use peqa::pipeline::{self, Ctx};
    use peqa::train::{Trainer, Tuner};

    let ctx = Ctx::new()?;
    let size = "n3";
    let base = pipeline::ensure_base(&ctx, size, pipeline::pretrain_steps())?;
    let qck = pipeline::rtn_quantize(&base, 4, None)?;
    let iters = steps(30);

    // ---- kernel artifact micro-bench ----
    let art = ctx.rt.load("kernel_qmatmul_256")?;
    let mut rng = Pcg32::new(3);
    let w = Tensor::normal(&[256, 256], 0.3, &mut rng);
    let q = quantize_rtn(&w, 4, Some(64))?;
    let x = Tensor::normal(&[8, 256], 1.0, &mut rng);
    let wq = Tensor::new(&[256, 256], q.codes.iter().map(|&c| c as f32).collect());
    let xb = ctx.rt.tensor_to_device(&x)?;
    let wqb = ctx.rt.tensor_to_device(&wq)?;
    let sb = ctx.rt.tensor_to_device(&q.scales)?;
    let zb = ctx.rt.tensor_to_device(&q.zeros)?;
    let t_kernel = time_fn("qmatmul_256 (pallas artifact)", 3, iters, || {
        art.run_b(&[&xb, &wqb, &sb, &zb]).unwrap();
    });

    // ---- decode-step latency: fp vs quantized serving path ----
    let fp_model = EvalModel::new(&ctx.rt, &format!("{size}_logits_b8"), &base)?;
    let q_model = EvalModel::new(&ctx.rt, &format!("{size}_logits_q_b4_gc_b8"), &qck)?;
    let tokens = vec![7i32; 8 * 64];
    let t_fp = time_fn("decode fp32 logits_b8", 2, iters, || {
        fp_model.logits(&ctx.rt, &tokens).unwrap();
    });
    let t_q = time_fn("decode quantized logits_q_b8", 2, iters, || {
        q_model.logits(&ctx.rt, &tokens).unwrap();
    });

    // ---- train-step latency per method ----
    let (train_s, _) = ctx.split("wikitext", pipeline::ADAPT_BYTES)?;
    let mut table = Table::new(
        "§Perf — artifact hot-path latencies (n3, CPU PJRT; see EXPERIMENTS.md §Perf)",
        &["Path", "mean ms", "p50 ms", "min ms"],
    );
    for tm in [t_kernel, t_fp, t_q] {
        table.row(&[
            tm.label.clone(),
            format!("{:.2}", tm.mean_s() * 1e3),
            format!("{:.2}", tm.p50_s() * 1e3),
            format!("{:.2}", tm.min_s() * 1e3),
        ]);
    }
    for tag in ["peqa_b4_gc", "lora_qv4", "full"] {
        let start = if tag == "peqa_b4_gc" {
            pipeline::prep(&ctx, size, "peqa_b4_gc", &base)?
        } else {
            base.clone()
        };
        let cfg = TrainConfig { steps: 10_000, log_every: 0, ..Default::default() };
        let mut trainer = Trainer::new(&ctx.rt, &format!("{size}_train_{tag}"), &start, cfg)?;
        let mut batcher = LmBatcher::new(train_s.clone(), 8, 64, 3);
        let tm = time_fn(&format!("train step {tag}"), 3, iters, || {
            trainer.step(&batcher.next_batch()).unwrap();
        });
        table.row(&[
            tm.label.clone(),
            format!("{:.2}", tm.mean_s() * 1e3),
            format!("{:.2}", tm.p50_s() * 1e3),
            format!("{:.2}", tm.min_s() * 1e3),
        ]);
    }

    // ---- adapter swap vs full reload ----
    for (label, mode, art_name) in [
        ("swap: scale-swap (PEQA)", SwitchMode::ScaleSwap, format!("{size}_logits_q_b4_gc_b8")),
        ("swap: full reload (PEFT+PTQ)", SwitchMode::FullReload, format!("{size}_logits_b8")),
    ] {
        let mut adapters = AdapterStore::new();
        let mut a1 = qck.extract_adapter(false);
        adapters.insert("a", a1.clone());
        for t in a1.names().to_vec() {
            let mut x = a1.get(&t).unwrap().clone();
            for v in x.data_mut() {
                *v *= 1.01;
            }
            a1.insert(t, x);
        }
        adapters.insert("b", a1);
        let mut coord = Coordinator::new(
            ctx.rt.clone(),
            &art_name,
            qck.clone(),
            adapters,
            mode,
            BatcherConfig { max_batch: 8, ..Default::default() },
        )?;
        // Alternate tasks so every group forces a swap.
        for i in 0..8 {
            coord.submit(if i % 2 == 0 { "a" } else { "b" }, vec![7, 8, 9], 4, 0);
        }
        coord.run_until_idle()?;
        table.row(&[
            label.to_string(),
            format!("{:.3}", coord.metrics.mean_swap_s() * 1e3),
            String::new(),
            String::new(),
        ]);
    }

    // ---- HBM-traffic model (DESIGN §Hardware-Adaptation) ----
    let m = ctx.rt.meta(&format!("{size}_eval"))?;
    let mm = m.model.as_ref().unwrap();
    let g = peqa::memmodel::Geometry::llama("x", mm.vocab, mm.d_model, mm.n_layers, mm.d_ff);
    let wq_params = g.n_quantizable();
    for (label, bits) in [("fp16", 16u64), ("int4", 4), ("int3", 3)] {
        table.row(&[
            format!("decode weight-bytes/token ({label})"),
            format!("{}", wq_params * bits / 8),
            String::new(),
            format!("{:.2}x vs fp16", 16.0 / bits as f64),
        ]);
    }
    table.print();
    table.save(&ctx.paths.results, "perf_micro")?;
    Ok(())
}
