//! Kernel & runtime micro-benchmarks (the §Perf instrumentation):
//!  * qmatmul artifact (Pallas fused dequant-matmul) vs fp logits forward,
//!  * train-step latency per method (PEQA vs LoRA vs full — paper's
//!    "training cost parity" claim),
//!  * decode-step latency fp vs quantized path,
//!  * adapter swap vs full reload wall time (Table 1 switching axis),
//!  * HBM-traffic model: weight bytes moved per decode step at 16/4/3 bit.

use peqa::bench::{steps, time_fn, Table};
use peqa::config::TrainConfig;
use peqa::coordinator::{AdapterStore, BatcherConfig, Coordinator, SwitchMode};
use peqa::data::LmBatcher;
use peqa::eval::EvalModel;
use peqa::pipeline::{self, Ctx};
use peqa::train::Trainer;
use peqa::util::Pcg32;

fn main() -> anyhow::Result<()> {
    let ctx = Ctx::new()?;
    let size = "n3";
    let base = pipeline::ensure_base(&ctx, size, pipeline::pretrain_steps())?;
    let qck = pipeline::rtn_quantize(&base, 4, None)?;
    let iters = steps(30);

    // ---- kernel artifact micro-bench ----
    let art = ctx.rt.load("kernel_qmatmul_256")?;
    let mut rng = Pcg32::new(3);
    let w = peqa::tensor::Tensor::normal(&[256, 256], 0.3, &mut rng);
    let q = peqa::quant::quantize_rtn(&w, 4, Some(64))?;
    let x = peqa::tensor::Tensor::normal(&[8, 256], 1.0, &mut rng);
    let wq = peqa::tensor::Tensor::new(&[256, 256], q.codes.iter().map(|&c| c as f32).collect());
    let xb = ctx.rt.tensor_to_device(&x)?;
    let wqb = ctx.rt.tensor_to_device(&wq)?;
    let sb = ctx.rt.tensor_to_device(&q.scales)?;
    let zb = ctx.rt.tensor_to_device(&q.zeros)?;
    let t_kernel = time_fn("qmatmul_256 (pallas artifact)", 3, iters, || {
        art.run_b(&[&xb, &wqb, &sb, &zb]).unwrap();
    });

    // ---- decode-step latency: fp vs quantized serving path ----
    let fp_model = EvalModel::new(&ctx.rt, &format!("{size}_logits_b8"), &base)?;
    let q_model = EvalModel::new(&ctx.rt, &format!("{size}_logits_q_b4_gc_b8"), &qck)?;
    let tokens = vec![7i32; 8 * 64];
    let t_fp = time_fn("decode fp32 logits_b8", 2, iters, || {
        fp_model.logits(&ctx.rt, &tokens).unwrap();
    });
    let t_q = time_fn("decode quantized logits_q_b8", 2, iters, || {
        q_model.logits(&ctx.rt, &tokens).unwrap();
    });

    // ---- train-step latency per method ----
    let (train_s, _) = ctx.split("wikitext", pipeline::ADAPT_BYTES)?;
    let mut table = Table::new(
        "§Perf — hot-path latencies (n3, CPU PJRT; see EXPERIMENTS.md §Perf)",
        &["Path", "mean ms", "p50 ms", "min ms"],
    );
    for tm in [t_kernel, t_fp, t_q] {
        table.row(&[
            tm.label.clone(),
            format!("{:.2}", tm.mean_s() * 1e3),
            format!("{:.2}", tm.p50_s() * 1e3),
            format!("{:.2}", tm.min_s() * 1e3),
        ]);
    }
    for tag in ["peqa_b4_gc", "lora_qv4", "full"] {
        let start = if tag == "peqa_b4_gc" {
            pipeline::prep(&ctx, size, "peqa_b4_gc", &base)?
        } else {
            base.clone()
        };
        let cfg = TrainConfig { steps: 10_000, log_every: 0, ..Default::default() };
        let mut trainer = Trainer::new(&ctx.rt, &format!("{size}_train_{tag}"), &start, cfg)?;
        let mut batcher = LmBatcher::new(train_s.clone(), 8, 64, 3);
        let tm = time_fn(&format!("train step {tag}"), 3, iters, || {
            trainer.step(&batcher.next_batch()).unwrap();
        });
        table.row(&[
            tm.label.clone(),
            format!("{:.2}", tm.mean_s() * 1e3),
            format!("{:.2}", tm.p50_s() * 1e3),
            format!("{:.2}", tm.min_s() * 1e3),
        ]);
    }

    // ---- adapter swap vs full reload ----
    for (label, mode, art_name) in [
        ("swap: scale-swap (PEQA)", SwitchMode::ScaleSwap, format!("{size}_logits_q_b4_gc_b8")),
        ("swap: full reload (PEFT+PTQ)", SwitchMode::FullReload, format!("{size}_logits_b8")),
    ] {
        let mut adapters = AdapterStore::new();
        let mut a1 = qck.extract_adapter(false);
        adapters.insert("a", a1.clone());
        for t in a1.names().to_vec() {
            let mut x = a1.get(&t).unwrap().clone();
            for v in x.data_mut() {
                *v *= 1.01;
            }
            a1.insert(t, x);
        }
        adapters.insert("b", a1);
        let mut coord = Coordinator::new(
            ctx.rt.clone(),
            &art_name,
            qck.clone(),
            adapters,
            mode,
            BatcherConfig { max_batch: 8 },
        )?;
        // Alternate tasks so every group forces a swap.
        for i in 0..8 {
            coord.submit(if i % 2 == 0 { "a" } else { "b" }, vec![7, 8, 9], 4, 0);
        }
        coord.run_until_idle()?;
        table.row(&[
            label.to_string(),
            format!("{:.3}", coord.metrics.mean_swap_s() * 1e3),
            String::new(),
            String::new(),
        ]);
    }

    // ---- HBM-traffic model (DESIGN §Hardware-Adaptation) ----
    let m = ctx.rt.meta(&format!("{size}_eval"))?;
    let mm = m.model.as_ref().unwrap();
    let g = peqa::memmodel::Geometry::llama("x", mm.vocab, mm.d_model, mm.n_layers, mm.d_ff);
    let wq_params = g.n_quantizable();
    for (label, bits) in [("fp16", 16u64), ("int4", 4), ("int3", 3)] {
        table.row(&[
            format!("decode weight-bytes/token ({label})"),
            format!("{}", wq_params * bits / 8),
            String::new(),
            format!("{:.2}x vs fp16", 16.0 / bits as f64),
        ]);
    }
    table.print();
    table.save(&ctx.paths.results, "perf_micro")?;
    Ok(())
}
