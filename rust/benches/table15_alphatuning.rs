//! Table 15 (appendix J): AlphaTuning (binary-coding quantization, train
//! only α₁) vs PEQA at 3/4-bit on the 1.3B-analog sizes.
//!
//! Shape target: PEQA ≤ AlphaTuning at both bit-widths (paper: PEQA wins
//! by ≥ 0.7 PPL on Wikitext2).

use peqa::bench::{steps, Table};
use peqa::pipeline::{self, Ctx};

fn main() -> anyhow::Result<()> {
    let ctx = Ctx::new()?;
    let sizes = ["n1", "n2"];
    let n_steps = steps(120);
    let (_, eval_s) = ctx.split("wikitext", pipeline::ADAPT_BYTES)?;

    let mut t = Table::new(
        "Table 15 — AlphaTuning vs PEQA on wikitext-sim (paper Table 15)",
        &["Method", "# Bits", "n1 (OPT-1.3B-sim)", "n2 (GPT-Neo-1.3B-sim)"],
    );
    for bits in [4u8, 3] {
        for (name, tag) in [
            ("AlphaTuning", format!("alpha_b{bits}")),
            ("PEQA (Ours)", format!("peqa_b{bits}_gc")),
        ] {
            let mut cells = vec![name.to_string(), bits.to_string()];
            for size in sizes {
                eprintln!("[table15] {size} {tag}…");
                let ck = pipeline::finetune_cached(&ctx, size, &tag, "wikitext", n_steps)?;
                cells.push(format!("{:.2}", pipeline::ppl(&ctx, size, &ck, &eval_s)?));
            }
            t.row(&cells);
        }
    }
    t.print();
    t.save(&ctx.paths.results, "table15_alphatuning")?;
    Ok(())
}
