//! Table 4: learnable-parameter counts and model sizes, both at paper
//! dims (analytic) and measured on our family (real packed file bytes).

use peqa::bench::Table;
use peqa::memmodel::{self, Geometry};
use peqa::pipeline::{self, Ctx};

fn main() -> anyhow::Result<()> {
    let ctx = Ctx::new()?;

    // ---- Paper-dims analytic check: the real LLaMA family. ----
    let paper: [(&str, usize, usize, usize, usize); 4] = [
        ("LLaMA 7B", 32000, 4096, 32, 11008),
        ("LLaMA 13B", 32000, 5120, 40, 13824),
        ("LLaMA 30B", 32000, 6656, 60, 17920),
        ("LLaMA 65B", 32000, 8192, 80, 22016),
    ];
    let mut tp = Table::new(
        "Table 4 (paper dims) — learnable params (M) & model size (GB)",
        &["Model", "LoRA QV4 (M)", "PEQA (M)", "fp16 (GB)", "PEQA 4-bit (GB)", "PEQA 3-bit (GB)"],
    );
    for (name, v, d, l, ff) in paper {
        let g = Geometry::llama(name, v, d, l, ff);
        let lora = memmodel::lora_trainable(d, l, 2, 4) as f64 / 1e6;
        let peqa = memmodel::peqa_trainable(&g, None) as f64 / 1e6;
        let fp16 = g.n_params() as f64 * 2.0 / 1e9;
        let b4 = memmodel::report(&g, memmodel::Method::Peqa { bits: 4, group: None })
            .deploy_bytes as f64
            / 1e9;
        let b3 = memmodel::report(&g, memmodel::Method::Peqa { bits: 3, group: None })
            .deploy_bytes as f64
            / 1e9;
        tp.row(&[
            name.to_string(),
            format!("{lora:.2}"),
            format!("{peqa:.2}"),
            format!("{fp16:.2}"),
            format!("{b4:.2}"),
            format!("{b3:.2}"),
        ]);
    }
    tp.print();
    tp.save(&ctx.paths.results, "table4_paper_dims")?;

    // ---- Our family: measured packed bytes from real checkpoints. ----
    let mut tm = Table::new(
        "Table 4 (measured) — our family: trainable params & packed bytes",
        &["Size", "Total params", "LoRA QV4 train", "PEQA train", "fp32 bytes", "4-bit packed", "3-bit packed"],
    );
    let dir = std::env::temp_dir().join("peqa_table4");
    std::fs::create_dir_all(&dir)?;
    for size in ["n1", "n2", "n3", "n4", "n5", "n6"] {
        let meta_peqa = ctx.rt.meta(&format!("{size}_train_peqa_b4_gc"))?;
        let meta_lora = ctx.rt.meta(&format!("{size}_train_lora_qv4"))?;
        let peqa_train: usize = meta_peqa.params_trainable.iter().map(|p| p.numel()).sum();
        let lora_train: usize = meta_lora.params_trainable.iter().map(|p| p.numel()).sum();
        let total = meta_peqa.model.as_ref().unwrap().n_params;
        let base = pipeline::ensure_base(&ctx, size, pipeline::pretrain_steps())?;
        let mut row = vec![
            size.to_string(),
            total.to_string(),
            lora_train.to_string(),
            peqa_train.to_string(),
            (base.n_params() * 4).to_string(),
        ];
        for bits in [4u8, 3] {
            let q = pipeline::rtn_quantize(&base, bits, None)?;
            let bytes = q.save_packed(&dir.join(format!("{size}.b{bits}")), bits)?;
            row.push(bytes.to_string());
        }
        // Sanity: PEQA has fewer trainable params than LoRA (paper claim).
        assert!(peqa_train < lora_train, "{size}: {peqa_train} !< {lora_train}");
        tm.row(&row);
    }
    tm.print();
    tm.save(&ctx.paths.results, "table4_measured")?;
    std::fs::remove_dir_all(&dir).ok();
    Ok(())
}
