//! Appendix L: training memory — LoRA vs PEQA vs full FT.
//!
//! Two measurements on the n4 (13B-analog) model:
//!  * analytic round-trip state (trainable + AdamW m/v bytes per step),
//!  * measured process RSS delta while training each method.
//! Shape target: PEQA state ≪ LoRA state ≪ full-FT state; RSS ordering
//! follows (the paper: 43 GB vs 59 GB peaks on LLaMA-7B).

use peqa::bench::{steps, Table};
use peqa::config::TrainConfig;
use peqa::data::LmBatcher;
use peqa::pipeline::{self, Ctx};
use peqa::train::{Trainer, Tuner};
use peqa::util::human_bytes;

fn rss_kb() -> u64 {
    let status = std::fs::read_to_string("/proc/self/status").unwrap_or_default();
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmRSS:") {
            return rest.trim().trim_end_matches(" kB").trim().parse().unwrap_or(0);
        }
    }
    0
}

fn main() -> anyhow::Result<()> {
    let ctx = Ctx::new()?;
    let size = "n4";
    let base = pipeline::ensure_base(&ctx, size, pipeline::pretrain_steps())?;
    let (train_s, _) = ctx.split("wikitext", pipeline::ADAPT_BYTES)?;
    let n_steps = steps(30);

    let mut t = Table::new(
        "Appendix L — training-memory comparison on n4 (paper: PEQA 43 GB vs LoRA 59 GB @7B)",
        &["Method", "Trainable+opt state", "RSS before", "RSS peak during", "RSS delta"],
    );
    for tag in ["peqa_b4_gc", "lora_qv4", "full"] {
        let start = match tag {
            "peqa_b4_gc" => pipeline::prep(&ctx, size, "peqa_b4_gc", &base)?,
            _ => base.clone(),
        };
        let cfg = TrainConfig {
            steps: n_steps,
            lr: TrainConfig::default_lr(tag.split('_').next().unwrap()),
            log_every: 0,
            ..Default::default()
        };
        let before = rss_kb();
        let mut trainer = Trainer::new(&ctx.rt, &format!("{size}_train_{tag}"), &start, cfg)?;
        let state = trainer.trainable_state_bytes();
        let mut batcher = LmBatcher::new(train_s.clone(), 8, 64, 3);
        let mut peak = before;
        for _ in 0..n_steps {
            trainer.step(&batcher.next_batch())?;
            peak = peak.max(rss_kb());
        }
        t.row(&[
            tag.to_string(),
            human_bytes(state),
            human_bytes(before * 1024),
            human_bytes(peak * 1024),
            human_bytes((peak.saturating_sub(before)) * 1024),
        ]);
        drop(trainer);
    }
    t.print();
    t.save(&ctx.paths.results, "appendixl_mempeak")?;
    Ok(())
}
