//! Table 2: PPL of fine-tuned LLMs — QAT (upper bound) vs LoRA+OPTQ
//! (lower bound) vs PEQA, 3- and 4-bit, on wikitext-sim.
//!
//! Reproduction target (shape): PEQA ≈ QAT at 4-bit; at 3-bit LoRA+OPTQ
//! degrades hard while PEQA stays close to QAT (paper: 19.47 vs 6.19 on
//! LLaMA-7B at 3-bit).

use peqa::bench::{steps, Table};
use peqa::pipeline::{self, Ctx};

fn main() -> anyhow::Result<()> {
    let ctx = Ctx::new()?;
    let sizes = ["n1", "n2", "n3", "n4"];
    let n_steps = steps(120);
    let dataset = "wikitext";
    let (_, eval_s) = ctx.split(dataset, pipeline::ADAPT_BYTES)?;

    let mut t = Table::new(
        "Table 2 — Wikitext-sim PPL: QAT vs LoRA+OPTQ vs PEQA (paper Table 2)",
        &["Method", "W Bits", "GPT-Neo-sim(n1)", "GPT-J-sim(n2)", "LLaMA-7B-sim(n3)", "LLaMA-13B-sim(n4)"],
    );
    for bits in [4u8, 3] {
        let mut rows: Vec<(String, Vec<f64>)> = vec![
            (format!("QAT"), vec![]),
            (format!("LoRA + OPTQ"), vec![]),
            (format!("PEQA (Ours)"), vec![]),
        ];
        for size in sizes {
            eprintln!("[table2] {size} {bits}-bit…");
            // QAT: trains all weights through the fake-quant STE.
            let qat = pipeline::finetune_cached(&ctx, size, &format!("qat_b{bits}"), dataset, n_steps)?;
            // QAT checkpoints are fp; quantize at the end (deployment form).
            let qat_q = pipeline::rtn_quantize(&qat, bits, None)?;
            rows[0].1.push(pipeline::ppl(&ctx, size, &qat_q, &eval_s)?);
            // LoRA fp16 fine-tune → merge → OPTQ.
            let lo = pipeline::lora_optq(&ctx, size, "lora_qv4", dataset, n_steps, bits, None)?;
            rows[1].1.push(pipeline::ppl(&ctx, size, &lo, &eval_s)?);
            // PEQA.
            let pq = pipeline::finetune_cached(
                &ctx, size, &format!("peqa_b{bits}_gc"), dataset, n_steps,
            )?;
            rows[2].1.push(pipeline::ppl(&ctx, size, &pq, &eval_s)?);
        }
        for (name, ppls) in rows {
            let mut cells = vec![name, bits.to_string()];
            cells.extend(ppls.iter().map(|p| format!("{p:.2}")));
            t.row(&cells);
        }
    }
    t.print();
    t.save(&ctx.paths.results, "table2_ppl")?;
    Ok(())
}
