//! Table 14 (appendix I): zero-shot task generalization — ROUGE-L of
//! greedy generations on *held-out* instruction families (ni-sim) after
//! alpaca-sim tuning. Base vs +LoRA vs +LoRA+OPTQ vs +PEQA.

use peqa::bench::{quick_mode, steps, Table};
use peqa::data;
use peqa::eval::{generate, rouge_l, EvalModel};
use peqa::model::Checkpoint;
use peqa::pipeline::{self, Ctx};
use peqa::tokenizer::{BOS, EOS};

fn score(ctx: &Ctx, size: &str, fp: &Checkpoint, items: &[data::Instruction]) -> anyhow::Result<f64> {
    let model = EvalModel::new(&ctx.rt, &format!("{size}_logits_b8"), fp)?;
    let mut total = 0.0;
    for ins in items {
        let mut prompt = vec![BOS];
        prompt.extend(ctx.tok.encode(&ins.prompt));
        let out = generate(&model, &ctx.rt, &prompt, 16, EOS)?;
        let text = ctx.tok.decode(&out)?;
        total += rouge_l(&text, &ins.response);
    }
    Ok(100.0 * total / items.len() as f64)
}

fn main() -> anyhow::Result<()> {
    let ctx = Ctx::new()?;
    let sizes: &[&str] = if quick_mode() { &["n3"] } else { &["n3"] }; // n4 via PEQA_BENCH_FULL (1-core budget)
    let n_items = if quick_mode() { 8 } else { 24 };
    let items = data::ni_sim(&ctx.world, 21, n_items);
    let n_steps = steps(120);

    let mut t = Table::new(
        "Table 14 — ni-sim zero-shot ROUGE-L on held-out task families (paper Table 14)",
        &["# Params", "base", "+LoRA", "+LoRA w/ OPTQ", "+PEQA"],
    );
    for size in sizes {
        eprintln!("[table14] {size}…");
        let base = pipeline::instruct_tuned(&ctx, size, "base", 256, n_steps)?;
        let lora = pipeline::instruct_tuned(&ctx, size, "lora_qkvo16", 256, n_steps)?;
        let (a, r) = pipeline::lora_hparams(&ctx, size, "lora_qkvo16")?;
        let lora_fp = lora.merge_lora(a, r)?;
        // OPTQ the instruction-tuned merged LoRA model.
        let (calib, _) = ctx.split("pretrain", pipeline::ADAPT_BYTES)?;
        let h = pipeline::hessians(&ctx, size, &lora_fp, &calib, 8)?;
        let lora_optq = pipeline::optq_quantize(&lora_fp, &h, 4, None)?.dequantize()?;
        let peqa = pipeline::instruct_tuned(&ctx, size, "peqa_b4_gc", 256, n_steps)?
            .dequantize()?;
        t.row(&[
            size.to_string(),
            format!("{:.1}", score(&ctx, size, &base, &items)?),
            format!("{:.1}", score(&ctx, size, &lora_fp, &items)?),
            format!("{:.1}", score(&ctx, size, &lora_optq, &items)?),
            format!("{:.1}", score(&ctx, size, &peqa, &items)?),
        ]);
    }
    t.print();
    t.save(&ctx.paths.results, "table14_ni")?;
    Ok(())
}
