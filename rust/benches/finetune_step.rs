//! Host PEQA fine-tuning benchmark (default build, no xla): end-to-end
//! optimizer steps through `train::HostPeqaTuner` — forward on the
//! fused packed kernels through the shared `model::blocks` compute
//! core, full host backward, scale-only Adam — measuring the numbers
//! the paper's training story hangs on:
//!
//! * per-step wall time (mean / p50),
//! * steady-state allocator traffic per step (count + bytes, via a
//!   counting global allocator): the trainer's `TapeArena` reuses every
//!   activation slab across steps, so after warm-up the per-step
//!   allocations are the kilobyte-scale gradient tensors and scoped
//!   thread bookkeeping — NOT the megabyte activation tape (which the
//!   pre-arena trainer reallocated every step),
//! * trainable + optimizer bytes vs packed code bytes (the Table 1
//!   "optimizer memory is kilobytes" ratio),
//! * the loss trajectory (first / final) as a sanity signal that the
//!   gradients keep doing work at bench scale.
//!
//! Writes `BENCH_finetune.json` (at `PEQA_BENCH_OUT` or the repo root)
//! so every PR leaves a training perf datapoint; `scripts/ci.sh` runs
//! this in quick mode and `scripts/bench_diff.py` fails CI when the
//! step time regresses. `PEQA_BENCH_QUICK=1` shrinks the model and step
//! count; `PEQA_BENCH_STEPS` overrides the step budget; `PEQA_THREADS`
//! pins the kernel worker count.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use peqa::bench::{quick_mode, save_json, steps as bench_steps, Table};
use peqa::config::{self, TrainConfig};
use peqa::data::LmBatcher;
use peqa::json::Value;
use peqa::pipeline;
use peqa::serve::{self, ModelGeom};
use peqa::train::{HostPeqaTuner, Tuner};

/// Counting wrapper around the system allocator: measures how much the
/// steady-state training loop still allocates (the arena should have
/// absorbed the activation tape after warm-up).
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);
static ALLOC_BYTES: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

fn main() -> anyhow::Result<()> {
    let quick = quick_mode();
    let geom = if quick {
        ModelGeom { vocab: 512, d_model: 64, n_layers: 2, n_heads: 4, d_ff: 192 }
    } else {
        ModelGeom { vocab: 512, d_model: 128, n_layers: 4, n_heads: 8, d_ff: 384 }
    };
    let bits = 4u8;
    let group = Some(64);
    let steps = bench_steps(40);
    let (batch, seq) = if quick { (4usize, 32usize) } else { (4, 64) };
    let threads = peqa::util::num_threads();

    let (pm, _) = serve::synth_packed(&geom, bits, group, 11)?;
    let packed_bytes = pm.packed_bytes();
    let cfg = TrainConfig {
        steps,
        lr: TrainConfig::default_lr("peqa"),
        warmup_steps: (steps / 10).max(1),
        log_every: 0,
        ..Default::default()
    };
    let mut tuner = HostPeqaTuner::from_packed(pm, geom, cfg, false, threads)?;
    let trainable = tuner.trainable_params();
    let state_bytes = tuner.trainable_state_bytes();

    let train_s = pipeline::host_stream("wikitext", 60_000)?;
    let mut batcher = LmBatcher::new(train_s, batch, seq, 91);
    // Warm-up steps grow the TapeArena to its high-water mark; the
    // allocator counters then measure the allocs-free steady state of
    // the TUNER STEP alone (batch generation sits outside the counted
    // window — the metric tracks arena effectiveness, not data loading).
    let warmup = if steps > 4 { 2usize } else { 0 };
    let mut samples = Vec::with_capacity(steps);
    let mut steady_allocs = 0u64;
    let mut steady_bytes = 0u64;
    for step in 0..steps {
        let b = batcher.next_batch();
        let (a0, by0) =
            (ALLOCS.load(Ordering::Relaxed), ALLOC_BYTES.load(Ordering::Relaxed));
        let t0 = std::time::Instant::now();
        tuner.step(&b)?;
        samples.push(t0.elapsed().as_secs_f64());
        if step >= warmup {
            steady_allocs += ALLOCS.load(Ordering::Relaxed) - a0;
            steady_bytes += ALLOC_BYTES.load(Ordering::Relaxed) - by0;
        }
    }
    let steady_steps = (steps - warmup).max(1) as f64;
    let allocs_per_step = steady_allocs as f64 / steady_steps;
    let alloc_bytes_per_step = steady_bytes as f64 / steady_steps;
    let losses = tuner.losses().to_vec();
    let (first_loss, final_loss) =
        (losses.first().copied().unwrap_or(0.0), losses.last().copied().unwrap_or(0.0));
    let mean_s = samples.iter().sum::<f64>() / samples.len().max(1) as f64;
    let p50_s = {
        let mut s = samples.clone();
        s.sort_by(f64::total_cmp);
        s[s.len() / 2]
    };

    let mut table = Table::new(
        &format!(
            "§Train — host PEQA finetune step (L{} d{} h{} b{bits}g{:?}, B{batch}×T{seq}, \
             {steps} steps, {threads} threads)",
            geom.n_layers, geom.d_model, geom.n_heads, group
        ),
        &["metric", "value"],
    );
    let rowf = |t: &mut Table, k: &str, v: String| t.row(&[k.to_string(), v]);
    rowf(&mut table, "step mean (ms)", format!("{:.2}", mean_s * 1e3));
    rowf(&mut table, "step p50 (ms)", format!("{:.2}", p50_s * 1e3));
    rowf(&mut table, "steady-state allocs / step", format!("{allocs_per_step:.0}"));
    rowf(
        &mut table,
        "steady-state alloc bytes / step",
        format!("{:.1} KiB", alloc_bytes_per_step / 1024.0),
    );
    rowf(&mut table, "loss first → final", format!("{first_loss:.4} → {final_loss:.4}"));
    rowf(&mut table, "trainable params (s only)", format!("{trainable}"));
    rowf(&mut table, "trainable+Adam bytes", format!("{state_bytes}"));
    rowf(&mut table, "packed code bytes", format!("{packed_bytes}"));
    rowf(
        &mut table,
        "codes / trainable-state ratio",
        format!("{:.1}x", packed_bytes as f64 / state_bytes.max(1) as f64),
    );
    table.print();
    let paths = config::Paths::default();
    table.save(&paths.results, "finetune_step").ok();

    let out = std::env::var("PEQA_BENCH_OUT")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| config::repo_root().join("BENCH_finetune.json"));
    let doc = Value::obj(vec![
        ("bench", Value::str("finetune_step")),
        ("quick", Value::str(if quick { "1" } else { "0" })),
        ("threads", Value::num(threads as f64)),
        ("n_layers", Value::num(geom.n_layers as f64)),
        ("d_model", Value::num(geom.d_model as f64)),
        ("n_heads", Value::num(geom.n_heads as f64)),
        ("d_ff", Value::num(geom.d_ff as f64)),
        ("vocab", Value::num(geom.vocab as f64)),
        ("bits", Value::num(bits as f64)),
        // 0 = per-channel (no grouping).
        ("group", Value::num(group.unwrap_or(0) as f64)),
        ("steps", Value::num(steps as f64)),
        ("batch", Value::num(batch as f64)),
        ("seq", Value::num(seq as f64)),
        ("step_mean_s", Value::num(mean_s)),
        ("step_p50_s", Value::num(p50_s)),
        ("allocs_per_step", Value::num(allocs_per_step)),
        ("alloc_bytes_per_step", Value::num(alloc_bytes_per_step)),
        ("first_loss", Value::num(first_loss as f64)),
        ("final_loss", Value::num(final_loss as f64)),
        ("trainable_params", Value::num(trainable as f64)),
        ("trainable_state_bytes", Value::num(state_bytes as f64)),
        ("packed_bytes", Value::num(packed_bytes as f64)),
    ]);
    save_json(&out, &doc)?;
    println!("\nwrote {}", out.display());
    Ok(())
}
