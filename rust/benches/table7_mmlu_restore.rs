//! Table 7: the restoration claim — fp base vs RTN-quantized (degraded)
//! vs RTN+PEQA-instruction-tuned (restored) on the 4-domain mmlu-sim.
//!
//! Shape target: RTN < base; PEQA-tuning recovers most (or all) of the
//! gap while staying at the quantized model size.

use peqa::bench::{quick_mode, steps, Table};
use peqa::data;
use peqa::eval::mc_accuracy;
use peqa::pipeline::{self, Ctx};

fn main() -> anyhow::Result<()> {
    let ctx = Ctx::new()?;
    let sizes: &[&str] = if quick_mode() { &["n3"] } else { &["n3", "n4"] };
    let n_items = if quick_mode() { 12 } else { 32 };
    let suite = data::mmlu_sim(&ctx.world, 3, n_items);
    let mut t = Table::new(
        "Table 7 — mmlu-sim 5-shot accuracy: base vs RTN vs RTN+PEQA (paper Table 7)",
        &["Model", "Method", "colors", "places", "sizes", "sounds", "Average"],
    );
    for size in sizes {
        for method in ["base", "rtn_b4", "peqa_b4_gc"] {
            eprintln!("[table7] {size} {method}…");
            let ck = pipeline::instruct_tuned(&ctx, size, method, 256, steps(120))?;
            let fp = if method == "base" { ck } else { ck.dequantize()? };
            let art = format!("{size}_logits_b8");
            let mut cells = vec![
                size.to_string(),
                match method {
                    "base" => "LLaMA-sim (fp)",
                    "rtn_b4" => "+ RTN (no tuning)",
                    _ => "+ PEQA (Ours)",
                }
                .to_string(),
            ];
            let mut accs = vec![];
            for task in &suite {
                let acc = mc_accuracy(&ctx.rt, &art, &fp, &ctx.tok, task, 5, 7)? * 100.0;
                accs.push(acc);
                cells.push(format!("{acc:.1}"));
            }
            cells.push(format!("{:.1}", accs.iter().sum::<f64>() / accs.len() as f64));
            t.row(&cells);
        }
    }
    t.print();
    t.save(&ctx.paths.results, "table7_mmlu_restore")?;
    Ok(())
}
