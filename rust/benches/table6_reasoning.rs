//! Table 6: common-sense reasoning + in-context learning after
//! instruction-tuning on alpaca-sim — base vs +LoRA(QKVO16) vs +PEQA(4b),
//! zero-shot and five-shot over the 5-task csr-sim suite.
//!
//! Shape target: +LoRA and +PEQA both ≥ base on average; PEQA within a
//! point or two of LoRA at ~4× smaller model bytes.

use peqa::bench::{quick_mode, steps, Table};
use peqa::data;
use peqa::eval::mc_accuracy;
use peqa::pipeline::{self, Ctx};

fn main() -> anyhow::Result<()> {
    let ctx = Ctx::new()?;
    let sizes: &[&str] = if quick_mode() { &["n3"] } else { &["n3"] }; // n4 via PEQA_BENCH_FULL (1-core budget)
    let n_items = if quick_mode() { 12 } else { 32 };
    let n_steps = steps(120);
    let suite = data::csr_suite(&ctx.world, 5, n_items);
    let task_names: Vec<&str> = suite.iter().map(|t| t.name).collect();

    for k_shot in [0usize, 5] {
        let mut t = Table::new(
            &format!(
                "Table 6 — csr-sim accuracy, {}-shot (paper Table 6)",
                k_shot
            ),
            &{
                let mut h = vec!["Model", "Method"];
                h.extend(task_names.iter().copied());
                h.push("Average");
                h
            },
        );
        for size in sizes {
            for method in ["base", "lora_qkvo16", "peqa_b4_gc"] {
                eprintln!("[table6] {size} {method} {k_shot}-shot…");
                let ck = pipeline::instruct_tuned(&ctx, size, method, 256, n_steps)?;
                let fp = if method.starts_with("peqa") { ck.dequantize()? } else if method
                    .starts_with("lora")
                {
                    let (a, r) = pipeline::lora_hparams(&ctx, size, method)?;
                    ck.merge_lora(a, r)?
                } else {
                    ck
                };
                let art = format!("{size}_logits_b8");
                let mut cells = vec![size.to_string(), method.to_string()];
                let mut accs = vec![];
                for task in &suite {
                    let acc =
                        mc_accuracy(&ctx.rt, &art, &fp, &ctx.tok, task, k_shot, 99)? * 100.0;
                    accs.push(acc);
                    cells.push(format!("{acc:.1}"));
                }
                cells.push(format!("{:.1}", accs.iter().sum::<f64>() / accs.len() as f64));
                t.row(&cells);
            }
        }
        t.print();
        t.save(&ctx.paths.results, &format!("table6_csr_{k_shot}shot"))?;
    }
    Ok(())
}
