//! Table 1 + Figure 2a: DRAM usage / inference speed / task-switching of
//! Full FT, PEFT, PEFT+PTQ, PTQ+PEFT, PEQA — at real LLaMA-65B dims
//! (analytic model) AND measured on our served family (adapter-swap vs
//! full-reload wall time, packed file sizes).

use peqa::bench::Table;
use peqa::memmodel::{self, Geometry, Method};
use peqa::model::Checkpoint;
use peqa::pipeline::{self, Ctx};
use peqa::util::decimal_gb;

fn main() -> anyhow::Result<()> {
    // ---- Analytic model at paper dimensions (Table 1 / Fig. 2a). ----
    let geom = Geometry::llama_65b();
    let lora_t = memmodel::lora_trainable(8192, 80, 2, 4);
    let mut t = Table::new(
        "Table 1 — DRAM & deployment axes @ LLaMA-65B dims (paper: 457/131/131/33/33 GB)",
        &["Method", "DRAM fine-tune", "DRAM deploy", "Inference", "Task-switching"],
    );
    for r in [
        memmodel::report(&geom, Method::FullFt),
        memmodel::report(&geom, Method::Peft { trainable_params: lora_t }),
        memmodel::report(&geom, Method::PeftPtq { trainable_params: lora_t, bits: 4 }),
        memmodel::report(&geom, Method::PtqPeft { trainable_params: lora_t, bits: 4 }),
        memmodel::report(&geom, Method::Peqa { bits: 4, group: None }),
    ] {
        t.row(&[
            r.method.to_string(),
            decimal_gb(r.finetune_bytes),
            decimal_gb(r.deploy_bytes),
            if r.fast_inference { "Fast" } else { "Slow" }.to_string(),
            if r.fast_switching { "Fast" } else { "Slow" }.to_string(),
        ]);
    }
    t.print();

    // Fig. 2a series (bar data): DRAM deploy per method.
    let mut f = Table::new(
        "Figure 2a — DRAM usage of LLaMA-65B by tuning method (GB, decimal)",
        &["Method", "Deploy GB"],
    );
    for (name, m) in [
        ("Full/PEFT fp16", Method::Peft { trainable_params: lora_t }),
        ("PEQA 4-bit", Method::Peqa { bits: 4, group: None }),
        ("PEQA 3-bit", Method::Peqa { bits: 3, group: None }),
    ] {
        let r = memmodel::report(&geom, m);
        f.row(&[name.to_string(), format!("{:.2}", r.deploy_bytes as f64 / 1e9)]);
    }
    f.print();

    // ---- Measured on our family: swap cost + packed sizes. ----
    let ctx = Ctx::new()?;
    let size = "n3";
    let base = pipeline::ensure_base(&ctx, size, pipeline::pretrain_steps())?;
    let qck = pipeline::rtn_quantize(&base, 4, None)?;
    let dir = std::env::temp_dir().join("peqa_table1");
    std::fs::create_dir_all(&dir)?;
    let packed_bytes = qck.save_packed(&dir.join("m.packed"), 4)?;
    let mut fp_ck = Checkpoint::new();
    for (n, x) in base.iter() {
        fp_ck.insert(n.clone(), x.clone());
    }
    fp_ck.save(&dir.join("m.fp.peqa"))?;
    let fp_bytes = std::fs::metadata(dir.join("m.fp.peqa"))?.len();
    let adapter = qck.extract_adapter(false);
    let adapter_bytes = adapter.n_params() as u64 * 4;

    let mut m = Table::new(
        "Table 1 (measured) — our n3 model: sizes & task-switch cost",
        &["Quantity", "Value"],
    );
    m.row(&["fp32 model file".into(), peqa::util::human_bytes(fp_bytes)]);
    m.row(&["4-bit packed model file".into(), peqa::util::human_bytes(packed_bytes)]);
    m.row(&[
        "compression".into(),
        format!("{:.2}x", fp_bytes as f64 / packed_bytes as f64),
    ]);
    m.row(&["task adapter (scales)".into(), peqa::util::human_bytes(adapter_bytes)]);
    m.row(&[
        "adapter/model ratio".into(),
        format!("{:.5}", adapter_bytes as f64 / packed_bytes as f64),
    ]);
    m.print();
    t.save(&ctx.paths.results, "table1_dram")?;
    m.save(&ctx.paths.results, "table1_measured")?;
    f.save(&ctx.paths.results, "fig2a_dram")?;
    std::fs::remove_dir_all(&dir).ok();
    Ok(())
}
