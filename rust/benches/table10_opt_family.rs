//! Table 10 (appendix E): the OPT-analog family — LoRA fp16 vs 4-bit
//! PEQA on wikitext-sim; the PPL gap should shrink as size grows.

use peqa::bench::{quick_mode, steps, Table};
use peqa::pipeline::{self, Ctx};

fn main() -> anyhow::Result<()> {
    let ctx = Ctx::new()?;
    let sizes: &[&str] =
        if quick_mode() { &["o1", "o2"] } else { &["o1", "o2", "o3", "o4"] }; // o5/o6: same trend, trimmed for the 1-core budget
    let n_steps = steps(120);
    let (_, eval_s) = ctx.split("wikitext", pipeline::ADAPT_BYTES)?;

    let mut t = Table::new(
        "Table 10 — OPT-sim family PPL on wikitext-sim (paper appendix E)",
        &{
            let mut h = vec!["Method", "W Bits"];
            h.extend(sizes.iter().copied());
            h
        },
    );
    let mut lora_row = vec!["LoRA(QV4)".to_string(), "16".to_string()];
    let mut peqa_row = vec!["PEQA(Ours)".to_string(), "4".to_string()];
    let mut gaps = vec![];
    for size in sizes {
        eprintln!("[table10] {size}…");
        let lora = pipeline::finetune_cached(&ctx, size, "lora_qv4", "wikitext", n_steps)?;
        let p_lora = pipeline::lora_ppl(&ctx, size, "lora_qv4", &lora, &eval_s)?;
        let pq = pipeline::finetune_cached(&ctx, size, "peqa_b4_gc", "wikitext", n_steps)?;
        let p_peqa = pipeline::ppl(&ctx, size, &pq, &eval_s)?;
        lora_row.push(format!("{p_lora:.2}"));
        peqa_row.push(format!("{p_peqa:.2}"));
        gaps.push(p_peqa - p_lora);
    }
    t.row(&lora_row);
    t.row(&peqa_row);
    t.print();
    println!("PPL gaps (PEQA − LoRA) by size: {gaps:.2?}");
    t.save(&ctx.paths.results, "table10_opt_family")?;
    Ok(())
}
