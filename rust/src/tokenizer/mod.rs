//! Byte-level tokenizer with trainable BPE merges.
//!
//! Vocabulary layout (fixed 512 ids, matching the models' vocab):
//!   0..=255   raw bytes
//!   256..=259 specials: PAD, BOS, EOS, SEP
//!   260..     learned BPE merges (up to vocab_size)
//!
//! The BPE trainer is the classic greedy most-frequent-pair loop over a
//! training corpus; `encode` applies merges by rank within
//! whitespace-delimited chunks (spaces attach to the following word,
//! GPT-2 style) so tokenization is stable under concatenation.

use std::collections::HashMap;

use anyhow::{bail, Result};

pub const PAD: u32 = 256;
pub const BOS: u32 = 257;
pub const EOS: u32 = 258;
pub const SEP: u32 = 259;
pub const FIRST_MERGE: u32 = 260;

#[derive(Clone, Debug)]
pub struct Tokenizer {
    /// merge rank -> (left id, right id); new id = FIRST_MERGE + rank.
    merges: Vec<(u32, u32)>,
    /// (left, right) -> rank, for O(1) lookup during encode.
    // peqa-lint: allow(nondeterminism-sources) -- lookup-only: encode
    // scans token pairs in sequence order and `get`s ranks; nothing
    // iterates this map.
    ranks: HashMap<(u32, u32), usize>,
    vocab_size: usize,
}

impl Tokenizer {
    /// Byte-level tokenizer with no merges.
    pub fn byte_level(vocab_size: usize) -> Self {
        assert!(vocab_size >= FIRST_MERGE as usize);
        // peqa-lint: allow(nondeterminism-sources) -- lookup-only rank
        // index (see the field's note).
        Tokenizer { merges: vec![], ranks: HashMap::new(), vocab_size }
    }

    /// Train BPE merges on `corpus` until the vocab is full (or no pair
    /// repeats). Deterministic: ties break toward the lexicographically
    /// smaller pair.
    pub fn train_bpe(corpus: &str, vocab_size: usize) -> Self {
        let mut tok = Tokenizer::byte_level(vocab_size);
        let n_merges = vocab_size - FIRST_MERGE as usize;
        // Work over whitespace chunks (dedup by count) for speed.
        // peqa-lint: allow(nondeterminism-sources) -- counting scratch:
        // drained into `chunks` which is sorted before any merge math,
        // so hash order never reaches the trained merges.
        let mut chunk_counts: HashMap<Vec<u32>, usize> = HashMap::new();
        for chunk in split_chunks(corpus) {
            *chunk_counts.entry(chunk.bytes().map(|b| b as u32).collect()).or_insert(0) += 1;
        }
        let mut chunks: Vec<(Vec<u32>, usize)> = chunk_counts.into_iter().collect();
        chunks.sort(); // determinism independent of hash order
        for rank in 0..n_merges {
            // peqa-lint: allow(nondeterminism-sources) -- counting
            // scratch: the winning pair is chosen by max_by with a total
            // (count, then lexicographic) order, independent of hash
            // iteration order.
            let mut pair_counts: HashMap<(u32, u32), usize> = HashMap::new();
            for (seq, cnt) in &chunks {
                for w in seq.windows(2) {
                    *pair_counts.entry((w[0], w[1])).or_insert(0) += cnt;
                }
            }
            let best = pair_counts
                .into_iter()
                .filter(|&(_, c)| c >= 2)
                .max_by(|a, b| a.1.cmp(&b.1).then_with(|| b.0.cmp(&a.0)));
            let Some((pair, _)) = best else { break };
            let new_id = FIRST_MERGE + rank as u32;
            tok.merges.push(pair);
            tok.ranks.insert(pair, rank);
            for (seq, _) in chunks.iter_mut() {
                merge_in_place(seq, pair, new_id);
            }
        }
        tok
    }

    pub fn vocab_size(&self) -> usize {
        self.vocab_size
    }

    pub fn n_merges(&self) -> usize {
        self.merges.len()
    }

    /// Text → token ids (no specials added).
    pub fn encode(&self, text: &str) -> Vec<u32> {
        let mut out = Vec::with_capacity(text.len() / 2 + 1);
        for chunk in split_chunks(text) {
            let mut seq: Vec<u32> = chunk.bytes().map(|b| b as u32).collect();
            // Repeatedly apply the lowest-rank applicable merge.
            loop {
                let mut best: Option<(usize, usize)> = None; // (rank, pos)
                for (i, w) in seq.windows(2).enumerate() {
                    if let Some(&r) = self.ranks.get(&(w[0], w[1])) {
                        if best.map_or(true, |(br, _)| r < br) {
                            best = Some((r, i));
                        }
                    }
                }
                match best {
                    Some((rank, _)) => {
                        let pair = self.merges[rank];
                        merge_in_place(&mut seq, pair, FIRST_MERGE + rank as u32);
                    }
                    None => break,
                }
            }
            out.extend(seq);
        }
        out
    }

    /// Token ids → text. Specials map to readable placeholders; merge ids
    /// expand recursively back to bytes.
    pub fn decode(&self, ids: &[u32]) -> Result<String> {
        let mut bytes = Vec::with_capacity(ids.len() * 2);
        for &id in ids {
            self.expand(id, &mut bytes)?;
        }
        Ok(String::from_utf8_lossy(&bytes).into_owned())
    }

    fn expand(&self, id: u32, out: &mut Vec<u8>) -> Result<()> {
        if id < 256 {
            out.push(id as u8);
        } else if id < FIRST_MERGE {
            // Specials decode to nothing (PAD) or markers.
            match id {
                PAD => {}
                BOS => {}
                EOS => {}
                SEP => out.push(b'\n'),
                _ => unreachable!(),
            }
        } else {
            let rank = (id - FIRST_MERGE) as usize;
            if rank >= self.merges.len() {
                bail!("token id {id} out of vocabulary");
            }
            let (l, r) = self.merges[rank];
            self.expand(l, out)?;
            self.expand(r, out)?;
        }
        Ok(())
    }

    /// Serialize merges (for embedding the tokenizer in checkpoints).
    pub fn to_lines(&self) -> String {
        self.merges.iter().map(|(l, r)| format!("{l} {r}\n")).collect()
    }

    pub fn from_lines(lines: &str, vocab_size: usize) -> Result<Self> {
        let mut tok = Tokenizer::byte_level(vocab_size);
        for (rank, line) in lines.lines().enumerate() {
            let mut it = line.split_whitespace();
            let (Some(l), Some(r)) = (it.next(), it.next()) else {
                bail!("bad merge line '{line}'");
            };
            let pair = (l.parse()?, r.parse()?);
            tok.merges.push(pair);
            tok.ranks.insert(pair, rank);
        }
        Ok(tok)
    }
}

/// Split text into chunks where a leading space sticks to the word.
fn split_chunks(text: &str) -> Vec<&str> {
    let b = text.as_bytes();
    let mut out = Vec::new();
    let mut start = 0;
    let mut i = 0;
    while i < b.len() {
        // A chunk begins at a space (or start) and runs to just before the
        // next space.
        i += 1;
        while i < b.len() && b[i] != b' ' {
            i += 1;
        }
        out.push(&text[start..i]);
        start = i;
    }
    out
}

fn merge_in_place(seq: &mut Vec<u32>, pair: (u32, u32), new_id: u32) {
    let mut w = 0;
    let mut r = 0;
    while r < seq.len() {
        if r + 1 < seq.len() && seq[r] == pair.0 && seq[r + 1] == pair.1 {
            seq[w] = new_id;
            r += 2;
        } else {
            seq[w] = seq[r];
            r += 1;
        }
        w += 1;
    }
    seq.truncate(w);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn byte_level_roundtrip() {
        let tok = Tokenizer::byte_level(512);
        let text = "hello world! ünïcode ok.";
        let ids = tok.encode(text);
        assert_eq!(tok.decode(&ids).unwrap(), text);
    }

    #[test]
    fn bpe_compresses_and_roundtrips() {
        let corpus = "the cat sat on the mat. the cat ate the rat. the mat was flat. "
            .repeat(50);
        let tok = Tokenizer::train_bpe(&corpus, 300);
        assert!(tok.n_merges() > 10);
        let ids = tok.encode(&corpus);
        assert!(ids.len() < corpus.len() / 2, "{} vs {}", ids.len(), corpus.len());
        assert_eq!(tok.decode(&ids).unwrap(), corpus);
        // " the" should be among the earliest merges' products.
        let the_ids = tok.encode(" the");
        assert!(the_ids.len() <= 2, "{the_ids:?}");
    }

    #[test]
    fn training_is_deterministic() {
        let corpus = "aa bb aa bb cc aa".repeat(20);
        let t1 = Tokenizer::train_bpe(&corpus, 280);
        let t2 = Tokenizer::train_bpe(&corpus, 280);
        assert_eq!(t1.to_lines(), t2.to_lines());
    }

    #[test]
    fn encode_stable_under_concatenation() {
        let corpus = "alpha beta gamma delta ".repeat(40);
        let tok = Tokenizer::train_bpe(&corpus, 320);
        let a = tok.encode("alpha beta");
        let b = tok.encode(" gamma");
        let joined = tok.encode("alpha beta gamma");
        assert_eq!(joined, [a, b].concat());
    }

    #[test]
    fn serialization_roundtrip() {
        let corpus = "foo bar foo bar baz foo ".repeat(30);
        let tok = Tokenizer::train_bpe(&corpus, 300);
        let tok2 = Tokenizer::from_lines(&tok.to_lines(), 300).unwrap();
        assert_eq!(tok.encode(&corpus), tok2.encode(&corpus));
    }

    #[test]
    fn out_of_vocab_decode_fails() {
        let tok = Tokenizer::byte_level(512);
        assert!(tok.decode(&[400]).is_err());
    }

    #[test]
    fn specials_do_not_collide() {
        let corpus = "x y z ".repeat(100);
        let tok = Tokenizer::train_bpe(&corpus, 512);
        let ids = tok.encode(&corpus);
        assert!(ids.iter().all(|&i| i < 256 || i >= FIRST_MERGE));
    }
}
