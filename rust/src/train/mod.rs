//! Fine-tuning backends behind one backend-agnostic surface.
//!
//! The paper's training story is that PEQA fine-tunes *only the
//! quantization scales* (and optionally zero-points) of a frozen integer
//! model, so the trainable + optimizer state is kilobytes while the
//! codes never move. This module makes that story backend-agnostic:
//!
//! * [`Tuner`] — the training API every backend implements: one
//!   optimizer [`Tuner::step`] per batch, loss bookkeeping, the
//!   trainable/optimizer byte accounting of the appendix-L memory
//!   tables, and [`Tuner::finish`] into a method-layout
//!   [`Checkpoint`](crate::model::Checkpoint).
//! * [`host::HostPeqaTuner`] — the **host PEQA backend** (default
//!   build): forward AND backward through the shared transformer
//!   compute core (`model::blocks` — the same block math the serving
//!   engine decodes with, plus a tape), activations in a reusable
//!   [`host::TapeArena`] (no per-step activation allocation), attention
//!   forward/backward sharded over `std::thread::scope` workers,
//!   gradients taken *only* w.r.t. the per-(row, group) scale and
//!   zero tensors via the straight-through estimator (codes frozen), and
//!   a shared-[`optim::Adam`] update. Bit-identical at any
//!   `PEQA_THREADS` value.
//! * [`host::MultiTaskTuner`] — N per-task scale/zero + Adam states
//!   round-robin over ONE shared packed model: multi-task PEQA tuning
//!   whose task switch is a kilobyte-scale swap (the training-side
//!   mirror of the serving scheduler's scale swap), bitwise equal to N
//!   independent runs.
//! * [`xla::Trainer`] — the original artifact-driven XLA backend
//!   (`--features xla`): the AOT'd graph owns forward/backward/AdamW,
//!   rust owns data, schedule and the step loop.
//!
//! Shared plumbing lives here and in [`optim`]: the Adam optimizer, the
//! LR schedule (in [`TrainConfig`](crate::config::TrainConfig)) and the
//! per-step loss/EMA bookkeeping ([`StepState`]), so the two backends
//! report identically shaped training runs.

pub mod host;
pub mod optim;
#[cfg(feature = "xla")]
pub mod xla;

pub use host::{HostPeqaTuner, MultiTaskTuner, TapeArena};
pub use optim::Adam;
#[cfg(feature = "xla")]
pub use xla::Trainer;

use anyhow::{bail, Result};

use crate::data::Batch;
use crate::model::Checkpoint;
use crate::util::stats::Ema;

/// A backend's complete mutable training state, exported for the
/// crash-safe journal (`store::journal`) and re-imported on resume.
/// `params`/`opt_m`/`opt_v` are parallel per-optimizer-slot vectors in
/// the backend's own slot order (for the host PEQA backend: per
/// projection prefix, scales then — when trained — zeros, exactly the
/// order [`optim::Adam`] steps them). Importing a state a backend
/// exported reproduces subsequent steps **bitwise**.
#[derive(Clone, Debug, PartialEq)]
pub struct TunerState {
    pub step: usize,
    /// Full per-step loss history.
    pub losses: Vec<f32>,
    /// EMA-smoothed loss (exact f64).
    pub ema: Option<f64>,
    pub params: Vec<Vec<f32>>,
    pub opt_m: Vec<Vec<f32>>,
    pub opt_v: Vec<Vec<f32>>,
}

/// Backend-agnostic fine-tuning surface (see module docs). Backends are
/// used by static dispatch; `finish`/`run` consume or borrow `self`
/// directly rather than through trait objects.
pub trait Tuner {
    /// One optimizer step on `batch`; returns the batch loss.
    fn step(&mut self, batch: &Batch) -> Result<f32>;

    /// Steps taken so far.
    fn step_count(&self) -> usize;

    /// Every per-step loss, in order.
    fn losses(&self) -> &[f32];

    /// EMA-smoothed loss (None before the first step).
    fn smoothed_loss(&self) -> Option<f64>;

    /// Number of trainable parameters (for PEQA: scale [+ zero] entries —
    /// the Table 4 "# trainable params" column).
    fn trainable_params(&self) -> usize;

    /// Bytes of trainable + optimizer state this backend carries per step
    /// (param + Adam m + v) — the appendix-L "training memory" number;
    /// for PEQA this is kilobytes against megabytes of packed codes.
    fn trainable_state_bytes(&self) -> u64;

    /// Snapshot the complete mutable training state (see [`TunerState`])
    /// for the crash-safe journal. Backends that cannot export state
    /// (the artifact-driven xla backend keeps its optimizer moments
    /// device-side) keep the default and resumable training is refused
    /// up front rather than silently wrong.
    fn export_state(&self) -> Result<TunerState> {
        bail!("this training backend does not support state export/resume")
    }

    /// Restore a state captured by [`Tuner::export_state`] — shapes are
    /// validated against this tuner before anything is overwritten, so
    /// a failed import leaves the tuner untouched. After a successful
    /// import, subsequent steps are bitwise identical to a run that
    /// never stopped.
    fn import_state(&mut self, state: &TunerState) -> Result<()> {
        let _ = state;
        bail!("this training backend does not support state export/resume")
    }

    /// Final method-layout checkpoint: trained + frozen tensors.
    fn finish(self) -> Result<Checkpoint>
    where
        Self: Sized;

    /// Drive [`Tuner::step`] until `steps` total steps have run.
    fn run<F: FnMut() -> Batch>(&mut self, steps: usize, mut next_batch: F) -> Result<()>
    where
        Self: Sized,
    {
        while self.step_count() < steps {
            let b = next_batch();
            self.step(&b)?;
        }
        Ok(())
    }
}

/// Per-step bookkeeping shared by every backend: step counter, loss
/// history, EMA smoothing and periodic logging.
pub struct StepState {
    pub step: usize,
    pub losses: Vec<f32>,
    ema: Ema,
    log_every: usize,
}

impl StepState {
    pub fn new(log_every: usize) -> StepState {
        StepState { step: 0, losses: Vec::new(), ema: Ema::new(0.05), log_every }
    }

    pub fn smoothed(&self) -> Option<f64> {
        self.ema.get()
    }

    /// Overwrite the bookkeeping with journaled values (training
    /// resume): step counter, full loss history, exact EMA.
    pub fn restore(&mut self, step: usize, losses: Vec<f32>, ema: Option<f64>) {
        self.step = step;
        self.losses = losses;
        self.ema.set(ema);
    }

    /// Record one finished step's loss (the caller has already advanced
    /// `self.step`) and emit the periodic log line.
    pub fn record(&mut self, loss: f32, lr: f64) {
        self.losses.push(loss);
        self.ema.push(loss as f64);
        if self.log_every > 0 && self.step % self.log_every == 0 {
            crate::info!(
                "step {:>5}  loss {:.4}  (ema {:.4})  lr {:.2e}",
                self.step,
                loss,
                self.ema.get().unwrap_or(loss as f64),
                lr
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_state_tracks_losses_and_ema() {
        let mut st = StepState::new(0);
        assert!(st.smoothed().is_none());
        for (i, l) in [2.0f32, 1.5, 1.0].iter().enumerate() {
            st.step = i + 1;
            st.record(*l, 1e-3);
        }
        assert_eq!(st.losses, vec![2.0, 1.5, 1.0]);
        let ema = st.smoothed().unwrap();
        assert!(ema < 2.0 && ema > 1.0);
    }
}
