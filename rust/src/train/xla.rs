//! XLA training backend: drives a `*_train_*` artifact step by step.
//!
//! The division of labor (DESIGN.md): the AOT'd XLA graph owns forward,
//! backward and AdamW; rust owns the data pipeline, the LR schedule, the
//! step loop, metrics and checkpointing. Frozen parameters are uploaded
//! to the device once and stay resident across all steps (`execute_b`);
//! only the trainable/optimizer tensors round-trip per step, which for
//! PEQA means kilobytes — the paper's training-memory story, visible in
//! the process RSS (appendix L bench).
//!
//! This backend sits behind the backend-agnostic [`Tuner`] trait; the
//! host equivalent that needs no device runtime is
//! [`super::host::HostPeqaTuner`].

use anyhow::{bail, Result};

use super::{StepState, Tuner};
use crate::config::TrainConfig;
use crate::data::Batch;
use crate::model::Checkpoint;
use crate::runtime::{literal_to_f32, literal_to_tensor, Artifact, Runtime};
use crate::tensor::Tensor;

pub struct Trainer<'rt> {
    rt: &'rt Runtime,
    art: std::rc::Rc<Artifact>,
    pub cfg: TrainConfig,
    trainable: Vec<Tensor>,
    m: Vec<Tensor>,
    v: Vec<Tensor>,
    frozen_host: Vec<Tensor>,
    frozen_dev: Vec<xla::PjRtBuffer>,
    state: StepState,
    /// Checkpoint tensors the artifact doesn't consume (returned intact).
    passthrough: Checkpoint,
}

impl<'rt> Trainer<'rt> {
    /// `ck` must contain the artifact's frozen tensors; missing trainable
    /// tensors are created from their init spec (fresh LoRA adapters).
    pub fn new(
        rt: &'rt Runtime,
        artifact_name: &str,
        ck: &Checkpoint,
        cfg: TrainConfig,
    ) -> Result<Trainer<'rt>> {
        let art = rt.load(artifact_name)?;
        if art.meta.kind != "train" {
            bail!("{artifact_name} is not a train artifact");
        }
        let tr_metas: Vec<_> = art.meta.params_trainable.iter().collect();
        let fz_metas: Vec<_> = art.meta.params_frozen.iter().collect();
        let trainable = ck.assemble(&tr_metas, cfg.seed)?;
        let frozen_host = ck.assemble(&fz_metas, cfg.seed)?;
        let m: Vec<Tensor> = trainable.iter().map(|t| Tensor::zeros(t.shape())).collect();
        let v = m.clone();
        let frozen_dev = frozen_host
            .iter()
            .map(|t| rt.tensor_to_device(t))
            .collect::<Result<Vec<_>>>()?;

        // peqa-lint: allow(nondeterminism-sources) -- membership-only:
        // `contains` checks while walking the checkpoint's ordered
        // iterator; never iterated itself.
        let known: std::collections::HashSet<&str> =
            art.meta.layout().iter().map(|p| p.name.as_str()).collect();
        let mut passthrough = Checkpoint::new();
        for (name, t) in ck.iter() {
            if !known.contains(name.as_str()) {
                passthrough.insert(name.clone(), t.clone());
            }
        }

        let state = StepState::new(cfg.log_every);
        Ok(Trainer {
            rt,
            art,
            cfg,
            trainable,
            m,
            v,
            frozen_host,
            frozen_dev,
            state,
            passthrough,
        })
    }

    pub fn artifact(&self) -> &Artifact {
        &self.art
    }
}

impl Tuner for Trainer<'_> {
    fn step_count(&self) -> usize {
        self.state.step
    }

    fn losses(&self) -> &[f32] {
        &self.state.losses
    }

    fn smoothed_loss(&self) -> Option<f64> {
        self.state.smoothed()
    }

    fn trainable_params(&self) -> usize {
        self.trainable.iter().map(|t| t.len()).sum()
    }

    /// Bytes of trainable + optimizer state this trainer round-trips per
    /// step — the appendix-L "training memory" number.
    fn trainable_state_bytes(&self) -> u64 {
        3 * self.trainable.iter().map(|t| 4 * t.len() as u64).sum::<u64>()
    }

    /// One optimizer step; returns the batch loss.
    fn step(&mut self, batch: &Batch) -> Result<f32> {
        let meta_inputs = &self.art.meta.inputs;
        let tok_spec = &meta_inputs[0];
        if batch.tokens.len() != tok_spec.numel() {
            bail!(
                "batch shape mismatch: {} tokens, artifact expects {:?}",
                batch.tokens.len(),
                tok_spec.shape
            );
        }
        self.state.step += 1;
        let lr = self.cfg.lr_at(self.state.step) as f32;

        // Upload per-step inputs; frozen params are already resident.
        let mut bufs: Vec<xla::PjRtBuffer> =
            Vec::with_capacity(4 + 3 * self.trainable.len());
        bufs.push(self.rt.to_device_i32(&batch.tokens, &tok_spec.shape)?);
        bufs.push(self.rt.to_device_f32(&batch.mask, &meta_inputs[1].shape)?);
        bufs.push(self.rt.scalar_to_device(lr)?);
        bufs.push(self.rt.scalar_to_device(self.state.step as f32)?);
        for t in self.trainable.iter().chain(self.m.iter()).chain(self.v.iter()) {
            bufs.push(self.rt.tensor_to_device(t)?);
        }

        // Input order: tokens, mask, lr, step, trainable…, frozen…, m…, v…
        let nt = self.trainable.len();
        let mut inputs: Vec<&xla::PjRtBuffer> =
            Vec::with_capacity(bufs.len() + self.frozen_dev.len());
        inputs.extend(bufs[..4 + nt].iter());
        inputs.extend(self.frozen_dev.iter());
        inputs.extend(bufs[4 + nt..].iter());

        let outs = self.art.run_b(&inputs)?;
        let loss = literal_to_f32(&outs[0])?;
        if !loss.is_finite() {
            bail!(
                "non-finite loss {loss} at step {} — reduce the learning rate",
                self.state.step
            );
        }
        let metas = &self.art.meta.params_trainable;
        for (i, p) in metas.iter().enumerate() {
            self.trainable[i] = literal_to_tensor(&outs[1 + i], &p.shape)?;
            self.m[i] = literal_to_tensor(&outs[1 + nt + i], &p.shape)?;
            self.v[i] = literal_to_tensor(&outs[1 + 2 * nt + i], &p.shape)?;
        }

        self.state.record(loss, lr as f64);
        Ok(loss)
    }

    /// Final method-layout checkpoint: trained + frozen + passthrough.
    fn finish(self) -> Result<Checkpoint> {
        let meta = &self.art.meta;
        let mut ck = self.passthrough.clone();
        for (p, t) in meta.params_trainable.iter().zip(&self.trainable) {
            ck.insert(p.name.clone(), t.clone());
        }
        for (p, t) in meta.params_frozen.iter().zip(&self.frozen_host) {
            ck.insert(p.name.clone(), t.clone());
        }
        Ok(ck)
    }
}
