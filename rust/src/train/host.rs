//! Host PEQA training backend — fine-tunes *only the quantization
//! scales* (and optionally zero-points) of a packed model, no `xla`
//! feature required.
//!
//! This is the paper's §3 algorithm executed on the host stack: the
//! forward pass runs the llama-family transformer straight off a
//! [`PackedModel`]'s bit-packed integer codes through the fused
//! `quant::kernels` GEMMs; the backward pass is full reverse-mode
//! through RMSNorm / rotary / causal attention / SwiGLU, but parameter
//! gradients exist **only for the per-(row, group) scale and zero
//! tensors** — the straight-through estimator with the codes `c`
//! frozen. Because `y = X·(s·(c − z))ᵀ` is exactly linear in `s` and
//! `z` once `c` is frozen, those gradients are exact
//! (`∂y/∂s = X·(c − z)ᵀ`-shaped reductions,
//! [`PackedMatrix::grad_scales_zeros`]); activation gradients flow
//! through [`PackedMatrix::grad_input`] without ever materializing a
//! dense Ŵ.
//!
//! Consequences the tests pin:
//! * the packed integer codes and every fp tensor (embeddings, norms,
//!   LM head) are bit-identical before and after training — only
//!   `scales`/`zeros` move;
//! * trainable + Adam state is `3 × 4 ×` (#scale [+ #zero]) bytes —
//!   kilobytes against the megabytes of packed codes
//!   ([`Tuner::trainable_state_bytes`], the paper's Table 1 optimizer
//!   memory story, cross-checkable against `memmodel::peqa_trainable`);
//! * every kernel on both passes accumulates in a fixed order, so a
//!   training step is **bit-identical at any `PEQA_THREADS` value**;
//! * the tuned scales, extracted with
//!   [`PackedModel::extract_adapter`], are a drop-in
//!   `serve::AdapterStore` adapter: `peqa finetune` writes a file that
//!   `peqa serve` scale-swaps without conversion.
//!
//! The forward here mirrors `serve::engine` (same RMS epsilon, rotary
//! table and SwiGLU) but recomputes full-sequence activations with a
//! tape instead of decoding through KV caches — training wants every
//! position's logits and the saved activations for backward.

use std::collections::HashMap;

use anyhow::{anyhow, bail, Result};

use super::optim::Adam;
use super::{StepState, Tuner};
use crate::config::TrainConfig;
use crate::data::Batch;
use crate::model::{Checkpoint, PackedModel};
use crate::quant::PackedMatrix;
// RMS_EPS and rope_freqs are shared with the serving engine: a model is
// tuned under exactly the norm and rotary table it is served with
// (tests/train_host.rs pins train-forward vs engine parity).
use crate::serve::engine::{rope_freqs, RMS_EPS};
use crate::serve::ModelGeom;
use crate::tensor::Tensor;

/// Host scale-only PEQA tuner (see module docs).
pub struct HostPeqaTuner {
    model: PackedModel,
    geom: ModelGeom,
    pub cfg: TrainConfig,
    train_zeros: bool,
    threads: usize,
    /// Trainable projection prefixes in deterministic (layer, slot) order.
    prefixes: Vec<String>,
    opt: Adam,
    state: StepState,
}

impl HostPeqaTuner {
    /// Wrap a packed model for scale-only fine-tuning. Every block
    /// projection must be *packed* (a dense fp projection has no scales
    /// to tune). `train_zeros` additionally trains the zero-points
    /// (the paper's PEQA+zp variant); `threads` pins the kernel worker
    /// count — results are bit-identical for any value.
    pub fn from_packed(
        model: PackedModel,
        geom: ModelGeom,
        cfg: TrainConfig,
        train_zeros: bool,
        threads: usize,
    ) -> Result<HostPeqaTuner> {
        validate_geom(&geom)?;
        let d = geom.d_model;
        let embed = model
            .fp_tensor("embed")
            .ok_or_else(|| anyhow!("packed model missing fp tensor 'embed'"))?;
        if embed.shape() != [geom.vocab, d].as_slice() {
            bail!("'embed' is {:?}, geometry wants [{}, {d}]", embed.shape(), geom.vocab);
        }
        if let Some(h) = model.fp_tensor("lm_head") {
            if h.shape() != [geom.vocab, d].as_slice() {
                bail!("'lm_head' is {:?}, geometry wants [{}, {d}]", h.shape(), geom.vocab);
            }
        }
        if model.fp_tensor("final_norm.g").is_none() {
            bail!("packed model missing 'final_norm.g'");
        }
        let mut prefixes = Vec::with_capacity(geom.n_layers * 7);
        for i in 0..geom.n_layers {
            let lp = format!("layers.{i}");
            for ln in ["ln1", "ln2"] {
                if model.fp_tensor(&format!("{lp}.{ln}.g")).is_none() {
                    bail!("packed model missing '{lp}.{ln}.g'");
                }
            }
            for (p, rows, cols) in [
                ("attn.q", d, d),
                ("attn.k", d, d),
                ("attn.v", d, d),
                ("attn.o", d, d),
                ("mlp.gate", geom.d_ff, d),
                ("mlp.up", geom.d_ff, d),
                ("mlp.down", d, geom.d_ff),
            ] {
                let prefix = format!("{lp}.{p}");
                let m = model.matrix(&prefix).ok_or_else(|| {
                    anyhow!(
                        "projection '{prefix}' is not packed — the host PEQA tuner \
                         trains quantization scales and needs every block projection \
                         quantized (run quantize + pack first)"
                    )
                })?;
                if (m.rows, m.cols) != (rows, cols) {
                    bail!(
                        "projection '{prefix}' is ({}, {}), geometry wants ({rows}, {cols})",
                        m.rows,
                        m.cols
                    );
                }
                prefixes.push(prefix);
            }
        }
        let mut sizes = Vec::new();
        for p in &prefixes {
            let m = model.matrix(p).expect("validated above");
            sizes.push(m.scales.len());
            if train_zeros {
                sizes.push(m.zeros.len());
            }
        }
        let state = StepState::new(cfg.log_every);
        Ok(HostPeqaTuner {
            model,
            geom,
            cfg,
            train_zeros,
            threads: threads.max(1),
            prefixes,
            opt: Adam::new(&sizes),
            state,
        })
    }

    pub fn geom(&self) -> &ModelGeom {
        &self.geom
    }

    pub fn train_zeros(&self) -> bool {
        self.train_zeros
    }

    pub fn model(&self) -> &PackedModel {
        &self.model
    }

    /// Mutable model access (tests perturb scales for finite-difference
    /// checks; the packed code bytes stay unreachable for mutation).
    pub fn model_mut(&mut self) -> &mut PackedModel {
        &mut self.model
    }

    /// Surrender the tuned model (codes + tuned scales) for serving.
    pub fn into_model(self) -> PackedModel {
        self.model
    }

    /// The tuned task adapter in the exact `serve::AdapterStore` format
    /// (zeros ride along only when they were trained).
    pub fn extract_adapter(&self) -> Checkpoint {
        self.model.extract_adapter(self.train_zeros)
    }

    /// Forward-only masked loss of one batch (no gradients, no state).
    pub fn loss(&self, batch: &Batch) -> Result<f32> {
        let (sum, count) = batch_nll(&self.model, &self.geom, self.threads, batch)?;
        if count == 0.0 {
            bail!("batch mask is all zero — no loss tokens");
        }
        Ok((sum / count) as f32)
    }

    /// Loss and the per-projection (ds, dz) gradients of one batch,
    /// without touching optimizer or model state — what `step` consumes
    /// and what the gradcheck tests probe directly. Gradients come back
    /// in `prefixes` order.
    pub fn forward_backward(&self, batch: &Batch) -> Result<(f32, Vec<(String, Tensor, Tensor)>)> {
        let (bsz, t_len, tokens) = check_batch(batch, self.geom.vocab)?;
        let tape = forward_tape(&self.model, &self.geom, self.threads, &tokens, bsz, t_len, true)?;
        let denom: f32 = batch.mask.iter().sum();
        if denom <= 0.0 {
            bail!("batch mask is all zero — nothing to train on");
        }
        let (loss, dlogits) =
            loss_and_dlogits(&tape.logits, &tokens, &batch.mask, bsz, t_len, self.geom.vocab);
        let by_prefix = backward(&self.model, &self.geom, self.threads, &tape, &dlogits, bsz, t_len)?;
        let mut out = Vec::with_capacity(self.prefixes.len());
        for p in &self.prefixes {
            let (ds, dz) = by_prefix
                .get(p)
                .ok_or_else(|| anyhow!("backward produced no gradient for '{p}'"))?;
            out.push((p.clone(), ds.clone(), dz.clone()));
        }
        Ok((loss, out))
    }
}

impl Tuner for HostPeqaTuner {
    fn step(&mut self, batch: &Batch) -> Result<f32> {
        let (loss, grads) = self.forward_backward(batch)?;
        if !loss.is_finite() {
            bail!(
                "non-finite loss {loss} at step {} — reduce the learning rate",
                self.state.step + 1
            );
        }
        self.state.step += 1;
        let t = self.state.step;
        let lr = self.cfg.lr_at(t) as f32;
        let Self { model, opt, train_zeros, .. } = self;
        let mut idx = 0usize;
        for (prefix, ds, dz) in &grads {
            let m = model.matrix_mut(prefix).expect("validated at construction");
            opt.step_tensor(idx, t, lr, m.scales.data_mut(), ds.data());
            idx += 1;
            if *train_zeros {
                opt.step_tensor(idx, t, lr, m.zeros.data_mut(), dz.data());
                idx += 1;
            }
        }
        self.state.record(loss, lr as f64);
        Ok(loss)
    }

    fn step_count(&self) -> usize {
        self.state.step
    }

    fn losses(&self) -> &[f32] {
        &self.state.losses
    }

    fn smoothed_loss(&self) -> Option<f64> {
        self.state.smoothed()
    }

    fn trainable_params(&self) -> usize {
        self.opt.n_params()
    }

    fn trainable_state_bytes(&self) -> u64 {
        // param + Adam m + v, all f32 — only s (and optionally z).
        3 * 4 * self.opt.n_params() as u64
    }

    fn finish(self) -> Result<Checkpoint> {
        Ok(self.model.to_checkpoint())
    }
}

/// Full-sequence logits of ONE sequence under the training forward,
/// `(tokens.len() · vocab)` row-major — the parity surface the tests
/// compare against `serve::Engine::prefill` and the dense
/// `reference_forward`: the model a tuner trains must be the model the
/// engine serves.
pub fn forward_logits(
    model: &PackedModel,
    geom: &ModelGeom,
    threads: usize,
    tokens: &[u32],
) -> Result<Vec<f32>> {
    if tokens.is_empty() {
        bail!("forward_logits needs at least one token");
    }
    let toks: Vec<usize> = tokens
        .iter()
        .map(|&t| {
            let t = t as usize;
            if t >= geom.vocab {
                bail!("token id {t} out of vocab {}", geom.vocab);
            }
            Ok(t)
        })
        .collect::<Result<_>>()?;
    let tape = forward_tape(model, geom, threads, &toks, 1, toks.len(), false)?;
    Ok(tape.logits)
}

/// Masked NLL of one batch under a packed model's forward — the host
/// evaluation primitive shared with `eval::host_perplexity`. Returns
/// `(Σ mask·nll, Σ mask)`.
pub fn batch_nll(
    model: &PackedModel,
    geom: &ModelGeom,
    threads: usize,
    batch: &Batch,
) -> Result<(f64, f64)> {
    let (bsz, t_len, tokens) = check_batch(batch, geom.vocab)?;
    // Forward-only: no activation tape retained (eval pays for logits,
    // not for backward state).
    let tape = forward_tape(model, geom, threads, &tokens, bsz, t_len, false)?;
    let vocab = geom.vocab;
    let mut sum = 0.0f64;
    let mut count = 0.0f64;
    for b in 0..bsz {
        for t in 0..t_len - 1 {
            let m = batch.mask[b * (t_len - 1) + t];
            if m == 0.0 {
                continue;
            }
            let row = &tape.logits[(b * t_len + t) * vocab..(b * t_len + t + 1) * vocab];
            let target = tokens[b * t_len + t + 1];
            sum += m as f64 * nll_row(row, target);
            count += m as f64;
        }
    }
    Ok((sum, count))
}

fn validate_geom(geom: &ModelGeom) -> Result<()> {
    if geom.vocab == 0 || geom.d_model == 0 || geom.n_layers == 0 || geom.d_ff == 0 {
        bail!("degenerate model geometry {geom:?}");
    }
    if geom.n_heads == 0 || geom.d_model % geom.n_heads != 0 {
        bail!("n_heads {} must divide d_model {}", geom.n_heads, geom.d_model);
    }
    if geom.head_dim() % 2 != 0 {
        bail!("rotary positions need an even head_dim, got {}", geom.head_dim());
    }
    Ok(())
}

/// Validate batch shapes and convert tokens to indices.
fn check_batch(batch: &Batch, vocab: usize) -> Result<(usize, usize, Vec<usize>)> {
    let (bsz, t_len) = (batch.batch, batch.seq);
    if t_len < 2 {
        bail!("training needs seq >= 2, got {t_len}");
    }
    if batch.tokens.len() != bsz * t_len {
        bail!("batch tokens {} != {}x{}", batch.tokens.len(), bsz, t_len);
    }
    if batch.mask.len() != bsz * (t_len - 1) {
        bail!("batch mask {} != {}x{}", batch.mask.len(), bsz, t_len - 1);
    }
    let mut tokens = Vec::with_capacity(bsz * t_len);
    for &t in &batch.tokens {
        if t < 0 || t as usize >= vocab {
            bail!("token id {t} out of vocab {vocab}");
        }
        tokens.push(t as usize);
    }
    Ok((bsz, t_len, tokens))
}

/// Saved forward activations of one batch (all row-major over the
/// `bsz·t_len` concatenated rows, batch-major).
struct Tape {
    layers: Vec<LayerTape>,
    /// Output of the last layer (input to the final norm).
    x_final: Vec<f32>,
    inv_final: Vec<f32>,
    logits: Vec<f32>,
}

struct LayerTape {
    /// Layer input (residual stream).
    x_in: Vec<f32>,
    /// Post-ln1 rows — input to the q/k/v projections.
    h1: Vec<f32>,
    inv1: Vec<f32>,
    /// Post-rope q/k and raw v.
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    /// Causal softmax probabilities, `(bsz, heads, T, T)` (zero above
    /// the diagonal).
    probs: Vec<f32>,
    /// Attention context rows — input to the o projection.
    ctx: Vec<f32>,
    /// Residual stream after attention — input to ln2.
    x_mid: Vec<f32>,
    h2: Vec<f32>,
    inv2: Vec<f32>,
    /// Pre-activation gate/up and act = silu(gate)·up — input to down.
    gate: Vec<f32>,
    up: Vec<f32>,
    act: Vec<f32>,
}

/// Fused packed projection over `m` rows.
fn proj(
    model: &PackedModel,
    threads: usize,
    prefix: &str,
    x: &[f32],
    m: usize,
) -> Result<Vec<f32>> {
    let pm = matrix(model, prefix)?;
    let mut out = vec![0.0f32; m * pm.rows];
    pm.matmul_t_rows(x, m, threads, &mut out)?;
    Ok(out)
}

fn matrix<'a>(model: &'a PackedModel, prefix: &str) -> Result<&'a PackedMatrix> {
    model.matrix(prefix).ok_or_else(|| anyhow!("no packed projection '{prefix}'"))
}

fn fp<'a>(model: &'a PackedModel, name: &str) -> Result<&'a Tensor> {
    model.fp_tensor(name).ok_or_else(|| anyhow!("packed model missing fp tensor '{name}'"))
}

/// RMSNorm over `m` rows, returning (normed, per-row inverse factor).
fn rms_norm(x: &[f32], g: &[f32], m: usize, d: usize) -> (Vec<f32>, Vec<f32>) {
    let mut out = vec![0.0f32; m * d];
    let mut invs = vec![0.0f32; m];
    for bi in 0..m {
        let xr = &x[bi * d..(bi + 1) * d];
        let mut ss = 0.0f32;
        for &v in xr {
            ss += v * v;
        }
        let inv = 1.0 / (ss / d as f32 + RMS_EPS).sqrt();
        invs[bi] = inv;
        let orow = &mut out[bi * d..(bi + 1) * d];
        for j in 0..d {
            orow[j] = g[j] * xr[j] * inv;
        }
    }
    (out, invs)
}

/// RMSNorm backward: dx_j = inv·g_j·dy_j − x_j·inv³/d · Σ_k dy_k·g_k·x_k.
fn rms_backward(dy: &[f32], x: &[f32], g: &[f32], invs: &[f32], m: usize, d: usize) -> Vec<f32> {
    let mut dx = vec![0.0f32; m * d];
    for bi in 0..m {
        let xr = &x[bi * d..(bi + 1) * d];
        let dyr = &dy[bi * d..(bi + 1) * d];
        let inv = invs[bi];
        let mut s = 0.0f32;
        for j in 0..d {
            s += dyr[j] * g[j] * xr[j];
        }
        let c = inv * inv * inv * s / d as f32;
        let dxr = &mut dx[bi * d..(bi + 1) * d];
        for j in 0..d {
            dxr[j] = inv * g[j] * dyr[j] - xr[j] * c;
        }
    }
    dx
}

/// Rotate rows in place at per-row position `row % t_len` (training
/// sequences all start at absolute position 0; matches
/// `serve::engine::rope_row_at`).
fn rope_rows(freqs: &[f32], hh: usize, hd: usize, rows: &mut [f32], t_len: usize, d: usize) {
    let half = hd / 2;
    for (r, row) in rows.chunks_mut(d).enumerate() {
        let p = (r % t_len) as f32;
        for h in 0..hh {
            let s = &mut row[h * hd..(h + 1) * hd];
            for i in 0..half {
                let (sin, cos) = (p * freqs[i]).sin_cos();
                let (x1, x2) = (s[i], s[i + half]);
                s[i] = x1 * cos - x2 * sin;
                s[i + half] = x1 * sin + x2 * cos;
            }
        }
    }
}

/// Backward of [`rope_rows`]: the rotation is orthogonal, so the
/// gradient rotates by −θ (transpose of the rotation).
fn rope_backward_rows(
    freqs: &[f32],
    hh: usize,
    hd: usize,
    rows: &mut [f32],
    t_len: usize,
    d: usize,
) {
    let half = hd / 2;
    for (r, row) in rows.chunks_mut(d).enumerate() {
        let p = (r % t_len) as f32;
        for h in 0..hh {
            let s = &mut row[h * hd..(h + 1) * hd];
            for i in 0..half {
                let (sin, cos) = (p * freqs[i]).sin_cos();
                let (g1, g2) = (s[i], s[i + half]);
                s[i] = g1 * cos + g2 * sin;
                s[i + half] = -g1 * sin + g2 * cos;
            }
        }
    }
}

#[inline]
fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

#[inline]
fn silu(x: f32) -> f32 {
    x * sigmoid(x)
}

/// d silu(x)/dx = σ(x)·(1 + x·(1 − σ(x))).
#[inline]
fn silu_grad(x: f32) -> f32 {
    let s = sigmoid(x);
    s * (1.0 + x * (1.0 - s))
}

/// Dense y (m, out) = X · Wᵀ with W row-major (out, in) — LM-head
/// forward, fixed-order accumulation.
fn dense_rows(w: &Tensor, x: &[f32], m: usize) -> Vec<f32> {
    let (o, i) = w.dims2().expect("dense projection is 2-D");
    let wd = w.data();
    let mut y = vec![0.0f32; m * o];
    for bi in 0..m {
        let xr = &x[bi * i..(bi + 1) * i];
        let yr = &mut y[bi * o..(bi + 1) * o];
        for (r, yv) in yr.iter_mut().enumerate() {
            let wr = &wd[r * i..(r + 1) * i];
            let mut acc = 0.0f32;
            for j in 0..i {
                acc += xr[j] * wr[j];
            }
            *yv = acc;
        }
    }
    y
}

/// Full-sequence training forward. With `keep_tape` the per-layer
/// activations are saved for [`backward`]; without it (loss/ppl
/// evaluation, [`forward_logits`]) they are dropped as each layer
/// completes and `Tape::layers` comes back empty.
fn forward_tape(
    model: &PackedModel,
    geom: &ModelGeom,
    threads: usize,
    tokens: &[usize],
    bsz: usize,
    t_len: usize,
    keep_tape: bool,
) -> Result<Tape> {
    let d = geom.d_model;
    let (hh, hd) = (geom.n_heads, geom.head_dim());
    let m = bsz * t_len;
    let freqs = rope_freqs(hd);
    let embed = fp(model, "embed")?;
    let ed = embed.data();
    let mut x = vec![0.0f32; m * d];
    for (r, &tok) in tokens.iter().enumerate() {
        x[r * d..(r + 1) * d].copy_from_slice(&ed[tok * d..(tok + 1) * d]);
    }
    let inv_sqrt = 1.0 / (hd as f32).sqrt();
    let mut layers = Vec::with_capacity(geom.n_layers);
    for layer in 0..geom.n_layers {
        let lp = format!("layers.{layer}");
        let x_in = if keep_tape { x.clone() } else { Vec::new() };
        let g1 = fp(model, &format!("{lp}.ln1.g"))?.data();
        let (h1, inv1) = rms_norm(&x, g1, m, d);
        let mut q = proj(model, threads, &format!("{lp}.attn.q"), &h1, m)?;
        let mut k = proj(model, threads, &format!("{lp}.attn.k"), &h1, m)?;
        let v = proj(model, threads, &format!("{lp}.attn.v"), &h1, m)?;
        rope_rows(&freqs, hh, hd, &mut q, t_len, d);
        rope_rows(&freqs, hh, hd, &mut k, t_len, d);
        // Causal attention. The (bsz, heads, T, T) probability tensor is
        // backward state: without the tape only one T-length score row is
        // ever live, so forward-only mode (loss/ppl eval) reuses a single
        // row scratch and stays linear in T.
        let mut probs =
            if keep_tape { vec![0.0f32; bsz * hh * t_len * t_len] } else { Vec::new() };
        let mut prow_scratch = vec![0.0f32; t_len];
        let mut ctx = vec![0.0f32; m * d];
        for b in 0..bsz {
            for h in 0..hh {
                for t in 0..t_len {
                    let qr = &q[(b * t_len + t) * d + h * hd..(b * t_len + t) * d + (h + 1) * hd];
                    let prow: &mut [f32] = if keep_tape {
                        &mut probs[((b * hh + h) * t_len + t) * t_len
                            ..((b * hh + h) * t_len + t + 1) * t_len]
                    } else {
                        // Stale beyond ..=t is never read: every j <= t is
                        // written below before any read.
                        &mut prow_scratch
                    };
                    let mut mx = f32::NEG_INFINITY;
                    for j in 0..=t {
                        let kr = &k
                            [(b * t_len + j) * d + h * hd..(b * t_len + j) * d + (h + 1) * hd];
                        let mut dot = 0.0f32;
                        for u in 0..hd {
                            dot += qr[u] * kr[u];
                        }
                        let sc = dot * inv_sqrt;
                        prow[j] = sc;
                        if sc > mx {
                            mx = sc;
                        }
                    }
                    let mut den = 0.0f32;
                    for p in prow[..=t].iter_mut() {
                        *p = (*p - mx).exp();
                        den += *p;
                    }
                    let cxr = &mut ctx
                        [(b * t_len + t) * d + h * hd..(b * t_len + t) * d + (h + 1) * hd];
                    for j in 0..=t {
                        prow[j] /= den;
                        let w = prow[j];
                        let vr = &v
                            [(b * t_len + j) * d + h * hd..(b * t_len + j) * d + (h + 1) * hd];
                        for u in 0..hd {
                            cxr[u] += w * vr[u];
                        }
                    }
                }
            }
        }
        let o = proj(model, threads, &format!("{lp}.attn.o"), &ctx, m)?;
        for (xv, ov) in x.iter_mut().zip(&o) {
            *xv += ov;
        }
        let x_mid = if keep_tape { x.clone() } else { Vec::new() };
        let g2 = fp(model, &format!("{lp}.ln2.g"))?.data();
        let (h2, inv2) = rms_norm(&x, g2, m, d);
        let gate = proj(model, threads, &format!("{lp}.mlp.gate"), &h2, m)?;
        let up = proj(model, threads, &format!("{lp}.mlp.up"), &h2, m)?;
        let mut act = vec![0.0f32; gate.len()];
        for j in 0..gate.len() {
            act[j] = silu(gate[j]) * up[j];
        }
        let down = proj(model, threads, &format!("{lp}.mlp.down"), &act, m)?;
        for (xv, dv) in x.iter_mut().zip(&down) {
            *xv += dv;
        }
        if keep_tape {
            layers.push(LayerTape {
                x_in,
                h1,
                inv1,
                q,
                k,
                v,
                probs,
                ctx,
                x_mid,
                h2,
                inv2,
                gate,
                up,
                act,
            });
        }
    }
    let x_final = x;
    let gf = fp(model, "final_norm.g")?.data();
    let (xn, inv_final) = rms_norm(&x_final, gf, m, d);
    let head = match model.fp_tensor("lm_head") {
        Some(h) => h,
        None => embed, // tied head
    };
    let logits = dense_rows(head, &xn, m);
    Ok(Tape { layers, x_final, inv_final, logits })
}

/// −log softmax(row)[target], numerically stable.
fn nll_row(row: &[f32], target: usize) -> f64 {
    let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut z = 0.0f64;
    for &v in row {
        z += ((v - mx) as f64).exp();
    }
    z.ln() - (row[target] - mx) as f64
}

/// Masked mean cross-entropy and its gradient w.r.t. the logits:
/// dlogits[b,t] = mask[b,t]/Σmask · (softmax(row) − onehot(target)).
fn loss_and_dlogits(
    logits: &[f32],
    tokens: &[usize],
    mask: &[f32],
    bsz: usize,
    t_len: usize,
    vocab: usize,
) -> (f32, Vec<f32>) {
    let denom: f32 = mask.iter().sum();
    let mut dlogits = vec![0.0f32; bsz * t_len * vocab];
    let mut loss = 0.0f64;
    for b in 0..bsz {
        for t in 0..t_len - 1 {
            let w = mask[b * (t_len - 1) + t];
            if w == 0.0 {
                continue;
            }
            let target = tokens[b * t_len + t + 1];
            let row = &logits[(b * t_len + t) * vocab..(b * t_len + t + 1) * vocab];
            let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            // One exp per logit: stash the numerators in drow while
            // accumulating the denominator, then scale in place.
            let drow =
                &mut dlogits[(b * t_len + t) * vocab..(b * t_len + t + 1) * vocab];
            let mut z = 0.0f32;
            for (dv, &v) in drow.iter_mut().zip(row) {
                let e = (v - mx).exp();
                *dv = e;
                z += e;
            }
            loss += (w as f64) * ((z as f64).ln() - (row[target] - mx) as f64);
            let scale = w / denom;
            let inv = scale / z;
            for dv in drow.iter_mut() {
                *dv *= inv;
            }
            drow[target] -= scale;
        }
    }
    ((loss / denom as f64) as f32, dlogits)
}

/// Full reverse-mode backward: activation gradients flow through every
/// layer; parameter gradients are collected only for the scale/zero
/// tensors of each packed projection.
fn backward(
    model: &PackedModel,
    geom: &ModelGeom,
    threads: usize,
    tape: &Tape,
    dlogits: &[f32],
    bsz: usize,
    t_len: usize,
) -> Result<HashMap<String, (Tensor, Tensor)>> {
    let d = geom.d_model;
    let (hh, hd) = (geom.n_heads, geom.head_dim());
    let m = bsz * t_len;
    let freqs = rope_freqs(hd);
    let inv_sqrt = 1.0 / (hd as f32).sqrt();
    let mut grads: HashMap<String, (Tensor, Tensor)> = HashMap::new();

    // LM head backward: dxn = dlogits · head (head itself is frozen).
    let head = match model.fp_tensor("lm_head") {
        Some(h) => h,
        None => fp(model, "embed")?,
    };
    let dxn = Tensor::new(&[m, geom.vocab], dlogits.to_vec())
        .matmul(head)?
        .into_data();
    let gf = fp(model, "final_norm.g")?.data();
    let mut dx = rms_backward(&dxn, &tape.x_final, gf, &tape.inv_final, m, d);

    // A projection's backward: dX into `dx_out` (overwritten), (ds, dz)
    // recorded under the prefix.
    let mut proj_back = |prefix: String,
                         x_in: &[f32],
                         dy: &[f32],
                         dx_out: &mut Vec<f32>|
     -> Result<()> {
        let pm = matrix(model, &prefix)?;
        dx_out.resize(m * pm.cols, 0.0);
        pm.grad_input(dy, m, threads, dx_out)?;
        let (ds, dz) = pm.grad_scales_zeros(x_in, dy, m, threads)?;
        grads.insert(prefix, (ds, dz));
        Ok(())
    };

    for layer in (0..geom.n_layers).rev() {
        let lp = format!("layers.{layer}");
        let tp = &tape.layers[layer];

        // x3 = x_mid + down(act): dx currently holds d(x3).
        let mut da = Vec::new();
        proj_back(format!("{lp}.mlp.down"), &tp.act, &dx, &mut da)?;
        // act = silu(gate) ⊙ up.
        let mf = m * geom.d_ff;
        let mut dgate = vec![0.0f32; mf];
        let mut dup = vec![0.0f32; mf];
        for j in 0..mf {
            dgate[j] = da[j] * tp.up[j] * silu_grad(tp.gate[j]);
            dup[j] = da[j] * silu(tp.gate[j]);
        }
        let mut dh2 = Vec::new();
        proj_back(format!("{lp}.mlp.gate"), &tp.h2, &dgate, &mut dh2)?;
        let mut dh2_up = Vec::new();
        proj_back(format!("{lp}.mlp.up"), &tp.h2, &dup, &mut dh2_up)?;
        for (a, b) in dh2.iter_mut().zip(&dh2_up) {
            *a += b;
        }
        // x_mid feeds both the residual and ln2.
        let g2 = fp(model, &format!("{lp}.ln2.g"))?.data();
        let mut dx2 = rms_backward(&dh2, &tp.x_mid, g2, &tp.inv2, m, d);
        for (a, b) in dx2.iter_mut().zip(&dx) {
            *a += b;
        }

        // x_mid = x_in + o(ctx): d(o out) = dx2.
        let mut dctx = Vec::new();
        proj_back(format!("{lp}.attn.o"), &tp.ctx, &dx2, &mut dctx)?;

        // Attention backward (per batch row and head, fixed order).
        let mut dq = vec![0.0f32; m * d];
        let mut dk = vec![0.0f32; m * d];
        let mut dv = vec![0.0f32; m * d];
        let mut dp = vec![0.0f32; t_len];
        for b in 0..bsz {
            for h in 0..hh {
                for t in 0..t_len {
                    let prow = &tp.probs
                        [((b * hh + h) * t_len + t) * t_len..((b * hh + h) * t_len + t + 1) * t_len];
                    let dcx = &dctx
                        [(b * t_len + t) * d + h * hd..(b * t_len + t) * d + (h + 1) * hd];
                    // dP and dV.
                    let mut row_dot = 0.0f32;
                    for j in 0..=t {
                        let vr = &tp.v
                            [(b * t_len + j) * d + h * hd..(b * t_len + j) * d + (h + 1) * hd];
                        let mut acc = 0.0f32;
                        for u in 0..hd {
                            acc += dcx[u] * vr[u];
                        }
                        dp[j] = acc;
                        row_dot += acc * prow[j];
                        let dvr = &mut dv
                            [(b * t_len + j) * d + h * hd..(b * t_len + j) * d + (h + 1) * hd];
                        for u in 0..hd {
                            dvr[u] += prow[j] * dcx[u];
                        }
                    }
                    // Softmax backward → dS, then dQ / dK.
                    let qr = &tp.q
                        [(b * t_len + t) * d + h * hd..(b * t_len + t) * d + (h + 1) * hd];
                    let dqr_base = (b * t_len + t) * d + h * hd;
                    for j in 0..=t {
                        let dsc = prow[j] * (dp[j] - row_dot) * inv_sqrt;
                        if dsc == 0.0 {
                            continue;
                        }
                        let kr = &tp.k
                            [(b * t_len + j) * d + h * hd..(b * t_len + j) * d + (h + 1) * hd];
                        for u in 0..hd {
                            dq[dqr_base + u] += dsc * kr[u];
                        }
                        let dkr = &mut dk
                            [(b * t_len + j) * d + h * hd..(b * t_len + j) * d + (h + 1) * hd];
                        for u in 0..hd {
                            dkr[u] += dsc * qr[u];
                        }
                    }
                }
            }
        }
        // Undo the rotation on the q/k gradients, then project back.
        rope_backward_rows(&freqs, hh, hd, &mut dq, t_len, d);
        rope_backward_rows(&freqs, hh, hd, &mut dk, t_len, d);
        let mut dh1 = Vec::new();
        proj_back(format!("{lp}.attn.q"), &tp.h1, &dq, &mut dh1)?;
        let mut dh1_k = Vec::new();
        proj_back(format!("{lp}.attn.k"), &tp.h1, &dk, &mut dh1_k)?;
        let mut dh1_v = Vec::new();
        proj_back(format!("{lp}.attn.v"), &tp.h1, &dv, &mut dh1_v)?;
        for (a, (b_, c)) in dh1.iter_mut().zip(dh1_k.iter().zip(&dh1_v)) {
            *a += b_ + c;
        }
        let g1 = fp(model, &format!("{lp}.ln1.g"))?.data();
        let mut dx1 = rms_backward(&dh1, &tp.x_in, g1, &tp.inv1, m, d);
        for (a, b) in dx1.iter_mut().zip(&dx2) {
            *a += b;
        }
        dx = dx1;
    }
    Ok(grads)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve;

    fn tiny_batch(bsz: usize, t_len: usize, vocab: u32, seed: u64) -> Batch {
        let mut rng = crate::util::Pcg32::new(seed);
        Batch {
            tokens: (0..bsz * t_len).map(|_| rng.below(vocab) as i32).collect(),
            mask: vec![1.0; bsz * (t_len - 1)],
            batch: bsz,
            seq: t_len,
        }
    }

    fn tiny_tuner(seed: u64, train_zeros: bool, threads: usize) -> HostPeqaTuner {
        let geom = ModelGeom { vocab: 64, d_model: 16, n_layers: 2, n_heads: 2, d_ff: 32 };
        let (pm, _) = serve::synth_packed(&geom, 4, Some(8), seed).unwrap();
        let cfg = TrainConfig { steps: 8, lr: 2e-3, warmup_steps: 1, log_every: 0, ..Default::default() };
        HostPeqaTuner::from_packed(pm, geom, cfg, train_zeros, threads).unwrap()
    }

    #[test]
    fn forward_loss_is_finite_and_near_uniform_at_init() {
        let tuner = tiny_tuner(3, false, 2);
        let batch = tiny_batch(2, 8, 64, 5);
        let loss = tuner.loss(&batch).unwrap();
        // A random quantized model is near-uniform over 64 tokens.
        assert!(loss.is_finite());
        assert!((loss - (64f32).ln()).abs() < 2.0, "loss {loss}");
    }

    #[test]
    fn step_reduces_state_and_counts_only_scales() {
        let mut tuner = tiny_tuner(3, false, 2);
        // 7 projections × 2 layers, per-group scales only.
        let expect: usize = (0..2)
            .flat_map(|_| [16 * 2, 16 * 2, 16 * 2, 16 * 2, 32 * 2, 32 * 2, 16 * 4])
            .sum();
        assert_eq!(tuner.trainable_params(), expect);
        assert_eq!(tuner.trainable_state_bytes(), 3 * 4 * expect as u64);
        let with_z = tiny_tuner(3, true, 2);
        assert_eq!(with_z.trainable_params(), 2 * expect);
        let batch = tiny_batch(2, 8, 64, 5);
        let l0 = tuner.step(&batch).unwrap();
        assert!(l0.is_finite());
        assert_eq!(tuner.step_count(), 1);
        assert_eq!(tuner.losses().len(), 1);
        assert!(tuner.smoothed_loss().is_some());
    }

    #[test]
    fn malformed_batches_are_rejected() {
        let tuner = tiny_tuner(9, false, 1);
        // Out-of-vocab token.
        let mut b = tiny_batch(1, 4, 64, 1);
        b.tokens[0] = 64;
        assert!(tuner.loss(&b).is_err());
        // seq too short.
        let b = Batch { tokens: vec![1], mask: vec![], batch: 1, seq: 1 };
        assert!(tuner.loss(&b).is_err());
        // All-zero mask.
        let mut b = tiny_batch(1, 4, 64, 1);
        b.mask.iter_mut().for_each(|m| *m = 0.0);
        assert!(tuner.forward_backward(&b).is_err());
    }

    #[test]
    fn unquantized_projection_is_rejected_at_construction() {
        let geom = ModelGeom { vocab: 32, d_model: 8, n_layers: 1, n_heads: 2, d_ff: 16 };
        let fp_ck = serve::synth_fp_base(&geom, 1);
        // A dense (never-quantized) model has no packed projections.
        let pm = PackedModel::from_checkpoint(&fp_ck, 4).unwrap();
        let err = HostPeqaTuner::from_packed(
            pm,
            geom,
            TrainConfig::default(),
            false,
            1,
        );
        assert!(err.is_err());
    }
}
