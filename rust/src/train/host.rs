//! Host PEQA training backend — fine-tunes *only the quantization
//! scales* (and optionally zero-points) of a packed model, no `xla`
//! feature required.
//!
//! This is the paper's §3 algorithm executed on the host stack: the
//! forward pass runs the llama-family transformer straight off a
//! [`PackedModel`]'s bit-packed integer codes through the fused
//! `quant::kernels` GEMMs; the backward pass is full reverse-mode
//! through RMSNorm / rotary / causal attention / SwiGLU, but parameter
//! gradients exist **only for the per-(row, group) scale and zero
//! tensors** — the straight-through estimator with the codes `c`
//! frozen. Because `y = X·(s·(c − z))ᵀ` is exactly linear in `s` and
//! `z` once `c` is frozen, those gradients are exact
//! (`∂y/∂s = X·(c − z)ᵀ`-shaped reductions,
//! [`PackedMatrix::grad_scales_zeros`]); activation gradients flow
//! through [`PackedMatrix::grad_input`] without ever materializing a
//! dense Ŵ.
//!
//! **The training forward IS the serving forward plus a tape.** Every
//! block primitive — RMSNorm, rotary, the head-blocked fixed-order
//! causal attention kernel, SwiGLU, the packed-projection call — comes
//! from the shared transformer compute core
//! ([`crate::model::blocks`]), the same functions `serve::engine`
//! decodes through. The only training-side difference is
//! [`blocks::Tape`]: with `Tape::Keep` the attention kernel saves the
//! causal softmax probabilities for reverse mode, with `Tape::None`
//! (loss/perplexity evaluation) only one O(window) score row is ever
//! live. Consequently the trainer-vs-engine forward parity test pins
//! **bitwise** equality, not a tolerance (tests/train_host.rs).
//!
//! Steady-state memory discipline mirrors the engine's scratch arena:
//! a [`TapeArena`] owns every activation slab of forward *and*
//! backward (tape layers, probs tensors, gradient slabs, per-worker
//! attention scratch, the kernel's yᵀ buffer, the resolved per-layer
//! tensor names), grown to the high-water mark once and reused across
//! training steps and eval batches — the training loop performs no
//! per-step activation allocation (benches/finetune_step.rs counts
//! allocator traffic per step to keep this honest).
//!
//! The attention pass — forward *and* backward — shards batch rows
//! (sequences) over `std::thread::scope` workers with per-worker
//! scratch, exactly like the engine's `attend_seq_chunk`: sequences
//! are mutually independent and every per-(head, position) reduction
//! has a fixed order, so a training step is **bit-identical at any
//! `PEQA_THREADS` value**.
//!
//! Consequences the tests pin:
//! * the packed integer codes and every fp tensor (embeddings, norms,
//!   LM head) are bit-identical before and after training — only
//!   `scales`/`zeros` move;
//! * trainable + Adam state is `3 × 4 ×` (#scale [+ #zero]) bytes —
//!   kilobytes against the megabytes of packed codes
//!   ([`Tuner::trainable_state_bytes`], the paper's Table 1 optimizer
//!   memory story, cross-checkable against `memmodel::peqa_trainable`);
//! * the tuned scales, extracted with
//!   [`PackedModel::extract_adapter`], are a drop-in
//!   `serve::AdapterStore` adapter: `peqa finetune` writes a file that
//!   `peqa serve` scale-swaps without conversion.
//!
//! [`MultiTaskTuner`] stacks N per-task scale/zero + Adam states onto
//! ONE shared [`HostPeqaTuner`] (and thus one shared packed model):
//! switching the active task swaps only kilobytes of f32 tensors —
//! training-side scale swap, the mirror of the serving scheduler's.
//! Because a task's step depends only on its own scales/zeros plus the
//! frozen codes, round-robin multi-task tuning is *bitwise* the N
//! independent single-task runs while holding the packed codes in
//! memory once.

use anyhow::{anyhow, bail, Result};

use super::optim::Adam;
use super::{StepState, Tuner, TunerState};
use crate::config::TrainConfig;
use crate::data::Batch;
use crate::model::blocks::{
    attend_seq_backward, attend_seq_tape, dense_grad_rows_into, dense_rows_core, ensure,
    proj_into, rms_backward_into, rms_norm_rows_into, rope_freqs, shard_chunks,
    swiglu_backward_into, swiglu_rows_into, AttnScratch, LayerNames, ProjScratch, Tape,
};
use crate::model::{Checkpoint, PackedModel};
use crate::quant::PackedMatrix;
use crate::serve::ModelGeom;
use crate::tensor::Tensor;

/// Projection slots per layer, in `prefixes` order (q k v o gate up
/// down) — the index base of [`TapeArena`]'s gradient table.
const SLOTS: usize = 7;

/// Host scale-only PEQA tuner (see module docs).
pub struct HostPeqaTuner {
    model: PackedModel,
    geom: ModelGeom,
    pub cfg: TrainConfig,
    train_zeros: bool,
    threads: usize,
    /// Trainable projection prefixes in deterministic (layer, slot) order.
    prefixes: Vec<String>,
    opt: Adam,
    state: StepState,
    arena: TapeArena,
}

impl HostPeqaTuner {
    /// Wrap a packed model for scale-only fine-tuning. Every block
    /// projection must be *packed* (a dense fp projection has no scales
    /// to tune). `train_zeros` additionally trains the zero-points
    /// (the paper's PEQA+zp variant); `threads` pins the kernel worker
    /// count — results are bit-identical for any value.
    pub fn from_packed(
        model: PackedModel,
        geom: ModelGeom,
        cfg: TrainConfig,
        train_zeros: bool,
        threads: usize,
    ) -> Result<HostPeqaTuner> {
        validate_geom(&geom)?;
        let d = geom.d_model;
        let embed = model
            .fp_tensor("embed")
            .ok_or_else(|| anyhow!("packed model missing fp tensor 'embed'"))?;
        if embed.shape() != [geom.vocab, d].as_slice() {
            bail!("'embed' is {:?}, geometry wants [{}, {d}]", embed.shape(), geom.vocab);
        }
        if let Some(h) = model.fp_tensor("lm_head") {
            if h.shape() != [geom.vocab, d].as_slice() {
                bail!("'lm_head' is {:?}, geometry wants [{}, {d}]", h.shape(), geom.vocab);
            }
        }
        if model.fp_tensor("final_norm.g").is_none() {
            bail!("packed model missing 'final_norm.g'");
        }
        let mut prefixes = Vec::with_capacity(geom.n_layers * SLOTS);
        for i in 0..geom.n_layers {
            let lp = format!("layers.{i}");
            for ln in ["ln1", "ln2"] {
                if model.fp_tensor(&format!("{lp}.{ln}.g")).is_none() {
                    bail!("packed model missing '{lp}.{ln}.g'");
                }
            }
            for (p, rows, cols) in [
                ("attn.q", d, d),
                ("attn.k", d, d),
                ("attn.v", d, d),
                ("attn.o", d, d),
                ("mlp.gate", geom.d_ff, d),
                ("mlp.up", geom.d_ff, d),
                ("mlp.down", d, geom.d_ff),
            ] {
                let prefix = format!("{lp}.{p}");
                let m = model.matrix(&prefix).ok_or_else(|| {
                    anyhow!(
                        "projection '{prefix}' is not packed — the host PEQA tuner \
                         trains quantization scales and needs every block projection \
                         quantized (run quantize + pack first)"
                    )
                })?;
                if (m.rows, m.cols) != (rows, cols) {
                    bail!(
                        "projection '{prefix}' is ({}, {}), geometry wants ({rows}, {cols})",
                        m.rows,
                        m.cols
                    );
                }
                prefixes.push(prefix);
            }
        }
        let sizes = opt_sizes(&model, &prefixes, train_zeros);
        let state = StepState::new(cfg.log_every);
        Ok(HostPeqaTuner {
            model,
            geom,
            cfg,
            train_zeros,
            threads: threads.max(1),
            prefixes,
            opt: Adam::new(&sizes),
            state,
            arena: TapeArena::new(),
        })
    }

    pub fn geom(&self) -> &ModelGeom {
        &self.geom
    }

    pub fn train_zeros(&self) -> bool {
        self.train_zeros
    }

    pub fn model(&self) -> &PackedModel {
        &self.model
    }

    /// Mutable model access (tests perturb scales for finite-difference
    /// checks; the packed code bytes stay unreachable for mutation).
    pub fn model_mut(&mut self) -> &mut PackedModel {
        &mut self.model
    }

    /// Surrender the tuned model (codes + tuned scales) for serving.
    pub fn into_model(self) -> PackedModel {
        self.model
    }

    /// The tuned task adapter in the exact `serve::AdapterStore` format
    /// (zeros ride along only when they were trained).
    pub fn extract_adapter(&self) -> Checkpoint {
        self.model.extract_adapter(self.train_zeros)
    }

    /// Forward-only masked loss of one batch (no gradients, no
    /// optimizer state — but the activation arena is reused, hence
    /// `&mut self`).
    pub fn loss(&mut self, batch: &Batch) -> Result<f32> {
        let Self { model, geom, threads, arena, .. } = self;
        let (sum, count) = batch_nll(model, geom, *threads, batch, arena)?;
        if count == 0.0 {
            bail!("batch mask is all zero — no loss tokens");
        }
        Ok((sum / count) as f32)
    }

    /// Loss and the per-projection (ds, dz) gradients of one batch,
    /// without touching optimizer or model state — the diagnostic
    /// surface the gradcheck tests probe directly. Gradients come back
    /// in `prefixes` order. ([`Tuner::step`] uses the same pass but
    /// leaves the gradients in the arena instead of cloning them out.)
    pub fn forward_backward(
        &mut self,
        batch: &Batch,
    ) -> Result<(f32, Vec<(String, Tensor, Tensor)>)> {
        let loss = self.forward_backward_into(batch)?;
        let mut out = Vec::with_capacity(self.prefixes.len());
        for (gi, p) in self.prefixes.iter().enumerate() {
            let (ds, dz) = self.arena.grads[gi]
                .as_ref()
                .ok_or_else(|| anyhow!("backward produced no gradient for '{p}'"))?;
            out.push((p.clone(), ds.clone(), dz.clone()));
        }
        Ok((loss, out))
    }

    /// One forward + backward: loss returned, (ds, dz) gradients left in
    /// `self.arena.grads` (per projection, `prefixes` order).
    fn forward_backward_into(&mut self, batch: &Batch) -> Result<f32> {
        let Self { model, geom, threads, arena, .. } = self;
        let (bsz, t_len) = check_batch_into(batch, geom.vocab, &mut arena.tokens)?;
        let denom: f32 = batch.mask.iter().sum();
        if denom <= 0.0 {
            bail!("batch mask is all zero — nothing to train on");
        }
        forward_tape(model, geom, *threads, bsz, t_len, true, arena)?;
        let m = bsz * t_len;
        let TapeArena { tokens, logits, dlogits, .. } = arena;
        let loss = loss_and_dlogits_into(
            &logits[..m * geom.vocab],
            tokens,
            &batch.mask,
            bsz,
            t_len,
            geom.vocab,
            dlogits,
        );
        backward(model, geom, *threads, bsz, t_len, arena)?;
        Ok(loss)
    }
}

impl Tuner for HostPeqaTuner {
    fn step(&mut self, batch: &Batch) -> Result<f32> {
        let loss = self.forward_backward_into(batch)?;
        if !loss.is_finite() {
            bail!(
                "non-finite loss {loss} at step {} — reduce the learning rate",
                self.state.step + 1
            );
        }
        self.state.step += 1;
        let t = self.state.step;
        let lr = self.cfg.lr_at(t) as f32;
        let Self { model, opt, train_zeros, prefixes, arena, .. } = self;
        let mut idx = 0usize;
        for (gi, prefix) in prefixes.iter().enumerate() {
            let (ds, dz) = arena.grads[gi].as_ref().expect("backward fills every projection");
            let m = model.matrix_mut(prefix).expect("validated at construction");
            opt.step_tensor(idx, t, lr, m.scales.data_mut(), ds.data());
            idx += 1;
            if *train_zeros {
                opt.step_tensor(idx, t, lr, m.zeros.data_mut(), dz.data());
                idx += 1;
            }
        }
        self.state.record(loss, lr as f64);
        Ok(loss)
    }

    fn step_count(&self) -> usize {
        self.state.step
    }

    fn losses(&self) -> &[f32] {
        &self.state.losses
    }

    fn smoothed_loss(&self) -> Option<f64> {
        self.state.smoothed()
    }

    fn trainable_params(&self) -> usize {
        self.opt.n_params()
    }

    fn trainable_state_bytes(&self) -> u64 {
        // param + Adam m + v, all f32 — only s (and optionally z).
        3 * 4 * self.opt.n_params() as u64
    }

    fn export_state(&self) -> Result<TunerState> {
        // Slot order must mirror `step`: per prefix, scales then (when
        // trained) zeros — the same layout `opt_sizes` built Adam with.
        let mut params = Vec::new();
        for p in &self.prefixes {
            let m = self.model.matrix(p).expect("validated at construction");
            params.push(m.scales.data().to_vec());
            if self.train_zeros {
                params.push(m.zeros.data().to_vec());
            }
        }
        let (opt_m, opt_v) = self.opt.export_moments();
        Ok(TunerState {
            step: self.state.step,
            losses: self.state.losses.clone(),
            ema: self.state.smoothed(),
            params,
            opt_m,
            opt_v,
        })
    }

    fn import_state(&mut self, st: &TunerState) -> Result<()> {
        let expected = opt_sizes(&self.model, &self.prefixes, self.train_zeros);
        if st.params.len() != expected.len() {
            bail!(
                "resume state has {} trainable slot(s), this model trains {} \
                 (train_zeros or model mismatch?)",
                st.params.len(),
                expected.len()
            );
        }
        for (i, (&want, got)) in expected.iter().zip(&st.params).enumerate() {
            if got.len() != want {
                bail!(
                    "resume state slot {i} has {} value(s), this model wants {want} \
                     (different quantization grouping?)",
                    got.len()
                );
            }
        }
        if st.losses.len() != st.step {
            bail!(
                "resume state is inconsistent: {} loss value(s) for step {}",
                st.losses.len(),
                st.step
            );
        }
        // import_moments re-validates against Adam's slots and either
        // applies fully or not at all; only then touch the model.
        self.opt.import_moments(&st.opt_m, &st.opt_v)?;
        let mut idx = 0usize;
        for p in &self.prefixes {
            let m = self.model.matrix_mut(p).expect("validated at construction");
            m.scales.data_mut().copy_from_slice(&st.params[idx]);
            idx += 1;
            if self.train_zeros {
                m.zeros.data_mut().copy_from_slice(&st.params[idx]);
                idx += 1;
            }
        }
        self.state.restore(st.step, st.losses.clone(), st.ema);
        Ok(())
    }

    fn finish(self) -> Result<Checkpoint> {
        Ok(self.model.to_checkpoint())
    }
}

/// Adam slot sizes for `prefixes` over `model` (scales, then zeros when
/// trained, per projection — the layout `step` walks).
fn opt_sizes(model: &PackedModel, prefixes: &[String], train_zeros: bool) -> Vec<usize> {
    let mut sizes = Vec::new();
    for p in prefixes {
        let m = model.matrix(p).expect("validated at construction");
        sizes.push(m.scales.len());
        if train_zeros {
            sizes.push(m.zeros.len());
        }
    }
    sizes
}

// ------------------------------------------------------ multi-task tuner

/// Round-robin multi-task PEQA tuning over ONE shared packed model.
///
/// Each task owns exactly what the paper says a task is — per-(row,
/// group) scale/zero tensors — plus its Adam moments and step
/// bookkeeping. The packed integer codes, embeddings, norms and LM head
/// are shared by every task and never move. Switching the active task
/// swaps only those kilobyte-scale f32 buffers into the shared
/// [`HostPeqaTuner`] (the training-side mirror of the serving
/// scheduler's scale swap). A task's gradient depends only on its own
/// scales/zeros and the frozen shared tensors, so interleaving tasks
/// round-robin is **bitwise identical** to N independent single-task
/// runs — pinned by tests/train_host.rs — while the code bytes are held
/// once.
pub struct MultiTaskTuner {
    tuner: HostPeqaTuner,
    slots: Vec<TaskSlot>,
    active: usize,
}

struct TaskSlot {
    name: String,
    /// The task's per-projection (scales, zeros), parked here while the
    /// task is inactive. The ACTIVE task's tensors live in the shared
    /// model; its slot holds the buffers last swapped out (garbage by
    /// invariant, reused as swap space).
    sz: Vec<(Tensor, Tensor)>,
    opt: Adam,
    state: StepState,
}

impl MultiTaskTuner {
    /// Stack one task state per `names` entry onto `tuner`, every task
    /// starting from the tuner's current scales/zeros (the shared base)
    /// with fresh Adam/step state. The tuner must be unstepped —
    /// otherwise task 0 would silently inherit its warm optimizer
    /// moments and mid-schedule step counter while the other tasks
    /// start fresh, breaking the "round-robin ≡ N independent runs"
    /// invariant.
    pub fn new(tuner: HostPeqaTuner, names: &[String]) -> Result<MultiTaskTuner> {
        if names.is_empty() {
            bail!("multi-task tuning needs at least one task");
        }
        if tuner.step_count() != 0 {
            bail!(
                "multi-task tuning must start from an unstepped tuner \
                 (this one has taken {} steps — its warm Adam/step state \
                 would leak into task 0 only)",
                tuner.step_count()
            );
        }
        for (i, n) in names.iter().enumerate() {
            if names[..i].contains(n) {
                bail!("duplicate task name '{n}'");
            }
        }
        let base_sz: Vec<(Tensor, Tensor)> = tuner
            .prefixes
            .iter()
            .map(|p| {
                let m = tuner.model.matrix(p).expect("validated at construction");
                (m.scales.clone(), m.zeros.clone())
            })
            .collect();
        let sizes = opt_sizes(&tuner.model, &tuner.prefixes, tuner.train_zeros);
        let log_every = tuner.cfg.log_every;
        let slots = names
            .iter()
            .map(|n| TaskSlot {
                name: n.clone(),
                sz: base_sz.clone(),
                opt: Adam::new(&sizes),
                state: StepState::new(log_every),
            })
            .collect();
        Ok(MultiTaskTuner { tuner, slots, active: 0 })
    }

    pub fn n_tasks(&self) -> usize {
        self.slots.len()
    }

    pub fn task_names(&self) -> Vec<&str> {
        self.slots.iter().map(|s| s.name.as_str()).collect()
    }

    /// Trainable parameters per task (every task trains the same shape).
    pub fn trainable_params(&self) -> usize {
        self.tuner.trainable_params()
    }

    /// Trainable + Adam bytes of ONE task (the shared tuner's formula).
    pub fn trainable_state_bytes(&self) -> u64 {
        self.tuner.trainable_state_bytes()
    }

    /// Trainable + Adam bytes across ALL tasks — still kilobytes each,
    /// N of them, against ONE copy of the packed codes.
    pub fn trainable_state_bytes_total(&self) -> u64 {
        self.tuner.trainable_state_bytes() * self.slots.len() as u64
    }

    pub fn packed_bytes(&self) -> usize {
        self.tuner.model().packed_bytes()
    }

    /// One optimizer step for task `idx` on `batch` (activates the task
    /// first — a kilobyte-scale swap when the task changes).
    pub fn step_task(&mut self, idx: usize, batch: &Batch) -> Result<f32> {
        self.activate(idx);
        self.tuner.step(batch)
    }

    /// Forward-only masked loss of `batch` under task `idx`.
    pub fn loss(&mut self, idx: usize, batch: &Batch) -> Result<f32> {
        self.activate(idx);
        self.tuner.loss(batch)
    }

    /// Per-step losses of task `idx`, in order.
    pub fn losses(&mut self, idx: usize) -> &[f32] {
        self.activate(idx);
        self.tuner.losses()
    }

    pub fn step_count(&mut self, idx: usize) -> usize {
        self.activate(idx);
        self.tuner.step_count()
    }

    /// Full resumable state of task `idx` — scales/zeros, Adam moments,
    /// loss bookkeeping — exactly what the journal persists per task
    /// slot. Activates the task first, so the export sees its live
    /// tensors.
    pub fn export_task_state(&mut self, idx: usize) -> Result<TunerState> {
        self.activate(idx);
        self.tuner.export_state()
    }

    /// Restore task `idx` bit-for-bit from an exported state (journal
    /// resume). Validates shapes against the shared model before
    /// touching anything, like the single-task import.
    pub fn import_task_state(&mut self, idx: usize, st: &TunerState) -> Result<()> {
        self.activate(idx);
        self.tuner.import_state(st)
    }

    /// Task `idx`'s adapter in the exact `serve::AdapterStore` format —
    /// N of these out of one shared model is the multi-task serving
    /// story's training half.
    pub fn extract_adapter(&mut self, idx: usize) -> Checkpoint {
        self.activate(idx);
        self.tuner.extract_adapter()
    }

    /// The shared model with task `idx`'s scales/zeros active
    /// (evaluation: `eval::host_perplexity` on a task's view).
    pub fn model(&mut self, idx: usize) -> &PackedModel {
        self.activate(idx);
        self.tuner.model()
    }

    /// Make task `idx` the live state of the shared tuner: swap the
    /// previous task's scale/zero tensors + Adam + step bookkeeping out
    /// and `idx`'s in. O(#projections) pointer swaps of kilobyte f32
    /// tensors — the packed codes never move.
    fn activate(&mut self, idx: usize) {
        assert!(idx < self.slots.len(), "task index {idx} out of {}", self.slots.len());
        if idx == self.active {
            return;
        }
        let MultiTaskTuner { tuner, slots, active } = self;
        for who in [*active, idx] {
            let slot = &mut slots[who];
            for (i, prefix) in tuner.prefixes.iter().enumerate() {
                let m = tuner.model.matrix_mut(prefix).expect("validated at construction");
                std::mem::swap(&mut m.scales, &mut slot.sz[i].0);
                std::mem::swap(&mut m.zeros, &mut slot.sz[i].1);
            }
            std::mem::swap(&mut tuner.opt, &mut slot.opt);
            std::mem::swap(&mut tuner.state, &mut slot.state);
        }
        self.active = idx;
    }
}

// ------------------------------------------------------------ free fns

/// Full-sequence logits of ONE sequence under the training forward,
/// `(tokens.len() · vocab)` row-major — the parity surface the tests
/// compare against `serve::Engine::prefill` (bitwise — same compute
/// core) and the dense `reference_forward` (≤ 1e-4): the model a tuner
/// trains must BE the model the engine serves.
pub fn forward_logits(
    model: &PackedModel,
    geom: &ModelGeom,
    threads: usize,
    tokens: &[u32],
) -> Result<Vec<f32>> {
    if tokens.is_empty() {
        bail!("forward_logits needs at least one token");
    }
    let mut arena = TapeArena::new();
    arena.tokens.clear();
    for &t in tokens {
        let t = t as usize;
        if t >= geom.vocab {
            bail!("token id {t} out of vocab {}", geom.vocab);
        }
        arena.tokens.push(t);
    }
    let t_len = tokens.len();
    forward_tape(model, geom, threads, 1, t_len, false, &mut arena)?;
    arena.logits.truncate(t_len * geom.vocab);
    Ok(arena.logits)
}

/// Masked NLL of one batch under a packed model's forward — the host
/// evaluation primitive shared with `eval::host_perplexity`. Returns
/// `(Σ mask·nll, Σ mask)`. The caller owns the [`TapeArena`] so
/// repeated evaluation (perplexity over many batches) reuses one set of
/// activation slabs instead of allocating per batch.
pub fn batch_nll(
    model: &PackedModel,
    geom: &ModelGeom,
    threads: usize,
    batch: &Batch,
    arena: &mut TapeArena,
) -> Result<(f64, f64)> {
    let (bsz, t_len) = check_batch_into(batch, geom.vocab, &mut arena.tokens)?;
    // Forward-only: no activation tape retained (eval pays for logits,
    // not for backward state).
    forward_tape(model, geom, threads, bsz, t_len, false, arena)?;
    let vocab = geom.vocab;
    let mut sum = 0.0f64;
    let mut count = 0.0f64;
    for b in 0..bsz {
        for t in 0..t_len - 1 {
            let m = batch.mask[b * (t_len - 1) + t];
            if m == 0.0 {
                continue;
            }
            let row = &arena.logits[(b * t_len + t) * vocab..(b * t_len + t + 1) * vocab];
            let target = arena.tokens[b * t_len + t + 1];
            sum += m as f64 * nll_row(row, target);
            count += m as f64;
        }
    }
    Ok((sum, count))
}

fn validate_geom(geom: &ModelGeom) -> Result<()> {
    if geom.vocab == 0 || geom.d_model == 0 || geom.n_layers == 0 || geom.d_ff == 0 {
        bail!("degenerate model geometry {geom:?}");
    }
    if geom.n_heads == 0 || geom.d_model % geom.n_heads != 0 {
        bail!("n_heads {} must divide d_model {}", geom.n_heads, geom.d_model);
    }
    if geom.head_dim() % 2 != 0 {
        bail!("rotary positions need an even head_dim, got {}", geom.head_dim());
    }
    Ok(())
}

/// Validate batch shapes and write the token indices into `tokens`
/// (reused across steps — no per-step allocation).
fn check_batch_into(batch: &Batch, vocab: usize, tokens: &mut Vec<usize>) -> Result<(usize, usize)> {
    let (bsz, t_len) = (batch.batch, batch.seq);
    if t_len < 2 {
        bail!("training needs seq >= 2, got {t_len}");
    }
    if batch.tokens.len() != bsz * t_len {
        bail!("batch tokens {} != {}x{}", batch.tokens.len(), bsz, t_len);
    }
    if batch.mask.len() != bsz * (t_len - 1) {
        bail!("batch mask {} != {}x{}", batch.mask.len(), bsz, t_len - 1);
    }
    tokens.clear();
    tokens.reserve(bsz * t_len);
    for &t in &batch.tokens {
        if t < 0 || t as usize >= vocab {
            bail!("token id {t} out of vocab {vocab}");
        }
        tokens.push(t as usize);
    }
    Ok((bsz, t_len))
}

// ---------------------------------------------------------------- arena

/// Saved activations of one forward layer (all row-major over the
/// `bsz·t_len` concatenated rows, batch-major).
#[derive(Default)]
struct LayerSlabs {
    /// Layer input (residual stream).
    x_in: Vec<f32>,
    /// Post-ln1 rows — input to the q/k/v projections.
    h1: Vec<f32>,
    inv1: Vec<f32>,
    /// Post-rope q/k and raw v.
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    /// Causal softmax probabilities, `(bsz, heads, T, T)` (entries above
    /// the diagonal are never written nor read).
    probs: Vec<f32>,
    /// Attention context rows — input to the o projection.
    ctx: Vec<f32>,
    /// Residual stream after attention — input to ln2.
    x_mid: Vec<f32>,
    h2: Vec<f32>,
    inv2: Vec<f32>,
    /// Pre-activation gate/up and act = silu(gate)·up — input to down.
    gate: Vec<f32>,
    up: Vec<f32>,
    act: Vec<f32>,
}

/// Reusable training arena, modeled on the serving engine's `Scratch`:
/// every activation slab of the training forward (the per-layer tape),
/// every gradient slab of the backward, the per-worker attention
/// scratch, the fused kernel's yᵀ buffer, the rotary table and the
/// resolved per-layer tensor names — grown to the high-water mark once
/// and reused across training steps and eval batches. Buffers hold
/// stale data between calls; every consumer writes its full range
/// before reading, so results are bitwise independent of arena history.
pub struct TapeArena {
    /// Resolved per-layer tensor names (no per-step string formatting).
    names: Vec<LayerNames>,
    /// Rotary table (rebuilt only when head_dim changes).
    freqs: Vec<f32>,
    /// Token indices of the current batch.
    tokens: Vec<usize>,
    /// Per-sequence row spans (`[t_len; bsz]`) for the ragged
    /// projection call.
    spans: Vec<usize>,
    /// Residual stream; holds the final-layer output after a forward.
    x: Vec<f32>,
    /// Per-layer tape slabs. Forward-only mode reuses slab 0 for every
    /// layer (nothing is kept); tape mode uses one slab per layer.
    layers: Vec<LayerSlabs>,
    /// Final-norm output rows (LM-head input) and per-row inverse norms.
    xn: Vec<f32>,
    inv_final: Vec<f32>,
    /// `(bsz·t_len, vocab)` logits of the last forward.
    logits: Vec<f32>,
    /// Shared output slab for the non-taped projection outputs (o, down).
    tmp: Vec<f32>,
    // -- backward slabs --
    dlogits: Vec<f32>,
    dx: Vec<f32>,
    dx2: Vec<f32>,
    dh: Vec<f32>,
    dh_b: Vec<f32>,
    da: Vec<f32>,
    dgate: Vec<f32>,
    dup: Vec<f32>,
    dctx: Vec<f32>,
    dq: Vec<f32>,
    dk: Vec<f32>,
    dv: Vec<f32>,
    /// Per-projection (ds, dz) of the last backward, indexed
    /// `layer·7 + slot` in `prefixes` order.
    grads: Vec<Option<(Tensor, Tensor)>>,
    /// Per-worker attention scratch (forward and backward sharding).
    attn: Vec<AttnScratch>,
    /// Shared kernel scratch (the fused GEMM's yᵀ buffer).
    proj: ProjScratch,
}

impl TapeArena {
    #[allow(clippy::new_without_default)]
    pub fn new() -> TapeArena {
        TapeArena {
            names: Vec::new(),
            freqs: Vec::new(),
            tokens: Vec::new(),
            spans: Vec::new(),
            x: Vec::new(),
            layers: Vec::new(),
            xn: Vec::new(),
            inv_final: Vec::new(),
            logits: Vec::new(),
            tmp: Vec::new(),
            dlogits: Vec::new(),
            dx: Vec::new(),
            dx2: Vec::new(),
            dh: Vec::new(),
            dh_b: Vec::new(),
            da: Vec::new(),
            dgate: Vec::new(),
            dup: Vec::new(),
            dctx: Vec::new(),
            dq: Vec::new(),
            dk: Vec::new(),
            dv: Vec::new(),
            grads: Vec::new(),
            attn: Vec::new(),
            proj: ProjScratch::default(),
        }
    }
}

// -------------------------------------------------------------- forward

/// Full-sequence training forward over `arena.tokens` (`bsz` sequences
/// of `t_len`), entirely through the shared compute core. With
/// `keep_tape` the per-layer activations land in `arena.layers[layer]`
/// for [`backward`]; without it (loss/ppl evaluation,
/// [`forward_logits`]) one slab is reused per layer and the O(T²)
/// probability tensors are never materialized. Logits land in
/// `arena.logits`.
fn forward_tape(
    model: &PackedModel,
    geom: &ModelGeom,
    threads: usize,
    bsz: usize,
    t_len: usize,
    keep_tape: bool,
    arena: &mut TapeArena,
) -> Result<()> {
    let d = geom.d_model;
    let (hh, hd) = (geom.n_heads, geom.head_dim());
    let m = bsz * t_len;
    let TapeArena {
        names, freqs, tokens, spans, x, layers, xn, inv_final, logits, tmp, attn, proj, ..
    } = arena;
    debug_assert_eq!(tokens.len(), m);
    while names.len() < geom.n_layers {
        names.push(LayerNames::new(names.len()));
    }
    if freqs.len() != hd / 2 {
        *freqs = rope_freqs(hd);
    }
    spans.clear();
    spans.resize(bsz, t_len);
    let slabs_needed = if keep_tape { geom.n_layers } else { 1 };
    if layers.len() < slabs_needed {
        layers.resize_with(slabs_needed, LayerSlabs::default);
    }

    let embed = fp(model, "embed")?;
    let ed = embed.data();
    ensure(x, m * d);
    for (r, &tok) in tokens.iter().enumerate() {
        x[r * d..(r + 1) * d].copy_from_slice(&ed[tok * d..(tok + 1) * d]);
    }

    for layer in 0..geom.n_layers {
        let ln = &names[layer];
        let slab = &mut layers[if keep_tape { layer } else { 0 }];
        if keep_tape {
            ensure(&mut slab.x_in, m * d);
            slab.x_in[..m * d].copy_from_slice(&x[..m * d]);
        }
        let g1 = fp(model, &ln.ln1)?.data();
        rms_norm_rows_into(&x[..m * d], g1, m, d, &mut slab.h1, Some(&mut slab.inv1));
        proj_into(model, threads, &ln.q, &slab.h1[..m * d], spans, &mut slab.q, proj)?;
        proj_into(model, threads, &ln.k, &slab.h1[..m * d], spans, &mut slab.k, proj)?;
        proj_into(model, threads, &ln.v, &slab.h1[..m * d], spans, &mut slab.v, proj)?;
        ensure(&mut slab.ctx, m * d);
        let pt = hh * t_len * t_len;
        if keep_tape {
            ensure(&mut slab.probs, bsz * pt);
        }
        attend_all(
            freqs,
            hh,
            hd,
            d,
            t_len,
            bsz,
            threads,
            &mut slab.q[..m * d],
            &mut slab.k[..m * d],
            &slab.v[..m * d],
            &mut slab.ctx[..m * d],
            if keep_tape { Some(&mut slab.probs[..bsz * pt]) } else { None },
            attn,
        );
        // Attention output + residual, then the SwiGLU MLP + residual.
        proj_into(model, threads, &ln.o, &slab.ctx[..m * d], spans, tmp, proj)?;
        for (xv, ov) in x[..m * d].iter_mut().zip(&tmp[..m * d]) {
            *xv += ov;
        }
        if keep_tape {
            ensure(&mut slab.x_mid, m * d);
            slab.x_mid[..m * d].copy_from_slice(&x[..m * d]);
        }
        let g2 = fp(model, &ln.ln2)?.data();
        rms_norm_rows_into(&x[..m * d], g2, m, d, &mut slab.h2, Some(&mut slab.inv2));
        proj_into(model, threads, &ln.gate, &slab.h2[..m * d], spans, &mut slab.gate, proj)?;
        proj_into(model, threads, &ln.up, &slab.h2[..m * d], spans, &mut slab.up, proj)?;
        let mf = m * geom.d_ff;
        swiglu_rows_into(&slab.gate[..mf], &slab.up[..mf], mf, &mut slab.act);
        proj_into(model, threads, &ln.down, &slab.act[..mf], spans, tmp, proj)?;
        for (xv, dv) in x[..m * d].iter_mut().zip(&tmp[..m * d]) {
            *xv += dv;
        }
    }

    let gf = fp(model, "final_norm.g")?.data();
    rms_norm_rows_into(&x[..m * d], gf, m, d, xn, Some(inv_final));
    let head = match model.fp_tensor("lm_head") {
        Some(h) => h,
        None => embed, // tied head
    };
    ensure(logits, m * geom.vocab);
    dense_rows_core(
        head,
        &xn[..m * d],
        m,
        &mut logits[..m * geom.vocab],
        crate::quant::simd::active(),
        &mut proj.kernel,
    );
    Ok(())
}

/// The trainer's attention pass: shard the `bsz` sequences over scoped
/// workers ([`shard_chunks`]), each running [`attend_seq_tape`] (the
/// shared core's full-sequence kernel — rotary + fixed-order causal
/// attention, optional probability tape) per sequence with its own
/// [`AttnScratch`]. Sequences are mutually independent, so results are
/// bitwise identical at any worker count — the same argument as the
/// serving engine's `attend_seq_chunk` sharding.
#[allow(clippy::too_many_arguments)]
fn attend_all(
    freqs: &[f32],
    hh: usize,
    hd: usize,
    d: usize,
    t_len: usize,
    bsz: usize,
    threads: usize,
    q: &mut [f32],
    k: &mut [f32],
    v: &[f32],
    ctx: &mut [f32],
    probs: Option<&mut [f32]>,
    attn: &mut Vec<AttnScratch>,
) {
    let workers = threads.min(bsz).max(1);
    if attn.len() < workers {
        attn.resize_with(workers, AttnScratch::default);
    }
    let pt = hh * t_len * t_len;
    let sd = t_len * d;
    let run_chunk = |b0: usize,
                     take: usize,
                     q_c: &mut [f32],
                     k_c: &mut [f32],
                     ctx_c: &mut [f32],
                     mut p_c: Option<&mut [f32]>,
                     scr: &mut AttnScratch| {
        for si in 0..take {
            let r0 = si * sd;
            let tape = match p_c.as_deref_mut() {
                Some(p) => Tape::Keep(&mut p[si * pt..(si + 1) * pt]),
                None => Tape::None,
            };
            attend_seq_tape(
                freqs,
                hh,
                hd,
                t_len,
                &mut q_c[r0..r0 + sd],
                &mut k_c[r0..r0 + sd],
                &v[(b0 + si) * sd..(b0 + si + 1) * sd],
                &mut ctx_c[r0..r0 + sd],
                scr,
                tape,
            );
        }
    };
    let mut q_rem: &mut [f32] = q;
    let mut k_rem: &mut [f32] = k;
    let mut ctx_rem: &mut [f32] = ctx;
    let mut probs_rem: Option<&mut [f32]> = probs;
    let mut attn_rem: &mut [AttnScratch] = &mut attn[..workers];
    shard_chunks(
        bsz,
        workers,
        |_, take| {
            let (q_c, qr) = std::mem::take(&mut q_rem).split_at_mut(take * sd);
            q_rem = qr;
            let (k_c, kr) = std::mem::take(&mut k_rem).split_at_mut(take * sd);
            k_rem = kr;
            let (ctx_c, xr) = std::mem::take(&mut ctx_rem).split_at_mut(take * sd);
            ctx_rem = xr;
            let p_c = match probs_rem.take() {
                Some(p) => {
                    let (a, b) = p.split_at_mut(take * pt);
                    probs_rem = Some(b);
                    Some(a)
                }
                None => None,
            };
            let (attn_c, ar) = std::mem::take(&mut attn_rem).split_at_mut(1);
            attn_rem = ar;
            (q_c, k_c, ctx_c, p_c, attn_c)
        },
        |start, take, (q_c, k_c, ctx_c, p_c, attn_c)| {
            run_chunk(start, take, q_c, k_c, ctx_c, p_c, &mut attn_c[0]);
        },
    );
}

/// Backward of [`attend_all`]: the same [`shard_chunks`] sequence
/// sharding, each worker running the shared core's
/// [`attend_seq_backward`] per sequence.
/// Bitwise identical at any worker count.
#[allow(clippy::too_many_arguments)]
fn attend_backward_all(
    freqs: &[f32],
    hh: usize,
    hd: usize,
    d: usize,
    t_len: usize,
    bsz: usize,
    threads: usize,
    probs: &[f32],
    q: &[f32],
    k: &[f32],
    v: &[f32],
    dctx: &[f32],
    dq: &mut [f32],
    dk: &mut [f32],
    dv: &mut [f32],
    attn: &mut Vec<AttnScratch>,
) {
    let workers = threads.min(bsz).max(1);
    if attn.len() < workers {
        attn.resize_with(workers, AttnScratch::default);
    }
    let pt = hh * t_len * t_len;
    let sd = t_len * d;
    let run_chunk = |b0: usize,
                     take: usize,
                     dq_c: &mut [f32],
                     dk_c: &mut [f32],
                     dv_c: &mut [f32],
                     scr: &mut AttnScratch| {
        for si in 0..take {
            let b = b0 + si;
            let r0 = si * sd;
            attend_seq_backward(
                freqs,
                hh,
                hd,
                t_len,
                &probs[b * pt..(b + 1) * pt],
                &q[b * sd..(b + 1) * sd],
                &k[b * sd..(b + 1) * sd],
                &v[b * sd..(b + 1) * sd],
                &dctx[b * sd..(b + 1) * sd],
                &mut dq_c[r0..r0 + sd],
                &mut dk_c[r0..r0 + sd],
                &mut dv_c[r0..r0 + sd],
                scr,
            );
        }
    };
    let mut dq_rem: &mut [f32] = dq;
    let mut dk_rem: &mut [f32] = dk;
    let mut dv_rem: &mut [f32] = dv;
    let mut attn_rem: &mut [AttnScratch] = &mut attn[..workers];
    shard_chunks(
        bsz,
        workers,
        |_, take| {
            let (dq_c, qr) = std::mem::take(&mut dq_rem).split_at_mut(take * sd);
            dq_rem = qr;
            let (dk_c, kr) = std::mem::take(&mut dk_rem).split_at_mut(take * sd);
            dk_rem = kr;
            let (dv_c, vr) = std::mem::take(&mut dv_rem).split_at_mut(take * sd);
            dv_rem = vr;
            let (attn_c, ar) = std::mem::take(&mut attn_rem).split_at_mut(1);
            attn_rem = ar;
            (dq_c, dk_c, dv_c, attn_c)
        },
        |start, take, (dq_c, dk_c, dv_c, attn_c)| {
            run_chunk(start, take, dq_c, dk_c, dv_c, &mut attn_c[0]);
        },
    );
}

// ------------------------------------------------------------- backward

fn matrix<'a>(model: &'a PackedModel, prefix: &str) -> Result<&'a PackedMatrix> {
    model.matrix(prefix).ok_or_else(|| anyhow!("no packed projection '{prefix}'"))
}

fn fp<'a>(model: &'a PackedModel, name: &str) -> Result<&'a Tensor> {
    model.fp_tensor(name).ok_or_else(|| anyhow!("packed model missing fp tensor '{name}'"))
}

/// −log softmax(row)[target], numerically stable.
fn nll_row(row: &[f32], target: usize) -> f64 {
    let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut z = 0.0f64;
    for &v in row {
        z += ((v - mx) as f64).exp();
    }
    z.ln() - (row[target] - mx) as f64
}

/// Masked mean cross-entropy and its gradient w.r.t. the logits, into
/// the arena's dlogits slab:
/// dlogits[b,t] = mask[b,t]/Σmask · (softmax(row) − onehot(target)).
/// Rows outside the mask (and every row's stale arena content) are
/// zeroed.
fn loss_and_dlogits_into(
    logits: &[f32],
    tokens: &[usize],
    mask: &[f32],
    bsz: usize,
    t_len: usize,
    vocab: usize,
    dlogits: &mut Vec<f32>,
) -> f32 {
    let n = bsz * t_len * vocab;
    ensure(dlogits, n);
    dlogits[..n].fill(0.0);
    let denom: f32 = mask.iter().sum();
    let mut loss = 0.0f64;
    for b in 0..bsz {
        for t in 0..t_len - 1 {
            let w = mask[b * (t_len - 1) + t];
            if w == 0.0 {
                continue;
            }
            let target = tokens[b * t_len + t + 1];
            let row = &logits[(b * t_len + t) * vocab..(b * t_len + t + 1) * vocab];
            let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            // One exp per logit: stash the numerators in drow while
            // accumulating the denominator, then scale in place.
            let drow = &mut dlogits[(b * t_len + t) * vocab..(b * t_len + t + 1) * vocab];
            let mut z = 0.0f32;
            for (dv, &v) in drow.iter_mut().zip(row) {
                let e = (v - mx).exp();
                *dv = e;
                z += e;
            }
            loss += (w as f64) * ((z as f64).ln() - (row[target] - mx) as f64);
            let scale = w / denom;
            let inv = scale / z;
            for dv in drow.iter_mut() {
                *dv *= inv;
            }
            drow[target] -= scale;
        }
    }
    (loss / denom as f64) as f32
}

/// One projection's backward: dX into `dx_out` (overwritten), the exact
/// (ds, dz) STE reductions recorded at `grads[gi]` — both through the
/// arena's pooled kernel scratch and the active SIMD tier.
#[allow(clippy::too_many_arguments)]
fn proj_back(
    model: &PackedModel,
    threads: usize,
    name: &str,
    gi: usize,
    x_in: &[f32],
    dy: &[f32],
    m: usize,
    dx_out: &mut Vec<f32>,
    grads: &mut [Option<(Tensor, Tensor)>],
    proj: &mut ProjScratch,
) -> Result<()> {
    let pm = matrix(model, name)?;
    let ops = crate::quant::simd::active();
    ensure(dx_out, m * pm.cols);
    pm.grad_input_core(dy, m, threads, &mut dx_out[..m * pm.cols], ops, &mut proj.kernel)?;
    let (ds, dz) = pm.grad_scales_zeros_core(x_in, dy, m, threads, ops, &mut proj.kernel)?;
    grads[gi] = Some((ds, dz));
    Ok(())
}

/// Full reverse-mode backward over the taped forward in `arena`:
/// activation gradients flow through every layer entirely in arena
/// slabs; parameter gradients are collected only for the scale/zero
/// tensors of each packed projection (into `arena.grads`, `prefixes`
/// order).
fn backward(
    model: &PackedModel,
    geom: &ModelGeom,
    threads: usize,
    bsz: usize,
    t_len: usize,
    arena: &mut TapeArena,
) -> Result<()> {
    let d = geom.d_model;
    let (hh, hd) = (geom.n_heads, geom.head_dim());
    let m = bsz * t_len;
    let mf = m * geom.d_ff;
    let TapeArena {
        names,
        freqs,
        x,
        layers,
        xn: _,
        inv_final,
        dlogits,
        dx,
        dx2,
        dh,
        dh_b,
        da,
        dgate,
        dup,
        dctx,
        dq,
        dk,
        dv,
        grads,
        attn,
        proj,
        ..
    } = arena;
    grads.clear();
    grads.resize_with(geom.n_layers * SLOTS, || None);

    // LM head backward: dxn = dlogits · head (head itself is frozen),
    // then the final norm. `x` still holds the last layer's output.
    let head = match model.fp_tensor("lm_head") {
        Some(h) => h,
        None => fp(model, "embed")?,
    };
    ensure(dh, m * d);
    dense_grad_rows_into(head, &dlogits[..m * geom.vocab], m, threads, &mut dh[..m * d]);
    let gf = fp(model, "final_norm.g")?.data();
    rms_backward_into(&dh[..m * d], &x[..m * d], gf, &inv_final[..m], m, d, dx);

    for layer in (0..geom.n_layers).rev() {
        let ln = &names[layer];
        let tp = &layers[layer];
        let g0 = layer * SLOTS;

        // x3 = x_mid + down(act): dx currently holds d(x3).
        proj_back(model, threads, &ln.down, g0 + 6, &tp.act[..mf], &dx[..m * d], m, da, grads, proj)?;
        // act = silu(gate) ⊙ up.
        swiglu_backward_into(&da[..mf], &tp.gate[..mf], &tp.up[..mf], mf, dgate, dup);
        proj_back(model, threads, &ln.gate, g0 + 4, &tp.h2[..m * d], &dgate[..mf], m, dh, grads, proj)?;
        proj_back(model, threads, &ln.up, g0 + 5, &tp.h2[..m * d], &dup[..mf], m, dh_b, grads, proj)?;
        for (a, b) in dh[..m * d].iter_mut().zip(&dh_b[..m * d]) {
            *a += b;
        }
        // x_mid feeds both the residual and ln2.
        let g2 = fp(model, &ln.ln2)?.data();
        rms_backward_into(&dh[..m * d], &tp.x_mid[..m * d], g2, &tp.inv2[..m], m, d, dx2);
        for (a, b) in dx2[..m * d].iter_mut().zip(&dx[..m * d]) {
            *a += b;
        }

        // x_mid = x_in + o(ctx): d(o out) = dx2.
        proj_back(model, threads, &ln.o, g0 + 3, &tp.ctx[..m * d], &dx2[..m * d], m, dctx, grads, proj)?;

        // Attention backward, sharded over sequences (shared core).
        ensure(dq, m * d);
        ensure(dk, m * d);
        ensure(dv, m * d);
        let pt = hh * t_len * t_len;
        attend_backward_all(
            freqs,
            hh,
            hd,
            d,
            t_len,
            bsz,
            threads,
            &tp.probs[..bsz * pt],
            &tp.q[..m * d],
            &tp.k[..m * d],
            &tp.v[..m * d],
            &dctx[..m * d],
            &mut dq[..m * d],
            &mut dk[..m * d],
            &mut dv[..m * d],
            attn,
        );
        proj_back(model, threads, &ln.q, g0, &tp.h1[..m * d], &dq[..m * d], m, dh, grads, proj)?;
        proj_back(model, threads, &ln.k, g0 + 1, &tp.h1[..m * d], &dk[..m * d], m, dh_b, grads, proj)?;
        for (a, b) in dh[..m * d].iter_mut().zip(&dh_b[..m * d]) {
            *a += b;
        }
        proj_back(model, threads, &ln.v, g0 + 2, &tp.h1[..m * d], &dv[..m * d], m, dh_b, grads, proj)?;
        for (a, b) in dh[..m * d].iter_mut().zip(&dh_b[..m * d]) {
            *a += b;
        }
        let g1 = fp(model, &ln.ln1)?.data();
        rms_backward_into(&dh[..m * d], &tp.x_in[..m * d], g1, &tp.inv1[..m], m, d, dx);
        for (a, b) in dx[..m * d].iter_mut().zip(&dx2[..m * d]) {
            *a += b;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve;

    fn tiny_batch(bsz: usize, t_len: usize, vocab: u32, seed: u64) -> Batch {
        let mut rng = crate::util::Pcg32::new(seed);
        Batch {
            tokens: (0..bsz * t_len).map(|_| rng.below(vocab) as i32).collect(),
            mask: vec![1.0; bsz * (t_len - 1)],
            batch: bsz,
            seq: t_len,
        }
    }

    fn tiny_tuner(seed: u64, train_zeros: bool, threads: usize) -> HostPeqaTuner {
        let geom = ModelGeom { vocab: 64, d_model: 16, n_layers: 2, n_heads: 2, d_ff: 32 };
        let (pm, _) = serve::synth_packed(&geom, 4, Some(8), seed).unwrap();
        let cfg = TrainConfig { steps: 8, lr: 2e-3, warmup_steps: 1, log_every: 0, ..Default::default() };
        HostPeqaTuner::from_packed(pm, geom, cfg, train_zeros, threads).unwrap()
    }

    #[test]
    fn forward_loss_is_finite_and_near_uniform_at_init() {
        let mut tuner = tiny_tuner(3, false, 2);
        let batch = tiny_batch(2, 8, 64, 5);
        let loss = tuner.loss(&batch).unwrap();
        // A random quantized model is near-uniform over 64 tokens.
        assert!(loss.is_finite());
        assert!((loss - (64f32).ln()).abs() < 2.0, "loss {loss}");
    }

    #[test]
    fn step_reduces_state_and_counts_only_scales() {
        let mut tuner = tiny_tuner(3, false, 2);
        // 7 projections × 2 layers, per-group scales only.
        let expect: usize = (0..2)
            .flat_map(|_| [16 * 2, 16 * 2, 16 * 2, 16 * 2, 32 * 2, 32 * 2, 16 * 4])
            .sum();
        assert_eq!(tuner.trainable_params(), expect);
        assert_eq!(tuner.trainable_state_bytes(), 3 * 4 * expect as u64);
        let with_z = tiny_tuner(3, true, 2);
        assert_eq!(with_z.trainable_params(), 2 * expect);
        let batch = tiny_batch(2, 8, 64, 5);
        let l0 = tuner.step(&batch).unwrap();
        assert!(l0.is_finite());
        assert_eq!(tuner.step_count(), 1);
        assert_eq!(tuner.losses().len(), 1);
        assert!(tuner.smoothed_loss().is_some());
    }

    #[test]
    fn arena_reuse_is_bitwise_invisible() {
        // Same batch through a fresh tuner vs a tuner whose arena is
        // warm from different-shaped work: identical loss and
        // gradients bit for bit (stale slab content is never read).
        let batch = tiny_batch(2, 8, 64, 5);
        let mut fresh = tiny_tuner(3, true, 2);
        let (l_fresh, g_fresh) = fresh.forward_backward(&batch).unwrap();
        let mut warm = tiny_tuner(3, true, 2);
        // Warm the arena with other shapes (bigger batch, longer seq,
        // forward-only eval) before the probe batch.
        warm.loss(&tiny_batch(3, 12, 64, 9)).unwrap();
        warm.forward_backward(&tiny_batch(1, 4, 64, 11)).unwrap();
        let (l_warm, g_warm) = warm.forward_backward(&batch).unwrap();
        assert_eq!(l_fresh, l_warm);
        assert_eq!(g_fresh.len(), g_warm.len());
        for ((pa, dsa, dza), (pb, dsb, dzb)) in g_fresh.iter().zip(&g_warm) {
            assert_eq!(pa, pb);
            assert_eq!(dsa.data(), dsb.data(), "{pa} ds");
            assert_eq!(dza.data(), dzb.data(), "{pa} dz");
        }
    }

    #[test]
    fn export_import_state_resumes_bitwise() {
        // Uninterrupted: 6 steps. Interrupted: 3 steps, state exported
        // into a FRESH tuner, 3 more steps. Scales, zeros, losses and
        // EMA must match bit for bit — the in-process core of the
        // journal's kill-and-resume guarantee.
        let batches: Vec<Batch> = (0..6).map(|i| tiny_batch(2, 8, 64, 40 + i)).collect();
        let mut full = tiny_tuner(17, true, 2);
        for b in &batches {
            full.step(b).unwrap();
        }
        let mut first = tiny_tuner(17, true, 2);
        for b in &batches[..3] {
            first.step(b).unwrap();
        }
        let st = first.export_state().unwrap();
        drop(first);
        let mut resumed = tiny_tuner(17, true, 2);
        resumed.import_state(&st).unwrap();
        assert_eq!(resumed.step_count(), 3);
        for b in &batches[3..] {
            resumed.step(b).unwrap();
        }
        assert_eq!(resumed.losses(), full.losses());
        assert_eq!(resumed.smoothed_loss(), full.smoothed_loss());
        let a = resumed.extract_adapter();
        let b = full.extract_adapter();
        assert_eq!(a.names(), b.names());
        for (n, t) in a.iter() {
            assert_eq!(t.data(), b.req(n).unwrap().data(), "{n}");
        }
        // Shape-mismatched state is rejected without touching the tuner.
        let mut other = tiny_tuner(17, false, 1); // train_zeros differs
        assert!(other.import_state(&st).is_err());
        assert_eq!(other.step_count(), 0);
        let mut bad = st.clone();
        bad.losses.pop();
        let mut t2 = tiny_tuner(17, true, 1);
        assert!(t2.import_state(&bad).is_err());
    }

    #[test]
    fn malformed_batches_are_rejected() {
        let mut tuner = tiny_tuner(9, false, 1);
        // Out-of-vocab token.
        let mut b = tiny_batch(1, 4, 64, 1);
        b.tokens[0] = 64;
        assert!(tuner.loss(&b).is_err());
        // seq too short.
        let b = Batch { tokens: vec![1], mask: vec![], batch: 1, seq: 1 };
        assert!(tuner.loss(&b).is_err());
        // All-zero mask.
        let mut b = tiny_batch(1, 4, 64, 1);
        b.mask.iter_mut().for_each(|m| *m = 0.0);
        assert!(tuner.forward_backward(&b).is_err());
    }

    #[test]
    fn unquantized_projection_is_rejected_at_construction() {
        let geom = ModelGeom { vocab: 32, d_model: 8, n_layers: 1, n_heads: 2, d_ff: 16 };
        let fp_ck = serve::synth_fp_base(&geom, 1);
        // A dense (never-quantized) model has no packed projections.
        let pm = PackedModel::from_checkpoint(&fp_ck, 4).unwrap();
        let err = HostPeqaTuner::from_packed(
            pm,
            geom,
            TrainConfig::default(),
            false,
            1,
        );
        assert!(err.is_err());
    }

    #[test]
    fn multi_task_switching_is_exact_and_isolated() {
        let geom = ModelGeom { vocab: 64, d_model: 16, n_layers: 2, n_heads: 2, d_ff: 32 };
        let (pm, _) = serve::synth_packed(&geom, 4, Some(8), 13).unwrap();
        let cfg = TrainConfig { steps: 8, lr: 3e-3, warmup_steps: 1, log_every: 0, ..Default::default() };
        let tuner = HostPeqaTuner::from_packed(pm, geom, cfg, false, 2).unwrap();
        let names = vec!["a".to_string(), "b".to_string()];
        let mut mt = MultiTaskTuner::new(tuner, &names).unwrap();
        assert_eq!(mt.n_tasks(), 2);
        let base_a = mt.extract_adapter(0);
        let base_b = mt.extract_adapter(1);
        // Both tasks start from the shared base.
        for (n, t) in base_a.iter() {
            assert_eq!(t.data(), base_b.req(n).unwrap().data());
        }
        // Train only task a on distinct batches; b must stay at base.
        for step in 0..3u64 {
            mt.step_task(0, &tiny_batch(2, 8, 64, 90 + step)).unwrap();
        }
        assert_eq!(mt.step_count(0), 3);
        assert_eq!(mt.step_count(1), 0);
        let tuned_a = mt.extract_adapter(0);
        let still_b = mt.extract_adapter(1);
        let mut moved = 0usize;
        for (n, t) in tuned_a.iter() {
            if t.max_abs_diff(base_a.req(n).unwrap()) > 0.0 {
                moved += 1;
            }
            assert_eq!(
                still_b.req(n).unwrap().data(),
                base_b.req(n).unwrap().data(),
                "task b must be untouched by task a's steps"
            );
        }
        assert_eq!(moved, geom.n_layers * 7, "every projection's scales should move");
        // Switching away and back is exact: adapter a is bitwise stable.
        mt.loss(1, &tiny_batch(2, 8, 64, 70)).unwrap();
        let again_a = mt.extract_adapter(0);
        for (n, t) in tuned_a.iter() {
            assert_eq!(t.data(), again_a.req(n).unwrap().data(), "{n}");
        }
        // Duplicate / empty task lists are rejected.
        let t2 = tiny_tuner(13, false, 1);
        assert!(MultiTaskTuner::new(t2, &["x".into(), "x".into()]).is_err());
        let t3 = tiny_tuner(13, false, 1);
        assert!(MultiTaskTuner::new(t3, &[]).is_err());
    }
}
