//! Shared host optimizer: Adam with bias correction.
//!
//! The xla backend runs AdamW inside the AOT graph; the host backend
//! runs this implementation. For PEQA the parameter set is the scale
//! (and optionally zero-point) vectors only, so `m`/`v` together are a
//! few kilobytes — the optimizer-memory story of the paper's Table 1,
//! reported through [`Adam::state_bytes`]. No weight decay: decaying
//! quantization scales toward zero would collapse the weight magnitudes
//! they carry (the paper fine-tunes s₀+Δs without decay).
//!
//! The update is element-wise and sequential per tensor, so it is
//! trivially bit-identical at any thread count.

/// Adam state over a fixed list of flat parameter tensors.
pub struct Adam {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
}

impl Adam {
    /// Fresh zeroed state for parameters of the given flat sizes.
    pub fn new(sizes: &[usize]) -> Adam {
        Adam {
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            m: sizes.iter().map(|&n| vec![0.0; n]).collect(),
            v: sizes.iter().map(|&n| vec![0.0; n]).collect(),
        }
    }

    pub fn n_params(&self) -> usize {
        self.m.iter().map(Vec::len).sum()
    }

    /// Bytes of optimizer state (m + v, f32).
    pub fn state_bytes(&self) -> u64 {
        2 * 4 * self.n_params() as u64
    }

    /// Clone out the per-slot first/second moments — the optimizer half
    /// of the training journal's full-state records.
    pub fn export_moments(&self) -> (Vec<Vec<f32>>, Vec<Vec<f32>>) {
        (self.m.clone(), self.v.clone())
    }

    /// Restore moments captured by [`Self::export_moments`]. Slot count
    /// and every slot size must match this optimizer's construction —
    /// validated before anything is overwritten, so a failed import
    /// leaves the state untouched.
    pub fn import_moments(&mut self, m: &[Vec<f32>], v: &[Vec<f32>]) -> anyhow::Result<()> {
        if m.len() != self.m.len() || v.len() != self.v.len() {
            anyhow::bail!(
                "optimizer state arity mismatch: restoring {}/{} slots into {}",
                m.len(),
                v.len(),
                self.m.len()
            );
        }
        for (i, (mi, vi)) in m.iter().zip(v).enumerate() {
            if mi.len() != self.m[i].len() || vi.len() != self.m[i].len() {
                anyhow::bail!(
                    "optimizer slot {i} size mismatch: restoring {}/{} into {}",
                    mi.len(),
                    vi.len(),
                    self.m[i].len()
                );
            }
        }
        for (dst, src) in self.m.iter_mut().zip(m) {
            dst.copy_from_slice(src);
        }
        for (dst, src) in self.v.iter_mut().zip(v) {
            dst.copy_from_slice(src);
        }
        Ok(())
    }

    /// One bias-corrected Adam step at (1-based) step `t`:
    /// `p -= lr · m̂ / (√v̂ + ε)`. `params[i]` and `grads[i]` must match
    /// the construction-time size of tensor `i`.
    pub fn step(&mut self, t: usize, lr: f32, params: &mut [&mut [f32]], grads: &[&[f32]]) {
        assert_eq!(params.len(), self.m.len(), "param/state arity");
        assert_eq!(grads.len(), self.m.len(), "grad/state arity");
        for (idx, (p, g)) in params.iter_mut().zip(grads).enumerate() {
            self.step_tensor(idx, t, lr, p, g);
        }
    }

    /// [`Self::step`] for one tensor at state slot `idx` — lets a caller
    /// whose parameters live inside other structures (the packed
    /// matrices' scale/zero tensors) update them one mutable borrow at a
    /// time. All tensors of a step must share the same `t`.
    pub fn step_tensor(&mut self, idx: usize, t: usize, lr: f32, param: &mut [f32], grad: &[f32]) {
        let (m, v) = (&mut self.m[idx], &mut self.v[idx]);
        assert_eq!(param.len(), m.len(), "param size changed under the optimizer");
        assert_eq!(grad.len(), m.len(), "grad size mismatch");
        let bc1 = 1.0 - self.beta1.powi(t as i32);
        let bc2 = 1.0 - self.beta2.powi(t as i32);
        for j in 0..m.len() {
            let gj = grad[j];
            m[j] = self.beta1 * m[j] + (1.0 - self.beta1) * gj;
            v[j] = self.beta2 * v[j] + (1.0 - self.beta2) * gj * gj;
            let mh = m[j] / bc1;
            let vh = v[j] / bc2;
            param[j] -= lr * mh / (vh.sqrt() + self.eps);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adam_descends_a_quadratic() {
        // min ½(p − 3)² from p = 0: gradient p − 3.
        let mut p = vec![0.0f32];
        let mut opt = Adam::new(&[1]);
        for t in 1..=400 {
            let g = vec![p[0] - 3.0];
            opt.step(t, 0.05, &mut [p.as_mut_slice()], &[g.as_slice()]);
        }
        assert!((p[0] - 3.0).abs() < 0.05, "p = {}", p[0]);
        assert_eq!(opt.state_bytes(), 8);
        assert_eq!(opt.n_params(), 1);
    }

    #[test]
    fn moments_export_import_roundtrip_bitwise() {
        let mut p = vec![0.0f32, 1.0, 2.0];
        let mut opt = Adam::new(&[3]);
        for t in 1..=5 {
            let g: Vec<f32> = p.iter().map(|x| x - 3.0).collect();
            opt.step(t, 0.05, &mut [p.as_mut_slice()], &[g.as_slice()]);
        }
        let (m, v) = opt.export_moments();
        // A fresh optimizer with the imported moments continues bitwise
        // identically to the original.
        let mut opt2 = Adam::new(&[3]);
        opt2.import_moments(&m, &v).unwrap();
        let mut p2 = p.clone();
        for t in 6..=8 {
            let g: Vec<f32> = p.iter().map(|x| x - 3.0).collect();
            opt.step(t, 0.05, &mut [p.as_mut_slice()], &[g.as_slice()]);
            let g2: Vec<f32> = p2.iter().map(|x| x - 3.0).collect();
            opt2.step(t, 0.05, &mut [p2.as_mut_slice()], &[g2.as_slice()]);
        }
        assert_eq!(p, p2);
        // Mismatched arity / sizes are rejected before any mutation.
        let mut opt3 = Adam::new(&[2]);
        assert!(opt3.import_moments(&m, &v).is_err());
        let mut opt4 = Adam::new(&[3, 1]);
        assert!(opt4.import_moments(&m, &v).is_err());
    }

    #[test]
    fn first_step_moves_by_about_lr() {
        // Bias correction makes the first update ≈ lr · sign(g).
        let mut p = vec![1.0f32, -2.0];
        let mut opt = Adam::new(&[2]);
        let g = vec![10.0f32, -0.01];
        opt.step(1, 0.1, &mut [p.as_mut_slice()], &[g.as_slice()]);
        assert!((p[0] - 0.9).abs() < 1e-3, "{}", p[0]);
        assert!((p[1] + 1.9).abs() < 1e-3, "{}", p[1]);
    }
}
