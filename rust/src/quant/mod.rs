//! Quantization substrate: RTN (paper Eq. 1), OPTQ/GPTQ baseline, packed
//! sub-4-bit storage, the fused quantized kernel layer, and SPD linear
//! algebra.

pub mod kernels;
pub mod linalg;
pub mod optq;
pub mod pack;
pub mod rtn;
pub mod simd;

pub use kernels::{reference_dequant_matmul, PackedMatrix};
pub use optq::{quantize_optq, weighted_error};
pub use pack::{pack_codes, packed_size, unpack_codes};
pub use rtn::{quantize_rtn, QuantizedMatrix};
