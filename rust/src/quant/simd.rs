//! Runtime-dispatched SIMD primitives for the packed hot loops.
//!
//! One function table ([`SimdOps`]) is chosen once per process and every
//! packed kernel (`quant::kernels`) and dense LM-head kernel
//! (`model::blocks`) routes its inner loop through it:
//!
//! * **scalar** — the verbatim baseline loops. `lanes == 1`; the packed
//!   kernels keep their original contiguous code on this tier, so scalar
//!   dispatch is byte-for-byte the pre-SIMD implementation.
//! * **avx2** — x86_64, selected at runtime via
//!   `is_x86_feature_detected!("avx2")`. 8 f32 lanes.
//! * **neon** — aarch64, where NEON is a baseline ISA feature. 4 f32 lanes.
//!
//! # The bitwise contract
//!
//! Every SIMD path must produce results **bitwise identical** to the scalar
//! baseline — the whole parity/invariance test suite (and the serve/train
//! bitwise contracts built on it) inherits this. Two rules make that hold:
//!
//! 1. **Vectorize across independent output elements, never across a
//!    reduction.** Each vector lane owns one output element's accumulator
//!    and steps the reduction index `j` in the same order as the scalar
//!    loop. No horizontal adds, no multi-accumulator splitting.
//! 2. **Separate multiply and add, never FMA.** The scalar loops round
//!    after the multiply and again after the add; a fused multiply-add
//!    rounds once and diverges in the last ulp. The ~2× FMA throughput is
//!    deliberately left on the table to keep f32 results bit-exact.
//!
//! Dispatch is resolved once ([`active`], honouring `PEQA_SIMD=scalar|auto`)
//! and cached; tests drive [`scalar`] vs [`detected`] explicitly so both
//! tiers are exercised in one process.
//!
//! # 2-bit fast unpack
//!
//! [`unpack2_into_f32`] specializes the code-tile unpack for the 2-bit
//! width with a u64 multiply-spread: one 16-bit load holds 8 codes, and two
//! masked multiplies fan them out to one byte each in a u64
//! (see [`spread8`]). A popcount identity over the packed word
//! ([`sum2_codes`]) cross-checks the expansion in debug builds. A full
//! popcount *dot* restructuring (counting code-bit/sign agreements) would
//! be faster still but changes the reduction order, so it is deliberately
//! not used on the f32 path.

use crate::quant::pack;
use std::sync::OnceLock;

/// Widest lane count any tier uses — size stack-allocated accumulator
/// blocks (`[f32; MAX_LANES]`) with this.
pub const MAX_LANES: usize = 8;

/// The per-process kernel function table. Fields are function pointers so
/// the choice is data, not branches, on the hot path.
pub struct SimdOps {
    /// Tier name as recorded in benches: `"scalar"`, `"avx2"`, `"neon"`.
    pub name: &'static str,
    /// f32 lanes per vector op (1 for the scalar tier).
    pub lanes: usize,
    dot_lanes_impl: fn(&mut [f32], &[f32], &[f32], usize),
    axpy_impl: fn(&mut [f32], f32, &[f32]),
    axpy_sub_impl: fn(&mut [f32], f32, f32, &[f32]),
}

impl SimdOps {
    /// Lane-parallel dot: `dots[l] += Σ_j mat[j·stride + l] · vec[j]`,
    /// `j` ascending — each lane is one output element's accumulator and
    /// sees the exact scalar mul-then-add rounding sequence. `dots.len()`
    /// may be anything up to [`MAX_LANES`]; partial blocks fall back to a
    /// per-lane scalar loop with identical per-lane order.
    #[inline]
    pub fn dot_lanes(&self, dots: &mut [f32], mat: &[f32], vec: &[f32], stride: usize) {
        assert!(
            dots.is_empty()
                || vec.is_empty()
                || mat.len() >= (vec.len() - 1) * stride + dots.len(),
            "dot_lanes: mat too short for {} lanes × {} steps at stride {stride}",
            dots.len(),
            vec.len(),
        );
        (self.dot_lanes_impl)(dots, mat, vec, stride);
    }

    /// `y[i] += a · x[i]` — element-independent, so any lane width is
    /// bitwise-equal to the scalar loop.
    #[inline]
    pub fn axpy(&self, y: &mut [f32], a: f32, x: &[f32]) {
        assert_eq!(y.len(), x.len(), "axpy: length mismatch");
        (self.axpy_impl)(y, a, x);
    }

    /// `y[i] += a · (x[i] − z)` — the `grad_input` inner update. The
    /// per-element op sequence (sub, mul, add) matches the scalar loop.
    #[inline]
    pub fn axpy_sub(&self, y: &mut [f32], a: f32, z: f32, x: &[f32]) {
        assert_eq!(y.len(), x.len(), "axpy_sub: length mismatch");
        (self.axpy_sub_impl)(y, a, z, x);
    }
}

// ---------------------------------------------------------------- scalar

fn dot_lanes_scalar(dots: &mut [f32], mat: &[f32], vec: &[f32], stride: usize) {
    for (l, d) in dots.iter_mut().enumerate() {
        let mut acc = *d;
        for (j, &v) in vec.iter().enumerate() {
            acc += mat[j * stride + l] * v;
        }
        *d = acc;
    }
}

fn axpy_scalar(y: &mut [f32], a: f32, x: &[f32]) {
    for (yi, &xi) in y.iter_mut().zip(x.iter()) {
        *yi += a * xi;
    }
}

fn axpy_sub_scalar(y: &mut [f32], a: f32, z: f32, x: &[f32]) {
    for (yi, &xi) in y.iter_mut().zip(x.iter()) {
        *yi += a * (xi - z);
    }
}

static SCALAR: SimdOps = SimdOps {
    name: "scalar",
    lanes: 1,
    dot_lanes_impl: dot_lanes_scalar,
    axpy_impl: axpy_scalar,
    axpy_sub_impl: axpy_sub_scalar,
};

// ----------------------------------------------------------------- avx2

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use core::arch::x86_64::{
        _mm256_add_ps, _mm256_loadu_ps, _mm256_mul_ps, _mm256_set1_ps, _mm256_storeu_ps,
        _mm256_sub_ps,
    };

    pub(super) const LANES: usize = 8;

    // SAFETY: `unsafe fn` because of `#[target_feature]` — callers must
    // ensure the host supports AVX2; the only callers are the shims below,
    // reachable solely through the table installed by `detected()` after
    // `is_x86_feature_detected!("avx2")`. All loads/stores stay inside the
    // slice bounds the `SimdOps` wrappers assert before dispatch.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn dot_lanes(dots: &mut [f32], mat: &[f32], vec: &[f32], stride: usize) {
        if dots.len() != LANES {
            super::dot_lanes_scalar(dots, mat, vec, stride);
            return;
        }
        let mp = mat.as_ptr();
        let mut acc = _mm256_loadu_ps(dots.as_ptr());
        for (j, &v) in vec.iter().enumerate() {
            let m = _mm256_loadu_ps(mp.add(j * stride));
            // mul then add — NOT fmadd; see the bitwise contract.
            acc = _mm256_add_ps(acc, _mm256_mul_ps(m, _mm256_set1_ps(v)));
        }
        _mm256_storeu_ps(dots.as_mut_ptr(), acc);
    }

    // SAFETY: `unsafe fn` because of `#[target_feature]` — same AVX2
    // precondition and in-bounds argument as `dot_lanes` above.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
        let n = y.len();
        let yp = y.as_mut_ptr();
        let xp = x.as_ptr();
        let av = _mm256_set1_ps(a);
        let mut i = 0usize;
        while i + LANES <= n {
            let yv = _mm256_loadu_ps(yp.add(i));
            let xv = _mm256_loadu_ps(xp.add(i));
            _mm256_storeu_ps(yp.add(i), _mm256_add_ps(yv, _mm256_mul_ps(av, xv)));
            i += LANES;
        }
        while i < n {
            y[i] += a * x[i];
            i += 1;
        }
    }

    // SAFETY: `unsafe fn` because of `#[target_feature]` — same AVX2
    // precondition and in-bounds argument as `dot_lanes` above.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn axpy_sub(y: &mut [f32], a: f32, z: f32, x: &[f32]) {
        let n = y.len();
        let yp = y.as_mut_ptr();
        let xp = x.as_ptr();
        let av = _mm256_set1_ps(a);
        let zv = _mm256_set1_ps(z);
        let mut i = 0usize;
        while i + LANES <= n {
            let yv = _mm256_loadu_ps(yp.add(i));
            let xv = _mm256_loadu_ps(xp.add(i));
            // sub, mul, add — the exact scalar op sequence per element.
            let t = _mm256_mul_ps(av, _mm256_sub_ps(xv, zv));
            _mm256_storeu_ps(yp.add(i), _mm256_add_ps(yv, t));
            i += LANES;
        }
        while i < n {
            y[i] += a * (x[i] - z);
            i += 1;
        }
    }
}

#[cfg(target_arch = "x86_64")]
fn dot_lanes_avx2(dots: &mut [f32], mat: &[f32], vec: &[f32], stride: usize) {
    // SAFETY: only reachable through the AVX2 table, which `detected()`
    // installs after `is_x86_feature_detected!("avx2")` returned true;
    // bounds were asserted by the `SimdOps::dot_lanes` wrapper.
    unsafe { avx2::dot_lanes(dots, mat, vec, stride) }
}

#[cfg(target_arch = "x86_64")]
fn axpy_avx2(y: &mut [f32], a: f32, x: &[f32]) {
    // SAFETY: AVX2 presence established by `detected()` (see above);
    // lengths were asserted equal by the `SimdOps::axpy` wrapper.
    unsafe { avx2::axpy(y, a, x) }
}

#[cfg(target_arch = "x86_64")]
fn axpy_sub_avx2(y: &mut [f32], a: f32, z: f32, x: &[f32]) {
    // SAFETY: AVX2 presence established by `detected()` (see above);
    // lengths were asserted equal by the `SimdOps::axpy_sub` wrapper.
    unsafe { avx2::axpy_sub(y, a, z, x) }
}

#[cfg(target_arch = "x86_64")]
static AVX2: SimdOps = SimdOps {
    name: "avx2",
    lanes: avx2::LANES,
    dot_lanes_impl: dot_lanes_avx2,
    axpy_impl: axpy_avx2,
    axpy_sub_impl: axpy_sub_avx2,
};

// ----------------------------------------------------------------- neon

#[cfg(target_arch = "aarch64")]
mod neon {
    use core::arch::aarch64::{vaddq_f32, vdupq_n_f32, vld1q_f32, vmulq_f32, vst1q_f32, vsubq_f32};

    pub(super) const LANES: usize = 4;

    // SAFETY: `unsafe fn` because the intrinsics are; NEON is a baseline
    // aarch64 feature so there is no runtime precondition beyond the
    // in-bounds arguments the `SimdOps` wrappers assert before dispatch.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn dot_lanes(dots: &mut [f32], mat: &[f32], vec: &[f32], stride: usize) {
        if dots.len() != LANES {
            super::dot_lanes_scalar(dots, mat, vec, stride);
            return;
        }
        let mp = mat.as_ptr();
        let mut acc = vld1q_f32(dots.as_ptr());
        for (j, &v) in vec.iter().enumerate() {
            let m = vld1q_f32(mp.add(j * stride));
            // mul then add — NOT vfmaq; see the bitwise contract.
            acc = vaddq_f32(acc, vmulq_f32(m, vdupq_n_f32(v)));
        }
        vst1q_f32(dots.as_mut_ptr(), acc);
    }

    // SAFETY: `unsafe fn` because the intrinsics are; same in-bounds
    // argument as `dot_lanes` above.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
        let n = y.len();
        let yp = y.as_mut_ptr();
        let xp = x.as_ptr();
        let av = vdupq_n_f32(a);
        let mut i = 0usize;
        while i + LANES <= n {
            let yv = vld1q_f32(yp.add(i));
            let xv = vld1q_f32(xp.add(i));
            vst1q_f32(yp.add(i), vaddq_f32(yv, vmulq_f32(av, xv)));
            i += LANES;
        }
        while i < n {
            y[i] += a * x[i];
            i += 1;
        }
    }

    // SAFETY: `unsafe fn` because the intrinsics are; same in-bounds
    // argument as `dot_lanes` above.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn axpy_sub(y: &mut [f32], a: f32, z: f32, x: &[f32]) {
        let n = y.len();
        let yp = y.as_mut_ptr();
        let xp = x.as_ptr();
        let av = vdupq_n_f32(a);
        let zv = vdupq_n_f32(z);
        let mut i = 0usize;
        while i + LANES <= n {
            let yv = vld1q_f32(yp.add(i));
            let xv = vld1q_f32(xp.add(i));
            let t = vmulq_f32(av, vsubq_f32(xv, zv));
            vst1q_f32(yp.add(i), vaddq_f32(yv, t));
            i += LANES;
        }
        while i < n {
            y[i] += a * (x[i] - z);
            i += 1;
        }
    }
}

#[cfg(target_arch = "aarch64")]
fn dot_lanes_neon(dots: &mut [f32], mat: &[f32], vec: &[f32], stride: usize) {
    // SAFETY: NEON is baseline on aarch64; bounds were asserted by the
    // `SimdOps::dot_lanes` wrapper.
    unsafe { neon::dot_lanes(dots, mat, vec, stride) }
}

#[cfg(target_arch = "aarch64")]
fn axpy_neon(y: &mut [f32], a: f32, x: &[f32]) {
    // SAFETY: NEON is baseline on aarch64; lengths were asserted equal by
    // the `SimdOps::axpy` wrapper.
    unsafe { neon::axpy(y, a, x) }
}

#[cfg(target_arch = "aarch64")]
fn axpy_sub_neon(y: &mut [f32], a: f32, z: f32, x: &[f32]) {
    // SAFETY: NEON is baseline on aarch64; lengths were asserted equal by
    // the `SimdOps::axpy_sub` wrapper.
    unsafe { neon::axpy_sub(y, a, z, x) }
}

#[cfg(target_arch = "aarch64")]
static NEON: SimdOps = SimdOps {
    name: "neon",
    lanes: neon::LANES,
    dot_lanes_impl: dot_lanes_neon,
    axpy_impl: axpy_neon,
    axpy_sub_impl: axpy_sub_neon,
};

// -------------------------------------------------------------- dispatch

/// The verbatim scalar baseline — what every parity test compares against.
pub fn scalar() -> &'static SimdOps {
    &SCALAR
}

/// Best tier this host supports, ignoring the `PEQA_SIMD` override.
pub fn detected() -> &'static SimdOps {
    #[cfg(target_arch = "x86_64")]
    if is_x86_feature_detected!("avx2") {
        return &AVX2;
    }
    #[cfg(target_arch = "aarch64")]
    return &NEON;
    #[cfg(not(target_arch = "aarch64"))]
    &SCALAR
}

/// Resolve a preference string (the `PEQA_SIMD` value) to a tier:
/// `"scalar"` forces the baseline; `"auto"`, unset, or anything else uses
/// [`detected`].
pub fn resolve(pref: Option<&str>) -> &'static SimdOps {
    match pref {
        Some("scalar") => &SCALAR,
        _ => detected(),
    }
}

/// The process-wide table: `resolve(PEQA_SIMD)`, computed once and cached.
/// In-process tests that need both tiers call [`scalar`]/[`detected`]
/// directly instead of re-reading the environment.
pub fn active() -> &'static SimdOps {
    static ACTIVE: OnceLock<&'static SimdOps> = OnceLock::new();
    ACTIVE.get_or_init(|| {
        let pref = std::env::var("PEQA_SIMD").ok();
        resolve(pref.as_deref())
    })
}

// --------------------------------------------------- 2-bit fast unpack

/// Spread 8 two-bit codes (one 16-bit little-endian load) into one byte
/// each of a u64, in order: byte `c` of the result is code `c`.
///
/// Two masked multiplies do all eight extractions: the even codes
/// `e = h & 0x3333` multiplied by `M = 1 + 2^12 + 2^24 + 2^36` place code
/// `2c` at bit `16c` (the shifted copies overlap, but each 2-bit field's
/// carries die in the zeroed gap bits below the next kept position, so the
/// mask `K` recovers clean fields), and likewise for the odd codes shifted
/// up one byte.
#[inline]
pub fn spread8(h: u16) -> u64 {
    const M: u64 = 1 | 1 << 12 | 1 << 24 | 1 << 36;
    const K: u64 = 0x0003_0003_0003_0003;
    let e = (h & 0x3333) as u64;
    let o = ((h >> 2) & 0x3333) as u64;
    (e.wrapping_mul(M) & K) | ((o.wrapping_mul(M) & K) << 8)
}

/// Sum of the 2-bit codes in a packed word — a popcount identity
/// (`Σ codes = popcount(even bits) + 2·popcount(odd bits)`) used to
/// cross-check [`spread8`] expansions in debug builds.
#[inline]
pub fn sum2_codes(w: u64) -> u32 {
    const EVEN: u64 = 0x5555_5555_5555_5555;
    (w & EVEN).count_ones() + 2 * ((w >> 1) & EVEN).count_ones()
}

/// 2-bit specialization of [`pack::unpack_into_f32`]: expands 8 codes per
/// 16-bit load via [`spread8`] instead of walking a 64-bit shift register.
/// Byte-unaligned starts (start % 4 ≠ 0) fall back to the generic path;
/// kernel group starts are always multiples of the group size, which the
/// packed formats keep byte-aligned in practice.
#[inline]
pub fn unpack2_into_f32(packed: &[u8], start: usize, out: &mut [f32]) {
    if start % 4 != 0 {
        pack::unpack_into_f32(packed, 2, start, out);
        return;
    }
    let n = out.len();
    let mut byte = start / 4;
    let mut i = 0usize;
    while i < n {
        let b0 = packed.get(byte).copied().unwrap_or(0);
        let b1 = packed.get(byte + 1).copied().unwrap_or(0);
        let h = u16::from_le_bytes([b0, b1]);
        let w = spread8(h);
        debug_assert_eq!(
            sum2_codes(h as u64),
            w.to_le_bytes().iter().map(|&b| b as u32).sum::<u32>(),
            "spread8 expansion lost codes"
        );
        let bytes = w.to_le_bytes();
        let take = (n - i).min(8);
        for (o, &b) in out[i..i + take].iter_mut().zip(bytes.iter()) {
            *o = b as f32;
        }
        i += take;
        byte += 2;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    fn rand_f32s(rng: &mut Pcg32, n: usize) -> Vec<f32> {
        (0..n).map(|_| (rng.next_u32() as f32 / u32::MAX as f32) * 2.0 - 1.0).collect()
    }

    fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
        assert_eq!(a.len(), b.len(), "{what}: length");
        for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{what}: element {i}: {x} vs {y}");
        }
    }

    #[test]
    fn dot_lanes_detected_is_bitwise_equal_to_scalar() {
        let mut rng = Pcg32::new(41);
        let det = detected();
        let sc = scalar();
        for &steps in &[0usize, 1, 3, 7, 16, 33, 127] {
            for &nl in &[1usize, 2, 3, 4, 5, 7, 8] {
                let stride = nl + (steps % 3); // lanes packed tighter or looser
                let mat = rand_f32s(&mut rng, steps.saturating_sub(1) * stride + nl + 4);
                let vecv = rand_f32s(&mut rng, steps);
                let init = rand_f32s(&mut rng, nl);
                let mut a = init.clone();
                let mut b = init.clone();
                sc.dot_lanes(&mut a, &mat, &vecv, stride);
                det.dot_lanes(&mut b, &mat, &vecv, stride);
                assert_bits_eq(&a, &b, &format!("dot_lanes steps={steps} nl={nl}"));
            }
        }
    }

    #[test]
    fn axpy_variants_detected_are_bitwise_equal_to_scalar() {
        let mut rng = Pcg32::new(43);
        let det = detected();
        let sc = scalar();
        for &n in &[0usize, 1, 3, 4, 7, 8, 9, 31, 64, 65] {
            let x = rand_f32s(&mut rng, n);
            let init = rand_f32s(&mut rng, n);
            let a = 0.731f32;
            let z = -0.25f32;
            let (mut y1, mut y2) = (init.clone(), init.clone());
            sc.axpy(&mut y1, a, &x);
            det.axpy(&mut y2, a, &x);
            assert_bits_eq(&y1, &y2, &format!("axpy n={n}"));
            let (mut y1, mut y2) = (init.clone(), init.clone());
            sc.axpy_sub(&mut y1, a, z, &x);
            det.axpy_sub(&mut y2, a, z, &x);
            assert_bits_eq(&y1, &y2, &format!("axpy_sub n={n}"));
        }
    }

    #[test]
    fn resolver_honours_scalar_and_defaults_to_detected() {
        assert_eq!(resolve(Some("scalar")).name, "scalar");
        assert_eq!(resolve(Some("scalar")).lanes, 1);
        assert_eq!(resolve(None).name, detected().name);
        assert_eq!(resolve(Some("auto")).name, detected().name);
        // Unknown values fall through to auto rather than failing a run.
        assert_eq!(resolve(Some("avx512-someday")).name, detected().name);
        // The cached process-wide choice is one of the known tiers.
        assert!(matches!(active().name, "scalar" | "avx2" | "neon"));
        assert!(active().lanes >= 1 && active().lanes <= MAX_LANES);
    }

    #[test]
    fn spread8_matches_bit_extraction_exhaustively() {
        for h in 0..=u16::MAX {
            let bytes = spread8(h).to_le_bytes();
            for (c, &b) in bytes.iter().enumerate() {
                assert_eq!(b, ((h >> (2 * c)) & 0x3) as u8, "h={h:#06x} code {c}");
            }
        }
    }

    #[test]
    fn sum2_codes_matches_naive_sum() {
        let mut rng = Pcg32::new(47);
        for _ in 0..200 {
            let w = ((rng.next_u32() as u64) << 32) | rng.next_u32() as u64;
            let naive: u32 = (0..32).map(|c| ((w >> (2 * c)) & 0x3) as u32).sum();
            assert_eq!(sum2_codes(w), naive, "w={w:#018x}");
        }
    }

    #[test]
    fn unpack2_matches_generic_unpack() {
        let mut rng = Pcg32::new(53);
        let n = 300usize;
        let codes: Vec<u8> = (0..n).map(|_| (rng.next_u32() & 0x3) as u8).collect();
        let packed = pack::pack_codes(&codes, 2);
        // Aligned starts (the kernel case), unaligned starts (fallback),
        // tail lengths that end mid-halfword and past the stream.
        for &(start, len) in
            &[(0usize, n), (0, 7), (4, 16), (8, 33), (12, 5), (1, 10), (3, 21), (n - 2, 2)]
        {
            let mut fast = vec![-1.0f32; len];
            let mut slow = vec![-2.0f32; len];
            unpack2_into_f32(&packed, start, &mut fast);
            pack::unpack_into_f32(&packed, 2, start, &mut slow);
            assert_bits_eq(&fast, &slow, &format!("unpack2 start={start} len={len}"));
        }
    }

    #[test]
    fn dot_lanes_wrapper_rejects_short_mat() {
        let res = std::panic::catch_unwind(|| {
            let mut dots = [0.0f32; 4];
            scalar().dot_lanes(&mut dots, &[1.0; 5], &[1.0; 3], 4);
        });
        assert!(res.is_err(), "short mat must be rejected before dispatch");
    }
}
