//! Round-to-nearest asymmetric quantization (paper Eq. 1) — rust mirror of
//! the Pallas kernel `python/compile/kernels/quantize.py`.
//!
//! Used on the deployment path (`peqa quantize`, PTQ of LoRA-merged
//! checkpoints) so quantization never needs Python at runtime. Bit-exact
//! agreement with the Pallas kernel is asserted by an integration test
//! that executes the `kernel_rtn_256` artifact on the same input.

use anyhow::{bail, Result};

use crate::tensor::Tensor;

/// Same degenerate-group guard as kernels/ref.py.
pub const EPS: f32 = 1e-8;

/// Quantized representation of one weight matrix: unsigned integer codes
/// plus per-(channel, group) scales and zero-points. `Ŵ = s · (codes − z)`.
#[derive(Clone, Debug)]
pub struct QuantizedMatrix {
    /// Codes in [0, 2^bits − 1], stored unpacked (one f32-exact int per u8).
    pub codes: Vec<u8>,
    pub scales: Tensor,  // (n, G)
    pub zeros: Tensor,   // (n, G)
    pub rows: usize,
    pub cols: usize,
    pub bits: u8,
    pub group: usize,    // cols for per-channel
}

impl QuantizedMatrix {
    pub fn n_groups(&self) -> usize {
        self.cols / self.group
    }

    /// Ŵ = s · (codes − z) as a dense tensor (rust mirror of dequant_ref),
    /// via the fused row-parallel kernel (quant::kernels).
    pub fn dequantize(&self) -> Tensor {
        super::kernels::dequantize_codes(
            &self.codes, &self.scales, &self.zeros, self.rows, self.cols, self.group,
        )
    }

    /// Dequantize with *replacement* scales/zeros — this is PEQA task
    /// switching: the shared integer matrix stays, only s/z swap. The code
    /// buffer is borrowed, never cloned (task switching is the cheap path).
    pub fn dequantize_with(&self, scales: &Tensor, zeros: &Tensor) -> Tensor {
        super::kernels::dequantize_codes(
            &self.codes, scales, zeros, self.rows, self.cols, self.group,
        )
    }
}

/// Quantize a (n, m) weight matrix; `group == None` means per-channel.
pub fn quantize_rtn(w: &Tensor, bits: u8, group: Option<usize>) -> Result<QuantizedMatrix> {
    let (n, m) = w.dims2()?;
    let g = group.unwrap_or(m);
    if m % g != 0 {
        bail!("group {g} must divide cols {m}");
    }
    if !(2..=8).contains(&bits) {
        bail!("bits must be in 2..=8, got {bits}");
    }
    let ng = m / g;
    let qmax = ((1u32 << bits) - 1) as f32;
    let mut codes = vec![0u8; n * m];
    let mut scales = Tensor::zeros(&[n, ng]);
    let mut zeros = Tensor::zeros(&[n, ng]);
    for i in 0..n {
        for k in 0..ng {
            let row = &w.data()[i * m + k * g..i * m + (k + 1) * g];
            // Zero forced into range — matches kernels/ref.py.
            let mut wmin = 0.0f32;
            let mut wmax = 0.0f32;
            for &x in row {
                wmin = wmin.min(x);
                wmax = wmax.max(x);
            }
            let s = ((wmax - wmin) / qmax).max(EPS);
            let z = (-wmin / s).round().clamp(0.0, qmax);
            scales.set2(i, k, s);
            zeros.set2(i, k, z);
            for (j, &x) in row.iter().enumerate() {
                let q = ((x / s).round() + z).clamp(0.0, qmax);
                codes[i * m + k * g + j] = q as u8;
            }
        }
    }
    Ok(QuantizedMatrix { codes, scales, zeros, rows: n, cols: m, bits, group: g })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    fn rand_w(n: usize, m: usize, seed: u64) -> Tensor {
        let mut rng = Pcg32::new(seed);
        Tensor::normal(&[n, m], 0.5, &mut rng)
    }

    #[test]
    fn reconstruction_error_bounded_by_scale() {
        for (bits, group) in [(4u8, None), (3, None), (4, Some(16)), (2, Some(8))] {
            let w = rand_w(16, 32, 7);
            let q = quantize_rtn(&w, bits, group).unwrap();
            let wh = q.dequantize();
            let g = q.group;
            for i in 0..16 {
                for k in 0..q.n_groups() {
                    let s = q.scales.at2(i, k);
                    for j in 0..g {
                        let col = k * g + j;
                        let err = (w.at2(i, col) - wh.at2(i, col)).abs();
                        assert!(err <= s + 1e-6, "bits={bits} err={err} s={s}");
                    }
                }
            }
        }
    }

    #[test]
    fn codes_in_range_and_error_decreases_with_bits() {
        let w = rand_w(32, 64, 3);
        let mut errs = vec![];
        for bits in [2u8, 3, 4, 8] {
            let q = quantize_rtn(&w, bits, None).unwrap();
            assert!(q.codes.iter().all(|&c| (c as u32) < (1 << bits)));
            errs.push((w.data().iter().zip(q.dequantize().data()))
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f32>());
        }
        for i in 1..errs.len() {
            assert!(errs[i] < errs[i - 1], "{errs:?}");
        }
    }

    #[test]
    fn grouping_reduces_error() {
        // Smaller groups = more scales = lower reconstruction error
        // (the Table 5 premise).
        let w = rand_w(16, 64, 11);
        let e = |group: Option<usize>| {
            let q = quantize_rtn(&w, 3, group).unwrap();
            let wh = q.dequantize();
            w.data().iter().zip(wh.data()).map(|(a, b)| (a - b) * (a - b)).sum::<f32>()
        };
        let (e_chan, e_g32, e_g16, e_g8) = (e(None), e(Some(32)), e(Some(16)), e(Some(8)));
        assert!(e_g32 <= e_chan && e_g16 <= e_g32 && e_g8 <= e_g16,
                "{e_chan} {e_g32} {e_g16} {e_g8}");
    }

    #[test]
    fn idempotent() {
        let w = rand_w(8, 16, 5);
        let q1 = quantize_rtn(&w, 4, None).unwrap();
        let q2 = quantize_rtn(&q1.dequantize(), 4, None).unwrap();
        assert!(q1.dequantize().max_abs_diff(&q2.dequantize()) < 1e-5);
    }

    #[test]
    fn constant_rows_reconstruct_exactly() {
        let w = Tensor::full(&[4, 16], 0.75);
        let q = quantize_rtn(&w, 4, Some(8)).unwrap();
        assert!(q.dequantize().max_abs_diff(&w) < 1e-6);
    }

    #[test]
    fn task_switch_is_scale_swap() {
        let w = rand_w(8, 16, 9);
        let q = quantize_rtn(&w, 4, None).unwrap();
        let mut s2 = q.scales.clone();
        for v in s2.data_mut() {
            *v *= 1.5;
        }
        let wh2 = q.dequantize_with(&s2, &q.zeros);
        let wh1 = q.dequantize();
        for (a, b) in wh1.data().iter().zip(wh2.data()) {
            // Ŵ scales linearly in s around the (shared) zero-point.
            assert!((b - 1.5 * a).abs() < 1e-5);
        }
    }

    #[test]
    fn invalid_inputs_rejected() {
        let w = Tensor::zeros(&[4, 10]);
        assert!(quantize_rtn(&w, 4, Some(3)).is_err());
        assert!(quantize_rtn(&w, 1, None).is_err());
        assert!(quantize_rtn(&w, 9, None).is_err());
    }
}
