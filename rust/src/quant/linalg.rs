//! Dense symmetric-positive-definite linear algebra for OPTQ: Cholesky
//! factorization, triangular solves, SPD inversion. f64 throughout — the
//! Hessians OPTQ consumes are ill-conditioned enough that f32 Cholesky
//! visibly degrades the quantization.

use anyhow::{bail, Result};

/// Row-major square matrix of f64.
#[derive(Clone, Debug)]
pub struct MatF64 {
    pub n: usize,
    pub a: Vec<f64>,
}

impl MatF64 {
    pub fn zeros(n: usize) -> Self {
        MatF64 { n, a: vec![0.0; n * n] }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n);
        for i in 0..n {
            m.a[i * n + i] = 1.0;
        }
        m
    }

    pub fn from_f32(n: usize, data: &[f32]) -> Self {
        assert_eq!(data.len(), n * n);
        MatF64 { n, a: data.iter().map(|&x| x as f64).collect() }
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f64 {
        self.a[i * self.n + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.a[i * self.n + j] = v;
    }

    pub fn transpose(&self) -> MatF64 {
        let n = self.n;
        let mut t = MatF64::zeros(n);
        for i in 0..n {
            for j in 0..n {
                t.a[j * n + i] = self.a[i * n + j];
            }
        }
        t
    }

    pub fn matmul(&self, b: &MatF64) -> MatF64 {
        let n = self.n;
        let mut c = MatF64::zeros(n);
        for i in 0..n {
            for k in 0..n {
                let aik = self.a[i * n + k];
                if aik == 0.0 {
                    continue;
                }
                for j in 0..n {
                    c.a[i * n + j] += aik * b.a[k * n + j];
                }
            }
        }
        c
    }
}

/// Lower Cholesky factor L with A = L·Lᵀ. Fails on non-PD input.
pub fn cholesky_lower(a: &MatF64) -> Result<MatF64> {
    let n = a.n;
    let mut l = MatF64::zeros(n);
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a.at(i, j);
            for k in 0..j {
                sum -= l.at(i, k) * l.at(j, k);
            }
            if i == j {
                if sum <= 0.0 {
                    bail!("matrix not positive definite at pivot {i} (sum={sum})");
                }
                l.set(i, j, sum.sqrt());
            } else {
                l.set(i, j, sum / l.at(j, j));
            }
        }
    }
    Ok(l)
}

/// Solve L·x = b (forward substitution), L lower-triangular.
pub fn solve_lower(l: &MatF64, b: &[f64]) -> Vec<f64> {
    let n = l.n;
    let mut x = b.to_vec();
    for i in 0..n {
        for k in 0..i {
            x[i] -= l.at(i, k) * x[k];
        }
        x[i] /= l.at(i, i);
    }
    x
}

/// Solve Lᵀ·x = b (back substitution), L lower-triangular.
pub fn solve_lower_t(l: &MatF64, b: &[f64]) -> Vec<f64> {
    let n = l.n;
    let mut x = b.to_vec();
    for i in (0..n).rev() {
        for k in i + 1..n {
            x[i] -= l.at(k, i) * x[k];
        }
        x[i] /= l.at(i, i);
    }
    x
}

/// A⁻¹ for SPD A via Cholesky (column-by-column solves).
pub fn invert_spd(a: &MatF64) -> Result<MatF64> {
    let n = a.n;
    let l = cholesky_lower(a)?;
    let mut inv = MatF64::zeros(n);
    let mut e = vec![0.0; n];
    for j in 0..n {
        e[j] = 1.0;
        let y = solve_lower(&l, &e);
        let x = solve_lower_t(&l, &y);
        for i in 0..n {
            inv.set(i, j, x[i]);
        }
        e[j] = 0.0;
    }
    Ok(inv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    fn random_spd(n: usize, seed: u64) -> MatF64 {
        let mut rng = Pcg32::new(seed);
        let mut b = MatF64::zeros(n);
        for v in b.a.iter_mut() {
            *v = rng.normal() as f64;
        }
        let mut a = b.matmul(&b.transpose());
        for i in 0..n {
            a.a[i * n + i] += n as f64 * 0.1;
        }
        a
    }

    #[test]
    fn cholesky_reconstructs() {
        let a = random_spd(12, 3);
        let l = cholesky_lower(&a).unwrap();
        let rec = l.matmul(&l.transpose());
        for (x, y) in a.a.iter().zip(&rec.a) {
            assert!((x - y).abs() < 1e-9, "{x} vs {y}");
        }
    }

    #[test]
    fn inverse_is_inverse() {
        let a = random_spd(10, 7);
        let inv = invert_spd(&a).unwrap();
        let prod = a.matmul(&inv);
        let eye = MatF64::eye(10);
        for (x, y) in prod.a.iter().zip(&eye.a) {
            assert!((x - y).abs() < 1e-8, "{x} vs {y}");
        }
    }

    #[test]
    fn triangular_solves() {
        let a = random_spd(8, 9);
        let l = cholesky_lower(&a).unwrap();
        let b: Vec<f64> = (0..8).map(|i| i as f64 + 1.0).collect();
        let y = solve_lower(&l, &b);
        // L·y == b
        for i in 0..8 {
            let mut s = 0.0;
            for k in 0..=i {
                s += l.at(i, k) * y[k];
            }
            assert!((s - b[i]).abs() < 1e-10);
        }
        let x = solve_lower_t(&l, &b);
        for i in 0..8 {
            let mut s = 0.0;
            for k in i..8 {
                s += l.at(k, i) * x[k];
            }
            assert!((s - b[i]).abs() < 1e-10);
        }
    }

    #[test]
    fn non_pd_rejected() {
        let mut a = MatF64::eye(3);
        a.set(2, 2, -1.0);
        assert!(cholesky_lower(&a).is_err());
    }
}
