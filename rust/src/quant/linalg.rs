//! Dense symmetric-positive-definite linear algebra for OPTQ: Cholesky
//! factorization, triangular solves, SPD inversion. f64 throughout — the
//! Hessians OPTQ consumes are ill-conditioned enough that f32 Cholesky
//! visibly degrades the quantization.

use anyhow::{bail, Result};

/// Row-major square matrix of f64.
#[derive(Clone, Debug)]
pub struct MatF64 {
    pub n: usize,
    pub a: Vec<f64>,
}

impl MatF64 {
    pub fn zeros(n: usize) -> Self {
        MatF64 { n, a: vec![0.0; n * n] }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n);
        for i in 0..n {
            m.a[i * n + i] = 1.0;
        }
        m
    }

    pub fn from_f32(n: usize, data: &[f32]) -> Self {
        assert_eq!(data.len(), n * n);
        MatF64 { n, a: data.iter().map(|&x| x as f64).collect() }
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f64 {
        self.a[i * self.n + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.a[i * self.n + j] = v;
    }

    pub fn transpose(&self) -> MatF64 {
        let n = self.n;
        let mut t = MatF64::zeros(n);
        for i in 0..n {
            for j in 0..n {
                t.a[j * n + i] = self.a[i * n + j];
            }
        }
        t
    }

    /// C = A·B, column-blocked and row-parallel (same scheme as
    /// `Tensor::matmul`; OPTQ Hessian products are the big consumers).
    pub fn matmul(&self, b: &MatF64) -> MatF64 {
        let n = self.n;
        let mut c = MatF64::zeros(n);
        if n == 0 {
            return c;
        }
        const JB: usize = 256;
        let row_block = |i0: usize, crows: &mut [f64]| {
            for (ii, crow) in crows.chunks_mut(n).enumerate() {
                let arow = &self.a[(i0 + ii) * n..(i0 + ii + 1) * n];
                for j0 in (0..n).step_by(JB) {
                    let j1 = (j0 + JB).min(n);
                    for (k, &aik) in arow.iter().enumerate() {
                        if aik == 0.0 {
                            continue;
                        }
                        let brow = &b.a[k * n + j0..k * n + j1];
                        for (o, &bv) in crow[j0..j1].iter_mut().zip(brow) {
                            *o += aik * bv;
                        }
                    }
                }
            }
        };
        let threads = crate::util::num_threads().min(n).max(1);
        if threads == 1 || n * n * n < (1 << 16) {
            row_block(0, &mut c.a);
        } else {
            let chunk_rows = n.div_ceil(threads);
            std::thread::scope(|s| {
                for (t, chunk) in c.a.chunks_mut(chunk_rows * n).enumerate() {
                    let row_block = &row_block;
                    s.spawn(move || row_block(t * chunk_rows, chunk));
                }
            });
        }
        c
    }
}

#[inline]
fn dot(x: &[f64], y: &[f64]) -> f64 {
    let mut s = 0.0;
    for (a, b) in x.iter().zip(y) {
        s += a * b;
    }
    s
}

/// Panel width of the right-looking blocked factorization.
const CHOL_NB: usize = 64;

/// Matrix size where the blocked path takes over from the seed column
/// path. Large-Hessian OPTQ (d_model ≥ 512 columns) gets the blocked
/// panel factorization with its parallel trailing update; everything
/// smaller — including every test-sized matrix — stays on the seed
/// column path, which remains the parity baseline.
const CHOL_BLOCKED_MIN: usize = 512;

/// Lower Cholesky factor L with A = L·Lᵀ. Fails on non-PD input.
///
/// Column-oriented (left-looking) formulation: after the diagonal pivot of
/// column j is fixed, every sub-diagonal entry of the column depends only
/// on already-final rows, so the column is computed in parallel row chunks
/// when it is large enough to pay for the spawns. Each entry is one
/// sequential dot product — results do not depend on the thread count.
///
/// At `n >= CHOL_BLOCKED_MIN` the right-looking blocked variant
/// ([`cholesky_lower_blocked`]) takes over: same factor up to f64
/// round-off (the summation order differs), much better cache behavior
/// and parallel fan-out on the OPTQ Hessians that dominate quantization
/// time.
pub fn cholesky_lower(a: &MatF64) -> Result<MatF64> {
    if a.n >= CHOL_BLOCKED_MIN {
        cholesky_lower_blocked(a, CHOL_NB, crate::util::num_threads())
    } else {
        cholesky_lower_impl(a, crate::util::num_threads(), 1 << 17)
    }
}

/// Right-looking blocked Cholesky: factor an `nb`-column panel (diagonal
/// block plus everything below it), then rank-`nb` downdate the trailing
/// submatrix `A₂₂ -= P·Pᵀ` in parallel row chunks, and recurse on the
/// trailing block. The trailing update is where almost all the flops
/// live, and unlike the column path it is one big embarrassingly
/// parallel sweep per panel instead of one small one per column.
///
/// The panel columns of the trailing rows are snapshotted into a shared
/// read-only buffer before the update, so workers never read rows
/// another worker writes. Per trailing entry the `k` sum runs in fixed
/// ascending order — results are **bit-identical for any `threads`**
/// (the left-vs-right-looking orders differ, so cross-path parity is
/// f64-tolerance, not bitwise; the tests pin both).
pub fn cholesky_lower_blocked(a: &MatF64, nb: usize, threads: usize) -> Result<MatF64> {
    let n = a.n;
    let nb = nb.max(1);
    let mut l = MatF64::zeros(n);
    // Working copy of the lower triangle: entries become final L panel by
    // panel; trailing entries hold the partially-downdated A.
    for i in 0..n {
        for j in 0..=i {
            l.a[i * n + j] = a.at(i, j);
        }
    }
    let mut panel: Vec<f64> = Vec::new();
    let mut r0 = 0usize;
    while r0 < n {
        let r1 = (r0 + nb).min(n);
        let jb = r1 - r0;
        // Factor the panel columns. Within the panel only columns r0..j
        // contribute to column j — earlier columns were already folded in
        // by the previous panels' trailing downdates.
        for j in r0..r1 {
            let d = {
                let lrow_j = &l.a[j * n + r0..j * n + j];
                l.a[j * n + j] - dot(lrow_j, lrow_j)
            };
            if d <= 0.0 {
                bail!("matrix not positive definite at pivot {j} (sum={d})");
            }
            let ljj = d.sqrt();
            l.a[j * n + j] = ljj;
            let (head, tail) = l.a.split_at_mut((j + 1) * n);
            let lrow_j = &head[j * n + r0..j * n + j];
            for lrow in tail.chunks_mut(n) {
                let s = lrow[j] - dot(&lrow[r0..j], lrow_j);
                lrow[j] = s / ljj;
            }
        }
        if r1 == n {
            break;
        }
        // Snapshot the trailing rows' finished panel columns.
        let tn = n - r1;
        panel.clear();
        panel.reserve(tn * jb);
        for i in r1..n {
            panel.extend_from_slice(&l.a[i * n + r0..i * n + r1]);
        }
        let pref = &panel;
        // Rank-jb downdate of the trailing lower triangle, row-parallel.
        let (_, trail) = l.a.split_at_mut(r1 * n);
        let update = |i0: usize, rows: &mut [f64]| {
            for (ri, row) in rows.chunks_mut(n).enumerate() {
                let i = i0 + ri;
                let pi = &pref[(i - r1) * jb..(i - r1 + 1) * jb];
                for c in r1..=i {
                    let pc = &pref[(c - r1) * jb..(c - r1 + 1) * jb];
                    row[c] -= dot(pi, pc);
                }
            }
        };
        let workers = threads.max(1).min(tn);
        if workers == 1 {
            update(r1, trail);
        } else {
            let chunk_rows = tn.div_ceil(workers);
            std::thread::scope(|s| {
                for (t, chunk) in trail.chunks_mut(chunk_rows * n).enumerate() {
                    let update = &update;
                    s.spawn(move || update(r1 + t * chunk_rows, chunk));
                }
            });
        }
        r0 = r1;
    }
    Ok(l)
}

/// `par_work`: minimum column work (rows-below × dot-length) before a
/// column is sharded across threads. Exposed for the tests, which force
/// the parallel branch on small matrices.
fn cholesky_lower_impl(a: &MatF64, threads: usize, par_work: usize) -> Result<MatF64> {
    let n = a.n;
    let mut l = MatF64::zeros(n);
    for j in 0..n {
        let d = {
            let lrow_j = &l.a[j * n..j * n + j];
            a.at(j, j) - dot(lrow_j, lrow_j)
        };
        if d <= 0.0 {
            bail!("matrix not positive definite at pivot {j} (sum={d})");
        }
        let ljj = d.sqrt();
        l.a[j * n + j] = ljj;
        let below = n - j - 1;
        if below == 0 {
            continue;
        }
        // Rows 0..=j are read-only history; rows j+1.. are this column's
        // disjoint write targets.
        let (head, tail) = l.a.split_at_mut((j + 1) * n);
        let lrow_j = &head[j * n..j * n + j];
        let col = |base: usize, rows: &mut [f64]| {
            for (ri, lrow) in rows.chunks_mut(n).enumerate() {
                let i = base + ri;
                let s = a.at(i, j) - dot(&lrow[..j], lrow_j);
                lrow[j] = s / ljj;
            }
        };
        if threads > 1 && below * j >= par_work {
            let chunk_rows = below.div_ceil(threads.min(below));
            std::thread::scope(|s| {
                for (t, chunk) in tail.chunks_mut(chunk_rows * n).enumerate() {
                    let col = &col;
                    s.spawn(move || col(j + 1 + t * chunk_rows, chunk));
                }
            });
        } else {
            col(j + 1, tail);
        }
    }
    Ok(l)
}

/// Solve L·x = b (forward substitution), L lower-triangular. Inherently
/// sequential (each entry depends on all previous); O(n²), not worth
/// parallelizing — callers parallelize across independent right-hand sides
/// instead (see `invert_spd`).
pub fn solve_lower(l: &MatF64, b: &[f64]) -> Vec<f64> {
    let n = l.n;
    let mut x = b.to_vec();
    for i in 0..n {
        for k in 0..i {
            x[i] -= l.at(i, k) * x[k];
        }
        x[i] /= l.at(i, i);
    }
    x
}

/// Solve Lᵀ·x = b (back substitution), L lower-triangular.
pub fn solve_lower_t(l: &MatF64, b: &[f64]) -> Vec<f64> {
    let n = l.n;
    let mut x = b.to_vec();
    for i in (0..n).rev() {
        for k in i + 1..n {
            x[i] -= l.at(k, i) * x[k];
        }
        x[i] /= l.at(i, i);
    }
    x
}

/// A⁻¹ for SPD A via Cholesky. The n unit-vector solves are independent,
/// so they run in parallel column chunks (each worker writes rows of the
/// transposed result, then one transpose lays out A⁻¹ — identical values
/// to the sequential column-by-column loop).
pub fn invert_spd(a: &MatF64) -> Result<MatF64> {
    let n = a.n;
    let l = cholesky_lower(a)?;
    if n == 0 {
        return Ok(MatF64::zeros(0));
    }
    let mut invt = vec![0.0f64; n * n]; // row j = A⁻¹·e_j
    let solve_cols = |c0: usize, rows: &mut [f64]| {
        let mut e = vec![0.0f64; n];
        for (ri, row) in rows.chunks_mut(n).enumerate() {
            e[c0 + ri] = 1.0;
            let y = solve_lower(&l, &e);
            row.copy_from_slice(&solve_lower_t(&l, &y));
            e[c0 + ri] = 0.0;
        }
    };
    let threads = crate::util::num_threads().min(n).max(1);
    if threads == 1 || n < 64 {
        solve_cols(0, &mut invt);
    } else {
        let chunk_cols = n.div_ceil(threads);
        std::thread::scope(|s| {
            for (t, chunk) in invt.chunks_mut(chunk_cols * n).enumerate() {
                let solve_cols = &solve_cols;
                s.spawn(move || solve_cols(t * chunk_cols, chunk));
            }
        });
    }
    let mut inv = MatF64::zeros(n);
    for j in 0..n {
        for i in 0..n {
            inv.a[i * n + j] = invt[j * n + i];
        }
    }
    Ok(inv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    fn random_spd(n: usize, seed: u64) -> MatF64 {
        let mut rng = Pcg32::new(seed);
        let mut b = MatF64::zeros(n);
        for v in b.a.iter_mut() {
            *v = rng.normal() as f64;
        }
        let mut a = b.matmul(&b.transpose());
        for i in 0..n {
            a.a[i * n + i] += n as f64 * 0.1;
        }
        a
    }

    #[test]
    fn cholesky_reconstructs() {
        let a = random_spd(12, 3);
        let l = cholesky_lower(&a).unwrap();
        let rec = l.matmul(&l.transpose());
        for (x, y) in a.a.iter().zip(&rec.a) {
            assert!((x - y).abs() < 1e-9, "{x} vs {y}");
        }
    }

    #[test]
    fn inverse_is_inverse() {
        let a = random_spd(10, 7);
        let inv = invert_spd(&a).unwrap();
        let prod = a.matmul(&inv);
        let eye = MatF64::eye(10);
        for (x, y) in prod.a.iter().zip(&eye.a) {
            assert!((x - y).abs() < 1e-8, "{x} vs {y}");
        }
    }

    #[test]
    fn triangular_solves() {
        let a = random_spd(8, 9);
        let l = cholesky_lower(&a).unwrap();
        let b: Vec<f64> = (0..8).map(|i| i as f64 + 1.0).collect();
        let y = solve_lower(&l, &b);
        // L·y == b
        for i in 0..8 {
            let mut s = 0.0;
            for k in 0..=i {
                s += l.at(i, k) * y[k];
            }
            assert!((s - b[i]).abs() < 1e-10);
        }
        let x = solve_lower_t(&l, &b);
        for i in 0..8 {
            let mut s = 0.0;
            for k in i..8 {
                s += l.at(k, i) * x[k];
            }
            assert!((s - b[i]).abs() < 1e-10);
        }
    }

    #[test]
    fn parallel_cholesky_matches_serial_bitwise() {
        let a = random_spd(64, 13);
        let serial = cholesky_lower_impl(&a, 1, usize::MAX).unwrap();
        for threads in [2usize, 4, 7] {
            let par = cholesky_lower_impl(&a, threads, 0).unwrap();
            assert_eq!(par.a, serial.a, "threads={threads}");
        }
    }

    #[test]
    fn parallel_invert_and_matmul_stay_exact() {
        // n = 96 crosses the invert/matmul parallel thresholds.
        let a = random_spd(96, 21);
        let inv = invert_spd(&a).unwrap();
        let prod = a.matmul(&inv);
        let eye = MatF64::eye(96);
        for (x, y) in prod.a.iter().zip(&eye.a) {
            assert!((x - y).abs() < 1e-7, "{x} vs {y}");
        }
    }

    #[test]
    fn blocked_cholesky_matches_the_seed_column_path() {
        // Right-looking blocked vs left-looking column factorization:
        // same factor up to f64 round-off (summation orders differ),
        // across panel widths that do / don't divide n, and with the
        // blocked path's trailing update forced both serial and parallel.
        let n = 96;
        let a = random_spd(n, 33);
        let seed = cholesky_lower_impl(&a, 1, usize::MAX).unwrap();
        for nb in [1usize, 8, 32, 96, 100] {
            for threads in [1usize, 4] {
                let blk = cholesky_lower_blocked(&a, nb, threads).unwrap();
                for (i, (x, y)) in blk.a.iter().zip(&seed.a).enumerate() {
                    assert!(
                        (x - y).abs() <= 1e-9 * x.abs().max(1.0),
                        "nb={nb} threads={threads} [{i}]: {x} vs {y}"
                    );
                }
                // And it is a genuine factor: L·Lᵀ reconstructs A.
                let rec = blk.matmul(&blk.transpose());
                for (x, y) in a.a.iter().zip(&rec.a) {
                    assert!((x - y).abs() < 1e-8, "nb={nb}: {x} vs {y}");
                }
            }
        }
    }

    #[test]
    fn blocked_cholesky_is_thread_invariant_bitwise() {
        let a = random_spd(64, 41);
        let one = cholesky_lower_blocked(&a, 16, 1).unwrap();
        for threads in [2usize, 5, 16] {
            let par = cholesky_lower_blocked(&a, 16, threads).unwrap();
            assert_eq!(one.a, par.a, "threads={threads}");
        }
    }

    #[test]
    fn non_pd_rejected() {
        let mut a = MatF64::eye(3);
        a.set(2, 2, -1.0);
        assert!(cholesky_lower(&a).is_err());
        // The blocked path reports the same failure (mid-panel pivot).
        let mut b = random_spd(24, 5);
        b.set(17, 17, -100.0);
        assert!(cholesky_lower_blocked(&b, 8, 2).is_err());
    }
}
