//! Dense symmetric-positive-definite linear algebra for OPTQ: Cholesky
//! factorization, triangular solves, SPD inversion. f64 throughout — the
//! Hessians OPTQ consumes are ill-conditioned enough that f32 Cholesky
//! visibly degrades the quantization.

use anyhow::{bail, Result};

/// Row-major square matrix of f64.
#[derive(Clone, Debug)]
pub struct MatF64 {
    pub n: usize,
    pub a: Vec<f64>,
}

impl MatF64 {
    pub fn zeros(n: usize) -> Self {
        MatF64 { n, a: vec![0.0; n * n] }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n);
        for i in 0..n {
            m.a[i * n + i] = 1.0;
        }
        m
    }

    pub fn from_f32(n: usize, data: &[f32]) -> Self {
        assert_eq!(data.len(), n * n);
        MatF64 { n, a: data.iter().map(|&x| x as f64).collect() }
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f64 {
        self.a[i * self.n + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.a[i * self.n + j] = v;
    }

    pub fn transpose(&self) -> MatF64 {
        let n = self.n;
        let mut t = MatF64::zeros(n);
        for i in 0..n {
            for j in 0..n {
                t.a[j * n + i] = self.a[i * n + j];
            }
        }
        t
    }

    /// C = A·B, column-blocked and row-parallel (same scheme as
    /// `Tensor::matmul`; OPTQ Hessian products are the big consumers).
    pub fn matmul(&self, b: &MatF64) -> MatF64 {
        let n = self.n;
        let mut c = MatF64::zeros(n);
        if n == 0 {
            return c;
        }
        const JB: usize = 256;
        let row_block = |i0: usize, crows: &mut [f64]| {
            for (ii, crow) in crows.chunks_mut(n).enumerate() {
                let arow = &self.a[(i0 + ii) * n..(i0 + ii + 1) * n];
                for j0 in (0..n).step_by(JB) {
                    let j1 = (j0 + JB).min(n);
                    for (k, &aik) in arow.iter().enumerate() {
                        if aik == 0.0 {
                            continue;
                        }
                        let brow = &b.a[k * n + j0..k * n + j1];
                        for (o, &bv) in crow[j0..j1].iter_mut().zip(brow) {
                            *o += aik * bv;
                        }
                    }
                }
            }
        };
        let threads = crate::util::num_threads().min(n).max(1);
        if threads == 1 || n * n * n < (1 << 16) {
            row_block(0, &mut c.a);
        } else {
            let chunk_rows = n.div_ceil(threads);
            std::thread::scope(|s| {
                for (t, chunk) in c.a.chunks_mut(chunk_rows * n).enumerate() {
                    let row_block = &row_block;
                    s.spawn(move || row_block(t * chunk_rows, chunk));
                }
            });
        }
        c
    }
}

#[inline]
fn dot(x: &[f64], y: &[f64]) -> f64 {
    let mut s = 0.0;
    for (a, b) in x.iter().zip(y) {
        s += a * b;
    }
    s
}

/// Lower Cholesky factor L with A = L·Lᵀ. Fails on non-PD input.
///
/// Column-oriented (left-looking) formulation: after the diagonal pivot of
/// column j is fixed, every sub-diagonal entry of the column depends only
/// on already-final rows, so the column is computed in parallel row chunks
/// when it is large enough to pay for the spawns. Each entry is one
/// sequential dot product — results do not depend on the thread count.
pub fn cholesky_lower(a: &MatF64) -> Result<MatF64> {
    cholesky_lower_impl(a, crate::util::num_threads(), 1 << 17)
}

/// `par_work`: minimum column work (rows-below × dot-length) before a
/// column is sharded across threads. Exposed for the tests, which force
/// the parallel branch on small matrices.
fn cholesky_lower_impl(a: &MatF64, threads: usize, par_work: usize) -> Result<MatF64> {
    let n = a.n;
    let mut l = MatF64::zeros(n);
    for j in 0..n {
        let d = {
            let lrow_j = &l.a[j * n..j * n + j];
            a.at(j, j) - dot(lrow_j, lrow_j)
        };
        if d <= 0.0 {
            bail!("matrix not positive definite at pivot {j} (sum={d})");
        }
        let ljj = d.sqrt();
        l.a[j * n + j] = ljj;
        let below = n - j - 1;
        if below == 0 {
            continue;
        }
        // Rows 0..=j are read-only history; rows j+1.. are this column's
        // disjoint write targets.
        let (head, tail) = l.a.split_at_mut((j + 1) * n);
        let lrow_j = &head[j * n..j * n + j];
        let col = |base: usize, rows: &mut [f64]| {
            for (ri, lrow) in rows.chunks_mut(n).enumerate() {
                let i = base + ri;
                let s = a.at(i, j) - dot(&lrow[..j], lrow_j);
                lrow[j] = s / ljj;
            }
        };
        if threads > 1 && below * j >= par_work {
            let chunk_rows = below.div_ceil(threads.min(below));
            std::thread::scope(|s| {
                for (t, chunk) in tail.chunks_mut(chunk_rows * n).enumerate() {
                    let col = &col;
                    s.spawn(move || col(j + 1 + t * chunk_rows, chunk));
                }
            });
        } else {
            col(j + 1, tail);
        }
    }
    Ok(l)
}

/// Solve L·x = b (forward substitution), L lower-triangular. Inherently
/// sequential (each entry depends on all previous); O(n²), not worth
/// parallelizing — callers parallelize across independent right-hand sides
/// instead (see `invert_spd`).
pub fn solve_lower(l: &MatF64, b: &[f64]) -> Vec<f64> {
    let n = l.n;
    let mut x = b.to_vec();
    for i in 0..n {
        for k in 0..i {
            x[i] -= l.at(i, k) * x[k];
        }
        x[i] /= l.at(i, i);
    }
    x
}

/// Solve Lᵀ·x = b (back substitution), L lower-triangular.
pub fn solve_lower_t(l: &MatF64, b: &[f64]) -> Vec<f64> {
    let n = l.n;
    let mut x = b.to_vec();
    for i in (0..n).rev() {
        for k in i + 1..n {
            x[i] -= l.at(k, i) * x[k];
        }
        x[i] /= l.at(i, i);
    }
    x
}

/// A⁻¹ for SPD A via Cholesky. The n unit-vector solves are independent,
/// so they run in parallel column chunks (each worker writes rows of the
/// transposed result, then one transpose lays out A⁻¹ — identical values
/// to the sequential column-by-column loop).
pub fn invert_spd(a: &MatF64) -> Result<MatF64> {
    let n = a.n;
    let l = cholesky_lower(a)?;
    if n == 0 {
        return Ok(MatF64::zeros(0));
    }
    let mut invt = vec![0.0f64; n * n]; // row j = A⁻¹·e_j
    let solve_cols = |c0: usize, rows: &mut [f64]| {
        let mut e = vec![0.0f64; n];
        for (ri, row) in rows.chunks_mut(n).enumerate() {
            e[c0 + ri] = 1.0;
            let y = solve_lower(&l, &e);
            row.copy_from_slice(&solve_lower_t(&l, &y));
            e[c0 + ri] = 0.0;
        }
    };
    let threads = crate::util::num_threads().min(n).max(1);
    if threads == 1 || n < 64 {
        solve_cols(0, &mut invt);
    } else {
        let chunk_cols = n.div_ceil(threads);
        std::thread::scope(|s| {
            for (t, chunk) in invt.chunks_mut(chunk_cols * n).enumerate() {
                let solve_cols = &solve_cols;
                s.spawn(move || solve_cols(t * chunk_cols, chunk));
            }
        });
    }
    let mut inv = MatF64::zeros(n);
    for j in 0..n {
        for i in 0..n {
            inv.a[i * n + j] = invt[j * n + i];
        }
    }
    Ok(inv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    fn random_spd(n: usize, seed: u64) -> MatF64 {
        let mut rng = Pcg32::new(seed);
        let mut b = MatF64::zeros(n);
        for v in b.a.iter_mut() {
            *v = rng.normal() as f64;
        }
        let mut a = b.matmul(&b.transpose());
        for i in 0..n {
            a.a[i * n + i] += n as f64 * 0.1;
        }
        a
    }

    #[test]
    fn cholesky_reconstructs() {
        let a = random_spd(12, 3);
        let l = cholesky_lower(&a).unwrap();
        let rec = l.matmul(&l.transpose());
        for (x, y) in a.a.iter().zip(&rec.a) {
            assert!((x - y).abs() < 1e-9, "{x} vs {y}");
        }
    }

    #[test]
    fn inverse_is_inverse() {
        let a = random_spd(10, 7);
        let inv = invert_spd(&a).unwrap();
        let prod = a.matmul(&inv);
        let eye = MatF64::eye(10);
        for (x, y) in prod.a.iter().zip(&eye.a) {
            assert!((x - y).abs() < 1e-8, "{x} vs {y}");
        }
    }

    #[test]
    fn triangular_solves() {
        let a = random_spd(8, 9);
        let l = cholesky_lower(&a).unwrap();
        let b: Vec<f64> = (0..8).map(|i| i as f64 + 1.0).collect();
        let y = solve_lower(&l, &b);
        // L·y == b
        for i in 0..8 {
            let mut s = 0.0;
            for k in 0..=i {
                s += l.at(i, k) * y[k];
            }
            assert!((s - b[i]).abs() < 1e-10);
        }
        let x = solve_lower_t(&l, &b);
        for i in 0..8 {
            let mut s = 0.0;
            for k in i..8 {
                s += l.at(k, i) * x[k];
            }
            assert!((s - b[i]).abs() < 1e-10);
        }
    }

    #[test]
    fn parallel_cholesky_matches_serial_bitwise() {
        let a = random_spd(64, 13);
        let serial = cholesky_lower_impl(&a, 1, usize::MAX).unwrap();
        for threads in [2usize, 4, 7] {
            let par = cholesky_lower_impl(&a, threads, 0).unwrap();
            assert_eq!(par.a, serial.a, "threads={threads}");
        }
    }

    #[test]
    fn parallel_invert_and_matmul_stay_exact() {
        // n = 96 crosses the invert/matmul parallel thresholds.
        let a = random_spd(96, 21);
        let inv = invert_spd(&a).unwrap();
        let prod = a.matmul(&inv);
        let eye = MatF64::eye(96);
        for (x, y) in prod.a.iter().zip(&eye.a) {
            assert!((x - y).abs() < 1e-7, "{x} vs {y}");
        }
    }

    #[test]
    fn non_pd_rejected() {
        let mut a = MatF64::eye(3);
        a.set(2, 2, -1.0);
        assert!(cholesky_lower(&a).is_err());
    }
}
