//! Fused, blocked, multithreaded quantized kernels — the host-side
//! deployment hot path (paper §"accelerated inference", Tables 1/4,
//! Fig. 2a).
//!
//! The paper's serving story keeps weights as packed sub-4-bit integer
//! codes; only the per-task scales/zero-points ever change. The seed
//! repo honored that for *storage* but not for *compute*: every forward
//! materialized a dense f32 Ŵ with scalar loops and then ran a naive
//! single-threaded matmul. This module computes
//!
//! ```text
//! y = X · Ŵᵀ,   Ŵ = s · (codes − z)
//! ```
//!
//! directly from the packed codes:
//!
//! * **word-at-a-time unpacking** — 2/3/4-bit groups are expanded with
//!   u64 loads (`pack::unpack_into_f32`) into a small per-thread group
//!   tile that stays in L1 and is reused across the whole batch;
//! * **fused scale/zero application** — per (row, group) the zero-point
//!   is folded through the algebraic identity
//!   `Σⱼ xⱼ·s·(cⱼ−z) = s·(Σⱼ xⱼ·cⱼ − z·Σⱼ xⱼ)`, so the inner loop is a
//!   pure code dot product and the precomputed group sums `Σⱼ xⱼ` pay
//!   the zero-point once per group instead of once per element;
//! * **cache blocking** — the group structure *is* the K-blocking (one
//!   tile of ≤ `group` codes at a time), and the output is computed as
//!   yᵀ in contiguous per-row slabs;
//! * **row parallelism** — output rows are sharded over
//!   `std::thread::scope` workers (no rayon in the vendored registry);
//!   each Ŵ row is owned by exactly one worker and accumulated in a
//!   fixed order, so results are **bit-identical for any thread count**;
//! * **runtime-dispatched SIMD** — the per-group code dot and the
//!   backward updates route through the `quant::simd` function table
//!   (AVX2/NEON, chosen once per process, `PEQA_SIMD=scalar|auto`
//!   override). Lane tiers vectorize across *independent output
//!   elements* — batch lanes when the batch is wide enough, weight-row
//!   lanes otherwise — never across the per-group reduction, and use
//!   separate mul+add (no FMA), so every tier is **bitwise identical**
//!   to the scalar baseline, which is kept verbatim as the
//!   `lanes == 1` path;
//! * **pooled scratch** — per-call group sums, lane transposes, and
//!   per-worker code tiles live in a caller-held [`KernelScratch`]
//!   (threaded from `model::blocks::ProjScratch` / the trainer tape),
//!   so steady-state decode/train steps do no kernel allocation.
//!
//! # Packed memory layout
//!
//! [`PackedMatrix`] stores codes **row-aligned**: row `r` occupies bytes
//! `[r·row_stride, (r+1)·row_stride)` with `row_stride =
//! packed_size(cols, bits)`; within a row, codes are bit-packed
//! little-endian exactly as `quant::pack` defines (code `j` starts at bit
//! `j·bits`). Row alignment costs at most 7 padding bits per row and buys
//! independent per-row access — the property the row-parallel kernels and
//! the `.packed` loader rely on. Scales/zeros stay f32 `(rows, n_groups)`
//! tensors: they are the task adapter and are swapped, never repacked.
//!
//! `reference_dequant_matmul` preserves the seed's scalar
//! unpack → dequantize → transpose → naive-matmul path verbatim; it is the
//! parity baseline for the tests and the "before" column of
//! `BENCH_kernels.json` (benches/kernels_micro.rs).

use std::sync::Arc;

use anyhow::{bail, Result};

use super::pack;
use super::rtn::QuantizedMatrix;
use super::simd::{self, SimdOps};
use crate::tensor::Tensor;

/// Pooled per-call scratch for the fused kernels: the (row, group) sum
/// buffers, lane-tier transposes, per-worker code-tile slabs, ragged cut
/// indices, and gradient staging that the kernels previously allocated per
/// call. Steady-state drivers (serve::engine's `Scratch`, train::host's
/// `TapeArena`) hold one of these inside `model::blocks::ProjScratch` and
/// thread it through every projection, so the decode/train hot loops do no
/// per-call kernel allocation. `KernelScratch::default()` is allocation-free
/// (empty vectors); buffers grow on first use and are then reused.
///
/// The public kernel entry points construct a transient default scratch so
/// their signatures stay stable; anything on a per-token path goes through
/// the `_core` variants with a pooled scratch.
#[derive(Default)]
pub struct KernelScratch {
    /// Per-(x-row, group) sums Σx, `(m, n_groups)`.
    pub(crate) sx: Vec<f32>,
    /// `sx` transposed to `(n_groups, m)` — the lane tiers' combine reads
    /// it contiguously along the lane axis.
    pub(crate) sxt: Vec<f32>,
    /// X transposed to `(cols, m)` — the batch-lane tiers load `lanes`
    /// consecutive x-rows per vector op from it.
    pub(crate) xt: Vec<f32>,
    /// Per-worker code-tile slab, `workers × tile_len` (tile_len is `group`
    /// for contiguous tiles, `lanes·group` for interleaved row-lane tiles).
    pub(crate) tiles: Vec<f32>,
    /// Dense LM-head per-(batch, lane) accumulators (model::blocks).
    pub(crate) acc: Vec<f32>,
    /// Ragged worker cut indices (`matmul_t_ragged`).
    pub(crate) cuts: Vec<usize>,
    /// Interleaved per-row `[ds…, dz…]` staging for `grad_scales_zeros`.
    pub(crate) dsz: Vec<f32>,
}

/// A weight matrix held as bit-packed integer codes plus per-(row, group)
/// f32 scales and zero-points. See the module docs for the byte layout.
///
/// The packed code bytes live behind an [`Arc`] and are **immutable for
/// the life of the matrix** — `Clone` shares them by reference and
/// deep-copies only the f32 scale/zero tensors. That is exactly the PEQA
/// deployment memory model: N serving engines built from clones of one
/// model share a single copy of the integer codes, and all per-engine
/// mutable state (task adapters) is kilobytes of scales/zeros.
#[derive(Clone, Debug)]
pub struct PackedMatrix {
    packed: Arc<[u8]>,
    row_stride: usize,
    pub scales: Tensor, // (rows, n_groups)
    pub zeros: Tensor,  // (rows, n_groups)
    pub rows: usize,
    pub cols: usize,
    pub bits: u8,
    pub group: usize,
}

impl PackedMatrix {
    /// Pack an unpacked [`QuantizedMatrix`] row by row.
    // peqa-lint: allow(hot-path-alloc) -- construction time: runs once
    // per matrix when a model is packed, never per token.
    pub fn from_quantized(q: &QuantizedMatrix) -> PackedMatrix {
        let row_stride = pack::packed_size(q.cols, q.bits);
        let mut packed = Vec::with_capacity(row_stride * q.rows);
        for r in 0..q.rows {
            let row = &q.codes[r * q.cols..(r + 1) * q.cols];
            packed.extend_from_slice(&pack::pack_codes(row, q.bits));
        }
        PackedMatrix {
            packed: packed.into(),
            row_stride,
            scales: q.scales.clone(),
            zeros: q.zeros.clone(),
            rows: q.rows,
            cols: q.cols,
            bits: q.bits,
            group: q.group,
        }
    }

    /// Build from a *contiguous* packed stream (the `.packed` file format,
    /// which bit-packs all `rows·cols` codes back to back). When a row is
    /// a whole number of bytes the stream is adopted as-is; otherwise the
    /// codes are re-packed once into the row-aligned layout.
    // peqa-lint: allow(hot-path-alloc) -- load time: runs once per
    // matrix when adopting a `.packed` stream, never per token.
    pub fn from_contiguous(
        stream: &[u8],
        rows: usize,
        cols: usize,
        bits: u8,
        scales: Tensor,
        zeros: Tensor,
    ) -> Result<PackedMatrix> {
        if !(1..=8).contains(&bits) {
            bail!("packed matrix: bits must be in 1..=8, got {bits}");
        }
        let (sn, ng) = scales.dims2()?;
        if sn != rows || ng == 0 || cols % ng != 0 {
            bail!(
                "packed matrix: scales {:?} do not tile a {rows}x{cols} matrix",
                scales.shape()
            );
        }
        if zeros.shape() != scales.shape() {
            bail!("packed matrix: zeros {:?} != scales {:?}", zeros.shape(), scales.shape());
        }
        let need = pack::packed_size(rows * cols, bits);
        if stream.len() < need {
            bail!("packed stream too short: {} < {need}", stream.len());
        }
        let group = cols / ng;
        let row_stride = pack::packed_size(cols, bits);
        let packed = if (cols * bits as usize) % 8 == 0 {
            stream[..rows * row_stride].to_vec()
        } else {
            let codes = pack::unpack_codes(stream, bits, rows * cols)?;
            let mut p = Vec::with_capacity(rows * row_stride);
            for r in 0..rows {
                p.extend_from_slice(&pack::pack_codes(&codes[r * cols..(r + 1) * cols], bits));
            }
            p
        };
        Ok(PackedMatrix { packed: packed.into(), row_stride, scales, zeros, rows, cols, bits, group })
    }

    /// Expand back to the unpacked representation (tooling/tests; the
    /// serving path never needs this).
    // peqa-lint: allow(hot-path-alloc) -- tooling/tests expansion path
    // (see doc above); the serving path never calls it.
    pub fn to_quantized(&self) -> Result<QuantizedMatrix> {
        let mut codes = Vec::with_capacity(self.rows * self.cols);
        for r in 0..self.rows {
            codes.extend(pack::unpack_codes(self.row_bytes(r), self.bits, self.cols)?);
        }
        Ok(QuantizedMatrix {
            codes,
            scales: self.scales.clone(),
            zeros: self.zeros.clone(),
            rows: self.rows,
            cols: self.cols,
            bits: self.bits,
            group: self.group,
        })
    }

    pub fn n_groups(&self) -> usize {
        self.cols / self.group
    }

    /// Bytes of packed code storage (the "Model Size" contribution).
    pub fn packed_bytes(&self) -> usize {
        self.packed.len()
    }

    /// Whether `self` and `other` share one physical copy of the packed
    /// codes (true for clones of one matrix — the engine-pool memory
    /// contract: N engines, one code buffer).
    pub fn codes_shared_with(&self, other: &PackedMatrix) -> bool {
        Arc::ptr_eq(&self.packed, &other.packed)
    }

    #[inline]
    fn row_bytes(&self, r: usize) -> &[u8] {
        &self.packed[r * self.row_stride..(r + 1) * self.row_stride]
    }

    /// Ŵ = s·(codes − z) as a dense tensor, fused unpack + scale,
    /// row-parallel.
    pub fn dequantize(&self) -> Tensor {
        self.dequantize_with(&self.scales, &self.zeros)
            .expect("scales/zeros shape is a struct invariant")
    }

    /// Dequantize with *replacement* scales/zeros (PEQA task switching:
    /// the packed codes are shared and untouched — no buffer clone).
    pub fn dequantize_with(&self, scales: &Tensor, zeros: &Tensor) -> Result<Tensor> {
        self.dequantize_with_threads(scales, zeros, crate::util::num_threads())
    }

    /// [`Self::dequantize_with`] with an explicit worker count (the
    /// thread-invariance tests and benches pin this).
    pub fn dequantize_with_threads(
        &self,
        scales: &Tensor,
        zeros: &Tensor,
        threads: usize,
    ) -> Result<Tensor> {
        let (rows, cols, g) = (self.rows, self.cols, self.group);
        let ng = self.n_groups();
        check_adapter_shape(scales, zeros, rows, ng)?;
        // peqa-lint: allow(hot-path-alloc) -- the dense Ŵ this function
        // exists to return; callers that cannot afford it use the fused
        // matmul entries instead.
        let mut out = vec![0.0f32; rows * cols];
        let (sd, zd) = (scales.data(), zeros.data());
        par_row_chunks(&mut out, cols, rows, threads, |r0, chunk| {
            for (ri, orow) in chunk.chunks_mut(cols).enumerate() {
                let prow = self.row_bytes(r0 + ri);
                for kg in 0..ng {
                    let seg = &mut orow[kg * g..(kg + 1) * g];
                    pack::unpack_into_f32(prow, self.bits, kg * g, seg);
                    let sc = sd[(r0 + ri) * ng + kg];
                    let zp = zd[(r0 + ri) * ng + kg];
                    for v in seg.iter_mut() {
                        *v = sc * (*v - zp);
                    }
                }
            }
        });
        Ok(Tensor::new(&[rows, cols], out))
    }

    /// Fused quantized GEMM: y = X · Ŵᵀ computed directly from the packed
    /// codes (X is (batch, cols); y is (batch, rows)). See module docs.
    pub fn matmul_t(&self, x: &Tensor) -> Result<Tensor> {
        self.matmul_t_threads(x, crate::util::num_threads())
    }

    /// [`Self::matmul_t`] with an explicit worker count. Results are
    /// bit-identical for every `threads` value.
    pub fn matmul_t_threads(&self, x: &Tensor, threads: usize) -> Result<Tensor> {
        self.matmul_t_with_ops(x, threads, simd::active())
    }

    /// [`Self::matmul_t_threads`] pinned to an explicit SIMD tier
    /// (`quant::simd::{scalar, detected}`) — the parity tests and the
    /// kernels bench drive scalar vs vector dispatch in one process
    /// through this; production callers use the `PEQA_SIMD`-resolved
    /// [`simd::active`] table via the plain entries.
    pub fn matmul_t_with_ops(
        &self,
        x: &Tensor,
        threads: usize,
        ops: &SimdOps,
    ) -> Result<Tensor> {
        let (b, k) = x.dims2()?;
        if k != self.cols {
            bail!("fused matmul: x is {:?} but matrix has {} cols", x.shape(), self.cols);
        }
        let rows = self.rows;
        // peqa-lint: allow(hot-path-alloc) -- backing store of the
        // returned Tensor; the per-token decode loop goes through
        // matmul_t_rows_core, which reuses caller buffers.
        let mut y = vec![0.0f32; b * rows];
        let mut yt = Vec::default();
        let mut scr = KernelScratch::default();
        self.matmul_t_rows_core(x.data(), b, threads, &mut y, &mut yt, ops, &mut scr)?;
        Ok(Tensor::new(&[b, rows], y))
    }

    /// Batched decode entry point (serve::engine): y = X·Ŵᵀ over raw
    /// slices, written into a caller buffer laid out `(batch, rows)` —
    /// no `Tensor` wrapper on the per-token hot path. Bitwise identical
    /// to [`Self::matmul_t`] on the same data: each output element is
    /// accumulated by the same per-row group loop.
    pub fn matmul_t_rows(
        &self,
        x: &[f32],
        batch: usize,
        threads: usize,
        out: &mut [f32],
    ) -> Result<()> {
        let mut yt = Vec::default();
        self.matmul_t_rows_scratch(x, batch, threads, out, &mut yt)
    }

    /// Serving prefill-batch/decode entry point: [`Self::matmul_t_rows`]
    /// accumulating through a caller-owned yᵀ scratch buffer. Kept for
    /// callers that only pool the transpose buffer; the steady-state
    /// drivers go through [`Self::matmul_t_rows_core`] with a full
    /// pooled [`KernelScratch`] (model::blocks::proj_into). Bitwise
    /// identical to [`Self::matmul_t`] on the same data for any `batch`,
    /// `threads`, or prior scratch contents.
    pub fn matmul_t_rows_scratch(
        &self,
        x: &[f32],
        batch: usize,
        threads: usize,
        out: &mut [f32],
        yt: &mut Vec<f32>,
    ) -> Result<()> {
        let mut scr = KernelScratch::default();
        self.matmul_t_rows_core(x, batch, threads, out, yt, simd::active(), &mut scr)
    }

    /// The pooled-scratch fused GEMM core every batched entry funnels
    /// into: y = X·Ŵᵀ written `(batch, rows)` into `out`, accumulating
    /// through the caller's yᵀ buffer, with group sums / lane transposes
    /// / worker code tiles pooled in `scr` and the inner loops routed
    /// through `ops`. Bitwise identical for any `batch`, `threads`,
    /// dispatch tier, or prior scratch contents.
    pub(crate) fn matmul_t_rows_core(
        &self,
        x: &[f32],
        batch: usize,
        threads: usize,
        out: &mut [f32],
        yt: &mut Vec<f32>,
        ops: &SimdOps,
        scr: &mut KernelScratch,
    ) -> Result<()> {
        if x.len() != batch * self.cols {
            bail!("matmul_t_rows: x has {} elems, expected {}x{}", x.len(), batch, self.cols);
        }
        if out.len() != batch * self.rows {
            bail!("matmul_t_rows: out has {} elems, expected {}x{}", out.len(), batch, self.rows);
        }
        if batch == 0 || self.rows == 0 {
            return Ok(());
        }
        if batch == 1 {
            // yᵀ (rows, 1) *is* y — accumulate straight into `out`.
            out.fill(0.0);
            self.matmul_t_yt(x, 1, threads, out, ops, scr);
            return Ok(());
        }
        yt.clear();
        yt.resize(self.rows * batch, 0.0);
        self.matmul_t_yt(x, batch, threads, yt, ops, scr);
        for r in 0..self.rows {
            for bi in 0..batch {
                out[bi * self.rows + r] = yt[r * batch + bi];
            }
        }
        Ok(())
    }

    /// Ragged direct-layout fused GEMM: y = X·Ŵᵀ over the concatenated
    /// token rows of several sequences (`spans[i]` rows belong to
    /// sequence `i`), written straight into `out` laid out
    /// `(Σ spans, rows)` — **no yᵀ transpose buffer**. This is the
    /// serving/training projection entry for mixed prefill+decode
    /// steps: the kernel walks per-sequence row spans directly, sharding
    /// the concatenated rows over `std::thread::scope` workers at span
    /// granularity (a span larger than a worker's budget is split by
    /// rows — output rows are mutually independent). Within a worker the
    /// weight-row loop is OUTER, so each (row, group) code tile is
    /// unpacked once per worker and reused across the worker's whole row
    /// chunk (the same locality trick as [`Self::grad_input`]).
    ///
    /// Every output element accumulates `s·(Σxⱼcⱼ − z·Σxⱼ)` over the
    /// groups in ascending order — exactly the order of
    /// [`Self::matmul_t`] / [`Self::matmul_t_rows`] — so the result is
    /// **bitwise identical** to those entry points for any span shape
    /// and any `threads` value.
    pub fn matmul_t_ragged(
        &self,
        x: &[f32],
        spans: &[usize],
        threads: usize,
        out: &mut [f32],
    ) -> Result<()> {
        let mut scr = KernelScratch::default();
        self.matmul_t_ragged_core(x, spans, threads, out, simd::active(), &mut scr)
    }

    /// [`Self::matmul_t_ragged`] with pooled scratch and an explicit
    /// SIMD tier — the projection driver's entry (model::blocks). The
    /// lane tier vectorizes across the concatenated x-rows (each lane
    /// one output row of the worker's chunk); when the batch is
    /// narrower than the lane width the scalar loop runs verbatim —
    /// either way every output element keeps the ascending
    /// (group, j) accumulation order, so results stay bitwise identical
    /// to every other entry point at every dispatch tier.
    pub(crate) fn matmul_t_ragged_core(
        &self,
        x: &[f32],
        spans: &[usize],
        threads: usize,
        out: &mut [f32],
        ops: &SimdOps,
        scr: &mut KernelScratch,
    ) -> Result<()> {
        let (rows, k, g) = (self.rows, self.cols, self.group);
        let ng = self.n_groups();
        let m: usize = spans.iter().sum();
        if spans.iter().any(|&s| s == 0) {
            bail!("matmul_t_ragged: empty sequence span");
        }
        if x.len() != m * k {
            bail!("matmul_t_ragged: x has {} elems, expected {}x{}", x.len(), m, k);
        }
        if out.len() != m * rows {
            bail!("matmul_t_ragged: out has {} elems, expected {}x{}", out.len(), m, rows);
        }
        if m == 0 || rows == 0 {
            return Ok(());
        }
        let KernelScratch { sx, sxt, xt, tiles, cuts, .. } = scr;
        group_sums_into(x, m, k, g, ng, sx);
        let (sd, zd) = (self.scales.data(), self.zeros.data());
        let (bits, sx_ref) = (self.bits, &*sx);
        let lanes = ops.lanes;
        let lane_tier = lanes > 1 && m >= lanes;
        if lane_tier {
            transpose_into(x, m, k, xt);
            transpose_into(sx_ref, m, ng, sxt);
        }
        let (xt_ref, sxt_ref) = (&*xt, &*sxt);
        ragged_cuts_into(spans, threads, m, cuts);
        let workers = cuts.len() - 1;
        tiles.clear();
        tiles.resize(workers * g, 0.0);
        // One worker's contiguous row chunk starting at x row `row0`.
        let work = |row0: usize, chunk: &mut [f32], tile: &mut [f32]| {
            let nb = chunk.len() / rows;
            chunk.fill(0.0);
            for r in 0..rows {
                let prow = self.row_bytes(r);
                for kg in 0..ng {
                    unpack_group(prow, bits, kg * g, tile, lane_tier);
                    let sc = sd[r * ng + kg];
                    let zp = zd[r * ng + kg];
                    if lane_tier {
                        let xg = &xt_ref[kg * g * m..];
                        let sxg = &sxt_ref[kg * m..(kg + 1) * m];
                        let mut ii = 0usize;
                        while ii < nb {
                            let bl = lanes.min(nb - ii);
                            let mut dots = [0.0f32; simd::MAX_LANES];
                            ops.dot_lanes(&mut dots[..bl], &xg[row0 + ii..], tile, m);
                            for (l, d) in dots[..bl].iter().enumerate() {
                                chunk[(ii + l) * rows + r] +=
                                    sc * (*d - zp * sxg[row0 + ii + l]);
                            }
                            ii += bl;
                        }
                    } else {
                        for ii in 0..nb {
                            let xseg =
                                &x[(row0 + ii) * k + kg * g..(row0 + ii) * k + (kg + 1) * g];
                            let mut dot = 0.0f32;
                            for j in 0..g {
                                dot += xseg[j] * tile[j];
                            }
                            chunk[ii * rows + r] +=
                                sc * (dot - zp * sx_ref[(row0 + ii) * ng + kg]);
                        }
                    }
                }
            }
        };
        if workers == 1 {
            work(0, out, &mut tiles[..g]);
            return Ok(());
        }
        std::thread::scope(|s| {
            let mut rest = out;
            let mut trest = tiles.as_mut_slice();
            for w in cuts.windows(2) {
                let (chunk, r) = std::mem::take(&mut rest).split_at_mut((w[1] - w[0]) * rows);
                rest = r;
                let (tile, tr) = std::mem::take(&mut trest).split_at_mut(g);
                trest = tr;
                let work = &work;
                let row0 = w[0];
                s.spawn(move || work(row0, chunk, tile));
            }
        });
        Ok(())
    }

    /// Single-row fused matvec: y = Ŵ·x for one activation row — the
    /// autoregressive decode hot path (one token per step). Row-parallel
    /// over the output rows and bitwise identical to a batch-1
    /// [`Self::matmul_t`].
    pub fn matvec_t(&self, x: &[f32], threads: usize, out: &mut [f32]) -> Result<()> {
        let mut scr = KernelScratch::default();
        self.matvec_t_core(x, threads, out, simd::active(), &mut scr)
    }

    /// [`Self::matvec_t`] with pooled scratch and an explicit SIMD tier.
    /// At batch 1 the lane tier runs weight-row lanes (each vector lane
    /// one output row against the shared x segment) — the decode-step
    /// shape where batch lanes cannot help.
    pub(crate) fn matvec_t_core(
        &self,
        x: &[f32],
        threads: usize,
        out: &mut [f32],
        ops: &SimdOps,
        scr: &mut KernelScratch,
    ) -> Result<()> {
        if x.len() != self.cols {
            bail!("matvec_t: x has {} elems, matrix has {} cols", x.len(), self.cols);
        }
        if out.len() != self.rows {
            bail!("matvec_t: out has {} elems, matrix has {} rows", out.len(), self.rows);
        }
        if self.rows == 0 {
            return Ok(());
        }
        out.fill(0.0);
        // For b = 1 the yᵀ (rows, 1) layout *is* y — no transpose needed.
        self.matmul_t_yt(x, 1, threads, out, ops, scr);
        Ok(())
    }

    /// Training backward, input side: dX = dY · Ŵ accumulated directly
    /// from the packed codes (dY is `(batch, rows)`, dX is
    /// `(batch, cols)`, overwritten). With Ŵ = s·(c − z) this is
    /// dX[i,j] = Σ_r dY[i,r]·s[r,g(j)]·(c[r,j] − z[r,g(j)]) — the
    /// gradient the host PEQA backend propagates to earlier layers while
    /// the codes stay frozen (train::host).
    ///
    /// Sharded over the *batch* rows of dX (each worker owns a
    /// contiguous dX slab); the weight-row loop is OUTER so each
    /// (row, group) code tile is unpacked once per worker and reused
    /// across the worker's whole dX chunk. Per dX row the accumulation
    /// order is (r, kg, j) ascending regardless of chunking, so results
    /// are bit-identical for any `threads` value.
    pub fn grad_input(
        &self,
        dy: &[f32],
        batch: usize,
        threads: usize,
        dx: &mut [f32],
    ) -> Result<()> {
        let mut scr = KernelScratch::default();
        self.grad_input_core(dy, batch, threads, dx, simd::active(), &mut scr)
    }

    /// [`Self::grad_input`] with pooled scratch and an explicit SIMD
    /// tier — the trainer backward's entry (train::host). The inner
    /// group update `seg[j] += a·s·(tile[j] − z)` is element-independent,
    /// so it routes through `ops.axpy_sub` unconditionally: the scalar
    /// tier's function *is* the verbatim baseline loop, and the vector
    /// tiers keep the per-element sub→mul→add rounding sequence.
    pub(crate) fn grad_input_core(
        &self,
        dy: &[f32],
        batch: usize,
        threads: usize,
        dx: &mut [f32],
        ops: &SimdOps,
        scr: &mut KernelScratch,
    ) -> Result<()> {
        let (rows, cols, g) = (self.rows, self.cols, self.group);
        let ng = self.n_groups();
        if dy.len() != batch * rows {
            bail!("grad_input: dy has {} elems, expected {}x{}", dy.len(), batch, rows);
        }
        if dx.len() != batch * cols {
            bail!("grad_input: dx has {} elems, expected {}x{}", dx.len(), batch, cols);
        }
        dx.fill(0.0);
        if batch == 0 || rows == 0 {
            return Ok(());
        }
        let (sd, zd) = (self.scales.data(), self.zeros.data());
        let bits = self.bits;
        let fast2 = ops.lanes > 1;
        let tiles = &mut scr.tiles;
        tiles.clear();
        tiles.resize(n_workers(threads, batch) * g, 0.0);
        par_row_chunks_tiled(dx, cols, batch, threads, tiles, g, |i0, chunk, tile| {
            let nb = chunk.len() / cols;
            for r in 0..rows {
                let prow = self.row_bytes(r);
                for kg in 0..ng {
                    unpack_group(prow, bits, kg * g, tile, fast2);
                    let sc = sd[r * ng + kg];
                    let zp = zd[r * ng + kg];
                    for ii in 0..nb {
                        let a = dy[(i0 + ii) * rows + r];
                        if a == 0.0 {
                            continue; // adding a·Ŵ with a == 0 is exact identity
                        }
                        let asc = a * sc;
                        let seg = &mut chunk[ii * cols + kg * g..ii * cols + (kg + 1) * g];
                        ops.axpy_sub(seg, asc, zp, tile);
                    }
                }
            }
        });
        Ok(())
    }

    /// Training backward, adapter side: the straight-through-estimator
    /// gradients of y = X·(s·(c − z))ᵀ w.r.t. the per-(row, group) scale
    /// and zero tensors, with the integer codes frozen (y is exactly
    /// linear in s and z, so these are exact, not approximate):
    ///
    /// ```text
    /// ds[r,g] = Σᵢ dY[i,r]·(Σ_{j∈g} X[i,j]·c[r,j] − z[r,g]·Σ_{j∈g} X[i,j])
    /// dz[r,g] = −s[r,g]·Σᵢ dY[i,r]·Σ_{j∈g} X[i,j]
    /// ```
    ///
    /// Returns `(ds, dz)` shaped like `scales`/`zeros`. One pass over the
    /// packed codes (each (row, group) tile unpacked once for the whole
    /// batch), sharded over weight rows with fixed-order accumulation —
    /// bit-identical for any `threads` value.
    pub fn grad_scales_zeros(
        &self,
        x: &[f32],
        dy: &[f32],
        batch: usize,
        threads: usize,
    ) -> Result<(Tensor, Tensor)> {
        let mut scr = KernelScratch::default();
        self.grad_scales_zeros_core(x, dy, batch, threads, simd::active(), &mut scr)
    }

    /// [`Self::grad_scales_zeros`] with pooled scratch and an explicit
    /// SIMD tier — the trainer backward's entry (train::host). The lane
    /// tier runs batch lanes for the per-(row, group) code dots; the
    /// `acc_s`/`acc_z` reductions then consume the lane results in the
    /// same ascending batch order as the scalar loop, so the gradients
    /// stay bitwise identical at every dispatch tier.
    // peqa-lint: allow(hot-path-alloc) -- training backward: one
    // adapter-gradient tensor pair per optimizer step, amortized over
    // the whole batch; never on the decode path. The staging buffers
    // (group sums, lane transposes, dsz interleave) are pooled in `scr`.
    pub(crate) fn grad_scales_zeros_core(
        &self,
        x: &[f32],
        dy: &[f32],
        batch: usize,
        threads: usize,
        ops: &SimdOps,
        scr: &mut KernelScratch,
    ) -> Result<(Tensor, Tensor)> {
        let (rows, k, g) = (self.rows, self.cols, self.group);
        let ng = self.n_groups();
        if x.len() != batch * k {
            bail!("grad_scales_zeros: x has {} elems, expected {}x{}", x.len(), batch, k);
        }
        if dy.len() != batch * rows {
            bail!("grad_scales_zeros: dy has {} elems, expected {}x{}", dy.len(), batch, rows);
        }
        // Interleaved per-row [ds…, dz…] staging so one row-parallel pass
        // fills both tensors.
        let KernelScratch { sx, sxt, xt, tiles, dsz, .. } = scr;
        dsz.clear();
        dsz.resize(rows * 2 * ng, 0.0);
        if batch > 0 && rows > 0 {
            group_sums_into(x, batch, k, g, ng, sx);
            let (sd, zd) = (self.scales.data(), self.zeros.data());
            let (bits, sx_ref) = (self.bits, &*sx);
            let lanes = ops.lanes;
            let lane_tier = lanes > 1 && batch >= lanes;
            if lane_tier {
                transpose_into(x, batch, k, xt);
                transpose_into(sx_ref, batch, ng, sxt);
            }
            let (xt_ref, sxt_ref) = (&*xt, &*sxt);
            tiles.clear();
            tiles.resize(n_workers(threads, rows) * g, 0.0);
            par_row_chunks_tiled(dsz, 2 * ng, rows, threads, tiles, g, |r0, chunk, tile| {
                for (ri, drow) in chunk.chunks_mut(2 * ng).enumerate() {
                    let r = r0 + ri;
                    let prow = self.row_bytes(r);
                    for kg in 0..ng {
                        unpack_group(prow, bits, kg * g, tile, lane_tier);
                        let sc = sd[r * ng + kg];
                        let zp = zd[r * ng + kg];
                        let mut acc_s = 0.0f32;
                        let mut acc_z = 0.0f32;
                        if lane_tier {
                            let xg = &xt_ref[kg * g * batch..];
                            let sxg = &sxt_ref[kg * batch..(kg + 1) * batch];
                            let mut bi = 0usize;
                            while bi < batch {
                                let bl = lanes.min(batch - bi);
                                let mut dots = [0.0f32; simd::MAX_LANES];
                                ops.dot_lanes(&mut dots[..bl], &xg[bi..], tile, batch);
                                for (l, d) in dots[..bl].iter().enumerate() {
                                    let dyv = dy[(bi + l) * rows + r];
                                    acc_s += dyv * (*d - zp * sxg[bi + l]);
                                    acc_z += dyv * sxg[bi + l];
                                }
                                bi += bl;
                            }
                        } else {
                            for bi in 0..batch {
                                let dyv = dy[bi * rows + r];
                                let xseg = &x[bi * k + kg * g..bi * k + (kg + 1) * g];
                                let mut dot = 0.0f32;
                                for j in 0..g {
                                    dot += xseg[j] * tile[j];
                                }
                                acc_s += dyv * (dot - zp * sx_ref[bi * ng + kg]);
                                acc_z += dyv * sx_ref[bi * ng + kg];
                            }
                        }
                        drow[kg] = acc_s;
                        drow[ng + kg] = -sc * acc_z;
                    }
                }
            });
        }
        let mut ds = vec![0.0f32; rows * ng];
        let mut dz = vec![0.0f32; rows * ng];
        for r in 0..rows {
            ds[r * ng..(r + 1) * ng].copy_from_slice(&dsz[r * 2 * ng..r * 2 * ng + ng]);
            dz[r * ng..(r + 1) * ng]
                .copy_from_slice(&dsz[r * 2 * ng + ng..(r + 1) * 2 * ng]);
        }
        Ok((Tensor::new(&[rows, ng], ds), Tensor::new(&[rows, ng], dz)))
    }

    /// Shared fused core: accumulate yᵀ (rows, b) += X·Ŵᵀ directly from
    /// the packed codes. `yt` must be zero-initialized by the caller; see
    /// the module docs for the group-sum zero-point identity.
    ///
    /// Three tiers, picked per call from `ops` (see module docs):
    /// * **batch lanes** (`b >= ops.lanes`) — X and the group sums are
    ///   transposed once so consecutive batch elements sit in consecutive
    ///   lanes; each group dot produces up to `lanes` output elements per
    ///   vector op, and the scale/zero combine consumes the lane results
    ///   in ascending batch order, matching the scalar loop.
    /// * **weight-row lanes** (`1 < b < ops.lanes`, covering matvec) —
    ///   up to `lanes` weight rows are unpacked into one interleaved tile
    ///   (`tile[j·lanes + l]` = code j of row l) so each lane carries one
    ///   output row's dot against the same x segment.
    /// * **scalar** (`ops.lanes == 1`) — the seed's loop, verbatim.
    fn matmul_t_yt(
        &self,
        xd: &[f32],
        b: usize,
        threads: usize,
        yt: &mut [f32],
        ops: &SimdOps,
        scr: &mut KernelScratch,
    ) {
        let (rows, g, k) = (self.rows, self.group, self.cols);
        let ng = self.n_groups();
        let KernelScratch { sx, sxt, xt, tiles, .. } = scr;
        group_sums_into(xd, b, k, g, ng, sx);
        // yᵀ (rows, b): each worker owns a contiguous slab of output rows.
        let (sd, zd) = (self.scales.data(), self.zeros.data());
        let (bits, sx_ref) = (self.bits, &*sx);
        let lanes = ops.lanes;
        if lanes > 1 && b >= lanes {
            // Batch-lane tier: lanes run across batch elements.
            transpose_into(xd, b, k, xt);
            transpose_into(sx_ref, b, ng, sxt);
            let (xt_ref, sxt_ref) = (&*xt, &*sxt);
            tiles.clear();
            tiles.resize(n_workers(threads, rows) * g, 0.0);
            par_row_chunks_tiled(yt, b, rows, threads, tiles, g, |r0, chunk, tile| {
                for (ri, yrow) in chunk.chunks_mut(b).enumerate() {
                    let r = r0 + ri;
                    let prow = self.row_bytes(r);
                    for kg in 0..ng {
                        unpack_group(prow, bits, kg * g, tile, true);
                        let sc = sd[r * ng + kg];
                        let zp = zd[r * ng + kg];
                        // Column kg·g + j of xᵀ starts at xt[(kg·g + j)·b];
                        // lane l of block bi reads xt[…·b + bi + l].
                        let xg = &xt_ref[kg * g * b..];
                        let sxg = &sxt_ref[kg * b..(kg + 1) * b];
                        let mut bi = 0usize;
                        while bi < b {
                            let bl = lanes.min(b - bi);
                            let mut dots = [0.0f32; simd::MAX_LANES];
                            ops.dot_lanes(&mut dots[..bl], &xg[bi..], tile, b);
                            for (l, d) in dots[..bl].iter().enumerate() {
                                yrow[bi + l] += sc * (*d - zp * sxg[bi + l]);
                            }
                            bi += bl;
                        }
                    }
                }
            });
        } else if lanes > 1 {
            // Weight-row-lane tier (small b, incl. matvec): lanes run
            // across output rows via an interleaved code tile.
            tiles.clear();
            tiles.resize(n_workers(threads, rows) * lanes * g, 0.0);
            par_row_chunks_tiled(yt, b, rows, threads, tiles, lanes * g, |r0, chunk, tilei| {
                let nr = chunk.len() / b;
                let mut rb = 0usize;
                while rb < nr {
                    let rl = lanes.min(nr - rb);
                    for kg in 0..ng {
                        for l in 0..rl {
                            let prow = self.row_bytes(r0 + rb + l);
                            pack::unpack_into_f32_strided(
                                prow,
                                bits,
                                kg * g,
                                &mut tilei[l..],
                                g,
                                lanes,
                            );
                        }
                        for bi in 0..b {
                            let xseg = &xd[bi * k + kg * g..bi * k + (kg + 1) * g];
                            let mut dots = [0.0f32; simd::MAX_LANES];
                            ops.dot_lanes(&mut dots[..rl], tilei, xseg, lanes);
                            for (l, d) in dots[..rl].iter().enumerate() {
                                let r = r0 + rb + l;
                                let sc = sd[r * ng + kg];
                                let zp = zd[r * ng + kg];
                                chunk[(rb + l) * b + bi] +=
                                    sc * (*d - zp * sx_ref[bi * ng + kg]);
                            }
                        }
                    }
                    rb += rl;
                }
            });
        } else {
            // Scalar tier: the seed's loop, verbatim, over a pooled tile.
            tiles.clear();
            tiles.resize(n_workers(threads, rows) * g, 0.0);
            par_row_chunks_tiled(yt, b, rows, threads, tiles, g, |r0, chunk, tile| {
                for (ri, yrow) in chunk.chunks_mut(b).enumerate() {
                    let r = r0 + ri;
                    let prow = self.row_bytes(r);
                    for kg in 0..ng {
                        pack::unpack_into_f32(prow, bits, kg * g, tile);
                        let sc = sd[r * ng + kg];
                        let zp = zd[r * ng + kg];
                        for bi in 0..b {
                            let xseg = &xd[bi * k + kg * g..bi * k + (kg + 1) * g];
                            let mut dot = 0.0f32;
                            for j in 0..g {
                                dot += xseg[j] * tile[j];
                            }
                            yrow[bi] += sc * (dot - zp * sx_ref[bi * ng + kg]);
                        }
                    }
                }
            });
        }
    }
}

/// Dequantize unpacked u8 codes (the [`QuantizedMatrix`] hot path):
/// out = s·(codes − z), fused scale application, row-parallel.
pub fn dequantize_codes(
    codes: &[u8],
    scales: &Tensor,
    zeros: &Tensor,
    rows: usize,
    cols: usize,
    group: usize,
) -> Tensor {
    assert_eq!(codes.len(), rows * cols, "codes/shape mismatch");
    let ng = if group == 0 { 0 } else { cols / group };
    assert_eq!(scales.shape(), [rows, ng].as_slice(), "scales shape");
    assert_eq!(zeros.shape(), [rows, ng].as_slice(), "zeros shape");
    // peqa-lint: allow(hot-path-alloc) -- backing store of the returned
    // dense tensor.
    let mut out = vec![0.0f32; rows * cols];
    let (sd, zd) = (scales.data(), zeros.data());
    par_row_chunks(&mut out, cols, rows, crate::util::num_threads(), |r0, chunk| {
        for (ri, orow) in chunk.chunks_mut(cols).enumerate() {
            let r = r0 + ri;
            let crow = &codes[r * cols..(r + 1) * cols];
            for kg in 0..ng {
                let sc = sd[r * ng + kg];
                let zp = zd[r * ng + kg];
                for j in kg * group..(kg + 1) * group {
                    orow[j] = sc * (crow[j] as f32 - zp);
                }
            }
        }
    });
    Tensor::new(&[rows, cols], out)
}

/// Dequantize f32-stored codes (checkpoint `.wq` tensors): same fused
/// kernel over a f32 code buffer. Caller has validated the shapes.
pub fn dequantize_f32_codes(
    wq: &[f32],
    scales: &[f32],
    zeros: &[f32],
    rows: usize,
    cols: usize,
    group: usize,
) -> Vec<f32> {
    let ng = if group == 0 { 0 } else { cols / group };
    // peqa-lint: allow(hot-path-alloc) -- backing store of the returned
    // dense buffer.
    let mut out = vec![0.0f32; rows * cols];
    par_row_chunks(&mut out, cols, rows, crate::util::num_threads(), |r0, chunk| {
        for (ri, orow) in chunk.chunks_mut(cols).enumerate() {
            let r = r0 + ri;
            let wrow = &wq[r * cols..(r + 1) * cols];
            for kg in 0..ng {
                let sc = scales[r * ng + kg];
                let zp = zeros[r * ng + kg];
                for j in kg * group..(kg + 1) * group {
                    orow[j] = sc * (wrow[j] - zp);
                }
            }
        }
    });
    out
}

/// The seed's scalar path, preserved verbatim: unpack codes with the
/// bit-cursor loop, materialize dense Ŵ with scalar dequant loops,
/// transpose, then the naive single-threaded ikj matmul. This is the
/// parity baseline for the tests and the reference side of the
/// kernels_micro bench.
// peqa-lint: allow(hot-path-alloc) -- the seed's scalar baseline,
// preserved verbatim as the parity/"before" reference; it exists to be
// the slow path.
pub fn reference_dequant_matmul(x: &Tensor, w: &PackedMatrix) -> Result<Tensor> {
    let (g, ng) = (w.group, w.n_groups());
    let mut dense = vec![0.0f32; w.rows * w.cols];
    for r in 0..w.rows {
        let codes = pack::unpack_codes_generic(w.row_bytes(r), w.bits, w.cols)?;
        for kg in 0..ng {
            let sc = w.scales.at2(r, kg);
            let zp = w.zeros.at2(r, kg);
            for j in 0..g {
                dense[r * w.cols + kg * g + j] = sc * (codes[kg * g + j] as f32 - zp);
            }
        }
    }
    let dense = Tensor::new(&[w.rows, w.cols], dense);
    x.matmul_naive(&dense.t())
}

fn check_adapter_shape(scales: &Tensor, zeros: &Tensor, rows: usize, ng: usize) -> Result<()> {
    if scales.shape() != [rows, ng].as_slice() {
        bail!("scales {:?}, expected [{rows}, {ng}]", scales.shape());
    }
    if zeros.shape() != [rows, ng].as_slice() {
        bail!("zeros {:?}, expected [{rows}, {ng}]", zeros.shape());
    }
    Ok(())
}

/// Per-(x-row, group) sums `Σ_{j∈g} X[i,j]`, accumulated in sequential
/// element order — the zero-point folding term `z·Σx` every fused entry
/// point pays once per group instead of once per element (module docs).
/// Lives exactly once: the bitwise-equality contract between
/// `matmul_t`/`matmul_t_rows`/`matmul_t_ragged`/`grad_scales_zeros`
/// depends on all of them folding the zero point through the SAME
/// reduction order.
fn group_sums_into(x: &[f32], m: usize, k: usize, g: usize, ng: usize, sx: &mut Vec<f32>) {
    sx.clear();
    sx.resize(m * ng, 0.0);
    for bi in 0..m {
        for kg in 0..ng {
            sx[bi * ng + kg] = x[bi * k + kg * g..bi * k + (kg + 1) * g].iter().sum();
        }
    }
}

/// Transpose a row-major `rows × cols` matrix into `dst` (col-major, i.e.
/// `dst[c·rows + r] = src[r·cols + c]`) — the once-per-call staging step
/// of the batch-lane SIMD tier, so batch elements become the contiguous
/// fast axis the lanes stride over.
fn transpose_into(src: &[f32], rows: usize, cols: usize, dst: &mut Vec<f32>) {
    dst.clear();
    dst.resize(rows * cols, 0.0);
    for r in 0..rows {
        for c in 0..cols {
            dst[c * rows + r] = src[r * cols + c];
        }
    }
}

/// Unpack one group of codes into an f32 tile, taking the 2-bit
/// multiply-spread fast path ([`simd::unpack2_into_f32`]) only on lane
/// tiers — the scalar tier keeps the seed's byte-wise unpacker verbatim.
/// Both produce identical tiles (codes are exact small integers), so this
/// split is about keeping the scalar path textually untouched, not about
/// values.
#[inline]
fn unpack_group(prow: &[u8], bits: u8, start: usize, tile: &mut [f32], fast2: bool) {
    if fast2 && bits == 2 {
        simd::unpack2_into_f32(prow, start, tile);
    } else {
        pack::unpack_into_f32(prow, bits, start, tile);
    }
}

/// Worker count [`par_row_chunks`]/[`par_row_chunks_tiled`] will actually
/// use for a `rows`-row job — callers size per-worker tile slabs off this.
#[inline]
fn n_workers(threads: usize, rows: usize) -> usize {
    threads.max(1).min(rows)
}

/// Worker boundaries for [`PackedMatrix::matmul_t_ragged`]: cut the
/// `m` concatenated rows into ≈`threads` contiguous chunks of at most
/// `⌈m/threads⌉` rows each, closing a chunk at a sequence-span boundary
/// when one lands on the budget and splitting inside a span otherwise
/// (any row cut is exact — output rows are mutually independent), so a
/// single long prefill span still fans out over all `threads` workers.
/// Returns ascending cut rows starting at 0 and ending at `m`.
fn ragged_cuts_into(spans: &[usize], threads: usize, m: usize, cuts: &mut Vec<usize>) {
    let threads = threads.max(1).min(m);
    let budget = m.div_ceil(threads);
    cuts.clear();
    cuts.push(0);
    let mut end = 0usize;
    for &sp in spans {
        end += sp;
        loop {
            let last = *cuts.last().unwrap();
            if end - last > budget {
                cuts.push(last + budget);
            } else {
                if end - last == budget && end < m {
                    cuts.push(end);
                }
                break;
            }
        }
    }
    if *cuts.last().unwrap() != m {
        cuts.push(m);
    }
}

/// Shard `out` (a `rows × elems_per_row` row-major buffer) into contiguous
/// per-worker row slabs and run `f(first_row, slab)` on scoped threads.
/// With `threads <= 1` (or a single row) the closure runs inline — the
/// compute path per row is identical either way, which is what makes every
/// kernel in this module thread-count invariant. Shared with the other
/// row-parallel host kernels (`model::blocks::dense_grad_rows_into`) so
/// there is one sharding policy.
pub(crate) fn par_row_chunks<F>(out: &mut [f32], elems_per_row: usize, rows: usize, threads: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    if rows == 0 || elems_per_row == 0 {
        return;
    }
    let threads = threads.max(1).min(rows);
    if threads == 1 {
        f(0, out);
        return;
    }
    let chunk_rows = rows.div_ceil(threads);
    std::thread::scope(|s| {
        for (t, chunk) in out.chunks_mut(chunk_rows * elems_per_row).enumerate() {
            let f = &f;
            s.spawn(move || f(t * chunk_rows, chunk));
        }
    });
}

/// [`par_row_chunks`] plus a per-worker tile: `tiles` is a pooled slab of
/// at least `n_workers(threads, rows) · tile_len` floats, sliced into one
/// disjoint `tile_len` window per worker and handed to
/// `f(first_row, slab, tile)`. This is how the kernel entries reuse their
/// group-code tiles across calls instead of allocating one per worker per
/// call (the retired hot-path-alloc exemptions).
pub(crate) fn par_row_chunks_tiled<F>(
    out: &mut [f32],
    elems_per_row: usize,
    rows: usize,
    threads: usize,
    tiles: &mut [f32],
    tile_len: usize,
    f: F,
) where
    F: Fn(usize, &mut [f32], &mut [f32]) + Sync,
{
    if rows == 0 || elems_per_row == 0 {
        return;
    }
    let workers = n_workers(threads, rows);
    if workers == 1 {
        f(0, out, &mut tiles[..tile_len]);
        return;
    }
    let chunk_rows = rows.div_ceil(workers);
    std::thread::scope(|s| {
        let mut trest = tiles;
        for (t, chunk) in out.chunks_mut(chunk_rows * elems_per_row).enumerate() {
            let (tile, rest) = std::mem::take(&mut trest).split_at_mut(tile_len);
            trest = rest;
            let f = &f;
            s.spawn(move || f(t * chunk_rows, chunk, tile));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::quantize_rtn;
    use crate::util::Pcg32;

    fn setup(
        rows: usize,
        cols: usize,
        batch: usize,
        bits: u8,
        group: Option<usize>,
        seed: u64,
    ) -> (Tensor, PackedMatrix) {
        let mut rng = Pcg32::new(seed);
        let w = Tensor::normal(&[rows, cols], 0.4, &mut rng);
        let x = Tensor::normal(&[batch, cols], 1.0, &mut rng);
        let q = quantize_rtn(&w, bits, group).unwrap();
        (x, PackedMatrix::from_quantized(&q))
    }

    /// Test-side shim over [`ragged_cuts_into`] (the production entry
    /// pools the cut vector; the cut-shape assertions below want a value).
    fn ragged_cuts(spans: &[usize], threads: usize, m: usize) -> Vec<usize> {
        let mut cuts = Vec::new();
        ragged_cuts_into(spans, threads, m, &mut cuts);
        cuts
    }

    /// The tentpole contract: every fused entry point must produce
    /// bitwise-identical f32 results under the detected vector tier and
    /// the scalar baseline, across bit widths, groupings, odd shapes
    /// (cols not a lane multiple, batch below/above the lane width,
    /// single row/col), thread counts, and a scratch reused across
    /// mismatched shapes. On a host without AVX2/NEON `detected()` ==
    /// `scalar()` and the test degenerates to self-consistency.
    #[test]
    fn simd_tiers_are_bitwise_equal_to_scalar_everywhere() {
        let (sc_ops, vec_ops) = (simd::scalar(), simd::detected());
        // One scratch pair reused across every shape: stale contents from
        // a previous (larger or smaller) call must never leak into results.
        let mut scr_s = KernelScratch::default();
        let mut scr_v = KernelScratch::default();
        for (rows, cols, batch) in [
            (13usize, 128usize, 9usize),
            (8, 48, 5),
            (37, 192, 1),
            (1, 64, 3),
            (5, 80, 16),
            (64, 64, 8),
        ] {
            for bits in [2u8, 3, 4] {
                for group in [None, Some(16), Some(cols / 4)] {
                    let tag = format!("rows={rows} cols={cols} b={batch} bits={bits} group={group:?}");
                    let (x, pm) = setup(rows, cols, batch, bits, group, 77 + bits as u64);
                    let mut rng = Pcg32::new(101);
                    let dy = Tensor::normal(&[batch, rows], 1.0, &mut rng);
                    for threads in [1usize, 3] {
                        // Fused GEMM (yT core, both batch- and row-lane tiers).
                        let ys = pm.matmul_t_with_ops(&x, threads, sc_ops).unwrap();
                        let yv = pm.matmul_t_with_ops(&x, threads, vec_ops).unwrap();
                        assert_eq!(ys.data(), yv.data(), "matmul {tag} threads={threads}");
                        // Ragged direct-layout entry, several span shapes.
                        let spans_set: &[Vec<usize>] = &[
                            vec![batch],
                            vec![1usize; batch],
                            if batch >= 3 { vec![batch - 2, 1, 1] } else { vec![batch] },
                        ];
                        for spans in spans_set {
                            let mut os = vec![f32::NAN; batch * rows];
                            let mut ov = vec![f32::NAN; batch * rows];
                            pm.matmul_t_ragged_core(x.data(), spans, threads, &mut os, sc_ops, &mut scr_s)
                                .unwrap();
                            pm.matmul_t_ragged_core(x.data(), spans, threads, &mut ov, vec_ops, &mut scr_v)
                                .unwrap();
                            assert_eq!(os, ov, "ragged {tag} spans={spans:?} threads={threads}");
                            assert_eq!(os.as_slice(), ys.data(), "ragged-vs-matmul {tag}");
                        }
                        // Decode matvec (row-lane tier on the vector side).
                        let mut rs = vec![0.0f32; rows];
                        let mut rv = vec![0.0f32; rows];
                        pm.matvec_t_core(&x.data()[..cols], threads, &mut rs, sc_ops, &mut scr_s)
                            .unwrap();
                        pm.matvec_t_core(&x.data()[..cols], threads, &mut rv, vec_ops, &mut scr_v)
                            .unwrap();
                        assert_eq!(rs, rv, "matvec {tag} threads={threads}");
                        // Backward, input side.
                        let mut dxs = vec![f32::NAN; batch * cols];
                        let mut dxv = vec![f32::NAN; batch * cols];
                        pm.grad_input_core(dy.data(), batch, threads, &mut dxs, sc_ops, &mut scr_s)
                            .unwrap();
                        pm.grad_input_core(dy.data(), batch, threads, &mut dxv, vec_ops, &mut scr_v)
                            .unwrap();
                        assert_eq!(dxs, dxv, "grad_input {tag} threads={threads}");
                        // Backward, adapter side.
                        let (dss, dzs) = pm
                            .grad_scales_zeros_core(x.data(), dy.data(), batch, threads, sc_ops, &mut scr_s)
                            .unwrap();
                        let (dsv, dzv) = pm
                            .grad_scales_zeros_core(x.data(), dy.data(), batch, threads, vec_ops, &mut scr_v)
                            .unwrap();
                        assert_eq!(dss.data(), dsv.data(), "ds {tag} threads={threads}");
                        assert_eq!(dzs.data(), dzv.data(), "dz {tag} threads={threads}");
                    }
                }
            }
        }
    }

    #[test]
    fn scalar_tier_is_the_seed_baseline_and_env_resolves_it() {
        // `resolve` is the PEQA_SIMD entry: "scalar" must pin the
        // lanes == 1 table (the verbatim seed loops); anything else is
        // the detected table.
        assert_eq!(simd::resolve(Some("scalar")).lanes, 1);
        assert_eq!(simd::resolve(None).name, simd::detected().name);
        // And the scalar table really drives the baseline path: results
        // match the seed's reference matmul within its tolerance.
        let (x, pm) = setup(11, 64, 4, 3, Some(16), 29);
        let y = pm.matmul_t_with_ops(&x, 2, simd::scalar()).unwrap();
        let yr = reference_dequant_matmul(&x, &pm).unwrap();
        assert!(y.max_abs_diff(&yr) <= 1e-4);
    }

    #[test]
    fn fused_matches_reference_across_bits_groups_and_odd_shapes() {
        // Odd row counts / batch sizes so no dimension is a multiple of
        // the worker shard size.
        for (rows, cols, batch) in [(37usize, 192usize, 5usize), (64, 64, 8), (3, 192, 1)] {
            for bits in [2u8, 3, 4] {
                for group in [None, Some(64), Some(16)] {
                    let (x, pm) = setup(rows, cols, batch, bits, group, 7 + bits as u64);
                    let y_ref = reference_dequant_matmul(&x, &pm).unwrap();
                    let y = pm.matmul_t(&x).unwrap();
                    assert_eq!(y.shape(), &[batch, rows]);
                    let d = y.max_abs_diff(&y_ref);
                    assert!(
                        d <= 1e-4,
                        "bits={bits} group={group:?} shape=({rows},{cols},{batch}): {d}"
                    );
                }
            }
        }
    }

    #[test]
    fn empty_and_single_row_edges() {
        // Empty batch.
        let (_, pm) = setup(6, 32, 4, 4, Some(16), 3);
        let x0 = Tensor::zeros(&[0, 32]);
        let y0 = pm.matmul_t(&x0).unwrap();
        assert_eq!(y0.shape(), &[0, 6]);
        // Single-row X and single-row W.
        let (x1, pm1) = setup(1, 48, 1, 3, None, 5);
        let y1 = pm1.matmul_t(&x1).unwrap();
        let yr = reference_dequant_matmul(&x1, &pm1).unwrap();
        assert_eq!(y1.shape(), &[1, 1]);
        assert!(y1.max_abs_diff(&yr) <= 1e-4);
    }

    #[test]
    fn thread_count_invariance_is_bitwise() {
        let (x, pm) = setup(53, 128, 7, 3, Some(64), 11);
        let y1 = pm.matmul_t_threads(&x, 1).unwrap();
        for threads in [2usize, 3, 8, 64] {
            let yn = pm.matmul_t_threads(&x, threads).unwrap();
            assert_eq!(y1.data(), yn.data(), "threads={threads}");
        }
        let d1 = pm.dequantize_with_threads(&pm.scales, &pm.zeros, 1).unwrap();
        let dn = pm.dequantize_with_threads(&pm.scales, &pm.zeros, 5).unwrap();
        assert_eq!(d1.data(), dn.data());
    }

    #[test]
    fn matvec_and_row_entry_points_match_matmul_bitwise() {
        let (x, pm) = setup(41, 96, 6, 3, Some(16), 19);
        let y = pm.matmul_t(&x).unwrap();
        let (b, k) = x.dims2().unwrap();
        // Batched raw-slice path.
        for threads in [1usize, 4] {
            let mut out = vec![0.0f32; b * pm.rows];
            pm.matmul_t_rows(x.data(), b, threads, &mut out).unwrap();
            assert_eq!(out.as_slice(), y.data(), "threads={threads}");
        }
        // Single-row matvec path, row by row.
        for bi in 0..b {
            let mut row = vec![0.0f32; pm.rows];
            pm.matvec_t(&x.data()[bi * k..(bi + 1) * k], 3, &mut row).unwrap();
            assert_eq!(row.as_slice(), &y.data()[bi * pm.rows..(bi + 1) * pm.rows], "bi={bi}");
        }
        // Shape errors are rejected, not mis-indexed.
        let mut bad = vec![0.0f32; pm.rows + 1];
        assert!(pm.matvec_t(&x.data()[..k], 1, &mut bad).is_err());
        assert!(pm.matvec_t(&x.data()[..k - 1], 1, &mut bad[..pm.rows]).is_err());
        let mut out = vec![0.0f32; b * pm.rows];
        assert!(pm.matmul_t_rows(&x.data()[1..], b, 1, &mut out).is_err());
    }

    #[test]
    fn scratch_entry_point_reuses_buffer_and_stays_bitwise() {
        // One scratch vec carried across calls of different batch sizes
        // and matrices (the serving pattern) must never change results.
        let mut yt = Vec::new();
        for (rows, cols, batch, seed) in
            [(23usize, 64usize, 9usize, 3u64), (40, 96, 2, 5), (7, 32, 1, 8)]
        {
            let (x, pm) = setup(rows, cols, batch, 3, Some(16), seed);
            let y = pm.matmul_t(&x).unwrap();
            let mut out = vec![f32::NAN; batch * rows]; // stale garbage
            pm.matmul_t_rows_scratch(x.data(), batch, 4, &mut out, &mut yt).unwrap();
            assert_eq!(out.as_slice(), y.data(), "rows={rows} batch={batch}");
        }
    }

    #[test]
    fn ragged_entry_is_bitwise_equal_to_rows_entry() {
        // matmul_t_ragged must reproduce matmul_t_rows bit for bit across
        // bit-widths, groupings, span shapes (single long prefill, pure
        // decode, mixed ragged) and worker counts.
        for bits in [2u8, 3, 4] {
            for group in [None, Some(16)] {
                let (x, pm) = setup(21, 64, 9, bits, group, 51 + bits as u64);
                let (b, _) = x.dims2().unwrap();
                let mut expect = vec![0.0f32; b * pm.rows];
                pm.matmul_t_rows(x.data(), b, 1, &mut expect).unwrap();
                for spans in [
                    vec![9usize],                // one long prefill block
                    vec![1usize; 9],             // pure decode batch
                    vec![5usize, 1, 2, 1],       // mixed prefill + decode
                    vec![2usize, 7],
                ] {
                    for threads in [1usize, 2, 3, 8] {
                        let mut out = vec![f32::NAN; b * pm.rows]; // stale garbage
                        pm.matmul_t_ragged(x.data(), &spans, threads, &mut out).unwrap();
                        assert_eq!(
                            out, expect,
                            "bits={bits} group={group:?} spans={spans:?} threads={threads}"
                        );
                    }
                }
            }
        }
        // Shape errors are rejected, not mis-indexed.
        let (x, pm) = setup(6, 32, 4, 4, Some(16), 3);
        let mut out = vec![0.0f32; 4 * pm.rows];
        assert!(pm.matmul_t_ragged(x.data(), &[2, 0, 2], 2, &mut out).is_err());
        assert!(pm.matmul_t_ragged(x.data(), &[3], 2, &mut out).is_err());
        assert!(pm.matmul_t_ragged(&x.data()[1..], &[4], 2, &mut out).is_err());
        assert!(pm.matmul_t_ragged(x.data(), &[4], 2, &mut out[1..]).is_err());
    }

    #[test]
    fn ragged_cuts_cover_rows_and_fan_out_every_shape() {
        // Pure decode: every span is its own chunk at enough threads.
        assert_eq!(ragged_cuts(&[1, 1, 1, 1], 4, 4), vec![0, 1, 2, 3, 4]);
        // One long prefill splits by rows across all workers.
        let c = ragged_cuts(&[48], 8, 48);
        assert_eq!(c, vec![0, 6, 12, 18, 24, 30, 36, 42, 48]);
        // A single span that is not a multiple of the budget still fans
        // out (the shape that would lose parallelism with a
        // boundary-only rule).
        assert_eq!(ragged_cuts(&[9], 2, 9), vec![0, 5, 9]);
        assert_eq!(ragged_cuts(&[31, 31], 4, 62), vec![0, 16, 32, 48, 62]);
        // Mixed prefill + decode: boundaries close chunks when they land
        // on the budget.
        let c = ragged_cuts(&[5, 1, 2, 1], 3, 9);
        assert_eq!(c, vec![0, 3, 6, 9]);
        // Never more chunks than threads; always cover [0, m] ascending.
        for (spans, threads) in
            [(vec![7usize, 1, 1, 3], 3usize), (vec![2, 9, 2], 5), (vec![4], 16)]
        {
            let m: usize = spans.iter().sum();
            let c = ragged_cuts(&spans, threads, m);
            assert_eq!((c[0], *c.last().unwrap()), (0, m), "{spans:?}");
            assert!(c.windows(2).all(|w| w[0] < w[1]), "{spans:?}: {c:?}");
            assert!(c.len() - 1 <= threads.min(m), "{spans:?}: {c:?}");
        }
        // Single thread: one chunk.
        assert_eq!(ragged_cuts(&[3, 3], 1, 6), vec![0, 6]);
    }

    #[test]
    fn grad_input_matches_dense_backward() {
        // dX = dY · Ŵ against the dense reference (dequantize, then an
        // explicit f64-accumulated matmul), across widths and groupings.
        for bits in [2u8, 3, 4] {
            for group in [None, Some(16)] {
                let (x, pm) = setup(13, 64, 5, bits, group, 43 + bits as u64);
                let (b, _) = x.dims2().unwrap();
                let mut rng = Pcg32::new(91);
                let dy = Tensor::normal(&[b, pm.rows], 1.0, &mut rng);
                let mut dx = vec![f32::NAN; b * pm.cols];
                pm.grad_input(dy.data(), b, 3, &mut dx).unwrap();
                let w = pm.dequantize();
                for i in 0..b {
                    for j in 0..pm.cols {
                        let mut acc = 0.0f64;
                        for r in 0..pm.rows {
                            acc += dy.at2(i, r) as f64 * w.at2(r, j) as f64;
                        }
                        let d = (dx[i * pm.cols + j] as f64 - acc).abs();
                        assert!(
                            d <= 1e-3 * acc.abs().max(1.0),
                            "bits={bits} group={group:?} ({i},{j}): {d}"
                        );
                    }
                }
                // Thread invariance is bitwise.
                let mut dx1 = vec![0.0f32; b * pm.cols];
                pm.grad_input(dy.data(), b, 1, &mut dx1).unwrap();
                assert_eq!(dx, dx1);
                // Shape errors rejected.
                assert!(pm.grad_input(&dy.data()[1..], b, 1, &mut dx).is_err());
                assert!(pm.grad_input(dy.data(), b, 1, &mut dx[1..]).is_err());
            }
        }
    }

    #[test]
    fn scale_zero_grads_match_finite_differences() {
        // L(s, z) = Σ w ⊙ (X·Ŵᵀ) is linear in s and z (codes frozen), so
        // central differences are exact up to f32 rounding; the analytic
        // reductions must land within 1e-3 relative of them. This is the
        // kernel half of the acceptance gradcheck (the model-level check
        // lives in tests/train_host.rs).
        for bits in [2u8, 3, 4] {
            for group in [None, Some(16)] {
                let (x, pm) = setup(9, 48, 4, bits, group, 7 + bits as u64);
                let (b, _) = x.dims2().unwrap();
                let mut rng = Pcg32::new(5);
                let dy = Tensor::normal(&[b, pm.rows], 1.0, &mut rng);
                let loss = |m: &PackedMatrix| -> f64 {
                    let y = m.matmul_t(&x).unwrap();
                    y.data().iter().zip(dy.data()).map(|(&a, &w)| (a * w) as f64).sum()
                };
                let (ds, dz) = pm.grad_scales_zeros(x.data(), dy.data(), b, 4).unwrap();
                let ng = pm.n_groups();
                for r in 0..pm.rows {
                    for kg in 0..ng {
                        for (which, grad) in [("s", ds.at2(r, kg)), ("z", dz.at2(r, kg))] {
                            let mut hi = pm.clone();
                            let mut lo = pm.clone();
                            let (t_hi, t_lo, v) = if which == "s" {
                                (&mut hi.scales, &mut lo.scales, pm.scales.at2(r, kg))
                            } else {
                                (&mut hi.zeros, &mut lo.zeros, pm.zeros.at2(r, kg))
                            };
                            let h = (0.01 * v.abs()).max(1e-3);
                            t_hi.set2(r, kg, v + h);
                            t_lo.set2(r, kg, v - h);
                            let fd = (loss(&hi) - loss(&lo)) / (2.0 * h as f64);
                            let d = (grad as f64 - fd).abs();
                            assert!(
                                d <= 1e-3 * fd.abs().max(1e-2),
                                "bits={bits} group={group:?} {which}[{r},{kg}]: \
                                 analytic {grad} vs fd {fd}"
                            );
                        }
                    }
                }
                // Bitwise thread invariance of the reductions.
                let (ds1, dz1) = pm.grad_scales_zeros(x.data(), dy.data(), b, 1).unwrap();
                assert_eq!(ds.data(), ds1.data());
                assert_eq!(dz.data(), dz1.data());
            }
        }
    }

    #[test]
    fn pack_roundtrip_and_contiguous_loader() {
        for (bits, cols) in [(3u8, 20usize), (4, 24), (2, 17)] {
            let mut rng = Pcg32::new(31 + bits as u64);
            let w = Tensor::normal(&[5, cols], 0.5, &mut rng);
            let q = quantize_rtn(&w, bits, None).unwrap();
            let pm = PackedMatrix::from_quantized(&q);
            assert_eq!(pm.to_quantized().unwrap().codes, q.codes, "bits={bits}");
            // The .packed file stream is contiguous (not row-aligned);
            // from_contiguous must land on the same matrix.
            let stream = pack::pack_codes(&q.codes, bits);
            let pm2 = PackedMatrix::from_contiguous(
                &stream,
                5,
                cols,
                bits,
                q.scales.clone(),
                q.zeros.clone(),
            )
            .unwrap();
            assert_eq!(pm2.to_quantized().unwrap().codes, q.codes);
            assert_eq!(pm.dequantize().data(), pm2.dequantize().data());
        }
    }

    #[test]
    fn dequantize_matches_unpacked_matrix_bitwise() {
        let (_, pm) = setup(19, 96, 1, 3, Some(16), 13);
        let q = pm.to_quantized().unwrap();
        // Same fused formula on both paths — element-wise, so exact.
        assert_eq!(pm.dequantize().data(), q.dequantize().data());
    }

    #[test]
    fn dequantize_with_swaps_scales_without_touching_codes() {
        let (_, pm) = setup(8, 32, 1, 4, None, 17);
        let mut s2 = pm.scales.clone();
        for v in s2.data_mut() {
            *v *= 2.0;
        }
        let a = pm.dequantize();
        let b = pm.dequantize_with(&s2, &pm.zeros).unwrap();
        for (x, y) in a.data().iter().zip(b.data()) {
            assert!((y - 2.0 * x).abs() < 1e-5);
        }
        // Shape mismatch is rejected, not silently mis-indexed.
        assert!(pm.dequantize_with(&Tensor::zeros(&[8, 2]), &pm.zeros).is_err());
    }

    #[test]
    fn clone_shares_codes_but_owns_scales() {
        // The engine-pool memory contract: cloning a packed matrix must
        // share the one physical copy of the integer codes (Arc) while
        // giving the clone its own scale/zero tensors to swap per task.
        let (x, pm) = setup(4, 32, 2, 4, Some(16), 23);
        let mut c = pm.clone();
        assert!(pm.codes_shared_with(&c));
        for v in c.scales.data_mut() {
            *v *= 1.5;
        }
        // The original's scales (and its outputs) are untouched…
        let y0 = pm.matmul_t(&x).unwrap();
        let y1 = c.matmul_t(&x).unwrap();
        assert!(y0.max_abs_diff(&y1) > 0.0, "clone's scale edit must not alias the original");
        // …and the codes are still byte-identical (same buffer).
        assert_eq!(pm.to_quantized().unwrap().codes, c.to_quantized().unwrap().codes);
        // An independently built matrix does not share codes.
        let (_, other) = setup(4, 32, 2, 4, Some(16), 23);
        assert!(!pm.codes_shared_with(&other));
    }

    #[test]
    fn contiguous_loader_rejects_bad_input() {
        let scales = Tensor::ones(&[4, 1]);
        let zeros = Tensor::zeros(&[4, 1]);
        // Stream too short.
        assert!(PackedMatrix::from_contiguous(&[0u8; 2], 4, 16, 4, scales.clone(), zeros.clone())
            .is_err());
        // Scales that do not tile the matrix.
        let bad_s = Tensor::ones(&[4, 3]);
        let bad_z = Tensor::zeros(&[4, 3]);
        assert!(PackedMatrix::from_contiguous(&[0u8; 32], 4, 16, 4, bad_s, bad_z).is_err());
    }
}
