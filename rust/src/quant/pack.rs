//! Packed sub-4-bit integer storage — where the paper's model-size numbers
//! come from (Tables 1/4, Fig. 2a).
//!
//! Codes are bit-packed little-endian within a byte stream: b bits per
//! code, codes crossing byte boundaries allowed, so storage is exactly
//! ⌈n·b/8⌉ bytes for n codes (3-bit: 8 codes in 3 bytes; 4-bit: 2/byte).
//! Scales and zero-points stay f32 (they are the per-task adapter).
//!
//! Two implementations share the format:
//! * a u64 word-wise fast path for bits ∈ {2, 3, 4, 8} (the widths the
//!   paper and the kernel layer use) that extracts up to ⌊57/b⌋ codes per
//!   64-bit load instead of doing per-code byte read-modify-writes, and
//! * the original scalar bit-cursor path, kept as `pack_codes_generic` /
//!   `unpack_codes_generic` — the fallback for other widths and the
//!   correctness baseline the tests compare against.

use anyhow::{bail, Result};

/// Bit widths the word-wise fast path covers.
#[inline]
fn has_fast_path(bits: u8) -> bool {
    matches!(bits, 2 | 3 | 4 | 8)
}

/// Read up to 8 bytes at `byte` as a little-endian u64, zero-padding past
/// the end of the stream (safe for tail reads).
#[inline]
fn read_word(packed: &[u8], byte: usize) -> u64 {
    if byte + 8 <= packed.len() {
        u64::from_le_bytes(packed[byte..byte + 8].try_into().unwrap())
    } else {
        let mut buf = [0u8; 8];
        let n = packed.len().saturating_sub(byte);
        buf[..n].copy_from_slice(&packed[byte..byte + n]);
        u64::from_le_bytes(buf)
    }
}

/// Pack `codes` (each < 2^bits) into a bit stream.
pub fn pack_codes(codes: &[u8], bits: u8) -> Vec<u8> {
    assert!((1..=8).contains(&bits));
    if has_fast_path(bits) {
        pack_codes_words(codes, bits)
    } else {
        pack_codes_generic(codes, bits)
    }
}

/// Word-wise packing: accumulate codes into a u64 shift register and emit
/// full bytes, instead of per-code masked read-modify-writes on the output.
fn pack_codes_words(codes: &[u8], bits: u8) -> Vec<u8> {
    let b = bits as usize;
    let mask = ((1u16 << bits) - 1) as u8;
    let mut out = Vec::with_capacity(packed_size(codes.len(), bits));
    let mut acc: u64 = 0;
    let mut nbits: usize = 0;
    for &c in codes {
        acc |= ((c & mask) as u64) << nbits;
        nbits += b;
        while nbits >= 8 {
            out.push(acc as u8);
            acc >>= 8;
            nbits -= 8;
        }
    }
    if nbits > 0 {
        out.push(acc as u8);
    }
    out
}

/// Scalar bit-cursor packing (all widths; the original implementation).
pub fn pack_codes_generic(codes: &[u8], bits: u8) -> Vec<u8> {
    assert!((1..=8).contains(&bits));
    let total_bits = codes.len() * bits as usize;
    let mut out = vec![0u8; total_bits.div_ceil(8)];
    let mask = ((1u16 << bits) - 1) as u8;
    let mut bitpos = 0usize;
    for &c in codes {
        let c = c & mask;
        let byte = bitpos / 8;
        let off = bitpos % 8;
        out[byte] |= c << off;
        if off + bits as usize > 8 {
            out[byte + 1] |= c >> (8 - off);
        }
        bitpos += bits as usize;
    }
    out
}

/// Inverse of `pack_codes`.
pub fn unpack_codes(packed: &[u8], bits: u8, n: usize) -> Result<Vec<u8>> {
    assert!((1..=8).contains(&bits));
    let need = (n * bits as usize).div_ceil(8);
    if packed.len() < need {
        bail!("packed stream too short: {} < {need}", packed.len());
    }
    if has_fast_path(bits) {
        Ok(unpack_codes_words(packed, bits, n))
    } else {
        unpack_codes_generic(packed, bits, n)
    }
}

/// Word-wise unpacking: one u64 load yields up to ⌊(64−7)/b⌋ codes
/// (19 codes per load at 3-bit) instead of 1–2 byte loads per code.
fn unpack_codes_words(packed: &[u8], bits: u8, n: usize) -> Vec<u8> {
    let b = bits as usize;
    let mask = ((1u64 << bits) - 1) as u64;
    let mut out = Vec::with_capacity(n);
    let mut i = 0usize;
    let mut bitpos = 0usize;
    while i < n {
        let byte = bitpos >> 3;
        let off = bitpos & 7;
        let mut w = read_word(packed, byte) >> off;
        let take = ((64 - off) / b).min(n - i);
        for _ in 0..take {
            out.push((w & mask) as u8);
            w >>= b;
        }
        i += take;
        bitpos += take * b;
    }
    out
}

/// Scalar bit-cursor unpacking (all widths; the original implementation).
pub fn unpack_codes_generic(packed: &[u8], bits: u8, n: usize) -> Result<Vec<u8>> {
    assert!((1..=8).contains(&bits));
    let need = (n * bits as usize).div_ceil(8);
    if packed.len() < need {
        bail!("packed stream too short: {} < {need}", packed.len());
    }
    let mask = ((1u16 << bits) - 1) as u8;
    let mut out = Vec::with_capacity(n);
    let mut bitpos = 0usize;
    for _ in 0..n {
        let byte = bitpos / 8;
        let off = bitpos % 8;
        let mut v = packed[byte] >> off;
        if off + bits as usize > 8 {
            v |= packed[byte + 1] << (8 - off);
        }
        out.push(v & mask);
        bitpos += bits as usize;
    }
    Ok(out)
}

/// Unpack `out.len()` codes starting at code index `start` directly into an
/// f32 tile — the kernel layer's inner unpacker (quant::kernels), word-wise
/// for every width. The caller guarantees the stream covers the range.
#[inline]
pub fn unpack_into_f32(packed: &[u8], bits: u8, start: usize, out: &mut [f32]) {
    let b = bits as usize;
    let mask = (1u64 << bits) - 1;
    let n = out.len();
    let mut i = 0usize;
    let mut bitpos = start * b;
    while i < n {
        let byte = bitpos >> 3;
        let off = bitpos & 7;
        let mut w = read_word(packed, byte) >> off;
        let take = ((64 - off) / b).min(n - i);
        for _ in 0..take {
            out[i] = (w & mask) as f32;
            w >>= b;
            i += 1;
        }
        bitpos += take * b;
    }
}

/// Strided variant of [`unpack_into_f32`]: code `i` lands at
/// `out[i * stride]` (the interleaved lane tiles the SIMD kernel tiers
/// build — one weight row per lane, `stride` = lane count). `count` codes
/// are written; `out` must cover `(count - 1) * stride + 1` slots.
#[inline]
pub fn unpack_into_f32_strided(
    packed: &[u8],
    bits: u8,
    start: usize,
    out: &mut [f32],
    count: usize,
    stride: usize,
) {
    debug_assert!(count == 0 || out.len() > (count - 1) * stride);
    let b = bits as usize;
    let mask = (1u64 << bits) - 1;
    let mut i = 0usize;
    let mut bitpos = start * b;
    while i < count {
        let byte = bitpos >> 3;
        let off = bitpos & 7;
        let mut w = read_word(packed, byte) >> off;
        let take = ((64 - off) / b).min(count - i);
        for _ in 0..take {
            out[i * stride] = (w & mask) as f32;
            w >>= b;
            i += 1;
        }
        bitpos += take * b;
    }
}

/// Exact packed size in bytes for `n` codes at `bits` width.
pub fn packed_size(n: usize, bits: u8) -> usize {
    (n * bits as usize).div_ceil(8)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    #[test]
    fn roundtrip_all_bit_widths() {
        let mut rng = Pcg32::new(17);
        for bits in 1..=8u8 {
            for n in [0usize, 1, 7, 8, 9, 64, 1000, 1023] {
                let codes: Vec<u8> =
                    (0..n).map(|_| (rng.next_u32() & ((1 << bits) - 1)) as u8).collect();
                let packed = pack_codes(&codes, bits);
                assert_eq!(packed.len(), packed_size(n, bits));
                let back = unpack_codes(&packed, bits, n).unwrap();
                assert_eq!(back, codes, "bits={bits} n={n}");
            }
        }
    }

    #[test]
    fn fast_paths_match_generic() {
        // Word-wise and scalar implementations must produce identical
        // streams and identical codes for every width/length combination.
        let mut rng = Pcg32::new(23);
        for bits in 1..=8u8 {
            for n in [1usize, 3, 8, 17, 63, 64, 65, 509] {
                let codes: Vec<u8> =
                    (0..n).map(|_| (rng.next_u32() & ((1 << bits) - 1)) as u8).collect();
                let fast = pack_codes(&codes, bits);
                let slow = pack_codes_generic(&codes, bits);
                assert_eq!(fast, slow, "pack bits={bits} n={n}");
                let back_fast = unpack_codes(&fast, bits, n).unwrap();
                let back_slow = unpack_codes_generic(&slow, bits, n).unwrap();
                assert_eq!(back_fast, back_slow, "unpack bits={bits} n={n}");
            }
        }
    }

    #[test]
    fn unpack_into_f32_matches_unpack_codes() {
        let mut rng = Pcg32::new(29);
        for bits in [2u8, 3, 4, 5, 8] {
            let n = 200usize;
            let codes: Vec<u8> =
                (0..n).map(|_| (rng.next_u32() & ((1 << bits) - 1)) as u8).collect();
            let packed = pack_codes(&codes, bits);
            // Whole stream and unaligned interior ranges.
            for (start, len) in [(0usize, n), (7, 64), (33, 13), (n - 1, 1)] {
                let mut tile = vec![0.0f32; len];
                unpack_into_f32(&packed, bits, start, &mut tile);
                for (j, &v) in tile.iter().enumerate() {
                    assert_eq!(v, codes[start + j] as f32, "bits={bits} start={start} j={j}");
                }
            }
        }
    }

    #[test]
    fn strided_unpack_matches_contiguous() {
        let mut rng = Pcg32::new(31);
        for bits in [2u8, 3, 4] {
            let n = 150usize;
            let codes: Vec<u8> =
                (0..n).map(|_| (rng.next_u32() & ((1 << bits) - 1)) as u8).collect();
            let packed = pack_codes(&codes, bits);
            for (start, len, stride) in [(0usize, 64usize, 4usize), (5, 33, 8), (n - 3, 3, 2)] {
                let mut flat = vec![0.0f32; len];
                unpack_into_f32(&packed, bits, start, &mut flat);
                let mut strided = vec![-1.0f32; len * stride];
                unpack_into_f32_strided(&packed, bits, start, &mut strided, len, stride);
                for j in 0..len {
                    assert_eq!(
                        strided[j * stride],
                        flat[j],
                        "bits={bits} start={start} stride={stride} j={j}"
                    );
                }
            }
        }
    }

    #[test]
    fn three_bit_density() {
        // 8 × 3-bit codes in exactly 3 bytes — the "sub-4-bit" headline.
        assert_eq!(packed_size(8, 3), 3);
        assert_eq!(packed_size(1024, 3), 384);
        assert_eq!(packed_size(1024, 4), 512);
        assert_eq!(packed_size(1024, 16), 2048); // fp16 reference
    }

    #[test]
    fn known_bit_pattern() {
        // 4-bit codes [0x1, 0xF] -> single byte 0xF1 (little-endian in byte).
        assert_eq!(pack_codes(&[0x1, 0xF], 4), vec![0xF1]);
        // 3-bit codes [7, 7, 7] -> bits 111_111_111 -> bytes [0xFF, 0x01].
        assert_eq!(pack_codes(&[7, 7, 7], 3), vec![0xFF, 0x01]);
    }

    #[test]
    fn short_stream_rejected() {
        assert!(unpack_codes(&[0xFF], 4, 3).is_err());
        assert!(unpack_codes_generic(&[0xFF], 4, 3).is_err());
    }
}
