//! Packed sub-4-bit integer storage — where the paper's model-size numbers
//! come from (Tables 1/4, Fig. 2a).
//!
//! Codes are bit-packed little-endian within a byte stream: b bits per
//! code, codes crossing byte boundaries allowed, so storage is exactly
//! ⌈n·b/8⌉ bytes for n codes (3-bit: 8 codes in 3 bytes; 4-bit: 2/byte).
//! Scales and zero-points stay f32 (they are the per-task adapter).

use anyhow::{bail, Result};

/// Pack `codes` (each < 2^bits) into a bit stream.
pub fn pack_codes(codes: &[u8], bits: u8) -> Vec<u8> {
    assert!((1..=8).contains(&bits));
    let total_bits = codes.len() * bits as usize;
    let mut out = vec![0u8; total_bits.div_ceil(8)];
    let mask = ((1u16 << bits) - 1) as u8;
    let mut bitpos = 0usize;
    for &c in codes {
        let c = c & mask;
        let byte = bitpos / 8;
        let off = bitpos % 8;
        out[byte] |= c << off;
        if off + bits as usize > 8 {
            out[byte + 1] |= c >> (8 - off);
        }
        bitpos += bits as usize;
    }
    out
}

/// Inverse of `pack_codes`.
pub fn unpack_codes(packed: &[u8], bits: u8, n: usize) -> Result<Vec<u8>> {
    assert!((1..=8).contains(&bits));
    let need = (n * bits as usize).div_ceil(8);
    if packed.len() < need {
        bail!("packed stream too short: {} < {need}", packed.len());
    }
    let mask = ((1u16 << bits) - 1) as u8;
    let mut out = Vec::with_capacity(n);
    let mut bitpos = 0usize;
    for _ in 0..n {
        let byte = bitpos / 8;
        let off = bitpos % 8;
        let mut v = packed[byte] >> off;
        if off + bits as usize > 8 {
            v |= packed[byte + 1] << (8 - off);
        }
        out.push(v & mask);
        bitpos += bits as usize;
    }
    Ok(out)
}

/// Exact packed size in bytes for `n` codes at `bits` width.
pub fn packed_size(n: usize, bits: u8) -> usize {
    (n * bits as usize).div_ceil(8)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    #[test]
    fn roundtrip_all_bit_widths() {
        let mut rng = Pcg32::new(17);
        for bits in 1..=8u8 {
            for n in [0usize, 1, 7, 8, 9, 64, 1000, 1023] {
                let codes: Vec<u8> =
                    (0..n).map(|_| (rng.next_u32() & ((1 << bits) - 1)) as u8).collect();
                let packed = pack_codes(&codes, bits);
                assert_eq!(packed.len(), packed_size(n, bits));
                let back = unpack_codes(&packed, bits, n).unwrap();
                assert_eq!(back, codes, "bits={bits} n={n}");
            }
        }
    }

    #[test]
    fn three_bit_density() {
        // 8 × 3-bit codes in exactly 3 bytes — the "sub-4-bit" headline.
        assert_eq!(packed_size(8, 3), 3);
        assert_eq!(packed_size(1024, 3), 384);
        assert_eq!(packed_size(1024, 4), 512);
        assert_eq!(packed_size(1024, 16), 2048); // fp16 reference
    }

    #[test]
    fn known_bit_pattern() {
        // 4-bit codes [0x1, 0xF] -> single byte 0xF1 (little-endian in byte).
        assert_eq!(pack_codes(&[0x1, 0xF], 4), vec![0xF1]);
        // 3-bit codes [7, 7, 7] -> bits 111_111_111 -> bytes [0xFF, 0x01].
        assert_eq!(pack_codes(&[7, 7, 7], 3), vec![0xFF, 0x01]);
    }

    #[test]
    fn short_stream_rejected() {
        assert!(unpack_codes(&[0xFF], 4, 3).is_err());
    }
}
