//! OPTQ (GPTQ; Frantar et al. 2023) — the PTQ baseline of Tables 2/3.
//!
//! Column-by-column quantization with second-order error feedback: after
//! quantizing column j, the rounding error is propagated into the not-yet
//! quantized columns through the inverse-Hessian Cholesky factor, which
//! minimizes the *layer-output* error  tr((W−Ŵ)·H·(W−Ŵ)ᵀ)  instead of the
//! plain weight error RTN minimizes. The Hessian H = Σ x xᵀ comes from the
//! `<size>_hess` calibration artifact accumulated by the rust trainer.
//!
//! This is what "LoRA + OPTQ" means in the paper: fine-tune LoRA in fp,
//! merge the adapters, then OPTQ-quantize the merged weights. The paper's
//! observation — OPTQ degrades hard at 3-bit while PEQA does not, because
//! OPTQ never sees the task loss — is exactly what the benches reproduce.

use anyhow::{bail, Result};

use super::linalg::{cholesky_lower, invert_spd, MatF64};
use super::rtn::{QuantizedMatrix, EPS};
use crate::tensor::Tensor;

/// OPTQ-quantize `w` (n, m) with Hessian `h` (m, m).
///
/// `damp`: diagonal damping as a fraction of mean(diag(H)) (GPTQ default 1%).
pub fn quantize_optq(
    w: &Tensor,
    h: &Tensor,
    bits: u8,
    group: Option<usize>,
    damp: f64,
) -> Result<QuantizedMatrix> {
    let (n, m) = w.dims2()?;
    let (hn, hm) = h.dims2()?;
    if hn != m || hm != m {
        bail!("hessian shape {:?} does not match weight cols {m}", h.shape());
    }
    if !(2..=8).contains(&bits) {
        bail!("bits must be in 2..=8");
    }
    let g = group.unwrap_or(m);
    if m % g != 0 {
        bail!("group {g} must divide cols {m}");
    }
    let qmax = ((1u32 << bits) - 1) as f32;
    let ng = m / g;

    // Damped Hessian → H⁻¹ → upper Cholesky U with H⁻¹ = Uᵀ·U.
    let mut hd = MatF64::from_f32(m, h.data());
    let mean_diag = (0..m).map(|i| hd.at(i, i)).sum::<f64>() / m as f64;
    let lambda = damp * mean_diag.max(1e-12);
    for i in 0..m {
        // Dead inputs (H[i,i] == 0) get unit curvature like GPTQ does.
        if hd.at(i, i) == 0.0 {
            hd.set(i, i, 1.0);
        }
        hd.set(i, i, hd.at(i, i) + lambda);
    }
    let hinv = invert_spd(&hd)?;
    let u = cholesky_lower(&hinv)?.transpose(); // upper: H⁻¹ = Uᵀ·U

    let mut wk: Vec<f32> = w.data().to_vec(); // working copy, updated in place
    let mut codes = vec![0u8; n * m];
    let mut scales = Tensor::zeros(&[n, ng]);
    let mut zeros = Tensor::zeros(&[n, ng]);

    for j in 0..m {
        let k = j / g;
        if j % g == 0 {
            // Entering a new group: fit RTN scale/zero per row over the
            // *current* (error-compensated) values of the group's columns.
            for i in 0..n {
                let row = &wk[i * m + k * g..i * m + (k + 1) * g];
                let mut wmin = 0.0f32;
                let mut wmax = 0.0f32;
                for &x in row {
                    wmin = wmin.min(x);
                    wmax = wmax.max(x);
                }
                let s = ((wmax - wmin) / qmax).max(EPS);
                let z = (-wmin / s).round().clamp(0.0, qmax);
                scales.set2(i, k, s);
                zeros.set2(i, k, z);
            }
        }
        let d = u.at(j, j);
        for i in 0..n {
            let s = scales.at2(i, k);
            let z = zeros.at2(i, k);
            let x = wk[i * m + j];
            let q = ((x / s).round() + z).clamp(0.0, qmax);
            codes[i * m + j] = q as u8;
            let xq = s * (q - z);
            let err = ((x - xq) as f64 / d) as f32;
            // Propagate the rounding error into the remaining columns.
            for jj in j + 1..m {
                let ujj = u.at(j, jj);
                if ujj != 0.0 {
                    wk[i * m + jj] -= err * ujj as f32;
                }
            }
        }
    }

    Ok(QuantizedMatrix { codes, scales, zeros, rows: n, cols: m, bits, group: g })
}

/// Weighted reconstruction error  tr((W−Ŵ)·H·(W−Ŵ)ᵀ)  — the quantity OPTQ
/// greedily minimizes; used by tests and the OPTQ-vs-RTN ablation bench.
pub fn weighted_error(w: &Tensor, what: &Tensor, h: &Tensor) -> f64 {
    let (n, m) = w.dims2().unwrap();
    let mut total = 0.0f64;
    let mut diff = vec![0.0f32; m];
    for i in 0..n {
        for j in 0..m {
            diff[j] = w.at2(i, j) - what.at2(i, j);
        }
        for j in 0..m {
            if diff[j] == 0.0 {
                continue;
            }
            let hrow = &h.data()[j * m..(j + 1) * m];
            let mut acc = 0.0f32;
            for jj in 0..m {
                acc += hrow[jj] * diff[jj];
            }
            total += (diff[j] * acc) as f64;
        }
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rtn::quantize_rtn;
    use crate::util::Pcg32;

    fn rand_w(n: usize, m: usize, seed: u64) -> Tensor {
        let mut rng = Pcg32::new(seed);
        Tensor::normal(&[n, m], 0.4, &mut rng)
    }

    fn rand_hessian(m: usize, rows: usize, seed: u64) -> Tensor {
        // H = Σ x xᵀ over `rows` random activations (PSD by construction).
        let mut rng = Pcg32::new(seed);
        let x = Tensor::normal(&[rows, m], 1.0, &mut rng);
        x.t().matmul(&x).unwrap()
    }

    #[test]
    fn identity_hessian_equals_rtn() {
        // With H = I there is no cross-column interaction: OPTQ degenerates
        // to column-wise RTN (same codes, same scales).
        let w = rand_w(8, 32, 1);
        let h = {
            let mut t = Tensor::zeros(&[32, 32]);
            for i in 0..32 {
                t.set2(i, i, 1.0);
            }
            t
        };
        let q_optq = quantize_optq(&w, &h, 4, None, 0.0).unwrap();
        let q_rtn = quantize_rtn(&w, 4, None).unwrap();
        assert_eq!(q_optq.codes, q_rtn.codes);
        assert!(q_optq.scales.max_abs_diff(&q_rtn.scales) < 1e-6);
    }

    #[test]
    fn beats_rtn_in_weighted_error() {
        // The whole point of OPTQ: lower output-space error than RTN.
        let mut wins = 0;
        for seed in 0..6u64 {
            let w = rand_w(16, 48, seed);
            let h = rand_hessian(48, 256, 100 + seed);
            for bits in [3u8, 4] {
                let qo = quantize_optq(&w, &h, bits, None, 0.01).unwrap();
                let qr = quantize_rtn(&w, bits, None).unwrap();
                let eo = weighted_error(&w, &qo.dequantize(), &h);
                let er = weighted_error(&w, &qr.dequantize(), &h);
                assert!(eo <= er * 1.05, "seed {seed} bits {bits}: {eo} vs {er}");
                if eo < er {
                    wins += 1;
                }
            }
        }
        assert!(wins >= 10, "OPTQ should win almost always, won {wins}/12");
    }

    #[test]
    fn group_wise_runs_and_beats_rtn() {
        let w = rand_w(8, 64, 3);
        let h = rand_hessian(64, 256, 33);
        let qo = quantize_optq(&w, &h, 3, Some(16), 0.01).unwrap();
        let qr = quantize_rtn(&w, 3, Some(16)).unwrap();
        assert_eq!(qo.n_groups(), 4);
        let eo = weighted_error(&w, &qo.dequantize(), &h);
        let er = weighted_error(&w, &qr.dequantize(), &h);
        assert!(eo <= er * 1.05, "{eo} vs {er}");
    }

    #[test]
    fn dead_inputs_handled() {
        // A zero row/col in H (feature never active) must not break Cholesky.
        let w = rand_w(4, 16, 5);
        let mut h = rand_hessian(16, 64, 55);
        for j in 0..16 {
            h.set2(3, j, 0.0);
            h.set2(j, 3, 0.0);
        }
        let q = quantize_optq(&w, &h, 4, None, 0.01).unwrap();
        assert_eq!(q.codes.len(), 64);
    }

    #[test]
    fn shape_mismatch_rejected() {
        let w = rand_w(4, 16, 5);
        let h = rand_hessian(8, 64, 5);
        assert!(quantize_optq(&w, &h, 4, None, 0.01).is_err());
    }
}
