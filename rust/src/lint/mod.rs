//! `peqa lint` — in-tree static analysis enforcing the repo's
//! determinism, panic-freedom, and hot-path invariants.
//!
//! Why in-tree and token-level: the vendored registry has no `syn`, and
//! the invariants the PEQA story rests on (fixed-order float
//! reductions, no panics in serving/store paths, no steady-state
//! allocation in the compute cores, total-order comparators) are
//! *lexically visible* — a hand-rolled lexer ([`lexer`]) plus
//! token-pattern rules ([`rules`]) catches the class without a
//! dependency. Runtime tests catch the instance; this catches the next
//! copy of the instance before it runs.
//!
//! ## Pipeline
//!
//! For each `.rs` file (walk is sorted → deterministic output):
//! 1. lex into tokens + comments;
//! 2. parse `peqa-lint:` suppression comments into per-rule line
//!    ranges (malformed / unjustified / unknown-rule allows become
//!    `allow-hygiene` diagnostics, and an invalid allow suppresses
//!    nothing);
//! 3. strip test-only items (`#[test]` fns, `#[cfg(test)]` items and
//!    mods — `#[cfg(not(test))]` is *not* stripped);
//! 4. run every rule (or the `--rule` selection) over the stripped
//!    stream; drop findings covered by a valid allow;
//! 5. sort by (file, line, rule).
//!
//! ## Suppression syntax
//!
//! ```text
//! // peqa-lint: allow(<rule>[, <rule>...]) -- <justification>
//! ```
//!
//! on its own line directly above the code it exempts. The allow covers
//! the next *syntactic unit*: the next code line through the line where
//! its bracket nesting returns to balance — one line for a plain
//! statement, the whole body when placed above an `fn`/`impl`/`mod`
//! header (attributes on the item are skipped over). A bare allow with
//! no `-- justification`, an unknown rule name, or an allow sharing a
//! line with code is itself a diagnostic (`allow-hygiene`) and
//! suppresses nothing — justifications are load-bearing, not optional.
//! `allow-hygiene` runs even under `--rule`, and cannot be allowed.
//!
//! Adding a rule: append a `Rule` to [`rules::all`] (name, one-line
//! invariant, `fn(&FileCtx, &mut Vec<Diagnostic>)` over the token
//! stream), then add a positive + near-miss-negative fixture pair under
//! `rust/tests/fixtures/lint/` and a row in `tests/lint_fixtures.rs`.
//! The engine picks it up everywhere (`--list`, allows, CLI) from the
//! registry alone.

pub mod lexer;
pub mod rules;

use std::collections::{BTreeMap, BTreeSet};
use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::json::Value;
use lexer::{Comment, Tok, Token};

/// Rule name reserved for problems with the suppression comments
/// themselves. Always on, never suppressible.
pub const ALLOW_HYGIENE: &str = "allow-hygiene";

/// One finding: `file:line: rule: msg`.
#[derive(Clone, Debug)]
pub struct Diagnostic {
    pub file: String,
    pub line: u32,
    pub rule: &'static str,
    pub msg: String,
}

/// Per-file context handed to every rule: the display path, the module
/// path derived from it (`src/serve/pool.rs` → `["serve", "pool"]`),
/// the test-stripped token stream, and the full comment list (rules
/// that require justification comments — `unsafe-confined` — look for
/// them here; comments are never stripped).
pub struct FileCtx {
    pub path: String,
    pub modpath: Vec<String>,
    pub tokens: Vec<Token>,
    pub comments: Vec<Comment>,
}

impl FileCtx {
    /// Ident text at token index `i`, if it is an ident.
    pub fn ident(&self, i: usize) -> Option<&str> {
        match self.tokens.get(i).map(|t| &t.tok) {
            Some(Tok::Ident(s)) => Some(s.as_str()),
            _ => None,
        }
    }

    /// Is token `i` the punct `c`?
    pub fn punct(&self, i: usize, c: char) -> bool {
        matches!(self.tokens.get(i).map(|t| &t.tok), Some(Tok::Punct(p)) if *p == c)
    }

    /// Is token `i` the joined `::`?
    pub fn pathsep(&self, i: usize) -> bool {
        matches!(self.tokens.get(i).map(|t| &t.tok), Some(Tok::PathSep))
    }

    /// Module path starts with `prefix` (e.g. `["serve"]` covers
    /// `serve`, `serve::pool`, …).
    pub fn in_mod(&self, prefix: &[&str]) -> bool {
        prefix.len() <= self.modpath.len()
            && prefix.iter().zip(&self.modpath).all(|(a, b)| *a == b.as_str())
    }

    /// Module path equals `exact`.
    pub fn is_mod(&self, exact: &[&str]) -> bool {
        exact.len() == self.modpath.len() && self.in_mod(exact)
    }

    /// Closing index of the delimiter opened at token `open`
    /// (`(`/`[`/`{`), counting all three kinds as nesting.
    pub fn match_delim(&self, open: usize) -> Option<usize> {
        match_delim_toks(&self.tokens, open)
    }

    /// For each token index, the index of the `}` closing its innermost
    /// enclosing brace block (`usize::MAX` when at top level or the
    /// brace is unclosed).
    pub fn enclosing_brace_close(&self) -> Vec<usize> {
        let n = self.tokens.len();
        let mut close_of = vec![usize::MAX; n];
        let mut stack: Vec<usize> = Vec::new();
        for i in 0..n {
            match self.tokens[i].tok {
                Tok::Punct('{') => stack.push(i),
                Tok::Punct('}') => {
                    if let Some(o) = stack.pop() {
                        close_of[o] = i;
                    }
                }
                _ => {}
            }
        }
        let mut res = vec![usize::MAX; n];
        stack.clear();
        for i in 0..n {
            match self.tokens[i].tok {
                Tok::Punct('{') => {
                    res[i] = stack.last().map(|&o| close_of[o]).unwrap_or(usize::MAX);
                    stack.push(i);
                }
                Tok::Punct('}') => {
                    stack.pop();
                    res[i] = stack.last().map(|&o| close_of[o]).unwrap_or(usize::MAX);
                }
                _ => res[i] = stack.last().map(|&o| close_of[o]).unwrap_or(usize::MAX),
            }
        }
        res
    }

    /// Emit a finding anchored at token `i`. The engine fills in the
    /// rule name after the check returns.
    pub fn diag(&self, out: &mut Vec<Diagnostic>, i: usize, msg: String) {
        let line = self.tokens.get(i).map(|t| t.line).unwrap_or(0);
        out.push(Diagnostic { file: self.path.clone(), line, rule: "", msg });
    }
}

fn match_delim_toks(toks: &[Token], open: usize) -> Option<usize> {
    let mut depth = 0i64;
    for (j, t) in toks.iter().enumerate().skip(open) {
        match t.tok {
            Tok::Punct('(') | Tok::Punct('[') | Tok::Punct('{') => depth += 1,
            Tok::Punct(')') | Tok::Punct(']') | Tok::Punct('}') => {
                depth -= 1;
                if depth == 0 {
                    return Some(j);
                }
            }
            _ => {}
        }
    }
    None
}

fn is_p(toks: &[Token], i: usize, c: char) -> bool {
    matches!(toks.get(i).map(|t| &t.tok), Some(Tok::Punct(p)) if *p == c)
}

// ------------------------------------------------------------- test strip

/// `#[test]` on a bare item, or `#[cfg(..)]` that mentions `test`
/// without `not` (so `#[cfg(not(test))]` survives). `#[cfg_attr(..)]`
/// never strips: it conditions an attribute, not the item.
fn is_test_attr(attr: &[Token]) -> bool {
    let first = match attr.first().map(|t| &t.tok) {
        Some(Tok::Ident(s)) => s.as_str(),
        _ => return false,
    };
    let has = |name: &str| attr.iter().any(|t| matches!(&t.tok, Tok::Ident(s) if s == name));
    match first {
        "test" => attr.len() == 1,
        "cfg" => has("test") && !has("not"),
        _ => false,
    }
}

/// Skip one item starting at `k` (after its test attribute): any
/// further attributes, then everything through the matching `}` of its
/// first top-level brace, or through a top-level `;`.
fn skip_item(toks: &[Token], mut k: usize) -> usize {
    let n = toks.len();
    while k < n && is_p(toks, k, '#') && is_p(toks, k + 1, '[') {
        match match_delim_toks(toks, k + 1) {
            Some(c) => k = c + 1,
            None => return n,
        }
    }
    let mut depth = 0i64;
    while k < n {
        match toks[k].tok {
            Tok::Punct('{') if depth == 0 => {
                return match_delim_toks(toks, k).map(|c| c + 1).unwrap_or(n);
            }
            Tok::Punct('(') | Tok::Punct('[') | Tok::Punct('{') => depth += 1,
            Tok::Punct(')') | Tok::Punct(']') | Tok::Punct('}') => depth -= 1,
            Tok::Punct(';') if depth == 0 => return k + 1,
            _ => {}
        }
        k += 1;
    }
    n
}

/// Remove test-only items from the stream (rules never see them).
fn strip_tests(toks: Vec<Token>) -> Vec<Token> {
    let n = toks.len();
    let mut out = Vec::with_capacity(n);
    let mut i = 0;
    while i < n {
        if is_p(&toks, i, '#') && is_p(&toks, i + 1, '[') {
            if let Some(close) = match_delim_toks(&toks, i + 1) {
                if is_test_attr(&toks[i + 2..close]) {
                    i = skip_item(&toks, close + 1);
                    continue;
                }
            }
        }
        out.push(toks[i].clone());
        i += 1;
    }
    out
}

// ------------------------------------------------------------ suppression

/// Valid allows per rule name, as inclusive line ranges.
#[derive(Default)]
struct Allows {
    ranges: BTreeMap<String, Vec<(u32, u32)>>,
}

impl Allows {
    fn covers(&self, rule: &str, line: u32) -> bool {
        self.ranges
            .get(rule)
            .is_some_and(|rs| rs.iter().any(|&(s, e)| s <= line && line <= e))
    }
}

/// The syntactic unit after `line`: from the first code line below the
/// comment through the line where bracket nesting returns to balance.
/// Attributes on the unit are stepped over so an allow above
/// `#[derive(..)] struct S { .. }` covers the whole struct.
fn extent_after(toks: &[Token], line: u32) -> (u32, u32) {
    let n = toks.len();
    let Some(mut k) = toks.iter().position(|t| t.line > line) else {
        return (line + 1, line + 1);
    };
    let start = toks[k].line;
    while k < n && is_p(toks, k, '#') && is_p(toks, k + 1, '[') {
        match match_delim_toks(toks, k + 1) {
            Some(c) => k = c + 1,
            None => return (start, start),
        }
    }
    let mut depth = 0i64;
    let mut end = start;
    while k < n {
        match toks[k].tok {
            Tok::Punct('(') | Tok::Punct('[') | Tok::Punct('{') => depth += 1,
            Tok::Punct(')') | Tok::Punct(']') | Tok::Punct('}') => depth -= 1,
            _ => {}
        }
        end = toks[k].line;
        let next_same_line = toks.get(k + 1).map(|t| t.line) == Some(end);
        if depth <= 0 && !next_same_line {
            break;
        }
        k += 1;
    }
    (start, end)
}

/// Parse every `peqa-lint:` comment: build the allow map and emit
/// `allow-hygiene` diagnostics for malformed/bare/unknown/misplaced
/// ones (which then suppress nothing).
fn parse_allows(path: &str, toks: &[Token], comments: &[Comment]) -> (Allows, Vec<Diagnostic>) {
    let known: BTreeSet<&str> = rules::all().iter().map(|r| r.name).collect();
    let token_lines: BTreeSet<u32> = toks.iter().map(|t| t.line).collect();
    let mut allows = Allows::default();
    let mut diags = Vec::new();
    let mut hygiene = |line: u32, msg: String| {
        diags.push(Diagnostic { file: path.to_string(), line, rule: ALLOW_HYGIENE, msg });
    };
    for c in comments {
        let text = c.text.trim();
        if !text.starts_with("peqa-lint") {
            continue;
        }
        if !c.line_comment {
            hygiene(c.line, "peqa-lint allows must be `//` line comments".into());
            continue;
        }
        let mut ok = true;
        let rest = match text.strip_prefix("peqa-lint:") {
            Some(r) => r.trim_start(),
            None => {
                hygiene(
                    c.line,
                    "malformed peqa-lint comment (expected `peqa-lint: allow(<rule>) -- \
                     <justification>`)"
                        .into(),
                );
                continue;
            }
        };
        let body = match rest.strip_prefix("allow").map(|b| b.trim_start()) {
            Some(b) if b.starts_with('(') => &b[1..],
            _ => {
                hygiene(
                    c.line,
                    "malformed peqa-lint comment (expected `peqa-lint: allow(<rule>) -- \
                     <justification>`)"
                        .into(),
                );
                continue;
            }
        };
        let Some(close) = body.find(')') else {
            hygiene(c.line, "unclosed `allow(` in peqa-lint comment".into());
            continue;
        };
        let names: Vec<String> =
            body[..close].split(',').map(|s| s.trim().to_string()).collect();
        for nm in &names {
            if nm.is_empty() {
                hygiene(c.line, "empty rule name in peqa-lint allow".into());
                ok = false;
            } else if !known.contains(nm.as_str()) {
                hygiene(
                    c.line,
                    format!("unknown rule `{nm}` in peqa-lint allow (see `peqa lint --list`)"),
                );
                ok = false;
            }
        }
        let justification =
            body[close + 1..].trim_start().strip_prefix("--").map(str::trim).unwrap_or("");
        if justification.is_empty() {
            hygiene(
                c.line,
                "peqa-lint allow without a justification — write `allow(<rule>) -- <why this \
                 site is sound>`; the exemption is suspended until it says why"
                    .into(),
            );
            ok = false;
        }
        if token_lines.contains(&c.line) {
            hygiene(
                c.line,
                "peqa-lint allow must be on its own line directly above the code it exempts"
                    .into(),
            );
            ok = false;
        }
        if !ok {
            continue;
        }
        let (s, e) = extent_after(toks, c.line);
        for nm in names {
            allows.ranges.entry(nm).or_default().push((s, e));
        }
    }
    (allows, diags)
}

// -------------------------------------------------------------- front end

/// `src/serve/pool.rs` → `["serve", "pool"]`; `mod.rs` drops its
/// segment; `lib.rs` is the crate root (empty path); everything up to
/// and including the last `src` component is ignored. Paths without a
/// `src` component (fixture virtual paths) are taken as module paths
/// directly.
pub fn modpath_of(path: &str) -> Vec<String> {
    let norm = path.replace('\\', "/");
    let comps: Vec<&str> =
        norm.split('/').filter(|c| !c.is_empty() && *c != ".").collect();
    let start = comps.iter().rposition(|c| *c == "src").map(|p| p + 1).unwrap_or(0);
    let mut segs: Vec<String> = comps[start..].iter().map(|s| s.to_string()).collect();
    if let Some(last) = segs.last_mut() {
        if let Some(stripped) = last.strip_suffix(".rs") {
            *last = stripped.to_string();
        }
    }
    if matches!(segs.last().map(|s| s.as_str()), Some("mod") | Some("lib")) {
        segs.pop();
    }
    segs
}

/// Lint one source text under a (possibly virtual) path. The workhorse
/// behind [`run`] and the fixture tests.
pub fn lint_source(path: &str, src: &str, rule_filter: Option<&str>) -> Vec<Diagnostic> {
    let lexed = lexer::lex(src);
    let (allows, mut diags) = parse_allows(path, &lexed.tokens, &lexed.comments);
    let ctx = FileCtx {
        path: path.to_string(),
        modpath: modpath_of(path),
        tokens: strip_tests(lexed.tokens),
        comments: lexed.comments,
    };
    for r in rules::all() {
        if let Some(want) = rule_filter {
            if r.name != want {
                continue;
            }
        }
        let mut found = Vec::new();
        (r.check)(&ctx, &mut found);
        for mut d in found {
            d.rule = r.name;
            if !allows.covers(r.name, d.line) {
                diags.push(d);
            }
        }
    }
    diags.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    diags
}

/// Collect `.rs` files under `root`, sorted, skipping build output and
/// test/fixture trees (the lint contract covers shipped source; test
/// code is stripped per-item anyway).
fn collect_files(root: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    if root.is_dir() {
        let mut entries: Vec<PathBuf> = fs::read_dir(root)
            .with_context(|| format!("reading directory {}", root.display()))?
            .filter_map(|e| e.ok().map(|e| e.path()))
            .collect();
        entries.sort();
        for e in entries {
            let name = e.file_name().map(|n| n.to_string_lossy().into_owned()).unwrap_or_default();
            if e.is_dir() {
                if matches!(
                    name.as_str(),
                    "target" | "fixtures" | "tests" | "benches" | "examples"
                ) || name.starts_with('.')
                {
                    continue;
                }
                collect_files(&e, out)?;
            } else if name.ends_with(".rs") {
                out.push(e);
            }
        }
    } else {
        out.push(root.to_path_buf());
    }
    Ok(())
}

/// Lint every `.rs` file under `paths` (files or directories). Output
/// is sorted by (file, line, rule) — byte-identical across runs.
pub fn run(paths: &[String], rule_filter: Option<&str>) -> Result<Vec<Diagnostic>> {
    if let Some(r) = rule_filter {
        if rules::find(r).is_none() && r != ALLOW_HYGIENE {
            anyhow::bail!("unknown rule `{r}` — `peqa lint --list` shows the registry");
        }
    }
    let mut files = Vec::new();
    for p in paths {
        let path = Path::new(p);
        if !path.exists() {
            anyhow::bail!("no such path: {p}");
        }
        collect_files(path, &mut files)?;
    }
    files.sort();
    files.dedup();
    let mut diags = Vec::new();
    for f in files {
        let src = fs::read_to_string(&f)
            .with_context(|| format!("reading {}", f.display()))?;
        diags.extend(lint_source(&f.to_string_lossy(), &src, rule_filter));
    }
    diags.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule))
    });
    Ok(diags)
}

/// Plain-text rendering: one `file:line: rule: msg` per finding.
pub fn render_text(diags: &[Diagnostic]) -> String {
    let mut s = String::new();
    for d in diags {
        s.push_str(&format!("{}:{}: {}: {}\n", d.file, d.line, d.rule, d.msg));
    }
    s
}

/// JSON rendering (the `--json` artifact): `{"count": N, "findings":
/// [{"file", "line", "rule", "msg"}, ...]}` — keys sorted, findings in
/// the deterministic text order.
pub fn to_json(diags: &[Diagnostic]) -> Value {
    Value::obj(vec![
        ("count", Value::num(diags.len() as f64)),
        (
            "findings",
            Value::Arr(
                diags
                    .iter()
                    .map(|d| {
                        Value::obj(vec![
                            ("file", Value::str(d.file.as_str())),
                            ("line", Value::num(f64::from(d.line))),
                            ("rule", Value::str(d.rule)),
                            ("msg", Value::str(d.msg.as_str())),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn modpath_derivation() {
        assert_eq!(modpath_of("rust/src/serve/pool.rs"), vec!["serve", "pool"]);
        assert_eq!(modpath_of("rust/src/quant/mod.rs"), vec!["quant"]);
        assert_eq!(modpath_of("rust/src/lib.rs"), Vec::<String>::new());
        assert_eq!(modpath_of("rust/src/main.rs"), vec!["main"]);
        assert_eq!(modpath_of("serve/dispatch.rs"), vec!["serve", "dispatch"]);
        assert_eq!(modpath_of("/abs/repo/rust/src/util/stats.rs"), vec!["util", "stats"]);
    }

    const VIOLATION: &str = "fn f(v: &mut Vec<f32>) {\n    v.sort_by(|a, b| a.partial_cmp(b).unwrap());\n}\n";

    #[test]
    fn justified_allow_suppresses_next_unit() {
        let src = format!(
            "// peqa-lint: allow(nan-comparator) -- exercised on NaN-free unit data\n{VIOLATION}"
        );
        let diags = lint_source("util/x.rs", &src, None);
        assert!(diags.is_empty(), "allow above the fn must cover its body: {diags:?}");
    }

    #[test]
    fn bare_allow_is_a_diagnostic_and_suppresses_nothing() {
        let src = format!("// peqa-lint: allow(nan-comparator)\n{VIOLATION}");
        let diags = lint_source("util/x.rs", &src, None);
        let rules: Vec<&str> = diags.iter().map(|d| d.rule).collect();
        assert!(rules.contains(&ALLOW_HYGIENE), "bare allow must be flagged: {diags:?}");
        assert!(
            rules.contains(&"nan-comparator"),
            "an invalid allow must not suppress: {diags:?}"
        );
    }

    #[test]
    fn unknown_rule_in_allow_is_flagged() {
        let src = format!("// peqa-lint: allow(no-such-rule) -- because\n{VIOLATION}");
        let diags = lint_source("util/x.rs", &src, None);
        assert!(
            diags.iter().any(|d| d.rule == ALLOW_HYGIENE && d.msg.contains("no-such-rule")),
            "{diags:?}"
        );
    }

    #[test]
    fn allow_sharing_a_line_with_code_is_flagged() {
        let src = "let x = 1; // peqa-lint: allow(nan-comparator) -- trailing\n";
        let diags = lint_source("util/x.rs", src, None);
        assert!(diags.iter().any(|d| d.rule == ALLOW_HYGIENE), "{diags:?}");
    }

    #[test]
    fn allow_on_single_statement_does_not_leak_to_the_next() {
        let src = "fn f(v: &mut Vec<f32>, w: &mut Vec<f32>) {\n\
                   // peqa-lint: allow(nan-comparator) -- first sort only\n\
                   v.sort_by(|a, b| a.partial_cmp(b).unwrap());\n\
                   w.sort_by(|a, b| a.partial_cmp(b).unwrap());\n\
                   }\n";
        let diags = lint_source("util/x.rs", src, None);
        assert_eq!(diags.len(), 1, "second sort must still fire: {diags:?}");
        assert_eq!(diags[0].line, 4);
    }

    #[test]
    fn test_items_are_stripped_but_not_cfg_not_test() {
        let src = "#[test]\nfn t() { let _ = a.partial_cmp(b).unwrap(); }\n\
                   #[cfg(test)]\nmod tests {\n  fn u() { let _ = a.partial_cmp(b).unwrap(); }\n}\n\
                   #[cfg(not(test))]\nfn real() { let _ = a.partial_cmp(b).unwrap(); }\n";
        let diags = lint_source("util/x.rs", src, None);
        assert_eq!(diags.len(), 1, "{diags:?}");
        assert_eq!(diags[0].line, 8, "only the cfg(not(test)) body fires: {diags:?}");
    }

    #[test]
    fn module_scoping_gates_hot_path_rules() {
        let src = "fn f(n: usize) -> Vec<f32> { vec![0.0f32; n] }\n";
        assert!(
            lint_source("quant/kernels.rs", src, None)
                .iter()
                .any(|d| d.rule == "hot-path-alloc"),
            "vec! must fire inside quant::kernels"
        );
        assert!(
            lint_source("serve/engine.rs", src, None).is_empty(),
            "vec! is fine outside the kernel modules"
        );
    }

    #[test]
    fn rule_filter_restricts_but_hygiene_stays_on() {
        let src = format!("// peqa-lint: allow(nan-comparator)\n{VIOLATION}");
        let diags = lint_source("util/x.rs", &src, Some("hot-path-alloc"));
        let rules: Vec<&str> = diags.iter().map(|d| d.rule).collect();
        assert!(rules.contains(&ALLOW_HYGIENE));
        assert!(!rules.contains(&"nan-comparator"));
    }

    #[test]
    fn json_roundtrips_through_the_in_tree_parser() {
        let diags = lint_source("util/x.rs", VIOLATION, None);
        assert_eq!(diags.len(), 1);
        let text = to_json(&diags).to_string();
        let v = Value::parse(&text).expect("lint --json must emit valid JSON");
        assert_eq!(v.usize_of("count").unwrap(), 1);
        assert_eq!(
            v.arr_of("findings").unwrap()[0].str_of("rule").unwrap(),
            "nan-comparator"
        );
    }
}
