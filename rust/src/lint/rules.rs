//! The shipped rule set. Each rule is a pure function over a file's
//! (test-stripped) token stream plus its module path; rules never do
//! I/O and never look at other files, which keeps `peqa lint`
//! deterministic and embarrassingly simple to test.
//!
//! Scoping philosophy: a rule fires only where its invariant is
//! load-bearing. `panic-free-paths` covers `serve::` and `store::`
//! (a panic there drops live traffic or corrupts a checkpoint — that
//! includes `serve::kvpage`, where a bad page index or a double free
//! must surface as a typed error, not an indexing panic mid-decode);
//! `hot-path-alloc` and `float-reduction-order` cover the compute
//! cores (`quant::kernels`, `model::blocks`, `quant::simd`) where
//! ProjScratch / TapeArena exist precisely so steady-state code never
//! allocates and reductions keep one fixed order; `unsafe-confined`
//! is global in the opposite direction — `unsafe` may appear *only*
//! in `quant::simd` (the intrinsics live there behind the runtime
//! dispatch table), and even there every `unsafe` must sit under a
//! `// SAFETY:` comment stating why the site is sound;
//! `nan-comparator` is global because a NaN comparator panic is wrong
//! everywhere. See `lint::mod` docs for the suppression syntax.

use super::lexer::Tok;
use super::{Diagnostic, FileCtx};

/// A named lint rule: `check` pushes diagnostics for `ctx`.
pub struct Rule {
    pub name: &'static str,
    /// One-line invariant statement, shown by `peqa lint --list`.
    pub invariant: &'static str,
    pub check: fn(&FileCtx, &mut Vec<Diagnostic>),
}

/// Registry of all shipped rules, in stable display order.
pub fn all() -> &'static [Rule] {
    &[
        Rule {
            name: "nan-comparator",
            invariant: "comparators must be total orders: `partial_cmp(..).unwrap()` \
                        panics (or lies) on NaN — key with `total_cmp`",
            check: nan_comparator,
        },
        Rule {
            name: "panic-free-paths",
            invariant: "no unwrap/expect/panic!/assert! in non-test serve::/store:: code \
                        — a panic drops live traffic or poisons a checkpoint",
            check: panic_free_paths,
        },
        Rule {
            name: "hot-path-alloc",
            invariant: "no per-call allocation (Vec::new/vec!/to_vec/format!/String::from/\
                        .clone()) in quant::kernels / model::blocks / quant::simd — \
                        scratch is pooled",
            check: hot_path_alloc,
        },
        Rule {
            name: "float-reduction-order",
            invariant: "no iterator float reductions (.sum::<f32>/fold) in kernel modules \
                        — bitwise reproducibility requires one explicit accumulation order",
            check: float_reduction_order,
        },
        Rule {
            name: "unsafe-confined",
            invariant: "`unsafe` is legal only inside quant::simd, and every occurrence \
                        there must sit under a `// SAFETY:` comment stating why the site \
                        is sound",
            check: unsafe_confined,
        },
        Rule {
            name: "lock-across-blocking",
            invariant: "no mutex guard lexically live across recv/send/join in serve:: \
                        — the pool's bounded channels make that a real deadlock shape",
            check: lock_across_blocking,
        },
        Rule {
            name: "nondeterminism-sources",
            invariant: "no HashMap/HashSet in artifact/numeric paths, no Instant::now/\
                        SystemTime outside bench//util::stats//util::log, no bare \
                        thread::spawn (scoped threads are the house rule)",
            check: nondeterminism_sources,
        },
    ]
}

/// Look up a rule by name.
pub fn find(name: &str) -> Option<&'static Rule> {
    all().iter().find(|r| r.name == name)
}

// ---------------------------------------------------------------- helpers

fn floatish(text: &str) -> bool {
    // Radix prefixes first: `0x1f32` is an integer despite its "suffix".
    if text.starts_with("0x") || text.starts_with("0b") || text.starts_with("0o") {
        return false;
    }
    if text.ends_with("f32") || text.ends_with("f64") {
        return true;
    }
    // Integer suffixes would otherwise trip the exponent check below
    // ("0usize" contains an 'e').
    if ["usize", "isize", "u128", "i128", "u64", "i64", "u32", "i32", "u16", "i16", "u8", "i8"]
        .iter()
        .any(|s| text.ends_with(s))
    {
        return false;
    }
    text.contains('.') || text.contains('e') || text.contains('E')
}

// ------------------------------------------------------------------ rules

/// `partial_cmp(..)` immediately unwrapped/defaulted in comparator
/// position. The safe spelling is `a.total_cmp(b)`.
fn nan_comparator(ctx: &FileCtx, out: &mut Vec<Diagnostic>) {
    let n = ctx.tokens.len();
    for i in 0..n {
        if ctx.ident(i) != Some("partial_cmp") || !ctx.punct(i + 1, '(') {
            continue;
        }
        let Some(close) = ctx.match_delim(i + 1) else { continue };
        if ctx.punct(close + 1, '.') {
            if let Some(m) = ctx.ident(close + 2) {
                if matches!(m, "unwrap" | "expect" | "unwrap_or" | "unwrap_or_else") {
                    ctx.diag(
                        out,
                        i,
                        format!(
                            "`partial_cmp(..).{m}(..)` is not a total order (NaN panics or \
                             mis-sorts); key the comparison with `f32::total_cmp`/`f64::total_cmp`"
                        ),
                    );
                }
            }
        }
    }
}

/// unwrap/expect/panic-family macros in non-test `serve::` / `store::`.
/// `debug_assert*` stays legal (stripped in release); mutex poison goes
/// through `util::sync::{lock_clean, try_lock_clean, wait_clean}`.
fn panic_free_paths(ctx: &FileCtx, out: &mut Vec<Diagnostic>) {
    if !(ctx.in_mod(&["serve"]) || ctx.in_mod(&["store"])) {
        return;
    }
    let n = ctx.tokens.len();
    for i in 0..n {
        if ctx.punct(i, '.') && ctx.punct(i + 2, '(') {
            if let Some(m) = ctx.ident(i + 1) {
                if m == "unwrap" || m == "expect" {
                    ctx.diag(
                        out,
                        i + 1,
                        format!(
                            "`.{m}()` in a serve/store path can panic in production; return a \
                             typed error, use util::sync for mutex poison, or allow with a \
                             written invariant"
                        ),
                    );
                }
            }
        }
        if ctx.punct(i + 1, '!') {
            if let Some(m) = ctx.ident(i) {
                if matches!(
                    m,
                    "panic" | "unreachable" | "todo" | "unimplemented" | "assert" | "assert_eq"
                        | "assert_ne"
                ) {
                    ctx.diag(
                        out,
                        i,
                        format!(
                            "`{m}!` in a serve/store path aborts live work; prefer a typed \
                             error (`debug_assert*` is fine for invariants checked in tests)"
                        ),
                    );
                }
            }
        }
    }
}

/// Per-call allocation in the compute cores.
fn hot_path_alloc(ctx: &FileCtx, out: &mut Vec<Diagnostic>) {
    if !(ctx.is_mod(&["quant", "kernels"])
        || ctx.is_mod(&["model", "blocks"])
        || ctx.is_mod(&["quant", "simd"]))
    {
        return;
    }
    let n = ctx.tokens.len();
    for i in 0..n {
        if ctx.ident(i) == Some("Vec") && ctx.pathsep(i + 1) && ctx.ident(i + 2) == Some("new") {
            ctx.diag(out, i, "`Vec::new` in a kernel module — pool the buffer through \
                              ProjScratch/TapeArena or allocate once at entry".into());
        }
        if ctx.ident(i) == Some("String") && ctx.pathsep(i + 1) && ctx.ident(i + 2) == Some("from")
        {
            ctx.diag(out, i, "`String::from` allocates in a kernel module".into());
        }
        if ctx.punct(i + 1, '!') {
            match ctx.ident(i) {
                Some("vec") => ctx.diag(out, i, "`vec![..]` allocates in a kernel module — \
                                                 pool it or allocate once at entry".into()),
                Some("format") => {
                    ctx.diag(out, i, "`format!` allocates in a kernel module".into())
                }
                _ => {}
            }
        }
        if ctx.punct(i, '.') && ctx.punct(i + 2, '(') {
            match ctx.ident(i + 1) {
                Some("to_vec") => {
                    ctx.diag(out, i + 1, "`.to_vec()` copies in a kernel module".into())
                }
                Some("clone") => ctx.diag(out, i + 1, "`.clone()` in a kernel module — \
                                                       borrow or pool instead".into()),
                _ => {}
            }
        }
    }
}

/// Iterator float reductions in kernel modules: `.sum::<f32>()`,
/// `.product::<f64>()`, or `.fold(<float literal>, ..)`. Order must be
/// an explicit loop so the accumulation order is pinned.
fn float_reduction_order(ctx: &FileCtx, out: &mut Vec<Diagnostic>) {
    if !(ctx.is_mod(&["quant", "kernels"])
        || ctx.is_mod(&["model", "blocks"])
        || ctx.is_mod(&["quant", "simd"]))
    {
        return;
    }
    let n = ctx.tokens.len();
    for i in 0..n {
        if !ctx.punct(i, '.') {
            continue;
        }
        let m = ctx.ident(i + 1);
        if matches!(m, Some("sum") | Some("product"))
            && ctx.pathsep(i + 2)
            && ctx.punct(i + 3, '<')
        {
            if matches!(ctx.ident(i + 4), Some("f32") | Some("f64")) {
                ctx.diag(
                    out,
                    i + 1,
                    format!(
                        "iterator `.{}::<float>()` leaves the accumulation order to the \
                         iterator; write a fixed-order loop (bitwise-invariance contract)",
                        m.unwrap_or("sum")
                    ),
                );
            }
        }
        if m == Some("fold") && ctx.punct(i + 2, '(') {
            let mut k = i + 3;
            if ctx.punct(k, '-') {
                k += 1;
            }
            if let Some(Tok::Num(t)) = ctx.tokens.get(k).map(|t| &t.tok) {
                if floatish(t) {
                    ctx.diag(
                        out,
                        i + 1,
                        "`.fold` over a float accumulator hides the reduction order; \
                         write a fixed-order loop (bitwise-invariance contract)"
                            .into(),
                    );
                }
            }
        }
    }
}

/// Line of the last code token before token `i` that is on an earlier
/// line than token `i`, stepping over `#[...]` attribute groups as if
/// they were not there (a `// SAFETY:` comment above
/// `#[target_feature(..)] unsafe fn` must still count as "directly
/// above"). Returns 0 when nothing precedes the token.
fn prev_code_line(ctx: &FileCtx, i: usize) -> u32 {
    let uline = ctx.tokens[i].line;
    let mut j = i;
    while j > 0 {
        j -= 1;
        if ctx.tokens[j].line >= uline {
            continue;
        }
        if ctx.punct(j, ']') {
            // Walk back to the matching `[`; if a `#` precedes it, the
            // whole group is an attribute — keep scanning above it.
            let mut depth = 1i64;
            let mut k = j;
            while k > 0 && depth > 0 {
                k -= 1;
                match ctx.tokens[k].tok {
                    Tok::Punct('[') => depth -= 1,
                    Tok::Punct(']') => depth += 1,
                    _ => {}
                }
            }
            if depth == 0 && k > 0 && ctx.punct(k - 1, '#') {
                j = k - 1;
                continue;
            }
        }
        return ctx.tokens[j].line;
    }
    0
}

/// `unsafe` anywhere outside `quant::simd` is a finding — the crate
/// used to carry `#![deny(unsafe_code)]` and this rule is its
/// replacement now that the SIMD kernels need intrinsics. Inside
/// `quant::simd`, each `unsafe` must be covered by a `//` line comment
/// whose text starts with `SAFETY`, on a line strictly after the
/// previous code line and at or before the `unsafe` itself (attributes
/// between the comment and the keyword are stepped over, so the
/// comment may sit above `#[target_feature(..)] unsafe fn`).
fn unsafe_confined(ctx: &FileCtx, out: &mut Vec<Diagnostic>) {
    let in_simd = ctx.is_mod(&["quant", "simd"]);
    let n = ctx.tokens.len();
    for i in 0..n {
        if ctx.ident(i) != Some("unsafe") {
            continue;
        }
        if !in_simd {
            ctx.diag(
                out,
                i,
                "`unsafe` outside `quant::simd` — intrinsics and their justifications \
                 live behind the dispatch table in quant::simd; everything else stays \
                 safe Rust"
                    .into(),
            );
            continue;
        }
        let uline = ctx.tokens[i].line;
        let prev = prev_code_line(ctx, i);
        let covered = ctx.comments.iter().any(|c| {
            c.line_comment
                && c.text.trim_start().starts_with("SAFETY")
                && c.line > prev
                && c.line <= uline
        });
        if !covered {
            ctx.diag(
                out,
                i,
                "`unsafe` in quant::simd without a `// SAFETY:` comment directly above \
                 — state the precondition (feature detection, bounds) that makes this \
                 site sound"
                    .into(),
            );
        }
    }
}

/// A mutex guard bound by `let` whose rhs acquires a lock
/// (`.lock()`/`.try_lock()` or the util::sync helpers) and that stays
/// lexically live while the same block calls `.recv()`/`.send()`/
/// `.join()`. Lexical liveness over-approximates (an early `return`
/// still counts) — `drop(guard)` before the blocking call, or an
/// allow, resolves it.
fn lock_across_blocking(ctx: &FileCtx, out: &mut Vec<Diagnostic>) {
    if !ctx.in_mod(&["serve"]) {
        return;
    }
    let n = ctx.tokens.len();
    // Innermost enclosing '{' for every token, by index; usize::MAX = none.
    let enclosing = ctx.enclosing_brace_close();
    for i in 0..n {
        if ctx.ident(i) != Some("let") {
            continue;
        }
        // Find the binding '=' before any ';' / '{' / '}'.
        let mut eq = None;
        let mut j = i + 1;
        while j < n {
            match &ctx.tokens[j].tok {
                Tok::Punct('=') => {
                    // Skip `==`, `=>`, `<=`-style composites.
                    if ctx.punct(j + 1, '=') || ctx.punct(j + 1, '>') || ctx.punct(j - 1, '=')
                        || ctx.punct(j - 1, '<') || ctx.punct(j - 1, '>') || ctx.punct(j - 1, '!')
                    {
                        j += 1;
                        continue;
                    }
                    eq = Some(j);
                    break;
                }
                Tok::Punct(';') | Tok::Punct('{') | Tok::Punct('}') => break,
                _ => {}
            }
            j += 1;
        }
        let Some(eq) = eq else { continue };
        // Guard name: last ident of the pattern that isn't `mut`/`Some`/`Ok`.
        let guard = (i + 1..eq)
            .rev()
            .filter_map(|k| ctx.ident(k))
            .find(|s| !matches!(*s, "mut" | "Some" | "Ok" | "Err" | "ref"));
        let Some(guard) = guard else { continue };
        // rhs: eq+1 until ';' or '{' at relative delimiter depth 0.
        let mut depth = 0i32;
        let mut k = eq + 1;
        let mut acquires = false;
        while k < n {
            match &ctx.tokens[k].tok {
                Tok::Punct('(') | Tok::Punct('[') => depth += 1,
                Tok::Punct(')') | Tok::Punct(']') => depth -= 1,
                Tok::Punct(';') | Tok::Punct('{') if depth <= 0 => break,
                Tok::Ident(s)
                    if matches!(
                        s.as_str(),
                        "lock" | "try_lock" | "lock_clean" | "try_lock_clean" | "wait_clean"
                    ) =>
                {
                    acquires = true;
                }
                _ => {}
            }
            k += 1;
        }
        if !acquires {
            continue;
        }
        let stmt_end = k;
        let scope_end = enclosing.get(i).copied().unwrap_or(n).min(n);
        // Scan the rest of the enclosing block for a blocking call,
        // stopping early at an explicit drop(guard).
        let mut p = stmt_end;
        while p < scope_end {
            if ctx.ident(p) == Some("drop")
                && ctx.punct(p + 1, '(')
                && ctx.ident(p + 2) == Some(guard)
                && ctx.punct(p + 3, ')')
            {
                break;
            }
            if ctx.punct(p, '.') && ctx.punct(p + 2, '(') {
                if let Some(m) = ctx.ident(p + 1) {
                    if matches!(m, "recv" | "recv_timeout" | "send" | "join") {
                        ctx.diag(
                            out,
                            p + 1,
                            format!(
                                "lock guard `{guard}` (bound above) is lexically live across \
                                 `.{m}()` — blocking on a channel/thread while holding a lock \
                                 is the pool deadlock shape; drop the guard first"
                            ),
                        );
                        break;
                    }
                }
            }
            p += 1;
        }
    }
}

/// Hash-order iteration in artifact/numeric paths, ambient wall-clock
/// reads outside the modules whose *job* is timing, and bare
/// `thread::spawn` (scoped threads / `thread::Builder` are the rule).
fn nondeterminism_sources(ctx: &FileCtx, out: &mut Vec<Diagnostic>) {
    let hash_scoped = matches!(
        ctx.modpath.first().map(|s| s.as_str()),
        Some("store") | Some("model") | Some("tokenizer") | Some("data") | Some("json")
            | Some("eval") | Some("quant") | Some("train") | Some("pipeline")
            | Some("coordinator") | Some("bench")
    );
    let clock_ok = ctx.modpath.first().map(|s| s.as_str()) == Some("bench")
        || ctx.is_mod(&["util", "stats"])
        || ctx.is_mod(&["util", "log"]);
    let n = ctx.tokens.len();
    let mut in_use = false;
    for i in 0..n {
        match &ctx.tokens[i].tok {
            Tok::Ident(s) if s == "use" => in_use = true,
            Tok::Punct(';') => in_use = false,
            _ => {}
        }
        if in_use {
            continue;
        }
        if hash_scoped {
            if let Some(s) = ctx.ident(i) {
                if s == "HashMap" || s == "HashSet" {
                    ctx.diag(
                        out,
                        i,
                        format!(
                            "`{s}` in an artifact/numeric path iterates in hash order; use \
                             BTreeMap/BTreeSet or sort before output (or allow with a written \
                             order-independence argument)"
                        ),
                    );
                }
            }
        }
        if !clock_ok {
            if ctx.ident(i) == Some("Instant")
                && ctx.pathsep(i + 1)
                && ctx.ident(i + 2) == Some("now")
            {
                ctx.diag(
                    out,
                    i,
                    "`Instant::now()` outside bench/util::stats/util::log makes output \
                     wall-clock dependent; metrics sites carry an allow naming the metric"
                        .into(),
                );
            }
            if ctx.ident(i) == Some("SystemTime") {
                ctx.diag(
                    out,
                    i,
                    "`SystemTime` outside bench/util::stats/util::log makes output \
                     wall-clock dependent"
                        .into(),
                );
            }
        }
        if ctx.ident(i) == Some("thread") && ctx.pathsep(i + 1) && ctx.ident(i + 2) == Some("spawn")
        {
            ctx.diag(
                out,
                i,
                "bare `thread::spawn` detaches from the panic/shutdown story; use \
                 `thread::scope` or `thread::Builder` with a joined handle"
                    .into(),
            );
        }
    }
}
