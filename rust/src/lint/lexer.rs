//! Hand-rolled Rust lexer for the lint pass (the vendored registry has
//! no `syn` — see `lint/mod.rs` for why the rules are token-level).
//!
//! Produces a flat token stream with 1-based line numbers plus a
//! separate comment list (suppression comments are parsed from line
//! comments by the engine). The lexer understands exactly the surface
//! syntax a *scanner* must not be fooled by:
//!
//! * line comments (`//`, `///`, `//!`) and **nested** block comments;
//! * string literals with escapes, raw strings with any `#` count, and
//!   the `b` / `r` / `br` / `c` / `cr` prefixes;
//! * `'a'` char literals (incl. escapes like `'\n'`, `'\u{1F600}'`)
//!   vs `'a` lifetime ticks;
//! * numbers with suffixes (`1e-3`, `0.5f32`, `1_000u64`) without
//!   swallowing range dots (`0..n`);
//! * `::` joined into one path-separator token (rules match
//!   `Vec::new`-style paths as three tokens).
//!
//! Everything else is a single-character punct. The lexer never fails:
//! unterminated constructs run to end of input, which is the right
//! behavior for a linter (the compiler owns syntax errors).

/// One lexical token. Keywords are `Ident`s; rules match on text.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Tok {
    /// Identifier or keyword (`fn`, `unwrap`, `HashMap`, …).
    Ident(String),
    /// `'a`, `'static`, `'_` — the tick without a closing quote.
    Lifetime(String),
    /// Numeric literal, raw text kept (suffix/exponent matter to rules).
    Num(String),
    /// Any string-like literal (`"…"`, `r#"…"#`, `b"…"`, `c"…"`).
    Str,
    /// Char or byte-char literal (`'x'`, `b'\n'`).
    Char,
    /// The `::` path separator, joined.
    PathSep,
    /// Any other single character (`{`, `.`, `!`, `<`, …).
    Punct(char),
}

/// A token plus the 1-based source line it starts on.
#[derive(Clone, Debug)]
pub struct Token {
    pub tok: Tok,
    pub line: u32,
}

/// A comment, with its text (delimiters stripped) and line span.
#[derive(Clone, Debug)]
pub struct Comment {
    /// Line the comment starts on (1-based).
    pub line: u32,
    /// `true` for `//…` comments, `false` for `/* … */`.
    pub line_comment: bool,
    /// Text after `//` resp. between `/*` and `*/`.
    pub text: String,
}

/// Lexer output: code tokens and comments, both in source order.
#[derive(Debug, Default)]
pub struct Lexed {
    pub tokens: Vec<Token>,
    pub comments: Vec<Comment>,
}

/// Lex `src` into tokens + comments. Infallible by design.
pub fn lex(src: &str) -> Lexed {
    Lexer { chars: src.chars().collect(), i: 0, line: 1, out: Lexed::default() }.run()
}

struct Lexer {
    chars: Vec<char>,
    i: usize,
    line: u32,
    out: Lexed,
}

impl Lexer {
    fn peek(&self, ahead: usize) -> Option<char> {
        self.chars.get(self.i + ahead).copied()
    }

    /// Advance one char, tracking newlines.
    fn bump(&mut self) -> Option<char> {
        let c = self.chars.get(self.i).copied();
        if let Some(c) = c {
            self.i += 1;
            if c == '\n' {
                self.line += 1;
            }
        }
        c
    }

    fn push(&mut self, tok: Tok, line: u32) {
        self.out.tokens.push(Token { tok, line });
    }

    fn run(mut self) -> Lexed {
        while let Some(c) = self.peek(0) {
            let line = self.line;
            match c {
                c if c.is_whitespace() => {
                    self.bump();
                }
                '/' if self.peek(1) == Some('/') => self.line_comment(line),
                '/' if self.peek(1) == Some('*') => self.block_comment(line),
                '"' => {
                    self.bump();
                    self.string_body();
                    self.push(Tok::Str, line);
                }
                '\'' => self.char_or_lifetime(line),
                c if c.is_ascii_digit() => self.number(line),
                c if c == '_' || c.is_alphabetic() => self.ident_or_prefixed(line),
                ':' if self.peek(1) == Some(':') => {
                    self.bump();
                    self.bump();
                    self.push(Tok::PathSep, line);
                }
                c => {
                    self.bump();
                    self.push(Tok::Punct(c), line);
                }
            }
        }
        self.out
    }

    fn line_comment(&mut self, line: u32) {
        self.bump(); // /
        self.bump(); // /
        let mut text = String::new();
        while let Some(c) = self.peek(0) {
            if c == '\n' {
                break;
            }
            text.push(c);
            self.bump();
        }
        self.out.comments.push(Comment { line, line_comment: true, text });
    }

    fn block_comment(&mut self, line: u32) {
        self.bump(); // /
        self.bump(); // *
        let mut depth = 1usize;
        let mut text = String::new();
        while let Some(c) = self.peek(0) {
            if c == '/' && self.peek(1) == Some('*') {
                depth += 1;
                self.bump();
                self.bump();
                text.push_str("/*");
            } else if c == '*' && self.peek(1) == Some('/') {
                depth -= 1;
                self.bump();
                self.bump();
                if depth == 0 {
                    break;
                }
                text.push_str("*/");
            } else {
                text.push(c);
                self.bump();
            }
        }
        self.out.comments.push(Comment { line, line_comment: false, text });
    }

    /// Body of a non-raw string, opening quote already consumed.
    fn string_body(&mut self) {
        while let Some(c) = self.bump() {
            match c {
                '\\' => {
                    self.bump(); // escaped char, incl. \" and \\
                }
                '"' => break,
                _ => {}
            }
        }
    }

    /// Raw string: at the first `#` or `"` after an `r`-carrying prefix.
    fn raw_string_body(&mut self) {
        let mut hashes = 0usize;
        while self.peek(0) == Some('#') {
            hashes += 1;
            self.bump();
        }
        if self.peek(0) != Some('"') {
            return; // `r#foo` raw identifier — prefix already emitted
        }
        self.bump(); // opening quote
        'outer: while let Some(c) = self.bump() {
            if c == '"' {
                // Need exactly `hashes` following #s to close.
                for k in 0..hashes {
                    if self.peek(k) != Some('#') {
                        continue 'outer;
                    }
                }
                for _ in 0..hashes {
                    self.bump();
                }
                break;
            }
        }
    }

    /// `'` starts either a char literal or a lifetime. A char literal is
    /// `'<escape-or-one-char>'`; anything else (`'a`, `'static`, `'_`)
    /// is a lifetime tick with no closing quote.
    fn char_or_lifetime(&mut self, line: u32) {
        self.bump(); // '
        match self.peek(0) {
            Some('\\') => {
                // Escaped char literal: scan to the closing quote.
                self.bump();
                self.bump(); // the escaped character (or `u` of \u{…})
                while let Some(c) = self.bump() {
                    if c == '\'' {
                        break;
                    }
                }
                self.push(Tok::Char, line);
            }
            Some(c) if self.peek(1) == Some('\'') => {
                // 'x' — one char then the closing quote.
                let _ = c;
                self.bump();
                self.bump();
                self.push(Tok::Char, line);
            }
            _ => {
                // Lifetime: consume ident chars after the tick.
                let mut name = String::new();
                while let Some(c) = self.peek(0) {
                    if c == '_' || c.is_alphanumeric() {
                        name.push(c);
                        self.bump();
                    } else {
                        break;
                    }
                }
                self.push(Tok::Lifetime(name), line);
            }
        }
    }

    fn number(&mut self, line: u32) {
        let mut text = String::new();
        while let Some(c) = self.peek(0) {
            if c.is_ascii_alphanumeric() || c == '_' {
                // Exponent sign: 1e-3 / 2E+5.
                if (c == 'e' || c == 'E')
                    && matches!(self.peek(1), Some('+') | Some('-'))
                    && self.peek(2).is_some_and(|d| d.is_ascii_digit())
                {
                    text.push(c);
                    self.bump();
                    text.push(self.peek(0).unwrap_or('+'));
                    self.bump();
                    continue;
                }
                text.push(c);
                self.bump();
            } else if c == '.' && self.peek(1).is_some_and(|d| d.is_ascii_digit()) {
                // 1.5 continues the number; 0..n leaves the dots alone.
                text.push(c);
                self.bump();
            } else {
                break;
            }
        }
        self.push(Tok::Num(text), line);
    }

    /// Identifier — or a string prefix (`r""`, `b""`, `br#""#`, `c""`,
    /// `b'x'`) when the quote follows with no gap.
    fn ident_or_prefixed(&mut self, line: u32) {
        let mut name = String::new();
        while let Some(c) = self.peek(0) {
            if c == '_' || c.is_alphanumeric() {
                name.push(c);
                self.bump();
            } else {
                break;
            }
        }
        let raw = matches!(name.as_str(), "r" | "br" | "rb" | "cr" | "rc");
        let stringy = raw || matches!(name.as_str(), "b" | "c");
        match self.peek(0) {
            Some('"') if stringy && raw => {
                self.raw_string_body();
                self.push(Tok::Str, line);
            }
            Some('"') if stringy => {
                self.bump();
                self.string_body();
                self.push(Tok::Str, line);
            }
            Some('#') if raw && self.looks_like_raw_start() => {
                self.raw_string_body();
                self.push(Tok::Str, line);
            }
            Some('\'') if name == "b" => {
                // Byte char b'x' — reuse the char path.
                self.char_or_lifetime(line);
                if let Some(t) = self.out.tokens.last_mut() {
                    t.tok = Tok::Char;
                }
            }
            _ => self.push(Tok::Ident(name), line),
        }
    }

    /// After `r`: is the upcoming `#…#` run followed by a quote? If not
    /// (e.g. the raw identifier `r#fn`), it is not a raw string.
    fn looks_like_raw_start(&self) -> bool {
        let mut k = 0;
        while self.peek(k) == Some('#') {
            k += 1;
        }
        self.peek(k) == Some('"')
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .tokens
            .into_iter()
            .filter_map(|t| match t.tok {
                Tok::Ident(s) => Some(s),
                _ => None,
            })
            .collect()
    }

    #[test]
    fn raw_strings_hide_their_contents() {
        // Tokens inside raw strings (any hash depth) must not leak.
        let src = r####"let x = r#"a.unwrap() // peqa"#; let y = r"also.unwrap()";"####;
        let ids = idents(src);
        assert_eq!(ids, vec!["let", "x", "let", "y"]);
        let l = lex(src);
        assert_eq!(l.tokens.iter().filter(|t| t.tok == Tok::Str).count(), 2);
        assert!(l.comments.is_empty(), "comment inside raw string leaked");
    }

    #[test]
    fn nested_block_comments_close_at_depth_zero() {
        let src = "a /* outer /* inner */ still comment */ b";
        assert_eq!(idents(src), vec!["a", "b"]);
        let l = lex("/* x /* y */ z */");
        assert_eq!(l.comments.len(), 1);
        assert!(l.comments[0].text.contains("y"));
    }

    #[test]
    fn char_literal_vs_lifetime_tick() {
        let l = lex("let c = 'a'; fn f<'a>(x: &'a str) {} let n = '\\n'; let u = '\\u{1F600}';");
        let chars = l.tokens.iter().filter(|t| t.tok == Tok::Char).count();
        let lifetimes: Vec<_> = l
            .tokens
            .iter()
            .filter_map(|t| match &t.tok {
                Tok::Lifetime(s) => Some(s.clone()),
                _ => None,
            })
            .collect();
        assert_eq!(chars, 3, "'a', '\\n' and '\\u{{..}}' are char literals");
        assert_eq!(lifetimes, vec!["a", "a"], "both <'a> ticks are lifetimes");
    }

    #[test]
    fn byte_and_c_strings_and_byte_chars() {
        let l = lex(r#"let a = b"bytes"; let b2 = br#lit; let c = b'x'; let d = c"cstr";"#);
        assert_eq!(l.tokens.iter().filter(|t| t.tok == Tok::Str).count(), 2);
        assert_eq!(l.tokens.iter().filter(|t| t.tok == Tok::Char).count(), 1);
    }

    #[test]
    fn numbers_keep_suffixes_and_release_range_dots() {
        let l = lex("0..n; 1.5f32; 1e-3; 1_000u64");
        let nums: Vec<_> = l
            .tokens
            .iter()
            .filter_map(|t| match &t.tok {
                Tok::Num(s) => Some(s.clone()),
                _ => None,
            })
            .collect();
        assert_eq!(nums, vec!["0", "1.5f32", "1e-3", "1_000u64"]);
        // `..` survives as two dots for the range in `0..n`.
        let dots = l.tokens.iter().filter(|t| t.tok == Tok::Punct('.')).count();
        assert_eq!(dots, 2);
    }

    #[test]
    fn pathsep_is_one_token_and_lines_track() {
        let l = lex("Vec::new()\n  .clone()");
        assert!(l.tokens.iter().any(|t| t.tok == Tok::PathSep && t.line == 1));
        let clone_tok = l
            .tokens
            .iter()
            .find(|t| t.tok == Tok::Ident("clone".into()))
            .expect("clone ident");
        assert_eq!(clone_tok.line, 2);
    }

    #[test]
    fn string_escapes_do_not_end_early() {
        let l = lex(r#"let s = "quote \" then.unwrap()"; done"#);
        assert_eq!(
            idents(r#"let s = "quote \" then.unwrap()"; done"#),
            vec!["let", "s", "done"]
        );
        assert_eq!(l.tokens.iter().filter(|t| t.tok == Tok::Str).count(), 1);
    }
}
