//! Evaluation harness: perplexity, multiple-choice likelihood scoring
//! (lm-eval-harness analog) and ROUGE-L generation scoring.
//!
//! All scoring goes through the shared fp-layout `*_eval` / `*_logits_b8`
//! artifacts; quantized models are dequantized once into that layout
//! (model::Checkpoint::dequantize), which pytest proved bit-compatible
//! with the quantized forward.

//! The artifact-driven scorers ([`EvalModel`], [`perplexity`],
//! [`mc_accuracy`], [`generate`]) need the PJRT runtime and are gated on
//! the `xla` feature; the pure scoring math (log-softmax, continuation
//! log-prob, ROUGE-L) is always available. Host-side dequantization on
//! the way into the fp-layout artifacts goes through the fused
//! quant::kernels layer via `model::Checkpoint::dequantize`.

use anyhow::{bail, Result};

#[cfg(feature = "xla")]
use crate::data::batch::Batch;
use crate::data::batch::eval_batches;
#[cfg(feature = "xla")]
use crate::data::tasks::{few_shot_prefix, McTask};
#[cfg(feature = "xla")]
use crate::model::Checkpoint;
use crate::model::PackedModel;
use crate::serve::ModelGeom;
#[cfg(feature = "xla")]
use crate::runtime::{literal_to_f32, Artifact, Runtime};
#[cfg(feature = "xla")]
use crate::tokenizer::{Tokenizer, BOS, PAD};
#[cfg(feature = "xla")]
use crate::util::Pcg32;

/// Device-resident parameters for repeated evaluation calls.
#[cfg(feature = "xla")]
pub struct EvalModel {
    art: std::rc::Rc<Artifact>,
    params: Vec<xla::PjRtBuffer>,
}

#[cfg(feature = "xla")]
impl EvalModel {
    /// `artifact_name` must be an eval / logits / logits_q artifact;
    /// `ck` must be in the artifact's param layout.
    pub fn new(rt: &Runtime, artifact_name: &str, ck: &Checkpoint) -> Result<EvalModel> {
        let art = rt.load(artifact_name)?;
        if !matches!(art.meta.kind.as_str(), "eval" | "logits" | "logits_q") {
            bail!("{artifact_name} is not an eval/logits artifact");
        }
        let metas: Vec<_> = art.meta.layout();
        let tensors = ck.assemble_strict(&metas)?;
        let params = tensors
            .iter()
            .map(|t| rt.tensor_to_device(t))
            .collect::<Result<Vec<_>>>()?;
        Ok(EvalModel { art, params })
    }

    pub fn meta(&self) -> &crate::runtime::ArtifactMeta {
        &self.art.meta
    }

    pub fn batch_size(&self) -> usize {
        self.art.meta.inputs[0].shape[0]
    }

    pub fn seq_len(&self) -> usize {
        self.art.meta.inputs[0].shape[1]
    }

    /// (sum_nll, n_tokens) over one masked batch (eval artifacts).
    pub fn nll_batch(&self, rt: &Runtime, batch: &Batch) -> Result<(f64, f64)> {
        let meta = &self.art.meta;
        if meta.kind != "eval" {
            bail!("nll_batch needs an eval artifact, got {}", meta.kind);
        }
        let tok = rt.to_device_i32(&batch.tokens, &meta.inputs[0].shape)?;
        let mask = rt.to_device_f32(&batch.mask, &meta.inputs[1].shape)?;
        let mut inputs: Vec<&xla::PjRtBuffer> = vec![&tok, &mask];
        inputs.extend(self.params.iter());
        let outs = self.art.run_b(&inputs)?;
        Ok((literal_to_f32(&outs[0])? as f64, literal_to_f32(&outs[1])? as f64))
    }

    /// Full logits (B, T, V) for a token batch (logits artifacts).
    pub fn logits(&self, rt: &Runtime, tokens: &[i32]) -> Result<Vec<f32>> {
        let meta = &self.art.meta;
        if !matches!(meta.kind.as_str(), "logits" | "logits_q") {
            bail!("logits needs a logits artifact, got {}", meta.kind);
        }
        let tok = rt.to_device_i32(tokens, &meta.inputs[0].shape)?;
        let mut inputs: Vec<&xla::PjRtBuffer> = vec![&tok];
        inputs.extend(self.params.iter());
        let outs = self.art.run_b(&inputs)?;
        Ok(outs[0].to_vec::<f32>()?)
    }

    /// Swap one named parameter buffer in place (PEQA task switching on
    /// the quantized serving path: only s / z tensors move).
    pub fn swap_param(&mut self, rt: &Runtime, name: &str, t: &crate::tensor::Tensor) -> Result<()> {
        let metas = self.art.meta.layout();
        let idx = metas
            .iter()
            .position(|p| p.name == name)
            .ok_or_else(|| anyhow::anyhow!("no param '{name}' in {}", self.art.meta.name))?;
        if metas[idx].shape != t.shape() {
            bail!("swap_param '{name}': shape mismatch");
        }
        self.params[idx] = rt.tensor_to_device(t)?;
        Ok(())
    }
}

/// Perplexity of `ck` (fp layout) over a token stream.
#[cfg(feature = "xla")]
pub fn perplexity(rt: &Runtime, eval_art: &str, ck: &Checkpoint, stream: &[u32]) -> Result<f64> {
    let model = EvalModel::new(rt, eval_art, ck)?;
    let (b, t) = (model.batch_size(), model.seq_len());
    let mut sum = 0.0;
    let mut count = 0.0;
    for batch in eval_batches(stream, b, t) {
        let (s, c) = model.nll_batch(rt, &batch)?;
        sum += s;
        count += c;
    }
    if count == 0.0 {
        bail!("empty eval stream");
    }
    Ok((sum / count).exp())
}

/// Host perplexity of a packed model over a token stream — the
/// tune→eval half of the loop that needs no artifacts: deterministic
/// non-overlapping eval windows ([`eval_batches`]) scored by the host
/// training forward (`train::host::batch_nll` over the shared
/// `model::blocks` compute core), every projection running
/// through the fused packed kernels. One `train::TapeArena` is reused
/// across ALL eval batches — forward-only mode retains no tape, so the
/// whole perplexity sweep runs out of one set of activation slabs
/// instead of allocating per batch. `n_heads` disambiguates the
/// geometry ([`ModelGeom::infer`]). The *stream* tokens must fit the
/// model's vocab; the PAD filler `eval_batches` writes into unfilled
/// tails (always mask-0) is remapped to token 0 here so models with
/// vocab ≤ PAD still score — padded positions sit after every scored
/// transition of their row, so under causal attention the remap cannot
/// change any masked-in logit.
pub fn host_perplexity(
    model: &PackedModel,
    n_heads: usize,
    stream: &[u32],
    batch: usize,
    seq: usize,
    threads: usize,
) -> Result<f64> {
    let geom = ModelGeom::infer(model, n_heads)?;
    if let Some(&bad) = stream.iter().find(|&&t| t as usize >= geom.vocab) {
        bail!("stream token {bad} out of the model's vocab {}", geom.vocab);
    }
    let mut arena = crate::train::TapeArena::new();
    let mut sum = 0.0;
    let mut count = 0.0;
    for mut b in eval_batches(stream, batch.max(1), seq.max(2)) {
        for t in b.tokens.iter_mut() {
            if *t as usize >= geom.vocab {
                *t = 0; // PAD filler of an unfilled tail (mask 0)
            }
        }
        let (s, c) = crate::train::host::batch_nll(model, &geom, threads, &b, &mut arena)?;
        sum += s;
        count += c;
    }
    if count == 0.0 {
        bail!("empty eval stream");
    }
    Ok((sum / count).exp())
}

#[cfg_attr(not(feature = "xla"), allow(dead_code))]
fn log_softmax_row(row: &[f32]) -> Vec<f32> {
    let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let lse = row.iter().map(|&x| (x - max).exp()).sum::<f32>().ln() + max;
    row.iter().map(|&x| x - lse).collect()
}

/// Sum log-prob of `target` tokens at positions [start, start+len) given
/// flat (T, V) logits for one sequence. Position p predicts token p+1.
#[cfg_attr(not(feature = "xla"), allow(dead_code))]
fn continuation_logprob(
    logits: &[f32],
    vocab: usize,
    tokens: &[i32],
    start: usize,
    len: usize,
) -> f32 {
    let mut total = 0.0;
    for p in start..start + len {
        let row = &logits[(p - 1) * vocab..p * vocab];
        let ls = log_softmax_row(row);
        total += ls[tokens[p] as usize];
    }
    total
}

/// Multiple-choice accuracy by option likelihood, k-shot.
#[cfg(feature = "xla")]
pub fn mc_accuracy(
    rt: &Runtime,
    logits_art: &str,
    ck: &Checkpoint,
    tok: &Tokenizer,
    task: &McTask,
    k_shot: usize,
    seed: u64,
) -> Result<f64> {
    let model = EvalModel::new(rt, logits_art, ck)?;
    let (b, t, vocab) = (
        model.batch_size(),
        model.seq_len(),
        model.meta().outputs[0].shape[2],
    );
    let mut rng = Pcg32::seeded(seed, 0x5c0e);

    // Flatten (item, option) pairs into scoring jobs.
    struct Job {
        tokens: Vec<i32>,
        start: usize,
        len: usize,
        item: usize,
        option: usize,
    }
    let mut jobs = Vec::new();
    for (i, item) in task.items.iter().enumerate() {
        let prefix = few_shot_prefix(task, k_shot, &mut rng);
        let prompt_ids = {
            let mut v = vec![BOS];
            v.extend(tok.encode(&format!("{prefix}{}", item.prompt)));
            v
        };
        for (o, opt) in item.options.iter().enumerate() {
            let opt_ids = tok.encode(opt);
            let mut ids: Vec<i32> = prompt_ids.iter().map(|&x| x as i32).collect();
            let start = ids.len();
            ids.extend(opt_ids.iter().map(|&x| x as i32));
            // Left-truncate long few-shot prompts, keeping the continuation.
            let (ids, start) = if ids.len() > t {
                let cut = ids.len() - t;
                if cut >= start {
                    bail!("option longer than context window");
                }
                (ids[cut..].to_vec(), start - cut)
            } else {
                (ids, start)
            };
            let len = ids.len() - start;
            let mut padded = ids;
            padded.resize(t, PAD as i32);
            jobs.push(Job { tokens: padded, start, len, item: i, option: o });
        }
    }

    // Score jobs in batches of `b`.
    let mut scores = vec![vec![f32::NEG_INFINITY; 4]; task.items.len()];
    for chunk in jobs.chunks(b) {
        let mut tokens = Vec::with_capacity(b * t);
        for j in chunk {
            tokens.extend(&j.tokens);
        }
        // Pad the final partial batch by repeating the last job.
        while tokens.len() < b * t {
            tokens.extend(&chunk.last().unwrap().tokens);
        }
        let logits = model.logits(rt, &tokens)?;
        for (bi, j) in chunk.iter().enumerate() {
            let seq_logits = &logits[bi * t * vocab..(bi + 1) * t * vocab];
            scores[j.item][j.option] =
                continuation_logprob(seq_logits, vocab, &j.tokens, j.start, j.len);
        }
    }

    let mut correct = 0usize;
    for (i, item) in task.items.iter().enumerate() {
        // total_cmp: a NaN score (degenerate logits) must not panic the
        // accuracy sweep — under a total order it just loses/wins
        // deterministically.
        let pred = scores[i]
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(idx, _)| idx)
            .unwrap_or(0);
        if pred == item.correct {
            correct += 1;
        }
    }
    Ok(correct as f64 / task.items.len() as f64)
}

/// Greedy generation through a logits artifact (window decode: re-feeds
/// the last T tokens each step — fine at reproduction scale).
#[cfg(feature = "xla")]
pub fn generate(
    model: &EvalModel,
    rt: &Runtime,
    prompt: &[u32],
    max_new: usize,
    stop: u32,
) -> Result<Vec<u32>> {
    let (b, t, vocab) = (
        model.batch_size(),
        model.seq_len(),
        model.meta().outputs[0].shape[2],
    );
    let mut ids: Vec<u32> = prompt.to_vec();
    let mut out = Vec::new();
    for _ in 0..max_new {
        let window: Vec<u32> = if ids.len() > t {
            ids[ids.len() - t..].to_vec()
        } else {
            ids.clone()
        };
        let pos = window.len() - 1;
        let mut tokens: Vec<i32> = window.iter().map(|&x| x as i32).collect();
        tokens.resize(t, PAD as i32);
        let mut batch_tokens = tokens.clone();
        for _ in 1..b {
            batch_tokens.extend(&tokens);
        }
        let logits = model.logits(rt, &batch_tokens)?;
        let row = &logits[pos * vocab..(pos + 1) * vocab];
        // NaN-safe greedy argmax (total_cmp) — same fix class as
        // serve::engine's sampler in PR 3.
        let next = crate::util::argmax_f32(row).unwrap_or(0) as u32;
        if next == stop {
            break;
        }
        out.push(next);
        ids.push(next);
    }
    Ok(out)
}

/// ROUGE-L F1 over whitespace tokens (Table 14 metric).
pub fn rouge_l(candidate: &str, reference: &str) -> f64 {
    let c: Vec<&str> = candidate.split_whitespace().collect();
    let r: Vec<&str> = reference.split_whitespace().collect();
    if c.is_empty() || r.is_empty() {
        return 0.0;
    }
    // LCS length by DP.
    let mut dp = vec![0usize; r.len() + 1];
    for ci in &c {
        let mut prev = 0;
        for (j, rj) in r.iter().enumerate() {
            let cur = dp[j + 1];
            dp[j + 1] = if ci == rj { prev + 1 } else { dp[j + 1].max(dp[j]) };
            prev = cur;
        }
    }
    let lcs = dp[r.len()] as f64;
    let p = lcs / c.len() as f64;
    let rec = lcs / r.len() as f64;
    if p + rec == 0.0 { 0.0 } else { 2.0 * p * rec / (p + rec) }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_softmax_normalizes() {
        let ls = log_softmax_row(&[1.0, 2.0, 3.0]);
        let total: f32 = ls.iter().map(|x| x.exp()).sum();
        assert!((total - 1.0).abs() < 1e-5);
        assert!(ls[2] > ls[1] && ls[1] > ls[0]);
    }

    #[test]
    fn rouge_l_cases() {
        assert!((rouge_l("the cat sat", "the cat sat") - 1.0).abs() < 1e-9);
        assert_eq!(rouge_l("", "x"), 0.0);
        assert_eq!(rouge_l("a b c", "d e f"), 0.0);
        // partial overlap: LCS("the red cat", "the cat") = 2 →
        // P=2/3, R=1 → F1 = 0.8.
        assert!((rouge_l("the red cat", "the cat") - 0.8).abs() < 1e-9);
    }

    #[test]
    fn continuation_logprob_indexing() {
        // vocab=2, T=3, uniform logits → each token logprob = ln(1/2).
        let logits = vec![0.0f32; 3 * 2];
        let tokens = vec![0i32, 1, 0];
        let lp = continuation_logprob(&logits, 2, &tokens, 1, 2);
        assert!((lp - 2.0 * (0.5f32).ln()).abs() < 1e-5);
    }
}
