//! The **transformer compute core** — one set of llama-family block
//! primitives shared by the serving engine (`serve::engine`) and the
//! host training backend (`train::host`).
//!
//! PEQA's whole premise is that the *same* quantized weights serve both
//! fine-tuning (scale-only gradients) and deployment (scale-swap
//! serving). Before this module, the repo maintained two hand-rolled
//! forwards — the engine's KV-cache decode and the trainer's
//! full-sequence tape — that each reimplemented RMSNorm, rotary
//! positions, causal attention and SwiGLU, held together only by a
//! ≤ 1e-4 parity test. Now both are thin drivers over the functions
//! here, so the training forward *is* the inference forward plus a
//! tape: same epsilon, same rotary table, same fixed-order reductions —
//! and the trainer-vs-engine parity test pins **bitwise** equality
//! (tests/train_host.rs).
//!
//! Contents:
//! * [`RMS_EPS`] / [`rope_freqs`] — the shared norm epsilon and rotary
//!   frequency table.
//! * [`rms_norm_rows_into`] (with optional inverse-norm capture for the
//!   backward tape) and [`rms_backward_into`].
//! * [`rope_row_at`] / [`rope_backward_rows`] — per-head half-split
//!   rotary apply and its transpose.
//! * Fixed-order causal attention over either a [`KvSeq`] window
//!   ([`attend_row`], [`attend_seq_chunk`] — the serving pass, ring or
//!   paged) or a full-sequence tape ([`attend_seq_tape`], parameterized
//!   by [`Tape`]; [`attend_seq_backward`] is its reverse mode). Every
//!   caller streams K/V as contiguous segments through the same
//!   [`attend_row_slabs`] kernel — a cloneable segment iterator: two
//!   slabs for the ring, one prefix slab for the tape, a page walk for
//!   the paged cache. One sweep per cached row for *all* heads with
//!   4-way blocked dots, per-head divide-at-end softmax. The arithmetic
//!   per (head, position) is a fixed-order reduction independent of
//!   batch composition, worker count and segmentation, which is what
//!   makes every consumer bitwise thread/batch/page-size invariant.
//! * SwiGLU forward/backward ([`swiglu_rows_into`],
//!   [`swiglu_backward_into`]) and the dense LM-head kernels
//!   ([`dense_rows_into`], [`dense_grad_rows_into`]).
//! * [`proj_into`] — the packed-projection call both drivers make:
//!   fused quantized GEMM through a caller-owned [`ProjScratch`], using
//!   the ragged direct-layout kernel entry
//!   (`quant::kernels::PackedMatrix::matmul_t_ragged`) when the batch
//!   amortizes it and the yᵀ scratch entry otherwise — the two entries
//!   are bitwise identical, so the policy is purely a throughput
//!   decision.
//!
//! Consumers: `serve::engine::forward_multi` (decode/prefill),
//! `train::host::forward_tape`/`backward` (tuning + `eval`'s host
//! perplexity). Every future numeric or perf change to the block math
//! lands here exactly once.

use anyhow::{anyhow, Result};

use super::PackedModel;
use crate::quant::kernels::KernelScratch;
use crate::quant::simd::{self, SimdOps};
use crate::serve::kvcache::KvSeq;
use crate::tensor::Tensor;

/// RMS-norm epsilon shared by serving and training: a model is tuned
/// under exactly the norm it is served with.
pub const RMS_EPS: f32 = 1e-6;

/// The rotary frequency table for a head dimension — the one formula
/// both the serving engine and the host training backend rotate with.
pub fn rope_freqs(head_dim: usize) -> Vec<f32> {
    let half = head_dim / 2;
    (0..half).map(|i| 10000.0f32.powf(-(i as f32) / half as f32)).collect()
}

/// Grow-only buffer sizing: slabs hold stale data between calls; every
/// consumer writes its full `[..len]` range before reading, which keeps
/// results bitwise independent of buffer history.
#[inline]
pub(crate) fn ensure(buf: &mut Vec<f32>, n: usize) {
    if buf.len() < n {
        buf.resize(n, 0.0);
    }
}

/// Per-layer tensor names resolved once at construction so the per-step
/// hot loops do no string formatting (shared by engine and tuner).
pub(crate) struct LayerNames {
    pub ln1: String,
    pub ln2: String,
    pub q: String,
    pub k: String,
    pub v: String,
    pub o: String,
    pub gate: String,
    pub up: String,
    pub down: String,
}

impl LayerNames {
    // peqa-lint: allow(hot-path-alloc) -- construction-time only: these
    // strings are built once per layer at engine/tuner setup precisely
    // so the per-step loops never format names.
    pub fn new(layer: usize) -> LayerNames {
        let lp = format!("layers.{layer}");
        LayerNames {
            ln1: format!("{lp}.ln1.g"),
            ln2: format!("{lp}.ln2.g"),
            q: format!("{lp}.attn.q"),
            k: format!("{lp}.attn.k"),
            v: format!("{lp}.attn.v"),
            o: format!("{lp}.attn.o"),
            gate: format!("{lp}.mlp.gate"),
            up: format!("{lp}.mlp.up"),
            down: format!("{lp}.mlp.down"),
        }
    }
}

/// Shared projection scratch owned by the caller's arena
/// (`serve::engine::Scratch`, `train::host::TapeArena`) and reused
/// across calls: the fused kernel's yᵀ transpose buffer plus the full
/// [`KernelScratch`] pool (per-(row, group) sums, lane-tier transposes,
/// per-worker code tiles, dense-head accumulators) — so a steady-state
/// decode or training step does no per-call kernel allocation
/// (`benches/finetune_step.rs` counts exactly this).
#[derive(Default)]
pub struct ProjScratch {
    yt: Vec<f32>,
    pub(crate) kernel: KernelScratch,
}

/// One worker's attention scratch: the `(n_heads, window)` score matrix
/// plus per-head running max / softmax denominator. The score buffer
/// doubles as the dP row scratch of [`attend_seq_backward`].
#[derive(Default)]
pub struct AttnScratch {
    scores: Vec<f32>,
    head_max: Vec<f32>,
    head_den: Vec<f32>,
}

/// Tape policy of the full-sequence attention primitive
/// ([`attend_seq_tape`]): `None` is the inference/eval shape (one
/// O(window) score row lives at a time — linear in T); `Keep` saves the
/// causal softmax probabilities into the caller's `(heads, T, T)` slab
/// for reverse mode (entries above the diagonal are never written nor
/// read). This is the *only* difference between the forward a request
/// decodes through and the forward a tuner differentiates.
pub enum Tape<'a> {
    None,
    Keep(&'a mut [f32]),
}

/// One packed (or dense-fallback) projection over the concatenated rows
/// of ragged sequence spans, into a scratch-backed output slab.
///
/// Packed projections run the fused quantized GEMM: the ragged
/// direct-layout entry (`matmul_t_ragged` — no yᵀ transpose, each
/// worker walks whole per-sequence row spans) once every worker
/// amortizes its code-tile unpack over ≥ 4 rows, the yᵀ scratch entry
/// (`matmul_t_rows_scratch`) otherwise — single-row decode is already
/// transpose-free there. Both entries accumulate every output element
/// in the same fixed order, so the choice never changes a bit of the
/// result.
pub fn proj_into(
    model: &PackedModel,
    threads: usize,
    name: &str,
    x: &[f32],
    spans: &[usize],
    out: &mut Vec<f32>,
    scratch: &mut ProjScratch,
) -> Result<()> {
    let m: usize = spans.iter().sum();
    let ops = simd::active();
    if let Some(pm) = model.matrix(name) {
        ensure(out, m * pm.rows);
        if m > 1 && m >= 4 * threads.max(1) {
            pm.matmul_t_ragged_core(
                x,
                spans,
                threads,
                &mut out[..m * pm.rows],
                ops,
                &mut scratch.kernel,
            )
        } else {
            pm.matmul_t_rows_core(
                x,
                m,
                threads,
                &mut out[..m * pm.rows],
                &mut scratch.yt,
                ops,
                &mut scratch.kernel,
            )
        }
    } else {
        // peqa-lint: allow(hot-path-alloc) -- dense-fallback lookup only:
        // packed models (the serving/training hot path) take the branch
        // above; this branch exists for fp reference checkpoints.
        let wname = format!("{name}.w");
        let w = model
            .fp_tensor(&wname)
            .ok_or_else(|| anyhow!("no projection '{name}'"))?;
        let (o, _) = w.dims2()?;
        ensure(out, m * o);
        dense_rows_core(w, x, m, &mut out[..m * o], ops, &mut scratch.kernel);
        Ok(())
    }
}

/// Shard `total` independent items (batch rows, sequences) into
/// contiguous chunks of `total.div_ceil(workers)` and run one chunk per
/// scoped worker thread — the ONE home of the sequence-sharding
/// scaffolding that the serving attention pass, the trainer attention
/// pass and its backward all drive (they used to carry three hand-rolled
/// copies of the `mem::take` + `split_at_mut` remainder walk).
///
/// `carve(start, take)` runs sequentially on the calling thread and
/// splits off the chunk's payload — typically a tuple of disjoint
/// `&mut` sub-slices peeled off remainder slices the closure holds
/// (`std::mem::take` + `split_at_mut`, which keeps the outer lifetime
/// the scoped threads need). `run(start, take, payload)` executes the
/// chunk: inline on the calling thread when `workers <= 1` (the hot
/// single-worker path never spawns), on a scoped thread otherwise.
///
/// Chunk boundaries depend only on `(total, workers)` and payloads are
/// disjoint, so any bitwise-determinism guarantee of the per-chunk body
/// extends unchanged to every worker count — the invariance argument
/// every caller's tests pin.
pub fn shard_chunks<T, C, R>(total: usize, workers: usize, mut carve: C, run: R)
where
    T: Send,
    C: FnMut(usize, usize) -> T,
    R: Fn(usize, usize, T) + Sync,
{
    if total == 0 {
        return;
    }
    if workers <= 1 {
        let payload = carve(0, total);
        run(0, total, payload);
        return;
    }
    let per = total.div_ceil(workers);
    std::thread::scope(|s| {
        let run = &run;
        let mut start = 0usize;
        while start < total {
            let take = per.min(total - start);
            let payload = carve(start, take);
            s.spawn(move || run(start, take, payload));
            start += take;
        }
    });
}

// ---------------------------------------------------------------- norms

/// RMSNorm over `b` rows of width `d` into a scratch-backed output slab:
/// g · x · rsqrt(mean(x²) + ε). With `invs` the per-row inverse factor
/// is captured for the backward tape (the same factor the forward used —
/// no recompute drift).
pub fn rms_norm_rows_into(
    x: &[f32],
    g: &[f32],
    b: usize,
    d: usize,
    out: &mut Vec<f32>,
    mut invs: Option<&mut Vec<f32>>,
) {
    ensure(out, b * d);
    if let Some(iv) = invs.as_mut() {
        ensure(iv, b);
    }
    for bi in 0..b {
        let xr = &x[bi * d..(bi + 1) * d];
        let mut ss = 0.0f32;
        for &v in xr {
            ss += v * v;
        }
        let inv = 1.0 / (ss / d as f32 + RMS_EPS).sqrt();
        if let Some(iv) = invs.as_mut() {
            iv[bi] = inv;
        }
        let orow = &mut out[bi * d..(bi + 1) * d];
        for j in 0..d {
            orow[j] = g[j] * xr[j] * inv;
        }
    }
}

/// Allocating [`rms_norm_rows_into`] (reference paths + tests).
// peqa-lint: allow(hot-path-alloc) -- deliberately-allocating reference
// wrapper; steady-state callers use rms_norm_rows_into with a pooled
// slab.
pub fn rms_norm_rows(x: &[f32], g: &[f32], b: usize, d: usize) -> Vec<f32> {
    let mut out = Vec::new();
    rms_norm_rows_into(x, g, b, d, &mut out, None);
    out
}

/// RMSNorm backward into a scratch-backed slab:
/// dx_j = inv·g_j·dy_j − x_j·inv³/d · Σ_k dy_k·g_k·x_k.
pub fn rms_backward_into(
    dy: &[f32],
    x: &[f32],
    g: &[f32],
    invs: &[f32],
    b: usize,
    d: usize,
    dx: &mut Vec<f32>,
) {
    ensure(dx, b * d);
    for bi in 0..b {
        let xr = &x[bi * d..(bi + 1) * d];
        let dyr = &dy[bi * d..(bi + 1) * d];
        let inv = invs[bi];
        let mut s = 0.0f32;
        for j in 0..d {
            s += dyr[j] * g[j] * xr[j];
        }
        let c = inv * inv * inv * s / d as f32;
        let dxr = &mut dx[bi * d..(bi + 1) * d];
        for j in 0..d {
            dxr[j] = inv * g[j] * dyr[j] - xr[j] * c;
        }
    }
}

// ---------------------------------------------------------------- rotary

/// Rotate one (d_model,) row in place at absolute position `pos`
/// (per-head half-split rotary, matching python/compile/model.py).
pub fn rope_row_at(freqs: &[f32], n_heads: usize, head_dim: usize, row: &mut [f32], pos: usize) {
    let half = head_dim / 2;
    let p = pos as f32;
    for h in 0..n_heads {
        let s = &mut row[h * head_dim..(h + 1) * head_dim];
        for i in 0..half {
            let (sin, cos) = (p * freqs[i]).sin_cos();
            let (x1, x2) = (s[i], s[i + half]);
            s[i] = x1 * cos - x2 * sin;
            s[i + half] = x1 * sin + x2 * cos;
        }
    }
}

/// Backward of the rotary apply over full-sequence rows (row `r` sits at
/// position `r % t_len`): the rotation is orthogonal, so the gradient
/// rotates by −θ (the transpose).
pub fn rope_backward_rows(
    freqs: &[f32],
    n_heads: usize,
    head_dim: usize,
    rows: &mut [f32],
    t_len: usize,
    d: usize,
) {
    let half = head_dim / 2;
    for (r, row) in rows.chunks_mut(d).enumerate() {
        let p = (r % t_len) as f32;
        for h in 0..n_heads {
            let s = &mut row[h * head_dim..(h + 1) * head_dim];
            for i in 0..half {
                let (sin, cos) = (p * freqs[i]).sin_cos();
                let (g1, g2) = (s[i], s[i + half]);
                s[i] = g1 * cos + g2 * sin;
                s[i + half] = -g1 * sin + g2 * cos;
            }
        }
    }
}

// ------------------------------------------------------------- attention

/// Head-blocked causal attention of one already-roped query row over a
/// window of `n` K/V rows supplied as contiguous segments in position
/// order. Writes the (d_model,) context row.
///
/// The segment source is any cloneable iterator of `(k, v)` row slabs:
/// the ring cache yields its ≤ 2 wrap slabs, the full-sequence tape one
/// prefix slab, and the paged cache a page walk of ≤ `window/P + 1`
/// segments — the kernel clones the iterator for its two sweeps and
/// never allocates. Each cached row is visited ONCE for all heads
/// (score pass over K, accumulate pass over V) with 4-way blocked dots;
/// softmax divides once per head at the end. Scores/max/denominator
/// live in the calling worker's [`AttnScratch`]. The arithmetic per
/// (head, position) is a fixed-order reduction independent of batch
/// composition, thread count and slab segmentation, preserving every
/// consumer's bitwise invariances.
pub(crate) fn attend_row_slabs<'a, I>(
    n_heads: usize,
    head_dim: usize,
    n: usize,
    slabs: I,
    q: &[f32],
    ctx: &mut [f32],
    scratch: &mut AttnScratch,
) where
    I: Iterator<Item = (&'a [f32], &'a [f32])> + Clone,
{
    let AttnScratch { scores, head_max, head_den } = scratch;
    let d = n_heads * head_dim;
    let inv = 1.0 / (head_dim as f32).sqrt();
    scores.clear();
    scores.resize(n_heads * n, 0.0);
    head_max.clear();
    head_max.resize(n_heads, f32::NEG_INFINITY);
    head_den.clear();
    head_den.resize(n_heads, 0.0);

    // Score pass: one sweep over the contiguous K slabs, all heads per row.
    let mut j = 0usize;
    for (kseg, _) in slabs.clone() {
        for krow in kseg.chunks_exact(d) {
            for h in 0..n_heads {
                let sc = inv
                    * dot_blocked(
                        &q[h * head_dim..(h + 1) * head_dim],
                        &krow[h * head_dim..(h + 1) * head_dim],
                    );
                scores[h * n + j] = sc;
                if sc > head_max[h] {
                    head_max[h] = sc;
                }
            }
            j += 1;
        }
    }
    // Stable softmax numerators + denominators, per head.
    for h in 0..n_heads {
        let mx = head_max[h];
        let mut den = 0.0f32;
        for sc in scores[h * n..(h + 1) * n].iter_mut() {
            *sc = (*sc - mx).exp();
            den += *sc;
        }
        head_den[h] = den;
    }
    // Accumulate pass: one sweep over the contiguous V slabs, then one
    // division per head (Σ wⱼ·vⱼ / Σ wⱼ).
    ctx[..d].fill(0.0);
    let mut j = 0usize;
    for (_, vseg) in slabs {
        for vrow in vseg.chunks_exact(d) {
            for h in 0..n_heads {
                axpy_blocked(
                    scores[h * n + j],
                    &vrow[h * head_dim..(h + 1) * head_dim],
                    &mut ctx[h * head_dim..(h + 1) * head_dim],
                );
            }
            j += 1;
        }
    }
    for h in 0..n_heads {
        let id = 1.0 / head_den[h];
        for t in ctx[h * head_dim..(h + 1) * head_dim].iter_mut() {
            *t *= id;
        }
    }
}

/// [`attend_row_slabs`] over a [`KvSeq`] window: the serving decode
/// shape. A ring cache wraps at most once, so its window arrives as two
/// contiguous slabs; a paged cache streams its page walk. Either way
/// the rows, their order, and the per-row arithmetic are identical —
/// the bitwise paged-vs-ring parity the serve tests pin.
#[allow(clippy::too_many_arguments)]
pub(crate) fn attend_row(
    n_heads: usize,
    head_dim: usize,
    cache: &KvSeq,
    layer: usize,
    abs: usize,
    q: &[f32],
    ctx: &mut [f32],
    scratch: &mut AttnScratch,
) {
    let n = cache.window_len(abs);
    match cache {
        KvSeq::Ring(c) => {
            let slabs = c.window_slabs(layer, abs);
            attend_row_slabs(n_heads, head_dim, n, slabs.iter().copied(), q, ctx, scratch);
        }
        KvSeq::Paged(c) => {
            attend_row_slabs(n_heads, head_dim, n, c.window_segments(layer, abs), q, ctx, scratch);
        }
    }
}

/// One worker's share of the serving attention pass: rotary + cache
/// append + [`attend_row`] for a contiguous range of sequences.
/// `q_c`/`k_c`/`v_c`/`ctx_c` are that range's row slabs; every sequence
/// only touches its own cache, so chunks run concurrently and the
/// per-sequence arithmetic is identical at any worker count.
#[allow(clippy::too_many_arguments)]
pub(crate) fn attend_seq_chunk(
    freqs: &[f32],
    hh: usize,
    hd: usize,
    d: usize,
    layer: usize,
    seq_chunk: &[&[u32]],
    cache_chunk: &mut [&mut KvSeq],
    q_c: &mut [f32],
    k_c: &mut [f32],
    v_c: &[f32],
    ctx_c: &mut [f32],
    attn: &mut AttnScratch,
) {
    let mut r0 = 0usize;
    for (si, seq) in seq_chunk.iter().enumerate() {
        let cache = &mut *cache_chunk[si];
        let base = cache.pos();
        for ti in 0..seq.len() {
            let r = r0 + ti;
            let abs = base + ti;
            rope_row_at(freqs, hh, hd, &mut q_c[r * d..(r + 1) * d], abs);
            rope_row_at(freqs, hh, hd, &mut k_c[r * d..(r + 1) * d], abs);
            cache.write(layer, abs, &k_c[r * d..(r + 1) * d], &v_c[r * d..(r + 1) * d]);
            attend_row(
                hh,
                hd,
                cache,
                layer,
                abs,
                &q_c[r * d..(r + 1) * d],
                &mut ctx_c[r * d..(r + 1) * d],
                attn,
            );
        }
        r0 += seq.len();
    }
}

/// Rotary + fixed-order causal attention over ONE full sequence of
/// `t_len` rows — the training-forward shape. `q`/`k` are roped in
/// place at positions `0..t_len`; position `t` then attends over
/// `k/v[0..=t]` through exactly the windowed kernel the serving engine
/// uses ([`attend_row_slabs`] with the sequence prefix as one contiguous
/// slab), so a training forward is bitwise the engine's prefill of the
/// same tokens. With [`Tape::Keep`] the per-(head, position) softmax
/// probabilities are saved into the caller's `(heads, T, T)` slab for
/// [`attend_seq_backward`]; with [`Tape::None`] only the O(window)
/// score row in `scratch` is ever live (loss/perplexity evaluation
/// stays linear in T).
#[allow(clippy::too_many_arguments)]
pub fn attend_seq_tape(
    freqs: &[f32],
    hh: usize,
    hd: usize,
    t_len: usize,
    q: &mut [f32],
    k: &mut [f32],
    v: &[f32],
    ctx: &mut [f32],
    scratch: &mut AttnScratch,
    mut tape: Tape<'_>,
) {
    let d = hh * hd;
    for t in 0..t_len {
        rope_row_at(freqs, hh, hd, &mut q[t * d..(t + 1) * d], t);
        rope_row_at(freqs, hh, hd, &mut k[t * d..(t + 1) * d], t);
        let n = t + 1;
        let slabs = [(&k[..n * d], &v[..n * d])];
        attend_row_slabs(
            hh,
            hd,
            n,
            slabs.iter().copied(),
            &q[t * d..(t + 1) * d],
            &mut ctx[t * d..(t + 1) * d],
            scratch,
        );
        if let Tape::Keep(probs) = &mut tape {
            // softmax probabilities = exp numerators / per-head denom —
            // exactly what the score buffer holds after the attend.
            for h in 0..hh {
                let den = scratch.head_den[h];
                let prow = &mut probs[(h * t_len + t) * t_len..(h * t_len + t) * t_len + n];
                for (pj, &e) in prow.iter_mut().zip(&scratch.scores[h * n..h * n + n]) {
                    *pj = e / den;
                }
            }
        }
    }
}

/// Reverse mode of [`attend_seq_tape`] for one sequence, rotary
/// included: given the taped softmax probabilities and the roped q/k
/// plus raw v rows, accumulates dQ/dK/dV (overwritten) from the
/// context-row gradient `dctx`, then un-rotates dQ/dK. Fixed
/// (head, position) order — bitwise identical however sequences are
/// sharded over workers. The worker's [`AttnScratch`] score buffer is
/// reused as the dP row scratch.
#[allow(clippy::too_many_arguments)]
pub fn attend_seq_backward(
    freqs: &[f32],
    hh: usize,
    hd: usize,
    t_len: usize,
    probs: &[f32],
    q: &[f32],
    k: &[f32],
    v: &[f32],
    dctx: &[f32],
    dq: &mut [f32],
    dk: &mut [f32],
    dv: &mut [f32],
    scratch: &mut AttnScratch,
) {
    let d = hh * hd;
    let inv_sqrt = 1.0 / (hd as f32).sqrt();
    dq.fill(0.0);
    dk.fill(0.0);
    dv.fill(0.0);
    let dp = &mut scratch.scores;
    dp.clear();
    dp.resize(t_len, 0.0);
    for h in 0..hh {
        for t in 0..t_len {
            let prow = &probs[(h * t_len + t) * t_len..(h * t_len + t) * t_len + t + 1];
            let dcx = &dctx[t * d + h * hd..t * d + (h + 1) * hd];
            // dP and dV.
            let mut row_dot = 0.0f32;
            for j in 0..=t {
                let vr = &v[j * d + h * hd..j * d + (h + 1) * hd];
                let mut acc = 0.0f32;
                for u in 0..hd {
                    acc += dcx[u] * vr[u];
                }
                dp[j] = acc;
                row_dot += acc * prow[j];
                let dvr = &mut dv[j * d + h * hd..j * d + (h + 1) * hd];
                for u in 0..hd {
                    dvr[u] += prow[j] * dcx[u];
                }
            }
            // Softmax backward → dS, then dQ / dK.
            let qr = &q[t * d + h * hd..t * d + (h + 1) * hd];
            let dqr_base = t * d + h * hd;
            for j in 0..=t {
                let dsc = prow[j] * (dp[j] - row_dot) * inv_sqrt;
                if dsc == 0.0 {
                    continue;
                }
                let kr = &k[j * d + h * hd..j * d + (h + 1) * hd];
                for u in 0..hd {
                    dq[dqr_base + u] += dsc * kr[u];
                }
                let dkr = &mut dk[j * d + h * hd..j * d + (h + 1) * hd];
                for u in 0..hd {
                    dkr[u] += dsc * qr[u];
                }
            }
        }
    }
    // Undo the rotation on the q/k gradients.
    rope_backward_rows(freqs, hh, hd, dq, t_len, d);
    rope_backward_rows(freqs, hh, hd, dk, t_len, d);
}

// --------------------------------------------------------------- swiglu

#[inline]
pub(crate) fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

#[inline]
pub(crate) fn silu(x: f32) -> f32 {
    x * sigmoid(x)
}

/// d silu(x)/dx = σ(x)·(1 + x·(1 − σ(x))).
#[inline]
pub(crate) fn silu_grad(x: f32) -> f32 {
    let s = sigmoid(x);
    s * (1.0 + x * (1.0 - s))
}

/// SwiGLU gating over `n` elements: act = silu(gate) ⊙ up.
pub fn swiglu_rows_into(gate: &[f32], up: &[f32], n: usize, act: &mut Vec<f32>) {
    ensure(act, n);
    for j in 0..n {
        act[j] = silu(gate[j]) * up[j];
    }
}

/// Backward of [`swiglu_rows_into`]: given d(act), the pre-activation
/// gate/up rows, writes d(gate) and d(up).
pub fn swiglu_backward_into(
    da: &[f32],
    gate: &[f32],
    up: &[f32],
    n: usize,
    dgate: &mut Vec<f32>,
    dup: &mut Vec<f32>,
) {
    ensure(dgate, n);
    ensure(dup, n);
    for j in 0..n {
        dgate[j] = da[j] * up[j] * silu_grad(gate[j]);
        dup[j] = da[j] * silu(gate[j]);
    }
}

// ---------------------------------------------------------- dense heads

/// Dense projection / LM head: y (b, out) = X · Wᵀ with W row-major
/// (out, in), accumulated row by row in a fixed order (deterministic,
/// batch-row independent).
pub fn dense_rows_into(w: &Tensor, x: &[f32], b: usize, y: &mut [f32]) {
    let mut scr = KernelScratch::default();
    dense_rows_core(w, x, b, y, simd::active(), &mut scr);
}

/// Columns of W interleaved per lane-tier j-block: small enough to stay
/// in L1 yet wide enough to amortize the interleave over the batch.
const DENSE_JB: usize = 256;

/// [`dense_rows_into`] with pooled scratch and an explicit SIMD tier —
/// the per-step LM-head entry (serve::engine, train::host). The lane
/// tier runs weight-row lanes: a block of up to `lanes` W rows is
/// interleaved j-block by j-block into a stack tile, and each batch
/// row's per-(output, lane) accumulator extends across the j-blocks in
/// ascending j with a single accumulator per output — exactly the scalar
/// loop's reduction order, so results are bitwise identical at every
/// tier. The interleave of one row block is reused across the whole
/// batch (the win over per-(bi, r) scalar dots).
pub(crate) fn dense_rows_core(
    w: &Tensor,
    x: &[f32],
    b: usize,
    y: &mut [f32],
    ops: &SimdOps,
    scr: &mut KernelScratch,
) {
    let (o, i) = w.dims2().expect("dense projection is 2-D");
    let wd = w.data();
    let lanes = ops.lanes;
    if lanes > 1 && b > 0 {
        let acc = &mut scr.acc;
        acc.clear();
        acc.resize(b * lanes, 0.0);
        let mut wtile = [0.0f32; simd::MAX_LANES * DENSE_JB];
        let mut r0 = 0usize;
        while r0 < o {
            let rl = lanes.min(o - r0);
            acc[..b * lanes].fill(0.0);
            let mut j0 = 0usize;
            while j0 < i {
                let jl = DENSE_JB.min(i - j0);
                for l in 0..rl {
                    let wr = &wd[(r0 + l) * i + j0..(r0 + l) * i + j0 + jl];
                    for (j, &wv) in wr.iter().enumerate() {
                        wtile[j * lanes + l] = wv;
                    }
                }
                let mseg = &wtile[..(jl - 1) * lanes + lanes];
                for bi in 0..b {
                    let xseg = &x[bi * i + j0..bi * i + j0 + jl];
                    ops.dot_lanes(&mut acc[bi * lanes..bi * lanes + rl], mseg, xseg, lanes);
                }
                j0 += jl;
            }
            for bi in 0..b {
                for l in 0..rl {
                    y[bi * o + r0 + l] = acc[bi * lanes + l];
                }
            }
            r0 += rl;
        }
    } else {
        // Scalar tier: the seed's loop, verbatim.
        for bi in 0..b {
            let xr = &x[bi * i..(bi + 1) * i];
            let yr = &mut y[bi * o..(bi + 1) * o];
            for (r, yv) in yr.iter_mut().enumerate() {
                let wr = &wd[r * i..(r + 1) * i];
                let mut acc = 0.0f32;
                for j in 0..i {
                    acc += xr[j] * wr[j];
                }
                *yv = acc;
            }
        }
    }
}

/// Gradient through a frozen dense projection: dX (b, in) = dY · W with
/// W row-major (out, in) — the LM-head backward. Rows of dX are
/// independent, so they are sharded over the kernel layer's shared
/// row-parallel helper; per row the accumulation walks the weight rows
/// in ascending order (skipping exact-zero dY entries, an exact
/// identity), so results are bit-identical at any `threads` value. The
/// per-row update `dx[j] += a·w[j]` is element-independent, so it routes
/// through the dispatched `axpy` — every tier performs the identical
/// single mul+add per element, keeping the result bitwise invariant to
/// the dispatch choice too.
pub fn dense_grad_rows_into(w: &Tensor, dy: &[f32], b: usize, threads: usize, dx: &mut [f32]) {
    let (o, i) = w.dims2().expect("dense projection is 2-D");
    assert_eq!(dy.len(), b * o, "dense_grad_rows_into: dy shape");
    assert_eq!(dx.len(), b * i, "dense_grad_rows_into: dx shape");
    let wd = w.data();
    let ops = simd::active();
    crate::quant::kernels::par_row_chunks(dx, i, b, threads, |b0, chunk| {
        for (ci, dxr) in chunk.chunks_mut(i).enumerate() {
            dxr.fill(0.0);
            let dyr = &dy[(b0 + ci) * o..(b0 + ci + 1) * o];
            for (r, &a) in dyr.iter().enumerate() {
                if a == 0.0 {
                    continue; // masked-out logits rows are all-zero
                }
                ops.axpy(dxr, a, &wd[r * i..(r + 1) * i]);
            }
        }
    });
}

// ------------------------------------------------------- blocked kernels

/// Fixed-order 4-accumulator dot product (deterministic; lets the
/// autovectorizer keep four independent FMA chains in flight).
#[inline]
pub(crate) fn dot_blocked(a: &[f32], b: &[f32]) -> f32 {
    let n4 = a.len() / 4 * 4;
    let mut acc = [0.0f32; 4];
    let mut i = 0;
    while i < n4 {
        acc[0] += a[i] * b[i];
        acc[1] += a[i + 1] * b[i + 1];
        acc[2] += a[i + 2] * b[i + 2];
        acc[3] += a[i + 3] * b[i + 3];
        i += 4;
    }
    let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]);
    for k in n4..a.len() {
        s += a[k] * b[k];
    }
    s
}

/// y += w · v, 4-way blocked, fixed order.
#[inline]
pub(crate) fn axpy_blocked(w: f32, v: &[f32], y: &mut [f32]) {
    let n4 = v.len() / 4 * 4;
    let mut i = 0;
    while i < n4 {
        y[i] += w * v[i];
        y[i + 1] += w * v[i + 1];
        y[i + 2] += w * v[i + 2];
        y[i + 3] += w * v[i + 3];
        i += 4;
    }
    for k in n4..v.len() {
        y[k] += w * v[k];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    #[test]
    fn blocked_dot_and_axpy_match_scalar() {
        let a: Vec<f32> = (0..23).map(|i| (i as f32) * 0.3 - 2.0).collect();
        let b: Vec<f32> = (0..23).map(|i| 1.5 - (i as f32) * 0.11).collect();
        let scalar: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot_blocked(&a, &b) - scalar).abs() < 1e-4);
        let mut y = vec![0.5f32; 23];
        let mut y_ref = y.clone();
        axpy_blocked(0.7, &a, &mut y);
        for (yr, av) in y_ref.iter_mut().zip(&a) {
            *yr += 0.7 * av;
        }
        for (u, v) in y.iter().zip(&y_ref) {
            assert!((u - v).abs() < 1e-6);
        }
    }

    #[test]
    fn rms_norm_captures_the_exact_forward_inverse() {
        let mut rng = Pcg32::new(3);
        let x = Tensor::normal(&[3, 8], 1.0, &mut rng);
        let g = Tensor::ones(&[8]);
        let mut out = Vec::new();
        let mut invs = Vec::new();
        rms_norm_rows_into(x.data(), g.data(), 3, 8, &mut out, Some(&mut invs));
        let plain = rms_norm_rows(x.data(), g.data(), 3, 8);
        assert_eq!(out[..24], plain[..]);
        for bi in 0..3 {
            let xr = &x.data()[bi * 8..(bi + 1) * 8];
            let ss: f32 = xr.iter().map(|v| v * v).sum();
            let inv = 1.0 / (ss / 8.0 + RMS_EPS).sqrt();
            assert_eq!(invs[bi], inv);
        }
    }

    #[test]
    fn full_sequence_attention_matches_windowed_kernel_bitwise() {
        // attend_seq_tape over t_len rows must produce, per position,
        // exactly attend_row_slabs over the growing prefix slab — and
        // Tape::Keep vs Tape::None must not change the context rows.
        let (hh, hd, t_len) = (2usize, 4usize, 5usize);
        let d = hh * hd;
        let mut rng = Pcg32::new(9);
        let q0 = Tensor::normal(&[t_len, d], 1.0, &mut rng);
        let k0 = Tensor::normal(&[t_len, d], 1.0, &mut rng);
        let v0 = Tensor::normal(&[t_len, d], 1.0, &mut rng);
        let freqs = rope_freqs(hd);

        let run = |keep: bool| -> (Vec<f32>, Vec<f32>) {
            let mut q = q0.data().to_vec();
            let mut k = k0.data().to_vec();
            let mut ctx = vec![0.0f32; t_len * d];
            let mut probs = vec![0.0f32; hh * t_len * t_len];
            let mut scr = AttnScratch::default();
            let tape = if keep { Tape::Keep(&mut probs) } else { Tape::None };
            attend_seq_tape(&freqs, hh, hd, t_len, &mut q, &mut k, v0.data(), &mut ctx, &mut scr, tape);
            (ctx, probs)
        };
        let (ctx_keep, probs) = run(true);
        let (ctx_none, _) = run(false);
        assert_eq!(ctx_keep, ctx_none, "tape mode must not change the forward");

        // Probabilities are a valid causal softmax: rows sum to 1.
        for h in 0..hh {
            for t in 0..t_len {
                let row = &probs[(h * t_len + t) * t_len..(h * t_len + t) * t_len + t + 1];
                let s: f32 = row.iter().sum();
                assert!((s - 1.0).abs() < 1e-5, "h={h} t={t}: Σp = {s}");
            }
        }

        // Per position, the shared windowed kernel over the prefix slab
        // reproduces the context row bitwise.
        let mut q = q0.data().to_vec();
        let mut k = k0.data().to_vec();
        for t in 0..t_len {
            rope_row_at(&freqs, hh, hd, &mut q[t * d..(t + 1) * d], t);
            rope_row_at(&freqs, hh, hd, &mut k[t * d..(t + 1) * d], t);
            let n = t + 1;
            // Split the prefix at an arbitrary row boundary: segmentation
            // must never change the result (the paged walk relies on it).
            let cut = (t / 2) * d;
            let kd = &k[..n * d];
            let vd = &v0.data()[..n * d];
            let slabs = [(&kd[..cut], &vd[..cut]), (&kd[cut..], &vd[cut..])];
            let mut ctx = vec![0.0f32; d];
            let mut scr = AttnScratch::default();
            attend_row_slabs(
                hh,
                hd,
                n,
                slabs.iter().copied(),
                &q[t * d..(t + 1) * d],
                &mut ctx,
                &mut scr,
            );
            assert_eq!(ctx[..], ctx_keep[t * d..(t + 1) * d], "t={t}");
        }
    }

    #[test]
    fn dense_head_simd_tier_is_bitwise_equal_to_scalar() {
        // LM-head shapes: out large vs small, in not a j-block multiple,
        // batch 0/1/odd — every (tier, shape) pair must agree bitwise.
        let mut scr_s = KernelScratch::default();
        let mut scr_v = KernelScratch::default();
        for (b, o, i) in [(1usize, 33usize, 48usize), (5, 8, 300), (3, 7, 6), (0, 9, 16), (4, 64, 257)] {
            let mut rng = Pcg32::new(7 + (b + o + i) as u64);
            let w = Tensor::normal(&[o, i], 0.5, &mut rng);
            let x = Tensor::normal(&[b.max(1), i], 1.0, &mut rng);
            let mut ys = vec![f32::NAN; b * o];
            let mut yv = vec![f32::NAN; b * o];
            dense_rows_core(&w, &x.data()[..b * i], b, &mut ys, simd::scalar(), &mut scr_s);
            dense_rows_core(&w, &x.data()[..b * i], b, &mut yv, simd::detected(), &mut scr_v);
            assert_eq!(ys, yv, "b={b} o={o} i={i}");
            // And the public wrapper (active dispatch) agrees with both.
            let mut yw = vec![f32::NAN; b * o];
            dense_rows_into(&w, &x.data()[..b * i], b, &mut yw);
            assert_eq!(yw, ys, "wrapper b={b} o={o} i={i}");
        }
    }

    #[test]
    fn dense_grad_is_thread_invariant_and_matches_f64() {
        let (b, o, i) = (5usize, 7usize, 6usize);
        let mut rng = Pcg32::new(17);
        let w = Tensor::normal(&[o, i], 0.5, &mut rng);
        let dy = Tensor::normal(&[b, o], 1.0, &mut rng);
        let mut dx1 = vec![f32::NAN; b * i];
        dense_grad_rows_into(&w, dy.data(), b, 1, &mut dx1);
        for threads in [2usize, 4, 16] {
            let mut dxn = vec![f32::NAN; b * i];
            dense_grad_rows_into(&w, dy.data(), b, threads, &mut dxn);
            assert_eq!(dx1, dxn, "threads={threads}");
        }
        for bi in 0..b {
            for j in 0..i {
                let mut acc = 0.0f64;
                for r in 0..o {
                    acc += dy.at2(bi, r) as f64 * w.at2(r, j) as f64;
                }
                assert!((dx1[bi * i + j] as f64 - acc).abs() <= 1e-4 * acc.abs().max(1.0));
            }
        }
    }

    #[test]
    fn swiglu_backward_matches_finite_differences() {
        let n = 9usize;
        let mut rng = Pcg32::new(23);
        let gate = Tensor::normal(&[n], 1.0, &mut rng);
        let up = Tensor::normal(&[n], 1.0, &mut rng);
        let da = Tensor::normal(&[n], 1.0, &mut rng);
        let mut dgate = Vec::new();
        let mut dup = Vec::new();
        swiglu_backward_into(da.data(), gate.data(), up.data(), n, &mut dgate, &mut dup);
        let loss = |g: &[f32], u: &[f32]| -> f64 {
            let mut act = Vec::new();
            swiglu_rows_into(g, u, n, &mut act);
            act.iter().zip(da.data()).map(|(&a, &w)| (a * w) as f64).sum()
        };
        let h = 1e-3f32;
        for j in 0..n {
            let mut gp = gate.data().to_vec();
            let mut gm = gate.data().to_vec();
            gp[j] += h;
            gm[j] -= h;
            let fd = (loss(&gp, up.data()) - loss(&gm, up.data())) / (2.0 * h as f64);
            assert!((dgate[j] as f64 - fd).abs() <= 1e-2 * fd.abs().max(1e-2), "gate[{j}]");
            let mut up_p = up.data().to_vec();
            let mut up_m = up.data().to_vec();
            up_p[j] += h;
            up_m[j] -= h;
            let fd = (loss(gate.data(), &up_p) - loss(gate.data(), &up_m)) / (2.0 * h as f64);
            assert!((dup[j] as f64 - fd).abs() <= 1e-2 * fd.abs().max(1e-2), "up[{j}]");
        }
    }
}
