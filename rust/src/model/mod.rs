//! Checkpoints: named parameter collections + the `.peqa` on-disk formats.
//!
//! Three related artifact kinds:
//! * `.peqa`  — full checkpoint: the JSON name/shape header plus raw
//!   little-endian f32 blobs, one per named tensor (any method layout).
//! * `.adapter` — a PEQA task adapter: only the scale (and optionally
//!   zero-point) vectors. Kilobytes; this is the paper's "fast task
//!   switching" object. Same layout as `.peqa`, different role.
//! * `.packed` — deployment format: integer codes bit-packed at b bits
//!   (quant::pack) + f32 scales/zeros; its file size is the "Model Size"
//!   column of Tables 4/6/7.
//!
//! All three are written inside the checksummed `PEQAS1` container
//! (`store::format`): per-section CRC32s plus a whole-file trailer, and
//! every write is atomic (temp file + fsync + rename), so a torn or
//! bit-flipped artifact is *detected at load* with the file, section and
//! expected-vs-actual checksum in the error. The pre-container formats
//! (`PEQA1` checkpoints/adapters, `PEQAP1` packed streams) still load
//! through the same entry points but are flagged **unverified** — see
//! [`Checkpoint::load_flagged`] / [`PackedModel::load_flagged`];
//! re-saving upgrades them.

pub mod blocks;

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::json::Value;
use crate::quant::{pack_codes, packed_size, PackedMatrix, QuantizedMatrix};
use crate::runtime::ParamMeta;
use crate::store::format::{is_container, Container, ContainerWriter};
use crate::tensor::Tensor;
use crate::util::Pcg32;

/// Ordered, named parameter collection.
#[derive(Clone, Debug, Default)]
pub struct Checkpoint {
    names: Vec<String>,
    tensors: Vec<Tensor>,
    // peqa-lint: allow(nondeterminism-sources) -- pure lookup index,
    // never iterated: every traversal (iter, save, names) walks the
    // insertion-ordered `names` Vec.
    index: HashMap<String, usize>,
}

impl Checkpoint {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, name: impl Into<String>, t: Tensor) {
        let name = name.into();
        if let Some(&i) = self.index.get(&name) {
            self.tensors[i] = t;
        } else {
            self.index.insert(name.clone(), self.tensors.len());
            self.names.push(name);
            self.tensors.push(t);
        }
    }

    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.index.get(name).map(|&i| &self.tensors[i])
    }

    pub fn req(&self, name: &str) -> Result<&Tensor> {
        self.get(name).ok_or_else(|| anyhow!("checkpoint missing tensor '{name}'"))
    }

    pub fn remove(&mut self, name: &str) -> Option<Tensor> {
        let i = self.index.remove(name)?;
        let t = self.tensors.remove(i);
        self.names.remove(i);
        for v in self.index.values_mut() {
            if *v > i {
                *v -= 1;
            }
        }
        Some(t)
    }

    pub fn names(&self) -> &[String] {
        &self.names
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&String, &Tensor)> {
        self.names.iter().zip(self.tensors.iter())
    }

    pub fn n_params(&self) -> usize {
        self.tensors.iter().map(|t| t.len()).sum()
    }

    /// Initialize from a param table's init specs (pretraining from
    /// scratch; also LoRA adapter init).
    pub fn init_from_meta(metas: &[&ParamMeta], seed: u64) -> Result<Checkpoint> {
        let mut rng = Pcg32::seeded(seed, 0x1417);
        let mut ck = Checkpoint::new();
        for p in metas {
            let t = init_tensor(p, &mut rng)?;
            ck.insert(p.name.clone(), t);
        }
        Ok(ck)
    }

    /// Assemble the flat tensor list for an artifact's param layout:
    /// tensors come from the checkpoint by name; missing *trainable*
    /// tensors (e.g. fresh LoRA adapters) fall back to their init spec.
    /// Missing frozen tensors are an error — they can never be legitimate.
    pub fn assemble(&self, layout: &[&ParamMeta], seed: u64) -> Result<Vec<Tensor>> {
        let mut rng = Pcg32::seeded(seed, 0xa55e);
        let mut out = Vec::with_capacity(layout.len());
        for p in layout {
            match self.get(&p.name) {
                Some(t) => {
                    if t.shape() != p.shape.as_slice() {
                        bail!(
                            "tensor '{}': checkpoint shape {:?} != artifact shape {:?}",
                            p.name, t.shape(), p.shape
                        );
                    }
                    out.push(t.clone());
                }
                None if p.trainable => out.push(init_tensor(p, &mut rng)?),
                None => bail!(
                    "checkpoint is missing frozen tensor '{}' required by the artifact \
                     (wrong layout? quantized vs fp?)",
                    p.name
                ),
            }
        }
        Ok(out)
    }

    /// Strict assembly: every tensor must be present (evaluation paths).
    pub fn assemble_strict(&self, layout: &[&ParamMeta]) -> Result<Vec<Tensor>> {
        let mut out = Vec::with_capacity(layout.len());
        for p in layout {
            let t = self.req(&p.name)?;
            if t.shape() != p.shape.as_slice() {
                bail!(
                    "tensor '{}': checkpoint shape {:?} != artifact shape {:?}",
                    p.name, t.shape(), p.shape
                );
            }
            out.push(t.clone());
        }
        Ok(out)
    }

    // -- .peqa binary format -------------------------------------------------

    /// Write a checksummed checkpoint container (`store::format`): the
    /// JSON name/shape header in the "meta" section, one `t:<name>`
    /// section of raw little-endian f32 per tensor, every byte covered
    /// by CRC32, written atomically (temp file + fsync + rename).
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut w = ContainerWriter::new("checkpoint");
        w.section("meta", self.header_json().into_bytes());
        for (n, t) in self.iter() {
            w.section(&format!("t:{n}"), tensor_le_bytes(t));
        }
        w.write_atomic(path)?;
        Ok(())
    }

    /// The JSON `[{name, shape}]` header (shared by the container and
    /// legacy writers' formats).
    fn header_json(&self) -> String {
        Value::Arr(
            self.iter()
                .map(|(n, t)| {
                    Value::obj(vec![
                        ("name", Value::str(n.clone())),
                        (
                            "shape",
                            Value::Arr(
                                t.shape().iter().map(|&d| Value::num(d as f64)).collect(),
                            ),
                        ),
                    ])
                })
                .collect(),
        )
        .to_string()
    }

    /// Load a checkpoint, warning when it is a legacy (unchecksummed)
    /// file — see [`Self::load_flagged`] to branch on that instead.
    pub fn load(path: &Path) -> Result<Checkpoint> {
        let (ck, verified) = Self::load_flagged(path)?;
        if !verified {
            crate::info!(
                "{}: legacy PEQA1 format carries no checksums — loaded UNVERIFIED \
                 (re-save to upgrade)",
                path.display()
            );
        }
        Ok(ck)
    }

    /// Load a checkpoint and report whether its bytes were checksum-
    /// verified: `true` for the `PEQAS1` container, `false` for legacy
    /// `PEQA1` files (still parsed, flagged unverified).
    pub fn load_flagged(path: &Path) -> Result<(Checkpoint, bool)> {
        let bytes =
            std::fs::read(path).with_context(|| format!("opening {}", path.display()))?;
        let label = path.display().to_string();
        if is_container(&bytes) {
            let c = Container::from_bytes(&bytes, &label)?;
            Ok((Self::from_container(&c, &label)?, true))
        } else if bytes.starts_with(b"PEQA1\n") {
            Ok((Self::load_legacy(&bytes, &label)?, false))
        } else {
            bail!("{label} is not a .peqa checkpoint (no PEQAS1/PEQA1 magic)")
        }
    }

    fn from_container(c: &Container, label: &str) -> Result<Checkpoint> {
        if c.kind != "checkpoint" {
            bail!("{label}: container kind '{}' is not 'checkpoint'", c.kind);
        }
        let header = parse_header_sections(c, label)?;
        let mut ck = Checkpoint::new();
        for (name, shape) in header {
            let numel: usize = shape.iter().product();
            let payload = c
                .section(&format!("t:{name}"))
                .with_context(|| label.to_string())?;
            if payload.len() != numel * 4 {
                bail!(
                    "{label}: tensor '{name}': section has {} byte(s), shape {shape:?} \
                     wants {}",
                    payload.len(),
                    numel * 4
                );
            }
            ck.insert(name, Tensor::new(&shape, le_bytes_to_f32(payload)));
        }
        Ok(ck)
    }

    /// Parse the legacy `PEQA1` stream with full error context: a short
    /// file names the tensor, its byte offset, and expected-vs-got.
    fn load_legacy(bytes: &[u8], label: &str) -> Result<Checkpoint> {
        let (header, mut off) = legacy_header(bytes, label, b"PEQA1\n")?;
        let mut ck = Checkpoint::new();
        for item in header.as_arr().ok_or_else(|| anyhow!("{label}: bad header"))? {
            let name = item.str_of("name")?;
            let shape: Vec<usize> = item
                .arr_of("shape")?
                .iter()
                .map(|x| x.as_usize().context("shape"))
                .collect::<Result<_>>()?;
            let numel: usize = shape.iter().product();
            let want = numel * 4;
            if off + want > bytes.len() {
                bail!(
                    "{label}: truncated reading tensor '{name}' at byte offset {off}: \
                     expected {want} byte(s) ({numel} f32), only {} available",
                    bytes.len() - off
                );
            }
            ck.insert(
                name.to_string(),
                Tensor::new(&shape, le_bytes_to_f32(&bytes[off..off + want])),
            );
            off += want;
        }
        Ok(ck)
    }

    // -- PEQA-layout helpers ---------------------------------------------------

    /// Dotted prefixes of quantized projections present in this checkpoint.
    pub fn quantized_prefixes(&self) -> Vec<String> {
        self.names
            .iter()
            .filter_map(|n| n.strip_suffix(".wq").map(String::from))
            .collect()
    }

    /// Quantized layout → fp layout: every PEQA (wq, s, z) triple becomes
    /// the dense Ŵ = s·(wq − z); every BCQ (alpha1, alpha_rest, code)
    /// triple becomes Σ_k α_k ⊙ B_k; other tensors pass through. This is
    /// what lets the shared fp eval/logits artifacts score any model.
    pub fn dequantize(&self) -> Result<Checkpoint> {
        let mut out = Checkpoint::new();
        for (name, t) in self.iter() {
            if [".wq", ".s", ".z", ".alpha1", ".alpha_rest", ".code"]
                .iter()
                .any(|suf| name.ends_with(suf))
            {
                continue;
            }
            out.insert(name.clone(), t.clone());
        }
        for prefix in self.quantized_prefixes() {
            let wq = self.req(&format!("{prefix}.wq"))?;
            let s = self.req(&format!("{prefix}.s"))?;
            let z = self.req(&format!("{prefix}.z"))?;
            out.insert(format!("{prefix}.w"), dequantize_tensor(wq, s, z)?);
        }
        for name in &self.names {
            if let Some(prefix) = name.strip_suffix(".code") {
                let a1 = self.req(&format!("{prefix}.alpha1"))?;
                let ar = self.req(&format!("{prefix}.alpha_rest"))?;
                let code = self.req(name)?;
                out.insert(format!("{prefix}.w"), bcq_dequant(a1, ar, code)?);
            }
        }
        Ok(out)
    }

    /// Extract the task adapter (trainable s / z vectors) from a
    /// PEQA-layout checkpoint.
    pub fn extract_adapter(&self, include_zeros: bool) -> Checkpoint {
        let mut out = Checkpoint::new();
        for (name, t) in self.iter() {
            if name.ends_with(".s") || (include_zeros && name.ends_with(".z")) {
                out.insert(name.clone(), t.clone());
            }
        }
        out
    }

    /// Overlay an adapter's tensors onto this checkpoint (task switch).
    pub fn apply_adapter(&mut self, adapter: &Checkpoint) -> Result<()> {
        for (name, t) in adapter.iter() {
            let Some(&i) = self.index.get(name) else {
                bail!("adapter tensor '{name}' not present in base model");
            };
            if self.tensors[i].shape() != t.shape() {
                bail!("adapter tensor '{name}' shape mismatch");
            }
            self.tensors[i] = t.clone();
        }
        Ok(())
    }

    /// Merge LoRA adapters into base weights: W += (α/r)·B·A.
    pub fn merge_lora(&self, alpha: f64, rank: usize) -> Result<Checkpoint> {
        let mut out = Checkpoint::new();
        for (name, t) in self.iter() {
            if name.ends_with(".lora_a") || name.ends_with(".lora_b") {
                continue;
            }
            out.insert(name.clone(), t.clone());
        }
        for name in &self.names {
            if let Some(prefix) = name.strip_suffix(".lora_a") {
                let a = self.req(name)?;
                let b = self.req(&format!("{prefix}.lora_b"))?;
                let w = self.req(&format!("{prefix}.w"))?;
                let mut merged = w.clone();
                let delta = b.matmul(a)?;
                merged.add_scaled(&delta, (alpha / rank as f64) as f32)?;
                out.insert(format!("{prefix}.w"), merged);
            }
        }
        Ok(out)
    }

    // -- packed deployment format ---------------------------------------------

    /// Write the deployment file as a checksummed container: quantized
    /// projections bit-packed at `bits`, fp tensors raw f32, one
    /// `t:<name>` section each, written atomically. Returns total file
    /// bytes (the "Model Size").
    pub fn save_packed(&self, path: &Path, bits: u8) -> Result<u64> {
        let mut entries = Vec::new();
        for (name, t) in self.iter() {
            let kind = if name.ends_with(".wq") { "packed" } else { "f32" };
            entries.push(Value::obj(vec![
                ("name", Value::str(name.clone())),
                (
                    "shape",
                    Value::Arr(t.shape().iter().map(|&d| Value::num(d as f64)).collect()),
                ),
                ("kind", Value::str(kind)),
            ]));
        }
        let header = Value::obj(vec![
            ("bits", Value::num(bits as f64)),
            ("tensors", Value::Arr(entries)),
        ])
        .to_string();
        let mut w = ContainerWriter::new("packed");
        w.section("meta", header.into_bytes());
        for (name, t) in self.iter() {
            if name.ends_with(".wq") {
                let codes: Vec<u8> = t.data().iter().map(|&x| x as u8).collect();
                let packed = pack_codes(&codes, bits);
                debug_assert_eq!(packed.len(), packed_size(codes.len(), bits));
                w.section(&format!("t:{name}"), packed);
            } else {
                w.section(&format!("t:{name}"), tensor_le_bytes(t));
            }
        }
        w.write_atomic(path)
    }

    /// Load a `.packed` deployment file back into a PEQA-layout checkpoint
    /// (codes expanded to one f32 per code). Serving paths that want the
    /// codes to *stay* packed should load a [`PackedModel`] instead.
    pub fn load_packed(path: &Path) -> Result<Checkpoint> {
        Ok(PackedModel::load(path)?.to_checkpoint())
    }
}

/// In-memory deployment model: the parsed `.packed` file with quantized
/// projections kept as bit-packed [`PackedMatrix`] entries (fused
/// dequant-GEMM ready; see quant::kernels) and fp tensors dense. This is
/// the serving-side load path — the integer codes are never expanded to
/// one-f32-per-code unless a checkpoint view is explicitly requested.
#[derive(Clone)]
pub struct PackedModel {
    pub bits: u8,
    /// Tensor names in original file order (wq/s/z names included).
    names: Vec<String>,
    /// Quantized projections by dotted prefix (name minus ".wq").
    // peqa-lint: allow(nondeterminism-sources) -- pure lookup table,
    // never iterated: ordered walks go through the file-ordered `names`
    // Vec (prefixes(), save paths).
    matrices: HashMap<String, PackedMatrix>,
    /// Every tensor that is not part of a (wq, s, z) triple.
    fp: Checkpoint,
}

impl PackedModel {
    /// Parse a `.packed` file (see [`Checkpoint::save_packed`] for the
    /// format): checksum-verified `PEQAS1` container or legacy
    /// `PEQAP1` stream (parsed unverified, with a warning) — JSON
    /// header, then per-tensor payloads: bit-packed code streams for
    /// `.wq` entries, raw little-endian f32 otherwise.
    pub fn load(path: &Path) -> Result<PackedModel> {
        let (pm, verified) = Self::load_flagged(path)?;
        if !verified {
            crate::info!(
                "{}: legacy PEQAP1 format carries no checksums — loaded UNVERIFIED \
                 (re-save to upgrade)",
                path.display()
            );
        }
        Ok(pm)
    }

    /// [`Self::load`] plus whether the bytes were checksum-verified
    /// (`false` for legacy `PEQAP1` files).
    pub fn load_flagged(path: &Path) -> Result<(PackedModel, bool)> {
        let bytes =
            std::fs::read(path).with_context(|| format!("opening {}", path.display()))?;
        let label = path.display().to_string();
        if is_container(&bytes) {
            let c = Container::from_bytes(&bytes, &label)?;
            if c.kind != "packed" {
                bail!("{label}: container kind '{}' is not 'packed'", c.kind);
            }
            let text = std::str::from_utf8(c.section("meta").with_context(|| label.clone())?)
                .with_context(|| format!("{label}: meta is not UTF-8"))?;
            let header = Value::parse(text).with_context(|| format!("{label}: meta JSON"))?;
            let take = |name: &str, want: usize| -> Result<Vec<u8>> {
                let payload =
                    c.section(&format!("t:{name}")).with_context(|| label.clone())?;
                if payload.len() != want {
                    bail!(
                        "{label}: tensor '{name}': section has {} byte(s), expected {want}",
                        payload.len()
                    );
                }
                Ok(payload.to_vec())
            };
            let pm = Self::assemble_from_header(&header, take)?;
            return Ok((pm, true));
        }
        if !bytes.starts_with(b"PEQAP1\n") {
            bail!("{label} is not a packed model (no PEQAS1/PEQAP1 magic)");
        }
        let (header, start) = legacy_header(&bytes, &label, b"PEQAP1\n")?;
        // The legacy stream is positional: payloads follow the header in
        // tensor order. `assemble_from_header` requests each tensor
        // exactly once, in header order, so one walking offset suffices.
        let mut off = start;
        let take = |name: &str, want: usize| -> Result<Vec<u8>> {
            if off + want > bytes.len() {
                bail!(
                    "{label}: truncated reading tensor '{name}' at byte offset {off}: \
                     expected {want} byte(s), only {} available",
                    bytes.len() - off
                );
            }
            let buf = bytes[off..off + want].to_vec();
            off += want;
            Ok(buf)
        };
        let pm = Self::assemble_from_header(&header, take)?;
        Ok((pm, false))
    }

    /// Shared header-driven assembly for both on-disk formats: `take`
    /// yields each tensor's exact payload bytes (container section or
    /// legacy stream slice).
    fn assemble_from_header(
        header: &Value,
        mut take: impl FnMut(&str, usize) -> Result<Vec<u8>>,
    ) -> Result<PackedModel> {
        let bits = header.usize_of("bits")? as u8;
        let mut names = Vec::new();
        let mut streams: Vec<(String, Vec<usize>, Vec<u8>)> = Vec::new();
        // peqa-lint: allow(nondeterminism-sources) -- assembly scratch:
        // only `remove(key)` lookups; the build walks the file-ordered
        // `streams`/`names` Vecs, never this map's iteration order.
        let mut dense: HashMap<String, Tensor> = HashMap::new();
        for item in header.arr_of("tensors")? {
            let name = item.str_of("name")?.to_string();
            let shape: Vec<usize> = item
                .arr_of("shape")?
                .iter()
                .map(|x| x.as_usize().context("shape"))
                .collect::<Result<_>>()?;
            let numel: usize = shape.iter().product();
            if item.str_of("kind")? == "packed" {
                let buf = take(&name, packed_size(numel, bits))?;
                streams.push((name.clone(), shape, buf));
            } else {
                let data = le_bytes_to_f32(&take(&name, numel * 4)?);
                dense.insert(name.clone(), Tensor::new(&shape, data));
            }
            names.push(name);
        }
        // Assemble (wq, s, z) triples into packed matrices; whatever is
        // left over is a plain fp tensor.
        // peqa-lint: allow(nondeterminism-sources) -- becomes the
        // lookup-only `PackedModel::matrices` table; insertion here walks
        // the file-ordered `streams` Vec and nothing iterates the map.
        let mut matrices = HashMap::new();
        for (name, shape, stream) in streams {
            let prefix = name
                .strip_suffix(".wq")
                .ok_or_else(|| anyhow!("packed tensor '{name}' is not a .wq projection"))?;
            let &[rows, cols] = shape.as_slice() else {
                bail!("packed tensor '{name}' is not 2-D: {shape:?}");
            };
            let s = dense
                .remove(&format!("{prefix}.s"))
                .ok_or_else(|| anyhow!("packed model missing '{prefix}.s'"))?;
            let z = dense
                .remove(&format!("{prefix}.z"))
                .ok_or_else(|| anyhow!("packed model missing '{prefix}.z'"))?;
            let m = PackedMatrix::from_contiguous(&stream, rows, cols, bits, s, z)?;
            matrices.insert(prefix.to_string(), m);
        }
        let mut fp = Checkpoint::new();
        for name in &names {
            if let Some(t) = dense.remove(name) {
                fp.insert(name.clone(), t);
            }
        }
        Ok(PackedModel { bits, names, matrices, fp })
    }

    /// Build the in-memory deployment model directly from a PEQA-layout
    /// checkpoint (codes stored one-f32-per-code) without touching disk —
    /// the in-process analog of [`Checkpoint::save_packed`] + [`Self::load`],
    /// used by the host serving path to stand up an engine from a
    /// just-quantized model.
    pub fn from_checkpoint(ck: &Checkpoint, bits: u8) -> Result<PackedModel> {
        if !(1..=8).contains(&bits) {
            bail!("packed model: bits must be in 1..=8, got {bits}");
        }
        let qmax = (1u16 << bits) - 1;
        let names: Vec<String> = ck.names().to_vec();
        // peqa-lint: allow(nondeterminism-sources) -- lookup-only table
        // (see PackedModel::matrices); built in checkpoint order.
        let mut matrices = HashMap::new();
        let mut fp = Checkpoint::new();
        for (name, t) in ck.iter() {
            if let Some(prefix) = name.strip_suffix(".wq") {
                let (rows, cols) = t.dims2()?;
                let s = ck.req(&format!("{prefix}.s"))?.clone();
                let z = ck.req(&format!("{prefix}.z"))?.clone();
                let (sn, ng) = s.dims2()?;
                if sn != rows || ng == 0 || cols % ng != 0 {
                    bail!("'{name}': scales {:?} do not tile {rows}x{cols}", s.shape());
                }
                if z.shape() != s.shape() {
                    bail!("'{name}': zeros {:?} != scales {:?}", z.shape(), s.shape());
                }
                // A code that does not fit `bits` means the checkpoint was
                // quantized at a wider width — packing would silently mask
                // the high bits and serve garbage weights.
                if let Some(&bad) = t.data().iter().find(|&&x| x < 0.0 || x > qmax as f32) {
                    bail!(
                        "'{name}': code {bad} does not fit {bits} bits \
                         (checkpoint quantized at a different width?)"
                    );
                }
                let codes: Vec<u8> = t.data().iter().map(|&x| x as u8).collect();
                let q = QuantizedMatrix {
                    codes,
                    scales: s,
                    zeros: z,
                    rows,
                    cols,
                    bits,
                    group: cols / ng,
                };
                matrices.insert(prefix.to_string(), PackedMatrix::from_quantized(&q));
            } else if name.ends_with(".s") || name.ends_with(".z") {
                // s/z of a (wq, s, z) triple live inside the matrix; an
                // orphaned s/z stays a plain fp tensor.
                let p = &name[..name.len() - 2];
                if ck.get(&format!("{p}.wq")).is_none() {
                    fp.insert(name.clone(), t.clone());
                }
            } else {
                fp.insert(name.clone(), t.clone());
            }
        }
        Ok(PackedModel { bits, names, matrices, fp })
    }

    /// Dotted prefixes of the packed projections, in file order.
    pub fn prefixes(&self) -> Vec<String> {
        self.names
            .iter()
            .filter_map(|n| n.strip_suffix(".wq").map(String::from))
            .collect()
    }

    pub fn matrix(&self, prefix: &str) -> Option<&PackedMatrix> {
        self.matrices.get(prefix)
    }

    /// Mutable access to one packed projection — the host scale-swap
    /// path: callers replace only the f32 `scales`/`zeros` tensors; the
    /// packed code bytes are not reachable for mutation.
    pub fn matrix_mut(&mut self, prefix: &str) -> Option<&mut PackedMatrix> {
        self.matrices.get_mut(prefix)
    }

    /// A non-projection fp tensor by name (embeddings, norms, LM head).
    pub fn fp_tensor(&self, name: &str) -> Option<&Tensor> {
        self.fp.get(name)
    }

    /// All tensor names in original file order (wq/s/z names included).
    pub fn tensor_names(&self) -> &[String] {
        &self.names
    }

    /// Fused y = X·Ŵᵀ straight from the packed codes of one projection.
    pub fn fused_matmul(&self, prefix: &str, x: &Tensor) -> Result<Tensor> {
        self.matrices
            .get(prefix)
            .ok_or_else(|| anyhow!("no packed projection '{prefix}'"))?
            .matmul_t(x)
    }

    /// Bytes of packed code storage across all projections.
    pub fn packed_bytes(&self) -> usize {
        self.matrices.values().map(|m| m.packed_bytes()).sum()
    }

    /// This model's task adapter in the exact `serve::AdapterStore`
    /// format: the current `{prefix}.s` (and, when `include_zeros`, the
    /// `{prefix}.z`) tensor of every packed projection, in file order.
    /// After host PEQA tuning this is the trained adapter — registering
    /// it with an engine built from the *base* model reproduces the
    /// tuned model by scale swap alone.
    pub fn extract_adapter(&self, include_zeros: bool) -> Checkpoint {
        let mut out = Checkpoint::new();
        for name in &self.names {
            if let Some(p) = name.strip_suffix(".wq") {
                let m = &self.matrices[p];
                out.insert(format!("{p}.s"), m.scales.clone());
                if include_zeros {
                    out.insert(format!("{p}.z"), m.zeros.clone());
                }
            }
        }
        out
    }

    /// Expand to a PEQA-layout [`Checkpoint`] (codes as one f32 each) in
    /// the original tensor order — the tooling/compat view.
    pub fn to_checkpoint(&self) -> Checkpoint {
        let mut ck = Checkpoint::new();
        for name in &self.names {
            if let Some(prefix) = name.strip_suffix(".wq") {
                let m = &self.matrices[prefix];
                let q = m.to_quantized().expect("packed rows validated at load");
                ck.insert(
                    name.clone(),
                    Tensor::new(&[m.rows, m.cols], q.codes.iter().map(|&c| c as f32).collect()),
                );
            } else if let Some(m) = name.strip_suffix(".s").and_then(|p| self.matrices.get(p)) {
                ck.insert(name.clone(), m.scales.clone());
            } else if let Some(m) = name.strip_suffix(".z").and_then(|p| self.matrices.get(p)) {
                ck.insert(name.clone(), m.zeros.clone());
            } else if let Some(t) = self.fp.get(name) {
                ck.insert(name.clone(), t.clone());
            }
        }
        ck
    }

    /// Fp-layout checkpoint: every packed projection dequantized with the
    /// fused kernel directly from the packed codes (`{p}.w` = s·(codes−z)),
    /// everything else handled exactly like [`Checkpoint::dequantize`]
    /// (BCQ triples expanded, adapter bookkeeping dropped) — without ever
    /// materializing the integer codes as f32.
    pub fn dequantize(&self) -> Result<Checkpoint> {
        // self.fp holds no (wq, s, z) triples — those live in `matrices` —
        // so its dequantize() is passthrough + BCQ expansion.
        let mut out = self.fp.dequantize()?;
        for name in &self.names {
            if let Some(prefix) = name.strip_suffix(".wq") {
                out.insert(format!("{prefix}.w"), self.matrices[prefix].dequantize());
            }
        }
        Ok(out)
    }
}

/// A tensor's data as raw little-endian f32 bytes.
fn tensor_le_bytes(t: &Tensor) -> Vec<u8> {
    let mut out = Vec::with_capacity(t.len() * 4);
    for x in t.data() {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

/// Raw little-endian f32 bytes back into values (`bytes.len()` must be
/// a multiple of 4 — callers validate lengths first).
fn le_bytes_to_f32(bytes: &[u8]) -> Vec<f32> {
    bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

/// Parse a legacy `magic + u64 header-len + JSON` prelude with
/// truncation errors carrying offsets. Returns the header value and
/// the offset of the first payload byte.
fn legacy_header(bytes: &[u8], label: &str, magic: &[u8]) -> Result<(Value, usize)> {
    let mut off = magic.len();
    debug_assert!(bytes.starts_with(magic));
    if bytes.len() < off + 8 {
        bail!(
            "{label}: truncated header: need 8-byte header length at offset {off}, \
             file has {} byte(s)",
            bytes.len()
        );
    }
    let hlen = u64::from_le_bytes(bytes[off..off + 8].try_into().unwrap()) as usize;
    off += 8;
    if off + hlen > bytes.len() {
        bail!(
            "{label}: truncated header: JSON header claims {hlen} byte(s) at offset \
             {off}, only {} available",
            bytes.len() - off
        );
    }
    let text = std::str::from_utf8(&bytes[off..off + hlen])
        .with_context(|| format!("{label}: header is not UTF-8"))?;
    let header = Value::parse(text).with_context(|| format!("{label}: header JSON"))?;
    Ok((header, off + hlen))
}

/// The checkpoint container's "meta" section as `(name, shape)` pairs.
fn parse_header_sections(c: &Container, label: &str) -> Result<Vec<(String, Vec<usize>)>> {
    let text = std::str::from_utf8(c.section("meta").with_context(|| label.to_string())?)
        .with_context(|| format!("{label}: meta is not UTF-8"))?;
    let header = Value::parse(text).with_context(|| format!("{label}: meta JSON"))?;
    let mut out = Vec::new();
    for item in header.as_arr().ok_or_else(|| anyhow!("{label}: bad header"))? {
        let name = item.str_of("name")?.to_string();
        let shape: Vec<usize> = item
            .arr_of("shape")?
            .iter()
            .map(|x| x.as_usize().context("shape"))
            .collect::<Result<_>>()?;
        out.push((name, shape));
    }
    Ok(out)
}

fn init_tensor(p: &ParamMeta, rng: &mut Pcg32) -> Result<Tensor> {
    if let Some(std) = p.init.strip_prefix("normal:") {
        let std: f32 = std.parse().context("init std")?;
        Ok(Tensor::normal(&p.shape, std, rng))
    } else {
        match p.init.as_str() {
            "zeros" => Ok(Tensor::zeros(&p.shape)),
            "ones" => Ok(Tensor::ones(&p.shape)),
            other => bail!("unknown init spec '{other}'"),
        }
    }
}

/// BCQ dequant: Ŵ = Σ_k α_k ⊙ B_k with α split (alpha1 (n,1) trainable,
/// alpha_rest (n, b−1) frozen) and codes (n, m, b) in {−1, +1}.
pub fn bcq_dequant(alpha1: &Tensor, alpha_rest: &Tensor, code: &Tensor) -> Result<Tensor> {
    let (n, _one) = alpha1.dims2()?;
    let (n2, brest) = alpha_rest.dims2()?;
    let b = brest + 1;
    let shape = code.shape();
    if shape.len() != 3 || shape[0] != n || n2 != n || shape[2] != b {
        bail!("bcq shape mismatch: alpha {n}x{b}, code {shape:?}");
    }
    let m = shape[1];
    let mut out = vec![0.0f32; n * m];
    for i in 0..n {
        let mut alphas = Vec::with_capacity(b);
        alphas.push(alpha1.data()[i]);
        alphas.extend_from_slice(&alpha_rest.data()[i * brest..(i + 1) * brest]);
        for j in 0..m {
            let base = (i * m + j) * b;
            let mut acc = 0.0;
            for (k, &a) in alphas.iter().enumerate() {
                acc += a * code.data()[base + k];
            }
            out[i * m + j] = acc;
        }
    }
    Ok(Tensor::new(&[n, m], out))
}

/// Ŵ = s · (wq − z) with (n, G) params broadcast over groups, via the
/// fused row-parallel kernel (quant::kernels).
pub fn dequantize_tensor(wq: &Tensor, s: &Tensor, z: &Tensor) -> Result<Tensor> {
    let (n, m) = wq.dims2()?;
    let (n2, ng) = s.dims2()?;
    if n2 != n || ng == 0 || m % ng != 0 {
        bail!("dequantize shape mismatch: wq {:?}, s {:?}", wq.shape(), s.shape());
    }
    if z.shape() != s.shape() {
        bail!("dequantize shape mismatch: s {:?}, z {:?}", s.shape(), z.shape());
    }
    let g = m / ng;
    let out = crate::quant::kernels::dequantize_f32_codes(wq.data(), s.data(), z.data(), n, m, g);
    Ok(Tensor::new(&[n, m], out))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta(name: &str, shape: &[usize], init: &str) -> ParamMeta {
        ParamMeta {
            name: name.to_string(),
            shape: shape.to_vec(),
            trainable: true,
            init: init.to_string(),
        }
    }

    #[test]
    fn init_respects_specs() {
        let metas = [
            meta("w", &[8, 8], "normal:0.02"),
            meta("g", &[8], "ones"),
            meta("b", &[8], "zeros"),
        ];
        let refs: Vec<&ParamMeta> = metas.iter().collect();
        let ck = Checkpoint::init_from_meta(&refs, 1).unwrap();
        assert!(ck.req("w").unwrap().data().iter().any(|&x| x != 0.0));
        assert!(ck.req("w").unwrap().data().iter().all(|&x| x.abs() < 0.2));
        assert!(ck.req("g").unwrap().data().iter().all(|&x| x == 1.0));
        assert!(ck.req("b").unwrap().data().iter().all(|&x| x == 0.0));
        // determinism
        let ck2 = Checkpoint::init_from_meta(&refs, 1).unwrap();
        assert_eq!(ck.req("w").unwrap(), ck2.req("w").unwrap());
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join("peqa_test_ckpt");
        let path = dir.join("a.peqa");
        let mut ck = Checkpoint::new();
        let mut rng = Pcg32::new(3);
        ck.insert("x.w", Tensor::normal(&[4, 6], 1.0, &mut rng));
        ck.insert("y.g", Tensor::ones(&[6]));
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.names(), ck.names());
        assert_eq!(back.req("x.w").unwrap(), ck.req("x.w").unwrap());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn dequantize_matches_quant_module() {
        let mut rng = Pcg32::new(5);
        let w = Tensor::normal(&[8, 16], 0.3, &mut rng);
        let q = crate::quant::quantize_rtn(&w, 4, Some(8)).unwrap();
        let wq = Tensor::new(&[8, 16], q.codes.iter().map(|&c| c as f32).collect());
        let dq = dequantize_tensor(&wq, &q.scales, &q.zeros).unwrap();
        assert!(dq.max_abs_diff(&q.dequantize()) < 1e-6);
    }

    #[test]
    fn adapter_roundtrip_and_apply() {
        let mut base = Checkpoint::new();
        base.insert("l.wq", Tensor::full(&[2, 4], 3.0));
        base.insert("l.s", Tensor::full(&[2, 1], 0.5));
        base.insert("l.z", Tensor::zeros(&[2, 1]));
        let mut tuned = base.clone();
        tuned.insert("l.s", Tensor::full(&[2, 1], 0.7));
        let adapter = tuned.extract_adapter(false);
        assert_eq!(adapter.len(), 1);
        base.apply_adapter(&adapter).unwrap();
        assert_eq!(base.req("l.s").unwrap().data()[0], 0.7);
        // unknown tensor rejected
        let mut bogus = Checkpoint::new();
        bogus.insert("nope.s", Tensor::zeros(&[1, 1]));
        assert!(base.apply_adapter(&bogus).is_err());
    }

    #[test]
    fn packed_roundtrip_and_size() {
        let dir = std::env::temp_dir().join("peqa_test_packed");
        let path = dir.join("m.packed");
        let mut ck = Checkpoint::new();
        let mut rng = Pcg32::new(7);
        let w = Tensor::normal(&[16, 32], 0.4, &mut rng);
        let q = crate::quant::quantize_rtn(&w, 3, None).unwrap();
        ck.insert("l.wq", Tensor::new(&[16, 32], q.codes.iter().map(|&c| c as f32).collect()));
        ck.insert("l.s", q.scales.clone());
        ck.insert("l.z", q.zeros.clone());
        let bytes = ck.save_packed(&path, 3).unwrap();
        assert_eq!(bytes, std::fs::metadata(&path).unwrap().len());
        // 16·32 3-bit codes = 192 bytes — far less than 2048 f32 bytes.
        let back = Checkpoint::load_packed(&path).unwrap();
        assert_eq!(back.req("l.wq").unwrap(), ck.req("l.wq").unwrap());
        assert_eq!(back.req("l.s").unwrap(), ck.req("l.s").unwrap());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn packed_model_keeps_codes_packed_and_matches_checkpoint_view() {
        let dir = std::env::temp_dir().join("peqa_test_packed_model");
        let path = dir.join("m.packed");
        let mut ck = Checkpoint::new();
        let mut rng = Pcg32::new(13);
        // cols=20 at 3 bits → rows are not byte-aligned in the contiguous
        // file stream, exercising the re-pack branch of the loader.
        let w = Tensor::normal(&[16, 20], 0.4, &mut rng);
        let q = crate::quant::quantize_rtn(&w, 3, None).unwrap();
        ck.insert("l.wq", Tensor::new(&[16, 20], q.codes.iter().map(|&c| c as f32).collect()));
        ck.insert("l.s", q.scales.clone());
        ck.insert("l.z", q.zeros.clone());
        ck.insert("head", Tensor::normal(&[4, 4], 1.0, &mut rng));
        ck.save_packed(&path, 3).unwrap();

        let pm = PackedModel::load(&path).unwrap();
        assert_eq!(pm.prefixes(), vec!["l".to_string()]);
        // Packed storage is rows × ⌈20·3/8⌉ bytes — never 4 bytes/code.
        assert_eq!(pm.packed_bytes(), 16 * 8);

        // Checkpoint view equals the compat loader.
        let via_ck = Checkpoint::load_packed(&path).unwrap();
        let via_pm = pm.to_checkpoint();
        assert_eq!(via_ck.names(), via_pm.names());
        for (name, t) in via_ck.iter() {
            assert_eq!(t, via_pm.req(name).unwrap(), "{name}");
        }

        // Fused dequantize from packed codes == dequantize of the view.
        let fp = pm.dequantize().unwrap();
        let fp_ref = via_ck.dequantize().unwrap();
        assert_eq!(fp.req("l.w").unwrap(), fp_ref.req("l.w").unwrap());
        assert_eq!(fp.req("head").unwrap(), ck.req("head").unwrap());

        // Fused GEMM straight off the packed codes matches dense matmul.
        let x = Tensor::normal(&[4, 20], 1.0, &mut rng);
        let y = pm.fused_matmul("l", &x).unwrap();
        let y_ref = x.matmul(&fp_ref.req("l.w").unwrap().t()).unwrap();
        assert!(y.max_abs_diff(&y_ref) <= 1e-4);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn packed_model_from_checkpoint_matches_file_roundtrip() {
        let dir = std::env::temp_dir().join("peqa_test_packed_mem");
        let path = dir.join("m.packed");
        let mut ck = Checkpoint::new();
        let mut rng = Pcg32::new(21);
        let w = Tensor::normal(&[12, 24], 0.4, &mut rng);
        let q = crate::quant::quantize_rtn(&w, 3, Some(8)).unwrap();
        ck.insert("layers.0.attn.q.wq", Tensor::new(&[12, 24], q.codes.iter().map(|&c| c as f32).collect()));
        ck.insert("layers.0.attn.q.s", q.scales.clone());
        ck.insert("layers.0.attn.q.z", q.zeros.clone());
        ck.insert("embed", Tensor::normal(&[6, 4], 1.0, &mut rng));
        ck.save_packed(&path, 3).unwrap();

        let via_file = PackedModel::load(&path).unwrap();
        let via_mem = PackedModel::from_checkpoint(&ck, 3).unwrap();
        assert_eq!(via_mem.tensor_names(), via_file.tensor_names());
        assert_eq!(via_mem.packed_bytes(), via_file.packed_bytes());
        let a = via_mem.to_checkpoint();
        let b = via_file.to_checkpoint();
        for (name, t) in a.iter() {
            assert_eq!(t, b.req(name).unwrap(), "{name}");
        }
        assert_eq!(via_mem.fp_tensor("embed").unwrap(), ck.req("embed").unwrap());

        // matrix_mut swaps scales without touching the codes.
        let mut pm = via_mem;
        let before_bytes = pm.packed_bytes();
        let m = pm.matrix_mut("layers.0.attn.q").unwrap();
        let mut s2 = m.scales.clone();
        for v in s2.data_mut() {
            *v *= 2.0;
        }
        m.scales = s2;
        assert_eq!(pm.packed_bytes(), before_bytes);
        assert_eq!(
            pm.to_checkpoint().req("layers.0.attn.q.wq").unwrap(),
            ck.req("layers.0.attn.q.wq").unwrap()
        );
        // Missing s/z triple member is rejected.
        let mut bad = Checkpoint::new();
        bad.insert("p.wq", Tensor::zeros(&[2, 8]));
        assert!(PackedModel::from_checkpoint(&bad, 4).is_err());
        // Codes that do not fit the requested width are rejected, not
        // silently masked (ck holds 3-bit codes; 2 bits can't hold 4..7).
        assert!(PackedModel::from_checkpoint(&ck, 2).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn packed_model_extract_adapter_is_store_format() {
        let mut ck = Checkpoint::new();
        let mut rng = Pcg32::new(33);
        let w = Tensor::normal(&[8, 16], 0.4, &mut rng);
        let q = crate::quant::quantize_rtn(&w, 4, Some(8)).unwrap();
        ck.insert("layers.0.attn.q.wq", Tensor::new(&[8, 16], q.codes.iter().map(|&c| c as f32).collect()));
        ck.insert("layers.0.attn.q.s", q.scales.clone());
        ck.insert("layers.0.attn.q.z", q.zeros.clone());
        ck.insert("embed", Tensor::normal(&[4, 4], 1.0, &mut rng));
        let pm = PackedModel::from_checkpoint(&ck, 4).unwrap();
        let a = pm.extract_adapter(false);
        assert_eq!(a.names(), &["layers.0.attn.q.s".to_string()]);
        assert_eq!(a.req("layers.0.attn.q.s").unwrap(), &q.scales);
        let az = pm.extract_adapter(true);
        assert_eq!(az.len(), 2);
        assert_eq!(az.req("layers.0.attn.q.z").unwrap(), &q.zeros);
        // Same shape contract as Checkpoint::extract_adapter (the xla
        // path's adapter source) — the two serving paths share one format.
        let via_ck = pm.to_checkpoint().extract_adapter(true);
        for (name, t) in az.iter() {
            assert_eq!(t, via_ck.req(name).unwrap(), "{name}");
        }
    }

    /// Hand-built legacy `PEQA1` stream: magic + u64 header-len + JSON
    /// header + raw little-endian f32 payloads in header order.
    fn legacy_peqa1_bytes(header: &str, payload: &[f32]) -> Vec<u8> {
        let mut bytes = b"PEQA1\n".to_vec();
        bytes.extend_from_slice(&(header.len() as u64).to_le_bytes());
        bytes.extend_from_slice(header.as_bytes());
        for x in payload {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        bytes
    }

    #[test]
    fn legacy_peqa1_loads_unverified_with_contextual_truncation_errors() {
        let dir = std::env::temp_dir().join("peqa_test_legacy");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("old.peqa");
        let header = r#"[{"name":"a.w","shape":[2,3]},{"name":"b.g","shape":[4]}]"#;
        let data: Vec<f32> = (0..10).map(|i| i as f32 * 0.5).collect();
        std::fs::write(&path, legacy_peqa1_bytes(header, &data)).unwrap();

        let (ck, verified) = Checkpoint::load_flagged(&path).unwrap();
        assert!(!verified, "legacy files must be flagged unverified");
        assert_eq!(ck.req("a.w").unwrap(), &Tensor::new(&[2, 3], data[..6].to_vec()));
        assert_eq!(ck.req("b.g").unwrap(), &Tensor::new(&[4], data[6..].to_vec()));

        // Re-saving upgrades to the checksummed container.
        let upgraded = dir.join("new.peqa");
        ck.save(&upgraded).unwrap();
        let bytes = std::fs::read(&upgraded).unwrap();
        assert!(is_container(&bytes));
        let (back, verified) = Checkpoint::load_flagged(&upgraded).unwrap();
        assert!(verified);
        assert_eq!(back.req("a.w").unwrap(), ck.req("a.w").unwrap());

        // A short legacy file names the tensor and byte offset.
        let full = legacy_peqa1_bytes(header, &data);
        std::fs::write(&path, &full[..full.len() - 8]).unwrap();
        let err = Checkpoint::load_flagged(&path).unwrap_err().to_string();
        assert!(err.contains("'b.g'"), "{err}");
        assert!(err.contains("byte offset"), "{err}");
        assert!(err.contains("expected 16 byte"), "{err}");

        // Garbage magic is neither format.
        std::fs::write(&path, b"NOPE").unwrap();
        let err = Checkpoint::load_flagged(&path).unwrap_err().to_string();
        assert!(err.contains("no PEQAS1/PEQA1 magic"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn legacy_peqap1_packed_loads_unverified() {
        let dir = std::env::temp_dir().join("peqa_test_legacy_packed");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("old.packed");
        // Build the reference model, then re-serialize it as a legacy
        // positional PEQAP1 stream.
        let mut ck = Checkpoint::new();
        let mut rng = Pcg32::new(11);
        let w = Tensor::normal(&[8, 16], 0.4, &mut rng);
        let q = crate::quant::quantize_rtn(&w, 3, None).unwrap();
        ck.insert("l.wq", Tensor::new(&[8, 16], q.codes.iter().map(|&c| c as f32).collect()));
        ck.insert("l.s", q.scales.clone());
        ck.insert("l.z", q.zeros.clone());
        let header = format!(
            r#"{{"bits":3,"tensors":[{{"name":"l.wq","shape":[8,16],"kind":"packed"}},{{"name":"l.s","shape":{s},"kind":"f32"}},{{"name":"l.z","shape":{s},"kind":"f32"}}]}}"#,
            s = format!("[{},{}]", q.scales.shape()[0], q.scales.shape()[1]),
        );
        let mut bytes = b"PEQAP1\n".to_vec();
        bytes.extend_from_slice(&(header.len() as u64).to_le_bytes());
        bytes.extend_from_slice(header.as_bytes());
        bytes.extend_from_slice(&pack_codes(&q.codes, 3));
        for t in [&q.scales, &q.zeros] {
            bytes.extend_from_slice(&tensor_le_bytes(t));
        }
        std::fs::write(&path, &bytes).unwrap();

        let (pm, verified) = PackedModel::load_flagged(&path).unwrap();
        assert!(!verified, "legacy packed files must be flagged unverified");
        let view = pm.to_checkpoint();
        for (name, t) in ck.iter() {
            assert_eq!(t, view.req(name).unwrap(), "{name}");
        }

        // Truncated legacy stream names the tensor it fell short in.
        std::fs::write(&path, &bytes[..bytes.len() - 8]).unwrap();
        let err = PackedModel::load_flagged(&path).unwrap_err().to_string();
        assert!(err.contains("'l.z'"), "{err}");
        assert!(err.contains("byte offset"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn merge_lora_identity_when_b_zero() {
        let mut ck = Checkpoint::new();
        let mut rng = Pcg32::new(9);
        let w = Tensor::normal(&[4, 4], 0.1, &mut rng);
        ck.insert("p.w", w.clone());
        ck.insert("p.lora_a", Tensor::normal(&[2, 4], 0.1, &mut rng));
        ck.insert("p.lora_b", Tensor::zeros(&[4, 2]));
        let merged = ck.merge_lora(8.0, 2).unwrap();
        assert!(merged.req("p.w").unwrap().max_abs_diff(&w) < 1e-7);
        assert!(merged.get("p.lora_a").is_none());
    }
}
