//! The checksummed artifact container (`PEQAS1`) and the atomic-write
//! discipline every on-disk artifact goes through.
//!
//! Layout (all integers little-endian), modeled on pippin's snapshot
//! format (magic + per-section identifiers + checksum-of-header):
//!
//! ```text
//! magic    b"PEQAS1\n"                      (7 bytes)
//! version  u32                              (currently 1)
//! kind     u8 length + ASCII bytes          ("checkpoint" | "packed" | "registry" | …)
//! nsect    u32
//! per section:
//!   name   u16 length + UTF-8 bytes         ("meta", "t:<tensor name>", …)
//!   len    u64   payload byte length
//!   crc    u32   CRC32 (IEEE) of the payload
//! hcrc     u32   CRC32 of every header byte above
//! payloads … concatenated in section order …
//! tcrc     u32   CRC32 trailer over header + payloads
//! ```
//!
//! Every byte of the file is covered by at least one checksum, so any
//! single bit flip is detected at load — with an error naming the file,
//! the section, and the expected-vs-actual checksum. Truncation anywhere
//! fails the length bookkeeping with the offset and the expected-vs-got
//! byte counts.
//!
//! Writes never touch the destination path directly: [`atomic_write`]
//! writes a sibling temp file, fsyncs it, and renames it into place, so
//! a crash mid-write can never leave a short artifact under the real
//! name — the previous version (or nothing) survives instead.

use std::io::Write;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{bail, Context, Result};

/// Magic of the checksummed container format.
pub const CONTAINER_MAGIC: &[u8; 7] = b"PEQAS1\n";
/// Current container format version.
pub const CONTAINER_VERSION: u32 = 1;

// -- CRC32 (IEEE 802.3, the zlib polynomial) -------------------------------
//
// The vendored registry has no checksum crate, so the table lives here.

const fn crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = crc_table();

/// Incremental CRC32 (IEEE) — the journal streams records through it.
#[derive(Clone, Debug)]
pub struct Crc32 {
    state: u32,
}

impl Default for Crc32 {
    fn default() -> Self {
        Self::new()
    }
}

impl Crc32 {
    pub fn new() -> Crc32 {
        Crc32 { state: 0xFFFF_FFFF }
    }

    pub fn update(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.state = CRC_TABLE[((self.state ^ b as u32) & 0xFF) as usize] ^ (self.state >> 8);
        }
    }

    pub fn finish(&self) -> u32 {
        self.state ^ 0xFFFF_FFFF
    }
}

/// One-shot CRC32 of a byte slice.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(bytes);
    c.finish()
}

// -- little-endian field readers -------------------------------------------
//
// Store parsers bounds-check with `need()` before every field, but the
// readers themselves still must not be able to panic on a short slice
// (`panic-free-paths`): out-of-range bytes read as zero and the
// surrounding length/checksum bookkeeping turns that into a typed error.

/// First `N` bytes at `off`, zero-padded past the end of `bytes`.
pub(crate) fn le_bytes<const N: usize>(bytes: &[u8], off: usize) -> [u8; N] {
    let mut out = [0u8; N];
    let end = off.saturating_add(N).min(bytes.len());
    if off < end {
        out[..end - off].copy_from_slice(&bytes[off..end]);
    }
    out
}

pub(crate) fn le_u16(bytes: &[u8], off: usize) -> u16 {
    u16::from_le_bytes(le_bytes(bytes, off))
}

pub(crate) fn le_u32(bytes: &[u8], off: usize) -> u32 {
    u32::from_le_bytes(le_bytes(bytes, off))
}

pub(crate) fn le_u64(bytes: &[u8], off: usize) -> u64 {
    u64::from_le_bytes(le_bytes(bytes, off))
}

pub(crate) fn le_f32(bytes: &[u8], off: usize) -> f32 {
    f32::from_le_bytes(le_bytes(bytes, off))
}

// -- atomic writes ----------------------------------------------------------

static TMP_COUNTER: AtomicU64 = AtomicU64::new(0);

/// Durably replace `path` with `bytes`: write a sibling temp file, fsync
/// it, rename it over `path`, then (best-effort) fsync the directory.
/// Returns the byte count written. A crash at any point leaves either
/// the old file or the new one — never a truncated mix.
pub fn atomic_write(path: &Path, bytes: &[u8]) -> Result<u64> {
    let dir = match path.parent() {
        Some(d) if !d.as_os_str().is_empty() => {
            std::fs::create_dir_all(d)
                .with_context(|| format!("creating directory {}", d.display()))?;
            Some(d)
        }
        _ => None,
    };
    let file_name = path
        .file_name()
        .and_then(|s| s.to_str())
        .with_context(|| format!("atomic_write: bad path {}", path.display()))?;
    let tmp_name = format!(
        ".{file_name}.tmp.{}.{}",
        std::process::id(),
        TMP_COUNTER.fetch_add(1, Ordering::Relaxed)
    );
    let tmp = match dir {
        Some(d) => d.join(&tmp_name),
        None => std::path::PathBuf::from(&tmp_name),
    };
    let write = || -> Result<()> {
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        f.write_all(bytes)?;
        f.sync_all()?;
        drop(f);
        std::fs::rename(&tmp, path)
            .with_context(|| format!("renaming into {}", path.display()))?;
        Ok(())
    };
    if let Err(e) = write() {
        let _ = std::fs::remove_file(&tmp);
        return Err(e);
    }
    if let Some(d) = dir {
        // Persist the rename itself; some filesystems cannot open a
        // directory for sync — the data fsync above already happened.
        if let Ok(df) = std::fs::File::open(d) {
            let _ = df.sync_all();
        }
    }
    Ok(bytes.len() as u64)
}

// -- container write --------------------------------------------------------

/// Builder for one container file: a kind tag plus named payload
/// sections, serialized with the checksums of the module docs.
pub struct ContainerWriter {
    kind: String,
    sections: Vec<(String, Vec<u8>)>,
}

impl ContainerWriter {
    // peqa-lint: allow(panic-free-paths) -- writer-side programmer-error
    // guard: kind strings are compile-time literals ("checkpoint",
    // "packed", ...); a 256-byte kind is a bug in this crate, not input.
    pub fn new(kind: &str) -> ContainerWriter {
        assert!(kind.len() < 256, "container kind too long");
        ContainerWriter { kind: kind.to_string(), sections: Vec::new() }
    }

    /// Append one named payload section (order is preserved).
    // peqa-lint: allow(panic-free-paths) -- writer-side programmer-error
    // guard: section names come from tensor names already bounded far
    // below u16::MAX; exceeding it is a bug, not corrupt input.
    pub fn section(&mut self, name: &str, payload: Vec<u8>) -> &mut Self {
        assert!(name.len() <= u16::MAX as usize, "section name too long");
        self.sections.push((name.to_string(), payload));
        self
    }

    /// Serialize header + payloads + checksums.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(CONTAINER_MAGIC);
        out.extend_from_slice(&CONTAINER_VERSION.to_le_bytes());
        out.push(self.kind.len() as u8);
        out.extend_from_slice(self.kind.as_bytes());
        out.extend_from_slice(&(self.sections.len() as u32).to_le_bytes());
        for (name, payload) in &self.sections {
            out.extend_from_slice(&(name.len() as u16).to_le_bytes());
            out.extend_from_slice(name.as_bytes());
            out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
            out.extend_from_slice(&crc32(payload).to_le_bytes());
        }
        let hcrc = crc32(&out);
        out.extend_from_slice(&hcrc.to_le_bytes());
        for (_, payload) in &self.sections {
            out.extend_from_slice(payload);
        }
        let tcrc = crc32(&out);
        out.extend_from_slice(&tcrc.to_le_bytes());
        out
    }

    /// Serialize and [`atomic_write`] to `path`; returns file bytes.
    pub fn write_atomic(&self, path: &Path) -> Result<u64> {
        atomic_write(path, &self.to_bytes())
    }
}

// -- container read ---------------------------------------------------------

/// One verified section of a read container.
pub struct Section {
    pub name: String,
    pub crc: u32,
    pub payload: Vec<u8>,
}

/// A fully verified container: every checksum (header, per-section,
/// whole-file trailer) has been checked before this value exists.
pub struct Container {
    pub kind: String,
    pub version: u32,
    sections: Vec<Section>,
}

/// Whether `bytes` starts with the container magic (format dispatch —
/// legacy files start with `PEQA1\n` / `PEQAP1\n` instead).
pub fn is_container(bytes: &[u8]) -> bool {
    bytes.len() >= CONTAINER_MAGIC.len() && &bytes[..CONTAINER_MAGIC.len()] == CONTAINER_MAGIC
}

impl Container {
    /// Read and verify a container file.
    pub fn read(path: &Path) -> Result<Container> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("opening {}", path.display()))?;
        Self::from_bytes(&bytes, &path.display().to_string())
    }

    /// Parse + verify from bytes; `label` names the source in errors
    /// (normally the file path).
    pub fn from_bytes(bytes: &[u8], label: &str) -> Result<Container> {
        let need = |off: usize, n: usize, what: &str| -> Result<()> {
            if off + n > bytes.len() {
                bail!(
                    "{label}: truncated container: {what} needs {n} byte(s) at offset \
                     {off}, file has {} ({} available)",
                    bytes.len(),
                    bytes.len().saturating_sub(off)
                );
            }
            Ok(())
        };
        need(0, CONTAINER_MAGIC.len(), "magic")?;
        if !is_container(bytes) {
            bail!("{label}: not a PEQA store container (bad magic)");
        }
        let mut off = CONTAINER_MAGIC.len();
        need(off, 4, "format version")?;
        let version = le_u32(bytes, off);
        off += 4;
        if version != CONTAINER_VERSION {
            bail!("{label}: container format version {version} (this build reads {CONTAINER_VERSION})");
        }
        need(off, 1, "kind length")?;
        let klen = bytes[off] as usize;
        off += 1;
        need(off, klen, "kind")?;
        let kind = std::str::from_utf8(&bytes[off..off + klen])
            .with_context(|| format!("{label}: container kind is not UTF-8"))?
            .to_string();
        off += klen;
        need(off, 4, "section count")?;
        let nsect = le_u32(bytes, off) as usize;
        off += 4;
        let mut metas: Vec<(String, u64, u32)> = Vec::with_capacity(nsect);
        for i in 0..nsect {
            need(off, 2, "section name length")?;
            let nlen = le_u16(bytes, off) as usize;
            off += 2;
            need(off, nlen, "section name")?;
            let name = std::str::from_utf8(&bytes[off..off + nlen])
                .with_context(|| format!("{label}: section {i} name is not UTF-8"))?
                .to_string();
            off += nlen;
            need(off, 12, "section length + checksum")?;
            let len = le_u64(bytes, off);
            let crc = le_u32(bytes, off + 8);
            off += 12;
            metas.push((name, len, crc));
        }
        need(off, 4, "header checksum")?;
        let hcrc = le_u32(bytes, off);
        let actual_hcrc = crc32(&bytes[..off]);
        if hcrc != actual_hcrc {
            bail!(
                "{label}: header checksum mismatch: expected {hcrc:08x}, got \
                 {actual_hcrc:08x} — the header is corrupt"
            );
        }
        off += 4;
        let mut sections = Vec::with_capacity(nsect);
        for (name, len, crc) in metas {
            let len = usize::try_from(len)
                .ok()
                .filter(|&l| off + l <= bytes.len().saturating_sub(4))
                .ok_or_else(|| {
                    anyhow::anyhow!(
                        "{label}: truncated container: section '{name}' expects {len} \
                         byte(s) at offset {off}, file has {} ({} available before the \
                         trailer)",
                        bytes.len(),
                        bytes.len().saturating_sub(4).saturating_sub(off)
                    )
                })?;
            let payload = bytes[off..off + len].to_vec();
            let actual = crc32(&payload);
            if actual != crc {
                bail!(
                    "{label}: checksum mismatch in section '{name}': expected \
                     {crc:08x}, got {actual:08x} — the payload is corrupt"
                );
            }
            off += len;
            sections.push(Section { name, crc, payload });
        }
        need(off, 4, "trailer checksum")?;
        let tcrc = le_u32(bytes, off);
        let actual_tcrc = crc32(&bytes[..off]);
        if tcrc != actual_tcrc {
            bail!(
                "{label}: trailer checksum mismatch: expected {tcrc:08x}, got \
                 {actual_tcrc:08x}"
            );
        }
        if off + 4 != bytes.len() {
            bail!(
                "{label}: {} trailing byte(s) after the container trailer",
                bytes.len() - off - 4
            );
        }
        Ok(Container { kind, version, sections })
    }

    pub fn sections(&self) -> &[Section] {
        &self.sections
    }

    /// A section's payload by name.
    pub fn section(&self, name: &str) -> Result<&[u8]> {
        self.sections
            .iter()
            .find(|s| s.name == name)
            .map(|s| s.payload.as_slice())
            .ok_or_else(|| anyhow::anyhow!("container has no section '{name}'"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Vec<u8> {
        let mut w = ContainerWriter::new("checkpoint");
        w.section("meta", b"{\"hello\":1}".to_vec());
        w.section("t:x.w", vec![1, 2, 3, 4, 5, 6, 7, 8]);
        w.section("t:empty", Vec::new());
        w.to_bytes()
    }

    #[test]
    fn crc32_known_vectors() {
        // IEEE CRC32 of "123456789" is the classic check value.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        let mut inc = Crc32::new();
        inc.update(b"1234");
        inc.update(b"56789");
        assert_eq!(inc.finish(), 0xCBF4_3926);
    }

    #[test]
    fn roundtrip() {
        let bytes = sample();
        let c = Container::from_bytes(&bytes, "mem").unwrap();
        assert_eq!(c.kind, "checkpoint");
        assert_eq!(c.version, CONTAINER_VERSION);
        assert_eq!(c.sections().len(), 3);
        assert_eq!(c.section("meta").unwrap(), b"{\"hello\":1}");
        assert_eq!(c.section("t:x.w").unwrap(), &[1, 2, 3, 4, 5, 6, 7, 8]);
        assert!(c.section("t:empty").unwrap().is_empty());
        assert!(c.section("nope").is_err());
    }

    #[test]
    fn every_single_byte_flip_is_detected() {
        let bytes = sample();
        for i in 0..bytes.len() {
            for bit in [0x01u8, 0x80] {
                let mut bad = bytes.clone();
                bad[i] ^= bit;
                let err = Container::from_bytes(&bad, "mem")
                    .err()
                    .unwrap_or_else(|| panic!("flip at byte {i} went undetected"));
                let msg = format!("{err:#}");
                assert!(msg.contains("mem"), "error names the source: {msg}");
            }
        }
    }

    #[test]
    fn every_truncation_length_is_detected() {
        let bytes = sample();
        for cut in 0..bytes.len() {
            assert!(
                Container::from_bytes(&bytes[..cut], "mem").is_err(),
                "truncation to {cut} bytes went undetected"
            );
        }
    }

    #[test]
    fn corrupt_section_error_names_section_and_checksums() {
        let bytes = sample();
        // Find the t:x.w payload (the 8 known bytes) and flip one.
        let pos = bytes
            .windows(8)
            .rposition(|w| w == [1, 2, 3, 4, 5, 6, 7, 8])
            .unwrap();
        let mut bad = bytes.clone();
        bad[pos] ^= 0xFF;
        let msg = format!("{:#}", Container::from_bytes(&bad, "mem").unwrap_err());
        assert!(msg.contains("t:x.w"), "{msg}");
        assert!(msg.contains("expected"), "{msg}");
    }

    #[test]
    fn atomic_write_replaces_and_cleans_up(){
        let dir = std::env::temp_dir().join("peqa_test_atomic_write");
        let path = dir.join("f.bin");
        atomic_write(&path, b"one").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"one");
        atomic_write(&path, b"two-longer").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"two-longer");
        // No temp litter left behind.
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().contains(".tmp."))
            .collect();
        assert!(leftovers.is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }
}
