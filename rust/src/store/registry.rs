//! Versioned adapter publication: a directory of immutable per-task
//! adapter files named by generation, fronted by a single
//! `registry.manifest` container that is replaced atomically.
//!
//! Publication protocol (crash-safe by ordering):
//! 1. every new adapter is written first, to a fresh
//!    `<task>.g<N>.adapter` name (checksummed container, atomic rename);
//! 2. the manifest — `{generation, tasks: {name → file}}` — is written
//!    last, also atomically.
//!
//! A crash between (1) and (2) leaves orphan adapter files but the old
//! manifest intact: readers never observe a partial generation. Tasks
//! not republished carry forward, still pointing at their previous
//! generation's files. Consumers ([`Registry::load`]) verify every
//! adapter's checksums before returning — a corrupt or half-written
//! adapter fails the *load*, and a watching server keeps serving the
//! generation it already has (store::registry never makes a server
//! crash; see `serve::server`).

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::format::{Container, ContainerWriter};
use crate::json::Value;
use crate::model::Checkpoint;

/// Manifest file name inside a registry directory.
pub const MANIFEST_NAME: &str = "registry.manifest";

/// A parsed manifest: the generation counter and task → file table.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Manifest {
    pub generation: u64,
    /// (task, adapter file name) pairs, sorted by task.
    pub tasks: Vec<(String, String)>,
}

impl Manifest {
    fn to_json(&self) -> String {
        Value::obj(vec![
            ("generation", Value::str(self.generation.to_string())),
            (
                "tasks",
                Value::Obj(
                    self.tasks
                        .iter()
                        .map(|(t, f)| (t.clone(), Value::str(f.clone())))
                        .collect(),
                ),
            ),
        ])
        .to_string()
    }

    fn from_json(text: &str) -> Result<Manifest> {
        let v = Value::parse(text).context("manifest JSON")?;
        let generation = v.str_of("generation")?.parse().context("manifest generation")?;
        let tasks_v = v.req("tasks")?;
        let Value::Obj(map) = tasks_v else { bail!("manifest 'tasks' is not an object") };
        let mut tasks = Vec::with_capacity(map.len());
        for (t, f) in map {
            let f = f.as_str().ok_or_else(|| anyhow::anyhow!("manifest task '{t}' file not a string"))?;
            tasks.push((t.clone(), f.to_string()));
        }
        Ok(Manifest { generation, tasks })
    }
}

/// Reject task names that could escape the registry directory or
/// collide with its bookkeeping.
pub fn validate_task_name(name: &str) -> Result<()> {
    if name.is_empty() {
        bail!("task name is empty");
    }
    if name.starts_with('.') {
        bail!("task name '{name}' must not start with '.'");
    }
    if name.contains(['/', '\\']) || name.contains('\0') {
        bail!("task name '{name}' must not contain path separators");
    }
    Ok(())
}

/// Handle on a registry directory. Opening never touches the
/// filesystem; an empty/missing directory is generation 0 with no
/// tasks.
pub struct Registry {
    dir: PathBuf,
}

impl Registry {
    pub fn open(dir: impl Into<PathBuf>) -> Registry {
        Registry { dir: dir.into() }
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn manifest_path(&self) -> PathBuf {
        self.dir.join(MANIFEST_NAME)
    }

    /// The current manifest; `Manifest::default()` (generation 0, no
    /// tasks) when none has been published yet. A *corrupt* manifest is
    /// an error, not an empty registry.
    pub fn manifest(&self) -> Result<Manifest> {
        let path = self.manifest_path();
        if !path.exists() {
            return Ok(Manifest::default());
        }
        let c = Container::read(&path)?;
        if c.kind != "registry" {
            bail!("{}: container kind '{}' is not 'registry'", path.display(), c.kind);
        }
        let text = std::str::from_utf8(c.section("manifest")?)
            .with_context(|| format!("{}: manifest is not UTF-8", path.display()))?;
        Manifest::from_json(text).with_context(|| path.display().to_string())
    }

    /// Cheap poll for watchers: the published generation (0 when no
    /// manifest exists). Errors on a corrupt manifest.
    pub fn generation(&self) -> Result<u64> {
        Ok(self.manifest()?.generation)
    }

    /// Publish `adapters` as the next generation: adapter files first,
    /// manifest last (see module docs). Tasks already in the registry
    /// but absent from `adapters` carry forward unchanged. Returns the
    /// new generation number.
    pub fn publish(&self, adapters: &[(String, &Checkpoint)]) -> Result<u64> {
        if adapters.is_empty() {
            bail!("refusing to publish an empty adapter set");
        }
        for (i, (name, ck)) in adapters.iter().enumerate() {
            validate_task_name(name)?;
            if adapters[..i].iter().any(|(n, _)| n == name) {
                bail!("duplicate task '{name}' in publish set");
            }
            if ck.is_empty() {
                bail!("task '{name}': refusing to publish an empty adapter");
            }
        }
        let prev = self.manifest()?;
        let generation = prev.generation + 1;
        std::fs::create_dir_all(&self.dir)
            .with_context(|| format!("creating registry {}", self.dir.display()))?;
        let mut tasks: Vec<(String, String)> = prev
            .tasks
            .iter()
            .filter(|(t, _)| !adapters.iter().any(|(n, _)| n == t))
            .cloned()
            .collect();
        for (name, ck) in adapters {
            let file = format!("{name}.g{generation}.adapter");
            ck.save(&self.dir.join(&file))
                .with_context(|| format!("publishing adapter '{name}'"))?;
            tasks.push((name.clone(), file));
        }
        tasks.sort_by(|a, b| a.0.cmp(&b.0));
        let manifest = Manifest { generation, tasks };
        let mut w = ContainerWriter::new("registry");
        w.section("manifest", manifest.to_json().into_bytes());
        w.write_atomic(&self.manifest_path())?;
        Ok(generation)
    }

    /// Garbage-collect superseded adapter generations. For every task,
    /// the union of (a) the file the live manifest references — which
    /// for a carried-forward task can be generations old — and (b) the
    /// `keep_last` newest `<task>.g<N>.adapter` files is kept; every
    /// other generation file of that task is deleted. Files that do not
    /// parse as `<task>.g<N>.adapter` (the manifest itself, stray
    /// files, dotfiles) are never touched, so a publisher crash between
    /// adapter writes and the manifest write leaves orphans that a
    /// later `gc` reclaims. Returns the deleted file names, sorted.
    pub fn gc(&self, keep_last: usize) -> Result<Vec<String>> {
        if !self.dir.exists() {
            return Ok(Vec::new());
        }
        let m = self.manifest()?;
        // peqa-lint: allow(nondeterminism-sources) -- membership-only:
        // `contains` checks during the prune scan; never iterated.
        let live: std::collections::HashSet<&str> =
            m.tasks.iter().map(|(_, f)| f.as_str()).collect();
        // peqa-lint: allow(nondeterminism-sources) -- the per-task prune
        // decision depends only on that task's sorted files plus `live`,
        // so visiting tasks in hash order deletes the same set, and the
        // returned list is sorted below.
        let mut by_task = std::collections::HashMap::<String, Vec<(u64, String)>>::new();
        for entry in std::fs::read_dir(&self.dir)
            .with_context(|| format!("reading registry {}", self.dir.display()))?
        {
            let p = entry?.path();
            if !p.is_file() {
                continue;
            }
            let Some(name) = p.file_name().and_then(|s| s.to_str()) else { continue };
            let Some((task, gen)) = parse_adapter_file(name) else { continue };
            by_task.entry(task).or_default().push((gen, name.to_string()));
        }
        let mut pruned = Vec::new();
        for files in by_task.values_mut() {
            files.sort_by_key(|(g, _)| std::cmp::Reverse(*g));
            for (i, (_, file)) in files.iter().enumerate() {
                if i < keep_last || live.contains(file.as_str()) {
                    continue;
                }
                std::fs::remove_file(self.dir.join(file))
                    .with_context(|| format!("pruning {file}"))?;
                pruned.push(file.clone());
            }
        }
        pruned.sort();
        Ok(pruned)
    }

    /// Load and fully verify the current generation: the manifest plus
    /// every adapter it references (each a checksummed container; any
    /// corruption or missing file fails the whole load). Returns
    /// `(generation, [(task, adapter)])`.
    pub fn load(&self) -> Result<(u64, Vec<(String, Checkpoint)>)> {
        let m = self.manifest()?;
        let mut out = Vec::with_capacity(m.tasks.len());
        for (task, file) in &m.tasks {
            let path = self.dir.join(file);
            let ck = Checkpoint::load(&path)
                .with_context(|| format!("registry task '{task}' (generation {})", m.generation))?;
            out.push((task.clone(), ck));
        }
        Ok((m.generation, out))
    }
}

/// Parse `<task>.g<N>.adapter` into `(task, N)`; anything else is
/// `None` (and therefore invisible to [`Registry::gc`]).
fn parse_adapter_file(name: &str) -> Option<(String, u64)> {
    let stem = name.strip_suffix(".adapter")?;
    let pos = stem.rfind(".g")?;
    let gen: u64 = stem[pos + 2..].parse().ok()?;
    let task = &stem[..pos];
    if task.is_empty() || validate_task_name(task).is_err() {
        return None;
    }
    Some((task.to_string(), gen))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    fn adapter(v: f32) -> Checkpoint {
        let mut ck = Checkpoint::new();
        ck.insert("layers.0.attn.q.s", Tensor::full(&[2, 1], v));
        ck
    }

    #[test]
    fn publish_load_and_carry_forward() {
        let dir = std::env::temp_dir().join("peqa_test_registry_pub");
        std::fs::remove_dir_all(&dir).ok();
        let reg = Registry::open(&dir);
        assert_eq!(reg.generation().unwrap(), 0);
        assert!(reg.load().unwrap().1.is_empty());

        let a1 = adapter(1.0);
        let b1 = adapter(2.0);
        let g = reg
            .publish(&[("a".to_string(), &a1), ("b".to_string(), &b1)])
            .unwrap();
        assert_eq!(g, 1);
        let (g, tasks) = reg.load().unwrap();
        assert_eq!(g, 1);
        assert_eq!(tasks.len(), 2);

        // Republish only 'a': 'b' carries forward from generation 1.
        let a2 = adapter(3.0);
        let g = reg.publish(&[("a".to_string(), &a2)]).unwrap();
        assert_eq!(g, 2);
        let (_, tasks) = reg.load().unwrap();
        let a = &tasks.iter().find(|(t, _)| t == "a").unwrap().1;
        let b = &tasks.iter().find(|(t, _)| t == "b").unwrap().1;
        assert_eq!(a.req("layers.0.attn.q.s").unwrap().data()[0], 3.0);
        assert_eq!(b.req("layers.0.attn.q.s").unwrap().data()[0], 2.0);
        // Old generation's files are untouched (immutable history).
        assert!(dir.join("a.g1.adapter").exists());
        assert!(dir.join("a.g2.adapter").exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupt_adapter_fails_load_but_manifest_survives() {
        let dir = std::env::temp_dir().join("peqa_test_registry_bad");
        std::fs::remove_dir_all(&dir).ok();
        let reg = Registry::open(&dir);
        reg.publish(&[("a".to_string(), &adapter(1.0))]).unwrap();
        // Flip one byte of the adapter payload.
        let p = dir.join("a.g1.adapter");
        let mut bytes = std::fs::read(&p).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x01;
        std::fs::write(&p, &bytes).unwrap();
        let err = reg.load().unwrap_err();
        assert!(format!("{err:#}").contains("a"), "{err:#}");
        // The generation counter is still readable.
        assert_eq!(reg.generation().unwrap(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn gc_keeps_live_and_recent_generations_including_carry_forward() {
        let dir = std::env::temp_dir().join("peqa_test_registry_gc");
        std::fs::remove_dir_all(&dir).ok();
        let reg = Registry::open(&dir);
        // b publishes once at generation 1 and carries forward while a
        // is republished through generation 4.
        reg.publish(&[("a".to_string(), &adapter(1.0)), ("b".to_string(), &adapter(9.0))])
            .unwrap();
        for v in [2.0, 3.0, 4.0] {
            reg.publish(&[("a".to_string(), &adapter(v))]).unwrap();
        }
        std::fs::write(dir.join("notes.txt"), b"not an adapter").unwrap();
        let pruned = reg.gc(2).unwrap();
        // a keeps its 2 newest (g3, g4 — g4 is also live); g1, g2 go.
        assert_eq!(pruned, vec!["a.g1.adapter", "a.g2.adapter"]);
        assert!(dir.join("a.g3.adapter").exists());
        assert!(dir.join("a.g4.adapter").exists());
        // b's only file is generations old but live via carry-forward.
        assert!(dir.join("b.g1.adapter").exists());
        assert!(dir.join("notes.txt").exists(), "gc only touches adapter files");
        // The registry still loads and verifies completely.
        let (g, tasks) = reg.load().unwrap();
        assert_eq!(g, 4);
        assert_eq!(tasks.len(), 2);
        // keep_last 0 keeps exactly the live set.
        let pruned = reg.gc(0).unwrap();
        assert_eq!(pruned, vec!["a.g3.adapter"]);
        assert!(dir.join("a.g4.adapter").exists());
        assert!(dir.join("b.g1.adapter").exists());
        assert_eq!(reg.load().unwrap().1.len(), 2);
        // Idempotent: nothing left to prune.
        assert!(reg.gc(0).unwrap().is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn gc_on_missing_or_empty_registry_is_a_noop() {
        let dir = std::env::temp_dir().join("peqa_test_registry_gc_empty");
        std::fs::remove_dir_all(&dir).ok();
        let reg = Registry::open(&dir);
        assert!(reg.gc(2).unwrap().is_empty(), "missing dir");
        std::fs::create_dir_all(&dir).unwrap();
        assert!(reg.gc(2).unwrap().is_empty(), "empty dir");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bad_task_names_rejected() {
        let dir = std::env::temp_dir().join("peqa_test_registry_names");
        std::fs::remove_dir_all(&dir).ok();
        let reg = Registry::open(&dir);
        let a = adapter(1.0);
        for bad in ["", ".hidden", "a/b", "a\\b"] {
            assert!(reg.publish(&[(bad.to_string(), &a)]).is_err(), "{bad:?}");
        }
        assert!(reg
            .publish(&[("x".to_string(), &a), ("x".to_string(), &a)])
            .is_err());
        assert!(reg.publish(&[]).is_err());
        assert!(reg.publish(&[("e".to_string(), &Checkpoint::new())]).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
