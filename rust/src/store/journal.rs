//! Crash-safe resumable fine-tuning: an append-only, per-record
//! checksummed commit log next to a base snapshot (sworndisk's
//! checkpoint-region discipline applied to PEQA training).
//!
//! Because PEQA trains only the per-(row, group) scale/zero vectors, the
//! *entire* mutable training state — trainable tensors, Adam moments,
//! loss bookkeeping, data-stream RNG position — is kilobytes (the
//! paper's Table 1 memory story applied to durability). So the journal
//! does not bother with deltas: every record is a full state snapshot,
//! and resume is "replay the last record", not "replay them all".
//!
//! File layout (integers little-endian):
//!
//! ```text
//! magic  b"PEQAJ1\n"             (7 bytes)
//! ver    u32                     (currently 1)
//! mlen   u64 + JSON meta          (task, base snapshot, seed, steps, …)
//! hcrc   u32                     CRC32 of every header byte above
//! then zero or more framed records:
//!   len  u32   payload byte length
//!   crc  u32   CRC32 of the payload
//!   payload    (see TrainRecord::to_bytes)
//! ```
//!
//! Torn-tail rule: a record that runs past EOF, or whose checksum fails
//! **and** which is the last thing in the file, is a torn tail — the
//! crash happened mid-append; [`read_journal`] reports it and
//! [`open_resume`] truncates it (the previous record is the durable
//! state). A checksum failure anywhere *before* the tail cannot be a
//! torn write and is a hard corruption error naming the record and the
//! expected-vs-actual checksum.
//!
//! Multi-task runs journal into the same file: the meta pins the task
//! list (`tasks`), and each checkpoint appends one record per task slot
//! (`TrainRecord::task_idx`, tasks in slot order at the same step). A
//! crash between a checkpoint's per-task appends leaves a *partial
//! round*; [`final_multi_state`] resumes from the last step at which
//! every task is durable, which keeps the round-robin lockstep — and
//! the bitwise-resume contract — intact.
//!
//! Exact-resume contract: the meta block pins every input that shapes
//! the run bit-for-bit — the LR schedule horizon (`steps`), the batcher
//! seed/geometry, the base snapshot — and u64/f64 values that JSON
//! cannot round-trip exactly (seed, lr) are stored as decimal strings
//! of their raw value. A resumed run killed at any step therefore
//! replays to a final adapter **bitwise identical** to the
//! uninterrupted run (pinned by tests/store_host.rs).

use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use super::format::{crc32, le_f32, le_u32, le_u64, Crc32};
use crate::json::Value;

/// Magic of the journal format.
pub const JOURNAL_MAGIC: &[u8; 7] = b"PEQAJ1\n";
/// Current journal format version.
pub const JOURNAL_VERSION: u32 = 1;

/// Everything a resumed run must replicate exactly. `seed` and
/// `lr_bits` (the `f64::to_bits` of the learning rate) are serialized
/// as decimal strings — JSON numbers are f64 and cannot hold them
/// exactly.
#[derive(Clone, Debug, PartialEq)]
pub struct JournalMeta {
    pub task: String,
    /// Multi-task round-robin runs journal every task's slot into ONE
    /// file: this pins the task list (order = `TrainRecord::task_idx`).
    /// Empty for single-task journals — the key is then omitted from
    /// the meta JSON, so single-task files are byte-identical to the
    /// pre-multi-task format.
    pub tasks: Vec<String>,
    /// Corpus the run streams — pinned separately from `task` because a
    /// run may name its adapter differently from its dataset.
    pub dataset: String,
    /// Base snapshot file name, relative to the journal's directory.
    pub base: String,
    pub seed: u64,
    /// Total step budget — the LR decay horizon; resume must keep it.
    pub steps: usize,
    pub save_every: usize,
    pub batch: usize,
    pub seq: usize,
    /// `f64::to_bits` of the learning rate.
    pub lr_bits: u64,
    pub warmup_steps: usize,
    pub train_zeros: bool,
    // Model geometry — the resumed tuner is rebuilt from these, not
    // from CLI flags that could silently drift.
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
}

impl JournalMeta {
    pub fn lr(&self) -> f64 {
        f64::from_bits(self.lr_bits)
    }

    fn to_json(&self) -> String {
        let mut pairs = vec![("task", Value::str(self.task.clone()))];
        if !self.tasks.is_empty() {
            pairs.push((
                "tasks",
                Value::Arr(self.tasks.iter().map(|t| Value::str(t.clone())).collect()),
            ));
        }
        pairs.extend(vec![
            ("dataset", Value::str(self.dataset.clone())),
            ("base", Value::str(self.base.clone())),
            ("seed", Value::str(self.seed.to_string())),
            ("steps", Value::num(self.steps as f64)),
            ("save_every", Value::num(self.save_every as f64)),
            ("batch", Value::num(self.batch as f64)),
            ("seq", Value::num(self.seq as f64)),
            ("lr_bits", Value::str(self.lr_bits.to_string())),
            ("warmup_steps", Value::num(self.warmup_steps as f64)),
            ("train_zeros", Value::Bool(self.train_zeros)),
            ("vocab", Value::num(self.vocab as f64)),
            ("d_model", Value::num(self.d_model as f64)),
            ("n_layers", Value::num(self.n_layers as f64)),
            ("n_heads", Value::num(self.n_heads as f64)),
            ("d_ff", Value::num(self.d_ff as f64)),
        ]);
        Value::obj(pairs).to_string()
    }

    fn from_json(text: &str) -> Result<JournalMeta> {
        let v = Value::parse(text).context("journal meta JSON")?;
        let tasks = match v.get("tasks") {
            None => Vec::new(),
            Some(t) => t
                .as_arr()
                .ok_or_else(|| anyhow!("journal meta 'tasks' is not an array"))?
                .iter()
                .map(|e| {
                    e.as_str()
                        .map(str::to_string)
                        .ok_or_else(|| anyhow!("journal meta 'tasks' entry is not a string"))
                })
                .collect::<Result<Vec<_>>>()?,
        };
        Ok(JournalMeta {
            task: v.str_of("task")?.to_string(),
            tasks,
            dataset: v.str_of("dataset")?.to_string(),
            base: v.str_of("base")?.to_string(),
            seed: v.str_of("seed")?.parse().context("journal meta seed")?,
            steps: v.usize_of("steps")?,
            save_every: v.usize_of("save_every")?,
            batch: v.usize_of("batch")?,
            seq: v.usize_of("seq")?,
            lr_bits: v.str_of("lr_bits")?.parse().context("journal meta lr_bits")?,
            warmup_steps: v.usize_of("warmup_steps")?,
            train_zeros: v.bool_of("train_zeros")?,
            vocab: v.usize_of("vocab")?,
            d_model: v.usize_of("d_model")?,
            n_layers: v.usize_of("n_layers")?,
            n_heads: v.usize_of("n_heads")?,
            d_ff: v.usize_of("d_ff")?,
        })
    }
}

/// One full-state record: everything [`Tuner::import_state`]
/// (`crate::train::Tuner`) and the data batcher need to continue
/// bit-for-bit. `params`/`opt_m`/`opt_v` are in the backend's optimizer
/// slot order (per projection: scales, then zeros when trained).
/// `losses` holds only the losses recorded *since the previous record*;
/// the reader accumulates them into the full history.
#[derive(Clone, Debug, PartialEq)]
pub struct TrainRecord {
    pub step: u64,
    /// Which task slot this record snapshots (index into
    /// [`JournalMeta::tasks`]); 0 for single-task journals. Serialized
    /// as a trailing field only when nonzero, so records a pre-multi
    /// build wrote (which never have trailing bytes) parse as task 0.
    pub task_idx: u32,
    /// Data-stream position: the batcher RNG's raw (state, inc).
    pub rng: (u64, u64),
    /// EMA-smoothed loss (bit-exact via f64 bits).
    pub ema: Option<f64>,
    pub losses: Vec<f32>,
    pub params: Vec<Vec<f32>>,
    pub opt_m: Vec<Vec<f32>>,
    pub opt_v: Vec<Vec<f32>>,
}

impl TrainRecord {
    // peqa-lint: allow(panic-free-paths) -- writer-side invariant: the
    // three optimizer slot vectors are built in lockstep by the trainer;
    // a mismatch is a bug in this crate, and persisting a malformed
    // record would be strictly worse than failing the writing run.
    fn to_bytes(&self) -> Vec<u8> {
        assert_eq!(self.params.len(), self.opt_m.len(), "record slot arity");
        assert_eq!(self.params.len(), self.opt_v.len(), "record slot arity");
        let mut b = Vec::new();
        b.extend_from_slice(&self.step.to_le_bytes());
        b.extend_from_slice(&self.rng.0.to_le_bytes());
        b.extend_from_slice(&self.rng.1.to_le_bytes());
        b.push(self.ema.is_some() as u8);
        b.extend_from_slice(&self.ema.unwrap_or(0.0).to_bits().to_le_bytes());
        b.extend_from_slice(&(self.losses.len() as u32).to_le_bytes());
        for &l in &self.losses {
            b.extend_from_slice(&l.to_le_bytes());
        }
        b.extend_from_slice(&(self.params.len() as u32).to_le_bytes());
        for (i, p) in self.params.iter().enumerate() {
            assert_eq!(p.len(), self.opt_m[i].len(), "slot {i} m size");
            assert_eq!(p.len(), self.opt_v[i].len(), "slot {i} v size");
            b.extend_from_slice(&(p.len() as u64).to_le_bytes());
            for vec in [p, &self.opt_m[i], &self.opt_v[i]] {
                for &x in vec.iter() {
                    b.extend_from_slice(&x.to_le_bytes());
                }
            }
        }
        // Trailing optional section: absent for task 0 keeps single-task
        // records byte-identical to the pre-multi-task format.
        if self.task_idx != 0 {
            b.extend_from_slice(&self.task_idx.to_le_bytes());
        }
        b
    }

    fn from_bytes(b: &[u8]) -> Result<TrainRecord> {
        let mut r = Reader { b, off: 0 };
        let step = r.u64("step")?;
        let rng = (r.u64("rng state")?, r.u64("rng inc")?);
        let ema_flag = r.u8("ema flag")?;
        let ema_bits = r.u64("ema bits")?;
        let ema = (ema_flag != 0).then(|| f64::from_bits(ema_bits));
        let n_losses = r.u32("loss count")? as usize;
        let losses = r.f32s(n_losses, "losses")?;
        let n_slots = r.u32("slot count")? as usize;
        let mut params = Vec::with_capacity(n_slots);
        let mut opt_m = Vec::with_capacity(n_slots);
        let mut opt_v = Vec::with_capacity(n_slots);
        for i in 0..n_slots {
            let len = usize::try_from(r.u64("slot length")?)
                .map_err(|_| anyhow!("slot {i} length overflows"))?;
            params.push(r.f32s(len, "slot params")?);
            opt_m.push(r.f32s(len, "slot m")?);
            opt_v.push(r.f32s(len, "slot v")?);
        }
        let task_idx = match b.len() - r.off {
            0 => 0,
            4 => r.u32("task index")?,
            n => bail!("record has {n} trailing byte(s)"),
        };
        Ok(TrainRecord { step, task_idx, rng, ema, losses, params, opt_m, opt_v })
    }
}

struct Reader<'a> {
    b: &'a [u8],
    off: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8]> {
        if self.off + n > self.b.len() {
            bail!(
                "record truncated: {what} needs {n} byte(s) at offset {}, record has {}",
                self.off,
                self.b.len()
            );
        }
        let s = &self.b[self.off..self.off + n];
        self.off += n;
        Ok(s)
    }

    fn u8(&mut self, what: &str) -> Result<u8> {
        Ok(self.take(1, what)?[0])
    }

    fn u32(&mut self, what: &str) -> Result<u32> {
        Ok(le_u32(self.take(4, what)?, 0))
    }

    fn u64(&mut self, what: &str) -> Result<u64> {
        Ok(le_u64(self.take(8, what)?, 0))
    }

    fn f32s(&mut self, n: usize, what: &str) -> Result<Vec<f32>> {
        let raw = self.take(n.checked_mul(4).ok_or_else(|| anyhow!("{what}: size overflow"))?, what)?;
        Ok(raw.chunks_exact(4).map(|c| le_f32(c, 0)).collect())
    }
}

/// A torn tail found at the end of a journal: bytes past `valid_len`
/// belong to an append the crash interrupted.
#[derive(Debug)]
pub struct TornTail {
    pub valid_len: u64,
    pub reason: String,
}

fn header_bytes(meta: &JournalMeta) -> Vec<u8> {
    let mj = meta.to_json();
    let mut h = Vec::new();
    h.extend_from_slice(JOURNAL_MAGIC);
    h.extend_from_slice(&JOURNAL_VERSION.to_le_bytes());
    h.extend_from_slice(&(mj.len() as u64).to_le_bytes());
    h.extend_from_slice(mj.as_bytes());
    h.extend_from_slice(&crc32(&h).to_le_bytes());
    h
}

/// Open handle that appends checksummed records, fsyncing each one
/// before returning — a record that `append` has acked is durable.
pub struct JournalWriter {
    file: std::fs::File,
    path: PathBuf,
    /// Last appended (step, task_idx) — appends must grow
    /// lexicographically, which reduces to strict step monotonicity for
    /// single-task journals (every task_idx is 0).
    last: Option<(u64, u32)>,
}

impl JournalWriter {
    /// Start a fresh journal at `path` (truncating any previous one) and
    /// durably write its header.
    pub fn create(path: &Path, meta: &JournalMeta) -> Result<JournalWriter> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("creating directory {}", dir.display()))?;
            }
        }
        let mut file = std::fs::File::create(path)
            .with_context(|| format!("creating journal {}", path.display()))?;
        file.write_all(&header_bytes(meta))?;
        file.sync_all()?;
        Ok(JournalWriter { file, path: path.to_path_buf(), last: None })
    }

    /// Append one record frame (`len | crc | payload`) and fsync it.
    pub fn append(&mut self, rec: &TrainRecord) -> Result<()> {
        if let Some(last) = self.last {
            if (rec.step, rec.task_idx) <= last {
                bail!(
                    "{}: journal records must be monotonic in (step, task) — appending \
                     step {} task {} after step {} task {}",
                    self.path.display(),
                    rec.step,
                    rec.task_idx,
                    last.0,
                    last.1
                );
            }
        }
        let payload = rec.to_bytes();
        let mut frame = Vec::with_capacity(8 + payload.len());
        frame.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        frame.extend_from_slice(&crc32(&payload).to_le_bytes());
        frame.extend_from_slice(&payload);
        self.file
            .write_all(&frame)
            .with_context(|| format!("appending to journal {}", self.path.display()))?;
        self.file.sync_data()?;
        self.last = Some((rec.step, rec.task_idx));
        Ok(())
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

/// Parse + verify a journal. Returns the meta, every intact record in
/// order, and `Some(TornTail)` if the file ends in an interrupted
/// append. Mid-file corruption (a bad checksum that is *not* the last
/// thing in the file) is a hard error naming the record and checksums.
pub fn read_journal(path: &Path) -> Result<(JournalMeta, Vec<TrainRecord>, Option<TornTail>)> {
    let bytes = std::fs::read(path).with_context(|| format!("opening {}", path.display()))?;
    let label = path.display().to_string();
    let need = |off: usize, n: usize, what: &str| -> Result<()> {
        if off + n > bytes.len() {
            bail!(
                "{label}: truncated journal header: {what} needs {n} byte(s) at offset {off}, \
                 file has {}",
                bytes.len()
            );
        }
        Ok(())
    };
    need(0, JOURNAL_MAGIC.len(), "magic")?;
    if &bytes[..JOURNAL_MAGIC.len()] != JOURNAL_MAGIC {
        bail!("{label}: not a PEQA training journal (bad magic)");
    }
    let mut off = JOURNAL_MAGIC.len();
    need(off, 4, "format version")?;
    let version = le_u32(&bytes, off);
    off += 4;
    if version != JOURNAL_VERSION {
        bail!("{label}: journal format version {version} (this build reads {JOURNAL_VERSION})");
    }
    need(off, 8, "meta length")?;
    let mlen = le_u64(&bytes, off) as usize;
    off += 8;
    need(off, mlen, "meta JSON")?;
    let meta_str = std::str::from_utf8(&bytes[off..off + mlen])
        .with_context(|| format!("{label}: journal meta is not UTF-8"))?;
    off += mlen;
    need(off, 4, "header checksum")?;
    let hcrc = le_u32(&bytes, off);
    let actual = crc32(&bytes[..off]);
    if hcrc != actual {
        bail!(
            "{label}: header checksum mismatch: expected {hcrc:08x}, got {actual:08x} — \
             the journal header is corrupt"
        );
    }
    off += 4;
    let meta = JournalMeta::from_json(meta_str).with_context(|| format!("{label}: meta"))?;

    let mut records = Vec::new();
    let mut torn = None;
    let mut last: Option<(u64, u32)> = None;
    let mut idx = 0usize;
    while off < bytes.len() {
        let frame_start = off;
        if bytes.len() - off < 8 {
            torn = Some(TornTail {
                valid_len: frame_start as u64,
                reason: format!(
                    "incomplete frame header at offset {frame_start} ({} byte(s) left)",
                    bytes.len() - off
                ),
            });
            break;
        }
        let plen = le_u32(&bytes, off) as usize;
        let crc = le_u32(&bytes, off + 4);
        off += 8;
        if off + plen > bytes.len() {
            torn = Some(TornTail {
                valid_len: frame_start as u64,
                reason: format!(
                    "record {idx} at offset {frame_start} expects {plen} payload byte(s), \
                     {} left",
                    bytes.len() - off
                ),
            });
            break;
        }
        let payload = &bytes[off..off + plen];
        let actual = crc32(payload);
        if actual != crc {
            if off + plen == bytes.len() {
                // Nothing after the bad record: indistinguishable from a
                // crash mid-append — torn tail, not corruption.
                torn = Some(TornTail {
                    valid_len: frame_start as u64,
                    reason: format!(
                        "record {idx} at offset {frame_start} checksum mismatch at EOF \
                         (expected {crc:08x}, got {actual:08x})"
                    ),
                });
                break;
            }
            bail!(
                "{label}: checksum mismatch in record {idx} at offset {frame_start}: \
                 expected {crc:08x}, got {actual:08x} — the journal is corrupt \
                 mid-file (not a torn tail)"
            );
        }
        let rec = TrainRecord::from_bytes(payload)
            .with_context(|| format!("{label}: record {idx} at offset {frame_start}"))?;
        if let Some(last) = last {
            if (rec.step, rec.task_idx) <= last {
                bail!(
                    "{label}: record {idx} (step {}, task {}) is not after the previous \
                     record (step {}, task {})",
                    rec.step,
                    rec.task_idx,
                    last.0,
                    last.1
                );
            }
        }
        last = Some((rec.step, rec.task_idx));
        records.push(rec);
        off += plen;
        idx += 1;
    }
    Ok((meta, records, torn))
}

/// Read a journal for resumption: verify it, truncate a torn tail in
/// place, and reopen for appending. Returns the meta, the surviving
/// records, and a writer positioned after the last intact record.
pub fn open_resume(path: &Path) -> Result<(JournalMeta, Vec<TrainRecord>, JournalWriter)> {
    let (meta, records, torn) = read_journal(path)?;
    let file = std::fs::OpenOptions::new()
        .read(true)
        .write(true)
        .open(path)
        .with_context(|| format!("reopening journal {}", path.display()))?;
    if let Some(t) = &torn {
        crate::info!(
            "{}: truncating torn tail at byte {} ({})",
            path.display(),
            t.valid_len,
            t.reason
        );
        file.set_len(t.valid_len)
            .with_context(|| format!("truncating torn tail of {}", path.display()))?;
        file.sync_all()?;
    }
    let mut file = file;
    use std::io::Seek;
    file.seek(std::io::SeekFrom::End(0))?;
    let last = records.last().map(|r| (r.step, r.task_idx));
    Ok((meta, records, JournalWriter { file, path: path.to_path_buf(), last }))
}

/// Fold the record stream into the final resumable state: the last
/// record's tensors/step/rng with the loss history accumulated across
/// *all* records. Returns `None` on an empty journal.
pub fn final_state(records: &[TrainRecord]) -> Option<(TrainRecord, Vec<f32>)> {
    let last = records.last()?;
    let mut losses = Vec::new();
    for r in records {
        losses.extend_from_slice(&r.losses);
    }
    Some((last.clone(), losses))
}

/// [`open_resume`] for multi-task journals: additionally truncates any
/// records past the last **complete** round. A crash can land between a
/// checkpoint's per-task appends, leaving a partial round at the tail
/// (after the byte-level torn-tail cut); the resumed run restarts from
/// the last complete round and re-appends the partial one, which the
/// (step, task) monotonicity check would reject if the stale records
/// were still in the file.
pub fn open_resume_multi(
    path: &Path,
    n_tasks: usize,
) -> Result<(JournalMeta, Vec<TrainRecord>, JournalWriter)> {
    let (meta, mut records, writer) = open_resume(path)?;
    let keep = match final_multi_state(&records, n_tasks) {
        None => 0,
        Some((s, _)) => records.iter().take_while(|r| r.step <= s).count(),
    };
    if keep == records.len() {
        return Ok((meta, records, writer));
    }
    drop(writer);
    // The record encoding is deterministic, so the surviving prefix's
    // byte length is recomputable: header + each kept record's frame.
    let mut valid = header_bytes(&meta).len() as u64;
    for r in &records[..keep] {
        valid += 8 + r.to_bytes().len() as u64;
    }
    crate::info!(
        "{}: dropping {} record(s) of a partial round (durable through the previous \
         complete round)",
        path.display(),
        records.len() - keep
    );
    let mut file = std::fs::OpenOptions::new()
        .read(true)
        .write(true)
        .open(path)
        .with_context(|| format!("reopening journal {}", path.display()))?;
    file.set_len(valid)
        .with_context(|| format!("truncating partial round of {}", path.display()))?;
    file.sync_all()?;
    use std::io::Seek;
    file.seek(std::io::SeekFrom::End(0))?;
    records.truncate(keep);
    let last = records.last().map(|r| (r.step, r.task_idx));
    Ok((meta, records, JournalWriter { file, path: path.to_path_buf(), last }))
}

/// Fold a MULTI-TASK record stream into per-task resumable state.
///
/// A multi-task checkpoint appends one record per task (task order 0..N)
/// at the same step, and a crash can land between those appends — some
/// tasks durable at step S, the rest still at the previous checkpoint.
/// Resuming from that mixed state would break the round-robin lockstep,
/// so the durable state is the last **complete** step: the largest step
/// at which every one of the `n_tasks` slots has a record. Records past
/// it (the torn checkpoint) are ignored; the resumed run re-steps and
/// re-appends them identically.
///
/// Returns `(step, per-task (last record, accumulated losses))`, tasks
/// in slot order, or `None` if no step is complete yet.
pub fn final_multi_state(
    records: &[TrainRecord],
    n_tasks: usize,
) -> Option<(u64, Vec<(TrainRecord, Vec<f32>)>)> {
    if n_tasks == 0 {
        return None;
    }
    // Records are (step, task) lexicographic (enforced on read), so each
    // step's group is contiguous and its tasks are in slot order.
    let mut last_complete: Option<u64> = None;
    let mut i = 0usize;
    while i < records.len() {
        let step = records[i].step;
        let mut j = i;
        while j < records.len() && records[j].step == step {
            j += 1;
        }
        let group = &records[i..j];
        if group.len() == n_tasks
            && group.iter().enumerate().all(|(k, r)| r.task_idx as usize == k)
        {
            last_complete = Some(step);
        }
        i = j;
    }
    let last = last_complete?;
    let mut out = Vec::with_capacity(n_tasks);
    for t in 0..n_tasks {
        let mut losses = Vec::new();
        let mut rec: Option<&TrainRecord> = None;
        for r in records.iter().filter(|r| r.task_idx as usize == t && r.step <= last) {
            losses.extend_from_slice(&r.losses);
            rec = Some(r);
        }
        out.push((rec?.clone(), losses));
    }
    Some((last, out))
}

/// Incremental whole-journal checksum helper used by fsck reporting.
pub fn journal_content_crc(bytes: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(bytes);
    c.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta() -> JournalMeta {
        JournalMeta {
            task: "alpaca".into(),
            tasks: Vec::new(),
            dataset: "wikitext".into(),
            base: "alpaca.base.packed".into(),
            seed: u64::MAX - 7,
            steps: 12,
            save_every: 3,
            batch: 2,
            seq: 16,
            lr_bits: (2e-3f64).to_bits(),
            warmup_steps: 1,
            train_zeros: true,
            vocab: 64,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 32,
        }
    }

    fn rec(step: u64) -> TrainRecord {
        rec_t(step, 0)
    }

    fn rec_t(step: u64, task_idx: u32) -> TrainRecord {
        TrainRecord {
            step,
            task_idx,
            rng: (0xDEAD_BEEF_0000_0000 + step, 0x5EED | 1),
            ema: (step > 0).then_some(1.25 + step as f64),
            losses: vec![step as f32 + 100.0 * task_idx as f32, step as f32 + 0.5],
            params: vec![vec![1.0, 2.0], vec![3.0; 3]],
            opt_m: vec![vec![0.1, 0.2], vec![0.3; 3]],
            opt_v: vec![vec![0.01, 0.02], vec![0.03; 3]],
        }
    }

    #[test]
    fn meta_roundtrips_u64_and_f64_exactly() {
        let m = meta();
        let back = JournalMeta::from_json(&m.to_json()).unwrap();
        assert_eq!(back, m);
        assert_eq!(back.seed, u64::MAX - 7);
        assert_eq!(back.lr(), 2e-3);
    }

    #[test]
    fn write_read_roundtrip_and_final_state() {
        let dir = std::env::temp_dir().join("peqa_test_journal_rt");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.journal");
        let mut w = JournalWriter::create(&path, &meta()).unwrap();
        w.append(&rec(3)).unwrap();
        w.append(&rec(6)).unwrap();
        drop(w);
        let (m, recs, torn) = read_journal(&path).unwrap();
        assert_eq!(m, meta());
        assert!(torn.is_none());
        assert_eq!(recs, vec![rec(3), rec(6)]);
        let (last, losses) = final_state(&recs).unwrap();
        assert_eq!(last, rec(6));
        assert_eq!(losses, vec![3.0, 3.5, 6.0, 6.5]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn multi_task_meta_and_records_roundtrip() {
        let mut m = meta();
        m.task = "a,b".into();
        m.tasks = vec!["a".into(), "b".into()];
        let back = JournalMeta::from_json(&m.to_json()).unwrap();
        assert_eq!(back, m);
        // Single-task meta omits the key entirely (byte-stable format).
        assert!(!meta().to_json().contains("tasks"));

        let dir = std::env::temp_dir().join("peqa_test_journal_multi");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.journal");
        let mut w = JournalWriter::create(&path, &m).unwrap();
        // Round 3 complete, round 6 torn after task 0's append.
        w.append(&rec_t(3, 0)).unwrap();
        w.append(&rec_t(3, 1)).unwrap();
        // Same (step, task) or going backwards is rejected.
        assert!(w.append(&rec_t(3, 1)).is_err());
        assert!(w.append(&rec_t(3, 0)).is_err());
        w.append(&rec_t(6, 0)).unwrap();
        drop(w);
        let (back, recs, torn) = read_journal(&path).unwrap();
        assert_eq!(back, m);
        assert!(torn.is_none());
        assert_eq!(recs, vec![rec_t(3, 0), rec_t(3, 1), rec_t(6, 0)]);
        // The durable state is the last COMPLETE round: step 3, both
        // tasks — task 0's lone step-6 record is ignored.
        let (step, per_task) = final_multi_state(&recs, 2).unwrap();
        assert_eq!(step, 3);
        assert_eq!(per_task.len(), 2);
        assert_eq!(per_task[0].0, rec_t(3, 0));
        assert_eq!(per_task[1].0, rec_t(3, 1));
        assert_eq!(per_task[0].1, vec![3.0, 3.5]);
        assert_eq!(per_task[1].1, vec![103.0, 3.5]);
        // A journal with no complete round yet has no durable state.
        assert!(final_multi_state(&recs[..1], 2).is_none());

        // open_resume_multi truncates the partial round so the resumed
        // run can re-step and re-append it without tripping the
        // monotonicity check.
        let (m3, recs, mut w) = open_resume_multi(&path, 2).unwrap();
        assert_eq!(m3, m);
        assert_eq!(recs, vec![rec_t(3, 0), rec_t(3, 1)]);
        w.append(&rec_t(6, 0)).unwrap();
        w.append(&rec_t(6, 1)).unwrap();
        drop(w);
        let (_, recs, torn) = read_journal(&path).unwrap();
        assert!(torn.is_none());
        assert_eq!(recs.len(), 4);
        assert_eq!(final_multi_state(&recs, 2).unwrap().0, 6);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn torn_tail_is_reported_and_truncated_mid_file_corruption_is_fatal() {
        let dir = std::env::temp_dir().join("peqa_test_journal_torn");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.journal");
        let mut w = JournalWriter::create(&path, &meta()).unwrap();
        w.append(&rec(3)).unwrap();
        drop(w);
        let good_len = std::fs::metadata(&path).unwrap().len();

        // Simulate a crash mid-append: garbage tail bytes.
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.extend_from_slice(&[0xAB, 0xCD, 0xEF]);
        std::fs::write(&path, &bytes).unwrap();
        let (_, recs, torn) = read_journal(&path).unwrap();
        assert_eq!(recs.len(), 1);
        assert_eq!(torn.as_ref().unwrap().valid_len, good_len);

        // open_resume truncates the tail and can append again.
        let (_, recs, mut w) = open_resume(&path).unwrap();
        assert_eq!(recs.len(), 1);
        assert_eq!(std::fs::metadata(&path).unwrap().len(), good_len);
        w.append(&rec(6)).unwrap();
        drop(w);
        let (_, recs, torn) = read_journal(&path).unwrap();
        assert!(torn.is_none());
        assert_eq!(recs.len(), 2);

        // Flip a byte inside the FIRST record (not the tail): hard error.
        let mut bytes = std::fs::read(&path).unwrap();
        let hdr = header_bytes(&meta()).len();
        bytes[hdr + 8 + 4] ^= 0xFF; // inside record 0's payload
        std::fs::write(&path, &bytes).unwrap();
        let err = read_journal(&path).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("record 0"), "{msg}");
        assert!(msg.contains("expected"), "{msg}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn non_monotonic_steps_rejected_on_write_and_read() {
        let dir = std::env::temp_dir().join("peqa_test_journal_mono");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.journal");
        let mut w = JournalWriter::create(&path, &meta()).unwrap();
        w.append(&rec(5)).unwrap();
        assert!(w.append(&rec(5)).is_err());
        assert!(w.append(&rec(4)).is_err());
        w.append(&rec(6)).unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn header_corruption_is_detected() {
        let dir = std::env::temp_dir().join("peqa_test_journal_hdr");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.journal");
        JournalWriter::create(&path, &meta()).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[JOURNAL_MAGIC.len() + 4 + 8 + 2] ^= 0x10; // inside meta JSON
        std::fs::write(&path, &bytes).unwrap();
        let msg = format!("{:#}", read_journal(&path).unwrap_err());
        assert!(msg.contains("header checksum"), "{msg}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
