//! Durable artifact storage: checksummed containers, the crash-safe
//! training journal, and versioned adapter publication.
//!
//! Three layers (ROADMAP Open item 2; formats modeled on pippin's
//! checksummed snapshot/commit-log and sworndisk's checkpoint region):
//!
//! * [`format`] — the `PEQAS1` container every artifact
//!   (`.peqa`/`.packed`/`.adapter`) is written in: magic + version +
//!   per-section length/CRC32 headers + whole-file trailer, written via
//!   temp-file → fsync → atomic rename. Any single flipped bit is
//!   detected at load; a crash mid-write can never leave a truncated
//!   file under the artifact's name. Legacy `PEQA1`/`PEQAP1` files
//!   still load (flagged unverified).
//! * [`journal`] — the `PEQAJ1` append-only commit log behind
//!   `peqa finetune --save-every N` / `--resume`: per-record-checksummed
//!   full training state (scales/zeros, Adam moments, loss bookkeeping,
//!   data-stream RNG), torn tails truncated on resume, resumed runs
//!   bitwise identical to uninterrupted ones.
//! * [`registry`] — generation-numbered adapter publication
//!   (`registry.manifest` + immutable `<task>.g<N>.adapter` files) that
//!   a live `peqa serve --registry` hot-reloads between requests.
//!
//! [`fsck`] verifies any of these (plus legacy files, best-effort) and
//! backs the `peqa fsck` subcommand.

pub mod format;
pub mod journal;
pub mod registry;

pub use format::{atomic_write, crc32, is_container, Container, ContainerWriter, Crc32};
pub use journal::{
    open_resume, read_journal, JournalMeta, JournalWriter, TornTail, TrainRecord,
};
pub use registry::{Manifest, Registry};

use std::path::Path;

use anyhow::{bail, Context, Result};

/// What `fsck` concluded about one file.
pub struct FsckReport {
    /// Every byte covered by a verified checksum (false for legacy
    /// formats, which parse but carry no checksums, and for journals
    /// with a torn tail).
    pub verified: bool,
    /// Human-readable report lines (header fields, sections, warnings).
    pub lines: Vec<String>,
}

/// Verify one artifact's checksums and describe its header. Understands
/// the `PEQAS1` container, the `PEQAJ1` journal, and the legacy
/// `PEQA1`/`PEQAP1` formats (parsed best-effort, reported unverified).
/// Corruption/truncation anywhere is an `Err` naming the damage.
pub fn fsck(path: &Path) -> Result<FsckReport> {
    let bytes = std::fs::read(path).with_context(|| format!("opening {}", path.display()))?;
    let disp = path.display();
    if is_container(&bytes) {
        let c = Container::from_bytes(&bytes, &disp.to_string())?;
        let mut lines = vec![format!(
            "{disp}: PEQAS1 container, kind '{}', version {}, {} section(s), {} bytes — all checksums OK",
            c.kind,
            c.version,
            c.sections().len(),
            bytes.len()
        )];
        for s in c.sections() {
            lines.push(format!("  section '{}': {} bytes, crc32 {:08x}", s.name, s.payload.len(), s.crc));
        }
        return Ok(FsckReport { verified: true, lines });
    }
    if bytes.starts_with(journal::JOURNAL_MAGIC) {
        let (meta, records, torn) = journal::read_journal(path)?;
        let mut lines = vec![format!(
            "{disp}: PEQAJ1 training journal, task '{}', base '{}', {} record(s), {} bytes",
            meta.task,
            meta.base,
            records.len(),
            bytes.len()
        )];
        if let Some(last) = records.last() {
            lines.push(format!(
                "  last record: step {}/{} ({} optimizer slot(s))",
                last.step,
                meta.steps,
                last.params.len()
            ));
        }
        if let Some(t) = &torn {
            lines.push(format!(
                "  WARNING: torn tail after byte {} ({}) — resume will truncate it",
                t.valid_len, t.reason
            ));
        }
        return Ok(FsckReport { verified: torn.is_none(), lines });
    }
    if bytes.starts_with(b"PEQA1\n") {
        let ck = crate::model::Checkpoint::load(path)?;
        return Ok(FsckReport {
            verified: false,
            lines: vec![format!(
                "{disp}: legacy PEQA1 checkpoint ({} tensor(s), {} params, {} bytes) — \
                 parsed OK but the format carries NO checksums (unverified); re-save to \
                 upgrade to the checksummed container",
                ck.len(),
                ck.n_params(),
                bytes.len()
            )],
        });
    }
    if bytes.starts_with(b"PEQAP1\n") {
        let pm = crate::model::PackedModel::load(path)?;
        return Ok(FsckReport {
            verified: false,
            lines: vec![format!(
                "{disp}: legacy PEQAP1 packed model ({}-bit, {} projection(s), {} bytes) — \
                 parsed OK but the format carries NO checksums (unverified); re-save to \
                 upgrade to the checksummed container",
                pm.bits,
                pm.prefixes().len(),
                bytes.len()
            )],
        });
    }
    bail!("{disp}: unrecognized artifact (no PEQAS1/PEQAJ1/PEQA1/PEQAP1 magic)");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fsck_reports_container_journal_and_garbage() {
        let dir = std::env::temp_dir().join("peqa_test_fsck");
        std::fs::create_dir_all(&dir).unwrap();
        // Container.
        let cpath = dir.join("x.adapter");
        let mut w = ContainerWriter::new("checkpoint");
        w.section("meta", b"[]".to_vec());
        w.write_atomic(&cpath).unwrap();
        let r = fsck(&cpath).unwrap();
        assert!(r.verified);
        assert!(r.lines[0].contains("checkpoint"), "{}", r.lines[0]);
        // Corrupt it → fsck errors.
        let mut bytes = std::fs::read(&cpath).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0x01;
        std::fs::write(&cpath, &bytes).unwrap();
        assert!(fsck(&cpath).is_err());
        // Garbage.
        let gpath = dir.join("junk.bin");
        std::fs::write(&gpath, b"hello world").unwrap();
        assert!(fsck(&gpath).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
