//! Command-line argument parser (no clap in the vendored registry).
//!
//! Grammar: `peqa <command> [positional…] [--flag] [--key value|--key=value]`.
//! Typed accessors with defaults; unknown-flag detection via `finish()`.

use std::collections::{BTreeMap, BTreeSet};

use anyhow::{anyhow, bail, Result};

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: Option<String>,
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: BTreeSet<String>,
    consumed: BTreeSet<String>,
}

impl Args {
    /// Parse from an iterator of arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if name.is_empty() {
                    bail!("bare '--' not supported");
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map_or(false, |n| !n.starts_with("--")) {
                    out.options.insert(name.to_string(), it.next().unwrap());
                } else {
                    out.flags.insert(name.to_string());
                }
            } else if out.command.is_none() {
                out.command = Some(arg);
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&mut self, name: &str) -> bool {
        self.consumed.insert(name.to_string());
        self.flags.contains(name)
    }

    pub fn opt(&mut self, name: &str) -> Option<String> {
        self.consumed.insert(name.to_string());
        // A value-less `--name` followed by another flag parses as a flag;
        // treat it as missing value rather than silently losing it.
        self.options.get(name).cloned()
    }

    pub fn get(&mut self, name: &str, default: &str) -> String {
        self.opt(name).unwrap_or_else(|| default.to_string())
    }

    pub fn get_usize(&mut self, name: &str, default: usize) -> Result<usize> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name} expects an integer, got '{v}'")),
        }
    }

    pub fn get_f64(&mut self, name: &str, default: f64) -> Result<f64> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name} expects a number, got '{v}'")),
        }
    }

    pub fn get_u64(&mut self, name: &str, default: u64) -> Result<u64> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name} expects an integer, got '{v}'")),
        }
    }

    pub fn require(&mut self, name: &str) -> Result<String> {
        self.opt(name).ok_or_else(|| anyhow!("missing required option --{name}"))
    }

    /// Error on any unconsumed option/flag (catches typos).
    pub fn finish(&self) -> Result<()> {
        for k in self.options.keys().chain(self.flags.iter()) {
            if !self.consumed.contains(k) {
                bail!("unknown option --{k}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn commands_positionals_options_flags() {
        let mut a = parse("train base.ckpt --size n3 --steps=200 --quiet --lr 1e-4");
        assert_eq!(a.command.as_deref(), Some("train"));
        assert_eq!(a.positional, vec!["base.ckpt"]);
        assert_eq!(a.get("size", "n1"), "n3");
        assert_eq!(a.get_usize("steps", 0).unwrap(), 200);
        assert!((a.get_f64("lr", 0.0).unwrap() - 1e-4).abs() < 1e-12);
        assert!(a.flag("quiet"));
        assert!(!a.flag("verbose"));
        a.finish().unwrap();
    }

    #[test]
    fn unknown_option_detected() {
        let mut a = parse("serve --prot 8080");
        let _ = a.flag("verbose");
        assert!(a.finish().is_err());
        assert_eq!(a.get("prot", ""), "8080");
        a.finish().unwrap();
    }

    #[test]
    fn defaults_and_required() {
        let mut a = parse("eval");
        assert_eq!(a.get_usize("batch", 8).unwrap(), 8);
        assert!(a.require("ckpt").is_err());
    }

    #[test]
    fn flag_before_flag_not_eaten() {
        let mut a = parse("x --fast --size n2");
        assert!(a.flag("fast"));
        assert_eq!(a.get("size", ""), "n2");
    }

    #[test]
    fn bad_number_errors() {
        let mut a = parse("x --steps abc");
        assert!(a.get_usize("steps", 1).is_err());
    }
}
