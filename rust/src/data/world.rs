//! The synthetic knowledge world.
//!
//! A deterministic universe of entities with attributes (color, place,
//! category, size, sound) plus a tool→use table and number words. The
//! pretraining corpus states these facts declaratively; the MC suites
//! (csr-sim / mmlu-sim) and instruction datasets query them. Because the
//! mapping is fixed by the seed, "knowledge" is measurable: a model that
//! memorized the facts scores high, a quantization-damaged model drops,
//! and PEQA-tuning can restore it — the Table 6/7 dynamic.

use crate::util::Pcg32;

pub const COLORS: [&str; 8] =
    ["red", "blue", "green", "gold", "black", "white", "pink", "gray"];
pub const PLACES: [&str; 8] =
    ["cave", "lake", "hill", "barn", "nest", "dune", "reef", "glen"];
pub const CATEGORIES: [&str; 6] = ["bird", "fish", "beast", "plant", "stone", "cloud"];
pub const SIZES: [&str; 4] = ["tiny", "small", "large", "huge"];
pub const SOUNDS: [&str; 6] = ["hum", "roar", "chirp", "buzz", "howl", "click"];
pub const TOOLS: [(&str, &str); 8] = [
    ("knife", "cut"),
    ("spoon", "stir"),
    ("lamp", "light"),
    ("rope", "tie"),
    ("broom", "sweep"),
    ("pen", "write"),
    ("saw", "split"),
    ("net", "catch"),
];
pub const NUMBERS: [&str; 10] =
    ["zero", "one", "two", "three", "four", "five", "six", "seven", "eight", "nine"];

const ONSETS: [&str; 10] = ["bl", "dor", "fen", "gri", "lum", "mer", "pol", "ras", "tav", "zor"];
const CODAS: [&str; 8] = ["im", "ax", "or", "ek", "un", "ish", "ol", "ar"];

/// One entity with its fixed attributes.
#[derive(Clone, Debug)]
pub struct Entity {
    pub name: String,
    pub color: usize,
    pub place: usize,
    pub category: usize,
    pub size: usize,
    pub sound: usize,
}

#[derive(Clone, Debug)]
pub struct World {
    pub entities: Vec<Entity>,
}

impl World {
    /// Deterministic world of `n` uniquely named entities.
    pub fn new(seed: u64, n: usize) -> Self {
        assert!(n <= ONSETS.len() * CODAS.len());
        let mut rng = Pcg32::seeded(seed, 0x77071d);
        let mut names: Vec<String> = ONSETS
            .iter()
            .flat_map(|o| CODAS.iter().map(move |c| format!("{o}{c}")))
            .collect();
        rng.shuffle(&mut names);
        let entities = names
            .into_iter()
            .take(n)
            .map(|name| Entity {
                name,
                color: rng.usize_below(COLORS.len()),
                place: rng.usize_below(PLACES.len()),
                category: rng.usize_below(CATEGORIES.len()),
                size: rng.usize_below(SIZES.len()),
                sound: rng.usize_below(SOUNDS.len()),
            })
            .collect();
        World { entities }
    }

    pub fn attr(&self, e: &Entity, domain: Domain) -> &'static str {
        match domain {
            Domain::Color => COLORS[e.color],
            Domain::Place => PLACES[e.place],
            Domain::Category => CATEGORIES[e.category],
            Domain::Size => SIZES[e.size],
            Domain::Sound => SOUNDS[e.sound],
        }
    }

    pub fn options(&self, domain: Domain) -> &'static [&'static str] {
        match domain {
            Domain::Color => &COLORS,
            Domain::Place => &PLACES,
            Domain::Category => &CATEGORIES,
            Domain::Size => &SIZES,
            Domain::Sound => &SOUNDS,
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Domain {
    Color,
    Place,
    Category,
    Size,
    Sound,
}

impl Domain {
    pub const ALL: [Domain; 5] =
        [Domain::Color, Domain::Place, Domain::Category, Domain::Size, Domain::Sound];

    pub fn noun(self) -> &'static str {
        match self {
            Domain::Color => "color",
            Domain::Place => "home",
            Domain::Category => "kind",
            Domain::Size => "size",
            Domain::Sound => "sound",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_unique_names() {
        let w1 = World::new(7, 48);
        let w2 = World::new(7, 48);
        assert_eq!(w1.entities.len(), 48);
        for (a, b) in w1.entities.iter().zip(&w2.entities) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.color, b.color);
        }
        let mut names: Vec<_> = w1.entities.iter().map(|e| e.name.clone()).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), 48);
    }

    #[test]
    fn different_seeds_differ() {
        let w1 = World::new(1, 32);
        let w2 = World::new(2, 32);
        let same = w1
            .entities
            .iter()
            .zip(&w2.entities)
            .filter(|(a, b)| a.name == b.name && a.color == b.color)
            .count();
        assert!(same < 8);
    }

    #[test]
    fn attributes_cover_domains() {
        let w = World::new(3, 48);
        for d in Domain::ALL {
            let opts = w.options(d);
            let e = &w.entities[0];
            assert!(opts.contains(&w.attr(e, d)));
        }
    }
}
