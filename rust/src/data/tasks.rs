//! Instruction-following data and evaluation suites (DESIGN §Substitutions).
//!
//! * `alpaca_sim`    — instruction/response pairs over *seen* task families
//!                     (color, place, addition, copy, tool-use): the
//!                     instruction-tuning set of Section 4.3.
//! * `ni_sim`        — *held-out* families (category, size): zero-shot task
//!                     generalization measured with ROUGE-L (Table 14).
//! * `csr_suite`     — five multiple-choice tasks standing in for
//!                     PIQA / HellaSwag / ARC-C / ARC-E / OBQA (Table 6).
//! * `mmlu_sim`      — four knowledge domains scored like MMLU (Table 7).
//!
//! MC items are scored by option log-likelihood (lm-eval-harness style) in
//! eval::mc; few-shot prompts prepend k solved examples from the same task.

use crate::util::Pcg32;

use super::world::{Domain, World, NUMBERS, TOOLS};

#[derive(Clone, Debug)]
pub struct Instruction {
    pub prompt: String,
    pub response: String,
    pub family: &'static str,
}

#[derive(Clone, Debug)]
pub struct McItem {
    /// Context ending right before the answer span.
    pub prompt: String,
    pub options: Vec<String>,
    pub correct: usize,
}

#[derive(Clone, Debug)]
pub struct McTask {
    pub name: &'static str,
    pub items: Vec<McItem>,
}

fn fmt_instruction(q: &str) -> String {
    // The Alpaca-style prompt format (fixed, learned during tuning).
    format!("instruction: {q} response:")
}

fn gen_one(world: &World, rng: &mut Pcg32, family: &'static str) -> Instruction {
    let e = rng.choose(&world.entities);
    let (q, a) = match family {
        "color" => (
            format!("what is the color of the {}?", e.name),
            format!(" {}", world.attr(e, Domain::Color)),
        ),
        "place" => (
            format!("where does the {} live?", e.name),
            format!(" the {}", world.attr(e, Domain::Place)),
        ),
        "add" => {
            let a = rng.below(5) as usize;
            let b = rng.below(5) as usize;
            (
                format!("what is {} plus {}?", NUMBERS[a], NUMBERS[b]),
                format!(" {}", NUMBERS[a + b]),
            )
        }
        "copy" => {
            let w1 = rng.choose(&world.entities).name.clone();
            (format!("repeat the word {w1}."), format!(" {w1}"))
        }
        "tool" => {
            let (tool, act) = rng.choose(&TOOLS);
            (format!("what do people use the {tool} for?"), format!(" to {act}"))
        }
        "category" => (
            format!("what kind of thing is the {}?", e.name),
            format!(" a {}", world.attr(e, Domain::Category)),
        ),
        "size" => (
            format!("what is the size of the {}?", e.name),
            format!(" {}", world.attr(e, Domain::Size)),
        ),
        _ => unreachable!("unknown family {family}"),
    };
    Instruction { prompt: fmt_instruction(&q), response: a, family }
}

pub const SEEN_FAMILIES: [&str; 5] = ["color", "place", "add", "copy", "tool"];
pub const HELDOUT_FAMILIES: [&str; 2] = ["category", "size"];

/// Instruction-tuning set over the seen families.
pub fn alpaca_sim(world: &World, seed: u64, n: usize) -> Vec<Instruction> {
    let mut rng = Pcg32::seeded(seed, 0xa1);
    (0..n).map(|i| gen_one(world, &mut rng, SEEN_FAMILIES[i % SEEN_FAMILIES.len()])).collect()
}

/// Held-out generalization set (Natural-Instruction analog).
pub fn ni_sim(world: &World, seed: u64, n: usize) -> Vec<Instruction> {
    let mut rng = Pcg32::seeded(seed, 0xa2);
    (0..n)
        .map(|i| gen_one(world, &mut rng, HELDOUT_FAMILIES[i % HELDOUT_FAMILIES.len()]))
        .collect()
}

fn mc_from_domain(
    world: &World,
    rng: &mut Pcg32,
    domain: Domain,
    template: impl Fn(&str) -> String,
    n: usize,
    n_options: usize,
) -> Vec<McItem> {
    (0..n)
        .map(|_| {
            let e = rng.choose(&world.entities);
            let opts_bank = world.options(domain);
            let correct_word = world.attr(e, domain);
            let mut distractors: Vec<&str> =
                opts_bank.iter().copied().filter(|w| *w != correct_word).collect();
            rng.shuffle(&mut distractors);
            let mut options: Vec<String> =
                distractors.into_iter().take(n_options - 1).map(|s| format!(" {s}")).collect();
            let pos = rng.usize_below(n_options);
            options.insert(pos, format!(" {correct_word}"));
            McItem { prompt: template(&e.name), options, correct: pos }
        })
        .collect()
}

/// Five common-sense-reasoning-analog tasks (Table 6).
pub fn csr_suite(world: &World, seed: u64, n_per_task: usize) -> Vec<McTask> {
    let mut rng = Pcg32::seeded(seed, 0xc5);
    let mut tasks = Vec::new();

    // piqa-sim: physical tool use.
    let items = (0..n_per_task)
        .map(|_| {
            let (tool, act) = *rng.choose(&TOOLS);
            let mut distractors: Vec<&str> = TOOLS
                .iter()
                .map(|(_, a)| *a)
                .filter(|a| *a != act)
                .collect();
            rng.shuffle(&mut distractors);
            let mut options: Vec<String> =
                distractors.into_iter().take(3).map(|s| format!(" {s}")).collect();
            let pos = rng.usize_below(4);
            options.insert(pos, format!(" {act}"));
            McItem { prompt: format!("people use the {tool} to"), options, correct: pos }
        })
        .collect();
    tasks.push(McTask { name: "piqa-sim", items });

    // hella-sim: scene continuation (place attribute, pretrain format).
    let items = mc_from_domain(
        world, &mut rng, Domain::Place,
        |name| format!("the {name} lives in the"), n_per_task, 4,
    );
    tasks.push(McTask { name: "hella-sim", items });

    // arc-c-sim: two-step arithmetic (hardest: degrades first).
    let items = (0..n_per_task)
        .map(|_| {
            let a = rng.below(4) as usize;
            let b = rng.below(3) as usize;
            let c = rng.below(3) as usize;
            let correct = a + b + c;
            let mut wrong: Vec<usize> =
                (0..10).filter(|&x| x != correct).collect();
            rng.shuffle(&mut wrong);
            let mut options: Vec<String> =
                wrong.into_iter().take(3).map(|x| format!(" {}", NUMBERS[x])).collect();
            let pos = rng.usize_below(4);
            options.insert(pos, format!(" {}", NUMBERS[correct]));
            McItem {
                prompt: format!(
                    "{} plus {} plus {} is", NUMBERS[a], NUMBERS[b], NUMBERS[c]
                ),
                options,
                correct: pos,
            }
        })
        .collect();
    tasks.push(McTask { name: "arc-c-sim", items });

    // arc-e-sim: color facts.
    let items = mc_from_domain(
        world, &mut rng, Domain::Color,
        |name| format!("the color of the {name} is"), n_per_task, 4,
    );
    tasks.push(McTask { name: "arc-e-sim", items });

    // obqa-sim: category facts.
    let items = mc_from_domain(
        world, &mut rng, Domain::Category,
        |name| format!("the {name} is a kind of"), n_per_task, 4,
    );
    tasks.push(McTask { name: "obqa-sim", items });

    tasks
}

/// Four knowledge domains scored like MMLU (Table 7). Prompts use the
/// exact declarative forms the pretraining corpus states the facts in
/// (corpus::fact_sentences), so accuracy measures *knowledge retention* —
/// the quantity RTN damages and PEQA restores — not format familiarity.
pub fn mmlu_sim(world: &World, seed: u64, n_per_domain: usize) -> Vec<McTask> {
    let mut rng = Pcg32::seeded(seed, 0xd7);
    let items_c = mc_from_domain(
        world, &mut rng, Domain::Color,
        |n| format!("the color of the {n} is"), n_per_domain, 4,
    );
    let items_p = mc_from_domain(
        world, &mut rng, Domain::Place,
        |n| format!("the {n} lives in the"), n_per_domain, 4,
    );
    let items_s = mc_from_domain(
        world, &mut rng, Domain::Size,
        |n| format!("the {n} is"), n_per_domain, 4,
    );
    let items_n = mc_from_domain(
        world, &mut rng, Domain::Sound,
        |n| format!("the {n} makes a"), n_per_domain, 4,
    );
    vec![
        McTask { name: "colors", items: items_c },
        McTask { name: "places", items: items_p },
        McTask { name: "sizes", items: items_s },
        McTask { name: "sounds", items: items_n },
    ]
}

/// k-shot prompt prefix: k solved items of the same task joined in front.
pub fn few_shot_prefix(task: &McTask, k: usize, rng: &mut Pcg32) -> String {
    let mut prefix = String::new();
    for _ in 0..k {
        let ex = &task.items[rng.usize_below(task.items.len())];
        prefix.push_str(&ex.prompt);
        prefix.push_str(&ex.options[ex.correct]);
        prefix.push_str(". ");
    }
    prefix
}

#[cfg(test)]
mod tests {
    use super::*;

    fn world() -> World {
        World::new(1, 32)
    }

    #[test]
    fn instructions_deterministic_and_well_formed() {
        let w = world();
        let a = alpaca_sim(&w, 3, 50);
        let b = alpaca_sim(&w, 3, 50);
        assert_eq!(a.len(), 50);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.response, y.response);
            assert!(x.prompt.starts_with("instruction:"));
            assert!(x.prompt.ends_with("response:"));
            assert!(x.response.starts_with(' '));
        }
    }

    #[test]
    fn heldout_families_disjoint_from_seen() {
        for f in HELDOUT_FAMILIES {
            assert!(!SEEN_FAMILIES.contains(&f));
        }
        let w = world();
        for ins in ni_sim(&w, 1, 20) {
            assert!(HELDOUT_FAMILIES.contains(&ins.family));
        }
    }

    #[test]
    fn mc_items_have_unique_options_and_valid_correct() {
        let w = world();
        for task in csr_suite(&w, 5, 20).iter().chain(mmlu_sim(&w, 5, 20).iter()) {
            assert!(!task.items.is_empty());
            for item in &task.items {
                assert_eq!(item.options.len(), 4, "{}", task.name);
                assert!(item.correct < 4);
                let mut o = item.options.clone();
                o.sort();
                o.dedup();
                assert_eq!(o.len(), 4, "dup options in {}: {:?}", task.name, item);
            }
        }
    }

    #[test]
    fn answers_consistent_with_world() {
        let w = world();
        let tasks = mmlu_sim(&w, 9, 30);
        for item in &tasks[0].items {
            // colors domain: the correct option really is the entity's color.
            let ename = item
                .prompt
                .split("of the ")
                .nth(1)
                .unwrap()
                .split(' ')
                .next()
                .unwrap();
            let e = w.entities.iter().find(|e| e.name == ename).unwrap();
            assert_eq!(
                item.options[item.correct].trim(),
                w.attr(e, Domain::Color)
            );
        }
    }

    #[test]
    fn few_shot_prefix_contains_k_examples() {
        let w = world();
        let tasks = csr_suite(&w, 2, 16);
        let mut rng = Pcg32::new(4);
        let p = few_shot_prefix(&tasks[0], 5, &mut rng);
        assert_eq!(p.matches("people use the").count(), 5);
    }

    #[test]
    fn correct_position_not_biased() {
        let w = world();
        let tasks = mmlu_sim(&w, 11, 200);
        let mut counts = [0usize; 4];
        for t in &tasks {
            for i in &t.items {
                counts[i.correct] += 1;
            }
        }
        for c in counts {
            assert!(c > 120, "position bias: {counts:?}");
        }
    }
}
