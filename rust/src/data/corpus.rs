//! Synthetic corpora (DESIGN §Substitutions).
//!
//! Three text distributions play the roles of the paper's datasets:
//!
//! * `pretrain` — the general mixture the base models are pretrained on:
//!   template grammar text + the world's fact sentences (so the facts the
//!   MC suites query are *in* the base model).
//! * `wikitext_sim` — encyclopedic templates over a distinct word bank
//!   (the task-adaptation target standing in for Wikitext2).
//! * `ptb_sim` — newswire-flavored templates (standing in for PTB).
//!
//! Each generator is a seeded stochastic template grammar: deterministic,
//! license-free, and distributionally distinct (validated by test:
//! cross-corpus PPL > in-corpus PPL).

use crate::util::Pcg32;

use super::world::{Domain, World, NUMBERS, TOOLS};

/// Stochastic template grammar: pick a template, fill slots from banks.
struct Grammar {
    templates: &'static [&'static str],
    banks: &'static [(&'static str, &'static [&'static str])],
}

impl Grammar {
    fn sentence(&self, rng: &mut Pcg32, out: &mut String) {
        let t = rng.choose(self.templates);
        let mut rest = *t;
        while let Some(pos) = rest.find('<') {
            out.push_str(&rest[..pos]);
            let end = rest[pos..].find('>').expect("unclosed slot") + pos;
            let slot = &rest[pos + 1..end];
            let bank = self
                .banks
                .iter()
                .find(|(k, _)| *k == slot)
                .unwrap_or_else(|| panic!("unknown slot {slot}"))
                .1;
            let choice: &&str = rng.choose(bank);
            out.push_str(choice);
            rest = &rest[end + 1..];
        }
        out.push_str(rest);
    }

    fn generate(&self, rng: &mut Pcg32, target_len: usize) -> String {
        let mut out = String::with_capacity(target_len + 64);
        while out.len() < target_len {
            self.sentence(rng, &mut out);
        }
        out
    }
}

const PRETRAIN: Grammar = Grammar {
    templates: &[
        "the <adj> <noun> <verb> near the <noun>. ",
        "a <noun> can <act> when the <noun> is <adj>. ",
        "<name> said the <noun> was <adj>. ",
        "every <noun> <verb> before the <noun>. ",
        "the <noun> and the <noun> <verb> together. ",
        "if the <noun> is <adj> then the <noun> <verb>. ",
        "some <noun> <verb> while others <act>. ",
    ],
    banks: &[
        ("adj", &["quick", "calm", "bright", "heavy", "soft", "cold", "warm", "dark"]),
        ("noun", &["river", "forest", "tower", "garden", "market", "valley", "bridge", "meadow"]),
        ("verb", &["moves", "rests", "grows", "turns", "waits", "falls", "rises", "sings"]),
        ("act", &["run", "hide", "float", "gather", "wander", "sleep"]),
        ("name", &["mara", "odin", "pell", "sira", "tomas", "vela"]),
    ],
};

const WIKITEXT_SIM: Grammar = Grammar {
    templates: &[
        "the <realm> of <place> was founded in the year <year>. ",
        "<person> served as the <role> of <place> until <year>. ",
        "the <realm> expanded its <asset> across the <region>. ",
        "historians note that <person> reformed the <asset> in <year>. ",
        "the battle of <place> ended the <realm> in <year>. ",
        "<place> is known for its ancient <asset> and vast <region>. ",
    ],
    banks: &[
        ("realm", &["empire", "kingdom", "republic", "duchy", "league"]),
        ("place", &["arvon", "belmar", "cardem", "dolvia", "elstan", "farholt"]),
        ("person", &["queen lira", "king aldo", "duke haren", "lady mirel", "consul brin"]),
        ("role", &["ruler", "regent", "governor", "chancellor"]),
        ("asset", &["roads", "temples", "archives", "harbors", "mints"]),
        ("region", &["north", "south", "east", "west", "coast", "highlands"]),
        ("year", &["three", "seven", "twelve", "forty", "ninety"]),
    ],
};

const PTB_SIM: Grammar = Grammar {
    templates: &[
        "shares of <firm> <moved> <num> percent in <period> trading. ",
        "<firm> posted <num> million in <metric> for the <period>. ",
        "analysts expect <firm> to <plan> its <metric> next <period>. ",
        "the <metric> of <firm> <moved> after the <period> report. ",
        "<firm> agreed to <plan> a unit of <firm>. ",
    ],
    banks: &[
        ("firm", &["acme corp", "zenix inc", "norvel group", "talos ltd", "quill co"]),
        ("moved", &["rose", "fell", "gained", "slipped", "jumped"]),
        ("num", &["two", "five", "nine", "twelve", "thirty"]),
        ("metric", &["revenue", "earnings", "output", "margins", "sales"]),
        ("period", &["morning", "quarter", "year", "week"]),
        ("plan", &["expand", "sell", "merge", "spin off", "buy"]),
    ],
};

/// Fact sentences stating the world's attributes (mixed into pretraining).
pub fn fact_sentences(world: &World, rng: &mut Pcg32, n: usize) -> String {
    let mut out = String::new();
    for _ in 0..n {
        let e = rng.choose(&world.entities);
        match rng.below(7) {
            0 => out.push_str(&format!("the color of the {} is {}. ", e.name,
                world.attr(e, Domain::Color))),
            1 => out.push_str(&format!("the {} lives in the {}. ", e.name,
                world.attr(e, Domain::Place))),
            2 => out.push_str(&format!("the {} is a kind of {}. ", e.name,
                world.attr(e, Domain::Category))),
            3 => out.push_str(&format!("the {} is {} in size. ", e.name,
                world.attr(e, Domain::Size))),
            4 => out.push_str(&format!("the {} makes a {} sound. ", e.name,
                world.attr(e, Domain::Sound))),
            5 => {
                let (tool, act) = rng.choose(&TOOLS);
                out.push_str(&format!("people use the {tool} to {act}. "));
            }
            _ => {
                let a = rng.below(5) as usize;
                let b = rng.below(5) as usize;
                out.push_str(&format!("{} plus {} is {}. ",
                    NUMBERS[a], NUMBERS[b], NUMBERS[a + b]));
            }
        }
    }
    out
}

/// General pretraining mixture: grammar text + world facts, interleaved.
pub fn pretrain(world: &World, seed: u64, target_len: usize) -> String {
    let mut rng = Pcg32::seeded(seed, 0x90);
    let mut out = String::with_capacity(target_len + 256);
    while out.len() < target_len {
        PRETRAIN.sentence(&mut rng, &mut out);
        if rng.below(3) == 0 {
            out.push_str(&fact_sentences(world, &mut rng, 2));
        }
    }
    out
}

pub fn wikitext_sim(seed: u64, target_len: usize) -> String {
    WIKITEXT_SIM.generate(&mut Pcg32::seeded(seed, 0x11), target_len)
}

pub fn ptb_sim(seed: u64, target_len: usize) -> String {
    PTB_SIM.generate(&mut Pcg32::seeded(seed, 0x22), target_len)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generators_are_deterministic() {
        let w = World::new(1, 32);
        assert_eq!(pretrain(&w, 5, 2000), pretrain(&w, 5, 2000));
        assert_eq!(wikitext_sim(5, 2000), wikitext_sim(5, 2000));
        assert_ne!(wikitext_sim(5, 2000), wikitext_sim(6, 2000));
    }

    #[test]
    fn distributions_differ() {
        // Disjoint content-word banks → corpora share few words.
        let a = wikitext_sim(1, 4000);
        let b = ptb_sim(1, 4000);
        let set = |s: &str| {
            s.split_whitespace().map(|w| w.to_string()).collect::<std::collections::HashSet<_>>()
        };
        let (sa, sb) = (set(&a), set(&b));
        let inter = sa.intersection(&sb).count();
        assert!(inter * 3 < sa.len().min(sb.len()), "{inter} shared");
    }

    #[test]
    fn pretrain_contains_facts() {
        let w = World::new(1, 32);
        let text = pretrain(&w, 7, 60_000);
        assert!(text.contains("the color of the"));
        assert!(text.contains("plus"));
        assert!(text.contains("people use the"));
    }

    #[test]
    fn target_length_respected() {
        let w = World::new(1, 16);
        let t = pretrain(&w, 3, 10_000);
        assert!(t.len() >= 10_000 && t.len() < 11_000);
    }
}
