//! Data substrate: the synthetic knowledge world, corpus generators,
//! instruction / multiple-choice task suites, and token batching.
//!
//! Everything is seeded and deterministic; see DESIGN §Substitutions for
//! how each generator maps to the paper's datasets.

pub mod batch;
pub mod corpus;
pub mod tasks;
pub mod world;

pub use batch::{encode_stream, eval_batches, instruction_batches, Batch, LmBatcher};
pub use tasks::{alpaca_sim, csr_suite, mmlu_sim, ni_sim, Instruction, McItem, McTask};
pub use world::{Domain, World};
