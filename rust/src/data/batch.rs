//! Token batching: corpora → (B, T) i32 batches for the train/eval
//! artifacts, and instruction examples → masked batches (loss only on
//! response tokens, appendix-H style).

use crate::tokenizer::{Tokenizer, BOS, EOS, PAD};
use crate::util::Pcg32;

use super::tasks::Instruction;

/// A (B, T) token batch plus the (B, T−1) loss mask the artifacts expect.
#[derive(Clone, Debug)]
pub struct Batch {
    pub tokens: Vec<i32>, // row-major (B, T)
    pub mask: Vec<f32>,   // row-major (B, T−1)
    pub batch: usize,
    pub seq: usize,
}

impl Batch {
    pub fn n_loss_tokens(&self) -> f32 {
        self.mask.iter().sum()
    }
}

/// Random-window language-model batcher over a contiguous token stream
/// (training); windows are seeded so runs are reproducible.
pub struct LmBatcher {
    stream: Vec<u32>,
    batch: usize,
    seq: usize,
    rng: Pcg32,
}

impl LmBatcher {
    pub fn new(stream: Vec<u32>, batch: usize, seq: usize, seed: u64) -> Self {
        assert!(stream.len() > seq + 1, "stream too short: {}", stream.len());
        LmBatcher { stream, batch, seq, rng: Pcg32::seeded(seed, 0xba7c4) }
    }

    /// The window RNG's exact `(state, inc)` position — the journal's
    /// data-stream cursor: restoring it via [`Self::set_rng_state`]
    /// makes the batch sequence continue bit-for-bit.
    pub fn rng_state(&self) -> (u64, u64) {
        self.rng.state_raw()
    }

    /// Jump the window RNG to a position captured by [`Self::rng_state`].
    pub fn set_rng_state(&mut self, state: u64, inc: u64) {
        self.rng = Pcg32::from_state(state, inc);
    }

    pub fn next_batch(&mut self) -> Batch {
        let mut tokens = Vec::with_capacity(self.batch * self.seq);
        for _ in 0..self.batch {
            let start = self.rng.usize_below(self.stream.len() - self.seq);
            tokens.extend(self.stream[start..start + self.seq].iter().map(|&t| t as i32));
        }
        Batch {
            tokens,
            mask: vec![1.0; self.batch * (self.seq - 1)],
            batch: self.batch,
            seq: self.seq,
        }
    }
}

/// Deterministic non-overlapping eval windows (perplexity measurement).
/// Returns ⌈len/(B·T)⌉ batches; the final partial batch is mask-padded so
/// every token is counted exactly once.
pub fn eval_batches(stream: &[u32], batch: usize, seq: usize) -> Vec<Batch> {
    let mut out = Vec::new();
    let mut pos = 0usize;
    while pos + 1 < stream.len() {
        let mut tokens = vec![PAD as i32; batch * seq];
        let mut mask = vec![0.0f32; batch * (seq - 1)];
        for b in 0..batch {
            if pos + 1 >= stream.len() {
                break;
            }
            let take = (stream.len() - pos).min(seq);
            for j in 0..take {
                tokens[b * seq + j] = stream[pos + j] as i32;
            }
            for j in 0..take.saturating_sub(1) {
                mask[b * (seq - 1) + j] = 1.0;
            }
            // Windows overlap by 1 token so every next-token prediction in
            // the stream is scored exactly once.
            pos += take - 1;
            if take < seq {
                pos = stream.len();
            }
        }
        if mask.iter().all(|&m| m == 0.0) {
            break;
        }
        out.push(Batch { tokens, mask, batch, seq });
    }
    out
}

/// Instruction examples → batches: BOS + prompt + response + EOS, padded
/// to T; loss mask covers only response tokens (and the EOS).
pub fn instruction_batches(
    tok: &Tokenizer,
    data: &[Instruction],
    batch: usize,
    seq: usize,
) -> Vec<Batch> {
    let mut out = Vec::new();
    for group in data.chunks(batch) {
        let mut tokens = vec![PAD as i32; batch * seq];
        let mut mask = vec![0.0f32; batch * (seq - 1)];
        for (b, ins) in group.iter().enumerate() {
            let p = tok.encode(&ins.prompt);
            let r = tok.encode(&ins.response);
            let mut ids = vec![BOS];
            ids.extend(&p);
            let resp_start = ids.len(); // first response position
            ids.extend(&r);
            ids.push(EOS);
            ids.truncate(seq);
            for (j, &id) in ids.iter().enumerate() {
                tokens[b * seq + j] = id as i32;
            }
            // Predicting token j+1 from position j: response tokens sit at
            // positions resp_start..; their predictors are resp_start-1..
            for j in resp_start.saturating_sub(1)..ids.len().saturating_sub(1) {
                mask[b * (seq - 1) + j] = 1.0;
            }
        }
        out.push(Batch { tokens, mask, batch, seq });
    }
    out
}

/// Encode a corpus with BOS separators at document-ish boundaries.
pub fn encode_stream(tok: &Tokenizer, text: &str) -> Vec<u32> {
    let mut stream = vec![BOS];
    stream.extend(tok.encode(text));
    stream.push(EOS);
    stream
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::world::World;

    fn tok() -> Tokenizer {
        Tokenizer::byte_level(512)
    }

    #[test]
    fn lm_batcher_shapes_and_determinism() {
        let stream: Vec<u32> = (0..500u32).map(|i| i % 200).collect();
        let mut b1 = LmBatcher::new(stream.clone(), 4, 32, 9);
        let mut b2 = LmBatcher::new(stream, 4, 32, 9);
        let x = b1.next_batch();
        let y = b2.next_batch();
        assert_eq!(x.tokens, y.tokens);
        assert_eq!(x.tokens.len(), 4 * 32);
        assert_eq!(x.mask.len(), 4 * 31);
        assert_eq!(x.n_loss_tokens(), 124.0);
    }

    #[test]
    fn eval_batches_cover_stream_exactly_once() {
        let stream: Vec<u32> = (0..1000u32).map(|i| i % 100).collect();
        let batches = eval_batches(&stream, 4, 64);
        let scored: f32 = batches.iter().map(|b| b.n_loss_tokens()).sum();
        // Every next-token transition scored once: len-1 predictions.
        assert_eq!(scored as usize, stream.len() - 1);
    }

    #[test]
    fn eval_batches_small_tail() {
        let stream: Vec<u32> = (0..70u32).collect();
        let batches = eval_batches(&stream, 2, 64);
        let scored: f32 = batches.iter().map(|b| b.n_loss_tokens()).sum();
        assert_eq!(scored as usize, 69);
    }

    #[test]
    fn instruction_mask_covers_response_only() {
        let t = tok();
        let w = World::new(1, 16);
        let data = crate::data::tasks::alpaca_sim(&w, 1, 3);
        let batches = instruction_batches(&t, &data, 4, 96);
        assert_eq!(batches.len(), 1);
        let b = &batches[0];
        let ins = &data[0];
        let p_len = t.encode(&ins.prompt).len();
        let r_len = t.encode(&ins.response).len();
        // Mask length == response tokens + EOS.
        let row_mask: f32 = b.mask[0..95].iter().sum();
        assert_eq!(row_mask as usize, r_len + 1);
        // Mask starts exactly at the last prompt position (predicting the
        // first response token).
        assert_eq!(b.mask[p_len], 1.0);
        assert_eq!(b.mask[p_len - 1], 0.0);
    }

    #[test]
    fn truncation_safe() {
        let t = tok();
        let w = World::new(1, 16);
        let data = crate::data::tasks::alpaca_sim(&w, 1, 2);
        let batches = instruction_batches(&t, &data, 2, 16); // tiny seq
        assert_eq!(batches[0].tokens.len(), 2 * 16);
    }
}
