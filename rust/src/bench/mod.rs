//! Benchmark framework: wall-clock measurement, paper-style table
//! rendering, and JSON result dumps (no criterion in the vendored
//! registry — `cargo bench` targets use this with `harness = false`).

use std::time::Instant;

use crate::json::Value;
use crate::util::stats;

/// Repeated-measurement timing.
#[derive(Clone, Debug)]
pub struct Timing {
    pub label: String,
    pub samples_s: Vec<f64>,
}

impl Timing {
    pub fn mean_s(&self) -> f64 {
        stats::mean(&self.samples_s)
    }

    pub fn std_s(&self) -> f64 {
        stats::std(&self.samples_s)
    }

    pub fn p50_s(&self) -> f64 {
        stats::percentile(&self.samples_s, 50.0)
    }

    pub fn min_s(&self) -> f64 {
        self.samples_s.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    pub fn summary(&self) -> String {
        format!(
            "{:32} mean {:>9.3} ms  p50 {:>9.3} ms  min {:>9.3} ms  (n={})",
            self.label,
            self.mean_s() * 1e3,
            self.p50_s() * 1e3,
            self.min_s() * 1e3,
            self.samples_s.len()
        )
    }
}

/// Time `f` `iters` times after `warmup` unmeasured calls.
pub fn time_fn(label: &str, warmup: usize, iters: usize, mut f: impl FnMut()) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    Timing { label: label.to_string(), samples_s: samples }
}

/// Paper-style table: fixed-width text rendering + CSV/JSON export.
#[derive(Clone, Debug)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn rowv(&mut self, cells: Vec<String>) {
        self.row(&cells.iter().cloned().collect::<Vec<_>>());
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    pub fn render(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        out.push_str(&format!("\n== {} ==\n", self.title));
        let line = |cells: &[String], w: &[usize]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>width$}", c, width = w[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&line(&self.headers, &w));
        out.push('\n');
        out.push_str(&"-".repeat(w.iter().sum::<usize>() + 2 * (w.len() - 1)));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&line(r, &w));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }

    pub fn to_csv(&self) -> String {
        let mut out = self.headers.join(",");
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.join(","));
            out.push('\n');
        }
        out
    }

    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("title", Value::str(self.title.clone())),
            (
                "headers",
                Value::Arr(self.headers.iter().map(|h| Value::str(h.clone())).collect()),
            ),
            (
                "rows",
                Value::Arr(
                    self.rows
                        .iter()
                        .map(|r| Value::Arr(r.iter().map(|c| Value::str(c.clone())).collect()))
                        .collect(),
                ),
            ),
        ])
    }

    /// Write table to `results/<name>.{txt,csv,json}`.
    pub fn save(&self, results_dir: &std::path::Path, name: &str) -> anyhow::Result<()> {
        std::fs::create_dir_all(results_dir)?;
        std::fs::write(results_dir.join(format!("{name}.txt")), self.render())?;
        std::fs::write(results_dir.join(format!("{name}.csv")), self.to_csv())?;
        std::fs::write(results_dir.join(format!("{name}.json")), self.to_json().to_string())?;
        Ok(())
    }
}

/// Write a JSON value to an explicit path (bench result files like
/// BENCH_kernels.json that live at the repo root rather than results/).
/// Atomic (temp sibling + rename via [`crate::store::atomic_write`]):
/// a killed bench never leaves a half-written JSON for the trend diff
/// to choke on.
pub fn save_json(path: &std::path::Path, v: &Value) -> anyhow::Result<()> {
    crate::store::atomic_write(path, v.to_string().as_bytes())?;
    Ok(())
}

/// `PEQA_BENCH_QUICK=1` shrinks bench workloads (CI-speed smoke runs).
pub fn quick_mode() -> bool {
    std::env::var("PEQA_BENCH_QUICK").as_deref() == Ok("1")
}

/// Scale a step count down in quick mode; `PEQA_BENCH_STEPS` overrides the
/// full budget (the 1-core testbed runs the suite at 60; see EXPERIMENTS).
pub fn steps(full: usize) -> usize {
    let full = std::env::var("PEQA_BENCH_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(full);
    if quick_mode() { (full / 10).max(5) } else { full }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_stats() {
        let t = time_fn("noop", 1, 16, || { std::hint::black_box(1 + 1); });
        assert_eq!(t.samples_s.len(), 16);
        assert!(t.mean_s() >= 0.0 && t.min_s() <= t.mean_s());
    }

    #[test]
    fn table_renders_and_exports() {
        let mut t = Table::new("Table 1: demo", &["Method", "PPL"]);
        t.row(&["PEQA".to_string(), "5.84".to_string()]);
        t.row(&["LoRA+OPTQ".to_string(), "7.13".to_string()]);
        let text = t.render();
        assert!(text.contains("Table 1: demo"));
        assert!(text.contains("PEQA"));
        let csv = t.to_csv();
        assert_eq!(csv.lines().count(), 3);
        let json = t.to_json().to_string();
        assert!(json.contains("5.84"));
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["only-one".to_string()]);
    }
}
