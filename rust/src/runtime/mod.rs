//! Runtime layer, split in two:
//!
//! * [`meta`] — the typed view of `artifacts/<name>.meta.json` (model
//!   geometry, method config, ordered parameter layouts). Pure host code,
//!   always compiled: checkpoints, the quant toolchain and the memory
//!   model all consume it.
//! * [`pjrt`] (feature `xla`) — the PJRT client, artifact compilation and
//!   execution, literal/buffer conversions. Needs the vendored `xla`
//!   crate (xla_extension 0.5.1 bindings); see rust/Cargo.toml for how to
//!   enable it.

pub mod meta;

pub use meta::{ArtifactMeta, IoSpec, MethodMeta, ModelMeta, ParamMeta};

#[cfg(feature = "xla")]
pub mod pjrt;

#[cfg(feature = "xla")]
pub use pjrt::{
    literal_to_f32, literal_to_tensor, scalar_literal, tensor_to_literal, tokens_to_literal,
    Artifact, Runtime,
};
