//! Artifact metadata: the typed view of `artifacts/<name>.meta.json`.
//!
//! meta.json is produced by `python/compile/aot.py` and is the single
//! source of truth for model geometry, method configuration and — most
//! importantly — the *ordered* parameter layout of each artifact's flat
//! HLO argument list.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::json::Value;

#[derive(Clone, Debug, PartialEq)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String, // "f32" | "i32"
}

impl IoSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct ParamMeta {
    pub name: String,
    pub shape: Vec<usize>,
    pub trainable: bool,
    pub init: String,
}

impl ParamMeta {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub family: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub seq_len: usize,
    pub n_params: usize,
}

#[derive(Clone, Debug)]
pub struct MethodMeta {
    pub kind: String,
    pub bits: u8,
    pub group: Option<usize>,
    pub tag: String,
    pub train_scales: bool,
    pub train_zeros: bool,
    pub rank: usize,
    pub lora_targets: Vec<String>,
    pub lora_alpha: f64,
}

#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub kind: String, // train | eval | logits | logits_q | hess | prep | kernel
    pub size: Option<String>,
    pub display: Option<String>,
    pub batch: usize,
    pub model: Option<ModelMeta>,
    pub method: Option<MethodMeta>,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
    /// Full canonical param table (eval/logits/prep kinds).
    pub params: Vec<ParamMeta>,
    /// Split tables for train kind (trainable first in the flat layout).
    pub params_trainable: Vec<ParamMeta>,
    pub params_frozen: Vec<ParamMeta>,
}

fn io_list(v: &Value, key: &str) -> Result<Vec<IoSpec>> {
    let mut out = Vec::new();
    for item in v.arr_of(key)? {
        out.push(IoSpec {
            name: item.str_of("name")?.to_string(),
            shape: item
                .arr_of("shape")?
                .iter()
                .map(|x| x.as_usize().context("shape element"))
                .collect::<Result<_>>()?,
            dtype: item.str_of("dtype").unwrap_or("f32").to_string(),
        });
    }
    Ok(out)
}

fn param_list(v: &Value, key: &str) -> Result<Vec<ParamMeta>> {
    let Some(arr) = v.get(key).and_then(|x| x.as_arr()) else {
        return Ok(vec![]);
    };
    let mut out = Vec::new();
    for item in arr {
        out.push(ParamMeta {
            name: item.str_of("name")?.to_string(),
            shape: item
                .arr_of("shape")?
                .iter()
                .map(|x| x.as_usize().context("shape element"))
                .collect::<Result<_>>()?,
            trainable: item.bool_of("trainable")?,
            init: item.str_of("init")?.to_string(),
        });
    }
    Ok(out)
}

impl ArtifactMeta {
    pub fn parse(text: &str) -> Result<ArtifactMeta> {
        let v = Value::parse(text)?;
        let model = match v.get("model") {
            Some(m) if *m != Value::Null => Some(ModelMeta {
                family: m.str_of("family")?.to_string(),
                vocab: m.usize_of("vocab")?,
                d_model: m.usize_of("d_model")?,
                n_layers: m.usize_of("n_layers")?,
                n_heads: m.usize_of("n_heads")?,
                d_ff: m.usize_of("d_ff")?,
                seq_len: m.usize_of("seq_len")?,
                n_params: m.usize_of("n_params")?,
            }),
            _ => None,
        };
        let method = match v.get("method") {
            Some(m) if *m != Value::Null => Some(MethodMeta {
                kind: m.str_of("kind")?.to_string(),
                bits: m.usize_of("bits")? as u8,
                group: match m.req("group")? {
                    Value::Null => None,
                    g => Some(g.as_usize().context("group")?),
                },
                tag: m.str_of("tag")?.to_string(),
                train_scales: m.bool_of("train_scales")?,
                train_zeros: m.bool_of("train_zeros")?,
                rank: m.usize_of("rank")?,
                lora_targets: m
                    .arr_of("lora_targets")?
                    .iter()
                    .map(|x| x.as_str().unwrap_or_default().to_string())
                    .collect(),
                lora_alpha: m.f64_of("lora_alpha")?,
            }),
            _ => None,
        };
        let meta = ArtifactMeta {
            name: v.str_of("name")?.to_string(),
            kind: v.str_of("kind")?.to_string(),
            size: v.get("size").and_then(|x| x.as_str()).map(String::from),
            display: v.get("display").and_then(|x| x.as_str()).map(String::from),
            batch: v.usize_of("batch")?,
            model,
            method,
            inputs: io_list(&v, "inputs")?,
            outputs: io_list(&v, "outputs")?,
            params: param_list(&v, "params")?,
            params_trainable: param_list(&v, "params_trainable")?,
            params_frozen: param_list(&v, "params_frozen")?,
        };
        meta.validate()?;
        Ok(meta)
    }

    pub fn load(path: &Path) -> Result<ArtifactMeta> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text).with_context(|| format!("parsing {}", path.display()))
    }

    fn validate(&self) -> Result<()> {
        if self.inputs.is_empty() || self.outputs.is_empty() {
            bail!("artifact {} has empty io signature", self.name);
        }
        if self.kind == "train" {
            if self.params_trainable.is_empty() {
                bail!("train artifact {} without trainable params", self.name);
            }
            // inputs = tokens, mask, lr, step + trainable + frozen + m + v
            let expect = 4 + 3 * self.params_trainable.len() + self.params_frozen.len();
            if self.inputs.len() != expect {
                bail!(
                    "train artifact {}: {} inputs, expected {expect}",
                    self.name,
                    self.inputs.len()
                );
            }
            let expect_out = 1 + 3 * self.params_trainable.len();
            if self.outputs.len() != expect_out {
                bail!("train artifact {}: bad output count", self.name);
            }
        }
        Ok(())
    }

    /// The artifact's full param table in flat-argument order.
    pub fn layout(&self) -> Vec<&ParamMeta> {
        if self.kind == "train" {
            self.params_trainable.iter().chain(self.params_frozen.iter()).collect()
        } else {
            self.params.iter().collect()
        }
    }

    /// Index of the first parameter tensor in `inputs`.
    pub fn first_param_input(&self) -> usize {
        match self.kind.as_str() {
            "train" => 4,                      // tokens, mask, lr, step
            "eval" => 2,                       // tokens, mask
            "logits" | "logits_q" | "hess" => 1, // tokens
            _ => 0,                            // prep, kernel: params only
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = r#"{
      "name": "t_train", "kind": "train", "size": "n1", "batch": 8,
      "display": "X-sim",
      "model": {"family":"llama","vocab":512,"d_model":64,"n_layers":2,
                "n_heads":4,"d_ff":192,"seq_len":64,"n_params":100},
      "method": {"kind":"peqa","bits":4,"group":null,"tag":"peqa_b4_gc",
                 "train_scales":true,"train_zeros":false,"rank":4,
                 "lora_targets":["attn.q"],"lora_alpha":8.0},
      "inputs": [
        {"name":"tokens","shape":[8,64],"dtype":"i32"},
        {"name":"mask","shape":[8,63],"dtype":"f32"},
        {"name":"lr","shape":[],"dtype":"f32"},
        {"name":"step","shape":[],"dtype":"f32"},
        {"name":"a.s","shape":[4,1],"dtype":"f32"},
        {"name":"a.wq","shape":[4,8],"dtype":"f32"},
        {"name":"m.a.s","shape":[4,1],"dtype":"f32"},
        {"name":"v.a.s","shape":[4,1],"dtype":"f32"}
      ],
      "outputs": [
        {"name":"loss","shape":[],"dtype":"f32"},
        {"name":"a.s","shape":[4,1],"dtype":"f32"},
        {"name":"m.a.s","shape":[4,1],"dtype":"f32"},
        {"name":"v.a.s","shape":[4,1],"dtype":"f32"}
      ],
      "params_trainable": [{"name":"a.s","shape":[4,1],"trainable":true,"init":"ones"}],
      "params_frozen": [{"name":"a.wq","shape":[4,8],"trainable":false,"init":"zeros"}]
    }"#;

    #[test]
    fn parses_train_meta() {
        let m = ArtifactMeta::parse(DOC).unwrap();
        assert_eq!(m.kind, "train");
        assert_eq!(m.model.as_ref().unwrap().d_model, 64);
        let meth = m.method.as_ref().unwrap();
        assert_eq!(meth.bits, 4);
        assert_eq!(meth.group, None);
        assert!(meth.train_scales && !meth.train_zeros);
        assert_eq!(m.layout().len(), 2);
        assert_eq!(m.layout()[0].name, "a.s");
        assert_eq!(m.first_param_input(), 4);
        assert_eq!(m.inputs[0].numel(), 512);
    }

    #[test]
    fn validation_catches_bad_counts() {
        let bad = DOC.replace(
            r#"{"name":"v.a.s","shape":[4,1],"dtype":"f32"}
      ],
      "outputs""#,
            r#"{"name":"v.a.s","shape":[4,1],"dtype":"f32"},
        {"name":"extra","shape":[1],"dtype":"f32"}
      ],
      "outputs""#,
        );
        assert!(ArtifactMeta::parse(&bad).is_err());
    }
}
