//! PJRT execution half of the runtime (xla feature): load AOT'd HLO-text
//! artifacts, compile once, execute from the rust hot path. Adapted from
//! /opt/xla-example/load_hlo (see DESIGN.md): HLO *text* is the
//! interchange format because jax >= 0.5 serialized protos are rejected by
//! xla_extension 0.5.1.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{anyhow, bail, Context, Result};

use super::meta::ArtifactMeta;
use crate::tensor::Tensor;

/// A compiled artifact: metadata + PJRT executable.
pub struct Artifact {
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

impl Artifact {
    /// Execute with host literals; returns the decomposed output tuple.
    /// Counts (and 2-D-ness of shapes) are validated against the meta.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        if inputs.len() != self.meta.inputs.len() {
            bail!(
                "artifact {}: {} inputs given, signature has {}",
                self.meta.name,
                inputs.len(),
                self.meta.inputs.len()
            );
        }
        let result = self.exe.execute::<xla::Literal>(inputs)?;
        self.collect(result)
    }

    /// Execute with device-resident buffers (frozen params stay uploaded
    /// across steps — the trainer's fast path).
    pub fn run_b(&self, inputs: &[&xla::PjRtBuffer]) -> Result<Vec<xla::Literal>> {
        if inputs.len() != self.meta.inputs.len() {
            bail!(
                "artifact {}: {} buffer inputs given, signature has {}",
                self.meta.name,
                inputs.len(),
                self.meta.inputs.len()
            );
        }
        let result = self.exe.execute_b::<&xla::PjRtBuffer>(inputs)?;
        self.collect(result)
    }

    fn collect(&self, result: Vec<Vec<xla::PjRtBuffer>>) -> Result<Vec<xla::Literal>> {
        // Single replica; aot.py lowers with return_tuple=True so the one
        // output buffer is a tuple literal we decompose.
        let buf = result
            .first()
            .and_then(|r| r.first())
            .ok_or_else(|| anyhow!("no output buffers from {}", self.meta.name))?;
        let lit = buf.to_literal_sync()?;
        let outs = lit.to_tuple()?;
        if outs.len() != self.meta.outputs.len() {
            bail!(
                "artifact {}: {} outputs, signature has {}",
                self.meta.name,
                outs.len(),
                self.meta.outputs.len()
            );
        }
        Ok(outs)
    }
}

/// The PJRT CPU client plus a compile cache over the artifacts directory.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: std::cell::RefCell<HashMap<String, Rc<Artifact>>>,
}

impl Runtime {
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        if !dir.is_dir() {
            bail!(
                "artifacts directory {} not found — run `make artifacts` first",
                dir.display()
            );
        }
        xla::set_tf_min_log_level(xla::TfLogLevel::Error);
        Ok(Runtime {
            client: xla::PjRtClient::cpu()?,
            dir,
            cache: Default::default(),
        })
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.dir
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// Names of all artifacts present on disk.
    pub fn list(&self) -> Result<Vec<String>> {
        let mut names = Vec::new();
        for entry in std::fs::read_dir(&self.dir)? {
            let p = entry?.path();
            if let Some(n) = p.file_name().and_then(|s| s.to_str()) {
                if let Some(stem) = n.strip_suffix(".meta.json") {
                    names.push(stem.to_string());
                }
            }
        }
        names.sort();
        Ok(names)
    }

    /// Load metadata only (cheap; no compilation).
    pub fn meta(&self, name: &str) -> Result<ArtifactMeta> {
        ArtifactMeta::load(&self.dir.join(format!("{name}.meta.json")))
    }

    /// Load + compile an artifact (cached).
    pub fn load(&self, name: &str) -> Result<Rc<Artifact>> {
        if let Some(a) = self.cache.borrow().get(name) {
            return Ok(a.clone());
        }
        let meta = self.meta(name)?;
        let hlo_path = self.dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(
            hlo_path
                .to_str()
                .ok_or_else(|| anyhow!("non-utf8 path {}", hlo_path.display()))?,
        )
        .with_context(|| format!("loading {}", hlo_path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact {name}"))?;
        let art = Rc::new(Artifact { meta, exe });
        self.cache.borrow_mut().insert(name.to_string(), art.clone());
        Ok(art)
    }

    /// Upload f32 data to the device.
    ///
    /// NOTE: always goes through `buffer_from_host_buffer`, whose
    /// `kImmutableOnlyDuringCall` semantics copy the host data before
    /// returning. The literal-based upload in the xla crate is *async*
    /// without exposing the ready-future — dropping the literal before
    /// execution is a use-after-free (segfault), so we never use it.
    pub fn to_device_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    pub fn to_device_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    pub fn tensor_to_device(&self, t: &Tensor) -> Result<xla::PjRtBuffer> {
        self.to_device_f32(t.data(), t.shape())
    }

    pub fn scalar_to_device(&self, x: f32) -> Result<xla::PjRtBuffer> {
        self.to_device_f32(&[x], &[])
    }
}

// ---------------------------------------------------------------------------
// Literal <-> host conversions
// ---------------------------------------------------------------------------

/// f32 tensor → literal with the tensor's shape.
pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(t.data());
    let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
    Ok(lit.reshape(&dims)?)
}

/// i32 token batch → literal of the given shape.
pub fn tokens_to_literal(tokens: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    assert_eq!(tokens.len(), shape.iter().product::<usize>());
    let lit = xla::Literal::vec1(tokens);
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(lit.reshape(&dims)?)
}

pub fn scalar_literal(x: f32) -> xla::Literal {
    xla::Literal::scalar(x)
}

/// literal → f32 tensor using the expected shape from the signature.
pub fn literal_to_tensor(lit: &xla::Literal, shape: &[usize]) -> Result<Tensor> {
    let v = lit.to_vec::<f32>()?;
    if v.len() != shape.iter().product::<usize>() {
        bail!("literal has {} elements, shape {:?} expects {}", v.len(), shape,
              shape.iter().product::<usize>());
    }
    Ok(Tensor::new(shape, v))
}

pub fn literal_to_f32(lit: &xla::Literal) -> Result<f32> {
    let v = lit.to_vec::<f32>()?;
    v.first().copied().ok_or_else(|| anyhow!("empty literal"))
}
