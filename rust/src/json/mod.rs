//! Minimal JSON: parser + writer over an owned `Value` tree.
//!
//! Built from scratch (no serde in the vendored registry). Scope: exactly
//! what artifact meta.json, checkpoints headers and results dumps need —
//! objects, arrays, strings (with \u escapes), numbers, bools, null.

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{anyhow, bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn parse(text: &str) -> Result<Value> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing bytes at offset {}", p.i);
        }
        Ok(v)
    }

    // -- typed accessors -----------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> Result<&Value> {
        self.get(key).ok_or_else(|| anyhow!("missing key '{key}'"))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn str_of(&self, key: &str) -> Result<&str> {
        self.req(key)?.as_str().ok_or_else(|| anyhow!("'{key}' not a string"))
    }

    pub fn usize_of(&self, key: &str) -> Result<usize> {
        self.req(key)?.as_usize().ok_or_else(|| anyhow!("'{key}' not a number"))
    }

    pub fn f64_of(&self, key: &str) -> Result<f64> {
        self.req(key)?.as_f64().ok_or_else(|| anyhow!("'{key}' not a number"))
    }

    pub fn bool_of(&self, key: &str) -> Result<bool> {
        self.req(key)?.as_bool().ok_or_else(|| anyhow!("'{key}' not a bool"))
    }

    pub fn arr_of(&self, key: &str) -> Result<&[Value]> {
        self.req(key)?.as_arr().ok_or_else(|| anyhow!("'{key}' not an array"))
    }

    /// Builder helpers for results dumps.
    pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
        Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Value {
        Value::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Value {
        Value::Str(s.into())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of JSON"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at offset {}, found '{}'", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at offset {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.lit("true", Value::Bool(true)),
            b'f' => self.lit("false", Value::Bool(false)),
            b'n' => self.lit("null", Value::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            m.insert(k, self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Value::Obj(m));
                }
                c => bail!("expected ',' or '}}', found '{}'", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Value::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Value::Arr(v));
                }
                c => bail!("expected ',' or ']', found '{}'", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("bad \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape '\\{}'", e as char),
                    }
                }
                _ => {
                    // Collect the full UTF-8 sequence starting at c.
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    self.i = start + len;
                    s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Value::Num(s.parse::<f64>().map_err(|_| anyhow!("bad number '{s}'"))?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Null => write!(f, "null"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Value::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Value::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Value::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{v}", Value::Str(k.clone()))?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_meta_like_document() {
        let doc = r#"{
          "name": "n1_train_peqa_b4_gc", "batch": 8,
          "model": {"d_model": 64, "n_layers": 2},
          "inputs": [{"name": "tokens", "shape": [8, 64], "dtype": "i32"}],
          "trainable": true, "group": null, "lr": 5e-5
        }"#;
        let v = Value::parse(doc).unwrap();
        assert_eq!(v.str_of("name").unwrap(), "n1_train_peqa_b4_gc");
        assert_eq!(v.usize_of("batch").unwrap(), 8);
        assert_eq!(v.req("model").unwrap().usize_of("d_model").unwrap(), 64);
        let ins = v.arr_of("inputs").unwrap();
        assert_eq!(ins[0].str_of("dtype").unwrap(), "i32");
        assert_eq!(
            ins[0].arr_of("shape").unwrap().iter().map(|x| x.as_usize().unwrap()).collect::<Vec<_>>(),
            vec![8, 64]
        );
        assert!(v.bool_of("trainable").unwrap());
        assert_eq!(v.req("group").unwrap(), &Value::Null);
        assert!((v.f64_of("lr").unwrap() - 5e-5).abs() < 1e-12);
    }

    #[test]
    fn roundtrip() {
        let doc = r#"{"a":[1,2.5,"x\ny",true,null],"b":{"c":-3}}"#;
        let v = Value::parse(doc).unwrap();
        let out = v.to_string();
        assert_eq!(Value::parse(&out).unwrap(), v);
    }

    #[test]
    fn escapes_and_unicode() {
        let v = Value::parse(r#""café \t \\""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "café \t \\");
        let v = Value::parse("\"héllo→\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo→");
    }

    #[test]
    fn errors() {
        assert!(Value::parse("{").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("12 34").is_err());
        assert!(Value::parse("{'a':1}").is_err());
    }
}
