//! Runtime configuration: paths, seeds, and the training recipes
//! (appendix A–C analog: per-method learning rates, steps, schedules).
//!
//! Model *architecture* is never configured here — it always comes from
//! the artifact's meta.json (single source of truth in python/compile).

use std::path::PathBuf;

/// Where artifacts / checkpoints / results live. Overridable by env vars
/// (PEQA_ARTIFACTS, PEQA_CHECKPOINTS, PEQA_RESULTS) and CLI flags.
#[derive(Clone, Debug)]
pub struct Paths {
    pub artifacts: PathBuf,
    pub checkpoints: PathBuf,
    pub results: PathBuf,
}

impl Default for Paths {
    fn default() -> Self {
        let root = repo_root();
        Paths {
            artifacts: env_path("PEQA_ARTIFACTS", root.join("artifacts")),
            checkpoints: env_path("PEQA_CHECKPOINTS", root.join("checkpoints")),
            results: env_path("PEQA_RESULTS", root.join("results")),
        }
    }
}

fn env_path(var: &str, default: PathBuf) -> PathBuf {
    std::env::var_os(var).map(PathBuf::from).unwrap_or(default)
}

/// Locate the repo root: walk up from cwd preferring `.git`/`artifacts/`
/// markers, falling back to the *topmost* `Cargo.toml` so binaries work
/// from any subdirectory. Cargo runs test/bench executables with cwd =
/// the package root (rust/), which has its own manifest — stopping at the
/// *first* Cargo.toml would strand artifacts/results/BENCH_kernels.json
/// under rust/ instead of the repo root.
pub fn repo_root() -> PathBuf {
    let mut dir = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    let mut topmost_manifest: Option<PathBuf> = None;
    loop {
        if dir.join(".git").exists() || dir.join("artifacts").is_dir() {
            return dir;
        }
        if dir.join("Cargo.toml").exists() {
            topmost_manifest = Some(dir.clone());
        }
        if !dir.pop() {
            return topmost_manifest.unwrap_or_else(|| PathBuf::from("."));
        }
    }
}

/// One fine-tuning run recipe (appendix A: AdamW + linear decay).
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub steps: usize,
    pub lr: f64,
    /// Linear decay to `lr_final_frac`·lr over the run (paper: decay to 0).
    pub lr_final_frac: f64,
    pub warmup_steps: usize,
    pub seed: u64,
    /// Evaluate every `eval_every` steps (0 = only at the end).
    pub eval_every: usize,
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            steps: 200,
            lr: 1e-3,
            lr_final_frac: 0.0,
            warmup_steps: 10,
            seed: 42,
            eval_every: 0,
            log_every: 25,
        }
    }
}

impl TrainConfig {
    /// Default learning rate per method kind, mirroring the appendix-C
    /// pattern: full/QAT smallest, PEQA small, LoRA largest.
    pub fn default_lr(method_kind: &str) -> f64 {
        match method_kind {
            "full" => 3e-4,
            "qat" => 3e-4,
            "lora" => 2e-2,
            "peqa" => 2e-3,
            "alpha" => 2e-3,
            _ => 1e-3,
        }
    }

    /// Learning rate at `step` (1-based): linear warmup then linear decay.
    pub fn lr_at(&self, step: usize) -> f64 {
        let s = step as f64;
        if step <= self.warmup_steps && self.warmup_steps > 0 {
            return self.lr * s / self.warmup_steps as f64;
        }
        let total = self.steps.max(1) as f64;
        let frac = ((total - s) / (total - self.warmup_steps as f64).max(1.0)).clamp(0.0, 1.0);
        self.lr * (self.lr_final_frac + (1.0 - self.lr_final_frac) * frac)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lr_schedule_shape() {
        let c = TrainConfig { steps: 100, lr: 1.0, warmup_steps: 10, ..Default::default() };
        assert!(c.lr_at(1) < 0.2);
        assert!((c.lr_at(10) - 1.0).abs() < 1e-9);
        assert!(c.lr_at(55) < c.lr_at(11));
        assert!(c.lr_at(100) < 0.01);
        // monotone decay after warmup
        for s in 11..100 {
            assert!(c.lr_at(s + 1) <= c.lr_at(s) + 1e-12);
        }
    }

    #[test]
    fn method_lrs_ordered() {
        assert!(TrainConfig::default_lr("lora") > TrainConfig::default_lr("peqa"));
        assert!(TrainConfig::default_lr("peqa") > TrainConfig::default_lr("full"));
    }

    #[test]
    fn paths_default() {
        let p = Paths::default();
        assert!(p.artifacts.ends_with("artifacts"));
    }
}
