//! Experiment pipeline: the orchestration layer every bench, example and
//! CLI command shares.
//!
//! Responsibilities:
//! * pretraining + caching the base models (`ensure_base`),
//! * running any fine-tuning method end-to-end (`finetune`),
//! * the PTQ paths: RTN (rust), OPTQ with artifact-accumulated Hessians,
//! * evaluation glue (perplexity of any method-layout checkpoint).
//!
//! Method tags mirror python/compile (MethodConfig.tag): `full`,
//! `lora_qv4`, `lora_qkvo16`, `qat_b{3,4}`, `peqa_b{bits}_{gc|gN}`,
//! `peqa_zp_b4_gc`, `peqa_szp_b4_gc`, `alpha_b{3,4}`.

//! The PTQ quantizers (`rtn_quantize`, `optq_quantize`) and recipe
//! helpers are pure host code and always available — they feed the fused
//! quant::kernels layer. Everything that drives AOT artifacts (pretrain,
//! fine-tune, Hessian accumulation, perplexity) needs the PJRT runtime
//! and is gated on the `xla` feature.

#[cfg(feature = "xla")]
use std::path::Path;
#[cfg(feature = "xla")]
use std::rc::Rc;

use anyhow::{bail, Result};
#[cfg(feature = "xla")]
use anyhow::Context;

use crate::config::TrainConfig;
#[cfg(feature = "xla")]
use crate::config::Paths;
#[cfg(feature = "xla")]
use crate::data::batch::LmBatcher;
#[cfg(feature = "xla")]
use crate::data::Batch;
use crate::data::{corpus, World};
#[cfg(feature = "xla")]
use crate::eval;
use crate::model::Checkpoint;
use crate::quant;
#[cfg(feature = "xla")]
use crate::runtime::{literal_to_tensor, tensor_to_literal, Runtime};  // tensor_to_literal: prep artifacts only (literals stay alive across run)
use crate::tensor::Tensor;
use crate::tokenizer::Tokenizer;
#[cfg(feature = "xla")]
use crate::train::{Trainer, Tuner};

pub const WORLD_SEED: u64 = 2023;
pub const WORLD_ENTITIES: usize = 48;
pub const PRETRAIN_BYTES: usize = 400_000;
pub const ADAPT_BYTES: usize = 120_000;

/// Token stream for a named dataset on the host stack — no runtime, no
/// artifacts: the synthetic corpus generators + the byte-level
/// tokenizer, with the same seeds as the xla [`Ctx::stream`] path so a
/// host fine-tune and an artifact fine-tune see the same data.
pub fn host_stream(dataset: &str, bytes: usize) -> Result<Vec<u32>> {
    let text = match dataset {
        "pretrain" => corpus::pretrain(&World::new(WORLD_SEED, WORLD_ENTITIES), 11, bytes),
        "wikitext" => corpus::wikitext_sim(12, bytes),
        "ptb" => corpus::ptb_sim(13, bytes),
        other => bail!("unknown dataset '{other}'"),
    };
    Ok(crate::data::encode_stream(&Tokenizer::byte_level(512), &text))
}

/// Train/eval split of a host dataset (last ~20% held out), mirroring
/// [`Ctx::split`].
pub fn host_split(dataset: &str, bytes: usize) -> Result<(Vec<u32>, Vec<u32>)> {
    let s = host_stream(dataset, bytes)?;
    let cut = s.len() * 4 / 5;
    Ok((s[..cut].to_vec(), s[cut..].to_vec()))
}

#[cfg(feature = "xla")]
/// Shared experiment context: runtime + tokenizer + world + paths.
pub struct Ctx {
    pub rt: Rc<Runtime>,
    pub tok: Tokenizer,
    pub world: World,
    pub paths: Paths,
}

#[cfg(feature = "xla")]
impl Ctx {
    pub fn new() -> Result<Ctx> {
        let paths = Paths::default();
        let rt = Rc::new(Runtime::new(&paths.artifacts)?);
        Ok(Ctx {
            rt,
            // Byte-level keeps every experiment tokenizer-stable; the BPE
            // trainer is exercised by its own tests and the CLI.
            tok: Tokenizer::byte_level(512),
            world: World::new(WORLD_SEED, WORLD_ENTITIES),
            paths,
        })
    }

    /// Token stream for a named dataset. Delegates to [`host_stream`]
    /// (the `Ctx` world/tokenizer are constructed with the same seeds),
    /// so host and artifact fine-tunes see identical data by
    /// construction, not by copy-paste.
    pub fn stream(&self, dataset: &str, bytes: usize) -> Result<Vec<u32>> {
        host_stream(dataset, bytes)
    }

    /// Train/eval split of a dataset (last ~20% held out for PPL).
    pub fn split(&self, dataset: &str, bytes: usize) -> Result<(Vec<u32>, Vec<u32>)> {
        host_split(dataset, bytes)
    }
}

#[cfg(feature = "xla")]
/// Pretrain (or load the cached) fp base model for `size`.
pub fn ensure_base(ctx: &Ctx, size: &str, steps: usize) -> Result<Checkpoint> {
    let path = ctx.paths.checkpoints.join(format!("{size}_base.peqa"));
    if path.exists() {
        return Checkpoint::load(&path);
    }
    crate::info!("pretraining {size} base model ({steps} steps) → {}", path.display());
    let art_name = format!("{size}_train_full");
    let meta = ctx.rt.meta(&art_name)?;
    let metas: Vec<_> = meta.params_trainable.iter().collect();
    let init = Checkpoint::init_from_meta(&metas, 7 + size.len() as u64)?;
    let cfg = TrainConfig {
        steps,
        lr: TrainConfig::default_lr("full"),
        warmup_steps: steps / 20 + 1,
        log_every: 100,
        ..Default::default()
    };
    let mut trainer = Trainer::new(&ctx.rt, &art_name, &init, cfg)?;
    let stream = ctx.stream("pretrain", PRETRAIN_BYTES)?;
    let (b, t) = batch_dims(&meta);
    let mut batcher = LmBatcher::new(stream, b, t, 91);
    let n_steps = trainer.cfg.steps;
    trainer.run(n_steps, || batcher.next_batch())?;
    let ck = trainer.finish()?;
    ck.save(&path)?;
    Ok(ck)
}

#[cfg(feature = "xla")]
fn batch_dims(meta: &crate::runtime::ArtifactMeta) -> (usize, usize) {
    (meta.inputs[0].shape[0], meta.inputs[0].shape[1])
}

#[cfg(feature = "xla")]
/// Run a `prep` artifact: fp checkpoint → method-layout checkpoint.
pub fn prep(ctx: &Ctx, size: &str, prep_tag: &str, fp: &Checkpoint) -> Result<Checkpoint> {
    let art = ctx.rt.load(&format!("{size}_prep_{prep_tag}"))?;
    let metas: Vec<_> = art.meta.inputs.iter().collect();
    let mut inputs = Vec::with_capacity(metas.len());
    for io in &art.meta.inputs {
        let t = fp.req(&io.name)?;
        inputs.push(tensor_to_literal(t)?);
    }
    let outs = art.run(&inputs)?;
    let mut ck = Checkpoint::new();
    for (io, lit) in art.meta.outputs.iter().zip(&outs) {
        ck.insert(io.name.clone(), literal_to_tensor(lit, &io.shape)?);
    }
    Ok(ck)
}

/// Method tag → (train artifact name, prep tag if the base must be
/// transformed first).
#[cfg(feature = "xla")]
fn plan(size: &str, tag: &str) -> (String, Option<String>) {
    let train = format!("{size}_train_{tag}");
    let prep = if tag.starts_with("peqa") {
        // peqa / peqa_zp / peqa_szp all share the plain peqa prep
        // (quantization is method-independent; only trainability differs).
        let bits_group = tag
            .trim_start_matches("peqa")
            .trim_start_matches("_zp")
            .trim_start_matches("_szp");
        Some(format!("peqa{bits_group}"))
    } else if tag.starts_with("alpha") {
        Some(tag.to_string())
    } else {
        None
    };
    (train, prep)
}

#[cfg(feature = "xla")]
/// Fine-tune `base` (fp layout) with the given method on token stream
/// batches. Returns the method-layout tuned checkpoint.
pub fn finetune(
    ctx: &Ctx,
    size: &str,
    tag: &str,
    base: &Checkpoint,
    train_stream: &[u32],
    cfg: &TrainConfig,
) -> Result<(Checkpoint, Vec<f32>)> {
    let (train_art, prep_tag) = plan(size, tag);
    let start = match &prep_tag {
        Some(p) => prep(ctx, size, p, base).with_context(|| format!("prep {p}"))?,
        None => base.clone(),
    };
    let meta = ctx.rt.meta(&train_art)?;
    let (b, t) = batch_dims(&meta);
    let mut trainer = Trainer::new(&ctx.rt, &train_art, &start, cfg.clone())?;
    let mut batcher = LmBatcher::new(train_stream.to_vec(), b, t, cfg.seed ^ 0x5eed);
    trainer.run(cfg.steps, || batcher.next_batch())?;
    let losses = trainer.losses().to_vec();
    Ok((trainer.finish()?, losses))
}

#[cfg(feature = "xla")]
/// Fine-tune on pre-built batches (instruction tuning).
pub fn finetune_batches(
    ctx: &Ctx,
    size: &str,
    tag: &str,
    base: &Checkpoint,
    batches: &[Batch],
    cfg: &TrainConfig,
) -> Result<Checkpoint> {
    let (train_art, prep_tag) = plan(size, tag);
    let start = match &prep_tag {
        Some(p) => prep(ctx, size, p, base)?,
        None => base.clone(),
    };
    let mut trainer = Trainer::new(&ctx.rt, &train_art, &start, cfg.clone())?;
    let mut i = 0usize;
    trainer.run(cfg.steps, || {
        let b = batches[i % batches.len()].clone();
        i += 1;
        b
    })?;
    trainer.finish()
}

#[cfg(feature = "xla")]
/// Perplexity of any method-layout checkpoint on a token stream.
pub fn ppl(ctx: &Ctx, size: &str, ck: &Checkpoint, stream: &[u32]) -> Result<f64> {
    let fp = if ck.quantized_prefixes().is_empty()
        && !ck.names().iter().any(|n| n.ends_with(".code"))
    {
        // fp or LoRA layout: merge adapters if present, else as-is.
        if ck.names().iter().any(|n| n.ends_with(".lora_a")) {
            // rank/alpha from any lora train artifact meta isn't needed:
            // adapters store their effect once merged with the meta below.
            bail!("ppl() needs LoRA checkpoints merged first (merge_lora)")
        } else {
            ck.clone()
        }
    } else {
        ck.dequantize()?
    };
    eval::perplexity(&ctx.rt, &format!("{size}_eval"), &fp, stream)
}

#[cfg(feature = "xla")]
/// Accumulate OPTQ Hessians for `fp` over calibration batches.
pub fn hessians(
    ctx: &Ctx,
    size: &str,
    fp: &Checkpoint,
    calib: &[u32],
    n_batches: usize,
) -> Result<Vec<(String, Tensor)>> {
    let art = ctx.rt.load(&format!("{size}_hess"))?;
    let (b, t) = batch_dims(&art.meta);
    let metas: Vec<_> = art.meta.layout();
    let params = fp.assemble(&metas, 0)?;
    let param_bufs = params
        .iter()
        .map(|t| ctx.rt.tensor_to_device(t))
        .collect::<Result<Vec<_>>>()?;
    let mut batcher = LmBatcher::new(calib.to_vec(), b, t, 0xca11b);
    let mut acc: Vec<(String, Tensor)> = art
        .meta
        .outputs
        .iter()
        .map(|io| (io.name.clone(), Tensor::zeros(&io.shape)))
        .collect();
    for _ in 0..n_batches {
        let batch = batcher.next_batch();
        let tok = ctx.rt.to_device_i32(&batch.tokens, &art.meta.inputs[0].shape)?;
        let mut inputs: Vec<&xla::PjRtBuffer> = vec![&tok];
        inputs.extend(param_bufs.iter());
        let outs = art.run_b(&inputs)?;
        for ((_, a), (lit, io)) in acc.iter_mut().zip(outs.iter().zip(art.meta.outputs.iter())) {
            a.add_scaled(&literal_to_tensor(lit, &io.shape)?, 1.0)?;
        }
    }
    Ok(acc)
}

/// Which Hessian family covers a projection prefix like "layers.0.attn.q".
fn hess_key(prefix: &str) -> Result<String> {
    let (layer, proj) = prefix
        .rsplit_once('.')
        .and_then(|(lp, p)| lp.rsplit_once('.').map(|(l, fam)| (l, format!("{fam}.{p}"))))
        .ok_or_else(|| anyhow::anyhow!("bad prefix {prefix}"))?;
    let fam = match proj.as_str() {
        "attn.q" | "attn.k" | "attn.v" => "qkv",
        "attn.o" => "o",
        "mlp.gate" | "mlp.up" => "gateup",
        "mlp.down" => "down",
        "mlp.fc1" => "fc1",
        "mlp.fc2" => "fc2",
        other => bail!("unknown projection {other}"),
    };
    Ok(format!("{layer}.hess.{fam}"))
}

/// PTQ of an fp checkpoint with OPTQ (paper's LoRA+OPTQ deployment path).
pub fn optq_quantize(
    fp: &Checkpoint,
    hessians: &[(String, Tensor)],
    bits: u8,
    group: Option<usize>,
) -> Result<Checkpoint> {
    // peqa-lint: allow(nondeterminism-sources) -- lookup-only: keyed
    // gets during quantize_with; the projection walk itself follows the
    // checkpoint's ordered names, never this map.
    let hmap: std::collections::HashMap<&str, &Tensor> =
        hessians.iter().map(|(n, t)| (n.as_str(), t)).collect();
    quantize_with(fp, |prefix, w| {
        let key = hess_key(prefix)?;
        let h = hmap
            .get(key.as_str())
            .ok_or_else(|| anyhow::anyhow!("missing hessian {key}"))?;
        quant::quantize_optq(w, h, bits, group, 0.01)
    })
}

/// PTQ with plain RTN (the paper's RTN rows; also PEQA's init).
pub fn rtn_quantize(fp: &Checkpoint, bits: u8, group: Option<usize>) -> Result<Checkpoint> {
    quantize_with(fp, |_, w| quant::quantize_rtn(w, bits, group))
}

fn quantize_with(
    fp: &Checkpoint,
    mut f: impl FnMut(&str, &Tensor) -> Result<quant::QuantizedMatrix>,
) -> Result<Checkpoint> {
    let mut out = Checkpoint::new();
    for (name, t) in fp.iter() {
        match name.strip_suffix(".w") {
            // Block projections are quantized; embeddings/norms/head are
            // not ".w"-suffixed block linears … but embed/lm_head have no
            // ".w" suffix at all, so matching ".w" is exactly the paper's
            // "every fully-connected layer of the blocks".
            Some(prefix) if name.starts_with("layers.") => {
                let q = f(prefix, t)?;
                out.insert(
                    format!("{prefix}.wq"),
                    Tensor::new(t.shape(), q.codes.iter().map(|&c| c as f32).collect()),
                );
                out.insert(format!("{prefix}.s"), q.scales);
                out.insert(format!("{prefix}.z"), q.zeros);
            }
            _ => out.insert(name.clone(), t.clone()),
        }
    }
    Ok(out)
}

#[cfg(feature = "xla")]
/// LoRA rank/alpha from the train artifact that produced a checkpoint.
pub fn lora_hparams(ctx: &Ctx, size: &str, tag: &str) -> Result<(f64, usize)> {
    let meta = ctx.rt.meta(&format!("{size}_train_{tag}"))?;
    let m = meta.method.as_ref().ok_or_else(|| anyhow::anyhow!("no method meta"))?;
    Ok((m.lora_alpha, m.rank))
}

/// Instruction-tune (alpaca-sim) with caching — Section 4.3 pipeline.
/// `tag` may also be "rtn_b4": RTN-quantize the base with NO tuning
/// (the Table 7 degradation baseline).
#[cfg(feature = "xla")]
pub fn instruct_tuned(
    ctx: &Ctx,
    size: &str,
    tag: &str,
    n_examples: usize,
    steps: usize,
) -> Result<Checkpoint> {
    let path = ctx
        .paths
        .checkpoints
        .join("ft")
        .join(format!("{size}_{tag}_alpaca_{steps}.peqa"));
    if path.exists() {
        return Checkpoint::load(&path);
    }
    let base = ensure_base(ctx, size, pretrain_steps())?;
    let ck = if tag == "base" {
        base
    } else if let Some(bits) = tag.strip_prefix("rtn_b") {
        rtn_quantize(&base, bits.parse()?, None)?
    } else {
        let meta = ctx.rt.meta(&format!("{size}_train_{tag}"))?;
        let (b, t) = batch_dims(&meta);
        let data = crate::data::alpaca_sim(&ctx.world, 77, n_examples);
        let batches = crate::data::instruction_batches(&ctx.tok, &data, b, t);
        let cfg = default_cfg(tag, steps, 7);
        finetune_batches(ctx, size, tag, &base, &batches, &cfg)?
    };
    ck.save(&path)?;
    Ok(ck)
}

/// Pretraining step budget (env PEQA_PRETRAIN_STEPS; default 500).
pub fn pretrain_steps() -> usize {
    std::env::var("PEQA_PRETRAIN_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(500)
}

#[cfg(feature = "xla")]
/// Cached fine-tune: benches share tuned checkpoints across tables.
/// Cache key = (size, method, dataset, steps) under checkpoints/ft/.
pub fn finetune_cached(
    ctx: &Ctx,
    size: &str,
    tag: &str,
    dataset: &str,
    steps: usize,
) -> Result<Checkpoint> {
    let cfg = default_cfg(tag, steps, 42);
    // Cache key carries the effective recipe so recipe changes invalidate.
    let path = ctx.paths.checkpoints.join("ft").join(format!(
        "{size}_{tag}_{dataset}_{}_lr{:.0e}.peqa",
        cfg.steps, cfg.lr
    ));
    if path.exists() {
        return Checkpoint::load(&path);
    }
    let base = ensure_base(ctx, size, pretrain_steps())?;
    let (train_s, _) = ctx.split(dataset, ADAPT_BYTES)?;
    let (ck, _) = finetune(ctx, size, tag, &base, &train_s, &cfg)?;
    ck.save(&path)?;
    Ok(ck)
}

/// Full "LoRA + OPTQ" baseline (Tables 2/3, Fig. 3): LoRA fine-tune in fp,
/// merge adapters, OPTQ-quantize the merged weights on calibration data.
/// Returns the quantized (peqa-layout) checkpoint.
#[cfg(feature = "xla")]
pub fn lora_optq(
    ctx: &Ctx,
    size: &str,
    lora_tag: &str,
    dataset: &str,
    steps: usize,
    bits: u8,
    group: Option<usize>,
) -> Result<Checkpoint> {
    let lora_ck = finetune_cached(ctx, size, lora_tag, dataset, steps)?;
    let (alpha, rank) = lora_hparams(ctx, size, lora_tag)?;
    let merged = lora_ck.merge_lora(alpha, rank)?;
    // Calibrate on the *adaptation* distribution, like the paper's OPTQ
    // runs which calibrate on the task data.
    let (calib, _) = ctx.split(dataset, ADAPT_BYTES)?;
    let h = hessians(ctx, size, &merged, &calib, 8)?;
    optq_quantize(&merged, &h, bits, group)
}

#[cfg(feature = "xla")]
/// PPL of a fine-tuned LoRA checkpoint (merges adapters first).
pub fn lora_ppl(
    ctx: &Ctx,
    size: &str,
    lora_tag: &str,
    ck: &Checkpoint,
    stream: &[u32],
) -> Result<f64> {
    let (alpha, rank) = lora_hparams(ctx, size, lora_tag)?;
    ppl(ctx, size, &ck.merge_lora(alpha, rank)?, stream)
}

/// Convenience: default fine-tune recipe for a method tag.
///
/// LoRA gets 2× the steps and a larger peak LR: with B initialized to
/// zero the adapters see no gradient on step one and rank-constrained
/// updates converge visibly slower at these scales — the paper similarly
/// tunes per-method recipes (appendix C) and trains 15 epochs.
pub fn default_cfg(tag: &str, steps: usize, seed: u64) -> TrainConfig {
    let kind = tag.split('_').next().unwrap_or("full");
    let steps = if kind == "lora" { steps * 2 } else { steps };
    TrainConfig {
        steps,
        lr: if kind == "lora" { 5e-2 } else { TrainConfig::default_lr(kind) },
        warmup_steps: (steps / 20).max(1),
        seed,
        log_every: 0,
        ..Default::default()
    }
}

#[cfg(feature = "xla")]
/// Cached fine-tune directory helper used by benches.
pub fn results_dir(ctx: &Ctx) -> &Path {
    &ctx.paths.results
}
