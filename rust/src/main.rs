//! `peqa` CLI — the leader entrypoint of the L3 coordinator.
//!
//! Commands:
//!   list-artifacts                      show AOT artifacts + signatures   [xla]
//!   pretrain   --size n3 [--steps N]    pretrain + cache the fp base model [xla]
//!   finetune   host PEQA scale-only fine-tuning on a packed model (default
//!              build) or, with --backend xla, the artifact-driven trainer
//!   eval       --size n3 --ckpt path --dataset wikitext                  [xla]
//!   quantize   --ckpt path --bits 4 [--group g] [--optq --size n3]
//!   pack       --ckpt path --bits 4 --out model.packed
//!   serve      [--model m.packed] host multi-task packed-decode serving
//!   serve-demo --size n3 [--requests N] multi-task adapter-swap serving demo [xla]
//!   fsck       <artifact|dir> […]       verify artifact checksums, print headers
//!   lint       [paths…]                 in-tree static analysis (peqa::lint)
//!   memreport                           Table-1 style DRAM model (paper dims)
//!
//! Commands marked [xla] drive AOT artifacts through the PJRT runtime and
//! need the `xla` feature (see rust/Cargo.toml); the rest — including RTN
//! quantization, packing, host `finetune` (scale-only PEQA training via
//! train::HostPeqaTuner) and the `serve` host decode engine — work in the
//! default build, closing the quantize → PEQA-tune → scale-swap-serve loop
//! without any device runtime.

#![deny(unsafe_code)]

use anyhow::{bail, Result};
use peqa::cli::Args;
use peqa::memmodel;
use peqa::model::Checkpoint;
use peqa::pipeline;

#[cfg(feature = "xla")]
use peqa::coordinator::{AdapterStore, BatcherConfig, Coordinator, SwitchMode};
#[cfg(feature = "xla")]
use peqa::info;
#[cfg(feature = "xla")]
use peqa::pipeline::Ctx;
#[cfg(feature = "xla")]
use peqa::tokenizer::EOS;

fn main() {
    let code = match run() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

const USAGE: &str = "\
peqa — PEQA (NeurIPS 2023) reproduction CLI

  peqa list-artifacts                                            [xla]
  peqa pretrain   --size n1..n6|o1..o6 [--steps 600]             [xla]
  peqa finetune   (host backend — default build)
                  [--model m.packed] [--dataset wikitext|ptb|pretrain]
                  [--steps 60] [--lr 2e-3] [--batch 4] [--seq 48]
                  [--heads 4] [--train-zeros] [--task NAME]
                  [--tasks t1,t2,...] [--out adapters]
                  [--save-model base.packed]
                  [--eval-tokens 8192] [--seed 7]
                  [--save-every N] [--resume] [--halt-after N]
                  [--publish registry] [--gc-keep K]
                  [--bits 4] [--group g] [--layers 2] [--d-model 64]
                  [--d-ff 192] [--vocab 512]
                  (no --model: synthesizes + RTN-quantizes a base model;
                   writes <task>.adapter servable by `peqa serve`.
                   --tasks tunes N adapters round-robin out of ONE shared
                   packed model — known dataset names use their corpus,
                   other names get deterministic synthetic task corpora —
                   all servable by one `peqa serve --adapters` run.
                   --save-every N journals the full training state every
                   N steps next to a base snapshot in --out; --resume
                   replays the journal — truncating a torn tail — and
                   finishes bitwise identical to an uninterrupted run;
                   with --tasks the journal holds one slot per task and
                   round-robin resume restores the last COMPLETE round
                   (a partial round is dropped like a torn tail);
                   --halt-after N exits after step N (simulated crash);
                   --publish DIR publishes the adapter(s) as one atomic
                   generation servable by `peqa serve --registry DIR`;
                   --gc-keep K prunes superseded generation files after
                   the publish, keeping each task's K newest plus
                   whatever the live manifest references)
  peqa finetune   --backend xla --size n3 --method peqa_b4_gc
                  --dataset wikitext|ptb [--steps 150] [--lr 2e-3]
                  [--out path.peqa]                              [xla]
  peqa eval       --size n3 --ckpt path.peqa --dataset wikitext|ptb [xla]
  peqa quantize   --ckpt path.peqa --bits 4 [--group 32]
                  [--optq --size n3] [--out path.peqa]
  peqa pack       --ckpt path.peqa --bits 4 --out model.packed
  peqa serve      [--model m.packed] [--adapters dir] [--registry dir]
                  [--heads 4]
                  [--tasks 3] [--requests 24] [--max-new 24] [--batch 8]
                  [--topk 0] [--temp 0.8] [--window 256] [--seed 7]
                  [--bits 4] [--group g] [--layers 2] [--d-model 64]
                  [--d-ff 192] [--vocab 512] [--clients 0] [--strict]
                  [--engines 0] [--queue-cap 64] [--deadline-ms 0]
                  [--affinity-burst 4] [--stream]
                  [--watch-interval-ms 0]
                  [--kv-pages 0] [--page-tokens 16]
                  [--prefix-tokens 0] [--require-shared]
                  (--clients N > 0 serves the same load through the
                   threaded serve::server with N concurrent clients;
                   --strict rejects partial-coverage adapters at
                   registration instead of basing uncovered projections;
                   --registry serves the current published generation
                   and — with --clients N — hot-reloads newly published
                   generations between request bursts without restart;
                   --engines N > 0 serves through the sharded engine
                   pool instead: N workers share ONE set of packed codes
                   and get batches task-affine from a work queue with
                   bounded per-task ingress — submits past --queue-cap
                   are rejected typed, requests queued past
                   --deadline-ms are shed; --stream delivers each
                   client's tokens over a per-token channel (bitwise
                   identical to non-streaming); --watch-interval-ms
                   rate-limits registry hot-reload polls for both the
                   pool and the --clients server, 0 = every burst;
                   --kv-pages N > 0 serves KV out of a paged pool of N
                   fixed-size pages per engine (--page-tokens each)
                   with copy-on-write prefix sharing — decoded tokens
                   stay bitwise identical to the ring buffers, requests
                   that can never fit are rejected typed at submit;
                   --prefix-tokens P makes every request share a P-token
                   prompt prefix, --require-shared fails the run unless
                   the paged backend attached shared prefix pages)
  peqa serve-demo --size n3 [--requests 16] [--full-reload]      [xla]
  peqa fsck       <artifact|dir> [...]
                  (verify checksums and print headers of .peqa /
                   .adapter / .packed / journal / registry artifacts;
                   exits nonzero on corruption, directories expand to
                   their files)
  peqa lint       [paths...] [--rule NAME] [--list] [--json]
                  (in-tree static analysis: determinism, panic-freedom
                   and hot-path invariants; paths default to rust/src;
                   deterministic file:line:rule output, exits nonzero
                   on any finding; exemptions are written in-source as
                   `// peqa-lint: allow(<rule>) -- <justification>`)
  peqa memreport

Methods: full | lora_qv4 | lora_qkvo16 | qat_b{3,4} | peqa_b{3,4}_{gc,g16,g32,g64}
         | peqa_zp_b4_gc | peqa_szp_b4_gc | alpha_b{3,4}

[xla] commands need a build with `--features xla` (vendored PJRT bindings).
";

fn run() -> Result<()> {
    let mut args = Args::from_env()?;
    let Some(cmd) = args.command.clone() else {
        print!("{USAGE}");
        return Ok(());
    };
    match cmd.as_str() {
        #[cfg(feature = "xla")]
        "list-artifacts" => {
            let ctx = Ctx::new()?;
            for name in ctx.rt.list()? {
                let m = ctx.rt.meta(&name)?;
                println!(
                    "{name:44} {:8} in={:<3} out={:<3} {}",
                    m.kind,
                    m.inputs.len(),
                    m.outputs.len(),
                    m.display.unwrap_or_default()
                );
            }
            args.finish()
        }
        #[cfg(feature = "xla")]
        "pretrain" => {
            let size = args.require("size")?;
            let steps = args.get_usize("steps", 600)?;
            args.finish()?;
            let ctx = Ctx::new()?;
            let ck = pipeline::ensure_base(&ctx, &size, steps)?;
            let (_, eval_stream) = ctx.split("pretrain", pipeline::PRETRAIN_BYTES)?;
            let ppl = pipeline::ppl(&ctx, &size, &ck, &eval_stream)?;
            println!("{size} base ready: held-out pretrain ppl {ppl:.3}");
            Ok(())
        }
        "finetune" => {
            // Default backend: host in the default build (the loop
            // closes without a device runtime), xla when the feature is
            // on (preserves the original artifact-driven behavior).
            let default_backend = if cfg!(feature = "xla") { "xla" } else { "host" };
            let backend = args.get("backend", default_backend);
            match backend.as_str() {
                "host" => finetune_host(args),
                #[cfg(feature = "xla")]
                "xla" => finetune_xla(args),
                #[cfg(not(feature = "xla"))]
                "xla" => bail!(
                    "--backend xla drives AOT artifacts and needs a build with \
                     `--features xla`; the host backend (--backend host) runs in \
                     this build"
                ),
                other => bail!("unknown training backend '{other}' (host | xla)"),
            }
        }
        #[cfg(feature = "xla")]
        "eval" => {
            let size = args.require("size")?;
            let ckpt = args.require("ckpt")?;
            let dataset = args.get("dataset", "wikitext");
            args.finish()?;
            let ctx = Ctx::new()?;
            let ck = Checkpoint::load(std::path::Path::new(&ckpt))?;
            let (_, eval_s) = ctx.split(&dataset, pipeline::ADAPT_BYTES)?;
            let ppl = pipeline::ppl(&ctx, &size, &ck, &eval_s)?;
            println!("{size} {ckpt} on {dataset}: ppl {ppl:.4}");
            Ok(())
        }
        "quantize" => {
            let ckpt = args.require("ckpt")?;
            let bits = args.get_usize("bits", 4)? as u8;
            let group = args.opt("group").map(|g| g.parse::<usize>()).transpose()?;
            let use_optq = args.flag("optq");
            let size = args.opt("size");
            let out = args.opt("out");
            args.finish()?;
            let fp = Checkpoint::load(std::path::Path::new(&ckpt))?;
            let q = if use_optq {
                let size = size.ok_or_else(|| anyhow::anyhow!("--optq needs --size"))?;
                optq_cmd(&size, &fp, bits, group)?
            } else {
                // RTN runs entirely on the host quant stack.
                pipeline::rtn_quantize(&fp, bits, group)?
            };
            let out = out.unwrap_or_else(|| format!("{ckpt}.q{bits}"));
            q.save(std::path::Path::new(&out))?;
            println!("quantized → {out}");
            Ok(())
        }
        "pack" => {
            let ckpt = args.require("ckpt")?;
            let bits = args.get_usize("bits", 4)? as u8;
            let out = args.require("out")?;
            args.finish()?;
            let ck = Checkpoint::load(std::path::Path::new(&ckpt))?;
            if ck.quantized_prefixes().is_empty() {
                bail!("{ckpt} has no quantized tensors — run `peqa quantize` first");
            }
            let bytes = ck.save_packed(std::path::Path::new(&out), bits)?;
            println!("packed model: {out} ({})", peqa::util::human_bytes(bytes));
            Ok(())
        }
        "serve" => {
            let opts = ServeOpts {
                model: args.opt("model"),
                adapters: args.opt("adapters"),
                registry: args.opt("registry"),
                heads: args.get_usize("heads", 4)?,
                tasks: args.get_usize("tasks", 3)?,
                requests: args.get_usize("requests", 24)?,
                max_new: args.get_usize("max-new", 24)?,
                batch: args.get_usize("batch", 8)?,
                topk: args.get_usize("topk", 0)?,
                temp: args.get_f64("temp", 0.8)?,
                window: args.get_usize("window", 256)?,
                seed: args.get_u64("seed", 7)?,
                bits: args.get_usize("bits", 4)? as u8,
                group: args.opt("group").map(|g| g.parse::<usize>()).transpose()?,
                layers: args.get_usize("layers", 2)?,
                d_model: args.get_usize("d-model", 64)?,
                d_ff: args.get_usize("d-ff", 192)?,
                vocab: args.get_usize("vocab", 512)?,
                clients: args.get_usize("clients", 0)?,
                strict: args.flag("strict"),
                engines: args.get_usize("engines", 0)?,
                queue_cap: args.get_usize("queue-cap", 64)?,
                deadline_ms: args.get_u64("deadline-ms", 0)?,
                affinity_burst: args.get_usize("affinity-burst", 4)?,
                stream: args.flag("stream"),
                watch_interval_ms: args.get_u64("watch-interval-ms", 0)?,
                kv_pages: args.get_usize("kv-pages", 0)?,
                page_tokens: args
                    .get_usize("page-tokens", peqa::serve::DEFAULT_PAGE_TOKENS)?,
                prefix_tokens: args.get_usize("prefix-tokens", 0)?,
                require_shared: args.flag("require-shared"),
            };
            args.finish()?;
            serve_host(opts)
        }
        #[cfg(feature = "xla")]
        "serve-demo" => {
            let size = args.get("size", "n3");
            let n_req = args.get_usize("requests", 16)?;
            let full_reload = args.flag("full-reload");
            args.finish()?;
            serve_demo(&size, n_req, full_reload)
        }
        "fsck" => {
            let paths = args.positional.clone();
            args.finish()?;
            fsck_cmd(&paths)
        }
        "lint" => {
            let paths = args.positional.clone();
            let rule = args.opt("rule");
            let list = args.flag("list");
            let json = args.flag("json");
            args.finish()?;
            lint_cmd(&paths, rule.as_deref(), list, json)
        }
        "memreport" => {
            args.finish()?;
            memreport();
            Ok(())
        }
        #[cfg(not(feature = "xla"))]
        c @ ("list-artifacts" | "pretrain" | "eval" | "serve-demo") => {
            bail!("'{c}' drives AOT artifacts and needs a build with `--features xla` \
                   (see rust/Cargo.toml)")
        }
        other => {
            print!("{USAGE}");
            bail!("unknown command '{other}'")
        }
    }
}

/// The original artifact-driven fine-tune path (`--backend xla`).
#[cfg(feature = "xla")]
fn finetune_xla(mut args: peqa::cli::Args) -> Result<()> {
    let size = args.require("size")?;
    let method = args.require("method")?;
    let dataset = args.get("dataset", "wikitext");
    let steps = args.get_usize("steps", 150)?;
    let lr = args.get_f64("lr", 0.0)?;
    let out = args.opt("out");
    args.finish()?;
    let ctx = Ctx::new()?;
    let base = pipeline::ensure_base(&ctx, &size, pipeline::pretrain_steps())?;
    let (train_s, eval_s) = ctx.split(&dataset, pipeline::ADAPT_BYTES)?;
    let mut cfg = pipeline::default_cfg(&method, steps, 42);
    if lr > 0.0 {
        cfg.lr = lr;
    }
    cfg.log_every = 25;
    let (ck, losses) = pipeline::finetune(&ctx, &size, &method, &base, &train_s, &cfg)?;
    info!(
        "finetune {size}/{method}: loss {:.4} → {:.4}",
        losses.first().copied().unwrap_or(0.0),
        losses.last().copied().unwrap_or(0.0)
    );
    let ppl = if method.starts_with("lora") {
        let (alpha, rank) = pipeline::lora_hparams(&ctx, &size, &method)?;
        pipeline::ppl(&ctx, &size, &ck.merge_lora(alpha, rank)?, &eval_s)?
    } else {
        pipeline::ppl(&ctx, &size, &ck, &eval_s)?
    };
    println!("{size} {method} {dataset}: eval ppl {ppl:.4}");
    let out = out.unwrap_or_else(|| {
        ctx.paths
            .checkpoints
            .join(format!("{size}_{method}_{dataset}.peqa"))
            .to_string_lossy()
            .into_owned()
    });
    ck.save(std::path::Path::new(&out))?;
    info!("saved {out}");
    Ok(())
}

/// Host PEQA fine-tuning (default build): quantized packed model + task
/// corpus → per-task `.adapter` file, immediately servable by
/// `peqa serve --model <base.packed> --adapters <dir>`. Only the f32
/// scale (and with --train-zeros, zero-point) tensors train; codes and
/// fp tensors are frozen, so the trainable + Adam state is kilobytes
/// (printed against the packed-code bytes — the paper's Table 1
/// optimizer-memory story).
fn finetune_host(mut args: peqa::cli::Args) -> Result<()> {
    use peqa::model::PackedModel;
    use peqa::serve::{self, ModelGeom};
    use peqa::train::HostPeqaTuner;

    let model_path = args.opt("model");
    let dataset_opt = args.opt("dataset");
    let dataset = dataset_opt.clone().unwrap_or_else(|| "wikitext".to_string());
    // Numeric flags are captured as Option first: `--resume` must know
    // which flags the user *explicitly* passed to cross-check them
    // against the journal meta.
    let steps_opt = args.opt("steps");
    let lr_opt = args.opt("lr");
    let batch_opt = args.opt("batch");
    let seq_opt = args.opt("seq");
    let heads_opt = args.opt("heads");
    let train_zeros = args.flag("train-zeros");
    let task_opt = args.opt("task");
    let tasks_opt = args.opt("tasks");
    let task = task_opt.clone().unwrap_or_else(|| dataset.clone());
    let out_dir = args.get("out", "adapters");
    let save_model = args.opt("save-model");
    let eval_tokens = args.get_usize("eval-tokens", 8192)?;
    let seed_opt = args.opt("seed");
    let save_every = args.get_usize("save-every", 0)?;
    let resume = args.flag("resume");
    let halt_after = args.get_usize("halt-after", 0)?;
    let publish = args.opt("publish");
    let gc_keep_opt = args.opt("gc-keep");
    // Synth-model shape flags: meaningful only without --model (a loaded
    // .packed file fixes its own bits/grouping/geometry) — rejecting the
    // combination beats silently tuning a different config than asked.
    let bits_opt = args.opt("bits");
    let group = args.opt("group").map(|g| g.parse::<usize>()).transpose()?;
    let layers_opt = args.opt("layers");
    let d_model_opt = args.opt("d-model");
    let d_ff_opt = args.opt("d-ff");
    let vocab_opt = args.opt("vocab");
    args.finish()?;
    fn parse_num<T: std::str::FromStr>(v: &Option<String>, name: &str) -> Result<Option<T>> {
        match v {
            None => Ok(None),
            Some(s) => s
                .parse()
                .map(Some)
                .map_err(|_| anyhow::anyhow!("--{name} expects a number, got '{s}'")),
        }
    }
    let steps_o: Option<usize> = parse_num(&steps_opt, "steps")?;
    let lr_o: Option<f64> = parse_num(&lr_opt, "lr")?;
    let batch_o: Option<usize> = parse_num(&batch_opt, "batch")?;
    let seq_o: Option<usize> = parse_num(&seq_opt, "seq")?;
    let heads_o: Option<usize> = parse_num(&heads_opt, "heads")?;
    let seed_o: Option<u64> = parse_num(&seed_opt, "seed")?;
    let steps = steps_o.unwrap_or(60);
    let lr = lr_o.unwrap_or(0.0);
    let batch = batch_o.unwrap_or(4).max(1);
    let seq = seq_o.unwrap_or(48).max(2);
    let heads = heads_o.unwrap_or(4);
    let seed = seed_o.unwrap_or(7);
    let bits = parse_num::<usize>(&bits_opt, "bits")?.unwrap_or(4) as u8;
    let layers = parse_num::<usize>(&layers_opt, "layers")?.unwrap_or(2);
    let d_model = parse_num::<usize>(&d_model_opt, "d-model")?.unwrap_or(64);
    let d_ff = parse_num::<usize>(&d_ff_opt, "d-ff")?.unwrap_or(192);
    let vocab = parse_num::<usize>(&vocab_opt, "vocab")?.unwrap_or(512);
    let gc_keep: Option<usize> = parse_num(&gc_keep_opt, "gc-keep")?;
    if gc_keep.is_some() && publish.is_none() {
        bail!("--gc-keep prunes the publish registry and needs --publish");
    }
    if model_path.is_some() {
        let synth_flags = [
            ("bits", bits_opt.is_some()),
            ("group", group.is_some()),
            ("layers", layers_opt.is_some()),
            ("d-model", d_model_opt.is_some()),
            ("d-ff", d_ff_opt.is_some()),
            ("vocab", vocab_opt.is_some()),
        ];
        if let Some((name, _)) = synth_flags.iter().find(|(_, set)| *set) {
            bail!(
                "--{name} configures the synthesized base model and conflicts with \
                 --model (a .packed file fixes its own bits/grouping/geometry)"
            );
        }
    }

    if tasks_opt.is_some() && task_opt.is_some() {
        bail!("--task names the single-task adapter and conflicts with --tasks");
    }
    if tasks_opt.is_some() && dataset_opt.is_some() {
        bail!(
            "--dataset feeds the single-task path and conflicts with --tasks \
             (each multi-task corpus is derived from its task name: known \
             dataset names stream their corpus, others get deterministic \
             synthetic task corpora)"
        );
    }
    let multi_names: Option<Vec<String>> = match &tasks_opt {
        None => None,
        Some(list) => {
            let names: Vec<String> = list
                .split(',')
                .map(|s| s.trim().to_string())
                .filter(|s| !s.is_empty())
                .collect();
            if names.is_empty() {
                bail!("--tasks expects a comma-separated task list, got '{list}'");
            }
            Some(names)
        }
    };
    if resume {
        if model_path.is_some() {
            bail!(
                "--resume rebuilds the model from the journal's base snapshot and \
                 conflicts with --model"
            );
        }
        if save_model.is_some() {
            bail!(
                "--resume conflicts with --save-model — the base model was already \
                 saved next to the journal when the run started"
            );
        }
        let synth = [
            ("bits", bits_opt.is_some()),
            ("group", group.is_some()),
            ("layers", layers_opt.is_some()),
            ("d-model", d_model_opt.is_some()),
            ("d-ff", d_ff_opt.is_some()),
            ("vocab", vocab_opt.is_some()),
        ];
        if let Some((name, _)) = synth.iter().find(|(_, set)| *set) {
            bail!("--{name} conflicts with --resume (geometry comes from the journal)");
        }
        if let Some(names) = multi_names {
            return finetune_host_multi_resume(MultiResumeOpts {
                out_dir,
                names,
                eval_tokens,
                halt_after,
                publish,
                gc_keep,
                steps: steps_o,
                lr: lr_o,
                batch: batch_o,
                seq: seq_o,
                heads: heads_o,
                seed: seed_o,
                save_every: (save_every > 0).then_some(save_every),
                train_zeros,
            });
        }
        return finetune_host_resume(ResumeOpts {
            out_dir,
            task,
            dataset: dataset_opt,
            eval_tokens,
            halt_after,
            publish,
            gc_keep,
            steps: steps_o,
            lr: lr_o,
            batch: batch_o,
            seq: seq_o,
            heads: heads_o,
            seed: seed_o,
            save_every: (save_every > 0).then_some(save_every),
            train_zeros,
        });
    }

    let pm = match &model_path {
        Some(p) => PackedModel::load(std::path::Path::new(p))?,
        None => {
            let geom = ModelGeom { vocab, d_model, n_layers: layers, n_heads: heads, d_ff };
            serve::synth_packed(&geom, bits, group, seed)?.0
        }
    };
    let geom = ModelGeom::infer(&pm, heads)?;
    // The byte-level corpus streams use token ids up to 512.
    if geom.vocab < 512 {
        bail!(
            "host finetune streams byte-level corpora (vocab 512); the model's \
             vocab is {} — rebuild the model with --vocab >= 512",
            geom.vocab
        );
    }
    let threads = peqa::util::num_threads();
    // Serve the BASE model + trained adapter(s): save it before tuning.
    if let Some(p) = &save_model {
        let bytes = pm.to_checkpoint().save_packed(std::path::Path::new(p), pm.bits)?;
        println!("base model: {p} ({})", peqa::util::human_bytes(bytes));
    }

    // Multi-task round-robin: N adapters out of ONE shared packed model.
    if let Some(names) = multi_names {
        return finetune_host_multi(FinetuneMultiOpts {
            pm,
            geom,
            names,
            steps,
            lr,
            batch,
            seq,
            heads,
            train_zeros,
            out_dir,
            save_model,
            eval_tokens,
            seed,
            threads,
            publish,
            gc_keep,
            save_every,
            halt_after,
        });
    }

    let (train_s, eval_s) = pipeline::host_split(&dataset, pipeline::ADAPT_BYTES)?;
    let base_model = pm.clone();

    let mut cfg = pipeline::default_cfg(&format!("peqa_b{}_host", pm.bits), steps, seed);
    if lr > 0.0 {
        cfg.lr = lr;
    }
    cfg.log_every = (steps / 10).max(1);

    // Crash-safe mode: before the first step, write the base snapshot
    // and open the append-only journal in --out; a crash at any later
    // point resumes bitwise from the last durable record
    // (store::journal module docs).
    let writer = if save_every > 0 {
        let out = std::path::Path::new(&out_dir);
        let base_name = format!("{task}.base.packed");
        base_model.to_checkpoint().save_packed(&out.join(&base_name), base_model.bits)?;
        let meta = peqa::store::JournalMeta {
            task: task.clone(),
            tasks: Vec::new(),
            dataset: dataset.clone(),
            base: base_name,
            seed,
            steps,
            save_every,
            batch,
            seq,
            lr_bits: cfg.lr.to_bits(),
            warmup_steps: cfg.warmup_steps,
            train_zeros,
            vocab: geom.vocab,
            d_model: geom.d_model,
            n_layers: geom.n_layers,
            n_heads: geom.n_heads,
            d_ff: geom.d_ff,
        };
        let w = peqa::store::JournalWriter::create(&out.join(format!("{task}.journal")), &meta)?;
        println!(
            "journal: {} (full state every {save_every} step(s), base snapshot {})",
            w.path().display(),
            meta.base
        );
        Some(w)
    } else {
        None
    };

    let tuner = HostPeqaTuner::from_packed(pm, geom, cfg, train_zeros, threads)?;
    let batcher = peqa::data::LmBatcher::new(train_s, batch, seq, seed ^ 0x5eed);
    run_single_task(SingleRun {
        tuner,
        batcher,
        writer,
        base_model,
        eval_s,
        task,
        dataset,
        out_dir,
        steps,
        save_every,
        halt_after,
        publish,
        gc_keep,
        eval_tokens,
        heads,
        batch,
        seq,
        threads,
        train_zeros,
        save_model,
    })
}

/// Shared single-task training drive: the step loop with optional
/// journal appends every `save_every` steps (plus one at the final
/// step), an optional simulated crash (`--halt-after`), then the final
/// adapter + eval + publish. Both the fresh path and `--resume` funnel
/// here, so an interrupted-and-resumed run takes exactly the code path
/// of an uninterrupted one.
struct SingleRun {
    tuner: peqa::train::HostPeqaTuner,
    batcher: peqa::data::LmBatcher,
    writer: Option<peqa::store::JournalWriter>,
    base_model: peqa::model::PackedModel,
    eval_s: Vec<u32>,
    task: String,
    dataset: String,
    out_dir: String,
    steps: usize,
    save_every: usize,
    halt_after: usize,
    publish: Option<String>,
    gc_keep: Option<usize>,
    eval_tokens: usize,
    heads: usize,
    batch: usize,
    seq: usize,
    threads: usize,
    train_zeros: bool,
    save_model: Option<String>,
}

fn run_single_task(mut o: SingleRun) -> Result<()> {
    use peqa::store::TrainRecord;
    use peqa::train::Tuner;

    let start_step = o.tuner.step_count();
    let mut last_recorded = start_step;
    // peqa-lint: allow(nondeterminism-sources) -- wall time for the
    // steps/s progress line only; training math is seeded.
    let t0 = std::time::Instant::now();
    while o.tuner.step_count() < o.steps {
        let b = o.batcher.next_batch();
        o.tuner.step(&b)?;
        let step = o.tuner.step_count();
        if let Some(w) = o.writer.as_mut() {
            if (o.save_every > 0 && step % o.save_every == 0) || step == o.steps {
                let st = o.tuner.export_state()?;
                w.append(&TrainRecord {
                    step: step as u64,
                    task_idx: 0,
                    rng: o.batcher.rng_state(),
                    ema: st.ema,
                    losses: st.losses[last_recorded..].to_vec(),
                    params: st.params,
                    opt_m: st.opt_m,
                    opt_v: st.opt_v,
                })?;
                last_recorded = step;
            }
        }
        if o.halt_after > 0 && step >= o.halt_after && step < o.steps {
            println!(
                "halted after step {step}/{} (simulated crash; journal durable through \
                 step {last_recorded}) — continue with: peqa finetune --resume \
                 --task {} --out {}",
                o.steps, o.task, o.out_dir
            );
            return Ok(());
        }
    }
    let train_wall = t0.elapsed().as_secs_f64();
    let steps_run = o.tuner.step_count() - start_step;

    let losses = o.tuner.losses();
    let adapter = o.tuner.extract_adapter();
    let out_path = std::path::Path::new(&o.out_dir).join(format!("{}.adapter", o.task));
    adapter.save(&out_path)?;

    println!(
        "finetune host: task '{}' on {} | {} steps in {train_wall:.1}s \
         ({:.3}s/step) | loss {:.4} → {:.4} (ema {:.4})",
        o.task,
        o.dataset,
        steps_run,
        train_wall / steps_run.max(1) as f64,
        losses.first().copied().unwrap_or(0.0),
        losses.last().copied().unwrap_or(0.0),
        o.tuner.smoothed_loss().unwrap_or(0.0),
    );
    println!(
        "trainable: {} params (s{}) | trainable+Adam {} vs packed codes {} \
         ({}x smaller)",
        o.tuner.trainable_params(),
        if o.train_zeros { "+z" } else { " only" },
        peqa::util::human_bytes(o.tuner.trainable_state_bytes()),
        peqa::util::human_bytes(o.tuner.model().packed_bytes() as u64),
        o.tuner.model().packed_bytes() as u64 / o.tuner.trainable_state_bytes().max(1),
    );
    if o.eval_tokens > 0 {
        let slice = &o.eval_s[..o.eval_s.len().min(o.eval_tokens)];
        let base_ppl = peqa::eval::host_perplexity(
            &o.base_model,
            o.heads,
            slice,
            o.batch,
            o.seq,
            o.threads,
        )?;
        let tuned_ppl = peqa::eval::host_perplexity(
            o.tuner.model(),
            o.heads,
            slice,
            o.batch,
            o.seq,
            o.threads,
        )?;
        println!(
            "held-out ppl ({} tokens): base {base_ppl:.3} → tuned {tuned_ppl:.3}",
            slice.len()
        );
    }
    println!("adapter → {}", out_path.display());
    if let Some(dir) = &o.publish {
        let reg = peqa::store::Registry::open(dir.as_str());
        let generation = reg.publish(&[(o.task.clone(), &adapter)])?;
        println!(
            "published: {dir} generation {generation} (task '{}') — a watching \
             `peqa serve --registry {dir}` hot-reloads it",
            o.task
        );
        if let Some(k) = o.gc_keep {
            let pruned = reg.gc(k)?;
            println!(
                "registry gc: pruned {} superseded adapter file(s) (keep-last {k})",
                pruned.len()
            );
        }
    }
    if let Some(p) = &o.save_model {
        println!(
            "serve it: peqa serve --model {p} --adapters {} --heads {} --tasks 1",
            o.out_dir, o.heads
        );
    }
    Ok(())
}

/// Flags the user passed explicitly on a `--resume` invocation. Each is
/// cross-checked against the journal meta before anything runs — the
/// journal is authoritative, and a silently different flag would break
/// the bitwise resume contract.
struct ResumeOpts {
    out_dir: String,
    task: String,
    dataset: Option<String>,
    eval_tokens: usize,
    halt_after: usize,
    publish: Option<String>,
    gc_keep: Option<usize>,
    steps: Option<usize>,
    lr: Option<f64>,
    batch: Option<usize>,
    seq: Option<usize>,
    heads: Option<usize>,
    seed: Option<u64>,
    save_every: Option<usize>,
    train_zeros: bool,
}

/// Resume a journaled single-task run: verify the journal (truncating a
/// torn tail), rebuild the tuner from the base snapshot, restore the
/// last durable record (scales/zeros, Adam moments, loss bookkeeping,
/// batcher RNG cursor), and drive the same loop to completion — bitwise
/// identical to a run that was never interrupted.
fn finetune_host_resume(o: ResumeOpts) -> Result<()> {
    use peqa::model::PackedModel;
    use peqa::serve::ModelGeom;
    use peqa::store::journal;
    use peqa::train::{HostPeqaTuner, Tuner, TunerState};

    let out = std::path::Path::new(&o.out_dir);
    let jpath = out.join(format!("{}.journal", o.task));
    if !jpath.is_file() {
        bail!(
            "--resume: no journal at {} — start the run with --save-every N \
             (and pass the same --task/--out)",
            jpath.display()
        );
    }
    let (meta, records, writer) = journal::open_resume(&jpath)?;

    fn pin<T: PartialEq + std::fmt::Display>(
        name: &str,
        cli: &Option<T>,
        journal: &T,
    ) -> Result<()> {
        if let Some(v) = cli {
            if v != journal {
                bail!(
                    "--{name} {v} disagrees with the journal's {journal} — drop the \
                     flag (the journal is authoritative) or start a fresh run"
                );
            }
        }
        Ok(())
    }
    pin("steps", &o.steps, &meta.steps)?;
    pin("batch", &o.batch, &meta.batch)?;
    pin("seq", &o.seq, &meta.seq)?;
    pin("heads", &o.heads, &meta.n_heads)?;
    pin("seed", &o.seed, &meta.seed)?;
    pin("save-every", &o.save_every, &meta.save_every)?;
    pin("dataset", &o.dataset, &meta.dataset)?;
    if let Some(lr) = o.lr {
        if lr.to_bits() != meta.lr_bits {
            bail!(
                "--lr {lr} disagrees with the journal's {} — drop the flag or start \
                 a fresh run",
                meta.lr()
            );
        }
    }
    if o.train_zeros && !meta.train_zeros {
        bail!(
            "--train-zeros disagrees with the journal (the run trains scales only) — \
             drop the flag or start a fresh run"
        );
    }

    let base_path = out.join(&meta.base);
    let pm = PackedModel::load(&base_path)?;
    let geom = ModelGeom::infer(&pm, meta.n_heads)?;
    let jgeom = ModelGeom {
        vocab: meta.vocab,
        d_model: meta.d_model,
        n_layers: meta.n_layers,
        n_heads: meta.n_heads,
        d_ff: meta.d_ff,
    };
    if geom != jgeom {
        bail!(
            "base snapshot {} has geometry {:?} but the journal pins {:?} — the \
             snapshot was replaced after the run started",
            base_path.display(),
            geom,
            jgeom
        );
    }
    let threads = peqa::util::num_threads();
    let mut cfg = pipeline::default_cfg(&format!("peqa_b{}_host", pm.bits), meta.steps, meta.seed);
    cfg.lr = meta.lr();
    cfg.warmup_steps = meta.warmup_steps;
    cfg.log_every = (meta.steps / 10).max(1);
    let base_model = pm.clone();
    let mut tuner = HostPeqaTuner::from_packed(pm, geom, cfg, meta.train_zeros, threads)?;
    let (train_s, eval_s) = pipeline::host_split(&meta.dataset, pipeline::ADAPT_BYTES)?;
    let mut batcher = peqa::data::LmBatcher::new(train_s, meta.batch, meta.seq, meta.seed ^ 0x5eed);

    if let Some((last, losses)) = journal::final_state(&records) {
        let step = usize::try_from(last.step)
            .map_err(|_| anyhow::anyhow!("journal step {} overflows usize", last.step))?;
        tuner.import_state(&TunerState {
            step,
            losses,
            ema: last.ema,
            params: last.params.clone(),
            opt_m: last.opt_m.clone(),
            opt_v: last.opt_v.clone(),
        })?;
        batcher.set_rng_state(last.rng.0, last.rng.1);
        println!(
            "resume: '{}' at step {}/{} from {} (+ base snapshot {})",
            meta.task,
            last.step,
            meta.steps,
            jpath.display(),
            meta.base
        );
    } else {
        println!(
            "resume: journal {} holds no durable records yet — replaying '{}' from \
             step 0",
            jpath.display(),
            meta.task
        );
    }

    run_single_task(SingleRun {
        tuner,
        batcher,
        writer: Some(writer),
        base_model,
        eval_s,
        task: meta.task.clone(),
        dataset: meta.dataset.clone(),
        out_dir: o.out_dir,
        steps: meta.steps,
        save_every: meta.save_every,
        halt_after: o.halt_after,
        publish: o.publish,
        gc_keep: o.gc_keep,
        eval_tokens: o.eval_tokens,
        heads: meta.n_heads,
        batch: meta.batch,
        seq: meta.seq,
        threads,
        train_zeros: meta.train_zeros,
        save_model: None,
    })
}

struct FinetuneMultiOpts {
    pm: peqa::model::PackedModel,
    geom: peqa::serve::ModelGeom,
    names: Vec<String>,
    steps: usize,
    lr: f64,
    batch: usize,
    seq: usize,
    heads: usize,
    train_zeros: bool,
    out_dir: String,
    save_model: Option<String>,
    eval_tokens: usize,
    seed: u64,
    threads: usize,
    publish: Option<String>,
    gc_keep: Option<usize>,
    save_every: usize,
    halt_after: usize,
}

/// Deterministic file stem for a multi-task run's journal + base
/// snapshot: the task list joined with `+` (the list is pinned in the
/// journal meta, so resume re-derives the same stem from `--tasks`).
fn multi_stem(names: &[String]) -> String {
    names.join("+")
}

/// Task corpus for multi-task tuning: named host datasets
/// (wikitext/ptb/pretrain) stream their corpus; any other task name gets
/// a deterministic synthetic motif corpus derived from the name —
/// distinct tasks get distinct learnable structure, so N-task demos do
/// not require N real datasets. Returns (train, eval) token streams.
fn task_split(name: &str, bytes: usize) -> Result<(Vec<u32>, Vec<u32>)> {
    if let Ok(split) = pipeline::host_split(name, bytes) {
        return Ok(split);
    }
    // FNV-1a over the task name seeds a repeating token motif.
    let mut seed = 0xcbf29ce484222325u64;
    for b in name.bytes() {
        seed ^= b as u64;
        seed = seed.wrapping_mul(0x100000001b3);
    }
    let mut rng = peqa::util::Pcg32::seeded(seed, 0x7a5c);
    let motif: Vec<u32> = (0..24).map(|_| rng.below(500)).collect();
    let stream: Vec<u32> = motif.iter().cycle().take(bytes.max(2_000)).cloned().collect();
    let cut = stream.len() * 4 / 5;
    Ok((stream[..cut].to_vec(), stream[cut..].to_vec()))
}

/// Multi-task round-robin PEQA tuning: N per-task scale/zero + Adam
/// states drive ONE shared packed model (`train::MultiTaskTuner`) —
/// each round steps every task once on its own corpus; each task's
/// trajectory is bitwise the single-task run, while the packed codes
/// are held in memory once. Writes `<task>.adapter` per task, all
/// servable together by one `peqa serve --adapters` invocation.
fn finetune_host_multi(o: FinetuneMultiOpts) -> Result<()> {
    use peqa::data::LmBatcher;
    use peqa::train::{HostPeqaTuner, MultiTaskTuner};

    let n = o.names.len();
    let mut cfg = pipeline::default_cfg(&format!("peqa_b{}_host", o.pm.bits), o.steps, o.seed);
    if o.lr > 0.0 {
        cfg.lr = o.lr;
    }
    cfg.log_every = 0; // per-task summaries are printed below
    let base_model = o.pm.clone();

    // Crash-safe mode (mirrors the single-task path): base snapshot +
    // ONE journal holding every task's slot, written before round 1.
    let writer = if o.save_every > 0 {
        let out = std::path::Path::new(&o.out_dir);
        let stem = multi_stem(&o.names);
        let base_name = format!("{stem}.base.packed");
        base_model.to_checkpoint().save_packed(&out.join(&base_name), base_model.bits)?;
        let meta = peqa::store::JournalMeta {
            task: o.names.join(","),
            tasks: o.names.clone(),
            // Multi-task corpora derive from the task names themselves
            // (task_split), so there is no separate dataset to pin.
            dataset: "multi".into(),
            base: base_name,
            seed: o.seed,
            steps: o.steps,
            save_every: o.save_every,
            batch: o.batch,
            seq: o.seq,
            lr_bits: cfg.lr.to_bits(),
            warmup_steps: cfg.warmup_steps,
            train_zeros: o.train_zeros,
            vocab: o.geom.vocab,
            d_model: o.geom.d_model,
            n_layers: o.geom.n_layers,
            n_heads: o.geom.n_heads,
            d_ff: o.geom.d_ff,
        };
        let w = peqa::store::JournalWriter::create(&out.join(format!("{stem}.journal")), &meta)?;
        println!(
            "journal: {} ({n} task slot(s) every {} round(s), base snapshot {})",
            w.path().display(),
            o.save_every,
            meta.base
        );
        Some(w)
    } else {
        None
    };

    let tuner = HostPeqaTuner::from_packed(o.pm, o.geom, cfg, o.train_zeros, o.threads)?;
    let mt = MultiTaskTuner::new(tuner, &o.names)?;
    let mut batchers = Vec::with_capacity(n);
    let mut evals = Vec::with_capacity(n);
    for (ti, name) in o.names.iter().enumerate() {
        let (train_s, eval_s) = task_split(name, pipeline::ADAPT_BYTES)?;
        batchers.push(LmBatcher::new(train_s, o.batch, o.seq, o.seed ^ 0x5eed ^ ti as u64));
        evals.push(eval_s);
    }

    run_multi_task(MultiRun {
        mt,
        batchers,
        evals,
        writer,
        base_model,
        names: o.names,
        out_dir: o.out_dir,
        steps: o.steps,
        save_every: o.save_every,
        halt_after: o.halt_after,
        publish: o.publish,
        gc_keep: o.gc_keep,
        eval_tokens: o.eval_tokens,
        heads: o.heads,
        batch: o.batch,
        seq: o.seq,
        threads: o.threads,
        save_model: o.save_model,
    })
}

/// Shared multi-task training drive (fresh and `--resume` runs both
/// funnel here, like [`run_single_task`]): the round-robin loop with an
/// optional per-round-checkpoint journal append of EVERY task slot, an
/// optional simulated crash, then per-task adapters + eval + publish.
struct MultiRun {
    mt: peqa::train::MultiTaskTuner,
    batchers: Vec<peqa::data::LmBatcher>,
    evals: Vec<Vec<u32>>,
    writer: Option<peqa::store::JournalWriter>,
    base_model: peqa::model::PackedModel,
    names: Vec<String>,
    out_dir: String,
    steps: usize,
    save_every: usize,
    halt_after: usize,
    publish: Option<String>,
    gc_keep: Option<usize>,
    eval_tokens: usize,
    heads: usize,
    batch: usize,
    seq: usize,
    threads: usize,
    save_model: Option<String>,
}

fn run_multi_task(mut o: MultiRun) -> Result<()> {
    use peqa::store::TrainRecord;

    let n = o.names.len();
    // Round-robin lockstep: every task is at the same round (resume
    // restores the last COMPLETE round, so this holds there too).
    let start = o.mt.step_count(0);
    let mut last_recorded = vec![start; n];
    // peqa-lint: allow(nondeterminism-sources) -- wall time for the
    // steps/s progress line only; training math is seeded.
    let t0 = std::time::Instant::now();
    for round in (start + 1)..=o.steps {
        for (ti, batcher) in o.batchers.iter_mut().enumerate() {
            let b = batcher.next_batch();
            o.mt.step_task(ti, &b)?;
        }
        if let Some(w) = o.writer.as_mut() {
            if (o.save_every > 0 && round % o.save_every == 0) || round == o.steps {
                // One record per task slot, slot order, same step — a
                // crash between these appends leaves a partial round
                // that open_resume_multi drops.
                for ti in 0..n {
                    let st = o.mt.export_task_state(ti)?;
                    w.append(&TrainRecord {
                        step: round as u64,
                        task_idx: ti as u32,
                        rng: o.batchers[ti].rng_state(),
                        ema: st.ema,
                        losses: st.losses[last_recorded[ti]..].to_vec(),
                        params: st.params,
                        opt_m: st.opt_m,
                        opt_v: st.opt_v,
                    })?;
                    last_recorded[ti] = round;
                }
            }
        }
        if o.halt_after > 0 && round >= o.halt_after && round < o.steps {
            println!(
                "halted after round {round}/{} (simulated crash) — continue with: \
                 peqa finetune --resume --tasks {} --out {}",
                o.steps,
                o.names.join(","),
                o.out_dir
            );
            return Ok(());
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let rounds_run = o.steps - start;
    println!(
        "finetune host multi-task: {n} tasks × {rounds_run} steps round-robin in {wall:.1}s \
         ({:.3}s/step) | one shared packed model ({}), per-task trainable+Adam {} \
         (total {})",
        wall / (rounds_run * n).max(1) as f64,
        peqa::util::human_bytes(o.mt.packed_bytes() as u64),
        peqa::util::human_bytes(o.mt.trainable_state_bytes()),
        peqa::util::human_bytes(o.mt.trainable_state_bytes_total()),
    );

    std::fs::create_dir_all(&o.out_dir)?;
    let mut published: Vec<(String, Checkpoint)> = Vec::new();
    for ti in 0..n {
        let name = o.names[ti].clone();
        let losses = o.mt.losses(ti).to_vec();
        let adapter = o.mt.extract_adapter(ti);
        let out_path = std::path::Path::new(&o.out_dir).join(format!("{name}.adapter"));
        adapter.save(&out_path)?;
        if o.publish.is_some() {
            published.push((name.clone(), adapter.clone()));
        }
        let ppl_note = if o.eval_tokens > 0 {
            let slice = &o.evals[ti][..o.evals[ti].len().min(o.eval_tokens)];
            let base_ppl = peqa::eval::host_perplexity(
                &o.base_model,
                o.heads,
                slice,
                o.batch,
                o.seq,
                o.threads,
            )?;
            let tuned_ppl = peqa::eval::host_perplexity(
                o.mt.model(ti),
                o.heads,
                slice,
                o.batch,
                o.seq,
                o.threads,
            )?;
            format!(" | ppl {base_ppl:.3} → {tuned_ppl:.3}")
        } else {
            String::new()
        };
        println!(
            "  task '{}': loss {:.4} → {:.4}{} | adapter → {}",
            name,
            losses.first().copied().unwrap_or(0.0),
            losses.last().copied().unwrap_or(0.0),
            ppl_note,
            out_path.display()
        );
    }
    if let Some(dir) = &o.publish {
        let reg = peqa::store::Registry::open(dir.as_str());
        let refs: Vec<(String, &Checkpoint)> =
            published.iter().map(|(t, a)| (t.clone(), a)).collect();
        let generation = reg.publish(&refs)?;
        println!(
            "published: {dir} generation {generation} ({n} task(s) in one atomic \
             generation) — a watching `peqa serve --registry {dir}` hot-reloads it"
        );
        if let Some(k) = o.gc_keep {
            let pruned = reg.gc(k)?;
            println!(
                "registry gc: pruned {} superseded adapter file(s) (keep-last {k})",
                pruned.len()
            );
        }
    }
    if let Some(p) = &o.save_model {
        println!(
            "serve all {n} tasks: peqa serve --model {p} --adapters {} --heads {} \
             --tasks {n}",
            o.out_dir, o.heads
        );
    }
    Ok(())
}

struct MultiResumeOpts {
    out_dir: String,
    names: Vec<String>,
    eval_tokens: usize,
    halt_after: usize,
    publish: Option<String>,
    gc_keep: Option<usize>,
    steps: Option<usize>,
    lr: Option<f64>,
    batch: Option<usize>,
    seq: Option<usize>,
    heads: Option<usize>,
    seed: Option<u64>,
    save_every: Option<usize>,
    train_zeros: bool,
}

/// Resume a journaled multi-task run: verify the journal (truncating a
/// torn tail AND any partial round), rebuild the shared packed model
/// from the base snapshot, restore EVERY task slot (scales/zeros, Adam
/// moments, batcher RNG, loss bookkeeping) from the last complete
/// round, and funnel into [`run_multi_task`] — the continued
/// round-robin is bitwise the uninterrupted run.
fn finetune_host_multi_resume(o: MultiResumeOpts) -> Result<()> {
    use peqa::model::PackedModel;
    use peqa::serve::ModelGeom;
    use peqa::store::journal;
    use peqa::train::{HostPeqaTuner, MultiTaskTuner, TunerState};

    let out = std::path::Path::new(&o.out_dir);
    let jpath = out.join(format!("{}.journal", multi_stem(&o.names)));
    if !jpath.is_file() {
        bail!(
            "--resume: no journal at {} — start the run with --save-every N \
             (and pass the same --tasks/--out)",
            jpath.display()
        );
    }
    let n = o.names.len();
    let (meta, records, writer) = journal::open_resume_multi(&jpath, n)?;
    if meta.tasks.is_empty() {
        bail!(
            "{} is a single-task journal — resume it with --task {}, not --tasks",
            jpath.display(),
            meta.task
        );
    }
    if meta.tasks != o.names {
        bail!(
            "--tasks {} disagrees with the journal's task list {} — the list (and \
             its order) is pinned when the run starts",
            o.names.join(","),
            meta.tasks.join(",")
        );
    }

    fn pin<T: PartialEq + std::fmt::Display>(
        name: &str,
        cli: &Option<T>,
        journal: &T,
    ) -> Result<()> {
        if let Some(v) = cli {
            if v != journal {
                bail!(
                    "--{name} {v} disagrees with the journal's {journal} — drop the \
                     flag (the journal is authoritative) or start a fresh run"
                );
            }
        }
        Ok(())
    }
    pin("steps", &o.steps, &meta.steps)?;
    pin("batch", &o.batch, &meta.batch)?;
    pin("seq", &o.seq, &meta.seq)?;
    pin("heads", &o.heads, &meta.n_heads)?;
    pin("seed", &o.seed, &meta.seed)?;
    pin("save-every", &o.save_every, &meta.save_every)?;
    if let Some(lr) = o.lr {
        if lr.to_bits() != meta.lr_bits {
            bail!(
                "--lr {lr} disagrees with the journal's {} — drop the flag or start \
                 a fresh run",
                meta.lr()
            );
        }
    }
    if o.train_zeros && !meta.train_zeros {
        bail!(
            "--train-zeros disagrees with the journal (the run trains scales only) — \
             drop the flag or start a fresh run"
        );
    }

    let base_path = out.join(&meta.base);
    let pm = PackedModel::load(&base_path)?;
    let geom = ModelGeom::infer(&pm, meta.n_heads)?;
    let jgeom = ModelGeom {
        vocab: meta.vocab,
        d_model: meta.d_model,
        n_layers: meta.n_layers,
        n_heads: meta.n_heads,
        d_ff: meta.d_ff,
    };
    if geom != jgeom {
        bail!(
            "base snapshot {} has geometry {:?} but the journal pins {:?} — the \
             snapshot was replaced after the run started",
            base_path.display(),
            geom,
            jgeom
        );
    }
    let threads = peqa::util::num_threads();
    let mut cfg = pipeline::default_cfg(&format!("peqa_b{}_host", pm.bits), meta.steps, meta.seed);
    cfg.lr = meta.lr();
    cfg.warmup_steps = meta.warmup_steps;
    cfg.log_every = 0; // per-task summaries are printed by run_multi_task
    let base_model = pm.clone();
    let tuner = HostPeqaTuner::from_packed(pm, geom, cfg, meta.train_zeros, threads)?;
    let mut mt = MultiTaskTuner::new(tuner, &meta.tasks)?;
    let mut batchers = Vec::with_capacity(n);
    let mut evals = Vec::with_capacity(n);
    for (ti, name) in meta.tasks.iter().enumerate() {
        let (train_s, eval_s) = task_split(name, pipeline::ADAPT_BYTES)?;
        batchers.push(peqa::data::LmBatcher::new(
            train_s,
            meta.batch,
            meta.seq,
            meta.seed ^ 0x5eed ^ ti as u64,
        ));
        evals.push(eval_s);
    }

    if let Some((round, per_task)) = journal::final_multi_state(&records, n) {
        for (ti, (rec, losses)) in per_task.iter().enumerate() {
            let step = usize::try_from(rec.step)
                .map_err(|_| anyhow::anyhow!("journal step {} overflows usize", rec.step))?;
            mt.import_task_state(
                ti,
                &TunerState {
                    step,
                    losses: losses.clone(),
                    ema: rec.ema,
                    params: rec.params.clone(),
                    opt_m: rec.opt_m.clone(),
                    opt_v: rec.opt_v.clone(),
                },
            )?;
            batchers[ti].set_rng_state(rec.rng.0, rec.rng.1);
        }
        println!(
            "resume: {n} task(s) at round {round}/{} from {} (+ base snapshot {})",
            meta.steps,
            jpath.display(),
            meta.base
        );
    } else {
        println!(
            "resume: journal {} holds no complete round yet — replaying all {n} \
             task(s) from round 0",
            jpath.display()
        );
    }

    run_multi_task(MultiRun {
        mt,
        batchers,
        evals,
        writer: Some(writer),
        base_model,
        names: meta.tasks.clone(),
        out_dir: o.out_dir,
        steps: meta.steps,
        save_every: meta.save_every,
        halt_after: o.halt_after,
        publish: o.publish,
        gc_keep: o.gc_keep,
        eval_tokens: o.eval_tokens,
        heads: meta.n_heads,
        batch: meta.batch,
        seq: meta.seq,
        threads,
        save_model: None,
    })
}

/// `peqa fsck`: verify every named artifact (directories expand to
/// their visible files) via [`peqa::store::fsck`]. Legacy formats print
/// as unverified but do not fail the command; corruption or truncation
/// anywhere exits nonzero.
fn fsck_cmd(paths: &[String]) -> Result<()> {
    use std::path::PathBuf;

    if paths.is_empty() {
        bail!("usage: peqa fsck <artifact|dir> [...]");
    }
    let mut files: Vec<PathBuf> = Vec::new();
    for p in paths {
        let pb = PathBuf::from(p);
        if pb.is_dir() {
            let mut entries: Vec<PathBuf> = std::fs::read_dir(&pb)
                .map_err(|e| anyhow::anyhow!("reading {}: {e}", pb.display()))?
                .filter_map(|e| e.ok().map(|e| e.path()))
                .filter(|p| p.is_file())
                .filter(|p| {
                    p.file_name()
                        .and_then(|s| s.to_str())
                        .is_some_and(|n| !n.starts_with('.'))
                })
                .collect();
            entries.sort();
            if entries.is_empty() {
                bail!("fsck: directory {} holds no files", pb.display());
            }
            files.extend(entries);
        } else {
            files.push(pb);
        }
    }
    let (mut corrupt, mut unverified) = (0usize, 0usize);
    for f in &files {
        match peqa::store::fsck(f) {
            Ok(r) => {
                for line in &r.lines {
                    println!("{line}");
                }
                if !r.verified {
                    unverified += 1;
                }
            }
            Err(e) => {
                println!("{}: FAILED — {e:#}", f.display());
                corrupt += 1;
            }
        }
    }
    println!(
        "fsck: {} file(s): {} verified, {} unverified, {} corrupt",
        files.len(),
        files.len() - corrupt - unverified,
        unverified,
        corrupt
    );
    if corrupt > 0 {
        bail!("fsck: {corrupt} corrupt file(s)");
    }
    Ok(())
}

/// `peqa lint`: run the in-tree static analysis ([`peqa::lint`]) over
/// the given paths (default `rust/src`). Deterministic
/// `file:line: rule: msg` output sorted by (file, line, rule); exits
/// nonzero on any finding. `--json` prints the machine-readable report
/// instead (same order); `--rule NAME` restricts to one rule
/// (allow-hygiene diagnostics always run); `--list` prints the rule
/// registry with the invariant each rule enforces.
fn lint_cmd(paths: &[String], rule: Option<&str>, list: bool, json: bool) -> Result<()> {
    if list {
        for r in peqa::lint::rules::all() {
            println!("{:24} {}", r.name, r.invariant);
        }
        println!(
            "{:24} {}",
            peqa::lint::ALLOW_HYGIENE,
            "suppressions must parse, name a known rule, sit on their own line, and \
             carry `-- <justification>` (always on, not suppressible)"
        );
        return Ok(());
    }
    let default_paths = vec!["rust/src".to_string()];
    let paths = if paths.is_empty() { &default_paths } else { paths };
    let diags = peqa::lint::run(paths, rule)?;
    if json {
        println!("{}", peqa::lint::to_json(&diags));
    } else {
        print!("{}", peqa::lint::render_text(&diags));
    }
    if !diags.is_empty() {
        bail!(
            "lint: {} finding(s) — fix, or exempt with \
             `// peqa-lint: allow(<rule>) -- <justification>`",
            diags.len()
        );
    }
    if !json {
        println!("lint: clean ({} path(s))", paths.len());
    }
    Ok(())
}

/// OPTQ quantization needs calibration Hessians from the `<size>_hess`
/// artifact, hence the PJRT runtime.
#[cfg(feature = "xla")]
fn optq_cmd(
    size: &str,
    fp: &Checkpoint,
    bits: u8,
    group: Option<usize>,
) -> Result<Checkpoint> {
    let ctx = Ctx::new()?;
    let calib = ctx.stream("pretrain", 40_000)?;
    let h = pipeline::hessians(&ctx, size, fp, &calib, 8)?;
    pipeline::optq_quantize(fp, &h, bits, group)
}

#[cfg(not(feature = "xla"))]
fn optq_cmd(
    _size: &str,
    _fp: &Checkpoint,
    _bits: u8,
    _group: Option<usize>,
) -> Result<Checkpoint> {
    bail!("--optq needs calibration artifacts — rebuild with `--features xla`")
}

/// Fine-tune two tiny task adapters, register them, serve a mixed request
/// stream, report throughput / latency / swap cost.
#[cfg(feature = "xla")]
fn serve_demo(size: &str, n_req: usize, full_reload: bool) -> Result<()> {
    let ctx = Ctx::new()?;
    let base = pipeline::ensure_base(&ctx, size, pipeline::pretrain_steps())?;
    info!("building task adapters (wikitext, ptb)…");
    let mut adapters = AdapterStore::new();
    let mut base_q: Option<Checkpoint> = None;
    for task in ["wikitext", "ptb"] {
        let (train_s, _) = ctx.split(task, pipeline::ADAPT_BYTES)?;
        let cfg = pipeline::default_cfg("peqa_b4_gc", 60, 1);
        let (ck, _) = pipeline::finetune(&ctx, size, "peqa_b4_gc", &base, &train_s, &cfg)?;
        if base_q.is_none() {
            base_q = Some(ck.clone());
        }
        adapters.insert(task, ck.extract_adapter(false));
    }
    // Scale-swap serving needs the quantized-layout artifact; sizes
    // without one (only n3/n4 ship logits_q) fall back to full-reload.
    let quant_art = format!("{size}_logits_q_b4_gc_b8");
    let have_quant = ctx.rt.meta(&quant_art).is_ok();
    let use_scale_swap = !full_reload && have_quant;
    let artifact = if use_scale_swap { quant_art } else { format!("{size}_logits_b8") };
    let mode = if use_scale_swap { SwitchMode::ScaleSwap } else { SwitchMode::FullReload };
    let mut coord = Coordinator::new(
        ctx.rt.clone(),
        &artifact,
        base_q.unwrap(),
        adapters,
        mode,
        BatcherConfig { max_batch: 8, ..Default::default() },
    )?;
    let mut rng = peqa::util::Pcg32::new(5);
    let prompts = ["the empire of", "shares of acme", "the battle of", "analysts expect"];
    for i in 0..n_req {
        let task = if rng.below(2) == 0 { "wikitext" } else { "ptb" };
        let prompt = ctx.tok.encode(prompts[i % prompts.len()]);
        coord.submit(task, prompt, 24, EOS);
    }
    let responses = coord.run_until_idle()?;
    for r in responses.iter().take(4) {
        let text = ctx.tok.decode(&r.tokens).unwrap_or_default();
        println!("[{}] {:10} {:?}", r.id, r.task, text);
    }
    let m = &coord.metrics;
    println!(
        "\nserved {} requests | {:.1} tok/s | p50 latency {:.3}s p99 {:.3}s | \
         {} task swaps, mean swap {:.4}s | mode: {}",
        m.completed,
        m.tokens_per_s(),
        m.p50_latency(),
        m.p99_latency(),
        m.swap_times_s.len(),
        m.mean_swap_s(),
        if use_scale_swap { "scale-swap (PEQA)" } else { "full-reload (PEFT+PTQ analog)" },
    );
    Ok(())
}

struct ServeOpts {
    model: Option<String>,
    adapters: Option<String>,
    registry: Option<String>,
    heads: usize,
    tasks: usize,
    requests: usize,
    max_new: usize,
    batch: usize,
    topk: usize,
    temp: f64,
    window: usize,
    seed: u64,
    bits: u8,
    group: Option<usize>,
    layers: usize,
    d_model: usize,
    d_ff: usize,
    vocab: usize,
    clients: usize,
    strict: bool,
    engines: usize,
    queue_cap: usize,
    deadline_ms: u64,
    affinity_burst: usize,
    stream: bool,
    watch_interval_ms: u64,
    /// Paged-KV pool size per engine; 0 serves per-sequence ring buffers.
    kv_pages: usize,
    /// Tokens per KV page (only read when `kv_pages > 0`).
    page_tokens: usize,
    /// When > 0, every request shares a deterministic prompt prefix of
    /// this many tokens (distinct final token per request) — the
    /// copy-on-write prefix-sharing workload.
    prefix_tokens: usize,
    /// Fail the run unless the paged backend actually attached shared
    /// prefix pages (`kv_pages_shared > 0`) — the CI smoke's assertion.
    require_shared: bool,
}

/// Host serving demo (no `xla` feature): decode a mixed multi-task
/// request stream from a packed model entirely through the fused
/// `quant::kernels` path, switching tasks by scale swap only.
///
/// With `--model`, serves an on-disk `.packed` file (adapters from
/// `--adapters <dir>` of `.adapter` files, or synthesized from the
/// model's own scales). Without it, synthesizes, RTN-quantizes and packs
/// a small base model in-process. With `--clients N` (N > 0) the same
/// request load is driven through the threaded `serve::Server` by N
/// concurrent client threads instead of the direct scheduler loop.
fn serve_host(o: ServeOpts) -> Result<()> {
    use peqa::model::PackedModel;
    use peqa::serve::{
        self, collect_stream, AdapterStore, Engine, EnginePool, ModelGeom, PoolConfig, Sampling,
        Scheduler, SchedulerConfig, ServeError, Server,
    };
    use peqa::tokenizer::{Tokenizer, EOS};

    let tok = Tokenizer::byte_level(512);
    let task_names: Vec<String> = ["wikitext", "ptb", "alpaca"]
        .iter()
        .map(|s| s.to_string())
        .chain((3..).map(|i| format!("task{i}")))
        .take(o.tasks.max(1))
        .collect();

    // Base model + per-task adapters.
    let (pm, base_view) = match &o.model {
        Some(p) => {
            let pm = PackedModel::load(std::path::Path::new(p))?;
            let view = pm.to_checkpoint();
            (pm, view)
        }
        None => {
            let geom = ModelGeom {
                vocab: o.vocab,
                d_model: o.d_model,
                n_layers: o.layers,
                n_heads: o.heads,
                d_ff: o.d_ff,
            };
            let (pm, q) = serve::synth_packed(&geom, o.bits, o.group, o.seed)?;
            (pm, q)
        }
    };
    let geom = ModelGeom::infer(&pm, o.heads)?;
    if o.registry.is_some() && o.adapters.is_some() {
        bail!("--registry and --adapters both name the adapter source; pick one");
    }
    let registry = o.registry.as_ref().map(|d| peqa::store::Registry::open(d.as_str()));
    let adapters = if let Some(reg) = &registry {
        // Registry mode: serve the current published generation (every
        // adapter checksum-verified by Registry::load); with --clients
        // the server also watches for newly published generations.
        let (generation, list) = reg.load()?;
        let mut store = AdapterStore::new();
        for (task, ck) in list {
            store.insert(task, ck);
        }
        println!(
            "registry: {} generation {generation} ({} task(s))",
            reg.dir().display(),
            store.tasks().len()
        );
        store
    } else {
        match &o.adapters {
            Some(dir) => AdapterStore::load_dir(std::path::Path::new(dir))?,
            None => {
                let names: Vec<&str> = task_names.iter().map(|s| s.as_str()).collect();
                serve::synth_adapters(&base_view, &names, o.seed ^ 0xad)
            }
        }
    };
    let tasks: Vec<String> = adapters.tasks().iter().map(|s| s.to_string()).collect();
    if tasks.is_empty() {
        bail!("no task adapters available");
    }
    let threads = peqa::util::num_threads();
    let packed_bytes = pm.packed_bytes();
    let adapter_bytes = adapters.total_bytes();
    let sampling = if o.topk == 0 {
        Sampling::Greedy
    } else {
        Sampling::TopK { k: o.topk, temperature: o.temp as f32 }
    };

    let sched_cfg = SchedulerConfig {
        max_batch: o.batch.max(1),
        window: o.window.max(1),
        sampling,
        seed: o.seed,
        strict_coverage: o.strict,
        kv_pages: o.kv_pages,
        page_tokens: o.page_tokens,
    };

    // Text prompts need the byte-level id range; a served model with a
    // smaller vocab gets deterministic in-vocab token prompts instead.
    let byte_level = geom.vocab >= 260;
    let texts = ["the empire of", "shares of acme", "the battle of", "analysts expect"];
    let prompts: Vec<Vec<u32>> = if o.prefix_tokens > 0 {
        // Prefix-sharing workload: every request repeats one
        // deterministic prefix and diverges only at the final token, so
        // a paged backend maps the prefix once (CoW-attached) while the
        // ring backend pays it per sequence.
        let mut rng = peqa::util::Pcg32::seeded(o.seed, 0x51a5);
        let prefix: Vec<u32> =
            (0..o.prefix_tokens).map(|_| rng.below(geom.vocab as u32)).collect();
        (0..o.requests.max(1) as u32)
            .map(|i| {
                let mut p = prefix.clone();
                p.push(i % geom.vocab as u32);
                p
            })
            .collect()
    } else if byte_level {
        texts.iter().map(|t| tok.encode(t)).collect()
    } else {
        let mut rng = peqa::util::Pcg32::seeded(o.seed, 0x9207);
        (0..texts.len())
            .map(|_| (0..12).map(|_| rng.below(geom.vocab as u32)).collect())
            .collect()
    };
    let (responses, m) = if o.engines > 0 {
        // Pool mode: N workers share the packed codes (Arc), each with
        // its own scales/zeros + KV + arena, fed task-affine from the
        // dispatcher's bounded per-task queues. Intra-op threads are
        // split across workers so N engines do not oversubscribe.
        let cfg = PoolConfig {
            engines: o.engines,
            max_batch: o.batch.max(1),
            window: o.window.max(1),
            sampling,
            seed: o.seed,
            strict_coverage: o.strict,
            queue_cap: o.queue_cap,
            deadline_ms: o.deadline_ms,
            affinity_burst: o.affinity_burst,
            kv_pages: o.kv_pages,
            page_tokens: o.page_tokens,
            watch_interval_ms: o.watch_interval_ms,
        };
        let per_engine = (threads / o.engines).max(1);
        let pool = match registry {
            Some(reg) => EnginePool::spawn_watching(pm, geom, per_engine, adapters, cfg, reg)?,
            None => EnginePool::spawn(pm, geom, per_engine, adapters, cfg)?,
        };
        let mut responses = Vec::new();
        let mut shed = 0usize;
        std::thread::scope(|s| -> Result<()> {
            let mut joins = Vec::new();
            for c in 0..o.clients.max(1) {
                let handle = pool.handle();
                let (tasks, prompts) = (&tasks, &prompts);
                let clients = o.clients.max(1);
                joins.push(s.spawn(
                    move || -> Result<(Vec<peqa::serve::GenResponse>, usize)> {
                        let mut got = Vec::new();
                        let mut shed = 0usize;
                        for i in (c..o.requests).step_by(clients) {
                            let task = &tasks[i % tasks.len()];
                            let prompt = prompts[i % prompts.len()].clone();
                            let r = if o.stream {
                                handle
                                    .submit_stream(task, prompt, o.max_new, EOS)
                                    .and_then(|rx| collect_stream(&rx).map(|(_, done)| done))
                            } else {
                                handle.submit(task, prompt, o.max_new, EOS)
                            };
                            match r {
                                Ok(resp) => got.push(resp),
                                // Admission control rejecting under load
                                // is the feature, not a failure.
                                Err(
                                    ServeError::Overloaded { .. }
                                    | ServeError::DeadlineExceeded { .. },
                                ) => shed += 1,
                                Err(e) => bail!("pool request failed: {e}"),
                            }
                        }
                        Ok((got, shed))
                    },
                ));
            }
            for j in joins {
                let (got, s) = j.join().expect("client thread panicked")?;
                responses.extend(got);
                shed += s;
            }
            Ok(())
        })?;
        let m = pool.shutdown();
        if shed > 0 {
            println!("admission control shed {shed} request(s) at the client");
        }
        responses.sort_by_key(|r| r.id);
        (responses, m)
    } else if o.clients > 0 {
        // Concurrent-client mode: one worker thread owns the scheduler;
        // N clients submit over the server's mpsc channel and block on
        // their own replies. Bursts admitted together share prefill
        // GEMMs. In registry mode the worker also polls the manifest
        // between bursts and hot-reloads new generations.
        let sched = Scheduler::new(Engine::from_packed(pm, geom, threads)?, adapters, sched_cfg)?;
        let server = match registry {
            Some(reg) => Server::spawn_watching_interval(sched, reg, o.watch_interval_ms)?,
            None => Server::spawn(sched)?,
        };
        let mut responses = Vec::new();
        std::thread::scope(|s| -> Result<()> {
            let mut joins = Vec::new();
            for c in 0..o.clients {
                let handle = server.handle();
                let (tasks, prompts) = (&tasks, &prompts);
                joins.push(s.spawn(move || -> Result<Vec<peqa::serve::GenResponse>> {
                    let mut got = Vec::new();
                    // Client c takes every o.clients-th request.
                    for i in (c..o.requests).step_by(o.clients) {
                        let task = &tasks[i % tasks.len()];
                        let prompt = prompts[i % prompts.len()].clone();
                        got.push(handle.generate(task, prompt, o.max_new, EOS)?);
                    }
                    Ok(got)
                }));
            }
            for j in joins {
                responses.extend(j.join().expect("client thread panicked")?);
            }
            Ok(())
        })?;
        let m = server.handle().metrics()?;
        server.shutdown();
        responses.sort_by_key(|r| r.id);
        (responses, m)
    } else {
        let mut sched =
            Scheduler::new(Engine::from_packed(pm, geom, threads)?, adapters, sched_cfg)?;
        for i in 0..o.requests {
            let task = &tasks[i % tasks.len()];
            let prompt = prompts[i % prompts.len()].clone();
            sched
                .submit(task, prompt, o.max_new, EOS)
                .map_err(|e| anyhow::anyhow!("request {i} rejected: {e}"))?;
        }
        let responses = sched.run_until_idle()?;
        let m = sched.metrics.clone();
        (responses, m)
    };
    for r in responses.iter().take(4) {
        if byte_level {
            let text = tok.decode(&r.tokens).unwrap_or_default();
            println!("[{}] {:10} {:?}", r.id, r.task, text);
        } else {
            println!("[{}] {:10} {:?}", r.id, r.task, r.tokens);
        }
    }
    let m = &m;
    println!(
        "\nserved {} requests over {} tasks | {:.1} tok/s | p50 latency {:.4}s p99 {:.4}s | \
         {} scale swaps, mean {:.6}s p99 {:.6}s | {} decode steps | {} prefill batches \
         ({} prompt tokens) | mode: scale-swap (PEQA, host{})",
        m.completed,
        tasks.len(),
        m.tokens_per_s(),
        m.p50_latency(),
        m.p99_latency(),
        m.swap_times_s.len(),
        m.mean_swap_s(),
        m.p99_swap_s(),
        m.decode_steps,
        m.prefill_batches,
        m.prefill_tokens,
        if o.engines > 0 {
            format!(
                ", {} pooled engines, {} client(s){}",
                o.engines,
                o.clients.max(1),
                if o.stream { ", streaming" } else { "" }
            )
        } else if o.clients > 0 {
            format!(", {} concurrent clients", o.clients)
        } else {
            String::new()
        },
    );
    if o.engines > 0 {
        println!(
            "pool: TTFT p50 {:.4}s p99 {:.4}s | inter-token p99 {:.6}s | queue depth max {} | \
             {} shed | {} swaps avoided (task-affine dispatch)",
            m.p50_ttft_s(),
            m.p99_ttft_s(),
            m.p99_inter_token_s(),
            m.queue_depth_max,
            m.shed_count,
            m.swaps_avoided,
        );
    }
    if o.kv_pages > 0 {
        println!(
            "paged kv: {} pages × {} tokens/page per engine | peak {} pages mapped | \
             {} shared prefix page(s) attached | {} request(s) rejected KvExhausted",
            o.kv_pages,
            o.page_tokens.max(1),
            m.kv_pages_peak,
            m.kv_pages_shared,
            m.kv_exhausted_count,
        );
        if o.require_shared && m.kv_pages_shared == 0 {
            bail!(
                "--require-shared: no prefix pages were shared (kv_pages_shared = 0); \
                 expected the paged backend to CoW-attach the common prompt prefix"
            );
        }
    }
    println!(
        "model: {} layers, d_model {}, {} heads, vocab {} | packed codes {} | adapters {} ({} tasks)",
        geom.n_layers,
        geom.d_model,
        geom.n_heads,
        geom.vocab,
        peqa::util::human_bytes(packed_bytes as u64),
        peqa::util::human_bytes(adapter_bytes),
        tasks.len(),
    );
    Ok(())
}

/// Table 1 / Fig. 2a at real LLaMA-65B dimensions.
fn memreport() {
    let geom = memmodel::Geometry::llama_65b();
    let lora_t = memmodel::lora_trainable(8192, 80, 2, 4);
    println!("LLaMA-65B ({} params)", geom.n_params());
    println!(
        "{:18} {:>10} {:>10}   {:9} {:9}   {:>12}",
        "Method", "FT DRAM", "Deploy", "Inference", "Switching", "Trainable"
    );
    for r in [
        memmodel::report(&geom, memmodel::Method::FullFt),
        memmodel::report(&geom, memmodel::Method::Peft { trainable_params: lora_t }),
        memmodel::report(&geom, memmodel::Method::PeftPtq { trainable_params: lora_t, bits: 4 }),
        memmodel::report(&geom, memmodel::Method::PtqPeft { trainable_params: lora_t, bits: 4 }),
        memmodel::report(&geom, memmodel::Method::Peqa { bits: 4, group: None }),
    ] {
        println!("{}", memmodel::fmt_row(&r));
    }
}
