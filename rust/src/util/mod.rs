//! Substrate utilities built from scratch (the vendored registry carries
//! no rand/log/serde): deterministic RNG, leveled logging, statistics.

pub mod log;
pub mod rng;
pub mod stats;
pub mod sync;

pub use rng::Pcg32;

/// Index of the maximum element under `total_cmp` — the NaN-safe
/// argmax for logits/score rows (ties break to the lowest index, NaN
/// sorts above every finite value instead of panicking the comparator).
/// `None` only on an empty slice.
pub fn argmax_f32(xs: &[f32]) -> Option<usize> {
    let mut best: Option<usize> = None;
    for (i, x) in xs.iter().enumerate() {
        match best {
            None => best = Some(i),
            Some(b) => {
                if x.total_cmp(&xs[b]) == std::cmp::Ordering::Greater {
                    best = Some(i);
                }
            }
        }
    }
    best
}

/// Worker-thread count for the host kernel layer (quant::kernels, the
/// blocked matmuls, the OPTQ linear algebra). `PEQA_THREADS` overrides;
/// defaults to the machine's available parallelism.
pub fn num_threads() -> usize {
    if let Some(n) = std::env::var("PEQA_THREADS").ok().and_then(|s| s.parse::<usize>().ok()) {
        return n.max(1);
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Human-readable byte sizes for memory tables ("33.4 GB", "1.2 MB").
pub fn human_bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KB", "MB", "GB", "TB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 { format!("{b} B") } else { format!("{v:.2} {}", UNITS[u]) }
}

/// Decimal gigabytes, the unit the paper's tables use (LLaMA-65B fp16 =
/// "131 GB", 4-bit PEQA = "33.45 GB").
pub fn decimal_gb(b: u64) -> String {
    format!("{:.2} GB", b as f64 / 1e9)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_is_nan_safe_and_first_wins_ties() {
        assert_eq!(argmax_f32(&[]), None);
        assert_eq!(argmax_f32(&[1.0, 3.0, 2.0]), Some(1));
        assert_eq!(argmax_f32(&[2.0, 2.0, 1.0]), Some(0));
        // NaN sorts above finite values under total_cmp — but it must
        // not panic, which is the property the old partial_cmp argmax
        // lacked.
        assert_eq!(argmax_f32(&[1.0, f32::NAN, 2.0]), Some(1));
    }

    #[test]
    fn bytes_formatting() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KB");
        assert_eq!(human_bytes(33 * 1024 * 1024 * 1024), "33.00 GB");
        assert_eq!(decimal_gb(33_450_000_000), "33.45 GB");
    }
}
