//! Deterministic PCG32 random number generator.
//!
//! The vendored registry has no `rand` crate, so the repo carries its own
//! small, well-tested generator. PCG-XSH-RR 64/32 (O'Neill 2014): 64-bit
//! LCG state, 32-bit xorshift-rotate output. Streams are selected by the
//! odd increment, so `Pcg32::seeded(seed, stream)` gives independent
//! sequences for data, init, scheduling, …
//!
//! Every randomized component in the repo (corpus generation, param init,
//! property tests, batch shuffling) goes through this type, which is what
//! makes runs reproducible end-to-end from a single CLI `--seed`.

/// PCG-XSH-RR 64/32 generator.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Generator with explicit seed and stream id.
    pub fn seeded(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    pub fn new(seed: u64) -> Self {
        Self::seeded(seed, 0xda3e39cb94b95bdb)
    }

    /// The raw `(state, inc)` pair — the exact stream position, for
    /// checkpointing (the training journal's data-stream cursor).
    pub fn state_raw(&self) -> (u64, u64) {
        (self.state, self.inc)
    }

    /// Rebuild a generator at an exact `(state, inc)` position captured
    /// by [`Self::state_raw`] — the restored stream continues
    /// bit-for-bit. `inc` must be odd (every constructor makes it so).
    pub fn from_state(state: u64, inc: u64) -> Pcg32 {
        assert!(inc & 1 == 1, "PCG increment must be odd (got {inc:#x})");
        Pcg32 { state, inc }
    }

    /// Derive an independent child generator (for per-task streams).
    pub fn fork(&mut self, tag: u64) -> Pcg32 {
        let seed = (self.next_u32() as u64) << 32 | self.next_u32() as u64;
        Pcg32::seeded(seed, tag.wrapping_mul(0x9e3779b97f4a7c15) | 1)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1 << 24) as f32)
    }

    /// Uniform integer in [0, bound) without modulo bias (Lemire).
    #[inline]
    pub fn below(&mut self, bound: u32) -> u32 {
        debug_assert!(bound > 0);
        let mut x = self.next_u32();
        let mut m = (x as u64) * (bound as u64);
        let mut l = m as u32;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u32();
                m = (x as u64) * (bound as u64);
                l = m as u32;
            }
        }
        (m >> 32) as u32
    }

    #[inline]
    pub fn usize_below(&mut self, bound: usize) -> usize {
        self.below(bound as u32) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f32().max(1e-12);
        let u2 = self.f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Fill with N(0, std²).
    pub fn fill_normal(&mut self, buf: &mut [f32], std: f32) {
        for x in buf.iter_mut() {
            *x = self.normal() * std;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick one element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_below(xs.len())]
    }

    /// Sample an index from unnormalized weights.
    pub fn weighted(&mut self, weights: &[f32]) -> usize {
        let total: f32 = weights.iter().sum();
        let mut t = self.f32() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_stream_independent() {
        let mut a = Pcg32::seeded(42, 1);
        let mut b = Pcg32::seeded(42, 1);
        let mut c = Pcg32::seeded(42, 2);
        let xs: Vec<u32> = (0..8).map(|_| a.next_u32()).collect();
        let ys: Vec<u32> = (0..8).map(|_| b.next_u32()).collect();
        let zs: Vec<u32> = (0..8).map(|_| c.next_u32()).collect();
        assert_eq!(xs, ys);
        assert_ne!(xs, zs);
    }

    #[test]
    fn uniform_moments() {
        let mut rng = Pcg32::new(7);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| rng.f32() as f64).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg32::new(9);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal() as f64).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn below_is_unbiased_at_small_bounds() {
        let mut rng = Pcg32::new(3);
        let mut counts = [0u32; 3];
        for _ in 0..30_000 {
            counts[rng.below(3) as usize] += 1;
        }
        for c in counts {
            assert!((c as i64 - 10_000).abs() < 600, "{counts:?}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg32::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_respects_weights() {
        let mut rng = Pcg32::new(11);
        let mut hit = [0u32; 2];
        for _ in 0..10_000 {
            hit[rng.weighted(&[1.0, 9.0])] += 1;
        }
        assert!(hit[1] > 8 * hit[0], "{hit:?}");
    }
}
