//! Minimal leveled logger + wall-clock timers.
//!
//! `PEQA_LOG={error,warn,info,debug,trace}` controls verbosity (default
//! `info`). All output goes to stderr so stdout stays machine-parseable
//! (bench tables, CSV dumps).

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

#[derive(Clone, Copy, PartialEq, PartialOrd, Debug)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX);

fn level() -> u8 {
    let v = LEVEL.load(Ordering::Relaxed);
    if v != u8::MAX {
        return v;
    }
    let parsed = match std::env::var("PEQA_LOG").as_deref() {
        Ok("error") => 0,
        Ok("warn") => 1,
        Ok("debug") => 3,
        Ok("trace") => 4,
        _ => 2,
    };
    LEVEL.store(parsed, Ordering::Relaxed);
    parsed
}

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn enabled(l: Level) -> bool {
    (l as u8) <= level()
}

pub fn log(l: Level, module: &str, msg: std::fmt::Arguments) {
    if enabled(l) {
        eprintln!("[{:5}] {}: {}", format!("{l:?}").to_lowercase(), module, msg);
    }
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Info, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Warn, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Debug, module_path!(), format_args!($($arg)*))
    };
}

/// RAII wall-clock timer: logs at Debug on drop; `elapsed_s()` for manual use.
pub struct Timer {
    label: String,
    start: Instant,
    logged: bool,
}

impl Timer {
    pub fn new(label: impl Into<String>) -> Self {
        Timer { label: label.into(), start: Instant::now(), logged: false }
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn stop(mut self) -> f64 {
        self.logged = true;
        let dt = self.elapsed_s();
        log(Level::Debug, "timer", format_args!("{}: {:.3}s", self.label, dt));
        dt
    }
}

impl Drop for Timer {
    fn drop(&mut self) {
        if !self.logged {
            let dt = self.elapsed_s();
            log(Level::Debug, "timer", format_args!("{}: {:.3}s", self.label, dt));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_order() {
        assert!(Level::Error < Level::Trace);
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }

    #[test]
    fn timer_measures() {
        let t = Timer::new("t");
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(t.stop() >= 0.004);
    }
}
