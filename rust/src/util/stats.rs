//! Summary statistics for benchmarks and evaluation: mean/std, percentiles,
//! exponential moving averages, and a tiny online accumulator.

/// Online mean/variance (Welford) — used by the trainer's metrics.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
}

/// Percentile by linear interpolation over a sorted copy (q in [0, 100]).
///
/// NaN-safe: samples come from latency/loss streams that can contain
/// NaN (a failed step, a poisoned metric), and `total_cmp` orders them
/// deterministically at the top instead of panicking mid-report the way
/// a `partial_cmp(..).unwrap()` comparator does.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let pos = (q / 100.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi { v[lo] } else { v[lo] + (pos - lo as f64) * (v[hi] - v[lo]) }
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() { 0.0 } else { xs.iter().sum::<f64>() / xs.len() as f64 }
}

pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Exponential moving average used for smoothed loss curves.
#[derive(Clone, Debug)]
pub struct Ema {
    alpha: f64,
    value: Option<f64>,
}

impl Ema {
    pub fn new(alpha: f64) -> Self {
        Ema { alpha, value: None }
    }

    pub fn push(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(v) => v + self.alpha * (x - v),
        };
        self.value = Some(v);
        v
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }

    /// Overwrite the smoothed value — state restore (training resume)
    /// only; normal updates go through [`Self::push`].
    pub fn set(&mut self, value: Option<f64>) {
        self.value = value;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_batch() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut w = Welford::default();
        for x in xs {
            w.push(x);
        }
        assert!((w.mean() - mean(&xs)).abs() < 1e-12);
        assert!((w.std() - std(&xs)).abs() < 1e-12);
        assert_eq!(w.count(), 5);
    }

    #[test]
    fn percentiles() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&xs, 50.0), 2.5);
    }

    #[test]
    fn percentile_survives_nan_samples() {
        // Regression: the old partial_cmp(..).unwrap() comparator
        // panicked the moment a NaN metric sample reached a percentile
        // report. total_cmp sorts NaN above every finite value, so low
        // percentiles still answer from the finite samples.
        let xs = [3.0, f64::NAN, 1.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 2.5);
        assert!(percentile(&xs, 100.0).is_nan());
        let all_nan = [f64::NAN, f64::NAN];
        assert!(percentile(&all_nan, 50.0).is_nan());
    }

    #[test]
    fn ema_converges() {
        let mut e = Ema::new(0.5);
        for _ in 0..32 {
            e.push(2.0);
        }
        assert!((e.get().unwrap() - 2.0).abs() < 1e-6);
    }
}
