//! Poison-recovering mutex/condvar helpers — the one sanctioned way to
//! take a lock on the serving and store paths.
//!
//! Std mutexes poison when a holder panics, and the habitual
//! `.lock().unwrap()` then *cascades* that panic into every other
//! thread touching the lock — one crashed pool worker would take the
//! dispatcher, the metrics merge, and ultimately the whole pool down
//! with it. Every critical section in this crate holds plain data
//! (queue state, metrics counters, adapter slots) with no multi-step
//! invariant that a mid-section panic could tear, so recovery via
//! [`std::sync::PoisonError::into_inner`] is sound: availability over
//! poison propagation. The `peqa lint` rule `panic-free-paths` bans
//! `lock().unwrap()` in `serve::`/`store::`; these helpers are the
//! replacement (and keep the acquisition sites greppable).

use std::sync::{Condvar, Mutex, MutexGuard};

/// Lock `m`, recovering the guard if a previous holder panicked.
pub fn lock_clean<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// Try-lock `m`: `None` only when the lock is genuinely held
/// (`WouldBlock`); a poisoned-but-free lock is recovered like
/// [`lock_clean`].
pub fn try_lock_clean<T>(m: &Mutex<T>) -> Option<MutexGuard<'_, T>> {
    use std::sync::TryLockError;
    match m.try_lock() {
        Ok(g) => Some(g),
        Err(TryLockError::Poisoned(poisoned)) => Some(poisoned.into_inner()),
        Err(TryLockError::WouldBlock) => None,
    }
}

/// Block on `cv` with `g`, recovering the reacquired guard if another
/// holder panicked while we slept.
pub fn wait_clean<'a, T>(cv: &Condvar, g: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    match cv.wait(g) {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    #[test]
    fn lock_clean_recovers_after_a_panicked_holder() {
        let m = Mutex::new(7usize);
        // Poison the mutex: a scoped thread panics while holding it.
        let panicked = std::thread::scope(|s| {
            s.spawn(|| {
                let _g = m.lock().unwrap();
                panic!("deliberate: poison the lock");
            })
            .join()
        });
        assert!(panicked.is_err(), "holder must have panicked");
        assert!(m.is_poisoned());
        // The plain unwrap path would now panic; lock_clean recovers.
        assert_eq!(*lock_clean(&m), 7);
        *lock_clean(&m) = 8;
        assert_eq!(*lock_clean(&m), 8);
    }

    #[test]
    fn try_lock_clean_distinguishes_held_from_poisoned() {
        let m = Mutex::new(1usize);
        let g = m.lock().unwrap();
        assert!(try_lock_clean(&m).is_none(), "held lock is WouldBlock");
        drop(g);
        assert_eq!(*try_lock_clean(&m).expect("free lock"), 1);
    }
}
