//! Host-side f32 tensors: shape-checked buffers with exactly the linear
//! algebra the coordinator needs (checkpoint transforms, LoRA merging,
//! OPTQ, dequantization). Heavy math belongs in the AOT'd XLA artifacts;
//! this module is deliberately small and obvious.

use anyhow::{bail, Result};

use crate::util::Pcg32;

#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        Tensor::new(shape, vec![0.0; shape.iter().product()])
    }

    pub fn ones(shape: &[usize]) -> Self {
        Tensor::new(shape, vec![1.0; shape.iter().product()])
    }

    pub fn full(shape: &[usize], v: f32) -> Self {
        Tensor::new(shape, vec![v; shape.iter().product()])
    }

    pub fn normal(shape: &[usize], std: f32, rng: &mut Pcg32) -> Self {
        let mut data = vec![0.0; shape.iter().product()];
        rng.fill_normal(&mut data, std);
        Tensor::new(shape, data)
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Number of rows/cols for 2-D tensors.
    pub fn dims2(&self) -> Result<(usize, usize)> {
        match self.shape.as_slice() {
            [n, m] => Ok((*n, *m)),
            s => bail!("expected 2-D tensor, got {s:?}"),
        }
    }

    #[inline]
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.shape[1] + j]
    }

    #[inline]
    pub fn set2(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.shape[1] + j] = v;
    }

    pub fn reshape(mut self, shape: &[usize]) -> Result<Self> {
        if shape.iter().product::<usize>() != self.data.len() {
            bail!("reshape {:?} -> {:?} size mismatch", self.shape, shape);
        }
        self.shape = shape.to_vec();
        Ok(self)
    }

    pub fn t(&self) -> Tensor {
        let (n, m) = self.dims2().expect("transpose needs 2-D");
        let mut out = vec![0.0f32; n * m];
        for i in 0..n {
            for j in 0..m {
                out[j * n + i] = self.data[i * m + j];
            }
        }
        Tensor::new(&[m, n], out)
    }

    /// C = A @ B for 2-D tensors — cache-blocked over output columns and
    /// parallelized over output rows (std::thread::scope; no rayon in the
    /// vendored registry). Each output row keeps the naive ikj accumulation
    /// order, so results are bit-identical to [`Self::matmul_naive`] and
    /// invariant to the thread count.
    pub fn matmul(&self, b: &Tensor) -> Result<Tensor> {
        let (n, k) = self.dims2()?;
        let (k2, m) = b.dims2()?;
        if k != k2 {
            bail!("matmul {:?} @ {:?}", self.shape, b.shape);
        }
        let mut out = vec![0.0f32; n * m];
        if n == 0 || m == 0 || k == 0 {
            return Ok(Tensor::new(&[n, m], out));
        }
        // Column blocks keep one out/B stripe pair L1-resident for big m.
        const JB: usize = 512;
        let row_block = |i0: usize, orows: &mut [f32]| {
            for (ii, orow) in orows.chunks_mut(m).enumerate() {
                let arow = &self.data[(i0 + ii) * k..(i0 + ii + 1) * k];
                for j0 in (0..m).step_by(JB) {
                    let j1 = (j0 + JB).min(m);
                    for (p, &a) in arow.iter().enumerate() {
                        if a == 0.0 {
                            continue;
                        }
                        let brow = &b.data[p * m + j0..p * m + j1];
                        for (o, &bv) in orow[j0..j1].iter_mut().zip(brow) {
                            *o += a * bv;
                        }
                    }
                }
            }
        };
        let threads = crate::util::num_threads().min(n).max(1);
        // Small products are not worth the spawn overhead.
        if threads == 1 || n * k * m < (1 << 16) {
            row_block(0, &mut out);
        } else {
            let chunk_rows = n.div_ceil(threads);
            std::thread::scope(|s| {
                for (t, chunk) in out.chunks_mut(chunk_rows * m).enumerate() {
                    let row_block = &row_block;
                    s.spawn(move || row_block(t * chunk_rows, chunk));
                }
            });
        }
        Ok(Tensor::new(&[n, m], out))
    }

    /// The seed's single-threaded ikj matmul, kept as the numerics
    /// baseline: `matmul` must match it bitwise (tested), and the kernel
    /// benches use it as the "before" reference path.
    pub fn matmul_naive(&self, b: &Tensor) -> Result<Tensor> {
        let (n, k) = self.dims2()?;
        let (k2, m) = b.dims2()?;
        if k != k2 {
            bail!("matmul {:?} @ {:?}", self.shape, b.shape);
        }
        let mut out = vec![0.0f32; n * m];
        for i in 0..n {
            for p in 0..k {
                let a = self.data[i * k + p];
                if a == 0.0 {
                    continue;
                }
                let brow = &b.data[p * m..(p + 1) * m];
                let orow = &mut out[i * m..(i + 1) * m];
                for j in 0..m {
                    orow[j] += a * brow[j];
                }
            }
        }
        Ok(Tensor::new(&[n, m], out))
    }

    pub fn add_scaled(&mut self, other: &Tensor, scale: f32) -> Result<()> {
        if self.shape != other.shape {
            bail!("add_scaled shape mismatch {:?} vs {:?}", self.shape, other.shape);
        }
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += scale * b;
        }
        Ok(())
    }

    pub fn frob_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_known() {
        let a = Tensor::new(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::new(&[2, 2], vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b).unwrap();
        assert_eq!(c.data(), &[3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_rectangular_and_transpose() {
        let a = Tensor::new(&[1, 3], vec![1.0, 2.0, 3.0]);
        let b = Tensor::new(&[3, 2], vec![1.0, 0.0, 0.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b).unwrap();
        assert_eq!(c.shape(), &[1, 2]);
        assert_eq!(c.data(), &[4.0, 5.0]);
        let bt = b.t();
        assert_eq!(bt.shape(), &[2, 3]);
        assert_eq!(bt.at2(0, 2), 1.0);
        assert_eq!(bt.at2(1, 1), 1.0);
    }

    #[test]
    fn blocked_parallel_matmul_is_bitwise_naive() {
        let mut rng = Pcg32::new(9);
        // Odd sizes straddle the column-block and row-chunk boundaries;
        // the large case crosses the parallel threshold.
        for (n, k, m) in [(3usize, 5usize, 7usize), (33, 65, 129), (40, 64, 1030)] {
            let a = Tensor::normal(&[n, k], 1.0, &mut rng);
            let b = Tensor::normal(&[k, m], 1.0, &mut rng);
            let fast = a.matmul(&b).unwrap();
            let slow = a.matmul_naive(&b).unwrap();
            assert_eq!(fast.data(), slow.data(), "({n},{k},{m})");
        }
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Pcg32::new(1);
        let a = Tensor::normal(&[5, 7], 1.0, &mut rng);
        assert_eq!(a.t().t(), a);
    }

    #[test]
    fn shape_errors() {
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[2, 3]);
        assert!(a.matmul(&b).is_err());
        assert!(a.clone().reshape(&[7]).is_err());
        assert!(a.clone().reshape(&[3, 2]).is_ok());
    }

    #[test]
    fn add_scaled() {
        let mut a = Tensor::ones(&[2, 2]);
        let b = Tensor::full(&[2, 2], 2.0);
        a.add_scaled(&b, 0.5).unwrap();
        assert_eq!(a.data(), &[2.0, 2.0, 2.0, 2.0]);
    }
}
