//! # peqa — Memory-Efficient Fine-Tuning of Compressed LLMs (PEQA)
//!
//! Rust reproduction of *Memory-Efficient Fine-Tuning of Compressed Large
//! Language Models via sub-4-bit Integer Quantization* (NeurIPS 2023) —
//! the L3 coordinator of a three-layer rust + JAX + Pallas stack.
//!
//! Layers (see DESIGN.md):
//! - **L1** Pallas kernels (`python/compile/kernels/`): RTN quantization,
//!   fused dequant-matmul, fused scale gradients.
//! - **L2** jax transformer + in-graph AdamW (`python/compile/`), AOT-lowered
//!   once to HLO text artifacts (`make artifacts`).
//! - **L3** this crate: PJRT runtime, data pipeline, trainer, quantization
//!   toolchain (RTN / OPTQ / sub-4-bit packing), multi-task serving
//!   coordinator, eval harness, memory model and bench framework. Python
//!   never runs at request time.

pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod json;
pub mod memmodel;
pub mod model;
pub mod pipeline;
pub mod quant;
pub mod runtime;
pub mod tensor;
pub mod tokenizer;
pub mod train;
pub mod util;
