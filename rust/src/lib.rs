//! # peqa — Memory-Efficient Fine-Tuning of Compressed LLMs (PEQA)
//!
//! Rust reproduction of *Memory-Efficient Fine-Tuning of Compressed Large
//! Language Models via sub-4-bit Integer Quantization* (NeurIPS 2023) —
//! the L3 coordinator of a three-layer rust + JAX + Pallas stack.
//!
//! Layers (see DESIGN.md):
//! - **L1** Pallas kernels (`python/compile/kernels/`): RTN quantization,
//!   fused dequant-matmul, fused scale gradients.
//! - **L2** jax transformer + in-graph AdamW (`python/compile/`), AOT-lowered
//!   once to HLO text artifacts (`make artifacts`).
//! - **L3** this crate: PJRT runtime, data pipeline, trainer, quantization
//!   toolchain (RTN / OPTQ / sub-4-bit packing), multi-task serving
//!   coordinator, eval harness, memory model and bench framework. Python
//!   never runs at request time.
//!
//! ## Host kernel layer (`quant::kernels`)
//!
//! The crate's own hot path is the fused quantized GEMM
//! `y = X · (s·(codes − z))ᵀ`, computed **directly from bit-packed
//! sub-4-bit codes** — word-at-a-time unpacking into per-thread group
//! tiles, scale/zero application fused into the inner product via the
//! group-sum identity, cache-blocked, and row-parallel over
//! `std::thread::scope`. `quant::PackedMatrix` is the packed in-memory
//! weight format (row-aligned bit-packed codes + f32 scale/zero tensors);
//! `model::PackedModel` is the `.packed`-file load path that keeps codes
//! packed end to end. Dense fallbacks (`tensor::Tensor::matmul`, the OPTQ
//! linear algebra in `quant::linalg`) are blocked and parallelized with
//! the same deterministic row-sharding, so every result is bit-identical
//! at any thread count (`PEQA_THREADS` pins the worker count).
//!
//! ### SIMD dispatch (`quant::simd`, `PEQA_SIMD`)
//!
//! The inner loops of the packed GEMM family (`matmul_t`,
//! `matmul_t_ragged`, `matvec_t`, `grad_input`, `grad_scales_zeros`)
//! and the dense LM-head kernels (`model::blocks::dense_rows_into` /
//! `dense_grad_rows_into`) run through a function table
//! ([`quant::simd::SimdOps`]) chosen **once per process**: AVX2 when
//! `is_x86_feature_detected!("avx2")` says so, NEON on aarch64, the
//! scalar baseline otherwise — `PEQA_SIMD=scalar` forces the baseline,
//! `auto`/unset takes the detected tier. The contract is that every
//! tier is **bitwise identical** to the scalar loops, which constrains
//! the vectorization shape:
//!
//! - lanes run across *independent output elements* (batch columns,
//!   weight rows), never across the reduction index, so each output
//!   keeps exactly one accumulator advanced in the same ascending-j
//!   order as the scalar code;
//! - vector code uses separate multiply and add (`_mm256_mul_ps` +
//!   `_mm256_add_ps`, `vmulq_f32` + `vaddq_f32`), **never FMA** — a
//!   fused multiply-add skips the intermediate rounding the scalar
//!   `mul` + `add` performs and changes low bits;
//! - `a * b` vs `b * a` operand order may differ between tiers — IEEE
//!   754 multiplication is commutative in value and bit pattern for
//!   the non-NaN inputs these kernels see;
//! - the 2-bit path additionally expands eight packed codes per 16-bit
//!   load with two masked u64 multiplies ([`quant::simd::spread8`],
//!   cross-checked in debug builds by a popcount identity). A full
//!   popcount *dot product* restructure was deliberately not used: it
//!   would reassociate the float accumulation and break bitwise parity
//!   with the scalar tier.
//!
//! The scalar loops are kept verbatim as the reference; parity tests
//! fuzz every tier against them across bits × grouping × shape ×
//! thread count, and `tests/simd_dispatch.rs` re-runs a whole
//! decode/train workload under `PEQA_SIMD=scalar` vs `auto` and
//! compares digests. All `unsafe` in the crate is confined to
//! `quant::simd` (lint rule `unsafe-confined`).
//!
//! ## The transformer compute core (`model::blocks`)
//!
//! One set of llama-family block primitives — RMSNorm (+ inverse-norm
//! capture), rotary apply/backward, fixed-order head-blocked causal
//! attention over either a KV-cache window or a full-sequence tape
//! (`blocks::Tape`), SwiGLU forward/backward, the dense LM-head
//! kernels, and the packed-projection call (fused GEMM through a shared
//! `ProjScratch`, picking the ragged direct-layout entry
//! `PackedMatrix::matmul_t_ragged` or the yᵀ scratch entry — bitwise
//! identical either way). Its consumers:
//!
//! | Consumer | Drives the core as |
//! |---|---|
//! | `serve::engine` | KV-cache decode/prefill (`Tape`-less windowed attention) |
//! | `train::HostPeqaTuner` | full-sequence forward + tape, reverse mode (`TapeArena`) |
//! | `eval::host_perplexity` | forward-only loss (tape-less, one arena across batches) |
//!
//! Because both forwards are the same fixed-order functions, the
//! trainer-vs-engine parity test pins **bitwise** equality and every
//! numeric or perf change to the block math lands exactly once.
//!
//! ## Host serving (`serve`)
//!
//! The default build *serves*, not just quantizes/packs: `serve::engine`
//! decodes autoregressively from a `model::PackedModel` with every block
//! projection running through the fused packed GEMM (embedding gather,
//! RMSNorm, head-blocked rotary attention over per-sequence
//! `serve::kvcache` ring buffers, SwiGLU MLP, fp LM head) out of a
//! per-engine scratch arena (no steady-state allocation), and
//! `serve::scheduler` continuously batches multi-task traffic with
//! cross-request prefill batching, switching tasks by swapping only the
//! f32 scale/zero tensors — the packed integer codes are immutable and
//! uncovered projections revert to base scales (the paper's scale-swap
//! deployment contract, residue-free). `serve::server` wraps the
//! scheduler in a worker thread so concurrent clients submit/await over
//! a channel. The request/response/metrics vocabulary lives in
//! `serve::types` and is shared with the xla coordinator. `peqa serve`
//! runs the CLI demo; `benches/serve_decode.rs` writes
//! `BENCH_serve.json` (tokens/s, latency p50/p99, swap p99, pooled TTFT
//! and inter-token percentiles).
//!
//! ## Serving at scale (`serve::pool` + `serve::dispatch`)
//!
//! One packed model, N engines: because a task is only f32 scale/zero
//! vectors, `PackedModel::clone` shares the bit-packed codes behind an
//! `Arc` and deep-copies kilobytes — so an N-worker pool costs one
//! model's DRAM plus N adapter-sized slots, the paper's deployment
//! economics applied to horizontal scale-out:
//!
//! ```text
//!                         ┌──────────────────────────────┐
//!  clients ── submit ──▶  │ dispatch::Dispatcher         │
//!           (bounded      │  per-task FIFO queues        │
//!            per-task     │  deadline shedding           │
//!            ingress)     │  task-affine handout         │
//!                         └──────┬───────────┬───────────┘
//!                                ▼           ▼
//!                         worker 0 …   worker N−1     (serve::pool)
//!                         Scheduler    Scheduler    ← per-worker s/z,
//!                         Engine+KV    Engine+KV      KV, arena
//!                                └─── Arc<[u8]> ───┘ ← ONE copy of the
//!                                                      packed codes
//! ```
//!
//! * **Admission control** (`serve::dispatch`): every task gets a
//!   bounded ingress queue; a submit past the cap is rejected *at submit
//!   time* with the typed `ServeError::Overloaded` (nothing queued,
//!   nothing decoded), and requests that sat queued past a deadline are
//!   shed with `ServeError::DeadlineExceeded` instead of burning decode
//!   steps on an answer nobody awaits.
//! * **Task-affine dispatch**: a worker sticks with its current task for
//!   up to `affinity_burst` batches while older work of another task
//!   waits — each such batch is a scale swap avoided (counted in
//!   `ServeMetrics::swaps_avoided`) — then yields to the oldest waiting
//!   head, so no task starves.
//! * **Token streaming**: `PoolHandle::submit_stream` returns a bounded
//!   channel fed one `StreamEvent::Token` per accepted token, then a
//!   terminal `Done` carrying the full response. Streaming is an extra
//!   send at the acceptance site, never a different decode: the tokens
//!   are bitwise what the non-streaming `submit` returns, and a slow
//!   consumer blocks only its own decode slot (backpressure by
//!   construction of the bounded channel).
//! * **Hot-reload**: `EnginePool::spawn_watching` polls the adapter
//!   registry between bursts (rate-limited by `watch_interval_ms`); one
//!   worker validates a new generation against its own scheduler, then
//!   every other worker adopts it lock-free via a version counter.
//!
//! Greedy decode is batch-composition- and thread-count-invariant, so a
//! pooled response is bitwise the single-scheduler response regardless
//! of which worker served it (`tests/serve_pool.rs` pins 1/2/4 engines
//! against a direct drain). `peqa serve --engines N` drives the pool;
//! `--queue-cap`, `--deadline-ms`, `--affinity-burst`, `--stream` and
//! `--watch-interval-ms` expose the knobs above.
//!
//! ## Paged KV memory (`serve::kvpage`)
//!
//! With `--kv-pages P > 0` the per-sequence ring buffers give way to a
//! paged KV backend: one fixed pool of `P` pages × `--page-tokens`
//! tokens per engine, allocated by a word-scan bitmap
//! (`kvpage::PageAllocator`), mapped per sequence through a page table
//! (`kvpage::PagedKvCache`), and shared across same-task requests whose
//! prompts fork from a common prefix — full prefix pages are attached
//! copy-on-write (refcounted `Arc` pages + a hash trie keyed on (task,
//! parent, token chunk)) instead of being prefilled again:
//!
//! ```text
//!  seq A  [pg 7][pg 2][pg 9]          page table per sequence
//!  seq B  [pg 7][pg 2][pg 4]...       shared prefix pages 7,2 (CoW,
//!  seq C  [pg 7][pg 2][pg 4][pg 1]    refcounted; diverge → own page)
//!              └── PagePool: bitmap allocator over P fixed pages ──┘
//! ```
//!
//! The contract is the ring's: slot = abs_pos % window, window_len =
//! min(abs_pos+1, window) — an N-segment page walk in ascending position
//! order is bitwise the 2-slab ring attention, so paged decode emits
//! **bitwise** the ring backend's tokens at any page size, thread count,
//! or sharing pattern (`tests/serve_paged.rs`; the ring is kept as the
//! oracle). Requests that could never fit (`ceil((prompt+max_new)/page)`
//! capped at the window's pages > pool) are rejected at submit with the
//! typed `ServeError::KvExhausted`; prompts past the window reject as
//! `ServeError::PromptTooLong`. Completion recycles pages, so a pool a
//! fraction of the offered load serves the whole backlog;
//! `ServeMetrics::{kv_pages_peak, kv_pages_shared, kv_exhausted_count}`
//! land in `BENCH_serve.json` and the `scripts/ci.sh` smoke asserts a
//! same-prefix burst under a tight budget actually shares
//! (`--require-shared`).
//!
//! | Flag | Effect |
//! |---|---|
//! | `--kv-pages P` | P > 0 serves from a P-page pool per engine (paged backend); 0 = per-sequence ring buffers. |
//! | `--page-tokens T` | Tokens per KV page (default `serve::DEFAULT_PAGE_TOKENS`). |
//! | `--prefix-tokens N` | Demo workload: every request shares one deterministic N-token prompt prefix (distinct final token) — the CoW sharing shape. |
//! | `--require-shared` | Exit nonzero unless `kv_pages_shared > 0` — the CI assertion that prefix sharing really happened. |
//!
//! ## Training backends (`train`)
//!
//! Fine-tuning sits behind the backend-agnostic `train::Tuner` trait.
//! The default build ships the **host PEQA backend**
//! (`train::HostPeqaTuner`): forward AND backward through the shared
//! `model::blocks` compute core (the serving forward plus a tape),
//! activations in a reusable `train::TapeArena` (no per-step activation
//! allocation; `benches/finetune_step.rs` counts allocator traffic),
//! attention forward/backward sharded over `std::thread::scope`
//! workers, gradients only w.r.t. the per-(row, group)
//! scale/zero tensors (straight-through estimator, integer codes
//! frozen), shared `train::Adam` state that is kilobytes next to the
//! packed codes. A training step is bit-identical at any `PEQA_THREADS`
//! value. `train::MultiTaskTuner` round-robins N per-task scale/zero +
//! Adam states over ONE shared packed model (`peqa finetune --tasks`),
//! bitwise equal to N independent runs. `peqa finetune` drives it end
//! to end: quantized model + task
//! corpus → a `.adapter` file that `peqa serve` scale-swaps directly;
//! `eval::host_perplexity` scores the result in the same build, so the
//! paper's quantize → PEQA-tune → scale-swap-serve loop closes on host.
//! With `--features xla` the artifact-driven `train::Trainer` implements
//! the same trait (`peqa finetune --backend xla`).
//! `benches/finetune_step.rs` writes `BENCH_finetune.json` (step time,
//! trainable+optimizer bytes, loss trajectory) and is gated by
//! `scripts/bench_diff.py` like the kernel/serve benches.
//!
//! ## Artifact formats & durability (`store`)
//!
//! Every artifact the crate writes — `.peqa` checkpoints, `.adapter`
//! task adapters, `.packed` deployment models, the adapter-registry
//! manifest — goes inside one checksummed container (`store::format`):
//!
//! ```text
//! "PEQAS1\n" | u32 version | kind | section table (name, len, crc32)
//!   | u32 header-crc | payloads | u32 trailer-crc(header+payloads)
//! ```
//!
//! Writes are atomic (temp sibling + fsync + rename), so a crash never
//! leaves a half-written artifact under the real name; any flipped or
//! truncated byte is detected at load with the file, section and
//! expected-vs-actual checksum in the error. Legacy `PEQA1`/`PEQAP1`
//! files still load, flagged unverified. `peqa fsck <path>` verifies any
//! artifact and prints its header.
//!
//! **Crash-safe training** (`store::journal`): `peqa finetune
//! --save-every N` writes a base `.peqa` snapshot plus an append-only
//! journal (`PEQAJ1\n` header, then per-record `[len | crc32 | payload]`
//! frames carrying step, full scale/zero state, Adam moments, loss/EMA
//! bookkeeping and the batcher RNG cursor). `--resume` replays snapshot
//! + journal — truncating a torn tail frame — and continues **bitwise
//! identically** to a run that never stopped, via
//! `train::Tuner::{export_state, import_state}`.
//!
//! **Atomic adapter hot-reload** (`store::registry`): `peqa finetune
//! --publish DIR` publishes adapters into a registry directory under a
//! generation-numbered manifest (manifest written last, atomically, so
//! readers see either the old or the new generation, never a mix). A
//! live `serve::Server` watches the registry between request bursts and
//! swaps adapters in without restart; an invalid or torn adapter set is
//! rejected (strict `serve::types::validate_coverage`) and the previous
//! generation keeps serving.
//!
//! ## Correctness tooling (`lint`, `peqa lint`)
//!
//! The invariants above — bitwise reproducibility, panic-free
//! serving/store paths, allocation-free compute cores — are enforced at
//! the *source* level by an in-tree static analysis (`peqa lint
//! [paths] [--rule NAME] [--list] [--json]`, module [`lint`]): a
//! dependency-free hand-rolled Rust lexer plus token-pattern rules,
//! deterministic `file:line: rule: msg` output, nonzero exit on any
//! finding. `scripts/ci.sh` gates on `peqa lint rust/src` before the
//! test suite. The crate root used to pin `#![deny(unsafe_code)]`;
//! with the SIMD kernels that blanket ban became the `unsafe-confined`
//! rule — `unsafe` is legal only inside `quant::simd`, and every
//! occurrence there must sit under a `// SAFETY:` comment.
//!
//! | Rule | Invariant it enforces | Why it is load-bearing for PEQA |
//! |---|---|---|
//! | `nan-comparator` | no `partial_cmp(..).unwrap()`-style comparators; key with `total_cmp` | metrics/logits can be NaN; a sort comparator that panics (or lies) turns one bad float into a crashed server — the exact bug class fixed in `serve::engine` (PR 3) and again in `util::stats`/`eval` here |
//! | `panic-free-paths` | no `unwrap`/`expect`/`panic!`-family in non-test `serve::`/`store::` code (that includes the `serve::kvpage` allocator/page tables — a bad page index must surface as a typed error, not an indexing panic mid-decode) | a panic in serving drops live traffic; in the store it can poison a checkpoint mid-write; mutex poison routes through `util::sync::{lock_clean, try_lock_clean, wait_clean}` |
//! | `hot-path-alloc` | no `Vec::new`/`vec!`/`to_vec`/`format!`/`String::from`/`.clone()` in `quant::kernels`/`model::blocks`/`quant::simd` | `ProjScratch`/`TapeArena`/`KernelScratch` exist precisely so steady-state decode/train steps never allocate (allocs/step is a gated bench metric) |
//! | `float-reduction-order` | no iterator `.sum::<f32>()`/`.product`/float `fold` in the kernel modules | one explicit accumulation order is the bitwise thread/batch-invariance contract the parity tests pin |
//! | `unsafe-confined` | `unsafe` only inside `quant::simd`, and there only under a `// SAFETY:` comment | the SIMD intrinsics are the one deliberate unsafe surface; the rule replaces the old crate-wide `#![deny(unsafe_code)]` without letting unsafe leak into the rest of the tree |
//! | `lock-across-blocking` | no mutex guard lexically live across `.recv()`/`.send()`/`.join()` in `serve::` | the pool's bounded channels make lock-then-block a real deadlock shape, not a style nit |
//! | `nondeterminism-sources` | no `HashMap`/`HashSet` in artifact/numeric paths; no `Instant::now`/`SystemTime` outside bench/`util::stats`/`util::log`; no bare `thread::spawn` | hash-order iteration, wall-clock reads and detached threads are the three ways "bitwise identical" quietly stops being true |
//!
//! Exemptions are written in the source, next to the code, with a
//! mandatory justification:
//!
//! ```text
//! // peqa-lint: allow(<rule>[, <rule>]) -- <why this site is sound>
//! ```
//!
//! on its own line directly above the exempted code; the allow covers
//! the next syntactic unit (one statement, or a whole `fn`/`impl` body
//! when placed above its header). A bare allow without `--
//! justification`, an unknown rule name, or an allow trailing code on
//! the same line is itself a finding (`allow-hygiene`) and suppresses
//! nothing. Adding a rule = one entry in `lint::rules::all()` plus a
//! positive/near-miss fixture pair in `rust/tests/fixtures/lint/`
//! (see the module docs of [`lint`]).
//!
//! ## Environment knobs
//!
//! The single reference for every `PEQA_*` variable the crate and its
//! scripts read:
//!
//! | Variable | Effect |
//! |---|---|
//! | `PEQA_THREADS` | Worker-thread count of the host kernel layer ([`util::num_threads`]) — serving *and* the host training backend; results are bit-identical at any value. Defaults to available parallelism. |
//! | `PEQA_SIMD` | Kernel tier of the packed/dense hot loops ([`quant::simd::active`]): `scalar` forces the baseline loops, `auto`/unset dispatches on the host (AVX2 / NEON / scalar). Read once per process; results are bit-identical either way. |
//! | `PEQA_BENCH_QUICK` | `1` shrinks every bench (model size / request volume / step count) to smoke scale; `0`/unset runs full size ([`bench::quick_mode`]). `scripts/ci.sh` sets it (`--full` clears it). |
//! | `PEQA_BENCH_OUT` | Absolute output path for a bench's JSON result file (`BENCH_kernels.json`, `BENCH_serve.json`, `BENCH_finetune.json`); defaults to the repo root. |
//! | `PEQA_BENCH_DIM` | Overrides the GEMM dimension of `benches/kernels_micro.rs`. |
//! | `PEQA_BENCH_STEPS` | Step-count override for the train benches ([`bench::steps`]), including `benches/finetune_step.rs`. |
//! | `PEQA_PRETRAIN_STEPS` | Step-count override for the xla pretraining pipeline. |
//! | `PEQA_LOG` | Log level of [`util::log`] (`debug`/`info`/`warn`/`error`). |
//! | `PEQA_SKIP_TREND` | `1` lets `scripts/ci.sh` pass without `python3` by skipping the bench trend diff (otherwise a missing interpreter fails CI loudly). |
//! | `PEQA_SKIP_PYCHECK` | `1` skips the f64 numpy cross-check of the host backward (`python/checks/host_backward_check.py`) in `scripts/ci.sh`; it runs whenever `python3 -c "import numpy"` succeeds. |
//! | `PEQA_SANITIZE` | `1` makes `scripts/ci.sh` additionally run the serve/store test suites under Miri (preferred) or ThreadSanitizer on hosts whose toolchain has them; prints a clear skip message otherwise. Off by default — the sanitizer pass is minutes, not seconds. |
//!
//! And the serving-scale knobs of `peqa serve` (CLI flags, same names as
//! the `serve::PoolConfig` fields):
//!
//! | Flag | Effect |
//! |---|---|
//! | `--engines N` | N > 0 serves through the sharded `serve::pool` (N workers over one `Arc`-shared set of packed codes) instead of a single scheduler. |
//! | `--queue-cap M` | Bounded per-task ingress: submits past M queued requests are rejected with the typed `ServeError::Overloaded` (0 = unbounded). |
//! | `--deadline-ms D` | Requests queued longer than D ms are shed with `ServeError::DeadlineExceeded` at dispatch (0 = no deadline). |
//! | `--affinity-burst B` | Batches a worker may stay on its current task while older other-task work waits, each one a scale swap avoided (0 = plain FIFO). |
//! | `--stream` | Clients receive tokens over a per-request bounded channel as they are accepted; tokens stay bitwise identical to non-streaming. |
//! | `--watch-interval-ms W` | Minimum gap between registry hot-reload polls, for the pool and the `--clients` server (0 = poll every burst). |
//! | `--gc-keep K` | (`peqa finetune --publish`) after publishing, prune superseded registry generation files, keeping each task's K newest plus the live manifest's. |
//!
//! ## Feature `xla`
//!
//! The PJRT execution half (`runtime::pjrt`, `train`, `coordinator`, and
//! the artifact-driven parts of `eval`/`pipeline`) is gated behind the
//! `xla` feature because it needs the vendored `xla` crate, which is not
//! in the public registry (see rust/Cargo.toml). The default build is the
//! full host-side stack: tensors, quantization, packed formats, fused
//! kernels, the `serve` decode engine and scheduler, data/tokenizer,
//! memory model, and the bench framework.

pub mod bench;
pub mod cli;
pub mod config;
#[cfg(feature = "xla")]
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod json;
pub mod lint;
pub mod memmodel;
pub mod model;
pub mod pipeline;
pub mod quant;
pub mod runtime;
pub mod serve;
pub mod store;
pub mod tensor;
pub mod tokenizer;
pub mod train;
pub mod util;
