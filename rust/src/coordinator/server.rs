//! Threaded server wrapper around the single-threaded [`Coordinator`].
//!
//! PJRT handles are not `Send`, so the whole engine (runtime + compiled
//! executables + device buffers) lives on one engine thread; clients talk
//! to it over channels — the same frontend/engine split vLLM's router
//! uses. Requests carry a oneshot-style response channel.

use std::path::PathBuf;
use std::sync::mpsc;
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use super::{AdapterStore, BatcherConfig, Coordinator, GenResponse, ServeMetrics, SwitchMode};
use crate::model::Checkpoint;
use crate::runtime::Runtime;

enum Msg {
    Generate {
        task: String,
        prompt: Vec<u32>,
        max_new: usize,
        stop: u32,
        reply: mpsc::Sender<Result<GenResponse, String>>,
    },
    Metrics {
        reply: mpsc::Sender<ServeMetrics>,
    },
    Shutdown,
}

/// Client handle (cheaply cloneable; safe to move across threads).
#[derive(Clone)]
pub struct ServerHandle {
    tx: mpsc::Sender<Msg>,
}

impl ServerHandle {
    /// Blocking generate call.
    pub fn generate(
        &self,
        task: &str,
        prompt: Vec<u32>,
        max_new: usize,
        stop: u32,
    ) -> Result<GenResponse> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Msg::Generate { task: task.to_string(), prompt, max_new, stop, reply })
            .map_err(|_| anyhow!("server is down"))?;
        rx.recv().map_err(|_| anyhow!("server dropped request"))?.map_err(|e| anyhow!(e))
    }

    pub fn metrics(&self) -> Result<ServeMetrics> {
        let (reply, rx) = mpsc::channel();
        self.tx.send(Msg::Metrics { reply }).map_err(|_| anyhow!("server is down"))?;
        rx.recv().map_err(|_| anyhow!("server dropped request"))
    }
}

pub struct Server {
    handle: ServerHandle,
    join: Option<JoinHandle<()>>,
}

pub struct ServerConfig {
    pub artifacts_dir: PathBuf,
    pub artifact_name: String,
    pub base_path: PathBuf,
    pub adapters_dir: PathBuf,
    pub scale_swap: bool,
    pub max_batch: usize,
}

impl Server {
    /// Spawn the engine thread. Construction errors surface on first call.
    pub fn spawn(cfg: ServerConfig) -> Result<Server> {
        let (tx, rx) = mpsc::channel::<Msg>();
        let join = std::thread::Builder::new()
            .name("peqa-engine".into())
            .spawn(move || engine_main(cfg, rx))?;
        Ok(Server { handle: ServerHandle { tx }, join: Some(join) })
    }

    pub fn handle(&self) -> ServerHandle {
        self.handle.clone()
    }

    pub fn shutdown(mut self) {
        let _ = self.handle.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        let _ = self.handle.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn engine_main(cfg: ServerConfig, rx: mpsc::Receiver<Msg>) {
    let build = || -> Result<Coordinator> {
        let rt = std::rc::Rc::new(Runtime::new(&cfg.artifacts_dir)?);
        let base = Checkpoint::load(&cfg.base_path)?;
        let adapters = AdapterStore::load_dir(&cfg.adapters_dir)?;
        Coordinator::new(
            rt,
            &cfg.artifact_name,
            base,
            adapters,
            if cfg.scale_swap { SwitchMode::ScaleSwap } else { SwitchMode::FullReload },
            BatcherConfig { max_batch: cfg.max_batch, ..Default::default() },
        )
    };
    let mut coord = match build() {
        Ok(c) => c,
        Err(e) => {
            // Fail every request with the construction error.
            let msg = format!("engine init failed: {e:#}");
            for m in rx.iter() {
                match m {
                    Msg::Generate { reply, .. } => {
                        let _ = reply.send(Err(msg.clone()));
                    }
                    Msg::Metrics { reply } => {
                        let _ = reply.send(ServeMetrics::default());
                    }
                    Msg::Shutdown => return,
                }
            }
            return;
        }
    };

    // Collect a burst of requests, then batch-decode — the dynamic batcher.
    let mut waiting: Vec<(u64, mpsc::Sender<Result<GenResponse, String>>)> = Vec::new();
    loop {
        // Block for at least one message; then drain whatever arrived.
        let first = match rx.recv() {
            Ok(m) => m,
            Err(_) => return,
        };
        let mut batch_msgs = vec![first];
        while let Ok(m) = rx.try_recv() {
            batch_msgs.push(m);
        }
        let mut shutdown = false;
        for m in batch_msgs {
            match m {
                Msg::Generate { task, prompt, max_new, stop, reply } => {
                    let id = coord.submit(&task, prompt, max_new, stop);
                    waiting.push((id, reply));
                }
                Msg::Metrics { reply } => {
                    let _ = reply.send(coord.metrics.clone());
                }
                Msg::Shutdown => shutdown = true,
            }
        }
        if coord.pending() > 0 {
            match coord.run_until_idle() {
                Ok(responses) => {
                    for resp in responses {
                        if let Some(pos) = waiting.iter().position(|(id, _)| *id == resp.id) {
                            let (_, reply) = waiting.swap_remove(pos);
                            let _ = reply.send(Ok(resp));
                        }
                    }
                }
                Err(e) => {
                    let msg = format!("decode failed: {e:#}");
                    for (_, reply) in waiting.drain(..) {
                        let _ = reply.send(Err(msg.clone()));
                    }
                }
            }
        }
        if shutdown {
            return;
        }
    }
}
