//! L3 coordination — multi-task serving of a shared quantized model.
//!
//! The paper's deployment story (§3.3, Table 1): one frozen integer model
//! is shared by every downstream task; a task is just a scale vector
//! (s₀+Δs), so task switching is a kilobyte-sized buffer swap and
//! inference runs through the quantized kernel. This module implements
//! that as a serving coordinator:
//!
//! * [`AdapterStore`] / [`GenRequest`] / [`GenResponse`] /
//!   [`BatcherConfig`] / [`ServeMetrics`] — the serving vocabulary,
//!   re-exported from [`crate::serve::types`] so this artifact-driven
//!   coordinator and the host `serve` engine speak one request/metrics
//!   language (the types compile without the `xla` feature).
//! * [`Coordinator`] — request queue + task-aware dynamic batcher +
//!   batched greedy decode over a logits artifact. On the quantized path
//!   (`logits_q`) a task switch swaps only the s/z device buffers; the
//!   fp fallback path must rebuild every weight buffer (the "Slow"
//!   switching column of Table 1, measurable in the serving bench).
//! * [`server`] — thread + channel wrapper for concurrent clients.

pub mod server;

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::eval::EvalModel;
use crate::model::Checkpoint;
use crate::runtime::Runtime;
use crate::tokenizer::PAD;

pub use crate::serve::types::{AdapterStore, BatcherConfig, GenRequest, GenResponse, ServeMetrics};

/// How task switches reach the device (the Table 1 "Task-Switching" axis).
pub enum SwitchMode {
    /// PEQA: swap only the adapter's s/z buffers on the quantized model.
    ScaleSwap,
    /// Merged-weights serving (PEFT+PTQ analog): any task change requires
    /// re-uploading every weight buffer of the dequantized model.
    FullReload,
}

pub struct Coordinator {
    rt: std::rc::Rc<Runtime>,
    model: EvalModel,
    artifact_name: String,
    base: Checkpoint,
    /// FullReload only: the base dequantized once at startup through the
    /// fused kernel layer. A task switch then re-dequantizes only the
    /// projections the adapter actually touches instead of the whole model
    /// (memory-for-latency trade on the deliberately-slow baseline path).
    fp_base: Option<Checkpoint>,
    adapters: AdapterStore,
    mode: SwitchMode,
    current_task: Option<String>,
    queue: VecDeque<(GenRequest, Instant)>,
    next_id: u64,
    pub batcher: BatcherConfig,
    pub metrics: ServeMetrics,
}

impl Coordinator {
    /// `base` must be in the layout of `artifact_name` (peqa layout for
    /// `logits_q` — the fast path; fp layout for plain `logits`, in which
    /// case adapters trigger a dequantize + full reload).
    pub fn new(
        rt: std::rc::Rc<Runtime>,
        artifact_name: &str,
        base: Checkpoint,
        adapters: AdapterStore,
        mode: SwitchMode,
        batcher: BatcherConfig,
    ) -> Result<Coordinator> {
        if batcher.strict_coverage {
            // Same registration contract as the host serve::Scheduler
            // (one shared gate: serve::types::validate_coverage).
            crate::serve::types::validate_coverage(&base.quantized_prefixes(), &adapters)?;
        }
        let fp_base = match mode {
            SwitchMode::ScaleSwap => None,
            SwitchMode::FullReload => Some(base.dequantize()?),
        };
        let serving_ck = fp_base.as_ref().unwrap_or(&base);
        let model = EvalModel::new(&rt, artifact_name, serving_ck)?;
        let max_b = model.batch_size();
        Ok(Coordinator {
            rt,
            model,
            artifact_name: artifact_name.to_string(),
            base,
            fp_base,
            adapters,
            mode,
            current_task: None,
            queue: VecDeque::new(),
            next_id: 1,
            batcher: BatcherConfig { max_batch: batcher.max_batch.min(max_b), ..batcher },
            metrics: ServeMetrics::default(),
        })
    }

    pub fn submit(&mut self, task: &str, prompt: Vec<u32>, max_new: usize, stop: u32) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        // peqa-lint: allow(nondeterminism-sources) -- submission stamp
        // for queue/latency metrics; it never reaches decoded output.
        self.queue.push_back((
            GenRequest { id, task: task.to_string(), prompt, max_new, stop },
            Instant::now(),
        ));
        id
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Switch the served task; returns the swap wall time.
    fn switch_task(&mut self, task: &str) -> Result<f64> {
        if self.current_task.as_deref() == Some(task) {
            return Ok(0.0);
        }
        // peqa-lint: allow(nondeterminism-sources) -- the swap wall time
        // IS the reported measurement (paper Table 4); tokens are
        // unaffected.
        let t0 = Instant::now();
        let adapter = self
            .adapters
            .get(task)
            .ok_or_else(|| anyhow!("no adapter registered for task '{task}'"))?
            .clone();
        match self.mode {
            SwitchMode::ScaleSwap => {
                // Only the s/z buffers move — the integer matrix stays.
                for (name, t) in adapter.iter() {
                    self.model.swap_param(&self.rt, name, t)?;
                }
            }
            SwitchMode::FullReload => {
                // An adapter that replaces integer codes or BCQ tensors
                // changes derived weights the incremental path below does
                // not recompute — take the full apply + dequantize route.
                let replaces_codes = adapter.names().iter().any(|n| {
                    n.ends_with(".wq")
                        || n.ends_with(".alpha1")
                        || n.ends_with(".alpha_rest")
                        || n.ends_with(".code")
                });
                if replaces_codes {
                    let mut ck = self.base.clone();
                    ck.apply_adapter(&adapter)?;
                    let fp = ck.dequantize()?;
                    self.model = EvalModel::new(&self.rt, &self.artifact_name, &fp)?;
                } else {
                    // Scale/zero-only adapter (the common case): rebuild
                    // from the cached fused-dequantized base,
                    // re-dequantizing only the projections this adapter
                    // touches (kernels layer) instead of the seed's
                    // clone → apply → dequantize-everything per switch.
                    let fp_base = self
                        .fp_base
                        .as_ref()
                        .ok_or_else(|| anyhow!("FullReload without fp_base"))?;
                    let mut ck = fp_base.clone();
                    let mut prefixes: Vec<String> = Vec::new();
                    for (name, t) in adapter.iter() {
                        let Some(base_t) = self.base.get(name) else {
                            bail!("adapter tensor '{name}' not present in base model");
                        };
                        // Same shape contract apply_adapter enforced:
                        // a mis-grouped s/z must fail loudly, not serve
                        // weights dequantized with the wrong grouping.
                        if base_t.shape() != t.shape() {
                            bail!("adapter tensor '{name}' shape mismatch");
                        }
                        let quant_prefix = name
                            .strip_suffix(".s")
                            .or_else(|| name.strip_suffix(".z"))
                            .filter(|p| self.base.get(&format!("{p}.wq")).is_some());
                        if let Some(p) = quant_prefix {
                            if !prefixes.iter().any(|q| q == p) {
                                prefixes.push(p.to_string());
                            }
                        } else if ck.get(name).is_some() {
                            ck.insert(name.clone(), t.clone());
                        }
                        // else: quant bookkeeping tensor with no fp-layout
                        // counterpart — nothing to overlay.
                    }
                    for p in &prefixes {
                        let wq = self.base.req(&format!("{p}.wq"))?;
                        let s_name = format!("{p}.s");
                        let z_name = format!("{p}.z");
                        let s =
                            adapter.get(&s_name).map(Ok).unwrap_or_else(|| self.base.req(&s_name))?;
                        let z =
                            adapter.get(&z_name).map(Ok).unwrap_or_else(|| self.base.req(&z_name))?;
                        ck.insert(format!("{p}.w"), crate::model::dequantize_tensor(wq, s, z)?);
                    }
                    self.model = EvalModel::new(&self.rt, &self.artifact_name, &ck)?;
                }
            }
        }
        let dt = t0.elapsed().as_secs_f64();
        self.metrics.swap_times_s.push(dt);
        self.current_task = Some(task.to_string());
        Ok(dt)
    }

    /// Pull the next same-task group (FIFO head decides the task).
    fn next_group(&mut self) -> Option<Vec<(GenRequest, Instant)>> {
        let task = self.queue.front()?.0.task.clone();
        let mut group = Vec::new();
        let mut rest = VecDeque::new();
        while let Some(item) = self.queue.pop_front() {
            if item.0.task == task && group.len() < self.batcher.max_batch {
                group.push(item);
            } else {
                rest.push_back(item);
            }
        }
        self.queue = rest;
        Some(group)
    }

    /// Drain the queue; returns responses in completion order.
    pub fn run_until_idle(&mut self) -> Result<Vec<GenResponse>> {
        // peqa-lint: allow(nondeterminism-sources) -- batch wall clock
        // for the throughput metric; group order is id-deterministic.
        let wall0 = Instant::now();
        let mut responses = Vec::new();
        while let Some(group) = self.next_group() {
            let task = group[0].0.task.clone();
            self.switch_task(&task)?;
            // peqa-lint: allow(nondeterminism-sources) -- service start
            // stamp for queue/latency metrics only.
            let started = Instant::now();
            let outputs = self.decode_group(&group)?;
            for ((req, submitted), tokens) in group.into_iter().zip(outputs) {
                self.metrics.completed += 1;
                self.metrics.generated_tokens += tokens.len();
                let queue_s = (started - submitted).as_secs_f64();
                let latency_s = submitted.elapsed().as_secs_f64();
                self.metrics.latencies_s.push(latency_s);
                self.metrics.queue_s.push(queue_s);
                responses.push(GenResponse { id: req.id, task: task.clone(), tokens, queue_s, latency_s });
            }
        }
        self.metrics.wall_s += wall0.elapsed().as_secs_f64();
        Ok(responses)
    }

    /// Synchronized batched greedy decode for one same-task group.
    fn decode_group(&mut self, group: &[(GenRequest, Instant)]) -> Result<Vec<Vec<u32>>> {
        let b = self.model.batch_size();
        let t = self.model.seq_len();
        let vocab = self.model.meta().outputs[0].shape[2];
        let mut seqs: Vec<Vec<u32>> = group.iter().map(|(r, _)| r.prompt.clone()).collect();
        let mut outs: Vec<Vec<u32>> = vec![Vec::new(); group.len()];
        let mut done: Vec<bool> = group
            .iter()
            .map(|(r, _)| r.max_new == 0 || r.prompt.is_empty())
            .collect();
        let max_new = group.iter().map(|(r, _)| r.max_new).max().unwrap_or(0);

        for _ in 0..max_new {
            if done.iter().all(|&d| d) {
                break;
            }
            // Build the (B, T) token block: each live row is its window.
            let mut tokens = vec![PAD as i32; b * t];
            let mut positions = vec![0usize; group.len()];
            for (i, seq) in seqs.iter().enumerate() {
                if done[i] {
                    continue;
                }
                let window = if seq.len() > t { &seq[seq.len() - t..] } else { &seq[..] };
                for (j, &id) in window.iter().enumerate() {
                    tokens[i * t + j] = id as i32;
                }
                positions[i] = window.len() - 1;
            }
            let logits = self.model.logits(&self.rt, &tokens)?;
            self.metrics.decode_steps += 1;
            for i in 0..group.len() {
                if done[i] {
                    continue;
                }
                let row = &logits[(i * t + positions[i]) * vocab..(i * t + positions[i] + 1) * vocab];
                // NaN-safe greedy argmax (total_cmp): one degenerate
                // logits row must not panic the whole decode group.
                let next = crate::util::argmax_f32(row).unwrap_or(0) as u32;
                let (req, _) = &group[i];
                if next == req.stop || outs[i].len() + 1 >= req.max_new {
                    if next != req.stop {
                        outs[i].push(next);
                        seqs[i].push(next);
                    }
                    done[i] = true;
                } else {
                    outs[i].push(next);
                    seqs[i].push(next);
                }
            }
        }
        Ok(outs)
    }

    pub fn tasks(&self) -> Vec<&str> {
        self.adapters.tasks()
    }
}

// The AdapterStore / ServeMetrics unit tests moved with the types to
// serve::types, where they run in the default (no-xla) tier-1 build.
