//! Host serving engine — packed transformer decode + KV cache +
//! multi-task scale-swap scheduling, no `xla` feature required.
//!
//! This subsystem is the paper's deployment story executed end to end on
//! a plain host: a [`model::PackedModel`](crate::model::PackedModel)
//! keeps its sub-4-bit integer codes bit-packed in memory, every block
//! projection of the decode loop runs through the fused quantized GEMM
//! (`quant::kernels`), and a task is nothing but f32 scale/zero vectors
//! that swap in microseconds while the codes never move.
//!
//! Layout:
//! * [`types`] — the serving vocabulary ([`AdapterStore`], [`GenRequest`],
//!   [`GenResponse`], [`BatcherConfig`], [`ServeMetrics`]) shared with the
//!   xla `coordinator`, compiled unconditionally.
//! * [`kvcache`] — preallocated per-sequence K/V ring buffers with
//!   incremental append (sliding-window attention past capacity) and
//!   contiguous window-slab access for the head-blocked attention
//!   kernel; [`KvSeq`] fronts both this ring and the paged backend.
//! * [`kvpage`] — paged KV memory: fixed-size pages from a bitmap
//!   allocator ([`PagePool`], `--kv-pages`/`--page-tokens`), per-sequence
//!   page tables, and copy-on-write prompt-prefix sharing across
//!   same-task requests. The ring stays in-tree as the bitwise oracle.
//! * [`engine`] — the transformer forward from a packed model
//!   (embedding gather, RMSNorm, rotary, head-blocked causal attention
//!   over the cache, SwiGLU MLP, fp LM head) with a per-engine scratch
//!   arena (activation slabs reused across decode steps and prefill
//!   chunks — no per-call allocation on the steady-state loop),
//!   cross-request prefill batching ([`Engine::prefill_batch`]),
//!   scale-swap task switching, greedy/top-k sampling, and the dense
//!   `matmul_naive` references (full and sliding-window) the engine is
//!   parity-tested against.
//! * [`scheduler`] — continuous batching over multiple tasks (per-task
//!   indexed queue, capacity-keyed KV-cache recycling, cross-request
//!   prefill admission) with swap latency recorded into
//!   `ServeMetrics::swap_times_s`.
//! * [`server`] — the concurrent-client wrapper: one worker thread owns
//!   the [`Scheduler`], clients submit/await over an mpsc channel
//!   (bursts of concurrent requests become one batched drain), with
//!   optional token streaming ([`ServerHandle::submit_stream`]).
//! * [`dispatch`] — the pool's admission control: bounded per-task
//!   ingress queues (typed [`ServeError::Overloaded`] backpressure),
//!   deadline shedding, task-affine batch handout.
//! * [`pool`] — the sharded engine pool: N workers, each a full
//!   [`Scheduler`] over a clone of the packed model (codes shared via
//!   `Arc`, scales/zeros + KV + arena per worker), fed by one
//!   [`dispatch::Dispatcher`]; token streaming and registry hot-reload
//!   included. `peqa serve --engines N` routes here.
//!
//! ## Scale-swap contract
//!
//! Packed integer codes are immutable for the life of an [`Engine`];
//! [`Engine::apply_adapter`] replaces the f32 scale/zero tensors of the
//! projections the adapter covers and restores the construction-time
//! base scales/zeros on every projection it does not cover — engine
//! state after a swap depends only on the adapter applied, never on the
//! sequence of previous swaps (no partial-coverage residue). Deployments
//! that would rather surface coverage mismatches than serve base
//! fallbacks set `BatcherConfig::strict_coverage` /
//! `SchedulerConfig::strict_coverage` (CLI: `peqa serve --strict`):
//! registration then rejects any adapter with coverage gaps
//! ([`Engine::adapter_coverage_gaps`]). Adapters need not be
//! hand-built: `train::HostPeqaTuner` / `peqa finetune` produce them
//! directly from host PEQA fine-tuning
//! (`model::PackedModel::extract_adapter`).
//!
//! Entry points: `peqa serve` (CLI demo over a synthesized or on-disk
//! `.packed` model; `--clients N` routes it through the threaded
//! [`server`]), `benches/serve_decode.rs` (writes BENCH_serve.json),
//! `tests/serve_host.rs` (decode parity + determinism + concurrency).

pub mod dispatch;
pub mod engine;
pub mod kvcache;
pub mod kvpage;
pub mod pool;
pub mod scheduler;
pub mod server;
pub mod types;

pub use dispatch::{DispatchConfig, Dispatcher};
pub use engine::{
    argmax, reference_forward, reference_forward_windowed, sample, Engine, ModelGeom, Sampling,
};
pub use kvcache::{KvCache, KvSeq};
pub use kvpage::{PageAllocator, PagePool, PagedKvCache, DEFAULT_PAGE_TOKENS};
pub use pool::{EnginePool, PoolConfig, PoolHandle, STREAM_CHANNEL_CAP};
pub use scheduler::{Scheduler, SchedulerConfig};
pub use server::{Server, ServerHandle};
pub use types::{
    collect_stream, AdapterStore, BatcherConfig, GenRequest, GenResponse, ServeError,
    ServeMetrics, StreamEvent,
};

use anyhow::Result;

use crate::model::{Checkpoint, PackedModel};
use crate::tensor::Tensor;
use crate::util::Pcg32;

/// Deterministically initialize a small fp llama-family checkpoint with
/// the canonical PEQA tensor names — the base model for serving demos,
/// benches and tests when no pretrained `.packed` file is at hand.
pub fn synth_fp_base(geom: &ModelGeom, seed: u64) -> Checkpoint {
    let mut rng = Pcg32::seeded(seed, 0x5e7e);
    let (v, d, f) = (geom.vocab, geom.d_model, geom.d_ff);
    let mut ck = Checkpoint::new();
    ck.insert("embed", Tensor::normal(&[v, d], 0.06, &mut rng));
    for i in 0..geom.n_layers {
        let lp = format!("layers.{i}");
        ck.insert(format!("{lp}.ln1.g"), Tensor::ones(&[d]));
        ck.insert(format!("{lp}.ln2.g"), Tensor::ones(&[d]));
        for p in ["attn.q", "attn.k", "attn.v", "attn.o"] {
            ck.insert(format!("{lp}.{p}.w"), Tensor::normal(&[d, d], 0.08, &mut rng));
        }
        ck.insert(format!("{lp}.mlp.gate.w"), Tensor::normal(&[f, d], 0.08, &mut rng));
        ck.insert(format!("{lp}.mlp.up.w"), Tensor::normal(&[f, d], 0.08, &mut rng));
        ck.insert(format!("{lp}.mlp.down.w"), Tensor::normal(&[d, f], 0.08, &mut rng));
    }
    ck.insert("final_norm.g", Tensor::ones(&[d]));
    ck.insert("lm_head", Tensor::normal(&[v, d], 0.06, &mut rng));
    ck
}

/// Synthesize, RTN-quantize and pack a demo model in one step. Returns
/// the in-memory [`PackedModel`] plus the PEQA-layout quantized
/// checkpoint it was packed from (the source of adapters and of the
/// dequantized parity reference).
pub fn synth_packed(
    geom: &ModelGeom,
    bits: u8,
    group: Option<usize>,
    seed: u64,
) -> Result<(PackedModel, Checkpoint)> {
    let fp = synth_fp_base(geom, seed);
    let q = crate::pipeline::rtn_quantize(&fp, bits, group)?;
    let pm = PackedModel::from_checkpoint(&q, bits)?;
    Ok((pm, q))
}

/// Build one adapter per task from a quantized base checkpoint. The
/// first task serves the base scales unchanged; each later task applies
/// a deterministic per-element jitter to the scale tensors, standing in
/// for per-task fine-tuned s₀+Δs. Zero-points ride along unchanged, so
/// every adapter covers the full (s, z) tensor set of every projection.
pub fn synth_adapters(base_q: &Checkpoint, tasks: &[&str], seed: u64) -> AdapterStore {
    let mut store = AdapterStore::new();
    let mut rng = Pcg32::seeded(seed, 0xada9);
    for (ti, task) in tasks.iter().enumerate() {
        let mut adapter = base_q.extract_adapter(true);
        if ti > 0 {
            let names = adapter.names().to_vec();
            for name in names {
                if name.ends_with(".s") {
                    // peqa-lint: allow(panic-free-paths) -- `name` came
                    // from this adapter's own names() two lines up;
                    // demo/bench helper, not the request path.
                    let mut t = adapter.get(&name).expect("just listed").clone();
                    for v in t.data_mut() {
                        *v *= 1.0 + 0.2 * (rng.f32() - 0.5);
                    }
                    adapter.insert(name, t);
                }
            }
        }
        store.insert(*task, adapter);
    }
    store
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synth_model_serves_and_adapters_differ() {
        let geom = ModelGeom { vocab: 48, d_model: 16, n_layers: 2, n_heads: 2, d_ff: 32 };
        let (pm, base_q) = synth_packed(&geom, 3, Some(8), 9).unwrap();
        let inferred = ModelGeom::infer(&pm, 2).unwrap();
        assert_eq!(inferred, geom);
        let store = synth_adapters(&base_q, &["x", "y", "z"], 1);
        assert_eq!(store.tasks(), vec!["x", "y", "z"]);
        // Task x is the base; y and z are perturbed and mutually distinct.
        let s0 = store.get("x").unwrap().req("layers.0.attn.q.s").unwrap();
        let s1 = store.get("y").unwrap().req("layers.0.attn.q.s").unwrap();
        let s2 = store.get("z").unwrap().req("layers.0.attn.q.s").unwrap();
        assert_eq!(s0, base_q.req("layers.0.attn.q.s").unwrap());
        assert!(s0.max_abs_diff(s1) > 0.0);
        assert!(s1.max_abs_diff(s2) > 0.0);
        // Determinism: same seed, same adapters.
        let store2 = synth_adapters(&base_q, &["x", "y", "z"], 1);
        assert_eq!(
            store.get("y").unwrap().req("layers.0.attn.q.s").unwrap(),
            store2.get("y").unwrap().req("layers.0.attn.q.s").unwrap()
        );
    }
}
